//! Quickstart: run the paper's 123-doubling exclusive scan (Algorithm 1)
//! on a 36-rank world, verify against the sequential oracle, and show the
//! round/⊕ accounting of Theorem 1.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use exscan::coll::validate::oracle_exscan;
use exscan::prelude::*;

fn main() -> anyhow::Result<()> {
    let p = 36; // the paper's small configuration
    let m = 8; // elements per rank
    let op = ops::bxor(); // MPI_BXOR over MPI_LONG, as in the paper

    // Each rank contributes an m-element vector.
    let inputs: Vec<Vec<i64>> =
        (0..p).map(|r| (0..m).map(|i| ((r * 17 + i) as i64) << 3).collect()).collect();

    // Real thread transport with tracing on.
    let world = WorldConfig::new(Topology::flat(p)).with_trace(true);
    let result = run_scan(&world, &Exscan123, &op, &inputs)?;

    // Verify: rank r holds V_0 ⊕ … ⊕ V_{r-1} (rank 0 undefined).
    let oracle = oracle_exscan(&inputs, &ops::bxor());
    for r in 1..p {
        assert_eq!(&result.outputs[r], oracle[r].as_ref().unwrap(), "rank {r}");
    }
    println!("✓ exclusive prefix sums verified on {p} ranks × {m} elements");

    // Theorem 1 accounting, straight from the trace.
    let trace = result.trace.unwrap();
    let algo: &dyn ScanAlgorithm<i64> = &Exscan123;
    println!(
        "rounds: {} (= ⌈log2(p-1) + log2(4/3)⌉ = {}), ⊕ on last rank: {} (= q-1 = {})",
        trace.total_rounds(),
        algo.predicted_rounds(p),
        trace.last_rank_ops(),
        algo.predicted_ops(p),
    );
    assert!(exscan::trace::check_all(&trace).is_empty());
    println!("one-ported send-receive invariant: OK");

    // Compare against the conventional algorithms.
    println!("\nround/⊕ counts at p = {p}:");
    for algo in exscan::coll::paper_exscan_algorithms::<i64>() {
        println!(
            "  {:>18}: {} rounds, {} ⊕",
            algo.name(),
            algo.predicted_rounds(p),
            algo.predicted_ops(p)
        );
    }
    Ok(())
}
