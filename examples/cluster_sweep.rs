//! The paper's evaluation, end to end: sweep the four MPI_Exscan
//! algorithms over message sizes on both simulated cluster configurations
//! (36×1 and 36×32), print Table-1-style output and write the Figure 1
//! CSV. This is the examples/ driver for experiments E1–E3 of DESIGN.md.
//!
//! ```bash
//! cargo run --release --example cluster_sweep            # quick grid
//! cargo run --release --example cluster_sweep -- --full  # paper grid
//! ```

use exscan::bench::{figure1_sweep, format_table, table1_rows, to_csv, PaperConfig, SweepSpec};

fn main() -> anyhow::Result<()> {
    let full = std::env::args().any(|a| a == "--full");
    let spec = if full { SweepSpec::figure1() } else { SweepSpec::quick() };
    let table_grid: &[usize] =
        if full { &[1, 10, 100, 1000, 10_000, 100_000] } else { &[1, 1000, 100_000] };

    let mut csv = String::new();
    for config in [PaperConfig::C36x1, PaperConfig::C36x32] {
        println!("== {} : Table 1 (simulated µs vs paper µs) ==", config.label());
        let rows = table1_rows(config, table_grid)?;
        let paper = config.paper_rows();
        println!(
            "{:>8} {:>10} {:>10} {:>10} {:>10}   (paper: nat/2op/1dbl/123)",
            "m", "native", "two-op", "1-dbl", "123"
        );
        for row in &rows {
            let pp = paper.iter().find(|x| x.0 == row.m);
            let paper_s = pp
                .map(|x| format!("({:.0}/{:.0}/{:.0}/{:.0})", x.1, x.2, x.3, x.4))
                .unwrap_or_default();
            println!(
                "{:>8} {:>10.2} {:>10.2} {:>10.2} {:>10.2}   {paper_s}",
                row.m, row.native, row.two_op, row.one_doubling, row.otd123
            );
            // The paper's headline: 123-doubling never loses to 1-doubling.
            assert!(row.otd123 <= row.one_doubling + 1e-9);
        }
        println!();

        let ms = figure1_sweep(config, &spec)?;
        println!("{}", format_table(&format!("Figure 1 series ({})", config.label()), &ms));
        let part = to_csv(config.label(), &ms);
        if csv.is_empty() {
            csv = part;
        } else {
            csv.push_str(part.split_once('\n').map(|x| x.1).unwrap_or(""));
        }
    }
    std::fs::write("figure1.csv", &csv)?;
    println!("wrote figure1.csv ({} lines) — plot time-vs-bytes, log-log", csv.lines().count());
    Ok(())
}
