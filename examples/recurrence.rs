//! Solving linear recurrences with the exclusive scan — the classic
//! "scans as primitive parallel operations" application ([Blelloch 89],
//! the paper's reference [1]).
//!
//! Each rank holds a chunk of the recurrence
//! `x_i = A_i · x_{i-1} + b_i` (2×2 affine maps). The composition of a
//! chunk's maps is one [`Rec2`] element; an **exclusive** scan over ranks
//! hands every rank the composed map of everything before it — exactly
//! the quantity it needs to evaluate its chunk locally. This is why
//! `MPI_Exscan` (not `MPI_Scan`) is "the more important variant" (§1).
//!
//! ```bash
//! cargo run --release --example recurrence
//! ```

use exscan::prelude::*;

fn main() -> anyhow::Result<()> {
    let p = 24; // ranks
    let chunk = 50; // recurrence steps per rank
    let x0 = [1.0f32, 0.5];

    // Deterministic well-conditioned coefficients (rotation-ish).
    let coeffs: Vec<Vec<Rec2>> = exscan::bench::inputs_rec2(p, chunk, 42);

    // Each rank composes its own chunk locally (sequential part).
    let chunk_maps: Vec<Rec2> = coeffs
        .iter()
        .map(|c| c.iter().fold(Rec2::identity(), |acc, e| acc.then(e)))
        .collect();

    // Exclusive scan over the chunk compositions with the non-commutative
    // affine operator — the paper's Algorithm 1 under an expensive ⊕.
    let inputs: Vec<Vec<Rec2>> = chunk_maps.iter().map(|m| vec![*m]).collect();
    let world = WorldConfig::new(Topology::flat(p));
    let res = run_scan(&world, &Exscan123, &ops::rec2_compose(), &inputs)?;

    // Every rank now evaluates its chunk from the scanned prefix state.
    let mut parallel = Vec::new();
    for r in 0..p {
        let prefix = if r == 0 { Rec2::identity() } else { res.outputs[r][0] };
        let mut x = prefix.apply(x0);
        // subtract the initial apply: prefix.apply already includes x0 → x_start
        // then run the local chunk.
        for e in &coeffs[r] {
            x = e.apply(x);
        }
        parallel.push(x);
    }

    // Sequential reference.
    let mut x = x0;
    let mut reference = Vec::new();
    for c in &coeffs {
        for e in c {
            x = e.apply(x);
        }
        reference.push(x);
    }

    let mut max_err = 0f32;
    for r in 0..p {
        for i in 0..2 {
            max_err = max_err.max((parallel[r][i] - reference[r][i]).abs());
        }
    }
    println!("✓ linear recurrence of {} steps solved on {p} ranks", p * chunk);
    println!("  max |parallel − sequential| = {max_err:.3e}");
    assert!(max_err < 1e-2, "recurrence diverged: {max_err}");

    // The ⊕ count is what matters for expensive operators: compare.
    println!("\n⊕ applications (critical rank) at p = {p}:");
    for algo in exscan::coll::paper_exscan_algorithms::<Rec2>() {
        println!("  {:>18}: {}", algo.name(), algo.predicted_ops(p));
    }
    Ok(())
}
