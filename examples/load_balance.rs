//! Load balancing with exclusive prefix sums — the bookkeeping use case
//! from the paper's introduction (and [Copik et al.], reference [2]):
//! p workers each produce a variable number of items; the exclusive scan
//! of the counts gives every worker the global offset at which to write
//! its items, turning a distributed "where do my results go?" problem
//! into one collective call.
//!
//! ```bash
//! cargo run --release --example load_balance
//! ```

use exscan::prelude::*;
use exscan::util::Rng;

fn main() -> anyhow::Result<()> {
    let p = 64;

    // Every worker "produces" a random number of items (skewed workload).
    let mut rng = Rng::seed_from_u64(7);
    let counts: Vec<i64> = (0..p)
        .map(|_| {
            let heavy = rng.gen_f64() < 0.2;
            if heavy {
                500 + rng.gen_range_usize(1500) as i64
            } else {
                rng.gen_range_usize(100) as i64
            }
        })
        .collect();

    // Exclusive scan of counts under + gives each worker its offset.
    let inputs: Vec<Vec<i64>> = counts.iter().map(|&c| vec![c]).collect();
    let world = WorldConfig::new(Topology::flat(p)).with_trace(true);
    let res = run_scan(&world, &Exscan123, &ops::sum_i64(), &inputs)?;

    // Verify the offsets: worker r writes at [offset_r, offset_r + count_r).
    let mut expect = 0i64;
    for r in 0..p {
        let offset = if r == 0 { 0 } else { res.outputs[r][0] };
        assert_eq!(offset, expect, "worker {r} offset");
        expect += counts[r];
    }
    let total = expect;
    println!("✓ {p} workers, {total} items: offsets verified, no gaps, no overlaps");

    // Simulate the actual scatter to prove the offsets work.
    let mut global = vec![-1i64; total as usize];
    for r in 0..p {
        let offset = if r == 0 { 0 } else { res.outputs[r][0] } as usize;
        for i in 0..counts[r] as usize {
            global[offset + i] = r as i64;
        }
    }
    assert!(global.iter().all(|&x| x >= 0), "coverage hole");
    println!("✓ scatter complete: every slot written exactly once");

    let trace = res.trace.unwrap();
    println!(
        "cost: {} communication rounds, {} total messages, {} bytes",
        trace.total_rounds(),
        trace.total_messages(),
        trace.total_bytes()
    );
    Ok(())
}
