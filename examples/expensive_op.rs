//! The expensive-⊕ path through the full three-layer stack: the operator
//! is the AOT-compiled Pallas `matrec` kernel (2×2 affine recurrence
//! composition) executed via PJRT from the Rust hot path — every ⊕
//! application is a real kernel launch, so the paper's ⊕-application
//! counts translate directly into launches you can count.
//!
//! Requires artifacts: `make artifacts` first.
//!
//! ```bash
//! cargo run --release --example expensive_op
//! ```

use exscan::coll::validate::oracle_exscan;
use exscan::prelude::*;
use exscan::runtime::{pjrt_rec2_compose, PjrtRuntime};

fn main() -> anyhow::Result<()> {
    let Some(handle) = PjrtRuntime::try_default() else {
        eprintln!("artifacts not built — run `make artifacts` first");
        std::process::exit(2);
    };

    let p = 16;
    let m = 64; // 64 affine maps per rank
    let inputs = exscan::bench::inputs_rec2(p, m, 99);
    let world = WorldConfig::new(Topology::flat(p));

    // ⊕ = compiled Pallas kernel via PJRT (Layer 1 on the request path).
    let kernel_op = pjrt_rec2_compose(handle.clone());

    println!("running {} algorithms with the PJRT matrec kernel as ⊕ …", 2);
    for algo in [&Exscan123 as &dyn ScanAlgorithm<Rec2>, &ExscanTwoOp] {
        let before = handle.stats()?.launches;
        let res = run_scan(&world, algo, &kernel_op, &inputs)?;
        let launches = handle.stats()?.launches - before;

        // Verify against the native-Rust oracle operator.
        let oracle = oracle_exscan(&inputs, &ops::rec2_compose());
        for r in 1..p {
            let expect = oracle[r].as_ref().unwrap();
            for (a, b) in res.outputs[r].iter().zip(expect) {
                for i in 0..4 {
                    assert!((a.a[i] - b.a[i]).abs() < 1e-2, "rank {r} mismatch");
                }
            }
        }
        println!(
            "  {:>16}: verified; {} kernel launches across all ranks \
             (critical-rank ⊕ = {})",
            algo.name(),
            launches,
            algo.predicted_ops(p),
        );
    }
    println!(
        "\nthe two-⊕ algorithm launches ~2× the kernels of 123-doubling — \
         the computation cost Theorem 1 eliminates"
    );
    Ok(())
}
