//! PJRT kernel-launch microbenchmark (§Perf, L1): per-launch latency of
//! the AOT-compiled Pallas `reduce_local` kernel across artifact sizes.
//! This is the number the single-block lowering optimization moved from
//! 12.7 ms to ~3 ms at m = 131072 (see EXPERIMENTS.md §Perf).
//!
//! ```bash
//! make artifacts && cargo run --release --example pjrt_bench
//! ```

fn main() -> anyhow::Result<()> {
    let h = exscan::runtime::PjrtRuntime::start("artifacts")?;
    for (op, n) in [("bxor_i64", 256usize), ("bxor_i64", 4096), ("bxor_i64", 131072)] {
        let a = vec![1i64; n];
        let mut b = vec![2i64; n];
        h.reduce_i64(op, &a, &mut b)?; // warm-up (includes compile)
        let t0 = std::time::Instant::now();
        let iters = 50;
        for _ in 0..iters {
            h.reduce_i64(op, &a, &mut b)?;
        }
        println!(
            "{op} m={n}: {:.1} µs/launch",
            t0.elapsed().as_secs_f64() * 1e6 / iters as f64
        );
    }
    let stats = h.stats()?;
    println!("total: {} launches, {} compiles", stats.launches, stats.compiles);
    Ok(())
}
