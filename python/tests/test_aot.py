"""AOT pipeline checks: lowering produces loadable HLO text and a manifest
the Rust side can parse (same format constants on both sides)."""

import os

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import pytest

from compile import aot, model


def test_to_hlo_text_reduce():
    spec = jax.ShapeDtypeStruct((256,), jnp.int64)
    text = aot.to_hlo_text(model.reduce_local_fn("bxor"), spec, spec)
    assert "HloModule" in text
    assert "s64" in text  # i64 dtype survived lowering
    # return_tuple contract: the entry computation returns a tuple.
    assert "(s64[256]" in text.replace("\n", "")


def test_to_hlo_text_matrec():
    spec = jax.ShapeDtypeStruct((64, 6), jnp.float32)
    text = aot.to_hlo_text(model.matrec_fn(), spec, spec)
    assert "HloModule" in text
    assert "f32[64,6]" in text


def test_emit_and_manifest(tmp_path):
    # Shrink the size ladders so the test stays fast.
    old_sizes = aot.REDUCE_SIZES, aot.MATREC_SIZES, aot.BLOCK_SIZES, aot.REDUCE_OPS
    aot.REDUCE_SIZES = [256]
    aot.MATREC_SIZES = [64]
    aot.BLOCK_SIZES = [64]
    aot.REDUCE_OPS = [("bxor", jnp.int64, "bxor_i64", "i64")]
    try:
        rows = aot.emit(str(tmp_path))
        aot.write_manifest(str(tmp_path), rows)
    finally:
        aot.REDUCE_SIZES, aot.MATREC_SIZES, aot.BLOCK_SIZES, aot.REDUCE_OPS = old_sizes

    manifest = (tmp_path / "manifest.tsv").read_text().splitlines()
    assert manifest[0].startswith("exscan-artifacts v1 jax=")
    assert len(manifest) == 1 + len(rows)
    for line in manifest[1:]:
        cols = line.split("\t")
        assert len(cols) == 7
        assert os.path.exists(tmp_path / cols[6])
        text = (tmp_path / cols[6]).read_text()
        assert text.startswith("HloModule")


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.tsv")),
    reason="artifacts not built (run `make artifacts`)",
)
def test_built_manifest_is_complete():
    path = os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.tsv")
    lines = open(path).read().splitlines()
    names = {line.split("\t")[0] for line in lines[1:]}
    # The runtime's lookup ladder must be present.
    for m in aot.REDUCE_SIZES:
        assert f"reduce_bxor_i64_m{m}" in names
    assert any(n.startswith("reduce_matrec_f32") for n in names)
    assert any(n.startswith("block_exscan_bxor_i64") for n in names)
