"""Layer-1 correctness: Pallas kernels vs the pure-jnp oracles in ref.py.

Hypothesis sweeps shapes and dtypes; exact equality for integer ops,
allclose for floats. This is the CORE correctness signal for the compiled
artifacts — everything the Rust runtime executes goes through these
kernels.
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels import reduce_local as k

INT_OPS = ["bxor", "bor", "sum", "max", "min"]
FLOAT_OPS = ["sum", "max", "min", "prod"]


def rand_ints(rng, shape, dtype):
    return jnp.asarray(
        rng.integers(np.iinfo(np.int64).min // 2, np.iinfo(np.int64).max // 2, size=shape),
        dtype=dtype,
    )


@settings(max_examples=40, deadline=None)
@given(
    op=st.sampled_from(INT_OPS),
    m=st.sampled_from([1, 2, 7, 100, 256, 1000, 4096, 5000]),
    seed=st.integers(0, 2**31 - 1),
)
def test_reduce_local_int_matches_ref(op, m, seed):
    rng = np.random.default_rng(seed)
    a = rand_ints(rng, (m,), jnp.int64)
    b = rand_ints(rng, (m,), jnp.int64)
    got = k.reduce_local(op, a, b)
    want = ref.reduce_local_ref(op, a, b)
    assert got.dtype == want.dtype
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=30, deadline=None)
@given(
    op=st.sampled_from(FLOAT_OPS),
    m=st.sampled_from([1, 3, 128, 1000, 4096]),
    dtype=st.sampled_from([jnp.float32, jnp.float64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_reduce_local_float_matches_ref(op, m, dtype, seed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.standard_normal(m), dtype=dtype)
    b = jnp.asarray(rng.standard_normal(m), dtype=dtype)
    got = k.reduce_local(op, a, b)
    want = ref.reduce_local_ref(op, a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    n=st.sampled_from([1, 2, 33, 256, 1000]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matrec_matches_ref(n, seed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.standard_normal((n, 6)), dtype=jnp.float32)
    b = jnp.asarray(rng.standard_normal((n, 6)), dtype=jnp.float32)
    got = k.matrec_compose(a, b)
    want = ref.matrec_compose_ref(a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)


def test_matrec_identity():
    n = 8
    ident = jnp.tile(jnp.asarray([1, 0, 0, 1, 0, 0], dtype=jnp.float32), (n, 1))
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((n, 6)), dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(k.matrec_compose(ident, x)), np.asarray(x), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(k.matrec_compose(x, ident)), np.asarray(x), rtol=1e-6)


def test_matrec_associative():
    rng = np.random.default_rng(7)
    xs = [jnp.asarray(rng.standard_normal((16, 6)) * 0.5, dtype=jnp.float32) for _ in range(3)]
    ab_c = k.matrec_compose(k.matrec_compose(xs[0], xs[1]), xs[2])
    a_bc = k.matrec_compose(xs[0], k.matrec_compose(xs[1], xs[2]))
    np.testing.assert_allclose(np.asarray(ab_c), np.asarray(a_bc), rtol=1e-3, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(
    op=st.sampled_from(["bxor", "sum"]),
    km=st.tuples(st.sampled_from([1, 2, 8, 32]), st.sampled_from([1, 64, 256, 1000])),
    seed=st.integers(0, 2**31 - 1),
)
def test_block_exscan_matches_ref(op, km, seed):
    kk, m = km
    rng = np.random.default_rng(seed)
    x = rand_ints(rng, (kk, m), jnp.int64)
    got = k.block_exscan(op, x)
    want = ref.block_exscan_ref(op, x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_block_exscan_row0_is_identity():
    x = jnp.ones((4, 16), dtype=jnp.int64)
    out = k.block_exscan("sum", x)
    assert int(jnp.sum(jnp.abs(out[0]))) == 0
    np.testing.assert_array_equal(np.asarray(out[3]), 3 * np.ones(16))


def test_reduce_local_empty():
    a = jnp.zeros((0,), dtype=jnp.int64)
    assert k.reduce_local("bxor", a, a).shape == (0,)


def test_reduce_local_rejects_shape_mismatch():
    a = jnp.zeros((4,), dtype=jnp.int64)
    b = jnp.zeros((5,), dtype=jnp.int64)
    with pytest.raises(AssertionError):
        k.reduce_local("bxor", a, b)


def test_tile_for_divides():
    for m in [1, 2, 3, 100, 256, 1000, 4096, 5000, 131072]:
        t = k._tile_for(m)
        assert m % t == 0
        assert 1 <= t <= k.TILE


@settings(max_examples=20, deadline=None)
@given(
    m=st.sampled_from([256, 1000, 4096, 8192]),
    tile=st.sampled_from([None, 64, 256, 1024, 4096]),
    seed=st.integers(0, 2**31 - 1),
)
def test_reduce_local_tiling_invariant(m, tile, seed):
    """The result must be identical for every legal tiling (single-block
    CPU lowering vs TPU-shaped grids) — tiling is layout, not semantics."""
    rng = np.random.default_rng(seed)
    a = rand_ints(rng, (m,), jnp.int64)
    b = rand_ints(rng, (m,), jnp.int64)
    got = k.reduce_local("bxor", a, b, tile=tile)
    want = ref.reduce_local_ref("bxor", a, b)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_block_exscan_tiling_invariant():
    rng = np.random.default_rng(5)
    x = rand_ints(rng, (8, 512), jnp.int64)
    base = np.asarray(k.block_exscan("sum", x, tile=None))
    for tile in [64, 128, 512]:
        np.testing.assert_array_equal(np.asarray(k.block_exscan("sum", x, tile=tile)), base)
