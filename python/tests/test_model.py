"""Layer-2 checks: the jit-able model functions compose the kernels
correctly and preserve shapes/dtypes under jit."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from compile import model
from compile.kernels import ref


def test_reduce_local_fn_tuple_contract():
    fn = model.reduce_local_fn("bxor")
    a = jnp.arange(64, dtype=jnp.int64)
    b = jnp.arange(64, dtype=jnp.int64) * 3
    out = fn(a, b)
    assert isinstance(out, tuple) and len(out) == 1
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(a ^ b))


def test_reduce_local_fn_jits():
    fn = jax.jit(model.reduce_local_fn("sum"))
    a = jnp.ones(256, dtype=jnp.int64)
    (out,) = fn(a, a)
    assert int(out[0]) == 2
    assert out.dtype == jnp.int64


def test_matrec_fn_against_ref():
    rng = np.random.default_rng(3)
    a = jnp.asarray(rng.standard_normal((32, 6)), dtype=jnp.float32)
    b = jnp.asarray(rng.standard_normal((32, 6)), dtype=jnp.float32)
    (got,) = jax.jit(model.matrec_fn())(a, b)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref.matrec_compose_ref(a, b)), rtol=1e-4, atol=1e-5
    )


def test_block_exscan_fn_shape():
    x = jnp.arange(32 * 16, dtype=jnp.int64).reshape(32, 16)
    (out,) = jax.jit(model.block_exscan_fn("bxor"))(x)
    assert out.shape == (32, 16)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref.block_exscan_ref("bxor", x)))


def test_exclusive_scan_composition_property():
    """Chaining reduce_local over ranks reproduces the exclusive scan —
    the exact composition the Rust coordinator performs."""
    rng = np.random.default_rng(11)
    p, m = 9, 40
    inputs = [jnp.asarray(rng.integers(-1 << 40, 1 << 40, m), dtype=jnp.int64) for _ in range(p)]
    fn = model.reduce_local_fn("bxor")
    acc = inputs[0]
    prefixes = [None, acc]
    for r in range(1, p - 1):
        (acc,) = fn(acc, inputs[r])
        prefixes.append(acc)
    for r in range(1, p):
        want = inputs[0]
        for i in range(1, r):
            want = want ^ inputs[i]
        np.testing.assert_array_equal(np.asarray(prefixes[r]), np.asarray(want))
