"""Layer-2 JAX "model": the jit-able compute graphs that get AOT-lowered.

For this paper the compute graph is not a neural network but the local
computation of the exclusive-scan machinery:

* ``reduce_local_fn`` — one ⊕ application (`MPI_Reduce_local`), calling
  the Layer-1 Pallas combine kernel. One artifact per (op, dtype, m).
* ``matrec_fn`` — the expensive non-commutative operator (2×2 affine
  recurrence composition), the ablation where ⊕-application counts bite.
* ``block_exscan_fn`` — the fused node-leader kernel: exclusive scan over
  the K rank-contributions of one node in a single launch, used by the
  hierarchical aggregation path instead of K−1 reduce_local launches.

Each function returns a tuple (the AOT contract: lowered with
``return_tuple=True``, unwrapped by the Rust side with ``to_tuple1``).
Python never runs at request time — these exist solely to be lowered by
``aot.py``.
"""

from __future__ import annotations

import jax

from .kernels import reduce_local as k

jax.config.update("jax_enable_x64", True)


def reduce_local_fn(op: str):
    """⊕ over two m-vectors: (earlier, later) -> (earlier ⊕ later,)."""

    def fn(earlier, later):
        return (k.reduce_local(op, earlier, later),)

    fn.__name__ = f"reduce_local_{op}"
    return fn


def matrec_fn():
    """(N, 6) affine-map composition: (earlier, later) -> (later ∘ earlier,)."""

    def fn(earlier, later):
        return (k.matrec_compose(earlier, later),)

    return fn


def block_exscan_fn(op: str):
    """(K, M) -> (K, M) exclusive scan over rows (node-leader fusion)."""

    def fn(x):
        return (k.block_exscan(op, x),)

    fn.__name__ = f"block_exscan_{op}"
    return fn
