"""AOT emitter: lower every Layer-2 function to HLO **text** and write the
artifact manifest the Rust runtime consumes.

HLO text — NOT ``lowered.compile()`` / serialized protos — is the
interchange format: jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids which xla_extension 0.5.1 (the version the published
``xla`` crate binds) rejects; the text parser reassigns ids and
round-trips cleanly. See /opt/xla-example/README.md.

Usage: ``python -m compile.aot --out ../artifacts`` (what `make
artifacts` runs). Idempotent: skips lowering when the manifest is newer
than this package's sources.
"""

from __future__ import annotations

import argparse
import os
import sys

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402

# Padded kernel sizes. The runtime picks the smallest artifact >= m, so
# this ladder covers the paper's m grid (1 … 100 000 long = 800 kB) with
# bounded padding waste (< 2x).
REDUCE_SIZES = [256, 4096, 65536, 131072]
MATREC_SIZES = [256, 4096, 65536]
BLOCK_K = 32  # ranks per node in the paper's 36x32 configuration
BLOCK_SIZES = [256, 4096]

REDUCE_OPS = [
    ("bxor", jnp.int64, "bxor_i64", "i64"),
    ("sum", jnp.int64, "sum_i64", "i64"),
    ("max", jnp.int64, "max_i64", "i64"),
    ("sum", jnp.float32, "sum_f32", "f32"),
]


def to_hlo_text(fn, *args) -> str:
    lowered = jax.jit(fn).lower(*args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def emit(out_dir: str) -> list[tuple[str, str, str, str, int, int, str]]:
    os.makedirs(out_dir, exist_ok=True)
    rows = []

    def write(name: str, kind: str, op: str, dtype: str, m: int, k: int, text: str):
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        rows.append((name, kind, op, dtype, m, k, fname))
        print(f"  {name}: {len(text)} chars")

    for op, dt, op_name, dt_name in REDUCE_OPS:
        for m in REDUCE_SIZES:
            spec = jax.ShapeDtypeStruct((m,), dt)
            text = to_hlo_text(model.reduce_local_fn(op), spec, spec)
            write(f"reduce_{op_name}_m{m}", "reduce", op_name, dt_name, m, 0, text)

    for n in MATREC_SIZES:
        spec = jax.ShapeDtypeStruct((n, 6), jnp.float32)
        text = to_hlo_text(model.matrec_fn(), spec, spec)
        write(f"reduce_matrec_f32_m{n}", "reduce", "matrec_f32", "rec2_f32", n, 0, text)

    for m in BLOCK_SIZES:
        spec = jax.ShapeDtypeStruct((BLOCK_K, m), jnp.int64)
        text = to_hlo_text(model.block_exscan_fn("bxor"), spec)
        write(
            f"block_exscan_bxor_i64_k{BLOCK_K}_m{m}",
            "block_exscan",
            "bxor_i64",
            "i64",
            m,
            BLOCK_K,
            text,
        )

    return rows


def write_manifest(out_dir: str, rows) -> None:
    path = os.path.join(out_dir, "manifest.tsv")
    with open(path, "w") as f:
        f.write(f"exscan-artifacts v1 jax={jax.__version__}\n")
        for name, kind, op, dtype, m, k, fname in rows:
            f.write(f"{name}\t{kind}\t{op}\t{dtype}\t{m}\t{k}\t{fname}\n")
    print(f"wrote {path} ({len(rows)} artifacts)")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifacts directory")
    args = ap.parse_args()
    rows = emit(args.out)
    write_manifest(args.out, rows)


if __name__ == "__main__":
    sys.exit(main())
