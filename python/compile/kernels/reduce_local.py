"""Layer-1 Pallas kernels: the element-wise ``MPI_Reduce_local`` hot spot.

Every kernel implements the contract ``out = combine(earlier, later)``
element-wise over ``m``-element vectors, matching the Rust side's
``CombineOp::combine(input, inout)`` (``input`` = earlier operand).

TPU mapping (DESIGN.md §Hardware-Adaptation): each grid step streams one
``TILE``-element slice HBM→VMEM via ``BlockSpec``, combines on the VPU
(bitwise / add / max are vector ops; only the 2×2 affine-recurrence
operator has an MXU-shaped contraction, expressed as a batched 2×2
einsum) and writes the tile back. ``TILE = 8 * 128 * 4`` f32 lanes keeps
three buffers (two inputs + one output) comfortably inside a single
core's ~16 MiB VMEM with double-buffering headroom.

All kernels are lowered with ``interpret=True``: the CPU PJRT plugin
cannot execute Mosaic custom-calls, and correctness (not wallclock) is
what the interpret path validates. See ``ref.py`` for the oracles.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# 8 sublanes x 128 lanes x 4 registers: one well-shaped VPU tile per step.
TILE = 4096


def _tile_for(m: int) -> int:
    """Largest power-of-two tile that divides m (kernel sizes are powers
    of two, so this is min(m, TILE) in practice)."""
    t = min(m, TILE)
    while m % t != 0:
        t //= 2
    return max(t, 1)


# ---------------------------------------------------------------------------
# Element-wise combine kernels (vectors of scalars)
# ---------------------------------------------------------------------------

_COMBINES = {
    "bxor": lambda a, b: jnp.bitwise_xor(a, b),
    "bor": lambda a, b: jnp.bitwise_or(a, b),
    "sum": lambda a, b: a + b,
    "max": lambda a, b: jnp.maximum(a, b),
    "min": lambda a, b: jnp.minimum(a, b),
    "prod": lambda a, b: a * b,
}


def _combine_kernel(combine, earlier_ref, later_ref, out_ref):
    out_ref[...] = combine(earlier_ref[...], later_ref[...])


def reduce_local(
    op: str, earlier: jax.Array, later: jax.Array, tile: int | None = None
) -> jax.Array:
    """Element-wise ``earlier ⊕ later`` over 1-D vectors via Pallas.

    ``tile=None`` lowers the whole vector as ONE block: on the CPU
    interpret path a multi-step grid materializes a full-array
    dynamic-update-slice per step (O(grid·m) — measured 12.7 ms at
    m=131072 vs ~1 ms single-block, §Perf), while a real TPU build would
    pass ``tile=TILE`` to stream VMEM-sized blocks. Tests cover both.
    """
    assert earlier.shape == later.shape and earlier.ndim == 1
    m = earlier.shape[0]
    if m == 0:
        return earlier
    combine = _COMBINES[op]
    tile = m if tile is None else _tile_for(min(m, tile))
    if m % tile:
        tile = _tile_for(m)
    grid = (m // tile,)
    spec = pl.BlockSpec((tile,), lambda i: (i,))
    return pl.pallas_call(
        functools.partial(_combine_kernel, combine),
        grid=grid,
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((m,), earlier.dtype),
        interpret=True,
    )(earlier, later)


# ---------------------------------------------------------------------------
# 2x2 affine recurrence composition ("matrec"): rows of 6 f32
#   row = [a11 a12 a21 a22 b1 b2];  earlier applied first:
#   A_out = A_later @ A_earlier ; b_out = A_later @ b_earlier + b_later
# ---------------------------------------------------------------------------


def _matrec_kernel(earlier_ref, later_ref, out_ref):
    e = earlier_ref[...]
    l = later_ref[...]  # noqa: E741 — mirrors the maths
    ea11, ea12, ea21, ea22 = e[:, 0], e[:, 1], e[:, 2], e[:, 3]
    eb1, eb2 = e[:, 4], e[:, 5]
    la11, la12, la21, la22 = l[:, 0], l[:, 1], l[:, 2], l[:, 3]
    lb1, lb2 = l[:, 4], l[:, 5]
    out_ref[...] = jnp.stack(
        [
            la11 * ea11 + la12 * ea21,
            la11 * ea12 + la12 * ea22,
            la21 * ea11 + la22 * ea21,
            la21 * ea12 + la22 * ea22,
            la11 * eb1 + la12 * eb2 + lb1,
            la21 * eb1 + la22 * eb2 + lb2,
        ],
        axis=1,
    )


def matrec_compose(
    earlier: jax.Array, later: jax.Array, tile: int | None = None
) -> jax.Array:
    """Compose batched affine maps: ``later ∘ earlier`` row-wise on (N, 6).

    ``tile`` as in :func:`reduce_local` (None = single block, CPU-optimal).
    """
    assert earlier.shape == later.shape and earlier.ndim == 2 and earlier.shape[1] == 6
    n = earlier.shape[0]
    if n == 0:
        return earlier
    tile = n if tile is None else _tile_for(min(n, tile))
    if n % tile:
        tile = _tile_for(n)
    grid = (n // tile,)
    spec = pl.BlockSpec((tile, 6), lambda i: (i, 0))
    return pl.pallas_call(
        _matrec_kernel,
        grid=grid,
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((n, 6), earlier.dtype),
        interpret=True,
    )(earlier, later)


# ---------------------------------------------------------------------------
# Block exclusive scan: (K, M) -> (K, M), row j := rows[0] ⊕ … ⊕ rows[j-1]
# (row 0 := identity of the op). Used by the hierarchical/node-leader
# aggregation: one fused kernel replaces K-1 separate reduce_local calls.
# ---------------------------------------------------------------------------

_IDENTITIES = {"bxor": 0, "bor": 0, "sum": 0}


def _block_exscan_kernel(combine, identity, k, x_ref, out_ref):
    # Grid is over M tiles; each instance walks the K rows sequentially —
    # the scan dimension is tiny (K = ranks/node), the vector dim is tiled.
    acc = jnp.full(x_ref.shape[1:], identity, dtype=x_ref.dtype)
    for j in range(k):  # K is static and small: unrolled
        out_ref[j, :] = acc
        acc = combine(acc, x_ref[j, :])


def block_exscan(op: str, x: jax.Array, tile: int | None = None) -> jax.Array:
    """Exclusive scan along axis 0 of (K, M) via one fused Pallas kernel.

    ``tile`` as in :func:`reduce_local` (None = single block, CPU-optimal).
    """
    assert x.ndim == 2
    k, m = x.shape
    if m == 0 or k == 0:
        return x
    combine = _COMBINES[op]
    identity = _IDENTITIES[op]
    tile = m if tile is None else _tile_for(min(m, tile))
    if m % tile:
        tile = _tile_for(m)
    grid = (m // tile,)
    spec = pl.BlockSpec((k, tile), lambda i: (0, i))
    return pl.pallas_call(
        functools.partial(_block_exscan_kernel, combine, identity, k),
        grid=grid,
        in_specs=[spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((k, m), x.dtype),
        interpret=True,
    )(x)
