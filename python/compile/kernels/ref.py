"""Pure-jnp oracles for the Pallas kernels (the correctness ground truth
pytest compares against — no Pallas, no tiling, just the maths)."""

from __future__ import annotations

import jax.numpy as jnp

_COMBINES = {
    "bxor": lambda a, b: jnp.bitwise_xor(a, b),
    "bor": lambda a, b: jnp.bitwise_or(a, b),
    "sum": lambda a, b: a + b,
    "max": lambda a, b: jnp.maximum(a, b),
    "min": lambda a, b: jnp.minimum(a, b),
    "prod": lambda a, b: a * b,
}

_IDENTITIES = {"bxor": 0, "bor": 0, "sum": 0}


def reduce_local_ref(op: str, earlier, later):
    """Element-wise ``earlier ⊕ later``."""
    return _COMBINES[op](earlier, later)


def matrec_compose_ref(earlier, later):
    """Row-wise affine composition on (N, 6): later ∘ earlier."""
    ea = earlier[:, :4].reshape(-1, 2, 2)
    eb = earlier[:, 4:].reshape(-1, 2, 1)
    la = later[:, :4].reshape(-1, 2, 2)
    lb = later[:, 4:].reshape(-1, 2, 1)
    a = jnp.einsum("nij,njk->nik", la, ea)
    b = jnp.einsum("nij,njk->nik", la, eb) + lb
    return jnp.concatenate([a.reshape(-1, 4), b.reshape(-1, 2)], axis=1)


def block_exscan_ref(op: str, x):
    """Exclusive scan along axis 0 of (K, M)."""
    k = x.shape[0]
    combine = _COMBINES[op]
    rows = [jnp.full(x.shape[1:], _IDENTITIES[op], dtype=x.dtype)]
    for j in range(k - 1):
        rows.append(combine(rows[-1], x[j]))
    return jnp.stack(rows, axis=0)
