//! Offline drop-in subset of the `anyhow` crate.
//!
//! This workspace builds with no network access and no crates.io registry,
//! so the real `anyhow` cannot be fetched. This shim implements the exact
//! API surface the `exscan` crate uses — `anyhow!`, `bail!`, `ensure!`,
//! [`Result`], [`Error`], and the [`Context`] extension trait — with the
//! same observable semantics:
//!
//! * `Display` prints the outermost message; the alternate form (`{:#}`)
//!   prints the whole chain joined with `": "`, exactly as `anyhow` does.
//! * `Debug` prints the message followed by a `Caused by:` list.
//! * Any `std::error::Error + Send + Sync + 'static` converts via `?`.
//!
//! Dropping the real `anyhow` back in (when a registry is available)
//! requires no source changes anywhere in the workspace.

use std::fmt;

/// An error chain: `chain[0]` is the outermost (most recently attached)
/// message, the last entry is the root cause.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a printable message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message (used by [`Context`]).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The `Display` messages of the chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`; that
// is what makes this blanket conversion coherent (same trick as upstream).
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>`: a `Result` defaulting the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait attaching context to `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    E: Into<Error>,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or any `Display` value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is not satisfied.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file missing")
    }

    #[test]
    fn display_outermost_alternate_chain() {
        let e: Error = Error::from(io_err()).context("loading config");
        assert_eq!(format!("{e}"), "loading config");
        assert_eq!(format!("{e:#}"), "loading config: file missing");
    }

    #[test]
    fn debug_lists_causes() {
        let e: Error = Error::from(io_err()).context("outer");
        let d = format!("{e:?}");
        assert!(d.contains("outer"), "{d}");
        assert!(d.contains("Caused by:"), "{d}");
        assert!(d.contains("file missing"), "{d}");
    }

    #[test]
    fn macros_and_question_mark() {
        fn inner() -> Result<i32> {
            let n: i32 = "42".parse()?; // ParseIntError converts
            ensure!(n > 0, "want positive, got {n}");
            if n == 0 {
                bail!("unreachable");
            }
            Ok(n)
        }
        assert_eq!(inner().unwrap(), 42);
        let e = anyhow!("x = {}", 7);
        assert_eq!(format!("{e}"), "x = 7");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| format!("step {}", 3)).unwrap_err();
        assert_eq!(format!("{e:#}"), "step 3: file missing");
        let o: Option<i32> = None;
        let e = o.context("missing value").unwrap_err();
        assert_eq!(format!("{e}"), "missing value");
    }

    #[test]
    fn typed_enum_error_pattern() {
        // Audit against the scan service's error handling (rust/src/svc):
        // a typed error enum implementing std::error::Error must convert
        // through the blanket `From`, survive `context` layering, and
        // render its full chain under `{:#}` — the exact pattern the
        // engine's worker threads use to surface collective failures.
        #[derive(Debug)]
        enum SvcLikeError {
            Collective { detail: String },
        }
        impl fmt::Display for SvcLikeError {
            fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
                match self {
                    SvcLikeError::Collective { detail } => {
                        write!(f, "batch collective failed: {detail}")
                    }
                }
            }
        }
        impl std::error::Error for SvcLikeError {}

        fn worker() -> Result<()> {
            let r: std::result::Result<(), SvcLikeError> =
                Err(SvcLikeError::Collective { detail: "rank 1 deadlocked".into() });
            r?;
            Ok(())
        }
        let e = worker().with_context(|| "executing wave 0").unwrap_err();
        assert_eq!(format!("{e}"), "executing wave 0");
        assert_eq!(
            format!("{e:#}"),
            "executing wave 0: batch collective failed: rank 1 deadlocked"
        );
        assert_eq!(e.root_cause(), "batch collective failed: rank 1 deadlocked");
    }

    #[test]
    fn source_chain_is_flattened() {
        // Nested std errors must surface their entire source() chain (the
        // engine stringifies worker errors with `{:#}` before shipping
        // them through `SvcError::Collective`).
        #[derive(Debug)]
        struct Outer(std::io::Error);
        impl fmt::Display for Outer {
            fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
                write!(f, "outer failure")
            }
        }
        impl std::error::Error for Outer {
            fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
                Some(&self.0)
            }
        }
        let e = Error::from(Outer(io_err()));
        assert_eq!(format!("{e:#}"), "outer failure: file missing");
    }

    #[test]
    fn ensure_without_message() {
        fn f(x: i32) -> Result<()> {
            ensure!(x < 10);
            Ok(())
        }
        assert!(f(5).is_ok());
        let e = f(50).unwrap_err();
        assert!(format!("{e}").contains("x < 10"));
    }
}
