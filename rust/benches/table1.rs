//! Bench: regenerate the paper's **Table 1** (experiments E1 + E2).
//!
//! Runs the four MPI_Exscan algorithms on the calibrated virtual-clock
//! cluster in both configurations and prints simulated vs paper times,
//! checking the paper's qualitative claims (§3) hold:
//!   * 123-doubling never loses to 1-doubling,
//!   * 123-doubling beats the native baseline at every m,
//!   * the two-⊕ penalty shows at large m,
//!   * at m = 10⁴ / 36×1 the native→123 improvement is ≳ 20% (paper: 25%).

use exscan::bench::{table1_rows, PaperConfig};

fn main() -> anyhow::Result<()> {
    let grid = [1usize, 10, 100, 1000, 10_000, 100_000];
    for config in [PaperConfig::C36x1, PaperConfig::C36x32] {
        let t0 = std::time::Instant::now();
        let rows = table1_rows(config, &grid)?;
        let paper = config.paper_rows();
        println!("== table1/{} (simulated µs | paper µs) ==", config.label());
        println!(
            "{:>8} | {:>9} {:>9} {:>9} {:>9} | {:>9} {:>9} {:>9} {:>9}",
            "m", "native", "two-op", "1-dbl", "123", "p-nat", "p-2op", "p-1dbl", "p-123"
        );
        for (row, p) in rows.iter().zip(&paper) {
            println!(
                "{:>8} | {:>9.2} {:>9.2} {:>9.2} {:>9.2} | {:>9.2} {:>9.2} {:>9.2} {:>9.2}",
                row.m, row.native, row.two_op, row.one_doubling, row.otd123, p.1, p.2, p.3, p.4
            );
            assert!(row.otd123 <= row.one_doubling + 1e-9, "123 must not lose to 1-dbl");
            // 123 vs native: the paper's claim holds from m ≈ 1000 up; at
            // m ≤ 100 on 36×32 the calibrated native handicap (mostly β)
            // is within noise of the portable α, as in the paper's own
            // m=1..100 rows where rankings flip between configurations.
            if row.m >= 1000 {
                assert!(row.otd123 <= row.native + 1e-9, "123 must not lose to native (m={})", row.m);
            }
        }
        // Shape claims at the paper's headline points.
        let at = |m: usize| rows.iter().find(|r| r.m == m).unwrap();
        let big = at(100_000);
        assert!(big.two_op > big.otd123, "two-⊕ penalty must show at large m");
        if config == PaperConfig::C36x1 {
            let mid = at(10_000);
            let improvement = (mid.native - mid.otd123) / mid.native;
            println!("native→123 improvement at m=10⁴: {:.1}% (paper: 25%)", improvement * 100.0);
            assert!(improvement > 0.20, "expected ≳20% improvement, got {improvement:.3}");
        }
        println!("bench wall time: {:.1}s\n", t0.elapsed().as_secs_f64());
    }
    println!("table1 bench: all shape assertions passed");
    Ok(())
}
