//! Bench/ablation: the large-vector regime (experiment E6). The paper's
//! closing caveat: "for large input vectors, other (pipelined,
//! fixed-degree tree) algorithms must be used". This bench locates the
//! crossover on the calibrated 36×1 cluster model: doubling algorithms
//! win while rounds dominate; the pipelined chain (m/B-sized blocks,
//! p+B−2 rounds) takes over once bandwidth dominates.

use exscan::bench::{inputs_i64, measure_exscan, BenchConfig};
use exscan::coll::PipelinedChain;
use exscan::prelude::*;

fn main() -> anyhow::Result<()> {
    let topo = Topology::cluster(36, 1);
    let world = WorldConfig::new(topo).virtual_clock(CostParams::paper_36x1());
    let bench = BenchConfig::default();

    println!("virtual 36×1 cluster, µs (pipelined chain B = auto)");
    println!(
        "{:>9} | {:>12} {:>12} {:>12} {:>12}",
        "m", "123", "linear", "pipe-chain", "winner"
    );
    let mut crossover_seen = false;
    let mut last_winner = String::new();
    for m in [1usize, 100, 10_000, 100_000, 400_000, 1_600_000, 6_400_000] {
        let inputs = inputs_i64(topo.size(), m, 11);
        let t123 = measure_exscan(&world, &bench, &Exscan123, &ops::bxor(), &inputs)?.min_us;
        let tlin = measure_exscan(&world, &bench, &ExscanLinear, &ops::bxor(), &inputs)?.min_us;
        let chain = PipelinedChain::auto();
        let tpipe = measure_exscan(&world, &bench, &chain, &ops::bxor(), &inputs)?.min_us;
        let winner = if t123 <= tpipe { "123" } else { "pipe-chain" };
        if winner == "pipe-chain" {
            crossover_seen = true;
        }
        last_winner = winner.to_string();
        println!("{m:>9} | {t123:>12.1} {tlin:>12.1} {tpipe:>12.1} {winner:>12}");
    }
    assert!(crossover_seen, "pipelined chain must win somewhere in the large-m regime");
    assert_eq!(last_winner, "pipe-chain", "largest m must be pipeline-bound");

    // Block-count sweep at a large size: the B vs m/B trade-off.
    println!("\nblock-count sweep at m = 1 600 000:");
    let inputs = inputs_i64(topo.size(), 1_600_000, 13);
    let mut best = (0usize, f64::INFINITY);
    for b in [1usize, 2, 4, 8, 16, 32, 64, 128, 256] {
        let chain = PipelinedChain::with_blocks(b);
        let t = measure_exscan(&world, &bench, &chain, &ops::bxor(), &inputs)?.min_us;
        println!("  B = {b:>4}: {t:>12.1} µs");
        if t < best.1 {
            best = (b, t);
        }
    }
    println!("best B = {} — auto policy picks {}", best.0, PipelinedChain::auto().block_count(1_600_000));
    println!("large_vector bench: crossover assertions passed");
    Ok(())
}
