//! Bench/ablation: the ⊕-application cost (experiment E7). As the
//! operator gets more expensive, the two-⊕ doubling algorithm's
//! `2⌈log₂p⌉−1` applications hurt proportionally more than 123-doubling's
//! `q−1` — the computational half of the paper's contribution.
//!
//! Measured on the **real thread transport** (wall clock) with the
//! tunable `expensive_bxor` operator, and — when artifacts are built —
//! with the AOT-compiled PJRT matrec kernel where every ⊕ is a real
//! kernel launch.

use exscan::bench::{inputs_i64, inputs_rec2, measure_exscan, BenchConfig};
use exscan::prelude::*;

fn main() -> anyhow::Result<()> {
    let p = 16;
    let m = 2048;
    let world = WorldConfig::new(Topology::flat(p));
    let bench = BenchConfig { warmups: 5, reps: 40, validate: true };
    let inputs = inputs_i64(p, m, 3);

    println!("p = {p}, m = {m}, real thread transport, min-of-max µs");
    println!("{:>10} | {:>12} {:>12} {:>12} | {:>8}", "op-work", "two-op", "1-dbl", "123", "123 wins by");
    for work in [0u32, 16, 64, 256, 1024] {
        let op = if work == 0 { ops::bxor() } else { ops::expensive_bxor(work) };
        let t2 = measure_exscan(&world, &bench, &ExscanTwoOp, &op, &inputs)?.min_us;
        let t1 = measure_exscan(&world, &bench, &ExscanOneDoubling, &op, &inputs)?.min_us;
        let t123 = measure_exscan(&world, &bench, &Exscan123, &op, &inputs)?.min_us;
        println!(
            "{:>10} | {:>12.1} {:>12.1} {:>12.1} | {:>7.1}%",
            work,
            t2,
            t1,
            t123,
            (t2 - t123) / t2 * 100.0
        );
    }

    // With a genuinely expensive operator the ranking must be decisive.
    let op = ops::expensive_bxor(1024);
    let t2 = measure_exscan(&world, &bench, &ExscanTwoOp, &op, &inputs)?.min_us;
    let t123 = measure_exscan(&world, &bench, &Exscan123, &op, &inputs)?.min_us;
    assert!(
        t123 < t2,
        "123-doubling must beat two-⊕ under an expensive operator: {t123} vs {t2}"
    );

    // PJRT kernel path (optional, artifacts needed): count real launches.
    if let Some(handle) = exscan::runtime::PjrtRuntime::try_default() {
        println!("\nPJRT matrec kernel as ⊕ (p = {p}, m = 256 affine maps):");
        let inputs = inputs_rec2(p, 256, 5);
        let op = exscan::runtime::pjrt_rec2_compose(handle.clone());
        for algo in [&ExscanTwoOp as &dyn ScanAlgorithm<Rec2>, &Exscan123] {
            let before = handle.stats()?.launches;
            let t0 = std::time::Instant::now();
            let res = run_scan(&world, algo, &op, &inputs)?;
            let dt = t0.elapsed().as_secs_f64() * 1e6;
            let launches = handle.stats()?.launches - before;
            assert_eq!(res.outputs.len(), p);
            println!("  {:>18}: {launches:>4} launches, {dt:>10.0} µs wall", algo.name());
        }
    } else {
        println!("\n(artifacts not built — skipping the PJRT kernel ablation)");
    }
    println!("op_cost_ablation bench: assertions passed");
    Ok(())
}
