//! Bench/ablation: round and ⊕ counts versus p (experiment E4 — the
//! quantitative content of Theorem 1), plus the latency-regime timing
//! consequence: at m = 1 the completion time is essentially
//! `rounds × α`, so the 123-doubling advantage tracks its round count.
//!
//! For every p in a ladder spanning 2…4096 the *measured* (traced)
//! counts are checked against the closed forms, then timed at m = 1 on
//! the virtual cluster.

use exscan::bench::{inputs_i64, measure_exscan, BenchConfig};
use exscan::prelude::*;

fn main() -> anyhow::Result<()> {
    let ladder = [
        2usize, 3, 4, 5, 6, 7, 8, 9, 12, 16, 17, 24, 31, 32, 33, 36, 48, 64, 65, 96, 100, 128,
        192, 256, 384, 512, 768, 1024, 1152, 2048, 3072, 4096,
    ];
    println!(
        "{:>6} | {:>12} {:>12} {:>12} | {:>8} {:>8} {:>8}",
        "p", "rounds(2op)", "rounds(1dbl)", "rounds(123)", "ops(2op)", "ops(1dbl)", "ops(123)"
    );
    for &p in &ladder {
        let algos = exscan::coll::paper_exscan_algorithms::<i64>();
        let by = |n: &str| algos.iter().find(|a| a.name() == n).unwrap();
        let (a2, a1, a123) = (by("two-op-doubling"), by("1-doubling"), by("123-doubling"));
        println!(
            "{:>6} | {:>12} {:>12} {:>12} | {:>8} {:>8} {:>8}",
            p,
            a2.predicted_rounds(p),
            a1.predicted_rounds(p),
            a123.predicted_rounds(p),
            a2.predicted_ops(p),
            a1.predicted_ops(p),
            a123.predicted_ops(p)
        );
        // Theorem 1 bounds: q123 <= q1dbl always; q123 <= ceil(log2(p-1))+1.
        assert!(a123.predicted_rounds(p) <= a1.predicted_rounds(p));
        if p > 2 {
            assert!(a123.predicted_rounds(p) <= exscan::util::ceil_log2(p - 1) + 1);
            assert_eq!(a123.predicted_ops(p), a123.predicted_rounds(p) - 1);
        }
        // Verify against the live trace for the moderate sizes.
        if p <= 256 {
            let world = WorldConfig::new(Topology::flat(p)).with_trace(true);
            let inputs = inputs_i64(p, 2, p as u64);
            for algo in [&**a2, &**a1, &**a123] {
                let res = run_scan(&world, algo, &ops::bxor(), &inputs)?;
                let tr = res.trace.unwrap();
                assert_eq!(
                    tr.total_rounds(),
                    algo.predicted_rounds(p),
                    "{} rounds p={p}",
                    algo.name()
                );
                assert!(exscan::trace::check_all(&tr).is_empty(), "{} p={p}", algo.name());
            }
        }
    }

    // Latency regime (m = 1): time ≈ rounds × α — where the saved round shows.
    println!("\nlatency regime, m = 1, virtual 36×1 cluster:");
    let world = WorldConfig::new(Topology::cluster(36, 1)).virtual_clock(CostParams::paper_36x1());
    let bench = BenchConfig::quick();
    let inputs = inputs_i64(36, 1, 1);
    for algo in exscan::coll::paper_exscan_algorithms::<i64>() {
        let m = measure_exscan(&world, &bench, &*algo, &ops::bxor(), &inputs)?;
        println!(
            "  {:>18}: {:>7.2} µs  ({} rounds)",
            m.algo,
            m.min_us,
            algo.predicted_rounds(36)
        );
    }
    // Hierarchical (SMP-aware) ablation: flat 123-doubling vs two-level
    // gather/leader-exscan/scatter at 36×32, sweeping the inter/intra
    // latency ratio. Flat wins at the calibrated ratio (~4×); the
    // hierarchy pays off once inter-node latency dominates enough to buy
    // back the 2(k−1) local rounds.
    println!("\nhierarchical ablation, p = 8×8, m = 16, virtual clock:");
    println!("{:>12} | {:>10} {:>12}", "inter/intra", "flat-123", "hierarchical");
    let mut hier_wins_somewhere = false;
    for ratio in [2.0, 8.0, 32.0, 128.0, 512.0] {
        let params = CostParams {
            alpha_intra: 0.5,
            alpha_inter: 0.5 * ratio,
            beta_intra: 1e-5,
            beta_inter: 1e-5 * ratio,
            gamma: 1e-5,
            overhead: 0.0,
        };
        let world = WorldConfig::new(Topology::cluster(8, 8)).virtual_clock(params);
        let inputs = inputs_i64(64, 16, 17);
        let flat =
            measure_exscan(&world, &BenchConfig::quick(), &Exscan123, &ops::bxor(), &inputs)?
                .min_us;
        let hier = measure_exscan(
            &world,
            &BenchConfig::quick(),
            &exscan::coll::ExscanHierarchical::new(8),
            &ops::bxor(),
            &inputs,
        )?
        .min_us;
        if hier < flat {
            hier_wins_somewhere = true;
        }
        println!("{ratio:>12} | {flat:>10.2} {hier:>12.2}");
    }
    assert!(
        hier_wins_somewhere,
        "the hierarchy must pay off at extreme inter/intra ratios"
    );
    println!("rounds_ablation bench: all Theorem-1 assertions passed");
    Ok(())
}
