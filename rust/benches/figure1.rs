//! Bench: regenerate the paper's **Figure 1** (experiment E3) — the
//! log-log time-vs-bytes curves for all four algorithms on both cluster
//! configurations. Writes `bench_figure1.csv` (long format) and prints
//! the series; checking the visual features of the paper's plot:
//! flat latency-bound region for small m, β-bound linear growth for
//! large m, with the native curve on top and 123-doubling on the bottom
//! at large m.

use exscan::bench::{figure1_sweep, to_csv, PaperConfig, SweepSpec};

fn main() -> anyhow::Result<()> {
    let spec = SweepSpec::figure1();
    let mut csv = String::new();
    for config in [PaperConfig::C36x1, PaperConfig::C36x32] {
        let t0 = std::time::Instant::now();
        let ms = figure1_sweep(config, &spec)?;
        println!("== figure1/{} ==", config.label());
        println!("{:>9} {:>18} {:>12}", "bytes", "algo", "µs");
        for m in &ms {
            println!("{:>9} {:>18} {:>12.2}", m.bytes, m.algo, m.min_us);
        }
        // Feature checks at the extremes.
        let series = |name: &str, m: usize| {
            ms.iter().find(|x| x.algo == name && x.m == m).map(|x| x.min_us).unwrap()
        };
        let m_max = *spec.m_values.last().unwrap();
        assert!(series("123-doubling", m_max) <= series("1-doubling", m_max) + 1e-9);
        assert!(series("123-doubling", m_max) < series("two-op-doubling", m_max));
        // Latency-bound region: m=0 and m=1 within a few percent.
        let flat0 = series("123-doubling", 0);
        let flat1 = series("123-doubling", 1);
        assert!((flat1 - flat0) / flat0 < 0.05, "small-m region must be latency-bound");
        let part = to_csv(config.label(), &ms);
        if csv.is_empty() {
            csv = part;
        } else {
            csv.push_str(part.split_once('\n').map(|x| x.1).unwrap_or(""));
        }
        println!("bench wall time: {:.1}s\n", t0.elapsed().as_secs_f64());
    }
    std::fs::write("bench_figure1.csv", &csv)?;
    println!("figure1 bench: wrote bench_figure1.csv; all curve-shape assertions passed");
    Ok(())
}
