//! Bench: L3 hot-path microbenchmarks (§Perf in EXPERIMENTS.md).
//!
//! Measures the building blocks every communication round is made of so
//! the per-round software overhead can be compared against the modelled
//! α (≈1.2 µs inter-node): if a full in-process round costs ≪ α, the
//! simulation's timing is dominated by the model, not the substrate, and
//! the real-transport benches measure algorithm structure, not runtime
//! noise.
//!
//!   * channel push/pop latency (the transport primitive)
//!   * ping-pong sendrecv round trip between two rank threads
//!   * reduce_local throughput (native ⊕ over large vectors)
//!   * world spawn/teardown cost vs p (the once-per-benchmark cost)

use std::time::Instant;

use exscan::prelude::*;
use exscan::util::Channel;

fn bench_ns<F: FnMut()>(iters: usize, mut f: F) -> f64 {
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    t0.elapsed().as_nanos() as f64 / iters as f64
}

fn main() -> anyhow::Result<()> {
    // Channel push/pop, same thread (pure queue cost).
    let ch: Channel<u64> = Channel::new();
    let ns = bench_ns(1_000_000, || {
        ch.push(1).unwrap();
        ch.try_pop().unwrap();
    });
    println!("channel push+pop (1 thread):     {ns:>9.1} ns");

    // Cross-thread ping-pong through the full RankCtx sendrecv path.
    let world = WorldConfig::new(Topology::flat(2));
    let iters = 50_000u32;
    let t0 = Instant::now();
    exscan::mpi::run_world::<i64, (), _>(&world, |ctx| {
        let peer = 1 - ctx.rank();
        let sbuf = [0i64];
        let mut rbuf = [0i64];
        for k in 0..iters {
            ctx.sendrecv(k, peer, &sbuf, peer, &mut rbuf)?;
        }
        Ok(())
    })?;
    let ns = t0.elapsed().as_nanos() as f64 / iters as f64;
    println!("sendrecv round trip (2 threads): {ns:>9.1} ns  (model α = 1155 ns)");

    // reduce_local throughput.
    let op = ops::bxor();
    for m in [1usize, 1000, 100_000] {
        let a = vec![0x5aa5_5aa5i64; m];
        let mut b = vec![-1i64; m];
        let ns = bench_ns(if m > 10_000 { 2_000 } else { 200_000 }, || {
            op.reduce_local(&a, &mut b);
        });
        let gbps = (m as f64 * 8.0) / ns;
        println!("reduce_local m={m:>7}:           {ns:>9.1} ns  ({gbps:>6.2} GB/s)");
    }

    // World spawn/teardown (the fixed cost amortized by the rep loop).
    for p in [16usize, 144, 1152] {
        let world = WorldConfig::new(Topology::flat(p));
        let iters = if p > 500 { 3 } else { 20 };
        let ns = bench_ns(iters, || {
            exscan::mpi::run_world::<i64, usize, _>(&world, |ctx| Ok(ctx.rank())).unwrap();
        });
        println!("world spawn+join p={p:>5}:        {:>9.2} ms", ns / 1e6);
    }

    // End-to-end: one full 123-doubling at p=36 on the thread transport.
    let world = WorldConfig::new(Topology::flat(36));
    let inputs = exscan::bench::inputs_i64(36, 1000, 1);
    let bench = exscan::bench::BenchConfig { warmups: 10, reps: 100, validate: false };
    let meas = exscan::bench::measure_exscan(&world, &bench, &Exscan123, &ops::bxor(), &inputs)?;
    println!(
        "123-doubling p=36 m=1000 (real):  {:>8.1} µs min, {:.1} µs mean",
        meas.min_us, meas.mean_us
    );
    println!("hotpath bench done");
    Ok(())
}
