//! Bench: L3 hot-path microbenchmarks (EXPERIMENTS.md §Perf).
//!
//! The paper's argument lives or dies on per-round cost, so the transport
//! under every algorithm must be cheap enough that the measured gap
//! between `Exscan123` and the ⌈log₂ p⌉+1-round baselines reflects round
//! structure, not allocator/scheduler noise. This bench quantifies that:
//!
//!   * **ring round-trip** on the current slot/pool transport vs the v0
//!     "legacy" transport (one Mutex+Condvar MPMC mailbox per rank,
//!     per-message `Box` allocation, O(pending) linear matching —
//!     faithfully reconstructed below) at p ∈ {4, 16, 32};
//!   * **inbox latency sweep** at p ∈ {4, 16, 32}: the adaptive per-slot
//!     EMA spin budget vs the fixed pre-adaptive budget
//!     (`WorldConfig::with_fixed_spin`), with receiver spin/park counters;
//!   * channel push/pop latency (the legacy primitive, kept for the
//!     executor job queues);
//!   * **kernel sweep** at m ∈ {1, 64, 4096, 65536} for ≥ 3 operators:
//!     one ⊕ application under slice-kernel dispatch (the resolved
//!     `OpKernel` path) vs the per-element reference, asserted
//!     bit-identical before timing;
//!   * **compute-path m-sweep** at m ∈ {1, 64, 4096, 65536}: the fused
//!     receive-reduce path vs the pre-fusion two-pass flow
//!     (`WorldConfig::unfused_compat`), and the chunked large-m pipeline
//!     vs the flat schedule — plus the Theorem-1 gate asserting the ⊕
//!     application counts (sharded counters and trace agree, last rank
//!     matches `predicted_ops`);
//!   * world spawn/teardown vs persistent-executor job submission — the
//!     cost `Harness::sweep` no longer pays per (algorithm, m) point;
//!   * **scan-service batching sweep** at K ∈ {1, 4, 16, 64} small-m
//!     requests: batched (one coalesced collective) vs serial (one
//!     collective per request) wall time per request, with a hard
//!     deterministic gate on the amortized rounds/request closed form
//!     (`rounds(p) / K`, measured from the batch trace);
//!   * **service latency under failure** (§Robustness): a sustained
//!     submit stream, baseline vs seeded rank-death mid-run, reporting
//!     the engine's histogram p50/p99/p999 with SLO gates (quantile
//!     sanity, zero lost requests, attributed failures, live rebuild);
//!   * **soak** (§Robustness): waves of mixed full-world + sub-range
//!     requests under a periodic rank-death schedule — gates the
//!     `submitted == completed + failed` invariant, a drained
//!     inflight-bytes gauge, and flat steady-state memory via the pool
//!     miss counters;
//!   * **large-m selection crossover** (§Perf large-m): at every (p, m)
//!     grid point the algorithm [`select_exscan`] picks under the
//!     calibrated paper parameters must equal the closed-form argmin
//!     over the candidate pool — the honest-selection gate — and the
//!     predicted round-regime → bandwidth-regime boundary per p is
//!     solved with [`crossover_m`] and reported; the block-decomposed
//!     and reduce-scatter+allgather engines also ride the compute-path
//!     m-sweep and the op-count gate, so the quick run smokes them end
//!     to end;
//!   * **topology sweep** (§Topology): virtual-clock completion of the
//!     two-level leader scheme vs flat 123-doubling on every hierarchical
//!     `Topo` preset and on the uniform null-hypothesis matrix, with hard
//!     gates — two-level strictly faster on every hierarchical matrix,
//!     never faster on the uniform one, and `select_exscan_topo` never
//!     picks it where hierarchy is absent;
//!   * **wire-fault overhead** (§Robustness): the same whole-scan
//!     workload on every wire backend this host offers, clean vs the
//!     seeded fault plan with recovery on — every faulted run must still
//!     verify bit-exactly against the oracle, with nonzero repair
//!     counters proving the recovery layer (not luck) carried it;
//!   * one full 123-doubling at p=36 end to end.
//!
//! Writes the machine-readable trajectory record `BENCH_hotpath.json`
//! (schema `exscan-hotpath-v8`). Pass `--quick` for the CI smoke run.
//! `EXSCAN_SOAK_REQUESTS` scales the soak's total request budget without
//! a rebuild (the same knob `exscan serve --soak` honors).

use std::sync::Arc;
use std::time::{Duration, Instant};

use exscan::bench::{
    hotpath_json, measure_exscan_world, CrossoverPoint, HotpathPoint, KernelPoint, LatencyPoint,
    MSweepPoint, SoakPoint, SvcLatencyPoint, SvcPoint, TopoSweepPoint, WireFaultPoint,
};
use exscan::coll::{oracle_exscan, select_candidates, select_exscan, select_exscan_topo};
use exscan::cost::{crossover_m, predict_schedule};
use exscan::mpi::{WireFaultConfig, World};
use exscan::prelude::*;
use exscan::util::bits::rounds_123;
use exscan::util::Channel;

fn bench_ns<F: FnMut()>(iters: usize, mut f: F) -> f64 {
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    t0.elapsed().as_nanos() as f64 / iters as f64
}

/// Snapshot the engine's metrics once the counters have quiesced: handle
/// fulfillment races the dispatcher's batch accounting by microseconds,
/// so right after a `wait` the `completed` counter can transiently lag.
fn quiesced_metrics(engine: &ScanEngine<i64>) -> exscan::svc::MetricsSnapshot {
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let s = engine.metrics();
        if s.submitted == s.completed + s.failed || Instant::now() >= deadline {
            return s;
        }
        std::thread::sleep(Duration::from_micros(200));
    }
}

// ───────────────────────── legacy transport (v0) ─────────────────────────
// The pre-slot transport, reconstructed verbatim so before/after runs on
// the same machine in the same binary: one MPMC channel per rank, a boxed
// allocation per message, linear (src, tag) matching over `pending`.

#[derive(Debug)]
struct LegacyMsg {
    src: usize,
    tag: u64,
    data: Box<[i64]>,
}

fn legacy_take(
    mailbox: &Channel<LegacyMsg>,
    pending: &mut Vec<LegacyMsg>,
    from: usize,
    tag: u64,
) -> LegacyMsg {
    if let Some(i) = pending.iter().position(|m| m.src == from && m.tag == tag) {
        return pending.swap_remove(i);
    }
    loop {
        let msg = mailbox.pop_timeout(Duration::from_secs(60)).expect("legacy deadlock");
        if msg.src == from && msg.tag == tag {
            return msg;
        }
        pending.push(msg);
    }
}

/// Warm-up rounds excluded from both transports' timed windows.
const WARM_ROUNDS: u32 = 64;

/// One rendezvous ring (each rank sendrecvs once per round) on the legacy
/// transport; returns wall nanoseconds per round, max over ranks.
///
/// Symmetric with [`slot_ring_ns`]: thread spawn/join and `WARM_ROUNDS`
/// cold-start rounds happen *outside* the timed barrier-to-barrier window.
fn legacy_ring_ns(p: usize, rounds: u32) -> f64 {
    let mailboxes: Arc<Vec<Channel<LegacyMsg>>> =
        Arc::new((0..p).map(|_| Channel::new()).collect());
    let barrier = Arc::new(std::sync::Barrier::new(p));
    let worst_ns = std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(p);
        for r in 0..p {
            let mailboxes = Arc::clone(&mailboxes);
            let barrier = Arc::clone(&barrier);
            handles.push(scope.spawn(move || {
                let mut pending = Vec::new();
                let sbuf = [r as i64];
                let mut ring = |k: u32| {
                    let msg = LegacyMsg {
                        src: r,
                        tag: k as u64,
                        data: sbuf.to_vec().into_boxed_slice(), // per-message alloc
                    };
                    if mailboxes[(r + 1) % p].push(msg).is_err() {
                        panic!("legacy mailbox closed");
                    }
                    let got =
                        legacy_take(&mailboxes[r], &mut pending, (r + p - 1) % p, k as u64);
                    assert_eq!(got.data.len(), 1);
                };
                for k in 0..WARM_ROUNDS {
                    ring(k);
                }
                barrier.wait();
                let t0 = Instant::now();
                for k in 0..rounds {
                    ring(WARM_ROUNDS + k);
                }
                barrier.wait();
                t0.elapsed().as_nanos() as f64
            }));
        }
        handles.into_iter().map(|h| h.join().unwrap()).fold(0.0f64, f64::max)
    });
    worst_ns / rounds as f64
}

/// The same ring on the current slot/pool transport through the full
/// `RankCtx::sendrecv` path, on a persistent world. Same protocol as
/// [`legacy_ring_ns`]: warm-up, barrier, timed rounds, barrier; max over
/// ranks. Job submission overhead sits outside the barriers.
fn slot_ring_ns(world: &World<i64>, rounds: u32) -> f64 {
    let worst_ns = world
        .run(|ctx| {
            let p = ctx.size();
            let r = ctx.rank();
            let sbuf = [r as i64];
            let mut rbuf = [0i64];
            for k in 0..WARM_ROUNDS {
                ctx.sendrecv(k, (r + 1) % p, &sbuf, (r + p - 1) % p, &mut rbuf)?;
            }
            ctx.barrier();
            let t0 = Instant::now();
            for k in 0..rounds {
                ctx.sendrecv(WARM_ROUNDS + k, (r + 1) % p, &sbuf, (r + p - 1) % p, &mut rbuf)?;
            }
            ctx.barrier();
            Ok(t0.elapsed().as_nanos() as f64)
        })
        .unwrap()
        .into_iter()
        .fold(0.0f64, f64::max);
    worst_ns / rounds as f64
}

/// Time one ⊕ application of `op` under both dispatch paths across the
/// kernel-sweep m grid, asserting bit-identity first. `mk_elem` produces
/// deterministic element values.
fn kernel_sweep_for<T: Elem>(
    op: &OpRef<T>,
    mk_elem: impl Fn(usize) -> T,
    quick: bool,
    out: &mut Vec<KernelPoint>,
) {
    for &m in &[1usize, 64, 4096, 65536] {
        let input: Vec<T> = (0..m).map(&mk_elem).collect();
        let base: Vec<T> = (0..m).map(|i| mk_elem(i.wrapping_mul(31).wrapping_add(7))).collect();
        // Bit-identity gate between the two dispatch paths before timing.
        let (mut sl, mut pe) = (base.clone(), base.clone());
        op.kernel().apply_sharded(0, &input, &mut sl);
        op.kernel_per_element().apply_sharded(0, &input, &mut pe);
        assert!(
            sl == pe,
            "slice kernel diverged from per-element reference (op {}, m {m})",
            op.name()
        );
        let iters = {
            let base = if m > 10_000 { 2_000 } else { 100_000 };
            if quick {
                base / 10
            } else {
                base
            }
        };
        let mut point = |path: &str, ns: f64| {
            out.push(KernelPoint {
                op: op.name().to_string(),
                path: path.into(),
                m,
                ns_per_apply: ns,
                elems_per_sec: if ns > 0.0 { m as f64 / (ns * 1e-9) } else { 0.0 },
            });
        };
        let k = op.kernel();
        let mut b = base.clone();
        let slice_ns = bench_ns(iters, || {
            k.apply_sharded(0, std::hint::black_box(&input), std::hint::black_box(&mut b));
        });
        let k = op.kernel_per_element();
        let mut b = base.clone();
        let pe_ns = bench_ns(iters, || {
            k.apply_sharded(0, std::hint::black_box(&input), std::hint::black_box(&mut b));
        });
        point("slice", slice_ns);
        point("per-element", pe_ns);
        println!(
            "  {:<16} m={m:>6}: slice {slice_ns:>9.1} ns  per-element {pe_ns:>9.1} ns  ({:>4.2}x)",
            op.name(),
            pe_ns / slice_ns
        );
    }
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let ring_rounds: u32 = if quick { 2_000 } else { 50_000 };

    // Transport backend under test: `EXSCAN_BENCH_TRANSPORT=thread|shm|
    // tcp|uds` (default thread). Cargo benches take no custom flags, so
    // the env var is the bench half of the CI backend matrix; unavailable
    // backends fail attributed before anything is timed. Applied to the
    // world-backed sections (ring, latency sweep, m-sweep, e2e) — the
    // legacy-MPMC reconstruction and the closed-form gates are
    // transport-free by construction.
    let backend: TransportBackend = match std::env::var("EXSCAN_BENCH_TRANSPORT") {
        Ok(s) => s.parse()?,
        Err(_) => TransportBackend::Thread,
    };
    backend.probe()?;
    println!("transport backend: {backend}");

    // ── Transport comparison: the tentpole before/after ──
    let mut points = Vec::new();
    println!("ring rendezvous, {ring_rounds} rounds, one sendrecv per rank per round:");
    for p in [4usize, 16, 32] {
        let legacy_ns = legacy_ring_ns(p, ring_rounds);
        let world: World<i64> =
            World::new(WorldConfig::new(Topology::flat(p)).with_transport(backend));
        let slot_ns = slot_ring_ns(&world, ring_rounds);
        let to_rate = |ns_per_round: f64| p as f64 / (ns_per_round * 1e-9);
        println!(
            "  p={p:>2}: legacy {legacy_ns:>9.1} ns/round   slot-pool {slot_ns:>9.1} ns/round   speedup {:>5.2}x",
            legacy_ns / slot_ns
        );
        points.push(HotpathPoint {
            transport: "legacy-mpmc".into(),
            p,
            rounds: ring_rounds as usize,
            msgs_per_sec: to_rate(legacy_ns),
            ns_per_round: legacy_ns,
        });
        points.push(HotpathPoint {
            transport: "slot-pool".into(),
            p,
            rounds: ring_rounds as usize,
            msgs_per_sec: to_rate(slot_ns),
            ns_per_round: slot_ns,
        });
    }

    // ── Inbox latency sweep: adaptive per-slot spin budget vs the fixed
    // pre-adaptive budget, same ring protocol, plus the receiver-side
    // spin/park counters (whole run incl. warmup — the policies differ
    // exactly in how much they spin before parking). ──
    let mut latency_sweep: Vec<LatencyPoint> = Vec::new();
    println!("\ninbox latency: adaptive vs fixed spin budget:");
    for p in [4usize, 16, 32] {
        for (mode, fixed) in [("adaptive", false), ("fixed-spin", true)] {
            let world: World<i64> = World::new(
                WorldConfig::new(Topology::flat(p))
                    .with_fixed_spin(fixed)
                    .with_transport(backend),
            );
            let ns = slot_ring_ns(&world, ring_rounds);
            let mut spins = 0u64;
            let mut parks = 0u64;
            for st in world.run(|ctx| Ok(ctx.inbox_stats()))? {
                spins += st.spins;
                parks += st.parks;
            }
            println!(
                "  p={p:>2} {mode:<10}: {ns:>9.1} ns/round   {spins:>10} spins  {parks:>7} parks"
            );
            latency_sweep.push(LatencyPoint {
                mode: mode.into(),
                p,
                rounds: ring_rounds as usize,
                ns_per_round: ns,
                spins,
                parks,
            });
        }
    }

    // ── Channel push/pop, same thread (the executor-queue primitive). ──
    let ch: Channel<u64> = Channel::new();
    let iters = if quick { 100_000 } else { 1_000_000 };
    let ns = bench_ns(iters, || {
        ch.push(1).unwrap();
        ch.try_pop().unwrap();
    });
    println!("channel push+pop (1 thread):     {ns:>9.1} ns");

    // ── Kernel sweep: slice-kernel dispatch vs per-element reference,
    // per op × m (schema-v4 `kernel_sweep`; bit-identity asserted). ──
    let mut kernel_sweep: Vec<KernelPoint> = Vec::new();
    println!("\n⊕ kernel dispatch, one application (slice vs per-element):");
    kernel_sweep_for(
        &ops::bxor(),
        |i| (i as i64).wrapping_mul(0x9E37) ^ 0x5aa5,
        quick,
        &mut kernel_sweep,
    );
    kernel_sweep_for(
        &ops::sum_u64(),
        |i| (i as u64).wrapping_mul(7919).wrapping_add(3),
        quick,
        &mut kernel_sweep,
    );
    kernel_sweep_for(
        &ops::rec2_compose(),
        |i| {
            let x = (i % 97) as f32;
            Rec2::new([1.0, 0.01 * x, -0.005 * x, 1.0], [0.25 * x, 1.0 - 0.125 * x])
        },
        quick,
        &mut kernel_sweep,
    );
    // The dyn-slice fallback (no registered kernel) rides along for
    // reference; its "slice" path is one virtual call per application.
    kernel_sweep_for(
        &ops::expensive_bxor(8),
        |i| (i as i64).rotate_left(13) ^ 0x0f,
        quick,
        &mut kernel_sweep,
    );

    // ── Compute-path m-sweep: fused vs unfused receive-reduce, and the
    // chunked large-m pipeline vs the flat schedule. Whole-scan timings
    // (paper statistic: min over reps of max over ranks) on persistent
    // worlds; the unfused world routes the same algorithms through the
    // pre-fusion two-pass flow, so the gap isolates the fusion itself. ──
    let p_sweep = 8usize;
    let m_values: &[usize] =
        if quick { &[1, 64, 4096] } else { &[1, 64, 4096, 65536] };
    let sweep_bench = if quick {
        exscan::bench::BenchConfig { warmups: 2, reps: 20, validate: false }
    } else {
        exscan::bench::BenchConfig { warmups: 10, reps: 100, validate: false }
    };
    let fused_world: World<i64> =
        World::new(WorldConfig::new(Topology::flat(p_sweep)).with_transport(backend));
    let unfused_world: World<i64> = World::new(
        WorldConfig::new(Topology::flat(p_sweep))
            .with_unfused_compat(true)
            .with_transport(backend),
    );
    let mut m_sweep: Vec<MSweepPoint> = Vec::new();
    println!("\ncompute-path m-sweep at p={p_sweep} (min µs over reps):");
    for &m in m_values {
        let inputs = exscan::bench::inputs_i64(p_sweep, m, 0xFA57);
        let mut point = |path: &str, world: &World<i64>, algo: &dyn ScanAlgorithm<i64>| {
            let op = ops::bxor();
            let meas = measure_exscan_world(world, &sweep_bench, algo, &op, &inputs)
                .expect("m-sweep measurement");
            m_sweep.push(MSweepPoint {
                path: path.into(),
                algo: meas.algo.clone(),
                p: p_sweep,
                m,
                min_us: meas.min_us,
                ops: op.applications(),
            });
            meas.min_us
        };
        let fused = point("fused", &fused_world, &Exscan123);
        let unfused = point("unfused", &unfused_world, &Exscan123);
        let chunked = point("chunked", &fused_world, &ExscanChunked::auto());
        let flat = point("flat", &fused_world, &ExscanOneDoubling);
        // The large-m engines ride the same sweep so even the quick run
        // smokes them on a real world. No ordering is asserted here:
        // their bandwidth advantage needs p ≫ 8 (see the selection
        // crossover gate below).
        let block = point("block", &fused_world, &ExscanBlock::auto());
        let rsag = point("rsag", &fused_world, &ExscanRsag);
        println!(
            "  m={m:>6}: fused {fused:>9.2}  unfused {unfused:>9.2}  ({:>4.2}x)   \
             chunked {chunked:>9.2}  flat {flat:>9.2}  ({:>4.2}x)   \
             block {block:>9.2}  rsag {rsag:>9.2}",
            unfused / fused,
            flat / chunked
        );
    }

    // ── Theorem-1 / sharded-counter gate (also the CI smoke assertion):
    // the fused path must apply exactly the predicted number of ⊕, and
    // the lazily aggregated sharded counters must agree with the trace. ──
    for &m in m_values {
        let inputs = exscan::bench::inputs_i64(p_sweep, m, 0x7E01);
        let cfg = WorldConfig::new(Topology::flat(p_sweep)).with_trace(true);
        let op = ops::bxor();
        let res = run_scan(&cfg, &Exscan123, &op, &inputs)?;
        let tr = res.trace.expect("tracing enabled");
        let algo: &dyn ScanAlgorithm<i64> = &Exscan123;
        assert_eq!(
            tr.last_rank_ops(),
            algo.predicted_ops(p_sweep),
            "Theorem 1 violated on the fused path at m={m}"
        );
        assert_eq!(
            op.applications(),
            tr.total_ops(),
            "sharded op counters disagree with the trace at m={m}"
        );

        // Slice-kernel vs per-element-reference A/B at the same m:
        // outputs bit-identical, ⊕ application count unchanged — the
        // kernel engine changes per-application cost, never counts.
        let cfg_pe = WorldConfig::new(Topology::flat(p_sweep))
            .with_trace(true)
            .with_per_element_ops(true);
        let op_pe = ops::bxor();
        let res_pe = run_scan(&cfg_pe, &Exscan123, &op_pe, &inputs)?;
        assert_eq!(
            res.outputs, res_pe.outputs,
            "per-element reference diverged from the slice kernel at m={m}"
        );
        assert_eq!(
            op_pe.applications(),
            op.applications(),
            "dispatch path changed the ⊕ application count at m={m}"
        );

        // The large-m engines through the same gate: outputs must match
        // the round-optimal reference (rank 0 is undefined for exscan,
        // so it is excluded) and the trace must match each engine's
        // closed-form round and last-rank ⊕ counts.
        let block = ExscanBlock::auto();
        let op_blk = ops::bxor();
        let res_blk = run_scan(&cfg, &block, &op_blk, &inputs)?;
        assert_eq!(
            res_blk.outputs[1..],
            res.outputs[1..],
            "block-exscan diverged from 123-doubling at m={m}"
        );
        let tr_blk = res_blk.trace.expect("tracing enabled");
        assert_eq!(
            tr_blk.total_rounds(),
            block.rounds_for(p_sweep, m, 8),
            "block-exscan round count off at m={m}"
        );
        assert_eq!(
            tr_blk.last_rank_ops(),
            block.ops_for(p_sweep, m, 8),
            "block-exscan ⊕ count off at m={m}"
        );
        let op_rs = ops::bxor();
        let res_rs = run_scan(&cfg, &ExscanRsag, &op_rs, &inputs)?;
        assert_eq!(
            res_rs.outputs[1..],
            res.outputs[1..],
            "rsag diverged from 123-doubling at m={m}"
        );
        let tr_rs = res_rs.trace.expect("tracing enabled");
        let (rs_rounds, rs_ops) = ExscanRsag::closed_form(p_sweep);
        assert_eq!(tr_rs.total_rounds(), rs_rounds, "rsag round count off at m={m}");
        assert_eq!(tr_rs.last_rank_ops(), rs_ops, "rsag ⊕ count off at m={m}");

        // Small fixed chunks so the quick grid exercises multi-chunk
        // schedules through the gate (at every m > 16; m = 1 still runs
        // the degenerate single-chunk schedule).
        let chunked = ExscanChunked::with_chunk_elems(16);
        let op = ops::bxor();
        let res = run_scan(&cfg, &chunked, &op, &inputs)?;
        let tr = res.trace.expect("tracing enabled");
        assert_eq!(
            tr.last_rank_ops(),
            chunked.ops_for(p_sweep, m),
            "chunked ⊕ count off at m={m}"
        );
        assert_eq!(
            tr.total_rounds(),
            chunked.rounds_for(p_sweep, m),
            "chunked round count off at m={m}"
        );
        assert_eq!(
            op.applications(),
            tr.total_ops(),
            "chunked sharded counters disagree with the trace at m={m}"
        );
    }
    println!("op-count gate: Theorem 1, sharded counters and dispatch A/B OK");

    // ── Large-m selection crossover (schema-v6 `m_crossover`): at every
    // (p, m) grid point the algorithm `select_exscan` picks under the
    // calibrated paper parameters must equal the closed-form argmin over
    // the candidate pool, each candidate priced through its own
    // critical_schedule(p, m) — the honest-selection gate. The grid spans
    // both regimes (m = 1 round-dominated → m = 2^20 bandwidth-dominated)
    // and the predicted boundary per p is solved with `crossover_m`
    // against the eventual bandwidth-regime winner. Closed form only: no
    // execution, so the full p = 256 sweep costs microseconds. ──
    let mut m_crossover: Vec<CrossoverPoint> = Vec::new();
    let xo_params = CostParams::paper_36x1();
    let xo_ms: &[usize] =
        if quick { &[1, 4096, 1 << 20] } else { &[1, 64, 4096, 262_144, 1 << 20] };
    println!("\nlarge-m selection crossover (paper 36x1 params, closed form):");
    for &p in &[8usize, 36, 256] {
        for &m in xo_ms {
            let picked = select_exscan::<i64>(p, m, &xo_params, 1);
            let picked_pred =
                predict_schedule(&picked.critical_schedule(p, m), p, 1, 8, &xo_params);
            let mut best: Option<(f64, String)> = None;
            for algo in select_candidates::<i64>() {
                let pred =
                    predict_schedule(&algo.critical_schedule(p, m), p, 1, 8, &xo_params);
                if best.as_ref().map(|(t, _)| pred.time_us < *t).unwrap_or(true) {
                    best = Some((pred.time_us, algo.name().to_string()));
                }
            }
            let (argmin_us, argmin) = best.expect("non-empty candidate pool");
            assert_eq!(
                picked.name(),
                argmin,
                "selection is not the argmin at p={p} m={m}"
            );
            println!(
                "  p={p:>3} m={m:>8}: {:<16} ({:>10.2} µs predicted)",
                picked.name(),
                picked_pred.time_us
            );
            m_crossover.push(CrossoverPoint {
                p,
                m,
                selected: picked.name().to_string(),
                argmin,
                selected_us: picked_pred.time_us,
                argmin_us,
            });
        }
        // The regime boundary: first m where the large-m winner's
        // schedule undercuts round-optimal 123-doubling.
        let bw_winner = select_exscan::<i64>(p, 1 << 20, &xo_params, 1);
        let boundary = crossover_m(
            |m| Exscan123.critical_schedule(p, m),
            |m| bw_winner.critical_schedule(p, m),
            p,
            1,
            8,
            &xo_params,
            1 << 24,
        );
        match boundary {
            Some(b) => println!(
                "  p={p:>3}: predicted crossover 123-doubling → {} at m ≈ {b}",
                bw_winner.name()
            ),
            None => println!(
                "  p={p:>3}: no crossover to {} below m = 2^24",
                bw_winner.name()
            ),
        }
        // The sweep must not drift back: once the selection leaves the
        // round-optimal pair along increasing m, it stays left.
        let picks: Vec<&str> = m_crossover
            .iter()
            .filter(|pt| pt.p == p)
            .map(|pt| pt.selected.as_str())
            .collect();
        let round_regime =
            |n: &str| n == "123-doubling" || n == "two-op-doubling" || n == "1-doubling";
        let first_bw = picks.iter().position(|n| !round_regime(n)).unwrap_or(picks.len());
        assert!(
            picks[first_bw..].iter().all(|n| !round_regime(n)),
            "selection flapped back to the round regime at p={p}: {picks:?}"
        );
    }
    println!("crossover gate: selection == closed-form argmin at every grid point");

    // ── Scan-service batching sweep: K small-m requests through the
    // engine, batched (all K submitted, one flush → one coalesced
    // collective) vs serial (flush and wait per request → K collectives).
    // Wall time is reported; the rounds/request numbers are deterministic
    // (measured from each batch's trace) and gated below. ──
    let p_svc = 8usize;
    let m_svc = 8usize;
    let svc_ks: &[usize] = if quick { &[1, 4, 16] } else { &[1, 4, 16, 64] };
    let mut svc_sweep: Vec<SvcPoint> = Vec::new();
    println!("\nscan service batching at p={p_svc}, m={m_svc} (per-request):");
    for &k in svc_ks {
        let policy = || exscan::svc::BatchPolicy {
            window: Duration::from_secs(600), // cycles cut by flush only
            max_batch: k.max(1),
            max_coalesced_elems: 1 << 24,
            window_range: None, // fixed window: batch composition stays deterministic
        };
        let all_inputs: Vec<Vec<Vec<i64>>> = (0..k)
            .map(|i| exscan::bench::inputs_i64(p_svc, m_svc, 0x5EC + i as u64))
            .collect();
        let oracles: Vec<_> =
            all_inputs.iter().map(|v| oracle_exscan(v, &ops::bxor())).collect();
        let verify = |outputs: &[Vec<i64>], i: usize| {
            for (r, want) in oracles[i].iter().enumerate() {
                if let Some(want) = want {
                    assert_eq!(&outputs[r], want, "svc request {i} rank {r} wrong");
                }
            }
        };

        // Batched: one cycle for all K.
        let engine =
            ScanEngine::<i64>::new(EngineConfig::new(p_svc).with_policy(policy())).unwrap();
        let t0 = Instant::now();
        let handles: Vec<_> = all_inputs
            .iter()
            .map(|v| engine.submit_exscan(ReqOp::bxor_i64(), v.clone()).unwrap())
            .collect();
        engine.flush();
        for (i, h) in handles.into_iter().enumerate() {
            let out = h.wait_timeout(Duration::from_secs(60)).unwrap();
            verify(&out.outputs, i);
        }
        let batched_us_per_req = t0.elapsed().as_secs_f64() * 1e6 / k as f64;
        let batched_rounds_per_req = engine.metrics().amortized_rounds_per_request;

        // Serial: one cycle per request.
        let engine =
            ScanEngine::<i64>::new(EngineConfig::new(p_svc).with_policy(policy())).unwrap();
        let t0 = Instant::now();
        for (i, v) in all_inputs.iter().enumerate() {
            let h = engine.submit_exscan(ReqOp::bxor_i64(), v.clone()).unwrap();
            engine.flush();
            let out = h.wait_timeout(Duration::from_secs(60)).unwrap();
            verify(&out.outputs, i);
        }
        let serial_us_per_req = t0.elapsed().as_secs_f64() * 1e6 / k as f64;
        let serial_rounds_per_req = engine.metrics().amortized_rounds_per_request;

        println!(
            "  K={k:>3}: batched {batched_us_per_req:>9.2} µs/req ({batched_rounds_per_req:>5.2} rounds/req)   \
             serial {serial_us_per_req:>9.2} µs/req ({serial_rounds_per_req:>4.2} rounds/req)   ({:>4.2}x)",
            serial_us_per_req / batched_us_per_req
        );
        svc_sweep.push(SvcPoint {
            k,
            p: p_svc,
            m: m_svc,
            batched_us_per_req,
            serial_us_per_req,
            batched_rounds_per_req,
            serial_rounds_per_req,
        });
    }
    // Deterministic amortization gate: K coalesced requests pay exactly
    // one collective's rounds — rounds(p)/K per request — while serial
    // execution pays rounds(p) per request; amortized cost must shrink
    // strictly as K grows.
    for pt in &svc_sweep {
        let want = rounds_123(p_svc) as f64 / pt.k as f64;
        assert!(
            (pt.batched_rounds_per_req - want).abs() < 1e-9,
            "K={}: amortized rounds {} != closed form {want}",
            pt.k,
            pt.batched_rounds_per_req
        );
        assert!(
            (pt.serial_rounds_per_req - rounds_123(p_svc) as f64).abs() < 1e-9,
            "K={}: serial rounds {} != rounds(p)",
            pt.k,
            pt.serial_rounds_per_req
        );
    }
    for w in svc_sweep.windows(2) {
        assert!(
            w[1].batched_rounds_per_req < w[0].batched_rounds_per_req,
            "amortized rounds/request must shrink as K grows"
        );
    }
    println!("svc amortization gate: rounds/request == rounds(p)/K for every K");

    // ── Service latency under failure (EXPERIMENTS.md §Robustness): a
    // sustained submit stream through the engine with an adaptive
    // batching window, baseline vs a seeded rank-death mid-run. The SLO
    // gates are deterministic invariants (quantile sanity, zero lost
    // requests, attributed failures, live rebuild) plus one generous
    // absolute tail bound — wall-clock quantiles themselves are
    // reported, not tightly gated, so shared CI runners stay green. ──
    let mut svc_latency: Vec<SvcLatencyPoint> = Vec::new();
    let lat_requests: u64 = if quick { 240 } else { 1200 };
    let lat_policy = || {
        exscan::svc::BatchPolicy {
            window: Duration::from_micros(200),
            max_batch: 16,
            max_coalesced_elems: 1 << 24,
            window_range: None,
        }
        .with_adaptive_window(Duration::from_micros(50), Duration::from_millis(2))
    };
    println!("\nscan service latency at p={p_svc}, m={m_svc}, {lat_requests} requests:");
    for scenario in ["baseline", "rank-death"] {
        let mut ecfg = EngineConfig::new(p_svc)
            .with_policy(lat_policy())
            .with_recv_timeout(Duration::from_millis(500));
        if scenario == "rank-death" {
            // Death only — delay/divert/yield off so every failure in
            // this scenario is attributable to the kill.
            ecfg = ecfg.with_chaos(
                ChaosConfig::new(0xD0A)
                    .with_delay_prob(0.0)
                    .with_divert_prob(0.0)
                    .with_yield_prob(0.0)
                    // Low tick so the kill reliably fires mid-stream
                    // (each 16-request burst advances a rank's chaos
                    // counter by only a handful of ticks; `>=` trigger
                    // means an early estimate can only fire sooner).
                    .with_rank_death(p_svc / 2, if quick { 60 } else { 300 }),
            );
        }
        let engine = ScanEngine::<i64>::new(ecfg).unwrap();
        // Closed-loop stream: submit a 16-request burst, flush, wait it
        // out, repeat — each cycle stays small, so a rank death fails at
        // most one burst and the post-rebuild tail keeps measuring.
        let (mut ok, mut err) = (0u64, 0u64);
        for burst in 0..(lat_requests / 16) {
            let handles: Vec<_> = (0..16)
                .map(|i| {
                    let inputs =
                        exscan::bench::inputs_i64(p_svc, m_svc, 0xA110 + burst * 16 + i);
                    engine.submit_exscan(ReqOp::bxor_i64(), inputs).unwrap()
                })
                .collect();
            engine.flush();
            for h in handles {
                match h.wait_timeout(Duration::from_secs(60)) {
                    Ok(_) => ok += 1,
                    Err(SvcError::WaitTimeout) => panic!("svc latency: handle timed out"),
                    Err(_) => err += 1,
                }
            }
        }
        let s = quiesced_metrics(&engine);
        drop(engine);
        println!(
            "  {scenario:<10}: p50 {:>9.1} µs  p99 {:>9.1} µs  p999 {:>9.1} µs   \
             ok {ok}  failed {err}  rebuilds {}",
            s.latency_p50_us, s.latency_p99_us, s.latency_p999_us, s.worlds_rebuilt
        );
        // SLO gates (deterministic invariants).
        assert_eq!(s.submitted, lat_requests, "{scenario}: all submissions admitted");
        assert_eq!(
            s.submitted,
            s.completed + s.failed,
            "{scenario}: zero-lost-requests invariant"
        );
        assert_eq!(s.completed, ok, "{scenario}: observed completions match metrics");
        assert_eq!(s.failed, err, "{scenario}: observed failures match metrics");
        assert_eq!(s.inflight_bytes, 0, "{scenario}: inflight gauge drained");
        assert_eq!(s.latency_count, s.completed, "{scenario}: histogram covers completions");
        assert!(
            s.latency_p50_us <= s.latency_p99_us && s.latency_p99_us <= s.latency_p999_us,
            "{scenario}: quantiles monotone"
        );
        assert!(
            s.latency_p999_us < 60_000_000.0,
            "{scenario}: p999 under the wait deadline"
        );
        match scenario {
            "baseline" => {
                assert_eq!(s.failed, 0, "baseline: no failures");
                assert_eq!(s.rank_failures, 0, "baseline: no rank failures");
            }
            _ => {
                assert!(s.rank_failures >= 1, "rank-death: attributed failures present");
                assert!(s.worlds_rebuilt >= 1, "rank-death: live rebuild happened");
                assert!(
                    s.completed > s.failed,
                    "rank-death: engine kept serving after the kill"
                );
                assert_eq!(
                    s.rank_failures, s.failed,
                    "rank-death: every failure attributed to the kill"
                );
            }
        }
        svc_latency.push(SvcLatencyPoint {
            scenario: scenario.into(),
            p: p_svc,
            requests: lat_requests,
            p50_us: s.latency_p50_us,
            p99_us: s.latency_p99_us,
            p999_us: s.latency_p999_us,
            failed: s.failed,
            rank_failures: s.rank_failures,
            worlds_rebuilt: s.worlds_rebuilt,
        });
    }
    println!("svc latency SLO gates: invariants hold in both scenarios");

    // ── Soak (EXPERIMENTS.md §Robustness): waves of mixed full-world +
    // sub-range requests under a periodic seeded rank-death schedule.
    // Deaths are scheduled to land in the first half; the second half is
    // the steady state whose pool counters must stay flat. ──
    let mut soak: Vec<SoakPoint> = Vec::new();
    // Scale knob: total request budget per seed (8 requests/wave), env-
    // overridable so CI and long-haul runs share one binary. Same knob
    // `exscan serve --soak` reads; the flag wins there, only the env
    // exists here (cargo benches take no custom flags).
    let soak_waves: usize = match std::env::var("EXSCAN_SOAK_REQUESTS") {
        Ok(s) => {
            let budget: usize = s
                .parse()
                .map_err(|e| anyhow::anyhow!("EXSCAN_SOAK_REQUESTS={s:?}: {e}"))?;
            (budget / 8).max(1)
        }
        Err(_) => {
            if quick {
                80
            } else {
                400
            }
        }
    };
    let soak_seeds: &[u64] = if quick { &[11] } else { &[11, 12] };
    // Death ticks are tuned so both kills land in the first half at the
    // default wave count; scale them with the wave count so an env-
    // overridden budget keeps that property (and the death-fired gate).
    let base_waves: usize = if quick { 80 } else { 400 };
    let base_sched: &[(usize, u64)] =
        if quick { &[(2, 150), (5, 300)] } else { &[(2, 600), (5, 1200)] };
    let death_sched: Vec<(usize, u64)> = base_sched
        .iter()
        .map(|&(r, t)| (r, ((t as usize * soak_waves / base_waves) as u64).max(1)))
        .collect();
    println!("\nsoak at p={p_svc}: {soak_waves} waves × 8 requests, deaths {death_sched:?}:");
    for &seed in soak_seeds {
        let mut chaos = ChaosConfig::new(seed)
            .with_delay_prob(0.0)
            .with_divert_prob(0.0)
            .with_yield_prob(0.0);
        for &(r, t) in &death_sched {
            chaos = chaos.with_rank_death(r, t);
        }
        let engine = ScanEngine::<i64>::new(
            EngineConfig::new(p_svc)
                .with_policy(lat_policy())
                .with_chaos(chaos)
                .with_recv_timeout(Duration::from_millis(500)),
        )
        .unwrap();
        let (mut mid_misses, mut mid_rebuilds) = (0u64, 0u64);
        for w in 0..soak_waves {
            let mut handles = Vec::with_capacity(8);
            for i in 0..6u64 {
                let inputs =
                    exscan::bench::inputs_i64(p_svc, m_svc, seed * 7919 + w as u64 * 8 + i);
                handles.push(engine.submit_exscan(ReqOp::bxor_i64(), inputs).unwrap());
            }
            // Two disjoint sub-range requests ride along so the solo /
            // segmented paths soak too.
            for start in [0, p_svc / 2] {
                let inputs: Vec<Vec<i64>> = (start..start + p_svc / 2)
                    .map(|r| vec![(r as i64) ^ (w as i64); m_svc])
                    .collect();
                handles
                    .push(engine.submit(ScanRequest::over(ReqOp::bxor_i64(), start, inputs)).unwrap());
            }
            engine.flush();
            for h in handles {
                match h.wait_timeout(Duration::from_secs(60)) {
                    Ok(_) | Err(SvcError::RankFailed { .. }) | Err(SvcError::Collective(_)) => {}
                    Err(e) => panic!("soak seed {seed} wave {w}: unexpected error {e:?}"),
                }
            }
            if w == soak_waves / 2 {
                let s = engine.metrics();
                mid_misses = s.pool_misses;
                mid_rebuilds = s.worlds_rebuilt;
            }
        }
        let s = quiesced_metrics(&engine);
        drop(engine);
        let pool_miss_delta = s.pool_misses.saturating_sub(mid_misses);
        println!(
            "  seed {seed}: submitted {}  completed {}  failed {}  rebuilds {}  \
             p99 {:>9.1} µs  pool-miss Δ(2nd half) {pool_miss_delta}",
            s.submitted, s.completed, s.failed, s.worlds_rebuilt, s.latency_p99_us
        );
        assert_eq!(
            s.submitted,
            s.completed + s.failed,
            "soak seed {seed}: zero-lost-requests invariant"
        );
        assert_eq!(s.inflight_bytes, 0, "soak seed {seed}: inflight gauge drained");
        assert_eq!(s.rejected, 0, "soak seed {seed}: wave pacing never trips admission");
        assert!(s.worlds_rebuilt >= 1, "soak seed {seed}: at least one death fired");
        assert!(s.rank_failures >= 1, "soak seed {seed}: failures attributed");
        assert!(
            s.completed > s.failed,
            "soak seed {seed}: steady state dominated by successes"
        );
        // Flat-memory gate, valid only when the second half saw no
        // rebuild (a rebuild legitimately re-warms fresh pools).
        if s.worlds_rebuilt == mid_rebuilds {
            assert_eq!(
                pool_miss_delta, 0,
                "soak seed {seed}: steady-state pools must recycle, not allocate"
            );
        }
        soak.push(SoakPoint {
            seed,
            p: p_svc,
            submitted: s.submitted,
            completed: s.completed,
            failed: s.failed,
            rejected: s.rejected,
            // Every rebuild in this scenario is death-caused (all other
            // chaos faults are disabled).
            rank_deaths: s.worlds_rebuilt,
            worlds_rebuilt: s.worlds_rebuilt,
            p99_us: s.latency_p99_us,
            pool_miss_delta,
        });
    }
    println!("soak gates: zero lost requests and flat steady-state memory");

    // ── Topology sweep (schema-v7 `topo_sweep`): the two-level leader
    // scheme vs flat 123-doubling on the virtual clock, priced by the
    // seeded per-link matrices. Gates: two-level strictly faster at every
    // (hierarchical preset, m) point; on the uniform null-hypothesis
    // matrix it must never be faster and the topology-aware selection
    // must never pick it (classic flat selection is untouched by
    // construction). Virtual clock only, so the sweep is deterministic
    // and costs seconds even in the full run. ──
    let topo_seed = 7u64;
    let topo_ms: &[usize] = if quick { &[4] } else { &[1, 4, 64, 4096] };
    let mut topo_sweep: Vec<TopoSweepPoint> = Vec::new();
    println!("\ntopology sweep (virtual clock, seed {topo_seed}):");
    let mut topo_presets = Topo::hierarchical_presets(topo_seed);
    topo_presets.push(Topo::flat(36, topo_seed));
    for topo in topo_presets {
        let topo = Arc::new(topo);
        let p = topo.size();
        for &m in topo_ms {
            let inputs = exscan::bench::inputs_i64(p, m, topo_seed);
            let completion = |algo: &dyn ScanAlgorithm<i64>| -> f64 {
                let cfg =
                    WorldConfig::new(Topology::flat(p)).virtual_clock_topo(topo.clone());
                run_scan(&cfg, algo, &ops::bxor(), &inputs).unwrap().completion_us()
            };
            let two = completion(&ExscanTwoLevel::new(topo.ranks_per_node()));
            let flat = completion(&Exscan123);
            let selected = select_exscan_topo::<i64>(p, m, &topo).name().to_string();
            if topo.is_hierarchical() {
                assert!(
                    two < flat,
                    "{} m={m}: two-level {two:.2} µs must strictly beat flat 123 {flat:.2} µs",
                    topo.name()
                );
            } else {
                assert!(
                    two >= flat,
                    "{} m={m}: two-level {two:.2} µs must not beat flat 123 {flat:.2} µs \
                     on the uniform matrix",
                    topo.name()
                );
                assert_ne!(
                    selected, "two-level",
                    "{} m={m}: selection must never pick two-level on a uniform matrix",
                    topo.name()
                );
            }
            println!(
                "  {:<12} m={m:>5}: two-level {two:>9.2} µs vs flat123 {flat:>9.2} µs → {selected}",
                topo.name()
            );
            topo_sweep.push(TopoSweepPoint {
                topo: topo.name().to_string(),
                seed: topo_seed,
                digest: topo.matrix_digest(),
                p,
                m,
                two_level_us: two,
                flat123_us: flat,
                selected,
            });
        }
    }
    println!(
        "topo gate: two-level strictly beats flat 123 on every hierarchical preset, \
         never on the uniform matrix"
    );

    // ── Wire-fault overhead (schema-v8 `wire_fault`, §Robustness): the
    // same whole-scan workload on every wire backend this host offers,
    // clean vs the seeded fault plan with recovery on. The gate is
    // correctness, not speed: every faulted run must verify bit-exactly
    // against the oracle, with the plan demonstrably injecting and the
    // repair counters proving the recovery layer (not luck) carried it.
    // The overhead ratio is the reported trajectory number. Thread
    // backend has no wire layer; hosts without a wire backend record an
    // empty section. ──
    let mut wire_fault: Vec<WireFaultPoint> = Vec::new();
    let wf_seed = 0xA11CEu64;
    // Enough reps that the ~9%-per-frame plan injects with overwhelming
    // probability even on the quick grid (the gates below demand it).
    let wf_reps = if quick { 4 } else { 8 };
    let wf_p = 4usize;
    let wf_m: usize = if quick { 64 } else { 1024 };
    println!("\nwire-fault overhead at p={wf_p}, m={wf_m} (seed {wf_seed:#x}, recovery on):");
    for b in TransportBackend::available() {
        if b == TransportBackend::Thread {
            continue;
        }
        let wf_inputs = exscan::bench::inputs_i64(wf_p, wf_m, wf_seed);
        let wf_oracle = oracle_exscan(&wf_inputs, &ops::bxor());
        let time_world = |world: &World<i64>| -> (f64, bool) {
            let op = ops::bxor();
            let mut best = f64::INFINITY;
            let mut ok = true;
            for _ in 0..wf_reps {
                let t0 = Instant::now();
                let outs = world
                    .run(|ctx| {
                        let input = &wf_inputs[ctx.rank()];
                        let mut output = vec![0i64; wf_m];
                        ctx.barrier();
                        Exscan123.run(ctx, input, &mut output, &op)?;
                        Ok(output)
                    })
                    .expect("wire-fault bench run failed (recovery is on)");
                best = best.min(t0.elapsed().as_secs_f64() * 1e6);
                for r in 1..wf_p {
                    ok &= Some(&outs[r]) == wf_oracle[r].as_ref();
                }
            }
            (best, ok)
        };
        let clean_world: World<i64> =
            World::new(WorldConfig::new(Topology::flat(wf_p)).with_transport(b));
        let (clean_us, clean_ok) = time_world(&clean_world);
        assert!(clean_ok, "{b}: clean reference run failed verification");
        let faulted_world: World<i64> = World::new(
            WorldConfig::new(Topology::flat(wf_p))
                .with_transport(b)
                .with_wire_faults(WireFaultConfig::new(wf_seed)),
        );
        let (faulted_us, verified) = time_world(&faulted_world);
        let stats = faulted_world.wire_stats();
        let report = faulted_world.wire_report().expect("fault plan armed");
        assert!(
            verified,
            "{b}: faulted run must verify bit-exactly at seed {wf_seed:#x}"
        );
        assert!(
            report.injected() >= 1,
            "{b}: the plan injected nothing — not a wire-fault measurement"
        );
        assert!(
            stats.retransmits + stats.reconnects + stats.dropped_dups >= 1,
            "{b}: verified run shows no recovery activity at seed {wf_seed:#x}"
        );
        println!(
            "  {b:<6}: clean {clean_us:>9.2} µs  faulted {faulted_us:>9.2} µs ({:>4.2}x)   \
             {} injected, {} retransmits, {} reconnects, {} dups dropped",
            faulted_us / clean_us,
            report.injected(),
            stats.retransmits,
            stats.reconnects,
            stats.dropped_dups
        );
        wire_fault.push(WireFaultPoint {
            backend: b.to_string(),
            seed: wf_seed,
            p: wf_p,
            m: wf_m,
            clean_us,
            faulted_us,
            injected: report.injected(),
            retransmits: stats.retransmits,
            reconnects: stats.reconnects,
            dropped_dups: stats.dropped_dups,
            fault_digest: report.digest,
            verified,
        });
    }
    if wire_fault.is_empty() {
        println!("  no wire backends available on this host; section empty");
    } else {
        println!("wire-fault gate: every faulted run verified with live recovery counters");
    }

    // ── World spawn/teardown vs persistent job submit at the same p. ──
    let mut spawn_meta = Vec::new();
    for p in [16usize, 144] {
        let cfg = WorldConfig::new(Topology::flat(p));
        let iters = if quick { 3 } else { 20 };
        let spawn_ns = bench_ns(iters, || {
            exscan::mpi::run_world::<i64, usize, _>(&cfg, |ctx| Ok(ctx.rank())).unwrap();
        });
        let world: World<i64> = World::new(cfg);
        let submit_ns = bench_ns(iters * 10, || {
            world.run(|ctx| Ok(ctx.rank())).unwrap();
        });
        println!(
            "p={p:>4}: spawn+join {:>9.2} ms/run   persistent submit {:>9.3} ms/run   ({:.1}x)",
            spawn_ns / 1e6,
            submit_ns / 1e6,
            spawn_ns / submit_ns
        );
        spawn_meta.push(format!(
            "p={p}: spawn={:.2}ms submit={:.3}ms",
            spawn_ns / 1e6,
            submit_ns / 1e6
        ));
    }

    // ── End-to-end: one full 123-doubling at p=36 on the new transport. ──
    let world36: World<i64> =
        World::new(WorldConfig::new(Topology::flat(36)).with_transport(backend));
    let inputs = exscan::bench::inputs_i64(36, 1000, 1);
    let bench = if quick {
        exscan::bench::BenchConfig::quick()
    } else {
        exscan::bench::BenchConfig { warmups: 10, reps: 100, validate: false }
    };
    let meas = exscan::bench::measure_exscan_world(
        &world36,
        &bench,
        &Exscan123,
        &ops::bxor(),
        &inputs,
    )?;
    println!(
        "123-doubling p=36 m=1000 (real):  {:>8.1} µs min, {:.1} µs mean",
        meas.min_us, meas.mean_us
    );

    // ── Trajectory record. ──
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(0);
    let meta = vec![
        ("bench", "hotpath".to_string()),
        ("mode", if quick { "quick".into() } else { "full".into() }),
        ("transport", backend.to_string()),
        ("os", std::env::consts::OS.to_string()),
        ("arch", std::env::consts::ARCH.to_string()),
        ("cores", cores.to_string()),
        ("spawn_vs_submit", spawn_meta.join("; ")),
        (
            "e2e_123_p36_m1000",
            format!("min={:.1}us mean={:.1}us", meas.min_us, meas.mean_us),
        ),
    ];
    let json = hotpath_json(
        &meta,
        &points,
        &m_sweep,
        &svc_sweep,
        &kernel_sweep,
        &latency_sweep,
        &svc_latency,
        &soak,
        &m_crossover,
        &topo_sweep,
        &wire_fault,
    );
    // Cargo runs bench binaries with cwd = the *package* root (rust/), so
    // anchor the output at the workspace root explicitly — that is where
    // the committed placeholder lives and where CI validates the schema.
    let out_path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_hotpath.json");
    std::fs::write(out_path, &json)?;
    println!("wrote {out_path}");

    // Regression gate: the slot transport must be strictly faster than
    // legacy. Only enforced where the measurement is meaningful — ring
    // rendezvous at p threads on a c-core host is scheduler-bound once
    // p > c, so oversubscribed points are reported but not gated (shared
    // CI runners have 2–4 cores). The 2x acceptance bar for this PR is
    // read off the full run on an idle multi-core host (EXPERIMENTS.md).
    for p in [4usize, 16, 32] {
        if backend != TransportBackend::Thread {
            // Wire backends pay serialization + a frame copy per hop by
            // design; the slot-vs-legacy bar is a thread-backend claim.
            println!("gate: skipping p={p} (transport={backend}, gate is thread-only)");
            continue;
        }
        if p > cores {
            println!("gate: skipping p={p} (> {cores} cores, oversubscribed)");
            continue;
        }
        let ns_of = |t: &str| {
            points
                .iter()
                .find(|x| x.transport == t && x.p == p)
                .map(|x| x.ns_per_round)
                .unwrap()
        };
        assert!(
            ns_of("slot-pool") < ns_of("legacy-mpmc"),
            "slot transport regressed at p={p}: {:.1} ns vs legacy {:.1} ns",
            ns_of("slot-pool"),
            ns_of("legacy-mpmc")
        );
    }
    println!("hotpath bench done");
    Ok(())
}
