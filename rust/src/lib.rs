//! # exscan — communication-round and computation efficient exclusive prefix sums
//!
//! A full reproduction of
//! *"Communication Round and Computation Efficient Exclusive Prefix-Sums
//! Algorithms (for MPI_Exscan)"* (J. L. Träff, 2025) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the coordination contribution: a
//!   message-passing runtime ([`mpi`]) with real-thread and virtual-clock
//!   transports, the scan collective library ([`coll`]) containing the
//!   paper's three exclusive-scan algorithms plus the library-native
//!   baseline and several extensions, a round tracer ([`trace`]) that
//!   checks the paper's round/operation counts, a calibrated α-β-γ cost
//!   model ([`cost`]), an mpicroscope-style benchmark harness
//!   ([`bench`]), and a multi-tenant scan service ([`svc`]) that
//!   coalesces independent small-m exscan requests into single
//!   collectives on communicator-isolated contexts.
//! * **Layer 2/1 (build time, `python/compile/`)** — the element-wise
//!   `⊕` combine (`MPI_Reduce_local`) and block-scan hot spots as Pallas
//!   kernels inside JAX functions, AOT-lowered to HLO text.
//! * **Runtime bridge** ([`runtime`]) — loads `artifacts/*.hlo.txt` via
//!   the PJRT C API (`xla` crate) so an "expensive ⊕" runs through the
//!   compiled kernel on the Layer-3 hot path; Python is never on the
//!   request path.
//!
//! ## Quickstart
//!
//! ```no_run
//! use exscan::prelude::*;
//!
//! // 36 ranks, one per node (the paper's 36x1 configuration), BXOR on i64.
//! let cfg = WorldConfig::new(Topology::cluster(36, 1)).virtual_clock(CostParams::paper_36x1());
//! let algo = Exscan123;
//! let inputs: Vec<Vec<i64>> = (0..36).map(|r| vec![r as i64; 8]).collect();
//! let out = run_scan(&cfg, &algo, &ops::bxor(), &inputs).unwrap();
//! assert_eq!(out.outputs[3], vec![0 ^ 1 ^ 2; 8]);
//! ```

// Style-lint allowances so CI can run `clippy -- -D warnings`: these are
// deliberate idioms here (indexed loops over rank grids, wide config
// constructors, transport channel types), not bugs.
#![allow(
    clippy::too_many_arguments,
    clippy::needless_range_loop,
    clippy::type_complexity,
    clippy::manual_div_ceil
)]

pub mod bench;
pub mod cli;
pub mod coll;
pub mod cost;
pub mod mpi;
pub mod runtime;
pub mod svc;
pub mod topo;
pub mod trace;
pub mod util;

/// Convenient re-exports of the most commonly used types.
pub mod prelude {
    pub use crate::bench::{BenchConfig, Harness, SweepSpec};
    pub use crate::coll::{
        all_exscan_algorithms, Exscan123, Exscan1247, ExscanBlelloch, ExscanBlock,
        ExscanChunked, ExscanLinear, ExscanMpich, ExscanOneDoubling, ExscanPow2, ExscanRsag,
        ExscanTwoLevel, ExscanTwoOp, ScanAlgorithm, ScanDoubling, ScanKind,
    };
    pub use crate::cost::{CostModel, CostParams, LinkClass};
    pub use crate::topo::Topo;
    pub use crate::mpi::{
        ops, run_scan, ChaosConfig, ChaosReport, CombineOp, Comm, Elem, OpKernel, OpRef,
        PoolStats, RankCtx, Rec2, RunResult, TagKey, Topology, TransportBackend, World,
        WorldConfig,
    };
    pub use crate::svc::{
        BatchPolicy, EngineConfig, ReqOp, ScanEngine, ScanHandle, ScanRequest, SvcError,
    };
    pub use crate::trace::{RankTrace, TraceReport};
}
