//! Service-side accounting: how much the batcher actually amortizes,
//! and what the failure-hardening layer costs in tail latency.
//!
//! The paper's small-m regime is round-dominated, so the service's
//! figure of merit is **rounds per request**: a batch of K coalesced
//! requests pays one collective's rounds for all K. The counters here
//! track that ratio (plus enough operational detail to debug a
//! misbehaving deployment: batch-size distribution, failures, world
//! rebuilds). All counters are relaxed atomics — the dispatcher is the
//! only writer on the hot path; readers snapshot.
//!
//! ## Latency histogram
//!
//! Completion latency (submit → fulfilled, successful requests only)
//! feeds a **fixed log-linear bucket histogram**: 4 sub-buckets per
//! power-of-two octave over nanoseconds, 256 pre-allocated atomic
//! buckets total — one relaxed `fetch_add` per completion, zero hot-path
//! allocation, ≤ 25 % relative bucket width. Quantiles (p50/p99/p999)
//! are derived at snapshot time by a cumulative rank walk and reported
//! as the matched bucket's **upper** bound — conservative, so an SLO
//! gate on them can only over-estimate, never excuse, the tail.

use std::sync::atomic::{AtomicU64, Ordering};

use super::request::BatchMode;

/// Power-of-two batch-size histogram buckets: 1, 2, 3–4, 5–8, 9–16,
/// 17–32, 33–64, 65+.
pub const BATCH_HIST_BUCKETS: usize = 8;

fn bucket(k: usize) -> usize {
    match k {
        0 | 1 => 0,
        2 => 1,
        3..=4 => 2,
        5..=8 => 3,
        9..=16 => 4,
        17..=32 => 5,
        33..=64 => 6,
        _ => 7,
    }
}

/// Latency histogram size: 4 sub-buckets per octave over the full u64
/// nanosecond range (4·63 + 4 < 256), fixed at construction.
pub const LAT_BUCKETS: usize = 256;

/// Bucket index of a latency observation in nanoseconds (log-linear:
/// 4 sub-buckets per power-of-two octave; exact below 4 ns).
fn lat_bucket(ns: u64) -> usize {
    let n = ns.max(1);
    if n < 4 {
        return n as usize;
    }
    let e = 63 - n.leading_zeros() as usize; // 2^e <= n < 2^(e+1), e >= 2
    let sub = ((n >> (e - 2)) & 3) as usize;
    4 * (e - 1) + sub
}

/// Inclusive lower bound (ns) of bucket `idx` — the inverse of
/// [`lat_bucket`]'s truncation.
fn lat_bucket_lower(idx: usize) -> u64 {
    if idx < 4 {
        return idx as u64;
    }
    let e = idx / 4 + 1;
    let sub = (idx % 4) as u64;
    if e - 2 >= 62 {
        return u64::MAX; // buckets past the top octave are unreachable
    }
    (4 + sub) << (e - 2)
}

/// Exclusive upper bound (ns) of bucket `idx` (saturating at the top).
fn lat_bucket_upper(idx: usize) -> u64 {
    if idx + 1 >= LAT_BUCKETS {
        return u64::MAX;
    }
    lat_bucket_lower(idx + 1).max(idx as u64 + 1)
}

/// Rank-walk quantile over a bucket snapshot: the upper bound of the
/// bucket holding the `q`-quantile observation (0 when empty).
fn quantile_ns(hist: &[u64; LAT_BUCKETS], count: u64, q: f64) -> u64 {
    if count == 0 {
        return 0;
    }
    let target = ((count as f64) * q).ceil().max(1.0) as u64;
    let mut seen = 0u64;
    for (idx, &c) in hist.iter().enumerate() {
        seen += c;
        if seen >= target {
            return lat_bucket_upper(idx);
        }
    }
    lat_bucket_upper(LAT_BUCKETS - 1)
}

/// Cumulative service counters (see the module docs).
#[derive(Debug, Default)]
pub struct ServiceMetrics {
    submitted: AtomicU64,
    completed: AtomicU64,
    failed: AtomicU64,
    batches: AtomicU64,
    solo_batches: AtomicU64,
    concat_batches: AtomicU64,
    segmented_batches: AtomicU64,
    /// Per-rank elements the coalesced collectives carried, summed.
    coalesced_elems: AtomicU64,
    /// Communication rounds actually paid by executed collectives.
    rounds_paid: AtomicU64,
    /// Rounds the same requests would have paid run one collective each
    /// (closed-form `predicted_rounds` over each request's span).
    rounds_solo_equiv: AtomicU64,
    worlds_rebuilt: AtomicU64,
    batch_hist: [AtomicU64; BATCH_HIST_BUCKETS],
    /// Requests refused at admission (never counted in `submitted`).
    rejected: AtomicU64,
    /// Timed-out handles whose late result was delivered unobserved.
    abandoned: AtomicU64,
    /// Requests failed with an attributed `SvcError::RankFailed`.
    rank_failures: AtomicU64,
    /// Gauge: payload bytes of accepted, not-yet-resolved requests.
    inflight_bytes: AtomicU64,
    /// Gauges mirroring the engine worlds' pool counters (set, not added).
    pool_hits: AtomicU64,
    pool_misses: AtomicU64,
    /// Gauges mirroring the engine worlds' wire-transport recovery
    /// counters ([`crate::mpi::TransportStats`]; all zero on the thread
    /// backend). Set once per cycle, like the pool gauges.
    wire_retransmits: AtomicU64,
    wire_reconnects: AtomicU64,
    wire_dropped_dups: AtomicU64,
    transport_faults: AtomicU64,
    latency_count: AtomicU64,
    latency_hist: LatencyHist,
}

/// 256 pre-allocated buckets; a nested struct keeps `Default` derivable.
#[derive(Debug)]
struct LatencyHist([AtomicU64; LAT_BUCKETS]);

impl Default for LatencyHist {
    fn default() -> Self {
        LatencyHist(std::array::from_fn(|_| AtomicU64::new(0)))
    }
}

impl ServiceMetrics {
    pub(crate) fn on_submit(&self) {
        self.submitted.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn on_failed(&self, n: u64) {
        self.failed.fetch_add(n, Ordering::Relaxed);
    }

    pub(crate) fn on_world_rebuilt(&self) {
        self.worlds_rebuilt.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn on_rejected(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn on_abandoned(&self) {
        self.abandoned.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn on_rank_failed(&self, n: u64) {
        self.rank_failures.fetch_add(n, Ordering::Relaxed);
    }

    pub(crate) fn add_inflight_bytes(&self, n: u64) {
        self.inflight_bytes.fetch_add(n, Ordering::Relaxed);
    }

    pub(crate) fn sub_inflight_bytes(&self, n: u64) {
        self.inflight_bytes.fetch_sub(n, Ordering::Relaxed);
    }

    pub(crate) fn inflight_bytes(&self) -> u64 {
        self.inflight_bytes.load(Ordering::Relaxed)
    }

    /// Requests submitted but not yet completed or failed — the count
    /// the engine's admission gate bounds. Three relaxed loads, so
    /// transiently approximate under concurrent submitters; the bounded
    /// queue is the structural backstop.
    pub(crate) fn open_requests(&self) -> u64 {
        let done = self.completed.load(Ordering::Relaxed)
            + self.failed.load(Ordering::Relaxed);
        self.submitted.load(Ordering::Relaxed).saturating_sub(done)
    }

    pub(crate) fn set_pool_gauges(&self, hits: u64, misses: u64) {
        self.pool_hits.store(hits, Ordering::Relaxed);
        self.pool_misses.store(misses, Ordering::Relaxed);
    }

    /// Mirror the worlds' wire-recovery counters (once per cycle; zero
    /// on the thread backend where no wire layer exists).
    pub(crate) fn set_wire_gauges(
        &self,
        retransmits: u64,
        reconnects: u64,
        dropped_dups: u64,
        faults: u64,
    ) {
        self.wire_retransmits.store(retransmits, Ordering::Relaxed);
        self.wire_reconnects.store(reconnects, Ordering::Relaxed);
        self.wire_dropped_dups.store(dropped_dups, Ordering::Relaxed);
        self.transport_faults.store(faults, Ordering::Relaxed);
    }

    /// One relaxed increment into the fixed histogram — no allocation.
    pub(crate) fn record_latency_ns(&self, ns: u64) {
        self.latency_count.fetch_add(1, Ordering::Relaxed);
        self.latency_hist.0[lat_bucket(ns)].fetch_add(1, Ordering::Relaxed);
    }

    /// Record one executed collective: `k` requests coalesced,
    /// `coalesced_m` elements per rank, `rounds` measured from its trace,
    /// `solo_equiv` the closed-form rounds its requests would have paid
    /// individually.
    pub(crate) fn on_batch(
        &self,
        mode: BatchMode,
        k: usize,
        coalesced_m: usize,
        rounds: u32,
        solo_equiv: u64,
    ) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        match mode {
            BatchMode::Solo => &self.solo_batches,
            BatchMode::Concat => &self.concat_batches,
            BatchMode::Segmented => &self.segmented_batches,
        }
        .fetch_add(1, Ordering::Relaxed);
        self.completed.fetch_add(k as u64, Ordering::Relaxed);
        self.coalesced_elems.fetch_add(coalesced_m as u64, Ordering::Relaxed);
        self.rounds_paid.fetch_add(rounds as u64, Ordering::Relaxed);
        self.rounds_solo_equiv.fetch_add(solo_equiv, Ordering::Relaxed);
        self.batch_hist[bucket(k)].fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let completed = self.completed.load(Ordering::Relaxed);
        let rounds_paid = self.rounds_paid.load(Ordering::Relaxed);
        let rounds_solo = self.rounds_solo_equiv.load(Ordering::Relaxed);
        let latency_count = self.latency_count.load(Ordering::Relaxed);
        let hist: [u64; LAT_BUCKETS] =
            std::array::from_fn(|i| self.latency_hist.0[i].load(Ordering::Relaxed));
        let us = |ns: u64| ns as f64 / 1_000.0;
        MetricsSnapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            completed,
            failed: self.failed.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            solo_batches: self.solo_batches.load(Ordering::Relaxed),
            concat_batches: self.concat_batches.load(Ordering::Relaxed),
            segmented_batches: self.segmented_batches.load(Ordering::Relaxed),
            coalesced_elems: self.coalesced_elems.load(Ordering::Relaxed),
            rounds_paid,
            rounds_solo_equiv: rounds_solo,
            worlds_rebuilt: self.worlds_rebuilt.load(Ordering::Relaxed),
            batch_hist: std::array::from_fn(|i| self.batch_hist[i].load(Ordering::Relaxed)),
            rejected: self.rejected.load(Ordering::Relaxed),
            abandoned: self.abandoned.load(Ordering::Relaxed),
            rank_failures: self.rank_failures.load(Ordering::Relaxed),
            inflight_bytes: self.inflight_bytes.load(Ordering::Relaxed),
            pool_hits: self.pool_hits.load(Ordering::Relaxed),
            pool_misses: self.pool_misses.load(Ordering::Relaxed),
            wire_retransmits: self.wire_retransmits.load(Ordering::Relaxed),
            wire_reconnects: self.wire_reconnects.load(Ordering::Relaxed),
            wire_dropped_dups: self.wire_dropped_dups.load(Ordering::Relaxed),
            transport_faults: self.transport_faults.load(Ordering::Relaxed),
            latency_count,
            latency_p50_us: us(quantile_ns(&hist, latency_count, 0.50)),
            latency_p99_us: us(quantile_ns(&hist, latency_count, 0.99)),
            latency_p999_us: us(quantile_ns(&hist, latency_count, 0.999)),
            amortized_rounds_per_request: if completed == 0 {
                0.0
            } else {
                rounds_paid as f64 / completed as f64
            },
            round_amortization: if rounds_paid == 0 {
                1.0
            } else {
                rounds_solo as f64 / rounds_paid as f64
            },
        }
    }
}

/// Point-in-time view of [`ServiceMetrics`].
#[derive(Debug, Clone, PartialEq)]
pub struct MetricsSnapshot {
    pub submitted: u64,
    pub completed: u64,
    pub failed: u64,
    pub batches: u64,
    pub solo_batches: u64,
    pub concat_batches: u64,
    pub segmented_batches: u64,
    pub coalesced_elems: u64,
    pub rounds_paid: u64,
    pub rounds_solo_equiv: u64,
    pub worlds_rebuilt: u64,
    pub batch_hist: [u64; BATCH_HIST_BUCKETS],
    /// Requests refused at admission (excluded from `submitted`).
    pub rejected: u64,
    /// Late fulfillments into handles already abandoned by `wait_timeout`.
    pub abandoned: u64,
    /// Requests that failed with `SvcError::RankFailed`.
    pub rank_failures: u64,
    /// Gauge: payload bytes of accepted, unresolved requests (0 at quiesce).
    pub inflight_bytes: u64,
    /// Gauges from the engine worlds' buffer pools (flat-memory evidence).
    pub pool_hits: u64,
    pub pool_misses: u64,
    /// Gauges from the engine worlds' wire-transport recovery layer
    /// (retransmitted frames, simulated reconnects, suppressed duplicate
    /// frames, typed transport faults). All zero on the thread backend.
    pub wire_retransmits: u64,
    pub wire_reconnects: u64,
    pub wire_dropped_dups: u64,
    pub transport_faults: u64,
    /// Successful completions recorded in the latency histogram.
    pub latency_count: u64,
    /// Quantiles in µs, each the matched bucket's upper bound (≤ 25 %
    /// over-estimate — conservative for SLO gating). 0 when empty.
    pub latency_p50_us: f64,
    pub latency_p99_us: f64,
    pub latency_p999_us: f64,
    /// `rounds_paid / completed` — the number batching shrinks.
    pub amortized_rounds_per_request: f64,
    /// `rounds_solo_equiv / rounds_paid` — ≥ 1 when coalescing wins.
    pub round_amortization: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_accounting_amortizes() {
        let m = ServiceMetrics::default();
        for _ in 0..8 {
            m.on_submit();
        }
        // One coalesced batch of 8 requests paying 4 rounds, where solo
        // execution would have paid 8 × 4.
        m.on_batch(BatchMode::Concat, 8, 64, 4, 32);
        let s = m.snapshot();
        assert_eq!(s.submitted, 8);
        assert_eq!(s.completed, 8);
        assert_eq!(s.batches, 1);
        assert_eq!(s.concat_batches, 1);
        assert_eq!(s.rounds_paid, 4);
        assert!((s.amortized_rounds_per_request - 0.5).abs() < 1e-12);
        assert!((s.round_amortization - 8.0).abs() < 1e-12);
        assert_eq!(s.batch_hist[3], 1, "8 lands in the 5–8 bucket");
    }

    #[test]
    fn histogram_buckets() {
        assert_eq!(bucket(1), 0);
        assert_eq!(bucket(2), 1);
        assert_eq!(bucket(4), 2);
        assert_eq!(bucket(8), 3);
        assert_eq!(bucket(16), 4);
        assert_eq!(bucket(33), 6);
        assert_eq!(bucket(1000), 7);
    }

    #[test]
    fn empty_snapshot_is_neutral() {
        let s = ServiceMetrics::default().snapshot();
        assert_eq!(s.amortized_rounds_per_request, 0.0);
        assert_eq!(s.round_amortization, 1.0);
        assert_eq!(s.latency_count, 0);
        assert_eq!(s.latency_p50_us, 0.0);
        assert_eq!(s.latency_p999_us, 0.0);
    }

    #[test]
    fn lat_bucket_bounds_are_consistent() {
        // Every bucket's lower bound maps back to that bucket, and
        // bounds are monotone non-decreasing across the whole range.
        for idx in 1..LAT_BUCKETS {
            let lo = lat_bucket_lower(idx);
            if lo > 0 && idx < 4 * 62 {
                assert_eq!(lat_bucket(lo), idx, "lower bound of bucket {idx}");
            }
            assert!(lat_bucket_lower(idx) >= lat_bucket_lower(idx - 1));
        }
        // Spot-check the log-linear shape: 4 sub-buckets per octave.
        assert_eq!(lat_bucket(4), 4);
        assert_eq!(lat_bucket(5), 5);
        assert_eq!(lat_bucket(7), 7);
        assert_eq!(lat_bucket(8), 8);
        assert_eq!(lat_bucket(1_000), lat_bucket(1_023));
        assert!(lat_bucket(u64::MAX) < LAT_BUCKETS);
        // Relative width ≤ 25 %: upper/lower ratio within one bucket.
        let idx = lat_bucket(1_000_000);
        let (lo, hi) = (lat_bucket_lower(idx), lat_bucket_upper(idx));
        assert!(lo <= 1_000_000 && 1_000_000 < hi);
        assert!((hi - lo) as f64 / lo as f64 <= 0.25 + 1e-9);
    }

    #[test]
    fn latency_quantiles_are_conservative_and_monotone() {
        let m = ServiceMetrics::default();
        // 99 fast observations at ~1 µs, one slow outlier at ~1 ms.
        for _ in 0..99 {
            m.record_latency_ns(1_000);
        }
        m.record_latency_ns(1_000_000);
        let s = m.snapshot();
        assert_eq!(s.latency_count, 100);
        // p50 covers the fast cluster; upper-bound convention means the
        // reported value is >= the true 1 µs but within one bucket.
        assert!(s.latency_p50_us >= 1.0 && s.latency_p50_us <= 1.5);
        // p99 rank (ceil(100·0.99) = 99) still lands in the fast cluster;
        // p999 (rank 100) must surface the outlier.
        assert!(s.latency_p99_us <= 1.5);
        assert!(s.latency_p999_us >= 1_000.0);
        assert!(s.latency_p50_us <= s.latency_p99_us);
        assert!(s.latency_p99_us <= s.latency_p999_us);
    }

    #[test]
    fn robustness_counters_round_trip() {
        let m = ServiceMetrics::default();
        m.on_rejected();
        m.on_rejected();
        m.on_abandoned();
        m.on_rank_failed(3);
        m.add_inflight_bytes(4096);
        m.sub_inflight_bytes(1024);
        m.set_pool_gauges(10, 2);
        m.set_wire_gauges(7, 2, 5, 1);
        let s = m.snapshot();
        assert_eq!(s.rejected, 2);
        assert_eq!(s.abandoned, 1);
        assert_eq!(s.rank_failures, 3);
        assert_eq!(s.inflight_bytes, 3072);
        assert_eq!(m.inflight_bytes(), 3072);
        assert_eq!((s.pool_hits, s.pool_misses), (10, 2));
        assert_eq!(
            (s.wire_retransmits, s.wire_reconnects, s.wire_dropped_dups, s.transport_faults),
            (7, 2, 5, 1)
        );
    }

    #[test]
    fn open_requests_tracks_submit_minus_resolved() {
        let m = ServiceMetrics::default();
        assert_eq!(m.open_requests(), 0);
        m.on_submit();
        m.on_submit();
        m.on_submit();
        assert_eq!(m.open_requests(), 3);
        m.on_batch(BatchMode::Solo, 1, 1, 1, 1); // completes one
        m.on_failed(1);
        assert_eq!(m.open_requests(), 1);
    }
}
