//! Service-side accounting: how much the batcher actually amortizes.
//!
//! The paper's small-m regime is round-dominated, so the service's
//! figure of merit is **rounds per request**: a batch of K coalesced
//! requests pays one collective's rounds for all K. The counters here
//! track that ratio (plus enough operational detail to debug a
//! misbehaving deployment: batch-size distribution, failures, world
//! rebuilds). All counters are relaxed atomics — the dispatcher is the
//! only writer on the hot path; readers snapshot.

use std::sync::atomic::{AtomicU64, Ordering};

use super::request::BatchMode;

/// Power-of-two batch-size histogram buckets: 1, 2, 3–4, 5–8, 9–16,
/// 17–32, 33–64, 65+.
pub const BATCH_HIST_BUCKETS: usize = 8;

fn bucket(k: usize) -> usize {
    match k {
        0 | 1 => 0,
        2 => 1,
        3..=4 => 2,
        5..=8 => 3,
        9..=16 => 4,
        17..=32 => 5,
        33..=64 => 6,
        _ => 7,
    }
}

/// Cumulative service counters (see the module docs).
#[derive(Debug, Default)]
pub struct ServiceMetrics {
    submitted: AtomicU64,
    completed: AtomicU64,
    failed: AtomicU64,
    batches: AtomicU64,
    solo_batches: AtomicU64,
    concat_batches: AtomicU64,
    segmented_batches: AtomicU64,
    /// Per-rank elements the coalesced collectives carried, summed.
    coalesced_elems: AtomicU64,
    /// Communication rounds actually paid by executed collectives.
    rounds_paid: AtomicU64,
    /// Rounds the same requests would have paid run one collective each
    /// (closed-form `predicted_rounds` over each request's span).
    rounds_solo_equiv: AtomicU64,
    worlds_rebuilt: AtomicU64,
    batch_hist: [AtomicU64; BATCH_HIST_BUCKETS],
}

impl ServiceMetrics {
    pub(crate) fn on_submit(&self) {
        self.submitted.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn on_failed(&self, n: u64) {
        self.failed.fetch_add(n, Ordering::Relaxed);
    }

    pub(crate) fn on_world_rebuilt(&self) {
        self.worlds_rebuilt.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one executed collective: `k` requests coalesced,
    /// `coalesced_m` elements per rank, `rounds` measured from its trace,
    /// `solo_equiv` the closed-form rounds its requests would have paid
    /// individually.
    pub(crate) fn on_batch(
        &self,
        mode: BatchMode,
        k: usize,
        coalesced_m: usize,
        rounds: u32,
        solo_equiv: u64,
    ) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        match mode {
            BatchMode::Solo => &self.solo_batches,
            BatchMode::Concat => &self.concat_batches,
            BatchMode::Segmented => &self.segmented_batches,
        }
        .fetch_add(1, Ordering::Relaxed);
        self.completed.fetch_add(k as u64, Ordering::Relaxed);
        self.coalesced_elems.fetch_add(coalesced_m as u64, Ordering::Relaxed);
        self.rounds_paid.fetch_add(rounds as u64, Ordering::Relaxed);
        self.rounds_solo_equiv.fetch_add(solo_equiv, Ordering::Relaxed);
        self.batch_hist[bucket(k)].fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let completed = self.completed.load(Ordering::Relaxed);
        let rounds_paid = self.rounds_paid.load(Ordering::Relaxed);
        let rounds_solo = self.rounds_solo_equiv.load(Ordering::Relaxed);
        MetricsSnapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            completed,
            failed: self.failed.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            solo_batches: self.solo_batches.load(Ordering::Relaxed),
            concat_batches: self.concat_batches.load(Ordering::Relaxed),
            segmented_batches: self.segmented_batches.load(Ordering::Relaxed),
            coalesced_elems: self.coalesced_elems.load(Ordering::Relaxed),
            rounds_paid,
            rounds_solo_equiv: rounds_solo,
            worlds_rebuilt: self.worlds_rebuilt.load(Ordering::Relaxed),
            batch_hist: std::array::from_fn(|i| self.batch_hist[i].load(Ordering::Relaxed)),
            amortized_rounds_per_request: if completed == 0 {
                0.0
            } else {
                rounds_paid as f64 / completed as f64
            },
            round_amortization: if rounds_paid == 0 {
                1.0
            } else {
                rounds_solo as f64 / rounds_paid as f64
            },
        }
    }
}

/// Point-in-time view of [`ServiceMetrics`].
#[derive(Debug, Clone, PartialEq)]
pub struct MetricsSnapshot {
    pub submitted: u64,
    pub completed: u64,
    pub failed: u64,
    pub batches: u64,
    pub solo_batches: u64,
    pub concat_batches: u64,
    pub segmented_batches: u64,
    pub coalesced_elems: u64,
    pub rounds_paid: u64,
    pub rounds_solo_equiv: u64,
    pub worlds_rebuilt: u64,
    pub batch_hist: [u64; BATCH_HIST_BUCKETS],
    /// `rounds_paid / completed` — the number batching shrinks.
    pub amortized_rounds_per_request: f64,
    /// `rounds_solo_equiv / rounds_paid` — ≥ 1 when coalescing wins.
    pub round_amortization: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_accounting_amortizes() {
        let m = ServiceMetrics::default();
        for _ in 0..8 {
            m.on_submit();
        }
        // One coalesced batch of 8 requests paying 4 rounds, where solo
        // execution would have paid 8 × 4.
        m.on_batch(BatchMode::Concat, 8, 64, 4, 32);
        let s = m.snapshot();
        assert_eq!(s.submitted, 8);
        assert_eq!(s.completed, 8);
        assert_eq!(s.batches, 1);
        assert_eq!(s.concat_batches, 1);
        assert_eq!(s.rounds_paid, 4);
        assert!((s.amortized_rounds_per_request - 0.5).abs() < 1e-12);
        assert!((s.round_amortization - 8.0).abs() < 1e-12);
        assert_eq!(s.batch_hist[3], 1, "8 lands in the 5–8 bucket");
    }

    #[test]
    fn histogram_buckets() {
        assert_eq!(bucket(1), 0);
        assert_eq!(bucket(2), 1);
        assert_eq!(bucket(4), 2);
        assert_eq!(bucket(8), 3);
        assert_eq!(bucket(16), 4);
        assert_eq!(bucket(33), 6);
        assert_eq!(bucket(1000), 7);
    }

    #[test]
    fn empty_snapshot_is_neutral() {
        let s = ServiceMetrics::default().snapshot();
        assert_eq!(s.amortized_rounds_per_request, 0.0);
        assert_eq!(s.round_amortization, 1.0);
    }
}
