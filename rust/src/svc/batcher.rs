//! Batch planning: turning a queue of independent small-m requests into
//! the fewest collectives.
//!
//! Coalescing exploits that `⊕` is element-wise, so one wide vector scan
//! *is* many independent scans:
//!
//! * **Lane concatenation** — full-world requests sharing an operator
//!   concatenate their vectors per rank and run one collective of width
//!   `Σ mₖ`. Works for any associative `⊕`; K requests pay one
//!   collective's rounds.
//! * **Segmented lanes** — sub-range requests (contiguous rank ranges)
//!   whose operator is *liftable* pack into shared lanes of one
//!   world-wide scan under the lifted `(flag, value)` operator
//!   (Blelloch's construction, [`crate::coll::segmented`]): requests with
//!   disjoint ranges share a lane; segment-start flags at each request's
//!   first rank stop any value from crossing request boundaries. Lanes
//!   are filled greedily in arrival order (interval partitioning).
//! * **Solo** — anything that cannot coalesce (a sub-range request with
//!   an opaque operator, a singleton group, or a segmented candidate
//!   whose world-wide cost `rounds(p)` would not strictly beat the
//!   members' summed solo cost) runs as its own collective on a
//!   communicator over exactly its ranks, paying only `rounds(span)`.
//!
//! Planning is pure (no I/O, no clocks): the engine feeds it whatever the
//! batching window collected.

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::mpi::Elem;

use super::metrics::ServiceMetrics;
use super::request::{HandleState, ScanRequest, SvcError};

/// Coalescing policy knobs.
#[derive(Debug, Clone)]
pub struct BatchPolicy {
    /// How long the dispatcher keeps collecting after the first queued
    /// request before executing the cycle ([`ScanEngine::flush`] cuts it
    /// short).
    ///
    /// [`ScanEngine::flush`]: super::ScanEngine::flush
    pub window: Duration,
    /// Maximum requests coalesced into one collective.
    pub max_batch: usize,
    /// Cap on the per-rank element count of one coalesced collective
    /// (concatenated width, or `lanes × m` for segmented batches).
    pub max_coalesced_elems: usize,
    /// Opt-in **adaptive batching window**: `Some((lo, hi))` lets the
    /// dispatcher widen the collection window (×2, up to `hi`) when a
    /// cycle fills `max_batch` — trading p50 latency for amortization
    /// under load — and narrow it (÷2, down to `lo`) when a cycle
    /// collects ≤ `max_batch / 4`, so an idle service converges back to
    /// low latency. When the admission gauge reports `Overloaded`
    /// pressure the window only narrows (see
    /// [`next_window`](Self::next_window)). `None` (the default) keeps
    /// the fixed `window`, which also keeps the deterministic
    /// manual-flush tests byte-stable.
    pub window_range: Option<(Duration, Duration)>,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            window: Duration::from_micros(200),
            max_batch: 64,
            max_coalesced_elems: 1 << 20,
            window_range: None,
        }
    }
}

impl BatchPolicy {
    /// Enable the adaptive window between `lo` and `hi` (see
    /// [`window_range`](Self::window_range)). The starting width is the
    /// current `window`, clamped into the range.
    pub fn with_adaptive_window(mut self, lo: Duration, hi: Duration) -> Self {
        assert!(lo <= hi, "adaptive window range must have lo <= hi");
        self.window = self.window.clamp(lo, hi);
        self.window_range = Some((lo, hi));
        self
    }

    /// One adaptive-window step (pure — the engine calls it once per
    /// dispatch cycle): widen ×2 when the cycle filled `max_batch` (more
    /// coalescing headroom under load), narrow ÷2 when it collected
    /// ≤ `max_batch / 4` (don't tax latency when idle), hold otherwise;
    /// always clamped to the configured range.
    ///
    /// `overloaded` is the admission-gauge hint: when the service is
    /// rejecting or blocking submits at its inflight caps, the cure is
    /// draining the queue sooner, not coalescing harder — a wider window
    /// only lets the gauge press the cap for longer. Under pressure the
    /// window therefore never widens; it narrows toward `lo` regardless
    /// of how full the cycle was.
    ///
    /// Returns `win` unchanged when no `window_range` is configured.
    pub fn next_window(&self, win: Duration, collected: usize, overloaded: bool) -> Duration {
        let Some((lo, hi)) = self.window_range else { return win };
        let max_batch = self.max_batch.max(1);
        if overloaded || collected <= max_batch / 4 {
            (win / 2).clamp(lo, hi)
        } else if collected >= max_batch {
            (win * 2).clamp(lo, hi)
        } else {
            win.clamp(lo, hi)
        }
    }
}

/// A queued request plus the handle its result scatters back to (and the
/// engine's metrics, so the abandonment path below stays accountable).
pub(crate) struct PendingReq<T: Elem> {
    pub req: ScanRequest<T>,
    pub state: Arc<HandleState<T>>,
    pub metrics: Arc<ServiceMetrics>,
    /// Admission instant — the latency histogram measures submit →
    /// fulfill.
    pub submitted_at: Instant,
    /// Payload bytes charged against the engine's inflight-bytes gauge at
    /// admission; released exactly once, in `drop` below.
    pub bytes: usize,
}

impl<T: Elem> Drop for PendingReq<T> {
    /// Last-resort containment: a request dropped without being fulfilled
    /// (a dispatcher panic unwinding a cycle, queue teardown after a
    /// dispatcher death) resolves its handle to a typed
    /// [`SvcError::Shutdown`] instead of leaving `wait` blocked forever,
    /// and counts the failure so `submitted == completed + failed` holds
    /// on every path. A no-op when the scatter already fulfilled.
    ///
    /// The inflight-bytes release lives here too — every `PendingReq`
    /// drops exactly once, *after* any fulfillment, so the gauge returns
    /// to zero on every path (success, failure, shutdown, unwind) without
    /// per-path bookkeeping.
    fn drop(&mut self) {
        self.metrics.sub_inflight_bytes(self.bytes as u64);
        if self.state.fulfill_if_empty(Err(SvcError::Shutdown)) {
            self.metrics.on_failed(1);
        }
    }
}

/// One planned collective, referencing requests by index into the cycle's
/// pending list.
#[derive(Debug, Clone, PartialEq, Eq)]
pub(crate) enum Plan {
    /// Full-world lane concatenation, members in concatenation order.
    Concat { members: Vec<usize> },
    /// Segmented world-wide scan: `lanes[l]` holds members with pairwise
    /// disjoint rank ranges; all members share (op, m).
    Segmented { lanes: Vec<Vec<usize>>, m: usize },
    /// One request on a communicator over exactly its ranks.
    Solo { member: usize },
}

impl Plan {
    /// Requests this collective serves.
    pub fn batch_size(&self) -> usize {
        match self {
            Plan::Concat { members } => members.len(),
            Plan::Segmented { lanes, .. } => lanes.iter().map(|l| l.len()).sum(),
            Plan::Solo { .. } => 1,
        }
    }

    /// All member indices, in scatter order.
    pub fn members(&self) -> Vec<usize> {
        match self {
            Plan::Concat { members } => members.clone(),
            Plan::Segmented { lanes, .. } => lanes.iter().flatten().copied().collect(),
            Plan::Solo { member } => vec![*member],
        }
    }
}

/// Group the cycle's pending requests into collectives. Deterministic and
/// arrival-order preserving within each group (so results are reproducible
/// given the same queue contents).
///
/// `rounds_for(n, m)` is the configured algorithm's closed-form round
/// count on an n-rank communicator at vector length m (m-aware so the
/// chunked/pipelined schedules are costed by what their traces will
/// actually measure): a segmented batch runs world-wide at width
/// `lanes·m` and is only kept when that is strictly cheaper than the
/// members' summed solo cost `Σ rounds_for(spanₖ, m)` — short-span pairs
/// on a large world fall back to solo sub-communicator execution instead
/// of a losing coalesce.
pub(crate) fn plan_batches<T: Elem>(
    pending: &[PendingReq<T>],
    p: usize,
    policy: &BatchPolicy,
    rounds_for: impl Fn(usize, usize) -> u32,
) -> Vec<Plan> {
    let mut plans = Vec::new();
    let mut consumed = vec![false; pending.len()];

    // ── Full-world requests: concat per operator name. ──
    // Group indices by op name, preserving arrival order.
    let mut concat_groups: Vec<(String, Vec<usize>)> = Vec::new();
    for (i, pr) in pending.iter().enumerate() {
        if pr.req.ranks != (0..p) {
            continue;
        }
        consumed[i] = true;
        let name = pr.req.op.name();
        match concat_groups.iter_mut().find(|(n, _)| n == name) {
            Some((_, g)) => g.push(i),
            None => concat_groups.push((name.to_string(), vec![i])),
        }
    }
    for (_, group) in concat_groups {
        let mut members: Vec<usize> = Vec::new();
        let mut width = 0usize;
        for i in group {
            let m = pending[i].req.m();
            if !members.is_empty()
                && (members.len() >= policy.max_batch
                    || width + m > policy.max_coalesced_elems)
            {
                plans.push(Plan::Concat { members: std::mem::take(&mut members) });
                width = 0;
            }
            members.push(i);
            width += m;
        }
        if !members.is_empty() {
            plans.push(Plan::Concat { members });
        }
    }

    // ── Sub-range liftable requests: segmented lanes per (op name, m). ──
    let mut seg_groups: Vec<((String, usize), Vec<usize>)> = Vec::new();
    for (i, pr) in pending.iter().enumerate() {
        if consumed[i] || !pr.req.op.is_liftable() {
            continue;
        }
        consumed[i] = true;
        let key = (pr.req.op.name().to_string(), pr.req.m());
        match seg_groups.iter_mut().find(|(k, _)| *k == key) {
            Some((_, g)) => g.push(i),
            None => seg_groups.push((key, vec![i])),
        }
    }
    for ((_, m), group) in seg_groups {
        if group.len() == 1 {
            plans.push(Plan::Solo { member: group[0] });
            continue;
        }
        let max_lanes = if m == 0 {
            policy.max_batch // zero-width lanes cost nothing
        } else {
            (policy.max_coalesced_elems / m).max(1)
        };
        // Greedy interval partitioning into open batches of lanes.
        let mut lanes: Vec<Vec<usize>> = Vec::new();
        let mut batch_count = 0usize;
        let rounds_ref = &rounds_for;
        let mut flush =
            |lanes: &mut Vec<Vec<usize>>, batch_count: &mut usize, plans: &mut Vec<Plan>| {
                if lanes.is_empty() {
                    return;
                }
                // Benefit gate: the world-wide lifted scan pays the
                // rounds of a p-rank collective at width lanes·m; keep
                // the batch only when that strictly beats the members'
                // summed solo cost (a lone leftover always fails this
                // and runs solo).
                let world_rounds = rounds_ref(p, lanes.len() * m);
                let solo_sum: u32 = lanes
                    .iter()
                    .flatten()
                    .map(|&j| rounds_ref(pending[j].req.span(), m))
                    .sum();
                if world_rounds >= solo_sum {
                    for &j in lanes.iter().flatten() {
                        plans.push(Plan::Solo { member: j });
                    }
                } else {
                    plans.push(Plan::Segmented { lanes: std::mem::take(lanes), m });
                }
                lanes.clear();
                *batch_count = 0;
            };
        for i in group {
            let range = pending[i].req.ranks.clone();
            if batch_count >= policy.max_batch {
                flush(&mut lanes, &mut batch_count, &mut plans);
            }
            let lane_idx = lanes.iter().position(|lane| {
                lane.iter().all(|&j| {
                    let r = &pending[j].req.ranks;
                    r.end <= range.start || range.end <= r.start
                })
            });
            match lane_idx {
                Some(li) => lanes[li].push(i),
                None if lanes.len() < max_lanes => lanes.push(vec![i]),
                None => {
                    flush(&mut lanes, &mut batch_count, &mut plans);
                    lanes.push(vec![i]);
                }
            }
            batch_count += 1;
        }
        flush(&mut lanes, &mut batch_count, &mut plans);
    }

    // ── Everything else runs solo on its own sub-communicator. ──
    for (i, done) in consumed.iter().enumerate() {
        if !done {
            plans.push(Plan::Solo { member: i });
        }
    }
    plans
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpi::ops;
    use crate::svc::request::ReqOp;
    use crate::util::bits::rounds_123;

    /// All planning tests use the 123-doubling closed form (m-independent),
    /// matching the engine's default algorithm.
    fn plan(pending: &[PendingReq<i64>], p: usize, policy: &BatchPolicy) -> Vec<Plan> {
        plan_batches(pending, p, policy, |n, _m| rounds_123(n))
    }

    fn pend(req: ScanRequest<i64>) -> PendingReq<i64> {
        PendingReq {
            req,
            state: HandleState::new(),
            metrics: Arc::new(ServiceMetrics::default()),
            submitted_at: Instant::now(),
            bytes: 0,
        }
    }

    fn full(op: ReqOp<i64>, p: usize, m: usize) -> PendingReq<i64> {
        pend(ScanRequest::full(op, vec![vec![1i64; m]; p]))
    }

    fn sub(op: ReqOp<i64>, start: usize, span: usize, m: usize) -> PendingReq<i64> {
        pend(ScanRequest::over(op, start, vec![vec![1i64; m]; span]))
    }

    #[test]
    fn same_op_full_world_requests_concat() {
        let p = 8;
        let pending = vec![
            full(ReqOp::sum_i64(), p, 4),
            full(ReqOp::sum_i64(), p, 2),
            full(ReqOp::sum_i64(), p, 8),
        ];
        let plans = plan(&pending, p, &BatchPolicy::default());
        assert_eq!(plans, vec![Plan::Concat { members: vec![0, 1, 2] }]);
        assert_eq!(plans[0].batch_size(), 3);
    }

    #[test]
    fn different_ops_do_not_mix() {
        let p = 4;
        let pending = vec![
            full(ReqOp::sum_i64(), p, 1),
            full(ReqOp::bxor_i64(), p, 1),
            full(ReqOp::sum_i64(), p, 1),
        ];
        let plans = plan(&pending, p, &BatchPolicy::default());
        assert_eq!(
            plans,
            vec![
                Plan::Concat { members: vec![0, 2] },
                Plan::Concat { members: vec![1] },
            ]
        );
    }

    #[test]
    fn max_batch_splits_concat_groups() {
        let p = 2;
        let pending: Vec<_> = (0..5).map(|_| full(ReqOp::sum_i64(), p, 1)).collect();
        let policy = BatchPolicy { max_batch: 2, ..Default::default() };
        let plans = plan(&pending, p, &policy);
        assert_eq!(
            plans,
            vec![
                Plan::Concat { members: vec![0, 1] },
                Plan::Concat { members: vec![2, 3] },
                Plan::Concat { members: vec![4] },
            ]
        );
    }

    #[test]
    fn elems_cap_splits_but_never_starves() {
        let p = 2;
        let pending = vec![
            full(ReqOp::sum_i64(), p, 600),
            full(ReqOp::sum_i64(), p, 600),
            full(ReqOp::sum_i64(), p, 2000), // alone over the cap: still admitted
        ];
        let policy = BatchPolicy { max_coalesced_elems: 1000, ..Default::default() };
        let plans = plan(&pending, p, &policy);
        assert_eq!(
            plans,
            vec![
                Plan::Concat { members: vec![0] },
                Plan::Concat { members: vec![1] },
                Plan::Concat { members: vec![2] },
            ]
        );
    }

    #[test]
    fn disjoint_subranges_share_a_lane() {
        let p = 8;
        let pending = vec![
            sub(ReqOp::sum_i64(), 0, 3, 2), // ranks 0..3
            sub(ReqOp::sum_i64(), 5, 3, 2), // ranks 5..8 — disjoint
            sub(ReqOp::sum_i64(), 1, 4, 2), // ranks 1..5 — overlaps both? (overlaps #0 only)
        ];
        let plans = plan(&pending, p, &BatchPolicy::default());
        assert_eq!(
            plans,
            vec![Plan::Segmented { lanes: vec![vec![0, 1], vec![2]], m: 2 }]
        );
        assert_eq!(plans[0].batch_size(), 3);
        assert_eq!(plans[0].members(), vec![0, 1, 2]);
    }

    #[test]
    fn segmented_groups_key_on_op_and_m() {
        // p = 6 so the benefit gate passes: rounds(6) = 3 < 2 + 2, the
        // solo cost of the two span-3 members.
        let p = 6;
        let pending = vec![
            sub(ReqOp::sum_i64(), 0, 3, 3),
            sub(ReqOp::sum_i64(), 4, 2, 5), // different m → different group (singleton → solo)
            sub(ReqOp::bxor_i64(), 2, 2, 3), // different op → different group (singleton → solo)
            sub(ReqOp::sum_i64(), 3, 3, 3), // coalesces with #0
        ];
        let plans = plan(&pending, p, &BatchPolicy::default());
        assert_eq!(
            plans,
            vec![
                Plan::Segmented { lanes: vec![vec![0, 3]], m: 3 },
                Plan::Solo { member: 1 },
                Plan::Solo { member: 2 },
            ]
        );
    }

    #[test]
    fn losing_coalesce_falls_back_to_solo() {
        // Two span-2 requests on a big world: the world-wide lifted scan
        // would pay rounds(64) = 7 for work that costs 1 + 1 solo — the
        // benefit gate must refuse the batch.
        let p = 64;
        let pending = vec![
            sub(ReqOp::sum_i64(), 0, 2, 4),
            sub(ReqOp::sum_i64(), 10, 2, 4),
        ];
        let plans = plan(&pending, p, &BatchPolicy::default());
        assert_eq!(
            plans,
            vec![Plan::Solo { member: 0 }, Plan::Solo { member: 1 }]
        );
        // Enough members flip the economics: four span-2 requests at
        // p = 5 cost 4 × rounds(2) = 4 solo vs rounds(5) = 3 batched —
        // the gate keeps the segmented batch (two lanes of two).
        let p = 5;
        let pending: Vec<_> = [0usize, 2, 0, 2]
            .iter()
            .map(|&s| sub(ReqOp::sum_i64(), s, 2, 4))
            .collect();
        let plans = plan(&pending, p, &BatchPolicy::default());
        assert_eq!(
            plans,
            vec![Plan::Segmented { lanes: vec![vec![0, 1], vec![2, 3]], m: 4 }]
        );
    }

    #[test]
    fn opaque_subrange_runs_solo() {
        let p = 6;
        let pending = vec![
            sub(ReqOp::from_op(&ops::bxor()), 1, 3, 4),
            full(ReqOp::from_op(&ops::bxor()), p, 4),
        ];
        let plans = plan(&pending, p, &BatchPolicy::default());
        // The full-world opaque request still concats (with itself); the
        // sub-range one cannot lift and runs solo.
        assert_eq!(
            plans,
            vec![Plan::Concat { members: vec![1] }, Plan::Solo { member: 0 }]
        );
    }

    #[test]
    fn zero_m_requests_plan_cleanly() {
        // p = 6 keeps the benefit gate open for the two span-3 members
        // (rounds(6) = 3 < 2 + 2) even at zero width.
        let p = 6;
        let pending = vec![
            full(ReqOp::sum_i64(), p, 0),
            full(ReqOp::sum_i64(), p, 0),
            sub(ReqOp::sum_i64(), 0, 3, 0),
            sub(ReqOp::sum_i64(), 3, 3, 0),
        ];
        let plans = plan(&pending, p, &BatchPolicy::default());
        assert_eq!(
            plans,
            vec![
                Plan::Concat { members: vec![0, 1] },
                Plan::Segmented { lanes: vec![vec![2, 3]], m: 0 },
            ]
        );
    }
}
