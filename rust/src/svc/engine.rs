//! The scan engine: a multi-tenant front-end over one persistent
//! [`World`].
//!
//! Clients [`submit`](ScanEngine::submit) independent exclusive-scan
//! requests and get nonblocking [`ScanHandle`]s back. A dispatcher thread
//! collects requests for a short window, plans them into as few
//! collectives as possible ([`super::batcher`]), and executes each cycle's
//! plans **concurrently in flight** on one world: every plan runs on its
//! own communicator (a recycled ring of dup'd contexts), and within one
//! executor job each rank works through the plans it is a member of in
//! plan order — so rank A can already be deep in plan 3 while rank B still
//! finishes plan 1, with the packed [`TagKey`](crate::mpi::TagKey)
//! guaranteeing no cross-matching. Per-edge blocking receives bound the
//! skew; the global plan order rules out cyclic waits.
//!
//! Context-ring discipline: context ids are 16-bit and never reallocated,
//! so a long-lived service must recycle them. The ring holds [`CTX_RING`]
//! dup'd communicator contexts; a context is reused only in a later wave,
//! after the executor's completion latch has proven every message of its
//! previous collective consumed. If a wave *fails* (e.g. a receive
//! deadline under fault injection), stale tagged messages may linger —
//! the engine then fails the wave's handles with a typed
//! [`SvcError::Collective`] carrying the `{:#}` error chain, tears the
//! tainted worlds down and rebuilds them (counted in
//! [`MetricsSnapshot::worlds_rebuilt`]).
//!
//! Segmented plans run over `Seg<T>` elements, which is a different
//! transport element type — they execute on a lazily created companion
//! `World<Seg<T>>` with the same topology/chaos configuration (built only
//! if a segmented batch ever forms).
//!
//! # Admission control and backpressure
//!
//! The submit queue is bounded. [`ScanEngine::submit`] admits a request
//! only while both limits hold: open requests (submitted but not yet
//! completed or failed) below [`EngineConfig::max_inflight`], and the
//! in-flight payload gauge plus the new request's payload within
//! [`EngineConfig::max_inflight_bytes`]. Over either limit the engine
//! either fast-fails with typed [`SvcError::Overloaded`] (the
//! [`AdmissionMode::FailFast`] default) or polls for capacity until a
//! deadline ([`AdmissionMode::Block`]), then rejects. Rejected requests
//! are **not** counted as submitted — `submitted == completed + failed`
//! stays an exact invariant and `rejected` is its own counter. A single
//! request larger than the whole byte budget is still admitted when the
//! gauge is at zero, so no request can starve forever.
//!
//! # Rank death and live rebuild
//!
//! Under chaos rank-death injection ([`ChaosConfig::with_rank_death`]) a
//! rank deterministically dies mid-collective; survivors' receives are
//! poisoned and fail fast, attributed via the world's dead-rank registry.
//! The dispatcher classifies such wave failures as
//! [`SvcError::RankFailed`] (structural — no error-string parsing),
//! fails every handle of the wave typed, strips the consumed death
//! entries from its chaos config so the rebuilt world does not re-die at
//! the same tick, and rebuilds the worlds — the engine keeps serving and
//! no request is ever lost.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::coll::segmented::Seg;
use crate::coll::{exscan_by_name, ScanAlgorithm};
use crate::mpi::{
    ChaosConfig, Comm, Elem, OpRef, Topology, TransportBackend, TransportStats, WireFaultConfig,
    World, WorldConfig, DEFAULT_WRITE_TIMEOUT,
};
use crate::trace::{RankTrace, TraceReport};
use crate::util::{Channel, PushError};

use super::batcher::{plan_batches, BatchPolicy, PendingReq, Plan};
use super::metrics::{MetricsSnapshot, ServiceMetrics};
use super::request::{
    BatchMode, HandleState, RequestStats, ScanHandle, ScanOutput, ScanRequest, SvcError,
};

/// Recycled communicator contexts (one per in-flight plan of a cycle
/// wave). Plans beyond the ring run in a follow-up wave of the same cycle.
pub const CTX_RING: usize = 32;

/// Hard cap on requests collected into one cycle (backpressure bound).
const COLLECT_CAP: usize = 4096;

/// Default per-receive deadline for the engine's worlds. Finite by
/// design: an engine world that waits forever on a dead peer turns a
/// rank failure into a service hang. 5 s is four orders of magnitude
/// above the chaos embargo-release cap (delayed deliveries are bounded
/// by `ChaosConfig::max_delay`, default 200 µs), so fault-injected
/// slowness can never masquerade as rank death.
pub const DEFAULT_RECV_TIMEOUT: Duration = Duration::from_secs(5);

/// Default cap on open requests (submitted, not yet completed/failed).
pub const DEFAULT_MAX_INFLIGHT: usize = 4096;

/// Default cap on the summed payload bytes of open requests (64 MiB).
pub const DEFAULT_MAX_INFLIGHT_BYTES: usize = 64 << 20;

/// What [`ScanEngine::submit`] does when admission limits are hit.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdmissionMode {
    /// Reject immediately with [`SvcError::Overloaded`] (the default —
    /// latency-predictable; callers own their retry policy).
    FailFast,
    /// Poll for capacity up to this long, then reject with
    /// [`SvcError::Overloaded`].
    Block(Duration),
}

/// Engine construction parameters.
#[derive(Clone)]
pub struct EngineConfig {
    pub topology: Topology,
    /// Registered exscan algorithm the collectives run
    /// (default `"123-doubling"` — the paper's round-optimal choice for
    /// the small-m regime the service amortizes).
    pub algo: String,
    pub policy: BatchPolicy,
    /// Seeded fault injection for the engine's worlds (differential
    /// verification; `None` in production).
    pub chaos: Option<ChaosConfig>,
    /// Per-receive deadline for the engine's worlds
    /// ([`DEFAULT_RECV_TIMEOUT`] unless overridden).
    pub recv_timeout: Duration,
    /// Admission cap on open requests; see the module docs.
    pub max_inflight: usize,
    /// Admission cap on summed open-request payload bytes.
    pub max_inflight_bytes: usize,
    /// Behaviour at the admission limits.
    pub admission: AdmissionMode,
    /// Transport backend the engine's worlds run on (default
    /// [`TransportBackend::Thread`]). The service layer is
    /// backend-agnostic: waves, rebuilds and chaos injection behave
    /// identically on any backend.
    pub transport: TransportBackend,
    /// Per-write deadline for the socket backends' send threads
    /// ([`DEFAULT_WRITE_TIMEOUT`] unless overridden); a blocked write
    /// past it raises a typed `WriteTimeout` transport fault instead of
    /// hanging the mesh.
    pub write_timeout: Duration,
    /// Seeded wire-level fault injection for the engine's worlds
    /// (below the chaos boundary; `None` in production). Ignored by the
    /// thread backend, which has no wire layer.
    pub wirefault: Option<WireFaultConfig>,
}

impl EngineConfig {
    pub fn new(p: usize) -> Self {
        EngineConfig {
            topology: Topology::flat(p),
            algo: "123-doubling".to_string(),
            policy: BatchPolicy::default(),
            chaos: None,
            recv_timeout: DEFAULT_RECV_TIMEOUT,
            max_inflight: DEFAULT_MAX_INFLIGHT,
            max_inflight_bytes: DEFAULT_MAX_INFLIGHT_BYTES,
            admission: AdmissionMode::FailFast,
            transport: TransportBackend::Thread,
            write_timeout: DEFAULT_WRITE_TIMEOUT,
            wirefault: None,
        }
    }

    pub fn with_algo(mut self, name: &str) -> Self {
        self.algo = name.to_string();
        self
    }

    pub fn with_policy(mut self, policy: BatchPolicy) -> Self {
        self.policy = policy;
        self
    }

    pub fn with_chaos(mut self, chaos: ChaosConfig) -> Self {
        self.chaos = Some(chaos);
        self
    }

    pub fn with_recv_timeout(mut self, t: Duration) -> Self {
        self.recv_timeout = t;
        self
    }

    /// Cap open requests / open payload bytes at admission.
    pub fn with_admission_limits(mut self, max_inflight: usize, max_bytes: usize) -> Self {
        assert!(max_inflight >= 1, "max_inflight must be at least 1");
        self.max_inflight = max_inflight;
        self.max_inflight_bytes = max_bytes;
        self
    }

    pub fn with_admission_mode(mut self, mode: AdmissionMode) -> Self {
        self.admission = mode;
        self
    }

    /// Run the engine's worlds on a specific transport backend.
    pub fn with_transport(mut self, backend: TransportBackend) -> Self {
        self.transport = backend;
        self
    }

    /// Per-write deadline for the socket backends' send threads.
    pub fn with_write_timeout(mut self, t: Duration) -> Self {
        self.write_timeout = t;
        self
    }

    /// Arm seeded wire-level fault injection on the engine's worlds.
    pub fn with_wire_faults(mut self, cfg: WireFaultConfig) -> Self {
        self.wirefault = Some(cfg);
        self
    }

    fn world_config(&self) -> WorldConfig {
        let mut wc = WorldConfig::new(self.topology)
            .with_trace(true)
            .with_recv_timeout(self.recv_timeout)
            .with_transport(self.transport)
            .with_write_timeout(self.write_timeout);
        if let Some(ch) = &self.chaos {
            wc = wc.with_chaos(ch.clone());
        }
        if let Some(wf) = &self.wirefault {
            wc = wc.with_wire_faults(wf.clone());
        }
        wc
    }
}

struct Shared<T: Elem> {
    p: usize,
    queue: Channel<PendingReq<T>>,
    /// Bumped by [`ScanEngine::flush`]; the dispatcher cuts its batching
    /// window short when it changes.
    flush_gen: AtomicU64,
    /// Shared with every [`PendingReq`] so the abandonment path
    /// (`PendingReq::drop`) can account its failure.
    metrics: Arc<ServiceMetrics>,
    /// Admission caps and mode (copied out of [`EngineConfig`] so
    /// `submit` needs no lock).
    max_inflight: usize,
    max_inflight_bytes: usize,
    admission: AdmissionMode,
}

/// The multi-tenant scan service (see the module docs).
pub struct ScanEngine<T: Elem> {
    shared: Arc<Shared<T>>,
    dispatcher: Option<std::thread::JoinHandle<()>>,
}

impl<T: Elem> ScanEngine<T> {
    /// Build the engine and spawn its dispatcher (which owns the
    /// persistent worlds). Fails on an unknown algorithm name.
    pub fn new(cfg: EngineConfig) -> Result<Self, SvcError> {
        let p = cfg.topology.size();
        if p < 1 {
            return Err(SvcError::Shape("world must have at least one rank".into()));
        }
        if exscan_by_name::<T>(&cfg.algo).is_none() {
            return Err(SvcError::Shape(format!("unknown scan algorithm {:?}", cfg.algo)));
        }
        let shared = Arc::new(Shared {
            p,
            // The queue cap mirrors the open-request cap: admission is
            // the real limit, the bounded queue a structural backstop
            // (queued ⊆ open, so it can only fill under a submit race).
            queue: Channel::bounded(cfg.max_inflight),
            flush_gen: AtomicU64::new(0),
            metrics: Arc::new(ServiceMetrics::default()),
            max_inflight: cfg.max_inflight,
            max_inflight_bytes: cfg.max_inflight_bytes,
            admission: cfg.admission,
        });
        let dispatcher = {
            let shared = Arc::clone(&shared);
            std::thread::Builder::new()
                .name("scan-svc".into())
                .spawn(move || dispatch_loop(cfg, shared))
                .expect("failed to spawn scan-service dispatcher")
        };
        Ok(ScanEngine { shared, dispatcher: Some(dispatcher) })
    }

    /// World size the engine serves.
    pub fn world_size(&self) -> usize {
        self.shared.p
    }

    /// Submit one exclusive-scan request; returns immediately with a
    /// nonblocking handle. Shape errors are reported synchronously;
    /// admission-limit rejections return [`SvcError::Overloaded`]
    /// (immediately under [`AdmissionMode::FailFast`], after the poll
    /// deadline under [`AdmissionMode::Block`]).
    pub fn submit(&self, req: ScanRequest<T>) -> Result<ScanHandle<T>, SvcError> {
        req.validate(self.shared.p)?;
        let bytes = req.payload_bytes();
        self.admit(bytes)?;
        let state = HandleState::new();
        // Gauge and counter move together, before the push: a push that
        // fails drops `pending`, whose `Drop` releases the gauge and
        // accounts the failure — keeping `submitted == completed +
        // failed` and a zero-returning gauge on every path.
        self.shared.metrics.on_submit();
        self.shared.metrics.add_inflight_bytes(bytes as u64);
        let pending = PendingReq {
            req,
            state: Arc::clone(&state),
            metrics: Arc::clone(&self.shared.metrics),
            submitted_at: Instant::now(),
            bytes,
        };
        match self.shared.queue.try_push(pending) {
            Ok(()) => Ok(ScanHandle { state }),
            Err(PushError::Closed(pr)) => {
                drop(pr);
                Err(SvcError::Shutdown)
            }
            Err(PushError::Full(pr)) => {
                // Backstop only: admission bounds open requests at the
                // queue cap, so Full needs a submit race. The dropped
                // request is accounted failed (it *was* submitted).
                drop(pr);
                Err(SvcError::Overloaded)
            }
        }
    }

    /// Block or fail until the request fits under both admission caps.
    /// A request larger than the whole byte budget is admitted once the
    /// gauge reaches zero, so nothing starves forever.
    fn admit(&self, bytes: usize) -> Result<(), SvcError> {
        let deadline = match self.shared.admission {
            AdmissionMode::FailFast => None,
            AdmissionMode::Block(t) => Some(Instant::now() + t),
        };
        loop {
            let open = self.shared.metrics.open_requests();
            let gauge = self.shared.metrics.inflight_bytes() as usize;
            let fits = (open as usize) < self.shared.max_inflight
                && (gauge == 0 || gauge + bytes <= self.shared.max_inflight_bytes);
            if fits {
                return Ok(());
            }
            match deadline {
                Some(d) if Instant::now() < d && !self.shared.queue.is_closed() => {
                    std::thread::sleep(Duration::from_micros(50));
                }
                _ => {
                    self.shared.metrics.on_rejected();
                    return Err(SvcError::Overloaded);
                }
            }
        }
    }

    /// Convenience: submit a full-world exscan (`inputs[r]` is rank r's
    /// vector).
    pub fn submit_exscan(
        &self,
        op: super::request::ReqOp<T>,
        inputs: Vec<Vec<T>>,
    ) -> Result<ScanHandle<T>, SvcError> {
        self.submit(ScanRequest::full(op, inputs))
    }

    /// Cut the current batching window short: everything queued so far is
    /// planned and executed now. (Tests and benchmarks use this to make
    /// batch composition deterministic.)
    pub fn flush(&self) {
        self.shared.flush_gen.fetch_add(1, Ordering::SeqCst);
    }

    /// Current service counters.
    pub fn metrics(&self) -> MetricsSnapshot {
        self.shared.metrics.snapshot()
    }

    /// Shared handle to the live counters. Outlives the engine, so a
    /// monitoring pipeline (or a shutdown test) can snapshot after drop
    /// — e.g. to check `submitted == completed + failed` and a drained
    /// `inflight_bytes` gauge once the dispatcher has quiesced.
    pub fn metrics_shared(&self) -> Arc<ServiceMetrics> {
        Arc::clone(&self.shared.metrics)
    }
}

impl<T: Elem> Drop for ScanEngine<T> {
    /// Graceful shutdown: stop accepting, drain and execute everything
    /// already queued, then join the dispatcher.
    fn drop(&mut self) {
        self.shared.queue.close();
        if let Some(h) = self.dispatcher.take() {
            let _ = h.join();
        }
    }
}

// ───────────────────────── dispatcher internals ─────────────────────────

/// One plan readied for execution: its communicator, operator and
/// per-communicator-rank prepared inputs.
struct ExecPlan<E: Elem> {
    plan: Plan,
    comm: Comm,
    op: OpRef<E>,
    inputs: Vec<Vec<E>>,
}

/// Dispatcher entry point: contains panics. The cycle loop itself never
/// intentionally panics, but an internal invariant slip must not leave
/// clients hanging — on unwind, the queue is closed (so `submit` fails
/// fast with [`SvcError::Shutdown`]) and every still-queued request is
/// dropped, which resolves and accounts it typed via `PendingReq::drop`;
/// requests captured inside the panicked cycle were already resolved the
/// same way during unwinding.
fn dispatch_loop<T: Elem>(cfg: EngineConfig, shared: Arc<Shared<T>>) {
    let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        dispatch_cycles(cfg, &shared);
    }));
    if outcome.is_err() {
        shared.queue.close();
        while let Some(pr) = shared.queue.try_pop() {
            drop(pr); // Drop fulfills the handle and counts the failure
        }
    }
}

/// Admission-gauge pressure test for the adaptive window
/// ([`BatchPolicy::next_window`]'s `overloaded` hint): true when a
/// fresh submit would find no headroom under either inflight cap — the
/// same predicate `admit` blocks or rejects on. The dispatcher reads it
/// once per cycle; a racy read is fine (the hint only biases the next
/// window's width).
fn admission_overloaded<T: Elem>(shared: &Shared<T>) -> bool {
    let open = shared.metrics.open_requests() as usize;
    let gauge = shared.metrics.inflight_bytes() as usize;
    open >= shared.max_inflight || (gauge > 0 && gauge >= shared.max_inflight_bytes)
}

fn dispatch_cycles<T: Elem>(cfg: EngineConfig, shared: &Arc<Shared<T>>) {
    let p = shared.p;
    // The running config is mutable: after a rank-death rebuild the
    // consumed death entries are stripped so the fresh world does not
    // re-die at the same tick (remaining entries keep firing — that is
    // what the soak bench's periodic-death schedule is made of).
    let mut run_cfg = cfg;
    let cfg = run_cfg.clone();
    let mut world_cfg = run_cfg.world_config();
    let mut world: World<T> = World::new(world_cfg.clone());
    let mut seg_world: Option<World<Seg<T>>> = None;
    let ring: Vec<u16> = {
        let wc = world.comm_world();
        (0..CTX_RING).map(|_| world.dup_comm(&wc).ctx()).collect()
    };
    let algo_t: Box<dyn ScanAlgorithm<T>> =
        exscan_by_name(&cfg.algo).expect("validated in ScanEngine::new");
    let algo_seg: Box<dyn ScanAlgorithm<Seg<T>>> =
        exscan_by_name(&cfg.algo).expect("validated in ScanEngine::new");

    // Wire-recovery counters already paid by torn-down (rebuilt) worlds:
    // the metrics gauges stay monotonic across rebuilds by adding the
    // live worlds' counters onto this base.
    let mut wire_base = TransportStats::default();

    // Flush tracking is level-based against the generation at engine
    // construction (0): any flush not yet consumed by a cycle cuts the
    // next window short, no matter when it lands relative to the
    // dispatcher's own progress — a client that submits K requests and
    // flushes gets them executed now even if the flush raced ahead of
    // this thread's startup or a previous cycle's teardown.
    let mut seen_gen: u64 = 0;
    // Adaptive batching window (fixed at `policy.window` unless a
    // `window_range` is configured; see `BatchPolicy::next_window`).
    let mut window = cfg.policy.window;
    loop {
        let Some(first) = shared.queue.pop_wait() else { break };
        // ── Collect the cycle: batching window from the first arrival. ──
        let mut collected: Vec<PendingReq<T>> = vec![first];
        let deadline = Instant::now() + window;
        loop {
            while collected.len() < COLLECT_CAP {
                match shared.queue.try_pop() {
                    Some(x) => collected.push(x),
                    None => break,
                }
            }
            let gen_now = shared.flush_gen.load(Ordering::SeqCst);
            if gen_now != seen_gen || shared.queue.is_closed() {
                // Everything enqueued before the flush (or close)
                // happened-before the generation bump we just observed,
                // so one final drain collects the complete flush set.
                while collected.len() < COLLECT_CAP {
                    match shared.queue.try_pop() {
                        Some(x) => collected.push(x),
                        None => break,
                    }
                }
                // Consume the flush only if the drain actually emptied
                // the queue: when the collection cap cut it short, the
                // leftover requests still belong to this flush and the
                // next cycle must start immediately, not wait a window.
                if shared.queue.is_empty() {
                    seen_gen = gen_now;
                }
                break;
            }
            let now = Instant::now();
            if now >= deadline || collected.len() >= COLLECT_CAP {
                break;
            }
            std::thread::sleep(Duration::from_micros(50).min(deadline - now));
        }
        window = cfg.policy.next_window(window, collected.len(), admission_overloaded(shared));

        // ── Plan, then execute in waves of ≤ CTX_RING concurrent plans. ──
        let plans = plan_batches(&collected, p, &cfg.policy, |n, m| {
            algo_t.predicted_rounds_m(n, m)
        });
        let mut pending: Vec<Option<PendingReq<T>>> =
            collected.into_iter().map(Some).collect();
        for wave in plans.chunks(CTX_RING) {
            let mut t_plans: Vec<ExecPlan<T>> = Vec::new();
            let mut s_plans: Vec<ExecPlan<Seg<T>>> = Vec::new();
            for (slot, plan) in wave.iter().enumerate() {
                let ctx = ring[slot];
                match plan {
                    Plan::Concat { members } => {
                        let op = req_of(&pending, members[0]).op.fresh();
                        let comm = Comm::new(ctx, (0..p).collect());
                        let inputs: Vec<Vec<T>> = (0..p)
                            .map(|r| {
                                let mut v = Vec::new();
                                for &mi in members {
                                    v.extend_from_slice(&req_of(&pending, mi).inputs[r]);
                                }
                                v
                            })
                            .collect();
                        t_plans.push(ExecPlan { plan: plan.clone(), comm, op, inputs });
                    }
                    Plan::Solo { member } => {
                        let req = req_of(&pending, *member);
                        let op = req.op.fresh();
                        let comm = Comm::new(ctx, req.ranks.clone().collect());
                        let inputs = req.inputs.clone();
                        t_plans.push(ExecPlan { plan: plan.clone(), comm, op, inputs });
                    }
                    Plan::Segmented { lanes, m } => {
                        let op = req_of(&pending, lanes[0][0])
                            .op
                            .lifted()
                            .expect("segmented plans require a liftable op");
                        let comm = Comm::new(ctx, (0..p).collect());
                        let inputs = segmented_inputs(&pending, lanes, *m, p);
                        s_plans.push(ExecPlan { plan: plan.clone(), comm, op, inputs });
                    }
                }
            }

            // Value-typed plans first, then segmented — two jobs at most;
            // within each job every plan is simultaneously in flight.
            let mut wave_failed: Option<String> = None;
            if !t_plans.is_empty() {
                match run_wave(&world, algo_t.as_ref(), &t_plans) {
                    Ok((outs, report)) => scatter_t(
                        &t_plans,
                        &outs,
                        &report,
                        &mut pending,
                        &shared,
                        algo_t.as_ref(),
                    ),
                    Err(e) => wave_failed = Some(e),
                }
            }
            if wave_failed.is_none() && !s_plans.is_empty() {
                let seg = seg_world.get_or_insert_with(|| World::new(world_cfg.clone()));
                match run_wave(seg, algo_seg.as_ref(), &s_plans) {
                    Ok((outs, report)) => scatter_seg(
                        &s_plans,
                        &outs,
                        &report,
                        &mut pending,
                        &shared,
                        algo_t.as_ref(),
                    ),
                    Err(e) => wave_failed = Some(e),
                }
            }
            if let Some(detail) = wave_failed {
                // Tainted transport state: fail every still-unconsumed
                // handle of this wave's plans typed, then rebuild the
                // worlds. Classification is structural — the dead-rank
                // registry, not error-string parsing — so a rank death
                // surfaces as an attributed `RankFailed` and anything
                // else (deadline, chaos drop) stays `Collective`.
                let mut dead: Vec<usize> = world.dead_ranks();
                if let Some(sw) = &seg_world {
                    dead.extend(sw.dead_ranks());
                }
                dead.sort_unstable();
                dead.dedup();
                let mut failed = 0u64;
                for plan in wave {
                    for mi in plan.members() {
                        if let Some(pr) = pending[mi].take() {
                            let err = match dead.first() {
                                Some(&rank) => {
                                    SvcError::RankFailed { rank, detail: detail.clone() }
                                }
                                None => SvcError::Collective(detail.clone()),
                            };
                            if pr.state.fulfill(Err(err)) {
                                shared.metrics.on_abandoned();
                            }
                            failed += 1;
                        }
                    }
                }
                shared.metrics.on_failed(failed);
                if !dead.is_empty() {
                    shared.metrics.on_rank_failed(failed);
                    // Strip the consumed death entries before rebuilding:
                    // the fresh world's ranks restart at tick 0 and would
                    // otherwise re-die at the same trigger forever.
                    if let Some(ch) = &mut run_cfg.chaos {
                        ch.rank_death.retain(|(r, _)| !dead.contains(r));
                    }
                    world_cfg = run_cfg.world_config();
                }
                shared.metrics.on_world_rebuilt();
                wire_base.merge(&world.wire_stats());
                if let Some(sw) = &seg_world {
                    wire_base.merge(&sw.wire_stats());
                }
                world = World::new(world_cfg.clone());
                seg_world = None;
            }
        }
        debug_assert!(
            pending.iter().all(|o| o.is_none()),
            "every request of a cycle must be fulfilled"
        );
        // Mirror the worlds' pool counters into the metrics gauges once
        // per cycle (the soak bench's flat-memory evidence: a steady
        // state allocates nothing, so `pool_misses` plateaus).
        let mut ps = world.pool_stats();
        if let Some(sw) = &seg_world {
            ps.merge(&sw.pool_stats());
        }
        shared.metrics.set_pool_gauges(ps.hits, ps.misses);
        // Same treatment for the wire-recovery counters (all zero on the
        // thread backend): the soak bench's self-healing evidence. The
        // rebuild base keeps the gauges monotonic across world teardowns.
        let mut ws = wire_base;
        ws.merge(&world.wire_stats());
        if let Some(sw) = &seg_world {
            ws.merge(&sw.wire_stats());
        }
        shared
            .metrics
            .set_wire_gauges(ws.retransmits, ws.reconnects, ws.dropped_dups, ws.faults);
    }
}

fn req_of<'a, T: Elem>(
    pending: &'a [Option<PendingReq<T>>],
    i: usize,
) -> &'a ScanRequest<T> {
    &pending[i].as_ref().expect("planned request already consumed").req
}

/// Fulfill one successfully executed request: record its submit→fulfill
/// latency in the histogram (successful completions only — failures
/// would pollute the SLO tail with injected-fault timing) and account a
/// late delivery into a `wait_timeout`-abandoned handle.
fn complete<T: Elem>(pr: PendingReq<T>, out: ScanOutput<T>, shared: &Shared<T>) {
    let elapsed_ns = pr.submitted_at.elapsed().as_nanos() as u64;
    if pr.state.fulfill(Ok(out)) {
        shared.metrics.on_abandoned();
    }
    shared.metrics.record_latency_ns(elapsed_ns);
}

/// Build the per-world-rank `Seg` lanes of one segmented plan
/// (lane-major layout: element `l·m + j` is lane `l`, offset `j`).
fn segmented_inputs<T: Elem>(
    pending: &[Option<PendingReq<T>>],
    lanes: &[Vec<usize>],
    m: usize,
    p: usize,
) -> Vec<Vec<Seg<T>>> {
    (0..p)
        .map(|r| {
            let mut v = Vec::with_capacity(lanes.len() * m);
            for lane in lanes {
                // The request of this lane covering rank r, if any.
                let req = lane
                    .iter()
                    .map(|&mi| req_of(pending, mi))
                    .find(|req| req.ranks.contains(&r));
                match req {
                    Some(req) => {
                        let local = r - req.ranks.start;
                        for j in 0..m {
                            v.push(Seg::new(r == req.ranks.start, req.inputs[local][j]));
                        }
                    }
                    None => {
                        // Gap rank: a fresh one-element segment of filler,
                        // so nothing accumulates across it (the next
                        // request's start flag blocks leakage anyway; this
                        // keeps gaps inert by construction).
                        for _ in 0..m {
                            v.push(Seg::start(T::filler()));
                        }
                    }
                }
            }
            v
        })
        .collect()
}

/// Execute one wave's plans of a single element type as one executor job:
/// each rank runs, in plan order, every plan it is a member of, inside a
/// `with_comm` scope. Returns per-rank per-plan outputs plus the job's
/// merged trace.
#[allow(clippy::type_complexity)]
fn run_wave<E: Elem>(
    world: &World<E>,
    algo: &dyn ScanAlgorithm<E>,
    plans: &[ExecPlan<E>],
) -> Result<(Vec<Vec<Option<Vec<E>>>>, TraceReport), String> {
    let per_rank = world
        .run(|ctx| {
            let w = ctx.rank();
            let mut outs: Vec<Option<Vec<E>>> = (0..plans.len()).map(|_| None).collect();
            for (pi, ep) in plans.iter().enumerate() {
                let Some(cr) = ep.comm.rank_of(w) else { continue };
                let input = &ep.inputs[cr];
                let mut output = vec![E::filler(); input.len()];
                ctx.with_comm(&ep.comm, |sub| algo.run(sub, input, &mut output, &ep.op))?;
                outs[pi] = Some(output);
            }
            Ok((outs, ctx.take_trace()))
        })
        .map_err(|e| format!("{e:#}"))?;

    let mut traces: Vec<RankTrace> = Vec::with_capacity(per_rank.len());
    let mut outs: Vec<Vec<Option<Vec<E>>>> = Vec::with_capacity(per_rank.len());
    for (rank, (o, t)) in per_rank.into_iter().enumerate() {
        outs.push(o);
        traces.push(t.unwrap_or_else(|| RankTrace::new(rank)));
    }
    Ok((outs, TraceReport::new(traces)))
}

/// Closed-form rounds the plan's requests would pay executed one
/// collective each (each on a communicator of its own span, at its own
/// vector length — m-aware so the chunked/pipelined schedules are costed
/// by what their traces measure).
fn solo_equiv_rounds<T: Elem>(
    pending: &[Option<PendingReq<T>>],
    members: &[usize],
    algo: &dyn ScanAlgorithm<T>,
) -> u64 {
    members
        .iter()
        .map(|&mi| {
            let req = req_of(pending, mi);
            algo.predicted_rounds_m(req.span(), req.m()) as u64
        })
        .sum()
}

/// Fulfill the handles of a value-typed wave: slice each request's output
/// back out of its plan's coalesced result.
fn scatter_t<T: Elem>(
    plans: &[ExecPlan<T>],
    outs: &[Vec<Option<Vec<T>>>],
    report: &TraceReport,
    pending: &mut [Option<PendingReq<T>>],
    shared: &Shared<T>,
    algo: &dyn ScanAlgorithm<T>,
) {
    for (pi, ep) in plans.iter().enumerate() {
        let rounds = report.for_ctx(ep.comm.ctx(), ep.comm.ranks()).total_rounds();
        let members = ep.plan.members();
        let k = ep.plan.batch_size();
        let coalesced_m = ep.inputs.iter().map(|v| v.len()).max().unwrap_or(0);
        let mode = match &ep.plan {
            Plan::Solo { .. } => BatchMode::Solo,
            Plan::Concat { .. } if k == 1 => BatchMode::Solo,
            Plan::Concat { .. } => BatchMode::Concat,
            Plan::Segmented { .. } => unreachable!("segmented plans are Seg-typed"),
        };
        let solo_equiv = solo_equiv_rounds(pending, &members, algo);
        let stats = RequestStats {
            mode,
            batch_size: k,
            coalesced_m,
            rounds,
            amortized_rounds: rounds as f64 / k as f64,
        };
        match &ep.plan {
            Plan::Concat { members } => {
                let mut offset = 0usize;
                for &mi in members {
                    let pr = pending[mi].take().expect("concat member pending");
                    let m = pr.req.m();
                    let outputs: Vec<Vec<T>> = (0..shared.p)
                        .map(|wr| {
                            outs[wr][pi].as_ref().map_or_else(
                                || vec![T::filler(); m],
                                |o| o[offset..offset + m].to_vec(),
                            )
                        })
                        .collect();
                    offset += m;
                    complete(pr, ScanOutput { outputs, stats }, shared);
                }
            }
            Plan::Solo { member } => {
                let pr = pending[*member].take().expect("solo member pending");
                let m = pr.req.m();
                let outputs: Vec<Vec<T>> = ep
                    .comm
                    .ranks()
                    .iter()
                    .map(|&wr| {
                        outs[wr][pi].clone().unwrap_or_else(|| vec![T::filler(); m])
                    })
                    .collect();
                complete(pr, ScanOutput { outputs, stats }, shared);
            }
            Plan::Segmented { .. } => unreachable!(),
        }
        shared.metrics.on_batch(mode, k, coalesced_m, rounds, solo_equiv);
    }
}

/// Fulfill the handles of a segmented wave: project each request's lane
/// back to plain values (`val` field), with the segment-start member's
/// output left as filler (undefined, per `MPI_Exscan`).
fn scatter_seg<T: Elem>(
    plans: &[ExecPlan<Seg<T>>],
    outs: &[Vec<Option<Vec<Seg<T>>>>],
    report: &TraceReport,
    pending: &mut [Option<PendingReq<T>>],
    shared: &Shared<T>,
    algo: &dyn ScanAlgorithm<T>,
) {
    for (pi, ep) in plans.iter().enumerate() {
        let Plan::Segmented { lanes, m } = &ep.plan else { unreachable!() };
        let m = *m;
        let rounds = report.for_ctx(ep.comm.ctx(), ep.comm.ranks()).total_rounds();
        let members = ep.plan.members();
        let k = ep.plan.batch_size();
        let coalesced_m = lanes.len() * m;
        let solo_equiv = solo_equiv_rounds(pending, &members, algo);
        let stats = RequestStats {
            mode: BatchMode::Segmented,
            batch_size: k,
            coalesced_m,
            rounds,
            amortized_rounds: rounds as f64 / k as f64,
        };
        for (l, lane) in lanes.iter().enumerate() {
            for &mi in lane {
                let pr = pending[mi].take().expect("segmented member pending");
                let start = pr.req.ranks.start;
                let outputs: Vec<Vec<T>> = pr
                    .req
                    .ranks
                    .clone()
                    .map(|wr| {
                        if wr == start {
                            vec![T::filler(); m] // undefined on the first member
                        } else {
                            (0..m)
                                .map(|j| {
                                    outs[wr][pi]
                                        .as_ref()
                                        .map(|o| o[l * m + j].val)
                                        .unwrap_or_else(T::filler)
                                })
                                .collect()
                        }
                    })
                    .collect();
                complete(pr, ScanOutput { outputs, stats }, shared);
            }
        }
        shared.metrics.on_batch(BatchMode::Segmented, k, coalesced_m, rounds, solo_equiv);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MS: Duration = Duration::from_millis(1);

    /// Adaptive policy used by the pure window-step tests: range
    /// `[1 ms, 16 ms]`, `max_batch` 64 (the default).
    fn adaptive() -> BatchPolicy {
        BatchPolicy::default().with_adaptive_window(MS, 16 * MS)
    }

    #[test]
    fn window_widens_under_load_and_narrows_when_idle() {
        let p = adaptive();
        // Saturated cycles double up to the cap.
        let mut w = 2 * MS;
        w = p.next_window(w, 64, false);
        assert_eq!(w, 4 * MS);
        w = p.next_window(w, 200, false);
        assert_eq!(w, 8 * MS);
        w = p.next_window(w, 64, false);
        assert_eq!(w, 16 * MS);
        w = p.next_window(w, 64, false);
        assert_eq!(w, 16 * MS, "clamped at hi");
        // Idle cycles halve down to the floor.
        w = p.next_window(w, 0, false);
        assert_eq!(w, 8 * MS);
        w = p.next_window(w, 16, false);
        assert_eq!(w, 4 * MS, "quarter-full still counts as idle");
        w = p.next_window(w, 1, false);
        w = p.next_window(w, 1, false);
        w = p.next_window(w, 1, false);
        assert_eq!(w, lo_of(&p), "clamped at lo");
        // Mid-load holds steady.
        assert_eq!(p.next_window(4 * MS, 32, false), 4 * MS);
    }

    #[test]
    fn window_step_clamps_an_out_of_range_start() {
        let p = BatchPolicy::default().with_adaptive_window(2 * MS, 8 * MS);
        assert_eq!(p.next_window(MS, 32, false), 2 * MS);
        assert_eq!(p.next_window(100 * MS, 32, false), 8 * MS);
        // Degenerate max_batch never divides by zero.
        let mut degenerate = p.clone();
        degenerate.max_batch = 0;
        assert_eq!(degenerate.next_window(4 * MS, 0, false), 2 * MS);
    }

    #[test]
    fn overloaded_hint_narrows_and_never_widens() {
        let p = adaptive();
        // Even a cycle that filled the batch cap — which would widen the
        // window under normal load — narrows when the admission gauge is
        // at its caps: the service must drain, not coalesce harder.
        assert_eq!(p.next_window(8 * MS, 64, true), 4 * MS);
        assert_eq!(p.next_window(8 * MS, 200, true), 4 * MS);
        assert_eq!(p.next_window(8 * MS, 32, true), 4 * MS);
        // Still clamped at the floor.
        assert_eq!(p.next_window(MS, 64, true), MS);
        // A fixed-window policy (no range) is untouched by the hint.
        let fixed = BatchPolicy::default();
        assert_eq!(fixed.next_window(8 * MS, 64, true), 8 * MS);
    }

    fn lo_of(p: &BatchPolicy) -> Duration {
        p.window_range.expect("adaptive").0
    }
}
