//! Client-facing request, operator and handle types of the scan service.
//!
//! A [`ScanRequest`] is one logical `MPI_Exscan` over a contiguous range of
//! world ranks (the full world by default): one input vector per member
//! rank, one operator. [`submit`](super::ScanEngine::submit) returns a
//! nonblocking [`ScanHandle`] with MPI_Request-style `test`/`wait`
//! semantics; the engine fulfills it after the request's batch completes.
//!
//! [`ReqOp`] wraps the operator two ways: the base element-wise combine
//! (enough for lane-concatenation coalescing, which works for *any*
//! associative ⊕), and optionally the scalar combine function, which lets
//! the batcher lift it into a segmented operator
//! ([`coll::segmented::lift`](crate::coll::segmented::lift)) and pack
//! disjoint sub-range requests into shared lanes of one world-wide scan.

use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::coll::segmented::{lift, Seg};
use crate::mpi::{CombineOp, Elem, OpRef};

// ───────────────────────────── errors ─────────────────────────────

/// Typed service error. Implements [`std::error::Error`], so it converts
/// into `anyhow::Error` via `?` and participates in `{:#}` context chains
/// (see the engine's worker-side error paths).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SvcError {
    /// The request's shape is invalid (rank range, input lengths…).
    Shape(String),
    /// The engine is shutting down and can no longer accept or complete
    /// requests.
    Shutdown,
    /// Admission control refused the request: the engine is at its
    /// configured in-flight depth/bytes limit (see
    /// [`EngineConfig`](super::EngineConfig)). The request was never
    /// queued — retry later or shed load upstream.
    Overloaded,
    /// The collective executing this request's batch failed; carries the
    /// rendered `{:#}` chain of the underlying transport error.
    Collective(String),
    /// A rank of the engine's world died (chaos rank-death injection, or
    /// any fault that permanently kills a rank) while this request's
    /// batch was in flight. The engine rebuilds its worlds after
    /// reporting this; subsequent requests run on the fresh world.
    RankFailed {
        /// World rank that died (the first one, if several).
        rank: usize,
        /// Rendered `{:#}` chain of the underlying failure.
        detail: String,
    },
    /// `wait_timeout` deadline expired before the result arrived.
    WaitTimeout,
}

impl std::fmt::Display for SvcError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SvcError::Shape(d) => write!(f, "invalid scan request: {d}"),
            SvcError::Shutdown => write!(f, "scan engine has shut down"),
            SvcError::Overloaded => {
                write!(f, "scan engine overloaded: admission limit reached")
            }
            SvcError::Collective(d) => write!(f, "batch collective failed: {d}"),
            SvcError::RankFailed { rank, detail } => {
                write!(f, "rank {rank} failed during batch collective: {detail}")
            }
            SvcError::WaitTimeout => write!(f, "timed out waiting for scan result"),
        }
    }
}

impl std::error::Error for SvcError {}

// ───────────────────────────── operator ─────────────────────────────

/// Element-wise combine defined by a scalar closure (the service-side
/// counterpart of [`FnOp`](crate::mpi::FnOp), which needs a `'static`
/// name). Marked non-commutative: nothing here exploits commutativity,
/// and claiming it for an unknown user closure would be wrong.
struct ScalarOp<T: Elem> {
    name: String,
    f: Arc<dyn Fn(T, T) -> T + Send + Sync>,
}

impl<T: Elem> CombineOp<T> for ScalarOp<T> {
    fn name(&self) -> &str {
        &self.name
    }

    fn combine(&self, input: &[T], inout: &mut [T]) {
        for (o, &i) in inout.iter_mut().zip(input) {
            *o = (self.f)(i, *o); // `input` is the earlier operand
        }
    }

    fn commutative(&self) -> bool {
        false
    }
}

/// The operator of a [`ScanRequest`]: a shared combine plus, when known,
/// the scalar function it is built from. Requests with equal
/// [`name`](Self::name) are assumed to denote the *same* operator — the
/// batcher coalesces on that key.
#[derive(Clone)]
pub struct ReqOp<T: Elem> {
    name: String,
    base: Arc<dyn CombineOp<T>>,
    scalar: Option<Arc<dyn Fn(T, T) -> T + Send + Sync>>,
}

impl<T: Elem> ReqOp<T> {
    /// Wrap an existing operator (concat coalescing only — the scalar is
    /// unknown, so segmented lifting is unavailable).
    pub fn from_op(op: &OpRef<T>) -> Self {
        ReqOp { name: op.name().to_string(), base: op.shared_op(), scalar: None }
    }

    /// Build from a scalar combine function. Liftable: sub-range requests
    /// under this operator can be packed into segmented lanes.
    pub fn liftable(name: &str, f: impl Fn(T, T) -> T + Send + Sync + 'static) -> Self {
        let f: Arc<dyn Fn(T, T) -> T + Send + Sync> = Arc::new(f);
        ReqOp {
            name: name.to_string(),
            base: Arc::new(ScalarOp { name: name.to_string(), f: Arc::clone(&f) }),
            scalar: Some(f),
        }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn is_liftable(&self) -> bool {
        self.scalar.is_some()
    }

    /// A fresh [`OpRef`] over the shared combine (its own counters).
    pub(crate) fn fresh(&self) -> OpRef<T> {
        OpRef::new(Arc::clone(&self.base))
    }

    /// The segmented lift of the scalar combine, if known.
    pub(crate) fn lifted(&self) -> Option<OpRef<Seg<T>>> {
        self.scalar.as_ref().map(|f| {
            let f = Arc::clone(f);
            lift(&self.name, move |a, b| f(a, b))
        })
    }
}

impl ReqOp<i64> {
    /// Wrapping `MPI_SUM` over i64 (liftable).
    pub fn sum_i64() -> Self {
        ReqOp::liftable("sum_i64", |a: i64, b: i64| a.wrapping_add(b))
    }

    /// `MPI_BXOR` over i64 (liftable).
    pub fn bxor_i64() -> Self {
        ReqOp::liftable("bxor_i64", |a: i64, b: i64| a ^ b)
    }

    /// `MPI_MAX` over i64 (liftable).
    pub fn max_i64() -> Self {
        ReqOp::liftable("max_i64", |a: i64, b: i64| a.max(b))
    }
}

// ───────────────────────────── request ─────────────────────────────

/// One logical exclusive scan: per-member input vectors over a contiguous
/// world-rank range. Output on the range's first member is undefined, per
/// `MPI_Exscan` (the service returns it as filler).
pub struct ScanRequest<T: Elem> {
    pub op: ReqOp<T>,
    /// One input vector per member rank, all the same length.
    pub inputs: Vec<Vec<T>>,
    /// The contiguous world-rank range this scan spans;
    /// `inputs.len() == ranks.len()`.
    pub ranks: std::ops::Range<usize>,
}

impl<T: Elem> ScanRequest<T> {
    /// A scan over the full world (`inputs.len()` ranks).
    pub fn full(op: ReqOp<T>, inputs: Vec<Vec<T>>) -> Self {
        let p = inputs.len();
        ScanRequest { op, inputs, ranks: 0..p }
    }

    /// A scan over world ranks `start..start + inputs.len()`.
    pub fn over(op: ReqOp<T>, start: usize, inputs: Vec<Vec<T>>) -> Self {
        let end = start + inputs.len();
        ScanRequest { op, inputs, ranks: start..end }
    }

    /// Vector length per rank.
    pub fn m(&self) -> usize {
        self.inputs.first().map(|v| v.len()).unwrap_or(0)
    }

    /// Number of member ranks.
    pub fn span(&self) -> usize {
        self.ranks.len()
    }

    /// Total payload size (all member input vectors), the unit the
    /// engine's inflight-bytes admission gauge is kept in.
    pub fn payload_bytes(&self) -> usize {
        self.inputs.iter().map(|v| v.len()).sum::<usize>() * T::size_bytes()
    }

    /// Validate against a world of size `p`.
    pub(crate) fn validate(&self, p: usize) -> Result<(), SvcError> {
        if self.ranks.start >= self.ranks.end || self.ranks.end > p {
            return Err(SvcError::Shape(format!(
                "rank range {:?} invalid for world size {p}",
                self.ranks
            )));
        }
        if self.inputs.len() != self.ranks.len() {
            return Err(SvcError::Shape(format!(
                "{} input vectors for {} member ranks",
                self.inputs.len(),
                self.ranks.len()
            )));
        }
        let m = self.m();
        if self.inputs.iter().any(|v| v.len() != m) {
            return Err(SvcError::Shape("member input lengths differ".into()));
        }
        Ok(())
    }
}

// ───────────────────────────── handle ─────────────────────────────

/// How a request was executed (recorded in its [`RequestStats`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BatchMode {
    /// Ran as its own collective (no coalescing partner).
    Solo,
    /// Lane-concatenated with other full-world requests sharing its op.
    Concat,
    /// Packed into a segmented lane of a world-wide lifted scan.
    Segmented,
}

/// Per-request accounting attached to a completed result.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RequestStats {
    pub mode: BatchMode,
    /// Requests that shared this request's collective (≥ 1, incl. itself).
    pub batch_size: usize,
    /// Elements per rank the coalesced collective carried.
    pub coalesced_m: usize,
    /// Communication rounds the collective paid — measured from the
    /// batch's [`TraceReport`](crate::trace::TraceReport).
    pub rounds: u32,
    /// `rounds / batch_size`: the amortized per-request round cost, the
    /// number the batching subsystem exists to shrink.
    pub amortized_rounds: f64,
}

/// A completed request: per-member output vectors (index 0 = the range's
/// first rank; its content is undefined/filler, per `MPI_Exscan`) plus the
/// batch accounting.
#[derive(Debug)]
pub struct ScanOutput<T: Elem> {
    pub outputs: Vec<Vec<T>>,
    pub stats: RequestStats,
}

pub(crate) struct HandleState<T: Elem> {
    slot: Mutex<Option<Result<ScanOutput<T>, SvcError>>>,
    cv: Condvar,
    /// Raised by [`ScanHandle::wait_timeout`] when the client gives up on
    /// the request: the dispatcher's eventual `fulfill` still resolves
    /// the slot (exactly-once discipline), but reports the delivery as
    /// unobserved so the engine can count it
    /// ([`MetricsSnapshot::abandoned`](super::MetricsSnapshot)) instead
    /// of completing into a dead handle silently.
    abandoned: std::sync::atomic::AtomicBool,
}

impl<T: Elem> HandleState<T> {
    pub(crate) fn new() -> Arc<Self> {
        Arc::new(HandleState {
            slot: Mutex::new(None),
            cv: Condvar::new(),
            abandoned: std::sync::atomic::AtomicBool::new(false),
        })
    }

    /// Deliver the result. Returns `true` when the client already
    /// abandoned the handle (`wait_timeout` expired), so the caller can
    /// account an unobserved completion.
    pub(crate) fn fulfill(&self, result: Result<ScanOutput<T>, SvcError>) -> bool {
        let mut slot = self.slot.lock().unwrap();
        debug_assert!(slot.is_none(), "a handle must be fulfilled exactly once");
        *slot = Some(result);
        drop(slot);
        self.cv.notify_all();
        self.abandoned.load(std::sync::atomic::Ordering::Acquire)
    }

    /// Fulfill only if nothing has been delivered yet — the last-resort
    /// path ([`PendingReq`](super::batcher::PendingReq)'s `Drop`) that
    /// turns an abandoned request into a typed error instead of a hung
    /// `wait`. Returns whether this call delivered (so the caller can
    /// account the failure).
    pub(crate) fn fulfill_if_empty(&self, result: Result<ScanOutput<T>, SvcError>) -> bool {
        let mut slot = self.slot.lock().unwrap();
        if slot.is_none() {
            *slot = Some(result);
            drop(slot);
            self.cv.notify_all();
            true
        } else {
            false
        }
    }
}

/// Nonblocking completion handle for a submitted request
/// (`MPI_Request`-flavoured): [`test`](Self::test) polls,
/// [`wait`](Self::wait) blocks and consumes.
pub struct ScanHandle<T: Elem> {
    pub(crate) state: Arc<HandleState<T>>,
}

impl<T: Elem> ScanHandle<T> {
    /// Nonblocking completion probe (`MPI_Test` without result take-out).
    pub fn test(&self) -> bool {
        self.state.slot.lock().unwrap().is_some()
    }

    /// Block until the result is available and take it (`MPI_Wait`).
    pub fn wait(self) -> Result<ScanOutput<T>, SvcError> {
        let mut slot = self.state.slot.lock().unwrap();
        loop {
            if let Some(result) = slot.take() {
                return result;
            }
            slot = self.state.cv.wait(slot).unwrap();
        }
    }

    /// [`wait`](Self::wait) with a deadline; `Err(WaitTimeout)` leaves the
    /// handle unusable (it is consumed either way — tests use this to
    /// avoid hanging on a defective engine).
    ///
    /// Timing out marks the pending slot *abandoned*: the request stays
    /// in flight and the dispatcher still resolves it exactly once, but
    /// that late delivery is counted in
    /// [`MetricsSnapshot::abandoned`](super::MetricsSnapshot) rather than
    /// vanishing into a dropped handle unobserved.
    pub fn wait_timeout(self, timeout: Duration) -> Result<ScanOutput<T>, SvcError> {
        let deadline = Instant::now() + timeout;
        let mut slot = self.state.slot.lock().unwrap();
        loop {
            if let Some(result) = slot.take() {
                return result;
            }
            let now = Instant::now();
            if now >= deadline {
                // Publish the abandonment while still holding the slot
                // lock: a fulfill racing this timeout either delivered
                // already (taken above on a later iteration — impossible
                // here, we return) or will take the lock after us and
                // observe the flag.
                self.state
                    .abandoned
                    .store(true, std::sync::atomic::Ordering::Release);
                return Err(SvcError::WaitTimeout);
            }
            let (guard, _) = self.state.cv.wait_timeout(slot, deadline - now).unwrap();
            slot = guard;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpi::ops;

    #[test]
    fn reqop_from_op_is_not_liftable() {
        let op = ReqOp::from_op(&ops::bxor());
        assert_eq!(op.name(), "bxor_i64");
        assert!(!op.is_liftable());
        assert!(op.lifted().is_none());
    }

    #[test]
    fn liftable_reqop_base_and_lift_agree() {
        let op = ReqOp::sum_i64();
        assert!(op.is_liftable());
        // Base combine: elementwise with `input` as earlier operand.
        let base = op.fresh();
        let mut inout = vec![10i64, 20];
        base.reduce_local_sharded(0, &[1, 2], &mut inout);
        assert_eq!(inout, vec![11, 22]);
        // Lifted combine: segment flag blocks the earlier operand.
        let lifted = op.lifted().unwrap();
        assert_eq!(lifted.name(), "seg_sum_i64");
        let mut seg = vec![Seg::cont(5i64), Seg::start(7)];
        lifted.reduce_local_sharded(0, &[Seg::cont(1), Seg::cont(2)], &mut seg);
        assert_eq!(seg[0], Seg::cont(6));
        assert_eq!(seg[1], Seg::start(7), "flag must block the earlier value");
    }

    #[test]
    fn request_validation() {
        let ok = ScanRequest::full(ReqOp::sum_i64(), vec![vec![1i64], vec![2]]);
        assert!(ok.validate(2).is_ok());
        assert_eq!(ok.m(), 1);
        let ragged = ScanRequest::full(ReqOp::sum_i64(), vec![vec![1i64], vec![2, 3]]);
        assert!(matches!(ragged.validate(2), Err(SvcError::Shape(_))));
        let out_of_world = ScanRequest::over(ReqOp::sum_i64(), 3, vec![vec![1i64], vec![2]]);
        assert!(matches!(out_of_world.validate(4), Err(SvcError::Shape(_))));
        assert!(out_of_world.validate(5).is_ok());
    }

    #[test]
    fn handle_test_wait_roundtrip() {
        let state = HandleState::<i64>::new();
        let h = ScanHandle { state: Arc::clone(&state) };
        assert!(!h.test());
        let filler = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            state.fulfill(Ok(ScanOutput {
                outputs: vec![vec![], vec![42]],
                stats: RequestStats {
                    mode: BatchMode::Solo,
                    batch_size: 1,
                    coalesced_m: 1,
                    rounds: 1,
                    amortized_rounds: 1.0,
                },
            }));
        });
        let out = h.wait().unwrap();
        assert_eq!(out.outputs[1], vec![42]);
        assert_eq!(out.stats.batch_size, 1);
        filler.join().unwrap();
    }

    #[test]
    fn handle_wait_timeout_expires() {
        let state = HandleState::<i64>::new();
        let h = ScanHandle { state };
        let t0 = Instant::now();
        let err = h.wait_timeout(Duration::from_millis(40)).unwrap_err();
        assert_eq!(err, SvcError::WaitTimeout);
        assert!(t0.elapsed() >= Duration::from_millis(35));
    }

    #[test]
    fn fulfill_after_timeout_reports_abandoned() {
        let state = HandleState::<i64>::new();
        let h = ScanHandle { state: Arc::clone(&state) };
        let err = h.wait_timeout(Duration::from_millis(5)).unwrap_err();
        assert_eq!(err, SvcError::WaitTimeout);
        // The dispatcher's late delivery still resolves the slot but is
        // flagged unobserved — the engine counts it as abandoned.
        let abandoned = state.fulfill(Err(SvcError::Shutdown));
        assert!(abandoned, "delivery into a timed-out handle must be flagged");
        // A live handle's delivery is not flagged.
        let live = HandleState::<i64>::new();
        assert!(!live.fulfill(Err(SvcError::Shutdown)));
    }

    #[test]
    fn svc_error_chains_through_anyhow() {
        // The typed error must ride the shim's blanket From and render in
        // `{:#}` context chains — the engine's worker-side pattern.
        fn inner() -> anyhow::Result<()> {
            let failed: Result<(), SvcError> =
                Err(SvcError::Collective("rank 3 deadlocked".into()));
            failed?; // converts via the blanket `From<E: std::error::Error>`
            Ok(())
        }
        use anyhow::Context as _;
        let err = inner().context("executing batch 7").unwrap_err();
        let chain = format!("{err:#}");
        assert!(chain.contains("executing batch 7"), "{chain}");
        assert!(chain.contains("rank 3 deadlocked"), "{chain}");
    }
}
