//! The multi-tenant scan service: nonblocking requests, communicator
//! isolation, and small-m batch coalescing.
//!
//! The paper's regime is small vectors, where latency is dominated by
//! communication **rounds** — so the production win for serving many
//! independent exscan requests is amortization: K coalesced requests pay
//! the `⌈log₂(p−1) + log₂(4/3)⌉` rounds of one collective *once*. This
//! subsystem supplies the three layers that turn the repo's collectives
//! into that service:
//!
//! * [`request`] — [`ScanRequest`]/[`ReqOp`] (operator with optional
//!   segmented lift) and the `MPI_Request`-flavoured [`ScanHandle`]
//!   (`test`/`wait`), plus the typed [`SvcError`].
//! * [`batcher`] — pure planning: full-world requests sharing an operator
//!   lane-concatenate; disjoint sub-range requests with a liftable
//!   operator pack into segmented lanes of one world-wide scan
//!   (Blelloch's operator lifting, [`crate::coll::segmented`]); the rest
//!   run solo on sub-communicators.
//! * [`engine`] — the dispatcher: one persistent [`World`] per element
//!   type, a recycled ring of communicator contexts, every plan of a
//!   cycle concurrently in flight, results scattered back to handles.
//! * [`metrics`] — rounds-per-request accounting (the number batching
//!   exists to shrink) and operational counters.
//!
//! Differential verification: the service path is covered by the chaos
//! harness — `exscan serve --smoke --chaos-seed N` and
//! `tests/service.rs` check service results under seeded fault injection
//! against each request executed serially on a clean world, and
//! [`crate::coll::validate::chaos_concurrent_comms`] pins the
//! communicator layer itself (outputs *and* per-context traces).
//!
//! [`World`]: crate::mpi::World

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod request;

pub use batcher::BatchPolicy;
pub use engine::{EngineConfig, ScanEngine, CTX_RING};
pub use metrics::{MetricsSnapshot, ServiceMetrics};
pub use request::{
    BatchMode, ReqOp, RequestStats, ScanHandle, ScanOutput, ScanRequest, SvcError,
};
