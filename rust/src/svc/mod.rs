//! The multi-tenant scan service: nonblocking requests, communicator
//! isolation, small-m batch coalescing, and failure hardening.
//!
//! The paper's regime is small vectors, where latency is dominated by
//! communication **rounds** — so the production win for serving many
//! independent exscan requests is amortization: K coalesced requests pay
//! the `⌈log₂(p−1) + log₂(4/3)⌉` rounds of one collective *once*. This
//! subsystem supplies the layers that turn the repo's collectives into
//! that service:
//!
//! * [`request`] — [`ScanRequest`]/[`ReqOp`] (operator with optional
//!   segmented lift) and the `MPI_Request`-flavoured [`ScanHandle`]
//!   (`test`/`wait`/`wait_timeout`), plus the typed [`SvcError`] —
//!   including [`SvcError::Overloaded`] (admission rejection) and the
//!   attributed [`SvcError::RankFailed`].
//! * [`batcher`] — pure planning: full-world requests sharing an operator
//!   lane-concatenate; disjoint sub-range requests with a liftable
//!   operator pack into segmented lanes of one world-wide scan
//!   (Blelloch's operator lifting, [`crate::coll::segmented`]); the rest
//!   run solo on sub-communicators. [`BatchPolicy`] optionally carries an
//!   adaptive batching-window range (widens under load, narrows idle).
//! * [`engine`] — the dispatcher: one persistent [`World`] per element
//!   type, a recycled ring of communicator contexts, every plan of a
//!   cycle concurrently in flight, results scattered back to handles.
//!   The submit side is a **bounded admission gate** (open-request and
//!   inflight-byte caps, fail-fast or block-with-deadline), and wave
//!   failures under chaos rank-death rebuild the worlds live with the
//!   `submitted == completed + failed` invariant intact.
//! * [`metrics`] — rounds-per-request accounting (the number batching
//!   exists to shrink), robustness counters (rejected / abandoned /
//!   rank_failures / inflight_bytes / pool gauges), and a fixed
//!   log-bucket latency histogram with conservative p50/p99/p999
//!   quantiles for SLO gating.
//!
//! Differential verification: the service path is covered by the chaos
//! harness — `exscan serve --smoke --chaos-seed N` and
//! `tests/service.rs` check service results under seeded fault injection
//! against each request executed serially on a clean world, and
//! [`crate::coll::validate::chaos_concurrent_comms`] pins the
//! communicator layer itself (outputs *and* per-context traces). The
//! rank-death path is pinned by `validate::rank_death_differential` and
//! the soak/kill modes of `exscan serve`.
//!
//! [`World`]: crate::mpi::World

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod request;

pub use batcher::BatchPolicy;
pub use engine::{
    AdmissionMode, EngineConfig, ScanEngine, CTX_RING, DEFAULT_MAX_INFLIGHT,
    DEFAULT_MAX_INFLIGHT_BYTES, DEFAULT_RECV_TIMEOUT,
};
pub use metrics::{MetricsSnapshot, ServiceMetrics};
pub use request::{
    BatchMode, ReqOp, RequestStats, ScanHandle, ScanOutput, ScanRequest, SvcError,
};
