//! Small shared utilities: bit tricks, statistics, dense linear algebra,
//! and the offline-build replacements for common crates (channel, RNG,
//! property-test harness).

pub mod bits;
pub mod channel;
pub mod linalg;
pub mod quickcheck;
pub mod rng;
pub mod stats;

pub use bits::{ceil_log2, floor_log2, is_pow2};
pub use channel::{Channel, OneShot, PushError};
pub use rng::Rng;
pub use stats::Summary;
