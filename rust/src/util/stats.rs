//! Summary statistics for benchmark repetitions (mpicroscope-style: the
//! paper reports, per element count, the *minimum over repetitions of the
//! maximum over ranks*).

/// Running summary over a set of f64 samples (times in microseconds).
#[derive(Debug, Clone, Default)]
pub struct Summary {
    samples: Vec<f64>,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.samples.push(x);
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Minimum sample — the paper's headline statistic [Träff, mpicroscope].
    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    /// Sample standard deviation (n-1 denominator).
    pub fn stddev(&self) -> f64 {
        let n = self.samples.len();
        if n < 2 {
            return 0.0;
        }
        let mu = self.mean();
        let var = self.samples.iter().map(|x| (x - mu) * (x - mu)).sum::<f64>() / (n - 1) as f64;
        var.sqrt()
    }

    /// Percentile by nearest-rank (q in [0,1]).
    pub fn percentile(&self, q: f64) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        let mut v = self.samples.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((q * (v.len() as f64 - 1.0)).round() as usize).min(v.len() - 1);
        v[idx]
    }

    pub fn median(&self) -> f64 {
        self.percentile(0.5)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let mut s = Summary::new();
        for x in [3.0, 1.0, 2.0] {
            s.push(x);
        }
        assert_eq!(s.len(), 3);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 3.0);
        assert!((s.mean() - 2.0).abs() < 1e-12);
        assert!((s.median() - 2.0).abs() < 1e-12);
        assert!((s.stddev() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn empty_is_nan() {
        let s = Summary::new();
        assert!(s.mean().is_nan());
        assert!(s.median().is_nan());
        assert!(s.is_empty());
    }

    #[test]
    fn percentiles() {
        let mut s = Summary::new();
        for i in 0..101 {
            s.push(i as f64);
        }
        assert_eq!(s.percentile(0.0), 0.0);
        assert_eq!(s.percentile(1.0), 100.0);
        assert_eq!(s.percentile(0.25), 25.0);
    }
}
