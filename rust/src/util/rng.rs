//! A small deterministic RNG (SplitMix64 seeding a xoshiro256**), replacing
//! the `rand` crate in this offline build. Not cryptographic; used only for
//! workload generation and the property-test harness.

/// xoshiro256** seeded via SplitMix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut st = seed;
        let s = [splitmix64(&mut st), splitmix64(&mut st), splitmix64(&mut st), splitmix64(&mut st)];
        Rng { s }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    pub fn gen_i64(&mut self) -> i64 {
        self.next_u64() as i64
    }

    /// Uniform in `[0, n)`; `n > 0`.
    pub fn gen_range_usize(&mut self, n: usize) -> usize {
        assert!(n > 0);
        // Lemire-style rejection-free approximation is fine for tests;
        // use 128-bit multiply for low bias.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform f64 in [0, 1).
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in [lo, hi).
    pub fn gen_range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.gen_f64() as f32
    }

    pub fn gen_bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::seed_from_u64(7);
        let mut b = Rng::seed_from_u64(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::seed_from_u64(1);
        let mut b = Rng::seed_from_u64(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::seed_from_u64(3);
        for _ in 0..10_000 {
            let x = r.gen_range_usize(17);
            assert!(x < 17);
            let f = r.gen_f64();
            assert!((0.0..1.0).contains(&f));
            let g = r.gen_range_f32(-2.0, 3.0);
            assert!((-2.0..3.0).contains(&g));
        }
    }

    #[test]
    fn rough_uniformity() {
        let mut r = Rng::seed_from_u64(11);
        let mut counts = [0usize; 8];
        for _ in 0..80_000 {
            counts[r.gen_range_usize(8)] += 1;
        }
        for &c in &counts {
            assert!((9_000..11_000).contains(&c), "skewed: {counts:?}");
        }
    }
}
