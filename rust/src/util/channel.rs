//! A small MPMC channel (Mutex + Condvar), replacing `crossbeam-channel`
//! in this offline build.
//!
//! Historically this was the per-rank mailbox of the message transport;
//! the scan hot path now goes through the slot-keyed
//! [`Inbox`](crate::mpi) matcher instead (see EXPERIMENTS.md §Perf for
//! the before/after numbers — `benches/hotpath.rs` still measures this
//! queue as the "legacy transport" baseline). The channel remains the
//! right tool for genuinely unordered MPMC traffic: the [`World`]
//! executor's per-rank job queues and the PJRT executor's request queue.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// Why a push was refused (the item is handed back in both cases so the
/// caller can resolve or account it — nothing is silently dropped).
#[derive(Debug)]
pub enum PushError<T> {
    /// Bounded channel at capacity (backpressure; see [`Channel::bounded`]).
    Full(T),
    /// [`Channel::close`] was called.
    Closed(T),
}

/// Bounded spin attempts before parking in `pop_timeout` (tuned in
/// `benches/hotpath.rs`; see EXPERIMENTS.md §Perf). Spinning only helps
/// when the sending thread can actually run in parallel — on a 1–2 core
/// host the peer needs *our* core, so we park immediately instead.
fn spin_tries() -> u32 {
    static N: std::sync::OnceLock<u32> = std::sync::OnceLock::new();
    *N.get_or_init(|| {
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        if cores > 2 {
            60
        } else {
            0
        }
    })
}

/// An MPMC queue, unbounded by default; [`bounded`](Channel::bounded)
/// adds a capacity for backpressure-aware producers ([`try_push`] /
/// [`push_deadline`]).
///
/// [`try_push`]: Channel::try_push
/// [`push_deadline`]: Channel::push_deadline
pub struct Channel<T> {
    q: Mutex<ChannelState<T>>,
    cv: Condvar,
    /// Producers blocked on a full bounded channel park here; every pop
    /// on a bounded channel notifies it.
    space_cv: Condvar,
    /// `None` = unbounded (the executor job queues), `Some(cap)` = at
    /// most `cap` items buffered (the scan service's admission backstop).
    cap: Option<usize>,
}

struct ChannelState<T> {
    items: VecDeque<T>,
    /// Set when all producers are gone (used by the executor shutdown).
    closed: bool,
}

impl<T> Default for Channel<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> Channel<T> {
    pub fn new() -> Self {
        Self::with_cap(None)
    }

    /// A channel holding at most `cap` items: pushes beyond that report
    /// [`PushError::Full`] (or block, for the deadline variants) instead
    /// of growing the queue without bound.
    pub fn bounded(cap: usize) -> Self {
        Self::with_cap(Some(cap.max(1)))
    }

    fn with_cap(cap: Option<usize>) -> Self {
        Channel {
            q: Mutex::new(ChannelState { items: VecDeque::new(), closed: false }),
            cv: Condvar::new(),
            space_cv: Condvar::new(),
            cap,
        }
    }

    fn is_full(&self, s: &ChannelState<T>) -> bool {
        self.cap.is_some_and(|c| s.items.len() >= c)
    }

    /// Enqueue an item. Returns `Err(item)` if the channel is closed. On
    /// a *bounded* channel this blocks while full (no deadline); use
    /// [`try_push`](Self::try_push) / [`push_deadline`](Self::push_deadline)
    /// for backpressure-aware producers.
    pub fn push(&self, item: T) -> Result<(), T> {
        let mut s = self.q.lock().unwrap();
        loop {
            if s.closed {
                return Err(item);
            }
            if !self.is_full(&s) {
                s.items.push_back(item);
                drop(s);
                self.cv.notify_one();
                return Ok(());
            }
            s = self.space_cv.wait(s).unwrap();
        }
    }

    /// Non-blocking enqueue: fails fast with [`PushError::Full`] on a
    /// bounded channel at capacity (never fails `Full` when unbounded).
    pub fn try_push(&self, item: T) -> Result<(), PushError<T>> {
        let mut s = self.q.lock().unwrap();
        if s.closed {
            return Err(PushError::Closed(item));
        }
        if self.is_full(&s) {
            return Err(PushError::Full(item));
        }
        s.items.push_back(item);
        drop(s);
        self.cv.notify_one();
        Ok(())
    }

    /// Enqueue, waiting up to `timeout` for space on a full bounded
    /// channel (the blocking admission mode). [`PushError::Full`] once
    /// the deadline expires with the channel still at capacity.
    pub fn push_deadline(&self, item: T, timeout: Duration) -> Result<(), PushError<T>> {
        let deadline = Instant::now() + timeout;
        let mut s = self.q.lock().unwrap();
        loop {
            if s.closed {
                return Err(PushError::Closed(item));
            }
            if !self.is_full(&s) {
                s.items.push_back(item);
                drop(s);
                self.cv.notify_one();
                return Ok(());
            }
            let now = Instant::now();
            if now >= deadline {
                return Err(PushError::Full(item));
            }
            let (guard, _) = self.space_cv.wait_timeout(s, deadline - now).unwrap();
            s = guard;
        }
    }

    /// Wake one producer parked on a full bounded channel. No-op (and no
    /// atomics beyond the branch) for unbounded channels — the executor
    /// hot path is unchanged.
    fn notify_space(&self) {
        if self.cap.is_some() {
            self.space_cv.notify_one();
        }
    }

    /// Blocking pop with timeout. `None` on timeout or when closed+empty.
    ///
    /// Fast path: a short spin phase (bounded `try_pop` attempts with CPU
    /// relax hints) before falling back to the condvar sleep — scan rounds
    /// are rendezvous-shaped, so the peer's message usually lands within a
    /// few hundred nanoseconds and the wakeup latency of a full park
    /// (~1–2 µs) would dominate the round (§Perf).
    pub fn pop_timeout(&self, timeout: Duration) -> Option<T> {
        for _ in 0..spin_tries() {
            if let Some(v) = self.try_pop() {
                return Some(v);
            }
            std::hint::spin_loop();
        }
        let deadline = Instant::now() + timeout;
        let mut s = self.q.lock().unwrap();
        loop {
            if let Some(item) = s.items.pop_front() {
                drop(s);
                self.notify_space();
                return Some(item);
            }
            if s.closed {
                return None;
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (guard, res) = self.cv.wait_timeout(s, deadline - now).unwrap();
            s = guard;
            if res.timed_out() && s.items.is_empty() {
                return None;
            }
        }
    }

    /// Non-blocking pop.
    pub fn try_pop(&self) -> Option<T> {
        let item = self.q.lock().unwrap().items.pop_front();
        if item.is_some() {
            self.notify_space();
        }
        item
    }

    /// Blocking pop with no deadline: waits until an item arrives or the
    /// channel is closed *and* drained (`None`). The [`World`] executor's
    /// worker loop idles here between jobs — parked on the condvar, not
    /// spinning — so a persistent world costs nothing while idle.
    pub fn pop_wait(&self) -> Option<T> {
        let mut s = self.q.lock().unwrap();
        loop {
            if let Some(item) = s.items.pop_front() {
                drop(s);
                self.notify_space();
                return Some(item);
            }
            if s.closed {
                return None;
            }
            s = self.cv.wait(s).unwrap();
        }
    }

    /// Close the channel: pending items remain poppable; pushes fail
    /// (including producers blocked on a full bounded channel).
    pub fn close(&self) {
        self.q.lock().unwrap().closed = true;
        self.cv.notify_all();
        self.space_cv.notify_all();
    }

    /// Whether [`close`](Self::close) has been called (items may still be
    /// poppable). Consumers that batch on a time window check this to cut
    /// the window short at shutdown.
    pub fn is_closed(&self) -> bool {
        self.q.lock().unwrap().closed
    }

    pub fn len(&self) -> usize {
        self.q.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// One-shot rendezvous cell for request/reply patterns.
pub struct OneShot<T> {
    cell: Mutex<Option<T>>,
    cv: Condvar,
}

impl<T> Default for OneShot<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> OneShot<T> {
    pub fn new() -> Self {
        OneShot { cell: Mutex::new(None), cv: Condvar::new() }
    }

    pub fn put(&self, value: T) {
        *self.cell.lock().unwrap() = Some(value);
        self.cv.notify_all();
    }

    /// Wait for the value, up to `timeout`. `None` on timeout.
    pub fn take_timeout(&self, timeout: Duration) -> Option<T> {
        let deadline = Instant::now() + timeout;
        let mut g = self.cell.lock().unwrap();
        loop {
            if let Some(v) = g.take() {
                return Some(v);
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (guard, _) = self.cv.wait_timeout(g, deadline - now).unwrap();
            g = guard;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_order() {
        let c = Channel::new();
        for i in 0..10 {
            c.push(i).unwrap();
        }
        for i in 0..10 {
            assert_eq!(c.try_pop(), Some(i));
        }
        assert_eq!(c.try_pop(), None);
    }

    #[test]
    fn cross_thread() {
        let c = Arc::new(Channel::new());
        let c2 = Arc::clone(&c);
        let h = std::thread::spawn(move || {
            for i in 0..1000 {
                c2.push(i).unwrap();
            }
        });
        let mut got = 0;
        while got < 1000 {
            if c.pop_timeout(Duration::from_secs(5)).is_some() {
                got += 1;
            } else {
                panic!("timed out");
            }
        }
        h.join().unwrap();
    }

    #[test]
    fn timeout_returns_none() {
        let c: Channel<i32> = Channel::new();
        let t0 = Instant::now();
        assert!(c.pop_timeout(Duration::from_millis(30)).is_none());
        assert!(t0.elapsed() >= Duration::from_millis(25));
    }

    #[test]
    fn close_rejects_push_but_drains() {
        let c = Channel::new();
        c.push(1).unwrap();
        c.close();
        assert!(c.push(2).is_err());
        assert_eq!(c.pop_timeout(Duration::from_millis(10)), Some(1));
        assert_eq!(c.pop_timeout(Duration::from_millis(10)), None);
    }

    #[test]
    fn bounded_try_push_fails_full_and_frees_on_pop() {
        let c: Channel<i32> = Channel::bounded(2);
        c.try_push(1).unwrap();
        c.try_push(2).unwrap();
        assert!(matches!(c.try_push(3), Err(PushError::Full(3))));
        assert_eq!(c.try_pop(), Some(1));
        c.try_push(3).unwrap();
        assert_eq!(c.try_pop(), Some(2));
        assert_eq!(c.try_pop(), Some(3));
    }

    #[test]
    fn bounded_push_deadline_times_out_then_succeeds_after_pop() {
        let c: Channel<i32> = Channel::bounded(1);
        c.try_push(1).unwrap();
        let t0 = Instant::now();
        assert!(matches!(
            c.push_deadline(2, Duration::from_millis(30)),
            Err(PushError::Full(2))
        ));
        assert!(t0.elapsed() >= Duration::from_millis(25));
        let c = Arc::new(c);
        let popper = Arc::clone(&c);
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(15));
            assert_eq!(popper.try_pop(), Some(1));
        });
        c.push_deadline(2, Duration::from_secs(5)).unwrap();
        h.join().unwrap();
        assert_eq!(c.try_pop(), Some(2));
    }

    #[test]
    fn bounded_close_unblocks_waiting_producer() {
        let c: Arc<Channel<i32>> = Arc::new(Channel::bounded(1));
        c.try_push(1).unwrap();
        let closer = Arc::clone(&c);
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(15));
            closer.close();
        });
        assert!(matches!(
            c.push_deadline(2, Duration::from_secs(5)),
            Err(PushError::Closed(2))
        ));
        h.join().unwrap();
    }

    #[test]
    fn unbounded_try_push_never_reports_full() {
        let c: Channel<i32> = Channel::new();
        for i in 0..10_000 {
            c.try_push(i).unwrap();
        }
        assert_eq!(c.len(), 10_000);
    }

    #[test]
    fn oneshot_roundtrip() {
        let o = Arc::new(OneShot::new());
        let o2 = Arc::clone(&o);
        std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(10));
            o2.put(42);
        });
        assert_eq!(o.take_timeout(Duration::from_secs(5)), Some(42));
    }
}
