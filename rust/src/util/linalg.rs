//! Tiny dense linear algebra: just enough for the cost-model calibration,
//! which solves a linear least-squares fit `min ||A x - b||` via the normal
//! equations with Gaussian elimination (the systems are 4x4–6x6, numerically
//! benign after column scaling).

/// Solve `M x = y` for square `M` (row-major, n x n) by Gaussian elimination
/// with partial pivoting. Returns `None` if the matrix is (numerically)
/// singular.
pub fn solve(mut m: Vec<Vec<f64>>, mut y: Vec<f64>) -> Option<Vec<f64>> {
    let n = y.len();
    assert!(m.len() == n && m.iter().all(|r| r.len() == n));
    for col in 0..n {
        // Pivot.
        let (piv, pv) = (col..n)
            .map(|r| (r, m[r][col].abs()))
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())?;
        if pv < 1e-12 {
            return None;
        }
        m.swap(col, piv);
        y.swap(col, piv);
        // Eliminate below.
        for r in col + 1..n {
            let f = m[r][col] / m[col][col];
            for c in col..n {
                m[r][c] -= f * m[col][c];
            }
            y[r] -= f * y[col];
        }
    }
    // Back-substitute.
    let mut x = vec![0.0; n];
    for r in (0..n).rev() {
        let mut s = y[r];
        for c in r + 1..n {
            s -= m[r][c] * x[c];
        }
        x[r] = s / m[r][r];
    }
    Some(x)
}

/// Linear least squares: minimize `||A x - b||_2` where `A` is m x n
/// (row-major rows), via normal equations `A^T A x = A^T b`.
pub fn lstsq(a: &[Vec<f64>], b: &[f64]) -> Option<Vec<f64>> {
    let m = a.len();
    assert_eq!(m, b.len());
    if m == 0 {
        return None;
    }
    let n = a[0].len();
    // Column scaling for conditioning: divide column j by its max |.|.
    let mut scale = vec![0.0f64; n];
    for row in a {
        for (j, v) in row.iter().enumerate() {
            scale[j] = scale[j].max(v.abs());
        }
    }
    for s in scale.iter_mut() {
        if *s < 1e-300 {
            *s = 1.0;
        }
    }
    let mut ata = vec![vec![0.0; n]; n];
    let mut atb = vec![0.0; n];
    for (row, &bi) in a.iter().zip(b) {
        for i in 0..n {
            let ri = row[i] / scale[i];
            for j in 0..n {
                ata[i][j] += ri * row[j] / scale[j];
            }
            atb[i] += ri * bi;
        }
    }
    let xs = solve(ata, atb)?;
    Some(xs.iter().zip(&scale).map(|(x, s)| x / s).collect())
}

/// Non-negative least squares by simple active-set projection: solve the
/// unconstrained problem, clamp negative coordinates to zero, re-solve on
/// the free set, and iterate. Good enough for the small, well-posed
/// calibration fits where the true solution is interior or near-boundary.
pub fn nnls(a: &[Vec<f64>], b: &[f64]) -> Option<Vec<f64>> {
    let n = a[0].len();
    // Columns with no support (all zeros) are pinned at 0 up front — they
    // would make the normal matrix singular (e.g. intra-node terms in a
    // one-rank-per-node configuration).
    let mut fixed: Vec<bool> = (0..n)
        .map(|j| a.iter().all(|row| row[j].abs() < 1e-300))
        .collect();
    loop {
        // Build the reduced problem over free columns.
        let free: Vec<usize> = (0..n).filter(|&j| !fixed[j]).collect();
        if free.is_empty() {
            return Some(vec![0.0; n]);
        }
        let ra: Vec<Vec<f64>> = a
            .iter()
            .map(|row| free.iter().map(|&j| row[j]).collect())
            .collect();
        let rx = lstsq(&ra, b)?;
        if let Some(worst) = rx
            .iter()
            .enumerate()
            .filter(|(_, &v)| v < -1e-12)
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        {
            fixed[free[worst.0]] = true;
            continue;
        }
        let mut x = vec![0.0; n];
        for (k, &j) in free.iter().enumerate() {
            x[j] = rx[k].max(0.0);
        }
        return Some(x);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn solve_2x2() {
        let m = vec![vec![2.0, 1.0], vec![1.0, 3.0]];
        let x = solve(m, vec![5.0, 10.0]).unwrap();
        assert!((x[0] - 1.0).abs() < 1e-12);
        assert!((x[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn solve_singular_is_none() {
        let m = vec![vec![1.0, 2.0], vec![2.0, 4.0]];
        assert!(solve(m, vec![1.0, 2.0]).is_none());
    }

    #[test]
    fn lstsq_exact() {
        // y = 2 + 3x fitted from exact points.
        let a: Vec<Vec<f64>> = (0..5).map(|i| vec![1.0, i as f64]).collect();
        let b: Vec<f64> = (0..5).map(|i| 2.0 + 3.0 * i as f64).collect();
        let x = lstsq(&a, &b).unwrap();
        assert!((x[0] - 2.0).abs() < 1e-9);
        assert!((x[1] - 3.0).abs() < 1e-9);
    }

    #[test]
    fn lstsq_overdetermined_noise() {
        // Least squares of a constant is the mean.
        let a: Vec<Vec<f64>> = (0..4).map(|_| vec![1.0]).collect();
        let b = vec![1.0, 2.0, 3.0, 4.0];
        let x = lstsq(&a, &b).unwrap();
        assert!((x[0] - 2.5).abs() < 1e-9);
    }

    #[test]
    fn nnls_clamps() {
        // Best unconstrained fit for column 2 would be negative; nnls
        // clamps it to zero and refits.
        let a = vec![
            vec![1.0, 1.0],
            vec![1.0, 2.0],
            vec![1.0, 3.0],
        ];
        let b = vec![3.0, 2.0, 1.0]; // slope -1
        let x = nnls(&a, &b).unwrap();
        assert!(x[1].abs() < 1e-12, "slope clamped to 0, got {:?}", x);
        assert!((x[0] - 2.0).abs() < 1e-9); // mean
    }
}
