//! A miniature property-testing harness (replacing `proptest` in this
//! offline build): run a property over many seeded-random cases; on
//! failure, greedily shrink the failing case and report the minimal seed
//! so the case is reproducible.
//!
//! ```no_run
//! use exscan::util::quickcheck::{forall, Gen};
//! forall(200, |g| {
//!     let p = g.usize_in(1, 64);
//!     let v: Vec<i64> = g.vec_i64(p);
//!     let doubled: Vec<i64> = v.iter().map(|x| x.wrapping_mul(2)).collect();
//!     assert_eq!(doubled.len(), v.len());
//! });
//! ```

use super::rng::Rng;

/// Case generator handed to properties.
pub struct Gen {
    rng: Rng,
    /// Size hint in [0,1]: early cases are small, later cases larger —
    /// cheap cases first, like proptest's sizing.
    pub size: f64,
    pub seed: u64,
}

impl Gen {
    fn new(seed: u64, size: f64) -> Self {
        Gen { rng: Rng::seed_from_u64(seed), size, seed }
    }

    /// Integer in [lo, hi] scaled by the current size hint: small cases
    /// stay near `lo`.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi >= lo);
        let span = hi - lo;
        let scaled = ((span as f64 * self.size).ceil() as usize).min(span);
        lo + if scaled == 0 { 0 } else { self.rng.gen_range_usize(scaled + 1) }
    }

    /// Unscaled uniform integer in [lo, hi].
    pub fn usize_uniform(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.gen_range_usize(hi - lo + 1)
    }

    pub fn i64(&mut self) -> i64 {
        self.rng.gen_i64()
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.gen_bool()
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.gen_range_f32(lo, hi)
    }

    pub fn vec_i64(&mut self, n: usize) -> Vec<i64> {
        (0..n).map(|_| self.i64()).collect()
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.gen_range_usize(xs.len())]
    }
}

/// Run `prop` over `cases` seeded cases; panics (with the failing seed)
/// on the first failure. Properties signal failure by panicking (assert!).
pub fn forall(cases: u64, prop: impl Fn(&mut Gen) + std::panic::RefUnwindSafe) {
    let base = base_seed();
    for i in 0..cases {
        let seed = base.wrapping_add(i);
        let size = (i + 1) as f64 / cases as f64;
        let result = std::panic::catch_unwind(|| {
            let mut g = Gen::new(seed, size);
            prop(&mut g);
        });
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property failed at case {i}/{cases} (seed {seed}, size {size:.2}): {msg}\n\
                 reproduce with EXSCAN_QC_SEED={seed} EXSCAN_QC_CASES=1"
            );
        }
    }
}

/// Base seed: fixed for reproducible CI, overridable for debugging.
fn base_seed() -> u64 {
    std::env::var("EXSCAN_QC_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xEC5C_A212)
}

/// Number of cases: default, or `EXSCAN_QC_CASES` override.
pub fn cases(default: u64) -> u64 {
    std::env::var("EXSCAN_QC_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        forall(50, |g| {
            let n = g.usize_in(0, 32);
            let v = g.vec_i64(n);
            assert_eq!(v.len(), n);
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_reports_seed() {
        forall(50, |g| {
            let n = g.usize_in(0, 100);
            assert!(n < 10, "n too big: {n}");
        });
    }

    #[test]
    fn sizes_grow() {
        // Early cases are small: with size 0.02 the range [0,1000] yields <= 20.
        let mut g = Gen::new(1, 0.02);
        for _ in 0..100 {
            assert!(g.usize_in(0, 1000) <= 20);
        }
    }
}
