//! Integer log/bit helpers used by the round-count formulas.

/// `ceil(log2(x))` for `x >= 1`. `ceil_log2(1) == 0`.
pub fn ceil_log2(x: usize) -> u32 {
    assert!(x >= 1, "ceil_log2 of 0");
    usize::BITS - (x - 1).leading_zeros()
}

/// `floor(log2(x))` for `x >= 1`.
pub fn floor_log2(x: usize) -> u32 {
    assert!(x >= 1, "floor_log2 of 0");
    usize::BITS - 1 - x.leading_zeros()
}

/// True iff `x` is a power of two (and nonzero).
pub fn is_pow2(x: usize) -> bool {
    x != 0 && x & (x - 1) == 0
}

/// `ceil(log2(p-1) + log2(4/3))` — the paper's round count `q` for the
/// 123-doubling algorithm (Theorem 1), computed exactly in integer
/// arithmetic: `q = min { q : 3 * 2^(q-2) >= p-1 }` for `p >= 3`,
/// with the degenerate small cases `p <= 2` handled explicitly.
///
/// Derivation: the doubling rounds use skips `s_0=1, s_1=2, s_k=3*2^(k-2)`;
/// rank `p-1` has received everything once `s_q' >= p-1` where `q'` is the
/// next skip after the last round, i.e. rounds `0..q-1` ran with
/// `s_{q-1} < p-1 <= s_q`... equivalently the smallest `q >= 2` with
/// `3 * 2^(q-2) >= p - 1`.
pub fn rounds_123(p: usize) -> u32 {
    assert!(p >= 1);
    match p {
        1 => 0,
        2 => 1,
        3 => 2,
        _ => {
            // smallest q >= 2 with 3 * 2^(q-2) >= p-1
            let mut q = 2u32;
            let mut skip = 3usize; // s_2 = 3*2^0
            while skip < p - 1 {
                skip *= 2;
                q += 1;
            }
            q
        }
    }
}

/// Round count of the 1-doubling exclusive scan: `1 + ceil(log2(p-1))`.
pub fn rounds_one_doubling(p: usize) -> u32 {
    match p {
        1 => 0,
        2 => 1,
        _ => 1 + ceil_log2(p - 1),
    }
}

/// Round count of the two-⊕ doubling exclusive scan: `ceil(log2 p)`.
pub fn rounds_two_op(p: usize) -> u32 {
    if p <= 1 {
        0
    } else {
        ceil_log2(p)
    }
}

/// Round count of the fully-fortified pow2-doubling exclusive scan:
/// `ceil(log2 p)` — the one-ported information lower bound. Every round
/// sends the *inclusive* partial `W ⊕ V`, so the trailing coverage after
/// round `k` is `2^(k+1) - 1` and rank `p-1` completes once
/// `2^q - 1 >= p - 1`.
pub fn rounds_pow2(p: usize) -> u32 {
    if p <= 1 {
        0
    } else {
        ceil_log2(p)
    }
}

/// Round count of the 1247-doubling exclusive scan: skips
/// `1, 2, 4, 7, 14, 28, …` (two fortified rounds instead of 123's one).
/// Coverage after round `k` is `c_0 = 1, c_1 = 3, c_2 = 7, c_k = 2·c_{k-1}`
/// (`= 7·2^(k-2)` for `k >= 2`), so
/// `q = ceil(log2(p-1) + log2(8/7)) = min { q : 7·2^(q-3) >= p-1 }` for
/// `p > 8`, between `rounds_pow2` and `rounds_123` for every p.
pub fn rounds_1247(p: usize) -> u32 {
    assert!(p >= 1);
    if p == 1 {
        return 0;
    }
    let mut q = 1u32;
    let mut coverage = 1usize; // after round 0 (the shift)
    while coverage < p - 1 {
        coverage = if q <= 2 { 2 * coverage + 1 } else { 2 * coverage };
        q += 1;
    }
    q
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_log2_small() {
        assert_eq!(ceil_log2(1), 0);
        assert_eq!(ceil_log2(2), 1);
        assert_eq!(ceil_log2(3), 2);
        assert_eq!(ceil_log2(4), 2);
        assert_eq!(ceil_log2(5), 3);
        assert_eq!(ceil_log2(36), 6);
        assert_eq!(ceil_log2(1152), 11);
    }

    #[test]
    fn floor_log2_small() {
        assert_eq!(floor_log2(1), 0);
        assert_eq!(floor_log2(2), 1);
        assert_eq!(floor_log2(3), 1);
        assert_eq!(floor_log2(4), 2);
        assert_eq!(floor_log2(1152), 10);
    }

    #[test]
    fn pow2() {
        assert!(is_pow2(1));
        assert!(is_pow2(64));
        assert!(!is_pow2(0));
        assert!(!is_pow2(36));
    }

    #[test]
    fn rounds_123_matches_formula() {
        // q = ceil(log2(p-1) + log2(4/3)) for p >= 2. The integer version is
        // ground truth; the float formula must agree up to boundary jitter:
        // raw <= q < raw + 1 (q is the ceiling of raw).
        for p in 2usize..=100_000 {
            let raw = ((p - 1) as f64).log2() + (4f64 / 3f64).log2();
            let q = rounds_123(p) as f64;
            assert!(q >= raw - 1e-9, "p={p} q={q} raw={raw}");
            assert!(q < raw + 1.0 + 1e-9, "p={p} q={q} raw={raw}");
        }
    }

    #[test]
    fn rounds_123_paper_values() {
        // p=36: ceil(log2 35 + log2 4/3) = ceil(5.129+0.415) = 6
        assert_eq!(rounds_123(36), 6);
        // p=1152: ceil(log2 1151 + 0.415) = ceil(10.168+0.415) = 11
        assert_eq!(rounds_123(1152), 11);
    }

    #[test]
    fn rounds_relationships() {
        for p in 3usize..=10_000 {
            // 123-doubling never takes more rounds than 1-doubling…
            assert!(rounds_123(p) <= rounds_one_doubling(p), "p={p}");
            // …and at most one more than the ceil(log2(p-1)) lower bound.
            assert!(rounds_123(p) <= ceil_log2(p - 1) + 1, "p={p}");
            assert!(rounds_123(p) >= ceil_log2(p - 1), "p={p}");
            // two-⊕ uses ceil(log2 p) rounds, never fewer than 123 minus one.
            assert!(rounds_two_op(p) + 1 >= rounds_123(p), "p={p}");
        }
    }

    #[test]
    fn rounds_1247_matches_formula() {
        // q = ceil(log2(p-1) + log2(8/7)) for p >= 2; the coverage loop is
        // ground truth and the float formula must agree up to the ceiling.
        for p in 2usize..=100_000 {
            let raw = ((p - 1) as f64).log2() + (8f64 / 7f64).log2();
            let q = rounds_1247(p) as f64;
            assert!(q >= raw - 1e-9, "p={p} q={q} raw={raw}");
            assert!(q < raw + 1.0 + 1e-9, "p={p} q={q} raw={raw}");
        }
    }

    #[test]
    fn rounds_1247_small_values() {
        assert_eq!(rounds_1247(1), 0);
        assert_eq!(rounds_1247(2), 1);
        assert_eq!(rounds_1247(3), 2);
        assert_eq!(rounds_1247(4), 2);
        assert_eq!(rounds_1247(5), 3);
        assert_eq!(rounds_1247(8), 3);
        assert_eq!(rounds_1247(9), 4);
        assert_eq!(rounds_1247(29), 5); // one fewer than rounds_123(29) = 6
        assert_eq!(rounds_1247(36), 6);
    }

    #[test]
    fn fortification_ladder() {
        // More fortified rounds buy fewer (never more) total rounds:
        // pow2 (every round fortified) <= 1247 (two) <= 123 (one), and
        // pow2 sits exactly on the one-ported information lower bound.
        for p in 2usize..=10_000 {
            assert!(rounds_pow2(p) <= rounds_1247(p), "p={p}");
            assert!(rounds_1247(p) <= rounds_123(p), "p={p}");
            assert_eq!(rounds_pow2(p), ceil_log2(p), "p={p}");
        }
        // And the gap is real: at p = 256 pow2 saves a round over 123,
        // at p = 29 even 1247 does.
        assert_eq!(rounds_pow2(256), 8);
        assert_eq!(rounds_123(256), 9);
        assert!(rounds_1247(29) < rounds_123(29));
    }
}
