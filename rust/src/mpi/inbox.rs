//! Slot-based rendezvous matching: the receive half of the transport
//! (EXPERIMENTS.md §Perf).
//!
//! The old transport funnelled every message for a rank through one
//! Mutex+Condvar MPMC queue and matched (src, round) with a linear scan
//! over an out-of-order `pending` vector — all senders contended on one
//! lock, and every mismatched pop paid O(pending).
//!
//! Scan schedules are fully deterministic: at any instant a rank has a
//! handful of in-flight messages, each uniquely keyed by (src, tag) —
//! where the tag is a packed [`TagKey`](super::comm::TagKey) carrying
//! (ctx, chunk, round), so concurrent collectives on distinct
//! communicators key distinctly even at equal round indices. The inbox
//! hashes (src, tag) into a small slot array (each slot padded to its own
//! 128 B cache line, so a sender raising one slot's flag never invalidates
//! the line a receiver is probing for a different slot):
//!
//! * **deposit** (sender side): take the slot's own lock (uncontended —
//!   only this sender and the receiver ever touch it), place the message,
//!   raise the slot's atomic flag. If the slot is occupied by a different
//!   in-flight message, fall back to the `overflow` queue — the unordered
//!   path, kept for correctness under arbitrary traffic.
//! * **match** (receiver side): check the local `pending` buffer, then
//!   spin on the *expected* slot's flag (a single atomic load per probe),
//!   draining `overflow` between probes; park on the inbox condvar when
//!   the spin budget runs out.
//!
//! ## Adaptive spin budget
//!
//! The spin budget used to be a fixed 100 probes. It is now driven by a
//! **per-slot EMA of the observed rendezvous wait** (in probe iterations,
//! receiver-written only, relaxed): slots whose partner historically
//! arrives within the spin window earn a budget proportional to the
//! observed wait; slots whose waits historically overflow into parks are
//! demoted to a short probe burst, so the receiver pays the park early
//! instead of burning a core. A park feeds back as a capped large wait;
//! recovery from demotion is guaranteed by a periodic full-budget
//! measurement burst (every [`DEMOTED_REPROBE_PERIOD`]th receive) that
//! observes the true wait, so a phase change in either direction
//! re-converges geometrically (decay 7/8 per match). Hosts with ≤ 2
//! cores never spin — the
//! `available_parallelism` probe is taken once per process and cached in
//! a `OnceLock` ([`spin_allowed`]), never re-queried inside a receive
//! loop. `WorldConfig::with_fixed_spin(true)` restores the fixed budget
//! as the A/B reference for the hotpath latency sweep.
//!
//! ## Memory ordering (the Dekker-with-backstop proof sketch)
//!
//! All four atomics here (`Slot::full`, `overflow_len`, `delayed_len`,
//! `parked`) were SeqCst; they are now Release/Acquire/Relaxed. The
//! downgrade is sound because **no safety property depends on the
//! atomics**:
//!
//! 1. *Message transfer is mutex-protected.* A message is only ever read
//!    out of `Slot::cell` / `overflow` / `delayed` under that queue's
//!    lock, and any probe that takes the lock after the depositing
//!    sender's unlock observes the message (mutex acquire/release
//!    ordering). The atomics are pure *liveness hints* that let the hot
//!    path skip the lock — a stale hint can only delay a match, never
//!    corrupt or duplicate one.
//! 2. *The park handshake is lock-ordered.* The receiver sets `parked`,
//!    re-probes, and enters `Condvar::wait` all under `park_lock`; a
//!    sender whose `wake()` sees `parked == true` takes `park_lock`
//!    before notifying. So the notify either happens while the receiver
//!    waits (delivered) or before the receiver's final re-probe (the
//!    re-probe, lock-ordered after the deposit, finds the message).
//! 3. *The one remaining race is bounded, not unsafe.* Without SeqCst,
//!    the classic Dekker store→load pair (sender: store `full`, load
//!    `parked`; receiver: store `parked`, re-probe `full`) can in theory
//!    both read stale values — the sender skips the notify *and* the
//!    receiver misses the deposit. The receiver then sleeps at most one
//!    `PARK_SLICE` (10 ms) and re-probes; by then the mutex guarantees
//!    visibility. Safety is unconditional; liveness degrades from
//!    "immediate" to "≤ one slice" in a window that requires a deposit
//!    racing the park transition exactly. The previous SeqCst version
//!    already documented (and sliced its parks against) this lost-wakeup
//!    shape; the downgrade makes the backstop load-bearing in exchange
//!    for removing full fences from every deposit and probe.
//!    Chaos-verified: the 3-seed CI fuzz grid replays bit-identical
//!    `ChaosReport` digests, outputs and traces across this change
//!    (`tests/chaos_sweep.rs`, `tests/kernel_equivalence.rs`).
//!
//! The matched message's pooled buffer is consumed in place by the fused
//! `RankCtx::{recv_reduce, sendrecv_reduce}` primitives — the `⊕` combine
//! reads straight out of the slot's buffer and the buffer recycles to the
//! sender's pool before the receive call returns, so a matched message
//! never costs an extra memory pass after leaving the slot.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use super::msg::Msg;

/// Slot count per inbox. Must be a power of two. 64 slots cover every
/// deterministic scan schedule with near-zero collisions (a rank has at
/// most ~⌈log₂ p⌉ + 2 messages in flight, each with a distinct round tag);
/// collisions are correctness-neutral (overflow path).
const NSLOTS: usize = 64;

/// Upper bound on one condvar park. A correctly delivered wakeup arrives
/// immediately; the slice bounds the damage of the lost-wakeup race the
/// Acquire/Release handshake tolerates (see the module docs: with relaxed
/// `parked` hints the backstop is load-bearing, not merely theoretical).
const PARK_SLICE: Duration = Duration::from_millis(10);

/// Fixed spin budget (probes) — the pre-adaptive policy, kept behind
/// `WorldConfig::with_fixed_spin(true)` as the latency-sweep baseline.
const FIXED_SPIN_TRIES: u32 = 100;

/// Initial per-slot wait EMA (probe iterations): start where the fixed
/// policy spun, adapt from there.
const EMA_INIT: u32 = 100;

/// Cap on one recorded wait observation. Every park contributes the cap,
/// so repeated parking walks the EMA above [`PARK_EMA_CUTOFF`] within a
/// few matches (geometric approach to the cap).
const WAIT_CAP: u32 = 2048;

/// EMA at or above this demotes the slot to the short probe burst: the
/// partner historically does not arrive within any reasonable spin
/// window, so park early and cheaply.
const PARK_EMA_CUTOFF: u32 = 900;

/// Probe burst kept even for park-biased slots (immediate arrivals
/// record w = 0 through it, pulling the EMA back down).
const MIN_PROBE_BURST: u32 = 32;

/// Every Nth receive on a *demoted* slot runs a full-budget measurement
/// burst instead of the short one. Necessary for recovery: a demoted
/// slot whose partner now lands within the spin window but *after* the
/// short burst would otherwise park every time and record the cap —
/// the demotion would be sticky. The periodic burst observes the true
/// wait, so the EMA decays back under the cutoff geometrically (~7
/// bursts for a wait of ~100 probes), at a bounded cost of one long
/// burst per [`DEMOTED_REPROBE_PERIOD`] receives while genuinely slow.
const DEMOTED_REPROBE_PERIOD: u32 = 16;

/// Ceiling of the adaptive budget.
const SPIN_BUDGET_MAX: u32 = 1024;

/// Whether spinning can pay off at all: only when the rendezvous partner
/// can run in parallel, so single-core (and dual-core, where the partner
/// fights the receiver for the second core) hosts park immediately. The
/// `available_parallelism` probe is cached in a `OnceLock` — one OS query
/// per process, never inside a receive loop.
fn spin_allowed() -> bool {
    static ALLOWED: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
    *ALLOWED.get_or_init(|| {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1) > 2
    })
}

/// Receiver-side wait counters (test/bench observability; see the hotpath
/// latency sweep). Monotonic over the inbox's lifetime.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct InboxStats {
    /// Spin probes executed across all receives.
    pub spins: u64,
    /// Condvar parks entered across all receives.
    pub parks: u64,
}

impl InboxStats {
    pub fn merge(&mut self, other: &InboxStats) {
        self.spins += other.spins;
        self.parks += other.parks;
    }
}

/// One rendezvous slot, padded to a 128 B cache line (two-line prefetch
/// granularity on x86, native line on Apple ARM) so neighbouring slots
/// never false-share under concurrent senders.
#[repr(align(128))]
struct Slot<T> {
    /// Raised (Release) after a message is placed; the receiver's cheap
    /// probe (Acquire). A liveness hint only — the message itself is
    /// transferred under `cell`'s lock (see the module-level proof
    /// sketch).
    full: AtomicBool,
    /// EMA of the receiver's observed wait on this slot, in probe
    /// iterations (capped at [`WAIT_CAP`]). Written only by the owning
    /// receiver, read only by it — Relaxed.
    wait_ema: AtomicU32,
    /// Receives served on this slot while demoted (drives the periodic
    /// [`DEMOTED_REPROBE_PERIOD`] measurement burst). Receiver-only,
    /// Relaxed.
    demoted_recvs: AtomicU32,
    cell: Mutex<Option<Msg<T>>>,
}

/// One rank's inbox. Senders call [`deposit`](Inbox::deposit); only the
/// owning rank calls [`recv_match`](Inbox::recv_match).
pub(crate) struct Inbox<T> {
    slots: Vec<Slot<T>>,
    /// Fixed (pre-adaptive) spin budget instead of the per-slot EMA —
    /// the latency-sweep A/B baseline.
    fixed_spin: bool,
    overflow: Mutex<VecDeque<Msg<T>>>,
    /// Lock-free emptiness hint for the overflow queue (Relaxed: a stale
    /// zero only delays the match until the next probe or park slice).
    overflow_len: AtomicUsize,
    /// Messages under chaos embargo: matchable only once their release
    /// instant passes (see [`super::chaos`]). Empty (and never locked on
    /// the probe path) when chaos is off.
    delayed: Mutex<Vec<(Instant, Msg<T>)>>,
    /// Lock-free emptiness hint for the embargo queue.
    delayed_len: AtomicUsize,
    /// Bumped by [`poison`](Self::poison) when a rank dies in this world:
    /// a receiver whose blocking receive observes the epoch change bails
    /// out early (returning `None` before its deadline) so its caller can
    /// attribute the failure to the dead rank instead of waiting out a
    /// full timeout that can never be satisfied.
    poison_epoch: AtomicU64,
    /// Receiver-is-parked hint (Dekker partner of `Slot::full`; Relaxed —
    /// see the module docs for why the park slice bounds the race).
    parked: AtomicBool,
    park_lock: Mutex<()>,
    park_cv: Condvar,
    /// Receiver-written wait counters (Relaxed).
    stat_spins: AtomicU64,
    stat_parks: AtomicU64,
}

fn slot_index(src: usize, tag: u64) -> usize {
    let h = (src as u64)
        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
        .wrapping_add(tag.wrapping_mul(0xC2B2_AE3D_27D4_EB4F));
    ((h >> 32) as usize) & (NSLOTS - 1)
}

impl<T> Default for Inbox<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> Inbox<T> {
    /// Adaptive-spin inbox (the default policy).
    pub fn new() -> Self {
        Self::new_with(false)
    }

    /// `fixed_spin = true` restores the fixed 100-probe budget (the
    /// pre-adaptive policy) for A/B latency measurement.
    pub fn new_with(fixed_spin: bool) -> Self {
        Inbox {
            slots: (0..NSLOTS)
                .map(|_| Slot {
                    full: AtomicBool::new(false),
                    wait_ema: AtomicU32::new(EMA_INIT),
                    demoted_recvs: AtomicU32::new(0),
                    cell: Mutex::new(None),
                })
                .collect(),
            fixed_spin,
            overflow: Mutex::new(VecDeque::new()),
            overflow_len: AtomicUsize::new(0),
            delayed: Mutex::new(Vec::new()),
            delayed_len: AtomicUsize::new(0),
            poison_epoch: AtomicU64::new(0),
            parked: AtomicBool::new(false),
            park_lock: Mutex::new(()),
            park_cv: Condvar::new(),
            stat_spins: AtomicU64::new(0),
            stat_parks: AtomicU64::new(0),
        }
    }

    /// Receiver-side wait counters since construction.
    pub fn stats(&self) -> InboxStats {
        InboxStats {
            spins: self.stat_spins.load(Ordering::Relaxed),
            parks: self.stat_parks.load(Ordering::Relaxed),
        }
    }

    /// Sender side: place `msg` for the owning rank to match.
    pub fn deposit(&self, msg: Msg<T>) {
        let slot = &self.slots[slot_index(msg.src, msg.tag)];
        let overflowed = {
            let mut cell = slot.cell.lock().unwrap();
            if cell.is_none() {
                *cell = Some(msg);
                slot.full.store(true, Ordering::Release);
                None
            } else {
                Some(msg) // collision with a different in-flight message
            }
        };
        if let Some(msg) = overflowed {
            self.overflow.lock().unwrap().push_back(msg);
            self.overflow_len.fetch_add(1, Ordering::Relaxed);
        }
        self.wake();
    }

    /// Chaos hook: hold `msg` under embargo until `release_at`, then make
    /// it matchable through the normal slot/overflow path. The receiver
    /// releases due messages itself inside [`recv_match`](Self::recv_match)
    /// (its parks are sliced, so an embargo adds bounded latency and can
    /// never deadlock).
    pub fn deposit_delayed(&self, msg: Msg<T>, release_at: Instant) {
        if release_at <= Instant::now() {
            self.deposit(msg);
            return;
        }
        {
            // The length mirror is only ever written under the `delayed`
            // lock (here and in `release_due`), so it can never drift.
            let mut held = self.delayed.lock().unwrap();
            held.push((release_at, msg));
            self.delayed_len.store(held.len(), Ordering::Relaxed);
        }
        self.wake(); // receiver re-probes and re-slices its park deadline
    }

    /// Chaos hook: route `msg` straight to the unordered overflow queue,
    /// as if its slot had collided — exercises the overflow and pending
    /// paths on schedules that would otherwise never touch them.
    pub fn deposit_overflow(&self, msg: Msg<T>) {
        self.overflow.lock().unwrap().push_back(msg);
        self.overflow_len.fetch_add(1, Ordering::Relaxed);
        self.wake();
    }

    /// Move every embargoed message whose release instant has passed into
    /// the normal matching path. Cheap when the embargo queue is empty
    /// (one atomic load).
    fn release_due(&self) {
        if self.delayed_len.load(Ordering::Relaxed) == 0 {
            return;
        }
        let now = Instant::now();
        let due = {
            let mut held = self.delayed.lock().unwrap();
            let mut due = Vec::new();
            let mut i = 0;
            while i < held.len() {
                if held[i].0 <= now {
                    due.push(held.swap_remove(i).1);
                } else {
                    i += 1;
                }
            }
            self.delayed_len.store(held.len(), Ordering::Relaxed);
            due
        };
        for msg in due {
            self.deposit(msg);
        }
    }

    /// Earliest release instant of any still-embargoed message. Probed
    /// under the park lock so a just-arrived embargo can never be slept
    /// past (its `wake()` may have fired before `parked` was raised).
    fn next_release_hint(&self) -> Option<Instant> {
        if self.delayed_len.load(Ordering::Relaxed) == 0 {
            return None;
        }
        self.delayed.lock().unwrap().iter().map(|(t, _)| *t).min()
    }

    /// Wake a parked receiver, if any. Fast path: **one relaxed load, no
    /// lock** — a sender depositing into an inbox whose receiver is busy
    /// (the overwhelming steady-state case) pays nothing here. Only when
    /// the hint reads `true` does the sender take `park_lock` so the
    /// notify cannot slip between the receiver's final re-probe and its
    /// wait. A stale `false` (the receiver parking concurrently) is the
    /// bounded Dekker race analysed in the module docs: the receiver's
    /// sliced park re-probes within `PARK_SLICE`.
    fn wake(&self) {
        if !self.parked.load(Ordering::Relaxed) {
            return;
        }
        let _g = self.park_lock.lock().unwrap();
        self.park_cv.notify_all();
    }

    /// Rank-death hook: force any in-flight (and every future) blocking
    /// receive on this inbox to return early. The epoch bump is observed
    /// by [`recv_match`](Self::recv_match)'s loop and the `wake()` kicks
    /// a parked receiver out of its condvar slice immediately.
    pub fn poison(&self) {
        self.poison_epoch.fetch_add(1, Ordering::Release);
        self.wake();
    }

    /// Take whatever message occupies `slot` — the caller checks the
    /// match and buffers strangers (slot collisions) itself.
    fn take_slot(slot: &Slot<T>) -> Option<Msg<T>> {
        if !slot.full.load(Ordering::Acquire) {
            return None;
        }
        let mut cell = slot.cell.lock().unwrap();
        let msg = cell.take();
        if msg.is_some() {
            // Receiver-only write, ordered by the cell mutex against the
            // next depositor's check.
            slot.full.store(false, Ordering::Relaxed);
        }
        msg
    }

    /// Pop one message from the unordered overflow queue.
    fn try_overflow(&self) -> Option<Msg<T>> {
        if self.overflow_len.load(Ordering::Relaxed) == 0 {
            return None;
        }
        let msg = self.overflow.lock().unwrap().pop_front();
        if msg.is_some() {
            self.overflow_len.fetch_sub(1, Ordering::Relaxed);
        }
        msg
    }

    /// The spin budget for one receive on `slot`, resolved at entry:
    /// fixed policy, or the per-slot EMA-derived budget (see the module
    /// docs).
    fn spin_budget(&self, slot: &Slot<T>) -> u32 {
        if !spin_allowed() {
            return 0;
        }
        if self.fixed_spin {
            return FIXED_SPIN_TRIES;
        }
        let ema = slot.wait_ema.load(Ordering::Relaxed);
        if ema >= PARK_EMA_CUTOFF {
            // Demoted: park early — but re-measure with a full burst every
            // Nth receive so recovery is possible (see the constant docs).
            let n = slot.demoted_recvs.fetch_add(1, Ordering::Relaxed);
            if n % DEMOTED_REPROBE_PERIOD == 0 {
                SPIN_BUDGET_MAX
            } else {
                MIN_PROBE_BURST
            }
        } else {
            (2 * ema + 16).min(SPIN_BUDGET_MAX)
        }
    }

    /// Feed one observed wait (probe iterations, capped) into the slot's
    /// EMA: `ema ← (7·ema + w) / 8`. Receiver-only, Relaxed.
    fn record_wait(slot: &Slot<T>, waited: u32) {
        let w = waited.min(WAIT_CAP) as u64;
        let old = slot.wait_ema.load(Ordering::Relaxed) as u64;
        slot.wait_ema.store(((old * 7 + w) / 8) as u32, Ordering::Relaxed);
    }

    /// Receiver side: block until the message from `src` tagged `tag`
    /// arrives, buffering strangers into `pending`. Returns `None` on
    /// deadline expiry **or** when the inbox is poisoned mid-receive
    /// (rank death elsewhere in the world) — the caller distinguishes the
    /// two by consulting the world's dead-rank registry and reports an
    /// attributed failure or a deadlock accordingly.
    ///
    /// `pending` is the rank-local out-of-order buffer: messages that
    /// collided in the slot array or arrived through overflow for a later
    /// receive. The caller checks it *before* calling (it is rank-private).
    pub fn recv_match(
        &self,
        src: usize,
        tag: u64,
        pending: &mut Vec<Msg<T>>,
        deadline: Instant,
    ) -> Option<Msg<T>> {
        // Poison is edge-triggered against the epoch at entry: a world
        // whose rank died *before* this call is the caller's problem (it
        // checks the dead-rank registry first); this detects deaths that
        // happen while we block.
        let entry_epoch = self.poison_epoch.load(Ordering::Acquire);
        // Hoist the expected slot and its budget out of the probe loop:
        // one hash, one EMA read per receive — not per probe.
        let slot = &self.slots[slot_index(src, tag)];
        let budget = self.spin_budget(slot);
        let mut waited = 0u32; // probes + park penalties — the EMA's input
        let mut probes = 0u32; // real spin probes only — the stats' input
        let mut spins = 0u32; // probes since the last park
        // Stat flush is deferred to the exit paths: one atomic add per
        // receive, not one per probe (the probe loop is the hot path).
        let flush = |probes: u32| {
            if probes > 0 {
                self.stat_spins.fetch_add(probes as u64, Ordering::Relaxed);
            }
        };
        loop {
            // 0. Bail out on rank death (single relaxed-cost atomic when
            // healthy) and release any chaos-embargoed messages that are
            // now due (no-op single atomic probe when chaos is off).
            if self.poison_epoch.load(Ordering::Acquire) != entry_epoch {
                flush(probes);
                return None;
            }
            self.release_due();
            // 1. The expected slot (single atomic probe on the fast path).
            if let Some(msg) = Self::take_slot(slot) {
                if msg.src == src && msg.tag == tag {
                    Self::record_wait(slot, waited);
                    flush(probes);
                    return Some(msg);
                }
                pending.push(msg);
                continue; // the wanted message may be right behind it
            }
            // 2. The unordered overflow path.
            if let Some(msg) = self.try_overflow() {
                if msg.src == src && msg.tag == tag {
                    flush(probes);
                    return Some(msg);
                }
                pending.push(msg);
                continue;
            }
            // 3. Spin a little, then park until a deposit (or time slice).
            if spins < budget {
                spins += 1;
                probes += 1;
                waited = waited.saturating_add(1);
                std::hint::spin_loop();
                continue;
            }
            spins = 0;
            let now = Instant::now();
            if now >= deadline {
                flush(probes);
                return None;
            }
            let mut wait = PARK_SLICE.min(deadline - now);
            let guard = self.park_lock.lock().unwrap();
            self.parked.store(true, Ordering::Relaxed);
            // Final re-check under the park lock: a deposit that happened
            // before we raised `parked` is caught here; one that happens
            // after will see `parked` and take the lock to notify. (The
            // store→load race both directions can lose is bounded by the
            // sliced wait below — module docs.)
            if let Some(m) = Self::take_slot(slot) {
                self.parked.store(false, Ordering::Relaxed);
                drop(guard);
                if m.src == src && m.tag == tag {
                    Self::record_wait(slot, waited);
                    flush(probes);
                    return Some(m);
                }
                pending.push(m);
                continue;
            }
            if self.overflow_len.load(Ordering::Relaxed) != 0 {
                self.parked.store(false, Ordering::Relaxed);
                drop(guard);
                continue;
            }
            // Cap the park at the earliest embargo release, probed *under
            // the park lock* so a delayed deposit landing at any point
            // before `parked = true` (whose wake() no-opped) can never be
            // slept past for a full slice — regardless of whether an
            // older, later-releasing embargo was already pending.
            if let Some(release_at) = self.next_release_hint() {
                let now = Instant::now();
                if release_at <= now {
                    self.parked.store(false, Ordering::Relaxed);
                    drop(guard);
                    continue;
                }
                wait = wait.min((release_at - now).max(Duration::from_micros(50)));
            }
            self.stat_parks.fetch_add(1, Ordering::Relaxed);
            let (_guard, _res) = self.park_cv.wait_timeout(guard, wait).unwrap();
            self.parked.store(false, Ordering::Relaxed);
            // A park means the partner was far outside the spin window:
            // feed the cap so the EMA demotes this slot toward parking
            // early next time.
            waited = waited.saturating_add(WAIT_CAP);
        }
    }

    /// Messages currently buffered inside the inbox (slots + overflow).
    /// Test/debug hook — not used on the hot path.
    #[allow(dead_code)] // crate-internal diagnostics; exercised in tests
    pub fn occupancy(&self) -> usize {
        let in_slots =
            self.slots.iter().filter(|s| s.full.load(Ordering::Acquire)).count();
        in_slots
            + self.overflow_len.load(Ordering::Relaxed)
            + self.delayed_len.load(Ordering::Relaxed)
    }

    /// Test hook: the wait EMA of the slot keyed by (src, tag).
    #[cfg(test)]
    fn ema_of(&self, src: usize, tag: u64) -> u32 {
        self.slots[slot_index(src, tag)].wait_ema.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpi::pool::PoolBuf;
    use std::sync::Arc;

    fn msg(src: usize, tag: u64, v: i64) -> Msg<i64> {
        Msg { src, tag, data: PoolBuf::detached(vec![v]), vtime: 0.0 }
    }

    fn deadline() -> Instant {
        Instant::now() + Duration::from_secs(5)
    }

    /// The caller-side matching discipline `RankCtx::take` uses: check the
    /// rank-local pending buffer first, then block on the inbox.
    fn take(inbox: &Inbox<i64>, pending: &mut Vec<Msg<i64>>, src: usize, tag: u64) -> Msg<i64> {
        if let Some(i) = pending.iter().position(|m| m.src == src && m.tag == tag) {
            return pending.swap_remove(i);
        }
        inbox.recv_match(src, tag, pending, deadline()).expect("timed out")
    }

    #[test]
    fn same_key_matches_through_slot() {
        let inbox: Inbox<i64> = Inbox::new();
        inbox.deposit(msg(3, 7, 42));
        let mut pending = Vec::new();
        let got = inbox.recv_match(3, 7, &mut pending, deadline()).unwrap();
        assert_eq!(got.src, 3);
        assert_eq!(got.tag, 7);
        assert_eq!(got.data[0], 42);
        assert!(pending.is_empty());
        assert_eq!(inbox.occupancy(), 0);
    }

    #[test]
    fn stranger_lands_in_pending() {
        let inbox: Inbox<i64> = Inbox::new();
        // Two messages; receive the second one first. Wherever the first
        // lands (slot or overflow), it must surface into `pending`.
        inbox.deposit(msg(0, 1, 10));
        inbox.deposit(msg(0, 2, 20));
        let mut pending = Vec::new();
        let got = inbox.recv_match(0, 2, &mut pending, deadline()).unwrap();
        assert_eq!(got.data[0], 20);
        // The round-1 message is either in pending already or still boxed.
        let leftover = pending.len() + inbox.occupancy();
        assert_eq!(leftover, 1);
    }

    #[test]
    fn collision_overflows_and_still_matches() {
        let inbox: Inbox<i64> = Inbox::new();
        // Find two keys that collide in the slot array.
        let (s1, t1) = (0usize, 0u64);
        let mut other = None;
        'outer: for src in 0..NSLOTS * 4 {
            for tag in 0..(NSLOTS as u64 * 4) {
                if (src, tag) != (s1, t1) && slot_index(src, tag) == slot_index(s1, t1) {
                    other = Some((src, tag));
                    break 'outer;
                }
            }
        }
        let (s2, t2) = other.expect("hash must collide somewhere");
        inbox.deposit(msg(s1, t1, 1)); // takes the slot
        inbox.deposit(msg(s2, t2, 2)); // collides → overflow
        let mut pending = Vec::new();
        let got2 = take(&inbox, &mut pending, s2, t2);
        assert_eq!(got2.data[0], 2);
        let got1 = take(&inbox, &mut pending, s1, t1);
        assert_eq!(got1.data[0], 1);
        assert!(pending.is_empty());
        assert_eq!(inbox.occupancy(), 0);
    }

    #[test]
    fn deadline_expires_to_none() {
        let inbox: Inbox<i64> = Inbox::new();
        let mut pending = Vec::new();
        let t0 = Instant::now();
        let got =
            inbox.recv_match(0, 0, &mut pending, Instant::now() + Duration::from_millis(50));
        assert!(got.is_none());
        assert!(t0.elapsed() >= Duration::from_millis(45));
        assert!(t0.elapsed() < Duration::from_secs(5));
    }

    #[test]
    fn cross_thread_wakeup() {
        let inbox: Arc<Inbox<i64>> = Arc::new(Inbox::new());
        let tx = Arc::clone(&inbox);
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30)); // let receiver park
            tx.deposit(msg(1, 9, 99));
        });
        let mut pending = Vec::new();
        let got = inbox.recv_match(1, 9, &mut pending, deadline()).unwrap();
        assert_eq!(got.data[0], 99);
        h.join().unwrap();
    }

    #[test]
    fn delayed_deposit_matches_after_embargo() {
        let inbox: Inbox<i64> = Inbox::new();
        let t0 = Instant::now();
        inbox.deposit_delayed(msg(2, 4, 77), Instant::now() + Duration::from_millis(20));
        assert_eq!(inbox.occupancy(), 1, "embargoed message must be counted");
        let mut pending = Vec::new();
        let got = inbox.recv_match(2, 4, &mut pending, deadline()).unwrap();
        assert_eq!(got.data[0], 77);
        assert!(t0.elapsed() >= Duration::from_millis(15), "embargo must hold");
        assert_eq!(inbox.occupancy(), 0);
    }

    #[test]
    fn delayed_deposit_in_the_past_is_immediate() {
        let inbox: Inbox<i64> = Inbox::new();
        inbox.deposit_delayed(msg(0, 1, 5), Instant::now());
        let mut pending = Vec::new();
        let got = inbox.recv_match(0, 1, &mut pending, deadline()).unwrap();
        assert_eq!(got.data[0], 5);
    }

    #[test]
    fn diverted_deposit_matches_through_overflow() {
        let inbox: Inbox<i64> = Inbox::new();
        inbox.deposit_overflow(msg(3, 9, 33));
        assert_eq!(inbox.occupancy(), 1);
        let mut pending = Vec::new();
        let got = inbox.recv_match(3, 9, &mut pending, deadline()).unwrap();
        assert_eq!(got.data[0], 33);
        assert!(pending.is_empty());
    }

    #[test]
    fn embargo_reorders_across_keys() {
        // Deposit round 0 under a long embargo, round 1 immediately: the
        // round-1 message becomes matchable *before* the round-0 one even
        // though it was deposited after — the adversarial delivery
        // reordering the chaos layer is built to produce. Matching round 0
        // first must block until release, then both match cleanly.
        let inbox: Inbox<i64> = Inbox::new();
        inbox.deposit_delayed(msg(0, 0, 10), Instant::now() + Duration::from_millis(15));
        inbox.deposit(msg(0, 1, 11));
        let mut pending = Vec::new();
        let got0 = inbox.recv_match(0, 0, &mut pending, deadline()).unwrap();
        assert_eq!(got0.data[0], 10);
        let got1 = take(&inbox, &mut pending, 0, 1);
        assert_eq!(got1.data[0], 11);
        assert!(pending.is_empty());
        assert_eq!(inbox.occupancy(), 0);
    }

    #[test]
    fn hammer_many_tags_out_of_order() {
        let inbox: Arc<Inbox<i64>> = Arc::new(Inbox::new());
        let tx = Arc::clone(&inbox);
        const K: u64 = 500;
        let h = std::thread::spawn(move || {
            for tag in 0..K {
                tx.deposit(msg(0, tag, tag as i64));
            }
        });
        let mut pending = Vec::new();
        // Receive even tags descending, then odd tags ascending — maximal
        // out-of-order pressure on slots, overflow and pending.
        for tag in (0..K).rev().filter(|t| t % 2 == 0) {
            let got = take(&inbox, &mut pending, 0, tag);
            assert_eq!(got.data[0], tag as i64);
        }
        for tag in (0..K).filter(|t| t % 2 == 1) {
            let got = take(&inbox, &mut pending, 0, tag);
            assert_eq!(got.data[0], tag as i64);
        }
        assert!(pending.is_empty());
        assert_eq!(inbox.occupancy(), 0);
        h.join().unwrap();
    }

    #[test]
    fn slots_are_cache_line_padded() {
        assert!(std::mem::align_of::<Slot<i64>>() >= 128);
        assert_eq!(std::mem::size_of::<Slot<i64>>() % 128, 0);
    }

    #[test]
    fn ema_converges_down_on_immediate_matches() {
        // Message already present on every receive → observed wait 0 →
        // the EMA decays geometrically from its initial 100.
        let inbox: Inbox<i64> = Inbox::new();
        let mut pending = Vec::new();
        assert_eq!(inbox.ema_of(5, 5), EMA_INIT);
        for _ in 0..64 {
            inbox.deposit(msg(5, 5, 1));
            let got = inbox.recv_match(5, 5, &mut pending, deadline()).unwrap();
            assert_eq!(got.data[0], 1);
        }
        assert!(
            inbox.ema_of(5, 5) < EMA_INIT / 4,
            "EMA must decay on immediate matches: {}",
            inbox.ema_of(5, 5)
        );
    }

    #[test]
    fn ema_rises_after_parks_and_recovers() {
        // A parked wait feeds the cap into the EMA (demoting the slot to
        // the short probe burst); a subsequent run of immediate matches
        // pulls it back down — the regime-change recovery path.
        let inbox: Arc<Inbox<i64>> = Arc::new(Inbox::new());
        let tx = Arc::clone(&inbox);
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(25));
            tx.deposit(msg(2, 8, 7));
        });
        let mut pending = Vec::new();
        let got = inbox.recv_match(2, 8, &mut pending, deadline()).unwrap();
        assert_eq!(got.data[0], 7);
        h.join().unwrap();
        let after_park = inbox.ema_of(2, 8);
        assert!(after_park > EMA_INIT, "a park must raise the EMA: {after_park}");
        assert!(inbox.stats().parks >= 1);
        // The spin counter reports *real* probes only — the EMA's
        // per-park penalty (WAIT_CAP) must not leak into the stats.
        assert!(
            inbox.stats().spins < 2 * WAIT_CAP as u64,
            "spin stats inflated by park penalties: {:?}",
            inbox.stats()
        );
        for _ in 0..200 {
            inbox.deposit(msg(2, 8, 7));
            inbox.recv_match(2, 8, &mut pending, deadline()).unwrap();
        }
        assert!(
            inbox.ema_of(2, 8) < PARK_EMA_CUTOFF,
            "EMA must recover once arrivals become immediate: {}",
            inbox.ema_of(2, 8)
        );
    }

    #[test]
    fn demoted_slot_gets_periodic_measurement_bursts() {
        if !spin_allowed() {
            return; // budgets are always 0 on <= 2-core hosts
        }
        let inbox: Inbox<i64> = Inbox::new();
        let slot = &inbox.slots[slot_index(4, 4)];
        slot.wait_ema.store(WAIT_CAP, Ordering::Relaxed); // force demotion
        let budgets: Vec<u32> = (0..DEMOTED_REPROBE_PERIOD * 2)
            .map(|_| inbox.spin_budget(slot))
            .collect();
        let bursts = budgets.iter().filter(|&&b| b == SPIN_BUDGET_MAX).count();
        assert_eq!(bursts, 2, "one full measurement burst per period: {budgets:?}");
        assert!(
            budgets.iter().all(|&b| b == SPIN_BUDGET_MAX || b == MIN_PROBE_BURST),
            "{budgets:?}"
        );
    }

    #[test]
    fn fixed_spin_policy_still_matches() {
        let inbox: Inbox<i64> = Inbox::new_with(true);
        inbox.deposit(msg(1, 1, 4));
        let mut pending = Vec::new();
        let got = inbox.recv_match(1, 1, &mut pending, deadline()).unwrap();
        assert_eq!(got.data[0], 4);
        // Budget resolution ignores the EMA under the fixed policy.
        let budget = inbox.spin_budget(&inbox.slots[slot_index(1, 1)]);
        assert!(budget == FIXED_SPIN_TRIES || !spin_allowed());
    }

    #[test]
    fn poison_interrupts_a_blocked_receive_early() {
        let inbox: Arc<Inbox<i64>> = Arc::new(Inbox::new());
        let tx = Arc::clone(&inbox);
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            tx.poison();
        });
        let mut pending = Vec::new();
        let t0 = Instant::now();
        // 5 s deadline, but the poison must kick us out in ~30 ms.
        let got = inbox.recv_match(0, 0, &mut pending, deadline());
        assert!(got.is_none());
        assert!(t0.elapsed() < Duration::from_secs(2), "poison must not wait out the deadline");
        h.join().unwrap();
        // A poisoned inbox still matches already-buffered messages for
        // receives entered after the poison (edge-triggered semantics).
        inbox.deposit(msg(1, 1, 8));
        let got = inbox.recv_match(1, 1, &mut pending, deadline()).unwrap();
        assert_eq!(got.data[0], 8);
    }

    #[test]
    fn stats_count_parks() {
        let inbox: Inbox<i64> = Inbox::new();
        let mut pending = Vec::new();
        let before = inbox.stats();
        let got =
            inbox.recv_match(0, 0, &mut pending, Instant::now() + Duration::from_millis(40));
        assert!(got.is_none());
        let after = inbox.stats();
        assert!(after.parks > before.parks, "a timed-out receive must have parked");
    }
}
