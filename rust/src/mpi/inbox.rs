//! Slot-based rendezvous matching: the receive half of the transport
//! (EXPERIMENTS.md §Perf).
//!
//! The old transport funnelled every message for a rank through one
//! Mutex+Condvar MPMC queue and matched (src, round) with a linear scan
//! over an out-of-order `pending` vector — all senders contended on one
//! lock, and every mismatched pop paid O(pending).
//!
//! Scan schedules are fully deterministic: at any instant a rank has a
//! handful of in-flight messages, each uniquely keyed by (src, tag) —
//! where the tag is a packed [`TagKey`](super::comm::TagKey) carrying
//! (ctx, chunk, round), so concurrent collectives on distinct
//! communicators key distinctly even at equal round indices. The inbox
//! hashes (src, tag) into a small slot array:
//!
//! * **deposit** (sender side): take the slot's own lock (uncontended —
//!   only this sender and the receiver ever touch it), place the message,
//!   raise the slot's atomic flag. If the slot is occupied by a different
//!   in-flight message, fall back to the `overflow` queue — the unordered
//!   path, kept for correctness under arbitrary traffic.
//! * **match** (receiver side): check the local `pending` buffer, then
//!   spin on the *expected* slot's flag (a single atomic load per probe),
//!   draining `overflow` between probes; park on the inbox condvar when
//!   the spin budget runs out.
//!
//! Wakeups use the Dekker-style `parked` flag + mutex handshake; parks are
//! additionally time-sliced (`PARK_SLICE`) so a theoretically lost wakeup
//! degrades to a bounded stall rather than a hang. The receive deadline
//! (deadlock detection) is enforced by the caller via `recv_deadline`.
//!
//! The matched message's pooled buffer is consumed in place by the fused
//! `RankCtx::{recv_reduce, sendrecv_reduce}` primitives — the `⊕` combine
//! reads straight out of the slot's buffer and the buffer recycles to the
//! sender's pool before the receive call returns, so a matched message
//! never costs an extra memory pass after leaving the slot.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use super::msg::Msg;

/// Slot count per inbox. Must be a power of two. 64 slots cover every
/// deterministic scan schedule with near-zero collisions (a rank has at
/// most ~⌈log₂ p⌉ + 2 messages in flight, each with a distinct round tag);
/// collisions are correctness-neutral (overflow path).
const NSLOTS: usize = 64;

/// Upper bound on one condvar park. A correctly delivered wakeup arrives
/// immediately; the slice only bounds the damage of the (never observed,
/// but theoretically possible under weak orderings) lost-wakeup race.
const PARK_SLICE: Duration = Duration::from_millis(10);

/// Bounded spin before parking. Rendezvous partners usually land within a
/// few hundred nanoseconds, far below the ~1–2 µs cost of a park+unpark
/// cycle — but spinning only pays off when the peer can run in parallel,
/// so single-core hosts park immediately (same policy the old channel
/// used; see EXPERIMENTS.md §Perf).
fn spin_tries() -> u32 {
    static N: std::sync::OnceLock<u32> = std::sync::OnceLock::new();
    *N.get_or_init(|| {
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        if cores > 2 {
            100
        } else {
            0
        }
    })
}

struct Slot<T> {
    /// Raised (SeqCst) after a message is placed; the receiver's cheap
    /// probe. SeqCst pairs with the `parked` flag for the Dekker handshake.
    full: AtomicBool,
    cell: Mutex<Option<Msg<T>>>,
}

/// One rank's inbox. Senders call [`deposit`](Inbox::deposit); only the
/// owning rank calls [`recv_match`](Inbox::recv_match).
pub(crate) struct Inbox<T> {
    slots: Vec<Slot<T>>,
    overflow: Mutex<VecDeque<Msg<T>>>,
    /// Lock-free emptiness probe for the overflow queue.
    overflow_len: AtomicUsize,
    /// Messages under chaos embargo: matchable only once their release
    /// instant passes (see [`super::chaos`]). Empty (and never locked on
    /// the probe path) when chaos is off.
    delayed: Mutex<Vec<(Instant, Msg<T>)>>,
    /// Lock-free emptiness probe for the embargo queue.
    delayed_len: AtomicUsize,
    /// Receiver-is-parked flag (Dekker partner of `Slot::full`).
    parked: AtomicBool,
    park_lock: Mutex<()>,
    park_cv: Condvar,
}

fn slot_index(src: usize, tag: u64) -> usize {
    let h = (src as u64)
        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
        .wrapping_add(tag.wrapping_mul(0xC2B2_AE3D_27D4_EB4F));
    ((h >> 32) as usize) & (NSLOTS - 1)
}

impl<T> Default for Inbox<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> Inbox<T> {
    pub fn new() -> Self {
        Inbox {
            slots: (0..NSLOTS)
                .map(|_| Slot { full: AtomicBool::new(false), cell: Mutex::new(None) })
                .collect(),
            overflow: Mutex::new(VecDeque::new()),
            overflow_len: AtomicUsize::new(0),
            delayed: Mutex::new(Vec::new()),
            delayed_len: AtomicUsize::new(0),
            parked: AtomicBool::new(false),
            park_lock: Mutex::new(()),
            park_cv: Condvar::new(),
        }
    }

    /// Sender side: place `msg` for the owning rank to match.
    pub fn deposit(&self, msg: Msg<T>) {
        let slot = &self.slots[slot_index(msg.src, msg.tag)];
        let overflowed = {
            let mut cell = slot.cell.lock().unwrap();
            if cell.is_none() {
                *cell = Some(msg);
                slot.full.store(true, Ordering::SeqCst);
                None
            } else {
                Some(msg) // collision with a different in-flight message
            }
        };
        if let Some(msg) = overflowed {
            self.overflow.lock().unwrap().push_back(msg);
            self.overflow_len.fetch_add(1, Ordering::SeqCst);
        }
        self.wake();
    }

    /// Chaos hook: hold `msg` under embargo until `release_at`, then make
    /// it matchable through the normal slot/overflow path. The receiver
    /// releases due messages itself inside [`recv_match`](Self::recv_match)
    /// (its parks are sliced, so an embargo adds bounded latency and can
    /// never deadlock).
    pub fn deposit_delayed(&self, msg: Msg<T>, release_at: Instant) {
        if release_at <= Instant::now() {
            self.deposit(msg);
            return;
        }
        {
            // The length mirror is only ever written under the `delayed`
            // lock (here and in `release_due`), so it can never drift.
            let mut held = self.delayed.lock().unwrap();
            held.push((release_at, msg));
            self.delayed_len.store(held.len(), Ordering::SeqCst);
        }
        self.wake(); // receiver re-probes and re-slices its park deadline
    }

    /// Chaos hook: route `msg` straight to the unordered overflow queue,
    /// as if its slot had collided — exercises the overflow and pending
    /// paths on schedules that would otherwise never touch them.
    pub fn deposit_overflow(&self, msg: Msg<T>) {
        self.overflow.lock().unwrap().push_back(msg);
        self.overflow_len.fetch_add(1, Ordering::SeqCst);
        self.wake();
    }

    /// Move every embargoed message whose release instant has passed into
    /// the normal matching path. Cheap when the embargo queue is empty
    /// (one atomic load).
    fn release_due(&self) {
        if self.delayed_len.load(Ordering::SeqCst) == 0 {
            return;
        }
        let now = Instant::now();
        let due = {
            let mut held = self.delayed.lock().unwrap();
            let mut due = Vec::new();
            let mut i = 0;
            while i < held.len() {
                if held[i].0 <= now {
                    due.push(held.swap_remove(i).1);
                } else {
                    i += 1;
                }
            }
            self.delayed_len.store(held.len(), Ordering::SeqCst);
            due
        };
        for msg in due {
            self.deposit(msg);
        }
    }

    /// Earliest release instant of any still-embargoed message. Probed
    /// under the park lock so a just-arrived embargo can never be slept
    /// past (its `wake()` may have fired before `parked` was raised).
    fn next_release_hint(&self) -> Option<Instant> {
        if self.delayed_len.load(Ordering::SeqCst) == 0 {
            return None;
        }
        self.delayed.lock().unwrap().iter().map(|(t, _)| *t).min()
    }

    fn wake(&self) {
        if self.parked.load(Ordering::SeqCst) {
            // Take the park lock so the notify cannot slip between the
            // receiver's final re-check and its wait (no lost wakeup).
            let _g = self.park_lock.lock().unwrap();
            self.park_cv.notify_all();
        }
    }

    /// Try to take the message in the slot keyed by (src, tag). Returns
    /// whatever message occupies that slot — the caller checks the match
    /// and buffers strangers (slot collisions) itself.
    fn try_slot(&self, src: usize, tag: u64) -> Option<Msg<T>> {
        let slot = &self.slots[slot_index(src, tag)];
        if !slot.full.load(Ordering::SeqCst) {
            return None;
        }
        let mut cell = slot.cell.lock().unwrap();
        let msg = cell.take();
        if msg.is_some() {
            slot.full.store(false, Ordering::SeqCst);
        }
        msg
    }

    /// Pop one message from the unordered overflow queue.
    fn try_overflow(&self) -> Option<Msg<T>> {
        if self.overflow_len.load(Ordering::SeqCst) == 0 {
            return None;
        }
        let msg = self.overflow.lock().unwrap().pop_front();
        if msg.is_some() {
            self.overflow_len.fetch_sub(1, Ordering::SeqCst);
        }
        msg
    }

    /// Receiver side: block until the message from `src` tagged `tag`
    /// arrives, buffering strangers into `pending`. Returns `None` on
    /// deadline expiry (the caller reports the deadlock).
    ///
    /// `pending` is the rank-local out-of-order buffer: messages that
    /// collided in the slot array or arrived through overflow for a later
    /// receive. The caller checks it *before* calling (it is rank-private).
    pub fn recv_match(
        &self,
        src: usize,
        tag: u64,
        pending: &mut Vec<Msg<T>>,
        deadline: Instant,
    ) -> Option<Msg<T>> {
        let mut spins = 0u32;
        loop {
            // 0. Release any chaos-embargoed messages that are now due
            // (no-op single atomic probe when chaos is off).
            self.release_due();
            // 1. The expected slot (single atomic probe on the fast path).
            if let Some(msg) = self.try_slot(src, tag) {
                if msg.src == src && msg.tag == tag {
                    return Some(msg);
                }
                pending.push(msg);
                continue; // the wanted message may be right behind it
            }
            // 2. The unordered overflow path.
            if let Some(msg) = self.try_overflow() {
                if msg.src == src && msg.tag == tag {
                    return Some(msg);
                }
                pending.push(msg);
                continue;
            }
            // 3. Spin a little, then park until a deposit (or time slice).
            if spins < spin_tries() {
                spins += 1;
                std::hint::spin_loop();
                continue;
            }
            spins = 0;
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let mut wait = PARK_SLICE.min(deadline - now);
            let guard = self.park_lock.lock().unwrap();
            self.parked.store(true, Ordering::SeqCst);
            // Final re-check under the park lock: a deposit that happened
            // before we raised `parked` is caught here; one that happens
            // after will see `parked` and take the lock to notify.
            if let Some(m) = self.try_slot(src, tag) {
                self.parked.store(false, Ordering::SeqCst);
                drop(guard);
                if m.src == src && m.tag == tag {
                    return Some(m);
                }
                pending.push(m);
                continue;
            }
            if self.overflow_len.load(Ordering::SeqCst) != 0 {
                self.parked.store(false, Ordering::SeqCst);
                drop(guard);
                continue;
            }
            // Cap the park at the earliest embargo release, probed *under
            // the park lock* so a delayed deposit landing at any point
            // before `parked = true` (whose wake() no-opped) can never be
            // slept past for a full slice — regardless of whether an
            // older, later-releasing embargo was already pending.
            if let Some(release_at) = self.next_release_hint() {
                let now = Instant::now();
                if release_at <= now {
                    self.parked.store(false, Ordering::SeqCst);
                    drop(guard);
                    continue;
                }
                wait = wait.min((release_at - now).max(Duration::from_micros(50)));
            }
            let (_guard, _res) = self.park_cv.wait_timeout(guard, wait).unwrap();
            self.parked.store(false, Ordering::SeqCst);
        }
    }

    /// Messages currently buffered inside the inbox (slots + overflow).
    /// Test/debug hook — not used on the hot path.
    #[allow(dead_code)] // crate-internal diagnostics; exercised in tests
    pub fn occupancy(&self) -> usize {
        let in_slots =
            self.slots.iter().filter(|s| s.full.load(Ordering::SeqCst)).count();
        in_slots
            + self.overflow_len.load(Ordering::SeqCst)
            + self.delayed_len.load(Ordering::SeqCst)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpi::pool::PoolBuf;
    use std::sync::Arc;

    fn msg(src: usize, tag: u64, v: i64) -> Msg<i64> {
        Msg { src, tag, data: PoolBuf::detached(vec![v]), vtime: 0.0 }
    }

    fn deadline() -> Instant {
        Instant::now() + Duration::from_secs(5)
    }

    /// The caller-side matching discipline `RankCtx::take` uses: check the
    /// rank-local pending buffer first, then block on the inbox.
    fn take(inbox: &Inbox<i64>, pending: &mut Vec<Msg<i64>>, src: usize, tag: u64) -> Msg<i64> {
        if let Some(i) = pending.iter().position(|m| m.src == src && m.tag == tag) {
            return pending.swap_remove(i);
        }
        inbox.recv_match(src, tag, pending, deadline()).expect("timed out")
    }

    #[test]
    fn same_key_matches_through_slot() {
        let inbox: Inbox<i64> = Inbox::new();
        inbox.deposit(msg(3, 7, 42));
        let mut pending = Vec::new();
        let got = inbox.recv_match(3, 7, &mut pending, deadline()).unwrap();
        assert_eq!(got.src, 3);
        assert_eq!(got.tag, 7);
        assert_eq!(got.data[0], 42);
        assert!(pending.is_empty());
        assert_eq!(inbox.occupancy(), 0);
    }

    #[test]
    fn stranger_lands_in_pending() {
        let inbox: Inbox<i64> = Inbox::new();
        // Two messages; receive the second one first. Wherever the first
        // lands (slot or overflow), it must surface into `pending`.
        inbox.deposit(msg(0, 1, 10));
        inbox.deposit(msg(0, 2, 20));
        let mut pending = Vec::new();
        let got = inbox.recv_match(0, 2, &mut pending, deadline()).unwrap();
        assert_eq!(got.data[0], 20);
        // The round-1 message is either in pending already or still boxed.
        let leftover = pending.len() + inbox.occupancy();
        assert_eq!(leftover, 1);
    }

    #[test]
    fn collision_overflows_and_still_matches() {
        let inbox: Inbox<i64> = Inbox::new();
        // Find two keys that collide in the slot array.
        let (s1, t1) = (0usize, 0u64);
        let mut other = None;
        'outer: for src in 0..NSLOTS * 4 {
            for tag in 0..(NSLOTS as u64 * 4) {
                if (src, tag) != (s1, t1) && slot_index(src, tag) == slot_index(s1, t1) {
                    other = Some((src, tag));
                    break 'outer;
                }
            }
        }
        let (s2, t2) = other.expect("hash must collide somewhere");
        inbox.deposit(msg(s1, t1, 1)); // takes the slot
        inbox.deposit(msg(s2, t2, 2)); // collides → overflow
        let mut pending = Vec::new();
        let got2 = take(&inbox, &mut pending, s2, t2);
        assert_eq!(got2.data[0], 2);
        let got1 = take(&inbox, &mut pending, s1, t1);
        assert_eq!(got1.data[0], 1);
        assert!(pending.is_empty());
        assert_eq!(inbox.occupancy(), 0);
    }

    #[test]
    fn deadline_expires_to_none() {
        let inbox: Inbox<i64> = Inbox::new();
        let mut pending = Vec::new();
        let t0 = Instant::now();
        let got =
            inbox.recv_match(0, 0, &mut pending, Instant::now() + Duration::from_millis(50));
        assert!(got.is_none());
        assert!(t0.elapsed() >= Duration::from_millis(45));
        assert!(t0.elapsed() < Duration::from_secs(5));
    }

    #[test]
    fn cross_thread_wakeup() {
        let inbox: Arc<Inbox<i64>> = Arc::new(Inbox::new());
        let tx = Arc::clone(&inbox);
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30)); // let receiver park
            tx.deposit(msg(1, 9, 99));
        });
        let mut pending = Vec::new();
        let got = inbox.recv_match(1, 9, &mut pending, deadline()).unwrap();
        assert_eq!(got.data[0], 99);
        h.join().unwrap();
    }

    #[test]
    fn delayed_deposit_matches_after_embargo() {
        let inbox: Inbox<i64> = Inbox::new();
        let t0 = Instant::now();
        inbox.deposit_delayed(msg(2, 4, 77), Instant::now() + Duration::from_millis(20));
        assert_eq!(inbox.occupancy(), 1, "embargoed message must be counted");
        let mut pending = Vec::new();
        let got = inbox.recv_match(2, 4, &mut pending, deadline()).unwrap();
        assert_eq!(got.data[0], 77);
        assert!(t0.elapsed() >= Duration::from_millis(15), "embargo must hold");
        assert_eq!(inbox.occupancy(), 0);
    }

    #[test]
    fn delayed_deposit_in_the_past_is_immediate() {
        let inbox: Inbox<i64> = Inbox::new();
        inbox.deposit_delayed(msg(0, 1, 5), Instant::now());
        let mut pending = Vec::new();
        let got = inbox.recv_match(0, 1, &mut pending, deadline()).unwrap();
        assert_eq!(got.data[0], 5);
    }

    #[test]
    fn diverted_deposit_matches_through_overflow() {
        let inbox: Inbox<i64> = Inbox::new();
        inbox.deposit_overflow(msg(3, 9, 33));
        assert_eq!(inbox.occupancy(), 1);
        let mut pending = Vec::new();
        let got = inbox.recv_match(3, 9, &mut pending, deadline()).unwrap();
        assert_eq!(got.data[0], 33);
        assert!(pending.is_empty());
    }

    #[test]
    fn embargo_reorders_across_keys() {
        // Deposit round 0 under a long embargo, round 1 immediately: the
        // round-1 message becomes matchable *before* the round-0 one even
        // though it was deposited after — the adversarial delivery
        // reordering the chaos layer is built to produce. Matching round 0
        // first must block until release, then both match cleanly.
        let inbox: Inbox<i64> = Inbox::new();
        inbox.deposit_delayed(msg(0, 0, 10), Instant::now() + Duration::from_millis(15));
        inbox.deposit(msg(0, 1, 11));
        let mut pending = Vec::new();
        let got0 = inbox.recv_match(0, 0, &mut pending, deadline()).unwrap();
        assert_eq!(got0.data[0], 10);
        let got1 = take(&inbox, &mut pending, 0, 1);
        assert_eq!(got1.data[0], 11);
        assert!(pending.is_empty());
        assert_eq!(inbox.occupancy(), 0);
    }

    #[test]
    fn hammer_many_tags_out_of_order() {
        let inbox: Arc<Inbox<i64>> = Arc::new(Inbox::new());
        let tx = Arc::clone(&inbox);
        const K: u64 = 500;
        let h = std::thread::spawn(move || {
            for tag in 0..K {
                tx.deposit(msg(0, tag, tag as i64));
            }
        });
        let mut pending = Vec::new();
        // Receive even tags descending, then odd tags ascending — maximal
        // out-of-order pressure on slots, overflow and pending.
        for tag in (0..K).rev().filter(|t| t % 2 == 0) {
            let got = take(&inbox, &mut pending, 0, tag);
            assert_eq!(got.data[0], tag as i64);
        }
        for tag in (0..K).filter(|t| t % 2 == 1) {
            let got = take(&inbox, &mut pending, 0, tag);
            assert_eq!(got.data[0], tag as i64);
        }
        assert!(pending.is_empty());
        assert_eq!(inbox.occupancy(), 0);
        h.join().unwrap();
    }
}
