//! Per-rank execution context: the API the collective algorithms program
//! against (`send` / `recv` / `sendrecv` / `reduce_local` / `barrier`).
//!
//! One context per rank thread. The same code path serves both transports:
//! in *real* mode, timing is wall-clock and the virtual machinery is inert;
//! in *virtual* mode, every operation advances a per-rank logical clock
//! according to the α-β-γ [`CostModel`](crate::cost::CostModel), giving a
//! deterministic, cluster-scale simulation (LogP-style) with the exact same
//! message flow.
//!
//! Transport hot path (EXPERIMENTS.md §Perf): sends copy into a buffer
//! recycled through the sending rank's [`BufferPool`] (no allocation in
//! steady state) and deposit into the receiver's slot-keyed
//! [`Inbox`](super::inbox::Inbox) (no shared MPMC lock, no linear
//! matching scan). `recv_owned` hands the pooled buffer straight to the
//! algorithm; dropping it recycles the buffer.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use super::elem::Elem;
use super::inbox::Inbox;
use super::msg::Msg;
use super::op::OpRef;
use super::pool::{BufferPool, PoolBuf, PoolStats};
use super::vbarrier::VBarrier;
use crate::cost::CostModel;
use crate::trace::{EventKind, RankTrace};

/// How time is accounted.
#[derive(Clone)]
pub enum ClockMode {
    /// Wall-clock: the harness times real execution.
    Real,
    /// Logical clocks driven by the cost model (simulated cluster).
    Virtual(Arc<CostModel>),
}

/// Default timeout for a blocking receive before declaring deadlock.
/// Generous (the test suite runs thousands of collectives; a genuine
/// deadlock is the only thing that should ever hit it); override with
/// `EXSCAN_RECV_TIMEOUT_MS` process-wide, or per world via
/// [`WorldConfig::recv_timeout`](super::WorldConfig) (which wins).
pub fn recv_timeout() -> Duration {
    static T: std::sync::OnceLock<Duration> = std::sync::OnceLock::new();
    *T.get_or_init(|| {
        std::env::var("EXSCAN_RECV_TIMEOUT_MS")
            .ok()
            .and_then(|s| s.parse::<u64>().ok())
            .map(Duration::from_millis)
            .unwrap_or(Duration::from_secs(60))
    })
}

/// Per-rank handle used by algorithm implementations.
pub struct RankCtx<T: Elem> {
    rank: usize,
    size: usize,
    /// `inboxes[r]` is rank r's inbox; this rank matches on `inboxes[rank]`.
    inboxes: Arc<Vec<Inbox<T>>>,
    /// This rank's send-buffer pool (buffers recycle back here when the
    /// receiver drops them).
    pool: Arc<BufferPool<T>>,
    /// Out-of-order arrivals waiting to be matched (slot collisions and
    /// overflow strangers surfaced by the inbox).
    pending: Vec<Msg<T>>,
    barrier: Arc<VBarrier>,
    barrier_gen: u64,
    mode: ClockMode,
    /// Deadlock-detection deadline per blocking receive.
    recv_deadline: Duration,
    /// Virtual clock (µs). Meaningless in real mode.
    vclock: f64,
    /// Whether tracing was requested for this world (lets a persistent
    /// executor re-arm the trace after `take_trace`).
    tracing: bool,
    /// Event log; `None` when tracing is disabled or already taken.
    trace: Option<RankTrace>,
}

impl<T: Elem> RankCtx<T> {
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn new(
        rank: usize,
        size: usize,
        inboxes: Arc<Vec<Inbox<T>>>,
        pool: Arc<BufferPool<T>>,
        barrier: Arc<VBarrier>,
        mode: ClockMode,
        tracing: bool,
        recv_deadline: Duration,
    ) -> Self {
        RankCtx {
            rank,
            size,
            inboxes,
            pool,
            pending: Vec::new(),
            barrier,
            barrier_gen: 0,
            mode,
            recv_deadline,
            vclock: 0.0,
            tracing,
            trace: tracing.then(|| RankTrace::new(rank)),
        }
    }

    /// This rank's id, `0 <= rank < size`.
    pub fn rank(&self) -> usize {
        self.rank
    }

    /// Number of ranks in the world (`p`).
    pub fn size(&self) -> usize {
        self.size
    }

    /// Current virtual clock (µs). 0 in real mode.
    pub fn vclock(&self) -> f64 {
        self.vclock
    }

    /// Reset the virtual clock and trace (between benchmark repetitions).
    pub fn reset_clock(&mut self) {
        self.vclock = 0.0;
        if let Some(t) = &mut self.trace {
            t.events.clear();
        }
    }

    /// Take the recorded trace (empties the log).
    pub fn take_trace(&mut self) -> Option<RankTrace> {
        self.trace.take()
    }

    /// Re-arm tracing after [`take_trace`](Self::take_trace) — called by
    /// the persistent [`World`](super::World) executor between jobs so a
    /// traced job does not silence tracing for the next one.
    pub(crate) fn rearm_trace(&mut self) {
        if self.tracing && self.trace.is_none() {
            self.trace = Some(RankTrace::new(self.rank));
        }
    }

    /// This rank's send-pool counters (hit rate must saturate in steady
    /// state — the transport's zero-allocation claim).
    pub fn pool_stats(&self) -> PoolStats {
        self.pool.stats()
    }

    fn bytes(len: usize) -> usize {
        len * T::size_bytes()
    }

    fn record(&mut self, round: u32, kind: EventKind) {
        if let Some(t) = &mut self.trace {
            t.push(round, kind);
        }
    }

    fn post(&self, to: usize, round: u32, data: &[T]) -> Result<()> {
        if to >= self.size {
            bail!("rank {} sending to out-of-range rank {}", self.rank, to);
        }
        let msg = Msg {
            src: self.rank,
            tag: round as u64,
            data: BufferPool::acquire_copy(&self.pool, data),
            vtime: self.vclock,
        };
        self.inboxes[to].deposit(msg);
        Ok(())
    }

    /// Blocking matched receive: returns the message from `from` with tag
    /// `round`, buffering any other arrivals.
    fn take(&mut self, from: usize, round: u32) -> Result<Msg<T>> {
        let tag = round as u64;
        if let Some(i) = self.pending.iter().position(|m| m.src == from && m.tag == tag) {
            return Ok(self.pending.swap_remove(i));
        }
        let deadline = Instant::now() + self.recv_deadline;
        match self.inboxes[self.rank].recv_match(from, tag, &mut self.pending, deadline) {
            Some(msg) => Ok(msg),
            None => bail!(
                "rank {} deadlocked waiting for (from={from}, round={round})",
                self.rank
            ),
        }
    }

    /// One-sided send in communication round `round` (one send-port slot).
    pub fn send(&mut self, round: u32, to: usize, buf: &[T]) -> Result<()> {
        self.post(to, round, buf)?;
        self.record(round, EventKind::Send { to, bytes: Self::bytes(buf.len()) });
        if let ClockMode::Virtual(model) = &self.mode {
            self.vclock += model.round_cost(self.rank, to, Self::bytes(buf.len()));
        }
        Ok(())
    }

    /// One-sided receive in communication round `round` (one recv-port slot).
    pub fn recv(&mut self, round: u32, from: usize, buf: &mut [T]) -> Result<()> {
        let msg = self.take(from, round)?;
        if msg.data.len() != buf.len() {
            bail!(
                "rank {}: recv size mismatch from {} round {}: got {} want {}",
                self.rank,
                from,
                round,
                msg.data.len(),
                buf.len()
            );
        }
        buf.copy_from_slice(&msg.data);
        self.record(round, EventKind::Recv { from, bytes: Self::bytes(buf.len()) });
        if let ClockMode::Virtual(model) = &self.mode {
            let c_in = model.round_cost(from, self.rank, Self::bytes(buf.len()));
            self.vclock = self.vclock.max(msg.vtime) + c_in;
        }
        Ok(())
    }

    /// Owned-buffer receive: like [`recv`](Self::recv) but hands back the
    /// transport's buffer instead of copying into a caller slice — the
    /// hot-path variant used by the scan algorithms (their only use of
    /// the received vector is as the read-only `input` of `reduce_local`,
    /// so no copy is ever needed). `expect` is the element count. The
    /// returned [`PoolBuf`] recycles to the sender's pool on drop.
    pub fn recv_owned(&mut self, round: u32, from: usize, expect: usize) -> Result<PoolBuf<T>> {
        let msg = self.take(from, round)?;
        if msg.data.len() != expect {
            bail!(
                "rank {}: recv size mismatch from {} round {}: got {} want {}",
                self.rank,
                from,
                round,
                msg.data.len(),
                expect
            );
        }
        self.record(round, EventKind::Recv { from, bytes: Self::bytes(expect) });
        if let ClockMode::Virtual(model) = &self.mode {
            let c_in = model.round_cost(from, self.rank, Self::bytes(expect));
            self.vclock = self.vclock.max(msg.vtime) + c_in;
        }
        Ok(msg.data)
    }

    /// Owned-buffer simultaneous send-receive (see [`recv_owned`](Self::recv_owned)).
    pub fn sendrecv_owned(
        &mut self,
        round: u32,
        to: usize,
        sbuf: &[T],
        from: usize,
        expect: usize,
    ) -> Result<PoolBuf<T>> {
        self.post(to, round, sbuf)?;
        self.record(round, EventKind::Send { to, bytes: Self::bytes(sbuf.len()) });
        let msg = self.take(from, round)?;
        if msg.data.len() != expect {
            bail!(
                "rank {}: sendrecv size mismatch from {} round {}: got {} want {}",
                self.rank,
                from,
                round,
                msg.data.len(),
                expect
            );
        }
        self.record(round, EventKind::Recv { from, bytes: Self::bytes(expect) });
        if let ClockMode::Virtual(model) = &self.mode {
            let c_out = model.round_cost(self.rank, to, Self::bytes(sbuf.len()));
            let c_in = model.round_cost(from, self.rank, Self::bytes(expect));
            self.vclock = self.vclock.max(msg.vtime) + c_out.max(c_in);
        }
        Ok(msg.data)
    }

    /// Simultaneous send-receive — the paper's `Send(·,t) ∥ Recv(·,f)`:
    /// both transfers share one communication round; in the virtual clock
    /// the round costs `max(c_out, c_in)` on top of the later of the two
    /// ranks' start times.
    pub fn sendrecv(
        &mut self,
        round: u32,
        to: usize,
        sbuf: &[T],
        from: usize,
        rbuf: &mut [T],
    ) -> Result<()> {
        self.post(to, round, sbuf)?;
        self.record(round, EventKind::Send { to, bytes: Self::bytes(sbuf.len()) });
        let msg = self.take(from, round)?;
        if msg.data.len() != rbuf.len() {
            bail!(
                "rank {}: sendrecv size mismatch from {} round {}: got {} want {}",
                self.rank,
                from,
                round,
                msg.data.len(),
                rbuf.len()
            );
        }
        rbuf.copy_from_slice(&msg.data);
        self.record(round, EventKind::Recv { from, bytes: Self::bytes(rbuf.len()) });
        if let ClockMode::Virtual(model) = &self.mode {
            let c_out = model.round_cost(self.rank, to, Self::bytes(sbuf.len()));
            let c_in = model.round_cost(from, self.rank, Self::bytes(rbuf.len()));
            self.vclock = self.vclock.max(msg.vtime) + c_out.max(c_in);
        }
        Ok(())
    }

    /// `MPI_Reduce_local`: `inout = input ⊕ inout`, attributed to `round`.
    /// Advances the virtual clock by `γ·bytes` and bumps the op counters.
    pub fn reduce_local(&mut self, round: u32, op: &OpRef<T>, input: &[T], inout: &mut [T]) {
        op.reduce_local(input, inout);
        self.record(round, EventKind::Reduce { bytes: Self::bytes(input.len()) });
        if let ClockMode::Virtual(model) = &self.mode {
            self.vclock += model.reduce_cost(Self::bytes(input.len()));
        }
    }

    /// Barrier over all ranks. In virtual mode this also synchronizes the
    /// logical clocks to the global maximum, exactly as a real barrier
    /// aligns wall time. Every rank must call it the same number of times.
    pub fn barrier(&mut self) {
        match &self.mode {
            ClockMode::Real => self.barrier.wait(),
            ClockMode::Virtual(_) => {
                self.barrier_gen += 1;
                self.vclock = self.barrier.wait_max(self.barrier_gen, self.vclock);
            }
        }
    }

    /// True when running under the virtual (simulated-cluster) clock.
    pub fn is_virtual(&self) -> bool {
        matches!(self.mode, ClockMode::Virtual(_))
    }
}
