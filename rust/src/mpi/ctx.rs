//! Per-rank execution context: the API the collective algorithms program
//! against (`send` / `recv` / `sendrecv` / `reduce_local` / `barrier`).
//!
//! One context per rank thread. The same code path serves both transports:
//! in *real* mode, timing is wall-clock and the virtual machinery is inert;
//! in *virtual* mode, every operation advances a per-rank logical clock
//! according to the α-β-γ [`CostModel`](crate::cost::CostModel), giving a
//! deterministic, cluster-scale simulation (LogP-style) with the exact same
//! message flow.
//!
//! Transport hot path (EXPERIMENTS.md §Perf, §Transport): sends copy into
//! a buffer recycled through the sending rank's [`BufferPool`] (no
//! allocation in steady state) and post through the world's pluggable
//! [`Transport`] — the thread backend deposits straight into the
//! receiver's slot-keyed [`Inbox`](super::inbox::Inbox) (no shared MPMC
//! lock, no linear matching scan); the shm/socket backends frame the
//! message over their medium into the same matcher. `recv_owned` hands
//! the pooled buffer straight to the algorithm; dropping it recycles the
//! buffer. Chaos decisions are made *here*, above the transport boundary,
//! so injected schedules and digests are backend-independent.
//!
//! Compute hot path (this PR): the fused primitives
//! [`recv_reduce`](RankCtx::recv_reduce) /
//! [`sendrecv_reduce`](RankCtx::sendrecv_reduce) match the inbound
//! `(src, round)` slot and apply `⊕` **directly from the pooled receive
//! buffer into the caller's buffer** — no intermediate owned handle, no
//! extra memory pass — and [`scratch_from`](RankCtx::scratch_from) /
//! [`scratch_filled`](RankCtx::scratch_filled) replace the algorithms'
//! per-call `to_vec()` temporaries with pool-recycled buffers. The
//! pre-fusion two-step flow is preserved behind
//! [`WorldConfig::unfused_compat`](super::WorldConfig) as the A/B
//! reference for the equivalence tests and the hotpath m-sweep.
//!
//! ⊕ dispatch funnels through [`OpKernel`]: algorithms resolve the
//! operator to its slice kernel **once per collective** via
//! [`kernel`](RankCtx::kernel) (which honours the world's
//! `with_per_element_ops` A/B flag) and every fused primitive and
//! [`reduce_local`](RankCtx::reduce_local) applies through the resolved
//! handle — no per-application dyn lookup for built-in operators (see
//! [`crate::mpi::op`]).
//!
//! Communicator scoping (the scan-service layer): inside
//! [`with_comm`](RankCtx::with_comm), `rank()`/`size()` and every peer
//! argument are communicator-relative, and every message tag carries the
//! communicator's context id (a packed [`TagKey`]) — so any number of
//! collectives on *distinct* communicators can be in flight on one world
//! without cross-matching. Traces record world ranks plus the context id;
//! [`TraceReport::for_ctx`](crate::trace::TraceReport::for_ctx) extracts
//! one communicator's sub-trace in communicator coordinates.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use super::chaos::{Chaos, ChaosAction};
use super::comm::{Comm, TagKey, WORLD_CTX};
use super::elem::Elem;
use super::inbox::InboxStats;
use super::msg::Msg;
use super::op::{OpKernel, OpRef};
use super::pool::{BufferPool, PoolBuf, PoolStats};
use super::transport::Transport;
use super::vbarrier::VBarrier;
use super::world::DeadRanks;
use crate::cost::CostModel;
use crate::trace::{EventKind, RankTrace};

/// How time is accounted.
#[derive(Clone)]
pub enum ClockMode {
    /// Wall-clock: the harness times real execution.
    Real,
    /// Logical clocks driven by the cost model (simulated cluster).
    Virtual(Arc<CostModel>),
}

/// Default timeout for a blocking receive before declaring deadlock.
/// Generous (the test suite runs thousands of collectives; a genuine
/// deadlock is the only thing that should ever hit it); override with
/// `EXSCAN_RECV_TIMEOUT_MS` process-wide, or per world via
/// [`WorldConfig::recv_timeout`](super::WorldConfig) (which wins).
pub fn recv_timeout() -> Duration {
    static T: std::sync::OnceLock<Duration> = std::sync::OnceLock::new();
    *T.get_or_init(|| {
        std::env::var("EXSCAN_RECV_TIMEOUT_MS")
            .ok()
            .and_then(|s| s.parse::<u64>().ok())
            .map(Duration::from_millis)
            .unwrap_or(Duration::from_secs(60))
    })
}

/// Per-rank handle used by algorithm implementations.
pub struct RankCtx<T: Elem> {
    rank: usize,
    size: usize,
    /// Active communicator scope (`None` = whole world). While set,
    /// `rank()`/`size()` and every peer argument are communicator-relative
    /// and message tags carry the communicator's context id — see
    /// [`with_comm`](Self::with_comm).
    comm: Option<Comm>,
    /// Communicator-relative view of this rank's id and the group size
    /// (equal to `rank`/`size` outside a comm scope).
    vrank: usize,
    vsize: usize,
    /// Context id stamped into every outgoing/expected [`TagKey`]
    /// ([`WORLD_CTX`] outside a comm scope).
    tag_ctx: u16,
    /// Sub-round lane id stamped into every [`TagKey`] (0 outside a
    /// [`with_chunk`](Self::with_chunk) scope).
    tag_chunk: u16,
    /// The world's rendezvous backend: posts address the destination
    /// rank's matcher, takes match on this rank's (`transport.take(rank,
    /// …)`). All ranks of a world share one instance.
    transport: Arc<dyn Transport<T>>,
    /// This rank's send-buffer pool (buffers recycle back here when the
    /// receiver drops them).
    pool: Arc<BufferPool<T>>,
    /// Out-of-order arrivals waiting to be matched (slot collisions and
    /// overflow strangers surfaced by the inbox).
    pending: Vec<Msg<T>>,
    barrier: Arc<VBarrier>,
    barrier_gen: u64,
    mode: ClockMode,
    /// A/B switch: route the fused `*_reduce` primitives through the
    /// pre-fusion flow (land the message in an owned scratch copy, then a
    /// separate reduce pass). Identical results and traces by
    /// construction; one extra memory pass per receive.
    unfused: bool,
    /// A/B switch: [`kernel`](Self::kernel) resolves operators to the
    /// per-element reference dispatch instead of the slice kernel.
    /// Bit-identical results by the [`CombineOp`](super::CombineOp)
    /// contract; only the per-application dispatch cost differs.
    per_element: bool,
    /// Deadlock-detection deadline per blocking receive.
    recv_deadline: Duration,
    /// Per-world chaos injection (None outside chaos worlds — the hot
    /// path then pays one branch per operation).
    chaos: Option<Arc<Chaos>>,
    /// This rank's chaos-point counter: the deterministic "time" axis of
    /// injected scheduler yields (advances once per send/receive/barrier).
    chaos_ticks: u64,
    /// World-shared registry of chaos-killed ranks (attributed failures
    /// for survivors; see [`DeadRanks`]).
    dead: Arc<DeadRanks>,
    /// Set once this rank's own death fires: every later send/receive on
    /// this rank fails immediately. The rank thread itself stays alive —
    /// an OS-thread exit would wedge the executor's completion latch, so
    /// "death" is an in-job bail that still participates in barriers.
    is_dead: bool,
    /// Virtual clock (µs). Meaningless in real mode.
    vclock: f64,
    /// Whether tracing was requested for this world (lets a persistent
    /// executor re-arm the trace after `take_trace`).
    tracing: bool,
    /// Event log; `None` when tracing is disabled or already taken.
    trace: Option<RankTrace>,
}

impl<T: Elem> RankCtx<T> {
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn new(
        rank: usize,
        size: usize,
        transport: Arc<dyn Transport<T>>,
        pool: Arc<BufferPool<T>>,
        barrier: Arc<VBarrier>,
        mode: ClockMode,
        tracing: bool,
        unfused: bool,
        per_element: bool,
        recv_deadline: Duration,
        chaos: Option<Arc<Chaos>>,
        dead: Arc<DeadRanks>,
    ) -> Self {
        RankCtx {
            rank,
            size,
            comm: None,
            vrank: rank,
            vsize: size,
            tag_ctx: WORLD_CTX,
            tag_chunk: 0,
            transport,
            pool,
            pending: Vec::new(),
            barrier,
            barrier_gen: 0,
            mode,
            unfused,
            per_element,
            recv_deadline,
            chaos,
            chaos_ticks: 0,
            dead,
            is_dead: false,
            vclock: 0.0,
            tracing,
            trace: tracing.then(|| RankTrace::new(rank)),
        }
    }

    /// One chaos point: advance this rank's deterministic tick and maybe
    /// inject a scheduler yield. No-op outside chaos worlds.
    fn chaos_point(&mut self) {
        if let Some(chaos) = &self.chaos {
            self.chaos_ticks += 1;
            chaos.maybe_yield(self.rank, self.chaos_ticks);
        }
    }

    /// Rank-death gate, called from `post` and `take` (never from
    /// `barrier` — a rank absent from `VBarrier::wait` would hang the
    /// whole world, so a dead rank keeps attending barriers and only its
    /// point-to-point traffic fails). On the first firing the rank
    /// registers in the world's [`DeadRanks`] set and poisons the whole
    /// transport so all blocked survivors wake immediately and attribute.
    fn ensure_alive(&mut self) -> Result<()> {
        if self.is_dead {
            bail!("rank {} is dead (chaos rank-death)", self.rank);
        }
        let Some(chaos) = &self.chaos else { return Ok(()) };
        if !chaos.should_die(self.rank, self.chaos_ticks) {
            return Ok(());
        }
        self.is_dead = true;
        if self.dead.mark_dead(self.rank) {
            chaos.note_death();
        }
        self.transport.poison_all();
        bail!(
            "rank {} killed by chaos rank-death at tick {}",
            self.rank,
            self.chaos_ticks
        );
    }

    /// This rank's id, `0 <= rank < size` — communicator-relative inside a
    /// [`with_comm`](Self::with_comm) scope, the world rank otherwise.
    pub fn rank(&self) -> usize {
        self.vrank
    }

    /// Number of ranks addressable from this scope (`p`): the communicator
    /// size inside [`with_comm`](Self::with_comm), the world size otherwise.
    pub fn size(&self) -> usize {
        self.vsize
    }

    /// This rank's world id, regardless of any communicator scope.
    pub fn world_rank(&self) -> usize {
        self.rank
    }

    /// World rank of scope-relative rank `r` (identity in world scope).
    /// Lets hierarchical collectives build sub-communicators of the
    /// *current* scope without assuming they run at world level.
    pub fn scope_world_rank(&self, r: usize) -> usize {
        match &self.comm {
            None => r,
            Some(c) => c.world_rank(r),
        }
    }

    /// Context id of the active scope ([`WORLD_CTX`] outside a comm).
    pub fn ctx_id(&self) -> u16 {
        self.tag_ctx
    }

    /// Run `f` with this context scoped to `comm`: `rank()`/`size()` and
    /// every peer argument become communicator-relative, and all message
    /// tags carry `comm`'s context id, so a collective inside the scope is
    /// match-isolated from collectives on any other communicator that are
    /// simultaneously in flight on the same world. Errors if this world
    /// rank is not a member. Scopes nest (membership is always looked up
    /// by world rank); the previous scope is restored on exit, including
    /// across panics (the persistent executor reuses this context for the
    /// next job).
    ///
    /// [`barrier`](Self::barrier) remains world-wide — it is an executor
    /// synchronization primitive, not a communicator collective; do not
    /// call it from code that only part of the world executes.
    pub fn with_comm<R>(
        &mut self,
        comm: &Comm,
        f: impl FnOnce(&mut Self) -> Result<R>,
    ) -> Result<R> {
        let Some(vrank) = comm.rank_of(self.rank) else {
            bail!(
                "world rank {} is not a member of communicator ctx={}",
                self.rank,
                comm.ctx()
            );
        };
        let saved = (self.comm.take(), self.vrank, self.vsize, self.tag_ctx);
        self.comm = Some(comm.clone());
        self.vrank = vrank;
        self.vsize = comm.size();
        self.tag_ctx = comm.ctx();
        let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(self)));
        self.comm = saved.0;
        self.vrank = saved.1;
        self.vsize = saved.2;
        self.tag_ctx = saved.3;
        match out {
            Ok(r) => r,
            Err(payload) => std::panic::resume_unwind(payload),
        }
    }

    /// Run `f` with all message tags carrying lane id `chunk` (the
    /// [`TagKey::chunk`] field): a wire-level sub-channel within the
    /// current scope's round numbering. The previous lane is restored on
    /// exit, including across panics.
    pub fn with_chunk<R>(
        &mut self,
        chunk: u16,
        f: impl FnOnce(&mut Self) -> Result<R>,
    ) -> Result<R> {
        let saved = self.tag_chunk;
        self.tag_chunk = chunk;
        let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(self)));
        self.tag_chunk = saved;
        match out {
            Ok(r) => r,
            Err(payload) => std::panic::resume_unwind(payload),
        }
    }

    /// Translate a scope-relative peer rank to its world rank.
    fn resolve_peer(&self, r: usize) -> Result<usize> {
        match &self.comm {
            None => Ok(r), // world scope; `post` bounds-checks
            Some(c) => {
                if r >= c.size() {
                    bail!(
                        "rank {} (ctx {}): peer {} out of range for communicator of size {}",
                        self.vrank,
                        self.tag_ctx,
                        r,
                        c.size()
                    );
                }
                Ok(c.world_rank(r))
            }
        }
    }

    /// The wire tag for `round` in the current scope.
    fn tag(&self, round: u32) -> u64 {
        TagKey::new(self.tag_ctx, self.tag_chunk, round).pack()
    }

    /// Current virtual clock (µs). 0 in real mode.
    pub fn vclock(&self) -> f64 {
        self.vclock
    }

    /// Reset the virtual clock and trace (between benchmark repetitions).
    pub fn reset_clock(&mut self) {
        self.vclock = 0.0;
        if let Some(t) = &mut self.trace {
            t.events.clear();
        }
    }

    /// Take the recorded trace (empties the log).
    pub fn take_trace(&mut self) -> Option<RankTrace> {
        self.trace.take()
    }

    /// Re-arm tracing after [`take_trace`](Self::take_trace) — called by
    /// the persistent [`World`](super::World) executor between jobs so a
    /// traced job does not silence tracing for the next one.
    pub(crate) fn rearm_trace(&mut self) {
        if self.tracing && self.trace.is_none() {
            self.trace = Some(RankTrace::new(self.rank));
        }
    }

    /// This rank's send-pool counters (hit rate must saturate in steady
    /// state — the transport's zero-allocation claim).
    pub fn pool_stats(&self) -> PoolStats {
        self.pool.stats()
    }

    /// This rank's inbox wait counters (spin probes / condvar parks) —
    /// the adaptive-rendezvous observability used by the hotpath latency
    /// sweep.
    pub fn inbox_stats(&self) -> InboxStats {
        self.transport.stats(self.rank)
    }

    /// Resolve `op` to its dispatch kernel for this collective, honouring
    /// the world's A/B flag (`WorldConfig::with_per_element_ops`): slice
    /// kernel by default, per-element reference when the flag is set.
    /// Call **once** at the top of an algorithm's `run` and pass the
    /// handle to the fused primitives — resolving per application would
    /// reintroduce the lookup this exists to hoist.
    pub fn kernel<'op>(&self, op: &'op OpRef<T>) -> OpKernel<'op, T> {
        if self.per_element {
            op.kernel_per_element()
        } else {
            op.kernel()
        }
    }

    fn bytes(len: usize) -> usize {
        len * T::size_bytes()
    }

    fn record(&mut self, round: u32, kind: EventKind) {
        if let Some(t) = &mut self.trace {
            t.push_ctx(self.tag_ctx, round, kind);
        }
    }

    /// `to` is a **world** rank (callers resolve communicator ranks via
    /// [`resolve_peer`](Self::resolve_peer) first). The tag carries the
    /// scope's full packed [`TagKey`]; chaos decisions key on it too, so
    /// injection stays pure in (seed, src, dst, ctx, chunk, round).
    fn post(&mut self, to: usize, round: u32, data: &[T]) -> Result<()> {
        if to >= self.size {
            bail!("rank {} sending to out-of-range rank {}", self.rank, to);
        }
        self.chaos_point();
        self.ensure_alive()?;
        let tag = self.tag(round);
        let msg = Msg {
            src: self.rank,
            tag,
            data: BufferPool::acquire_copy(&self.pool, data),
            vtime: self.vclock,
        };
        match self.chaos.as_ref().map(|c| c.plan_message(self.rank, to, tag)) {
            None | Some(ChaosAction::Deliver) => self.transport.post(to, msg),
            Some(ChaosAction::Delay { micros }) => self
                .transport
                .post_delayed(to, msg, Instant::now() + Duration::from_micros(micros)),
            Some(ChaosAction::Divert) => self.transport.post_overflow(to, msg),
            // Fault injection: the message is lost. The matching receive
            // surfaces it as a per-world recv_timeout error naming
            // (rank, round, src) — see tests/chaos_sweep.rs.
            Some(ChaosAction::Drop) => {}
        }
        Ok(())
    }

    /// Blocking matched receive: returns the message from **world** rank
    /// `from` with the scope's tag for `round`, buffering any other
    /// arrivals (including messages for other contexts or lanes).
    fn take(&mut self, from: usize, round: u32) -> Result<Msg<T>> {
        self.chaos_point();
        self.ensure_alive()?;
        let tag = self.tag(round);
        if let Some(i) = self.pending.iter().position(|m| m.src == from && m.tag == tag) {
            return Ok(self.pending.swap_remove(i));
        }
        let deadline = Instant::now() + self.recv_deadline;
        loop {
            // A typed wire-transport fault (budget-exhausted corruption,
            // stream reset, write timeout): register the faulted *source*
            // rank as dead — the engine's structural failure attribution
            // then classifies the run as RankFailed without parsing any
            // error string — and surface the typed fault itself.
            if let Some(f) = self.transport.fault() {
                self.dead.mark_dead(f.src);
                bail!(
                    "rank {} aborting receive (from={from}, round={round}): {f}",
                    self.rank
                );
            }
            // A rank that died before we started blocking: fail fast and
            // attributed rather than waiting out the full deadline for a
            // message that may never come (the whole job is doomed — every
            // survivor bails, the caller rebuilds the world).
            if self.dead.any() {
                bail!(
                    "rank {} aborting receive (from={from}, round={round}): rank(s) {:?} died (chaos rank-death)",
                    self.rank,
                    self.dead.list()
                );
            }
            match self.transport.take(self.rank, from, tag, &mut self.pending, deadline) {
                Some(msg) => return Ok(msg),
                None => {
                    // None is overloaded: poison wake-up (a rank died or
                    // the wire faulted — the next loop pass attributes
                    // it) or deadline expiry (a genuine lost message /
                    // deadlock). Distinguish by the fault slot, the
                    // registry and the clock; a spurious early return
                    // with none of them re-enters the receive with the
                    // remaining deadline.
                    if self.dead.any() || self.transport.fault().is_some() {
                        continue;
                    }
                    if Instant::now() < deadline {
                        continue;
                    }
                    if self.tag_ctx == WORLD_CTX {
                        bail!(
                            "rank {} deadlocked waiting for (from={from}, round={round}) \
                             [transport={}]",
                            self.rank,
                            self.transport.name()
                        );
                    }
                    bail!(
                        "rank {} deadlocked waiting for (from={from}, round={round}) on ctx={} \
                         [transport={}]",
                        self.rank,
                        self.tag_ctx,
                        self.transport.name()
                    );
                }
            }
        }
    }

    /// [`take`](Self::take) plus the element-count check every receive
    /// variant performs. `what` names the calling primitive for the error.
    fn take_expect(
        &mut self,
        from: usize,
        round: u32,
        expect: usize,
        what: &str,
    ) -> Result<Msg<T>> {
        let msg = self.take(from, round)?;
        if msg.data.len() != expect {
            bail!(
                "rank {}: {what} size mismatch from {} round {}: got {} want {}",
                self.rank,
                from,
                round,
                msg.data.len(),
                expect
            );
        }
        Ok(msg)
    }

    /// Trace + virtual-clock accounting for one completed receive.
    fn account_recv(&mut self, round: u32, from: usize, len: usize, vtime: f64) {
        self.record(round, EventKind::Recv { from, bytes: Self::bytes(len) });
        if let ClockMode::Virtual(model) = &self.mode {
            let c_in = model.round_cost(from, self.rank, Self::bytes(len));
            self.vclock = self.vclock.max(vtime) + c_in;
        }
    }

    /// Trace + virtual-clock accounting for one completed simultaneous
    /// send-receive (the round costs `max(c_out, c_in)` on top of the
    /// later of the two ranks' start times).
    fn account_sendrecv(
        &mut self,
        round: u32,
        to: usize,
        sent: usize,
        from: usize,
        len: usize,
        vtime: f64,
    ) {
        self.record(round, EventKind::Recv { from, bytes: Self::bytes(len) });
        if let ClockMode::Virtual(model) = &self.mode {
            let c_out = model.round_cost(self.rank, to, Self::bytes(sent));
            let c_in = model.round_cost(from, self.rank, Self::bytes(len));
            self.vclock = self.vclock.max(vtime) + c_out.max(c_in);
        }
    }

    /// One traced `⊕` application: sharded counter bump, trace event,
    /// virtual-clock advance. Every reduce — fused or explicit — funnels
    /// through here, so op counts and γ costs cannot diverge per path.
    /// Takes the **resolved** [`OpKernel`] (per-collective resolution):
    /// the application is a relaxed counter add plus the resolved slice
    /// call, with no per-application dyn lookup for built-in operators.
    fn fold(&mut self, round: u32, op: &OpKernel<T>, input: &[T], inout: &mut [T]) {
        op.apply_sharded(self.rank, input, inout);
        self.record(round, EventKind::Reduce { bytes: Self::bytes(input.len()) });
        if let ClockMode::Virtual(model) = &self.mode {
            self.vclock += model.reduce_cost(Self::bytes(input.len()));
        }
    }

    /// Fold a just-received message into `inout` (`inout = msg ⊕ inout`,
    /// the received partial being the earlier operand). Fused path: the
    /// combine reads straight from the pooled receive buffer. Unfused
    /// compat: copy into a pooled scratch first, then reduce — the
    /// pre-fusion extra memory pass, kept as the A/B reference.
    fn fold_msg(&mut self, round: u32, op: &OpKernel<T>, msg: Msg<T>, inout: &mut [T]) {
        if self.unfused {
            let tmp = BufferPool::acquire_copy(&self.pool, &msg.data);
            drop(msg); // recycle the transport buffer before reducing
            self.fold(round, op, &tmp, inout);
        } else {
            self.fold(round, op, &msg.data, inout);
        }
        // msg (fused path) drops here → its buffer recycles to the
        // sender's pool.
    }

    /// [`fold_msg`](Self::fold_msg) with the **local** value as the
    /// earlier operand: `keep = keep ⊕ msg`. The combine writes into the
    /// pooled receive buffer, then the result copies back into `keep`.
    fn fold_msg_right(&mut self, round: u32, op: &OpKernel<T>, mut msg: Msg<T>, keep: &mut [T]) {
        if self.unfused {
            let mut tmp = BufferPool::acquire_copy(&self.pool, &msg.data);
            drop(msg);
            self.fold(round, op, keep, &mut tmp);
            keep.copy_from_slice(&tmp);
        } else {
            self.fold(round, op, keep, &mut msg.data);
            keep.copy_from_slice(&msg.data);
        }
    }

    /// One-sided send in communication round `round` (one send-port slot).
    /// `to` is scope-relative (a communicator rank inside
    /// [`with_comm`](Self::with_comm)); traces record world ranks.
    pub fn send(&mut self, round: u32, to: usize, buf: &[T]) -> Result<()> {
        let to = self.resolve_peer(to)?;
        self.post(to, round, buf)?;
        self.record(round, EventKind::Send { to, bytes: Self::bytes(buf.len()) });
        if let ClockMode::Virtual(model) = &self.mode {
            self.vclock += model.round_cost(self.rank, to, Self::bytes(buf.len()));
        }
        Ok(())
    }

    /// One-sided receive in communication round `round` (one recv-port slot).
    pub fn recv(&mut self, round: u32, from: usize, buf: &mut [T]) -> Result<()> {
        let from = self.resolve_peer(from)?;
        let msg = self.take_expect(from, round, buf.len(), "recv")?;
        buf.copy_from_slice(&msg.data);
        self.account_recv(round, from, buf.len(), msg.vtime);
        Ok(())
    }

    /// Owned-buffer receive: like [`recv`](Self::recv) but hands back the
    /// transport's buffer instead of copying into a caller slice — the
    /// hot-path variant used by the scan algorithms (their only use of
    /// the received vector is as the read-only `input` of `reduce_local`,
    /// so no copy is ever needed). `expect` is the element count. The
    /// returned [`PoolBuf`] recycles to the sender's pool on drop.
    pub fn recv_owned(&mut self, round: u32, from: usize, expect: usize) -> Result<PoolBuf<T>> {
        let from = self.resolve_peer(from)?;
        let msg = self.take_expect(from, round, expect, "recv")?;
        self.account_recv(round, from, expect, msg.vtime);
        Ok(msg.data)
    }

    /// **Fused receive-reduce** — the compute hot path. Matches the
    /// `(from, round)` message and applies `inout = T ⊕ inout` (the
    /// received partial `T` is the earlier operand) directly from the
    /// pooled receive buffer: no owned handle crosses into the algorithm
    /// and the buffer recycles before this call returns. Trace and
    /// virtual-clock effects are exactly those of
    /// `recv_owned` + `reduce_local` (one `Recv`, one `Reduce`).
    pub fn recv_reduce(
        &mut self,
        round: u32,
        from: usize,
        op: &OpKernel<T>,
        inout: &mut [T],
    ) -> Result<()> {
        let from = self.resolve_peer(from)?;
        let msg = self.take_expect(from, round, inout.len(), "recv")?;
        self.account_recv(round, from, inout.len(), msg.vtime);
        self.fold_msg(round, op, msg, inout);
        Ok(())
    }

    /// Fused receive-reduce with the **local** value as the earlier
    /// operand: `keep = keep ⊕ T`. Used where the receiver's own partial
    /// covers earlier ranks than the received one (e.g. the Blelloch
    /// up-sweep folding a right-child segment). The combine writes into
    /// the pooled receive buffer and the result is copied back into
    /// `keep` — still one reduce pass plus one copy, with no
    /// algorithm-side temporary.
    pub fn recv_reduce_right(
        &mut self,
        round: u32,
        from: usize,
        op: &OpKernel<T>,
        keep: &mut [T],
    ) -> Result<()> {
        let from = self.resolve_peer(from)?;
        let msg = self.take_expect(from, round, keep.len(), "recv")?;
        self.account_recv(round, from, keep.len(), msg.vtime);
        self.fold_msg_right(round, op, msg, keep);
        Ok(())
    }

    /// Owned-buffer simultaneous send-receive (see [`recv_owned`](Self::recv_owned)).
    pub fn sendrecv_owned(
        &mut self,
        round: u32,
        to: usize,
        sbuf: &[T],
        from: usize,
        expect: usize,
    ) -> Result<PoolBuf<T>> {
        let (to, from) = (self.resolve_peer(to)?, self.resolve_peer(from)?);
        self.post(to, round, sbuf)?;
        self.record(round, EventKind::Send { to, bytes: Self::bytes(sbuf.len()) });
        let msg = self.take_expect(from, round, expect, "sendrecv")?;
        self.account_sendrecv(round, to, sbuf.len(), from, expect, msg.vtime);
        Ok(msg.data)
    }

    /// **Fused send-receive-reduce** for the doubling algorithms'
    /// steady-state rounds, where the value sent *is* the value kept:
    /// posts `keep`, matches the inbound `(from, round)` partial `T`, and
    /// folds `keep = T ⊕ keep` straight from the pooled receive buffer.
    /// Trace and virtual-clock effects are exactly those of
    /// `sendrecv_owned` + `reduce_local` (`Send`, `Recv`, `Reduce`).
    pub fn sendrecv_reduce(
        &mut self,
        round: u32,
        to: usize,
        from: usize,
        op: &OpKernel<T>,
        keep: &mut [T],
    ) -> Result<()> {
        let (to, from) = (self.resolve_peer(to)?, self.resolve_peer(from)?);
        self.post(to, round, keep)?;
        self.record(round, EventKind::Send { to, bytes: Self::bytes(keep.len()) });
        let msg = self.take_expect(from, round, keep.len(), "sendrecv")?;
        self.account_sendrecv(round, to, keep.len(), from, keep.len(), msg.vtime);
        self.fold_msg(round, op, msg, keep);
        Ok(())
    }

    /// [`sendrecv_reduce`](Self::sendrecv_reduce) with the **local** value
    /// as the earlier operand: posts `keep`, then `keep = keep ⊕ T` (the
    /// mpich baseline's non-commutative "reduce then swap", done in place
    /// in the pooled receive buffer).
    pub fn sendrecv_reduce_right(
        &mut self,
        round: u32,
        to: usize,
        from: usize,
        op: &OpKernel<T>,
        keep: &mut [T],
    ) -> Result<()> {
        let (to, from) = (self.resolve_peer(to)?, self.resolve_peer(from)?);
        self.post(to, round, keep)?;
        self.record(round, EventKind::Send { to, bytes: Self::bytes(keep.len()) });
        let msg = self.take_expect(from, round, keep.len(), "sendrecv")?;
        self.account_sendrecv(round, to, keep.len(), from, keep.len(), msg.vtime);
        self.fold_msg_right(round, op, msg, keep);
        Ok(())
    }

    /// Fused send-receive-reduce with a separately prepared send buffer
    /// (`sbuf` ≠ the kept partial): posts `sbuf`, folds the inbound
    /// partial into `inout`. This is the round-1 shape of the 123-doubling
    /// and two-⊕ algorithms, which send `W ⊕ V` while keeping `W`.
    pub fn sendrecv_reduce_into(
        &mut self,
        round: u32,
        to: usize,
        sbuf: &[T],
        from: usize,
        op: &OpKernel<T>,
        inout: &mut [T],
    ) -> Result<()> {
        let (to, from) = (self.resolve_peer(to)?, self.resolve_peer(from)?);
        self.post(to, round, sbuf)?;
        self.record(round, EventKind::Send { to, bytes: Self::bytes(sbuf.len()) });
        let msg = self.take_expect(from, round, inout.len(), "sendrecv")?;
        self.account_sendrecv(round, to, sbuf.len(), from, inout.len(), msg.vtime);
        self.fold_msg(round, op, msg, inout);
        Ok(())
    }

    /// Simultaneous send-receive — the paper's `Send(·,t) ∥ Recv(·,f)`:
    /// both transfers share one communication round; in the virtual clock
    /// the round costs `max(c_out, c_in)` on top of the later of the two
    /// ranks' start times.
    pub fn sendrecv(
        &mut self,
        round: u32,
        to: usize,
        sbuf: &[T],
        from: usize,
        rbuf: &mut [T],
    ) -> Result<()> {
        let (to, from) = (self.resolve_peer(to)?, self.resolve_peer(from)?);
        self.post(to, round, sbuf)?;
        self.record(round, EventKind::Send { to, bytes: Self::bytes(sbuf.len()) });
        let msg = self.take_expect(from, round, rbuf.len(), "sendrecv")?;
        rbuf.copy_from_slice(&msg.data);
        self.account_sendrecv(round, to, sbuf.len(), from, rbuf.len(), msg.vtime);
        Ok(())
    }

    /// `MPI_Reduce_local`: `inout = input ⊕ inout`, attributed to `round`.
    /// Advances the virtual clock by `γ·bytes` and bumps this rank's
    /// shard of the op counters.
    pub fn reduce_local(&mut self, round: u32, op: &OpKernel<T>, input: &[T], inout: &mut [T]) {
        self.fold(round, op, input, inout);
    }

    /// Local inclusive prefix scan over the first `n` row-major rows of
    /// `rows` (each `width` elements): row `j` becomes `row_0 ⊕ … ⊕
    /// row_j`, attributed to `round` — the local phase of the large-m
    /// block algorithms. One [`OpKernel::scan_sharded`] launch applies
    /// all `n − 1` ⊕ in a tight loop (no per-row dispatch), while the
    /// trace records the same `n − 1` [`Reduce`](EventKind::Reduce)
    /// events `reduce_local` would have — counters, traces and the γ
    /// clock cost stay exactly equivalent to the unfused row-by-row
    /// formulation, including for `width == 0` (where `fold` also counts
    /// applications on empty slices).
    pub fn scan_rows(&mut self, round: u32, op: &OpKernel<T>, rows: &mut [T], width: usize, n: usize) {
        op.scan_sharded(self.rank, rows, width, n);
        for _ in 1..n {
            self.record(round, EventKind::Reduce { bytes: Self::bytes(width) });
        }
        if let ClockMode::Virtual(model) = &self.mode {
            if n > 1 {
                self.vclock += model.reduce_cost(Self::bytes(width)) * (n - 1) as f64;
            }
        }
    }

    /// Pooled scratch buffer initialized to a copy of `src` — the
    /// replacement for algorithm-side `input.to_vec()` temporaries. The
    /// buffer comes from this rank's transport pool and recycles to it on
    /// drop, so steady-state use performs zero heap allocations (visible
    /// in [`pool_stats`](Self::pool_stats), asserted in
    /// `tests/transport.rs`).
    pub fn scratch_from(&self, src: &[T]) -> PoolBuf<T> {
        BufferPool::acquire_copy(&self.pool, src)
    }

    /// Pooled scratch buffer of `len` filler elements (the pooled
    /// counterpart of `vec![T::filler(); len]`).
    pub fn scratch_filled(&self, len: usize) -> PoolBuf<T> {
        BufferPool::acquire_filled(&self.pool, len, T::filler())
    }

    /// Barrier over all ranks. In virtual mode this also synchronizes the
    /// logical clocks to the global maximum, exactly as a real barrier
    /// aligns wall time. Every rank must call it the same number of times.
    pub fn barrier(&mut self) {
        self.chaos_point();
        match &self.mode {
            ClockMode::Real => self.barrier.wait(),
            ClockMode::Virtual(_) => {
                self.barrier_gen += 1;
                self.vclock = self.barrier.wait_max(self.barrier_gen, self.vclock);
            }
        }
    }

    /// True when running under the virtual (simulated-cluster) clock.
    pub fn is_virtual(&self) -> bool {
        matches!(self.mode, ClockMode::Virtual(_))
    }
}

#[cfg(test)]
mod tests {
    use crate::mpi::{ops, run_world, Topology, WorldConfig};

    #[test]
    fn recv_reduce_folds_received_as_earlier_operand() {
        let cfg = WorldConfig::new(Topology::flat(2));
        let out = run_world::<i64, Vec<i64>, _>(&cfg, |ctx| {
            let op = ops::bxor();
            let k = ctx.kernel(&op);
            if ctx.rank() == 0 {
                ctx.send(0, 1, &[1i64, 2])?;
                Ok(vec![])
            } else {
                let mut inout = vec![10i64, 20];
                ctx.recv_reduce(0, 0, &k, &mut inout)?;
                Ok(inout)
            }
        })
        .unwrap();
        assert_eq!(out[1], vec![1 ^ 10, 2 ^ 20]);
    }

    #[test]
    fn recv_reduce_right_keeps_local_as_earlier_operand() {
        use crate::mpi::Rec2;
        // Non-commutative compose: keep = keep ∘-earlier recv.
        let a = Rec2::new([2.0, 0.0, 0.0, 2.0], [1.0, 1.0]);
        let b = Rec2::new([1.0, 1.0, 0.0, 1.0], [0.0, 3.0]);
        let cfg = WorldConfig::new(Topology::flat(2));
        let out = run_world::<Rec2, Vec<Rec2>, _>(&cfg, |ctx| {
            let op = ops::rec2_compose();
            let k = ctx.kernel(&op);
            if ctx.rank() == 0 {
                ctx.send(0, 1, &[b])?;
                Ok(vec![])
            } else {
                let mut keep = vec![a];
                ctx.recv_reduce_right(0, 0, &k, &mut keep)?;
                Ok(keep)
            }
        })
        .unwrap();
        assert_eq!(out[1][0], a.then(&b), "keep must be the earlier operand");
    }

    #[test]
    fn sendrecv_reduce_ring_matches_manual() {
        // Every rank keeps its rank id and folds the left neighbour's in;
        // the fused ring must equal the recv_owned + reduce_local ring.
        let p = 8;
        let cfg = WorldConfig::new(Topology::flat(p));
        let fused = run_world::<i64, i64, _>(&cfg, |ctx| {
            let (r, p) = (ctx.rank(), ctx.size());
            let op = ops::sum_i64();
            let k = ctx.kernel(&op);
            let mut keep = [r as i64];
            ctx.sendrecv_reduce(0, (r + 1) % p, (r + p - 1) % p, &k, &mut keep)?;
            Ok(keep[0])
        })
        .unwrap();
        let two_step = run_world::<i64, i64, _>(&cfg, |ctx| {
            let (r, p) = (ctx.rank(), ctx.size());
            let op = ops::sum_i64();
            let k = ctx.kernel(&op);
            let mut keep = [r as i64];
            let t = ctx.sendrecv_owned(0, (r + 1) % p, &keep, (r + p - 1) % p, 1)?;
            ctx.reduce_local(0, &k, &t, &mut keep);
            Ok(keep[0])
        })
        .unwrap();
        assert_eq!(fused, two_step);
    }

    #[test]
    fn unfused_compat_is_bit_identical() {
        let mk = |unfused: bool| {
            let cfg =
                WorldConfig::new(Topology::flat(4)).with_unfused_compat(unfused);
            run_world::<i64, i64, _>(&cfg, |ctx| {
                let (r, p) = (ctx.rank(), ctx.size());
                let op = ops::bxor();
                let k = ctx.kernel(&op);
                let mut keep = [(r as i64) << 4 | 3];
                ctx.sendrecv_reduce(0, (r + 1) % p, (r + p - 1) % p, &k, &mut keep)?;
                ctx.sendrecv_reduce(1, (r + 2) % p, (r + p - 2) % p, &k, &mut keep)?;
                Ok(keep[0])
            })
            .unwrap()
        };
        assert_eq!(mk(false), mk(true));
    }

    #[test]
    fn with_comm_remaps_ranks_and_isolates_tags() {
        use crate::mpi::comm::CtxAlloc;
        use crate::mpi::Comm;
        // World of 4; comm over world ranks {1, 3}. Inside the scope the
        // members see rank 0/1 of a size-2 communicator, and their round-0
        // messages must not collide with a *world-scope* round-0 exchange
        // between the same physical ranks that is in flight simultaneously.
        let alloc = CtxAlloc::new();
        let comm = Comm::world(4).split(&alloc, &[0, 1, 0, 1])[1].clone();
        assert_eq!(comm.ranks(), &[1, 3]);
        let cfg = WorldConfig::new(Topology::flat(4));
        let out = run_world::<i64, (usize, usize, i64, i64), _>(&cfg, |ctx| {
            let w = ctx.rank();
            let mut seen = (usize::MAX, 0usize, 0i64, 0i64);
            if w == 1 || w == 3 {
                // World-scope round-0 exchange between 1 and 3 …
                let peer = 4 - w; // 1 <-> 3
                let sbuf = [w as i64 * 100];
                let mut rbuf = [0i64];
                ctx.send(0, peer, &sbuf)?;
                // … and a comm-scope round-0 exchange between the same two
                // ranks, posted before the world-scope receive: without
                // ctx isolation the keys (src, round 0) would collide.
                ctx.with_comm(&comm, |sub| {
                    seen.0 = sub.rank();
                    seen.1 = sub.size();
                    let speer = 1 - sub.rank();
                    sub.send(0, speer, &[sub.rank() as i64 + 7])?;
                    let mut r = [0i64];
                    sub.recv(0, speer, &mut r)?;
                    seen.2 = r[0];
                    Ok(())
                })?;
                ctx.recv(0, peer, &mut rbuf)?;
                seen.3 = rbuf[0];
                // Scope restored: world addressing again.
                assert_eq!(ctx.rank(), w);
                assert_eq!(ctx.size(), 4);
            }
            Ok(seen)
        })
        .unwrap();
        assert_eq!(out[1], (0, 2, 8, 300)); // comm rank 0; got comm peer's 1+7, world 3*100
        assert_eq!(out[3], (1, 2, 7, 100));
    }

    #[test]
    fn with_comm_rejects_non_members() {
        use crate::mpi::comm::CtxAlloc;
        use crate::mpi::Comm;
        let alloc = CtxAlloc::new();
        let comm = Comm::world(3).split(&alloc, &[0, 0, 1])[1].clone(); // {2}
        let cfg = WorldConfig::new(Topology::flat(3));
        let res = run_world::<i64, (), _>(&cfg, |ctx| {
            if ctx.rank() == 0 {
                ctx.with_comm(&comm, |_| Ok(()))?;
            }
            Ok(())
        });
        let err = format!("{:#}", res.unwrap_err());
        assert!(err.contains("not a member"), "{err}");
    }

    #[test]
    fn with_chunk_isolates_lanes_within_a_round() {
        // Two messages in the same (src, round) but different lanes must
        // match their own lane's receive, in either order.
        let cfg = WorldConfig::new(Topology::flat(2));
        let out = run_world::<i64, Vec<i64>, _>(&cfg, |ctx| {
            if ctx.rank() == 0 {
                ctx.with_chunk(1, |c| c.send(0, 1, &[11]))?;
                ctx.with_chunk(2, |c| c.send(0, 1, &[22]))?;
                Ok(vec![])
            } else {
                let mut a = [0i64];
                let mut b = [0i64];
                // Receive lane 2 first: cross-lane matching would hand
                // over lane 1's payload here.
                ctx.with_chunk(2, |c| c.recv(0, 0, &mut b))?;
                ctx.with_chunk(1, |c| c.recv(0, 0, &mut a))?;
                Ok(vec![a[0], b[0]])
            }
        })
        .unwrap();
        assert_eq!(out[1], vec![11, 22]);
    }

    #[test]
    fn scratch_buffers_recycle_through_the_rank_pool() {
        let cfg = WorldConfig::new(Topology::flat(1));
        run_world::<i64, (), _>(&cfg, |ctx| {
            drop(ctx.scratch_from(&[1, 2, 3])); // warm the pool (one miss)
            let before = ctx.pool_stats();
            for _ in 0..20 {
                let s = ctx.scratch_from(&[4, 5, 6]);
                assert_eq!(&*s, &[4i64, 5, 6][..]);
                let f = ctx.scratch_filled(2);
                assert_eq!(&*f, &[0i64, 0][..]);
            }
            let after = ctx.pool_stats();
            assert_eq!(
                after.misses,
                before.misses + 1,
                "only the first filled acquire may allocate (second slot)"
            );
            Ok(())
        })
        .unwrap();
    }
}
