//! A barrier that also synchronizes virtual clocks.
//!
//! In virtual-clock mode a barrier must make every rank resume at the
//! maximum clock over all ranks (that is what a real barrier does to wall
//! time). Implemented as a generation-stamped max-reduction slot around a
//! `std::sync::Barrier`: the first writer of each generation resets the
//! slot, so the barrier is reusable with no extra phase.

use std::sync::{Barrier, Mutex};

pub struct VBarrier {
    barrier: Barrier,
    slot: Mutex<(u64, f64)>, // (generation, max vclock)
}

impl VBarrier {
    pub fn new(n: usize) -> Self {
        VBarrier { barrier: Barrier::new(n), slot: Mutex::new((0, f64::NEG_INFINITY)) }
    }

    /// Plain rendezvous (real-clock mode).
    pub fn wait(&self) {
        self.barrier.wait();
    }

    /// Rendezvous and clock-sync: returns `max(vclock)` over all ranks.
    /// Every rank must pass a monotonically increasing `generation`
    /// starting at 1 and call this the same number of times.
    pub fn wait_max(&self, generation: u64, vclock: f64) -> f64 {
        {
            let mut s = self.slot.lock().unwrap();
            if s.0 != generation {
                *s = (generation, vclock);
            } else {
                s.1 = s.1.max(vclock);
            }
        }
        self.barrier.wait();
        let out = {
            let s = self.slot.lock().unwrap();
            debug_assert_eq!(s.0, generation);
            s.1
        };
        // Second rendezvous so no rank can start generation g+1's write
        // before every rank has read generation g's max.
        self.barrier.wait();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn max_is_global() {
        let n = 8;
        let b = Arc::new(VBarrier::new(n));
        let handles: Vec<_> = (0..n)
            .map(|r| {
                let b = Arc::clone(&b);
                std::thread::spawn(move || {
                    let m1 = b.wait_max(1, r as f64);
                    let m2 = b.wait_max(2, 100.0 - r as f64);
                    (m1, m2)
                })
            })
            .collect();
        for h in handles {
            let (m1, m2) = h.join().unwrap();
            assert_eq!(m1, 7.0);
            assert_eq!(m2, 100.0);
        }
    }

    #[test]
    fn reusable_many_generations() {
        let n = 4;
        let b = Arc::new(VBarrier::new(n));
        let handles: Vec<_> = (0..n)
            .map(|r| {
                let b = Arc::clone(&b);
                std::thread::spawn(move || {
                    let mut clock = r as f64;
                    for g in 1..=50u64 {
                        clock = b.wait_max(g, clock) + 1.0;
                    }
                    clock
                })
            })
            .collect();
        let res: Vec<f64> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        // All clocks converge after the first sync: 3.0 then +1 per gen.
        for c in res {
            assert_eq!(c, 3.0 + 50.0);
        }
    }
}
