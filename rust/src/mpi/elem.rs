//! Element types ("datatypes" in MPI terms) that scan vectors are made of.
//!
//! The paper benchmarks `MPI_LONG` (here [`i64`]); the library is generic
//! over any [`Elem`], including the composite [`Rec2`] element used by the
//! linear-recurrence examples (an "expensive ⊕" whose operator is
//! non-commutative — a good stress test for algorithm order-correctness).


/// Tag identifying an element type across the Rust/Python boundary (the AOT
/// artifact manifest uses the same names).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dtype {
    I64,
    U64,
    F32,
    F64,
    /// 2x2 affine recurrence element over f32: (A: 2x2 matrix, b: 2-vector).
    Rec2F32,
    /// Composed/lifted element types (e.g. segmented-scan pairs) that have
    /// no kernel artifact counterpart.
    Composite,
}

impl Dtype {
    pub fn name(&self) -> &'static str {
        match self {
            Dtype::I64 => "i64",
            Dtype::U64 => "u64",
            Dtype::F32 => "f32",
            Dtype::F64 => "f64",
            Dtype::Rec2F32 => "rec2_f32",
            Dtype::Composite => "composite",
        }
    }
}

/// An element of a scan vector. `Copy + Send + 'static` so vectors can move
/// between rank threads; `size_bytes` feeds the β/γ cost terms.
pub trait Elem: Copy + Clone + Send + Sync + std::fmt::Debug + PartialEq + 'static {
    const DTYPE: Dtype;

    /// Identity-ish default used to size receive buffers (NOT assumed to be
    /// an identity of any operator — the algorithms never rely on one;
    /// exclusive prefix 0 is left as the caller-provided initial value, per
    /// MPI_Exscan semantics where output on rank 0 is undefined).
    fn filler() -> Self;

    /// Size in bytes, for the cost model (`size_of::<Self>()` for all impls).
    fn size_bytes() -> usize {
        std::mem::size_of::<Self>()
    }

    /// Bytes of one element's **wire encoding** — the padding-free
    /// little-endian form the cross-process transports (shm rings, socket
    /// frames) ship. Distinct from [`size_bytes`](Self::size_bytes): the
    /// in-memory layout may carry padding (e.g. `Seg<T>` packs its `bool`
    /// flag into one byte on the wire), and the encoding is explicit per
    /// field so no uninitialized padding bytes are ever read.
    fn wire_bytes() -> usize;

    /// Append this element's wire encoding (exactly
    /// [`wire_bytes`](Self::wire_bytes) bytes) to `out`.
    fn write_wire(&self, out: &mut Vec<u8>);

    /// Decode one element from `bytes[..Self::wire_bytes()]`. Callers
    /// guarantee the slice is at least that long (the frame codec
    /// length-checks payloads before decoding).
    fn read_wire(bytes: &[u8]) -> Self;
}

/// The scalar impls share one shape: `to_le_bytes`/`from_le_bytes` over
/// the full in-memory width (no padding to skip).
macro_rules! scalar_wire {
    () => {
        fn wire_bytes() -> usize {
            std::mem::size_of::<Self>()
        }
        fn write_wire(&self, out: &mut Vec<u8>) {
            out.extend_from_slice(&self.to_le_bytes());
        }
        fn read_wire(bytes: &[u8]) -> Self {
            let mut raw = [0u8; std::mem::size_of::<Self>()];
            raw.copy_from_slice(&bytes[..std::mem::size_of::<Self>()]);
            Self::from_le_bytes(raw)
        }
    };
}

impl Elem for i64 {
    const DTYPE: Dtype = Dtype::I64;
    fn filler() -> Self {
        0
    }
    scalar_wire!();
}

impl Elem for u64 {
    const DTYPE: Dtype = Dtype::U64;
    fn filler() -> Self {
        0
    }
    scalar_wire!();
}

impl Elem for f32 {
    const DTYPE: Dtype = Dtype::F32;
    fn filler() -> Self {
        0.0
    }
    scalar_wire!();
}

impl Elem for f64 {
    const DTYPE: Dtype = Dtype::F64;
    fn filler() -> Self {
        0.0
    }
    scalar_wire!();
}

/// Element of the 2x2 affine linear recurrence `x_i = A_i x_{i-1} + b_i`.
///
/// The scan operator composes affine maps: applying `e1` then `e2` gives
/// `(A2·A1, A2·b1 + b2)`. This operator is associative but NOT commutative,
/// and is deliberately "expensive" (22 flops/element) — the regime where the
/// paper's ⊕-application counts matter most.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Rec2 {
    /// Row-major 2x2 matrix A.
    pub a: [f32; 4],
    /// Offset vector b.
    pub b: [f32; 2],
}

impl Rec2 {
    pub fn identity() -> Self {
        Rec2 { a: [1.0, 0.0, 0.0, 1.0], b: [0.0, 0.0] }
    }

    pub fn new(a: [f32; 4], b: [f32; 2]) -> Self {
        Rec2 { a, b }
    }

    /// Compose: `self` applied first, then `later` (i.e. `later ∘ self`).
    pub fn then(&self, later: &Rec2) -> Rec2 {
        let (m, n) = (&later.a, &self.a);
        Rec2 {
            a: [
                m[0] * n[0] + m[1] * n[2],
                m[0] * n[1] + m[1] * n[3],
                m[2] * n[0] + m[3] * n[2],
                m[2] * n[1] + m[3] * n[3],
            ],
            b: [
                m[0] * self.b[0] + m[1] * self.b[1] + later.b[0],
                m[2] * self.b[0] + m[3] * self.b[1] + later.b[1],
            ],
        }
    }

    /// Apply the affine map to a state vector.
    pub fn apply(&self, x: [f32; 2]) -> [f32; 2] {
        [
            self.a[0] * x[0] + self.a[1] * x[1] + self.b[0],
            self.a[2] * x[0] + self.a[3] * x[1] + self.b[1],
        ]
    }
}

impl Elem for Rec2 {
    const DTYPE: Dtype = Dtype::Rec2F32;
    fn filler() -> Self {
        Rec2::identity()
    }
    fn wire_bytes() -> usize {
        24 // 6 × f32, field by field — repr(Rust) offers no layout promise
    }
    fn write_wire(&self, out: &mut Vec<u8>) {
        for v in self.a {
            out.extend_from_slice(&v.to_le_bytes());
        }
        for v in self.b {
            out.extend_from_slice(&v.to_le_bytes());
        }
    }
    fn read_wire(bytes: &[u8]) -> Self {
        let f = |i: usize| {
            let mut raw = [0u8; 4];
            raw.copy_from_slice(&bytes[i * 4..i * 4 + 4]);
            f32::from_le_bytes(raw)
        };
        Rec2 { a: [f(0), f(1), f(2), f(3)], b: [f(4), f(5)] }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes() {
        assert_eq!(i64::size_bytes(), 8);
        assert_eq!(f32::size_bytes(), 4);
        assert_eq!(Rec2::size_bytes(), 24);
    }

    #[test]
    fn wire_roundtrip_every_elem() {
        fn rt<T: Elem>(v: T) {
            let mut buf = Vec::new();
            v.write_wire(&mut buf);
            assert_eq!(buf.len(), T::wire_bytes());
            assert_eq!(T::read_wire(&buf), v);
        }
        rt(-37i64);
        rt(u64::MAX - 3);
        rt(1.5f32);
        rt(-0.25f64);
        rt(Rec2::new([1.0, -2.0, 3.5, 0.0], [9.0, -1.0]));
    }

    #[test]
    fn rec2_identity_neutral() {
        let e = Rec2::new([1.0, 2.0, 3.0, 4.0], [5.0, 6.0]);
        let id = Rec2::identity();
        assert_eq!(id.then(&e), e);
        assert_eq!(e.then(&id), e);
    }

    #[test]
    fn rec2_associative_not_commutative() {
        let x = Rec2::new([1.0, 2.0, 0.0, 1.0], [1.0, 0.0]);
        let y = Rec2::new([0.5, 0.0, 1.0, 1.0], [0.0, 2.0]);
        let z = Rec2::new([2.0, 1.0, 1.0, 0.0], [3.0, -1.0]);
        let ab_c = x.then(&y).then(&z);
        let a_bc = x.then(&y.then(&z));
        for i in 0..4 {
            assert!((ab_c.a[i] - a_bc.a[i]).abs() < 1e-5);
        }
        for i in 0..2 {
            assert!((ab_c.b[i] - a_bc.b[i]).abs() < 1e-5);
        }
        assert_ne!(x.then(&y), y.then(&x));
    }

    #[test]
    fn rec2_apply_matches_composition() {
        let e1 = Rec2::new([2.0, 0.0, 0.0, 2.0], [1.0, 1.0]);
        let e2 = Rec2::new([1.0, 1.0, 0.0, 1.0], [0.0, 3.0]);
        let x0 = [1.0, -1.0];
        let step = e2.apply(e1.apply(x0));
        let composed = e1.then(&e2).apply(x0);
        assert!((step[0] - composed[0]).abs() < 1e-6);
        assert!((step[1] - composed[1]).abs() < 1e-6);
    }
}
