//! The **wire recovery layer** shared by both cross-process backends
//! (`mpi/shm.rs` rings and `mpi/socket.rs` meshes): sequence-number
//! accounting, duplicate suppression, NACK/retransmit repair of corrupt
//! frames with a bounded exponential-backoff retry budget, and the typed
//! [`TransportFault`] taxonomy that replaces every receiver-thread
//! `panic!` the backends used to contain corruption with.
//!
//! ## The protocol
//!
//! Every frame carries a per-(src → dst) channel sequence number
//! (`seq`, wire v2 — see `mpi/wire.rs`), assigned at encode time from a
//! monotone per-channel counter. The receiver tracks the next expected
//! seq per channel; both shm rings and socket streams are FIFO per
//! channel, so in a fault-free run the observed stream is exactly
//! 0, 1, 2, ….
//!
//! * **Duplicate suppression:** a frame with `seq <` expected is a
//!   replay (injected duplication, or a retransmission that crossed a
//!   repaired original) — dropped and counted, never double-delivered.
//! * **NACK/retransmit:** on a verification failure (bad header, bad
//!   checksum, truncation) the receiver NACKs the frame *by sequence
//!   number*. The sender keeps a bounded per-channel **retransmit
//!   shelf** of recently transmitted frames; because both backends run
//!   their channel endpoints in one process today, the NACK is serviced
//!   synchronously — the receiver pulls the shelved clean copy directly
//!   instead of round-tripping a control frame (the shelf would move
//!   into the shm segment / onto the socket once the multi-process
//!   launcher of ROADMAP item 3 lands; the protocol is already keyed
//!   for it). Each retry backs off exponentially (2^attempt µs, capped)
//!   and re-enters fault injection with the attempt number in the key,
//!   so a retransmission can itself be faulted.
//! * **Budget exhaustion:** after `max_attempts` transmission attempts
//!   (or a shelf miss — the bounded shelf evicted the frame), the
//!   receiver gives up with a typed [`TransportFault`] recording
//!   backend, channel, seq, fault kind and attempt count. The backend
//!   stores it (first-wins), poisons every inbox, and the rank context
//!   turns it into the existing dead-rank / `RankFailed` attribution —
//!   a receiver thread never aborts the process.
//!
//! Recovery is **below the chaos boundary**: a repaired frame is
//! byte-identical to the original, so recovered runs stay bit-identical
//! to the clean thread-world oracle (outputs, traces, chaos digests) —
//! the gate `tests/wirefault.rs` holds both backends to.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use super::transport::TransportBackend;
use super::wire::{self, FrameHeader, HEADER_BYTES};
use super::wirefault::{
    WireFaultConfig, WireFaultKind, WireFaultPlan, WireFaultReport, WireMutation,
};

/// What a wire transport observed going wrong, as a receiver sees it —
/// the observable taxonomy (a receiver cannot tell a header flip from a
/// checksum smash; both verify as corruption).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransportFaultKind {
    /// Frame header failed structural decode (magic/version/kind/...).
    CorruptHeader,
    /// FNV checksum mismatch over header ∥ payload.
    ChecksumMismatch,
    /// Frame shorter than its header claims.
    Truncated,
    /// Sequence number jumped forward: a frame went missing on a FIFO
    /// channel.
    SeqGap,
    /// Header and checksum verified but the payload would not decode.
    UndecodablePayload,
    /// Peer stream reset mid-run (socket backends).
    ConnectionReset,
    /// Send-side write watchdog expired.
    WriteTimeout,
}

impl TransportFaultKind {
    pub fn name(self) -> &'static str {
        match self {
            TransportFaultKind::CorruptHeader => "corrupt-header",
            TransportFaultKind::ChecksumMismatch => "checksum-mismatch",
            TransportFaultKind::Truncated => "truncated",
            TransportFaultKind::SeqGap => "seq-gap",
            TransportFaultKind::UndecodablePayload => "undecodable-payload",
            TransportFaultKind::ConnectionReset => "connection-reset",
            TransportFaultKind::WriteTimeout => "write-timeout",
        }
    }
}

impl std::fmt::Display for TransportFaultKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// A typed, attributed transport failure: which backend, which ordered
/// channel, which frame, what kind, and how many transmission attempts
/// the recovery layer burned before giving up. This is the value that
/// replaces the old receiver-thread `panic!`s and the socket mesh's
/// first-wins fault *string* — it funnels through `poison_all` into the
/// engine's `RankFailed` attribution instead of aborting anything.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TransportFault {
    pub backend: TransportBackend,
    pub src: usize,
    pub dst: usize,
    pub seq: u64,
    pub kind: TransportFaultKind,
    pub attempts: u32,
}

impl std::fmt::Display for TransportFault {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "transport fault [{}]: channel {}→{} seq={} kind={} after {} attempt(s)",
            self.backend, self.src, self.dst, self.seq, self.kind, self.attempts
        )
    }
}

/// Whole-transport recovery/fault counters, surfaced by
/// `Transport::wire_stats` → `exscan transports` and the service
/// metrics. Monotonic over the transport's lifetime.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TransportStats {
    /// Frames repaired by a shelf retransmission.
    pub retransmits: u64,
    /// Simulated stream reconnects after an injected reset (sockets).
    pub reconnects: u64,
    /// Frames dropped by seq-based duplicate suppression.
    pub dropped_dups: u64,
    /// Fatal typed faults raised (budget exhaustion, resets without
    /// recovery, write timeouts).
    pub faults: u64,
}

impl TransportStats {
    /// Fold another transport's counters in (e.g. the engine's value and
    /// segmented worlds feeding one metrics gauge set).
    pub fn merge(&mut self, other: &TransportStats) {
        self.retransmits += other.retransmits;
        self.reconnects += other.reconnects;
        self.dropped_dups += other.dropped_dups;
        self.faults += other.faults;
    }
}

/// Verdict of [`WireRecovery::process_frame`] for one incoming frame.
pub enum FrameVerdict {
    /// Frame verified clean and in-order: decode and deposit these
    /// bytes (header ∥ payload, byte-identical to what was encoded).
    Deliver(Vec<u8>),
    /// Duplicate sequence number on this channel — suppressed.
    Dup,
}

/// Per-transport recovery state: seq counters and retransmit shelves
/// for every ordered channel, the optional fault-injection plan, the
/// first-wins typed fault slot, and the counters.
pub(crate) struct WireRecovery {
    backend: TransportBackend,
    p: usize,
    plan: Option<WireFaultPlan>,
    recover: bool,
    max_attempts: u32,
    shelf_cap: usize,
    /// Next seq to assign per channel (sender side), row-major src*p+dst.
    send_seq: Vec<AtomicU64>,
    /// Next seq expected per channel (receiver side). Each channel has a
    /// single consumer (the owning rank's drain / the pair's recv
    /// thread), so a plain store after load is race-free.
    expect_seq: Vec<AtomicU64>,
    /// Bounded FIFO of (seq, clean frame) per channel; empty (and never
    /// pushed) when no fault plan is armed.
    shelves: Vec<Mutex<VecDeque<(u64, Vec<u8>)>>>,
    retransmits: AtomicU64,
    reconnects: AtomicU64,
    dropped_dups: AtomicU64,
    faults: AtomicU64,
    fault: Mutex<Option<TransportFault>>,
}

/// Sender-side injection decisions for one frame, resolved at encode
/// time so backends apply them uniformly. (Stream resets are re-derived
/// on the socket send thread via [`WireRecovery::reset_planned`] — plan
/// decisions are pure, so no decision needs to cross threads.)
#[derive(Debug, Clone, Copy, Default)]
pub(crate) struct SendPlan {
    /// Write the frame to the wire twice.
    pub duplicate: bool,
}

impl WireRecovery {
    pub fn new(backend: TransportBackend, p: usize, cfg: Option<&WireFaultConfig>) -> Self {
        let (recover, max_attempts, shelf_cap) = match cfg {
            Some(c) => (c.recover, c.max_attempts.max(1), c.shelf_cap.max(1)),
            None => (true, 1, 1),
        };
        Self {
            backend,
            p,
            plan: cfg.map(|c| WireFaultPlan::new(c.clone())),
            recover,
            max_attempts,
            shelf_cap,
            send_seq: (0..p * p).map(|_| AtomicU64::new(0)).collect(),
            expect_seq: (0..p * p).map(|_| AtomicU64::new(0)).collect(),
            shelves: (0..p * p).map(|_| Mutex::new(VecDeque::new())).collect(),
            retransmits: AtomicU64::new(0),
            reconnects: AtomicU64::new(0),
            dropped_dups: AtomicU64::new(0),
            faults: AtomicU64::new(0),
            fault: Mutex::new(None),
        }
    }

    fn ch(&self, src: usize, dst: usize) -> usize {
        src * self.p + dst
    }

    /// Assign the next sequence number on channel src → dst.
    pub fn next_seq(&self, src: usize, dst: usize) -> u64 {
        self.send_seq[self.ch(src, dst)].fetch_add(1, Ordering::Relaxed)
    }

    /// Sender-side hook, called with the fully encoded frame: shelve a
    /// clean copy for possible retransmission and resolve the send-side
    /// injection decisions. Free (no copy, no decisions) when no fault
    /// plan is armed.
    pub fn on_send(&self, src: usize, dst: usize, seq: u64, frame: &[u8]) -> SendPlan {
        let Some(plan) = &self.plan else {
            return SendPlan::default();
        };
        {
            let mut shelf =
                self.shelves[self.ch(src, dst)].lock().unwrap_or_else(|e| e.into_inner());
            if shelf.len() >= self.shelf_cap {
                shelf.pop_front();
            }
            shelf.push_back((seq, frame.to_vec()));
        }
        let duplicate = plan.duplicate(src, dst, seq);
        if duplicate {
            plan.note(WireFaultKind::Duplicate, src, dst, seq, 0);
        }
        SendPlan { duplicate }
    }

    /// Whether the fault plan schedules a connection reset before the
    /// frame `seq` on channel src → dst. Decisions are pure in
    /// (seed, src, dst, seq), so the socket send thread re-derives the
    /// sampler's answer without any cross-thread marker. Always false
    /// for shm (rings have no stream to reset) and without a plan.
    pub fn reset_planned(&self, src: usize, dst: usize, seq: u64) -> bool {
        match &self.plan {
            Some(plan) => self.backend != TransportBackend::Shm && plan.reset(src, dst, seq),
            None => false,
        }
    }

    /// Whether faulted frames are repaired (retransmit/reconnect) or
    /// immediately fatal.
    pub fn recovery_enabled(&self) -> bool {
        self.recover
    }

    /// Record an applied stream reset + simulated reconnect (sockets,
    /// recovery enabled).
    pub fn note_reset_reconnect(&self, src: usize, dst: usize, seq: u64) {
        if let Some(plan) = &self.plan {
            plan.note(WireFaultKind::Reset, src, dst, seq, 0);
        }
        self.reconnects.fetch_add(1, Ordering::Relaxed);
    }

    /// Record an applied stream reset that will *not* be recovered
    /// (recovery disabled): the caller raises the typed fault.
    pub fn note_reset_fatal(&self, src: usize, dst: usize, seq: u64) {
        if let Some(plan) = &self.plan {
            plan.note(WireFaultKind::Reset, src, dst, seq, 0);
        }
    }

    /// Exponential backoff for transmission attempt `attempt`:
    /// 2^attempt µs, capped at 256 µs — long enough to be a real
    /// escalation ladder, short enough that a full retry budget costs
    /// well under a millisecond.
    pub fn backoff(attempt: u32) -> Duration {
        Duration::from_micros(1u64 << attempt.min(8))
    }

    /// Receiver-side path for one incoming frame (header ∥ payload,
    /// pristine as read from the ring/stream). Applies the fault plan's
    /// receiver-side mutations, verifies, repairs via the retransmit
    /// shelf inside the bounded backoff budget, suppresses duplicates,
    /// and either yields deliverable clean bytes or a typed fault.
    pub fn process_frame(
        &self,
        src: usize,
        dst: usize,
        frame: Vec<u8>,
    ) -> Result<FrameVerdict, TransportFault> {
        let seq = wire::peek_seq(&frame).unwrap_or(0);
        let fault = |kind: TransportFaultKind, attempts: u32| TransportFault {
            backend: self.backend,
            src,
            dst,
            seq,
            kind,
            attempts,
        };
        if frame.len() < HEADER_BYTES {
            // Backends always hand over at least a header's worth; this
            // is a framing bug, not an injected fault — still typed.
            return Err(self.raise(fault(TransportFaultKind::Truncated, 1)));
        }
        let mut wire_bytes = frame;
        let mut attempt: u32 = 0;
        loop {
            if let Some(plan) = &self.plan {
                if let Some(m) = plan.mutation(src, dst, seq, attempt) {
                    apply_mutation(&mut wire_bytes, m);
                    plan.note(m.kind, src, dst, seq, attempt);
                }
            }
            match validate_frame(&wire_bytes) {
                Ok(header) => {
                    let expect = &self.expect_seq[self.ch(src, dst)];
                    let e = expect.load(Ordering::Relaxed);
                    if header.seq < e {
                        self.dropped_dups.fetch_add(1, Ordering::Relaxed);
                        return Ok(FrameVerdict::Dup);
                    }
                    if header.seq > e {
                        return Err(self.raise(fault(TransportFaultKind::SeqGap, attempt + 1)));
                    }
                    expect.store(e + 1, Ordering::Relaxed);
                    return Ok(FrameVerdict::Deliver(wire_bytes));
                }
                Err(kind) => {
                    attempt += 1;
                    if !self.recover || attempt >= self.max_attempts {
                        return Err(self.raise(fault(kind, attempt)));
                    }
                    // NACK by seq: pull the shelved clean copy (the
                    // synchronous in-process form of the retransmit
                    // round-trip) after backing off.
                    std::thread::sleep(Self::backoff(attempt));
                    match self.shelf_fetch(src, dst, seq) {
                        Some(clean) => {
                            self.retransmits.fetch_add(1, Ordering::Relaxed);
                            wire_bytes = clean;
                        }
                        None => return Err(self.raise(fault(kind, attempt))),
                    }
                }
            }
        }
    }

    fn shelf_fetch(&self, src: usize, dst: usize, seq: u64) -> Option<Vec<u8>> {
        let shelf = self.shelves[self.ch(src, dst)].lock().unwrap_or_else(|e| e.into_inner());
        shelf.iter().find(|(s, _)| *s == seq).map(|(_, f)| f.clone())
    }

    /// Count and store a fatal fault (first one wins), returning it for
    /// the caller to propagate. The caller is responsible for poisoning
    /// its inboxes so blocked receivers wake and attribute it.
    pub fn raise(&self, f: TransportFault) -> TransportFault {
        self.faults.fetch_add(1, Ordering::Relaxed);
        let mut slot = self.fault.lock().unwrap_or_else(|e| e.into_inner());
        if slot.is_none() {
            *slot = Some(f);
        }
        f
    }

    /// Count and store a fault observed outside [`Self::process_frame`]
    /// (payload decode after delivery, stream-level errors), attributed
    /// to the channel's most recently accepted seq.
    pub fn raise_external(
        &self,
        src: usize,
        dst: usize,
        kind: TransportFaultKind,
    ) -> TransportFault {
        let seq =
            self.expect_seq[self.ch(src, dst)].load(Ordering::Relaxed).saturating_sub(1);
        self.raise(TransportFault { backend: self.backend, src, dst, seq, kind, attempts: 1 })
    }

    /// The backend this recovery layer is attached to.
    pub fn backend(&self) -> TransportBackend {
        self.backend
    }

    /// First recorded fatal fault, if any.
    pub fn fault(&self) -> Option<TransportFault> {
        *self.fault.lock().unwrap_or_else(|e| e.into_inner())
    }

    pub fn stats(&self) -> TransportStats {
        TransportStats {
            retransmits: self.retransmits.load(Ordering::Relaxed),
            reconnects: self.reconnects.load(Ordering::Relaxed),
            dropped_dups: self.dropped_dups.load(Ordering::Relaxed),
            faults: self.faults.load(Ordering::Relaxed),
        }
    }

    /// Injection report, when a fault plan is armed.
    pub fn report(&self) -> Option<WireFaultReport> {
        self.plan.as_ref().map(|p| p.report())
    }
}

/// Structural validation shared by both backends: header decode, length
/// agreement, checksum — classified into the observable fault taxonomy.
fn validate_frame(frame: &[u8]) -> Result<FrameHeader, TransportFaultKind> {
    if frame.len() < HEADER_BYTES {
        return Err(TransportFaultKind::Truncated);
    }
    let header = wire::decode_header(&frame[..HEADER_BYTES])
        .map_err(|_| TransportFaultKind::CorruptHeader)?;
    if frame.len() != HEADER_BYTES + header.payload_len {
        return Err(TransportFaultKind::Truncated);
    }
    wire::verify_payload(&frame[..HEADER_BYTES], &frame[HEADER_BYTES..])
        .map_err(|_| TransportFaultKind::ChecksumMismatch)?;
    Ok(header)
}

/// Apply one sampled receiver-side mutation to the frame bytes in
/// place — the moment "the wire" corrupts the frame.
fn apply_mutation(frame: &mut Vec<u8>, m: WireMutation) {
    match m.kind {
        WireFaultKind::HeaderFlip => {
            let bit = (m.raw as usize) % (HEADER_BYTES * 8);
            frame[bit / 8] ^= 1 << (bit % 8);
        }
        WireFaultKind::PayloadFlip => {
            let payload_bits = (frame.len() - HEADER_BYTES) * 8;
            if payload_bits == 0 {
                // m = 0 frames have no payload; corrupt the checksum
                // instead so the injection still lands.
                frame[HEADER_BYTES - 1] ^= 0x40;
            } else {
                let bit = (m.raw as usize) % payload_bits;
                frame[HEADER_BYTES + bit / 8] ^= 1 << (bit % 8);
            }
        }
        WireFaultKind::ChecksumSmash => {
            frame[wire::CHECKSUM_OFFSET] ^= 0xA5;
        }
        WireFaultKind::Truncate => {
            // Cut anywhere strictly inside the frame, header included.
            let keep = (m.raw as usize) % frame.len();
            frame.truncate(keep);
        }
        // Sender-side kinds never reach the mutation applier.
        WireFaultKind::Duplicate | WireFaultKind::Reset => {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpi::wire::{encode_frame, FrameKind};

    fn frame(seq: u64, data: &[i64]) -> Vec<u8> {
        encode_frame(FrameKind::Deliver, 0, 1, 7, 0, 0.0, seq, data)
    }

    fn clean_recovery() -> WireRecovery {
        WireRecovery::new(TransportBackend::Shm, 2, None)
    }

    #[test]
    fn clean_frames_deliver_in_order() {
        let r = clean_recovery();
        for seq in 0..5u64 {
            assert_eq!(r.next_seq(0, 1), seq);
            match r.process_frame(0, 1, frame(seq, &[seq as i64])).unwrap() {
                FrameVerdict::Deliver(bytes) => {
                    let h = wire::decode_header(&bytes[..HEADER_BYTES]).unwrap();
                    assert_eq!(h.seq, seq);
                }
                FrameVerdict::Dup => panic!("clean in-order frame flagged dup"),
            }
        }
        assert_eq!(r.stats(), TransportStats::default());
    }

    #[test]
    fn duplicates_are_suppressed_by_seq() {
        let r = clean_recovery();
        assert!(matches!(
            r.process_frame(0, 1, frame(0, &[1])).unwrap(),
            FrameVerdict::Deliver(_)
        ));
        assert!(matches!(r.process_frame(0, 1, frame(0, &[1])).unwrap(), FrameVerdict::Dup));
        assert_eq!(r.stats().dropped_dups, 1);
    }

    #[test]
    fn seq_gap_is_a_typed_fault() {
        let r = clean_recovery();
        let err = r.process_frame(0, 1, frame(3, &[1])).unwrap_err();
        assert_eq!(err.kind, TransportFaultKind::SeqGap);
        assert_eq!((err.src, err.dst, err.seq), (0, 1, 3));
        assert_eq!(r.fault(), Some(err));
        assert_eq!(r.stats().faults, 1);
    }

    #[test]
    fn corruption_recovers_from_the_shelf() {
        // Checksum smash on every first attempt, clean afterwards is not
        // expressible with one probability — instead corrupt the frame
        // bytes ourselves and verify the shelf repairs them.
        let cfg = WireFaultConfig {
            header_flip_prob: 0.0,
            payload_flip_prob: 0.0,
            checksum_prob: 0.0,
            truncate_prob: 0.0,
            duplicate_prob: 0.0,
            reset_prob: 0.0,
            ..WireFaultConfig::new(1)
        };
        let r = WireRecovery::new(TransportBackend::Shm, 2, Some(&cfg));
        let seq = r.next_seq(0, 1);
        let clean = frame(seq, &[42]);
        assert!(!r.on_send(0, 1, seq, &clean).duplicate);
        let mut corrupt = clean.clone();
        corrupt[HEADER_BYTES] ^= 0xFF; // payload corruption on the "wire"
        match r.process_frame(0, 1, corrupt).unwrap() {
            FrameVerdict::Deliver(bytes) => assert_eq!(bytes, clean),
            FrameVerdict::Dup => panic!("repaired frame flagged dup"),
        }
        assert_eq!(r.stats().retransmits, 1);
        assert_eq!(r.stats().faults, 0);
    }

    #[test]
    fn exhausted_budget_is_a_typed_fault_with_attempts() {
        // Without a plan there is no shelf, so recovery cannot repair:
        // set recover off via a plan with certain corruption.
        let cfg = WireFaultConfig::new(1)
            .with_checksum_prob(1.0)
            .with_header_flip_prob(0.0)
            .with_payload_flip_prob(0.0)
            .with_truncate_prob(0.0)
            .with_duplicate_prob(0.0)
            .with_reset_prob(0.0)
            .with_max_attempts(3);
        let r = WireRecovery::new(TransportBackend::Shm, 2, Some(&cfg));
        let seq = r.next_seq(0, 1);
        let clean = frame(seq, &[7]);
        r.on_send(0, 1, seq, &clean);
        let err = r.process_frame(0, 1, clean).unwrap_err();
        assert_eq!(err.kind, TransportFaultKind::ChecksumMismatch);
        assert_eq!(err.attempts, 3, "budget of 3 attempts burned");
        assert_eq!(r.stats().retransmits, 2, "two shelf retransmissions before giving up");
        let shown = err.to_string();
        assert!(shown.contains("checksum-mismatch"), "{shown}");
        assert!(shown.contains("0→1"), "{shown}");
    }

    #[test]
    fn recovery_disabled_faults_on_first_corruption() {
        let cfg = WireFaultConfig::new(1)
            .with_checksum_prob(1.0)
            .with_header_flip_prob(0.0)
            .with_payload_flip_prob(0.0)
            .with_truncate_prob(0.0)
            .with_duplicate_prob(0.0)
            .with_reset_prob(0.0)
            .without_recovery();
        let r = WireRecovery::new(TransportBackend::Uds, 2, Some(&cfg));
        let seq = r.next_seq(0, 1);
        let clean = frame(seq, &[7]);
        r.on_send(0, 1, seq, &clean);
        let err = r.process_frame(0, 1, clean).unwrap_err();
        assert_eq!(err.attempts, 1);
        assert_eq!(err.backend, TransportBackend::Uds);
        assert_eq!(r.stats().retransmits, 0);
    }

    #[test]
    fn mutations_always_yield_error_or_valid_decode() {
        // Property sweep: every mutation kind over many raws must leave
        // validate_frame either Ok (impossible here — all kinds damage
        // the checksummed region) or a classified error — never a panic.
        let base = frame(0, &[1, 2, 3]);
        for kind in [
            WireFaultKind::HeaderFlip,
            WireFaultKind::PayloadFlip,
            WireFaultKind::ChecksumSmash,
            WireFaultKind::Truncate,
        ] {
            for raw in 0..4096u64 {
                let mut f = base.clone();
                apply_mutation(&mut f, WireMutation { kind, raw });
                assert!(
                    validate_frame(&f).is_err(),
                    "{kind} raw={raw} slipped past validation"
                );
            }
        }
    }
}
