//! Seeded, replayable **wire-fault injection** for the cross-process
//! transports — the below-the-boundary sibling of [`chaos`](super::chaos).
//!
//! The chaos layer (PR 3) perturbs message *schedules* above the
//! transport: embargo, diversion, drops — decisions a correct transport
//! must survive by design. This module perturbs the *wire itself*, at
//! frame encode/decode and stream level, the hazards the shm rings and
//! socket meshes (PR 8) actually face in the world: flipped bits,
//! smashed checksums, truncated frames, replayed duplicates, and
//! mid-stream connection resets.
//!
//! Same design rules as `chaos.rs`:
//!
//! * **Pure decisions.** Every verdict is a pure function of
//!   `(seed, src, dst, seq, attempt)` via SplitMix64 — no RNG state, no
//!   ordering sensitivity. Two runs at the same seed inject the *same*
//!   faults on the same frames, which is what makes a fault run
//!   replayable from its seed alone and the recovery≡oracle gate in
//!   `tests/wirefault.rs` meaningful.
//! * **Attempt-keyed.** The retransmit path re-samples with the attempt
//!   number in the key: a corrupted first transmission does not doom its
//!   retransmission (or, at high probabilities, it may — which is
//!   exactly what exercises the bounded retry budget).
//! * **Accounted.** Every injection is counted per kind, XOR-folded
//!   into an order-insensitive schedule digest (occurrence-salted so
//!   repeats cannot cancel), and appended to a capped event log.
//!   [`WireFaultPlan::report`] snapshots all of it as a
//!   [`WireFaultReport`].
//!
//! Injection sits **below the chaos boundary**: with recovery enabled
//! (the default) a faulted run must be bit-identical — outputs, per-rank
//! traces, chaos digests — to the clean thread-world oracle, because
//! every fault is repaired before the frame reaches the inbox layer.
//! With recovery disabled, faults surface as typed
//! [`TransportFault`](super::recover::TransportFault)s instead.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Cap on the retained injection log (counters and the digest keep
/// accumulating past it; the log is for human replay triage).
pub const WIRE_FAULT_LOG_CAP: usize = 4096;

const SALT_HEADER: u64 = 0xFA17_0011;
const SALT_PAYLOAD: u64 = 0xFA17_0022;
const SALT_CHECKSUM: u64 = 0xFA17_0033;
const SALT_TRUNCATE: u64 = 0xFA17_0044;
const SALT_DUPLICATE: u64 = 0xFA17_0055;
const SALT_RESET: u64 = 0xFA17_0066;
const SALT_RAW: u64 = 0xFA17_0077;
const SALT_DIGEST: u64 = 0xFA17_00EE;

/// SplitMix64 finalizer — same mixer as `chaos.rs`, good avalanche.
fn mix(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

/// Uniform [0, 1) from a hash.
fn frac(h: u64) -> f64 {
    (h >> 11) as f64 / (1u64 << 53) as f64
}

/// The injectable wire-fault taxonomy.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum WireFaultKind {
    /// One bit flipped inside the 64-byte frame header.
    HeaderFlip,
    /// One bit flipped inside the payload bytes.
    PayloadFlip,
    /// The checksum field XORed with a constant (header and payload
    /// intact — isolates the verifier).
    ChecksumSmash,
    /// The frame cut short at an arbitrary byte boundary.
    Truncate,
    /// The frame written to the wire twice (same seq) — exercises
    /// duplicate suppression.
    Duplicate,
    /// Mid-stream connection reset (socket backends only; a shared
    /// memory ring has no connection to reset).
    Reset,
}

impl WireFaultKind {
    pub fn name(self) -> &'static str {
        match self {
            WireFaultKind::HeaderFlip => "header-flip",
            WireFaultKind::PayloadFlip => "payload-flip",
            WireFaultKind::ChecksumSmash => "checksum-smash",
            WireFaultKind::Truncate => "truncate",
            WireFaultKind::Duplicate => "duplicate",
            WireFaultKind::Reset => "reset",
        }
    }

    fn tag(self) -> u64 {
        match self {
            WireFaultKind::HeaderFlip => 1,
            WireFaultKind::PayloadFlip => 2,
            WireFaultKind::ChecksumSmash => 3,
            WireFaultKind::Truncate => 4,
            WireFaultKind::Duplicate => 5,
            WireFaultKind::Reset => 6,
        }
    }
}

impl std::fmt::Display for WireFaultKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Wire-fault injection profile. All probabilities are per frame
/// transmission attempt and independently sampled; the receiver-side
/// corruption kinds (header flip, payload flip, checksum smash,
/// truncation) are mutually exclusive per attempt — first sampled kind
/// wins, in that fixed order.
#[derive(Debug, Clone)]
pub struct WireFaultConfig {
    pub seed: u64,
    pub header_flip_prob: f64,
    pub payload_flip_prob: f64,
    pub checksum_prob: f64,
    pub truncate_prob: f64,
    pub duplicate_prob: f64,
    pub reset_prob: f64,
    /// Repair faults via the shared recovery layer (retransmit shelf,
    /// duplicate suppression, reconnect-with-backoff). When false, the
    /// first fault on a channel surfaces as a typed `TransportFault`.
    pub recover: bool,
    /// Retry budget per frame: total transmission attempts (first
    /// delivery included) before the fault is declared fatal.
    pub max_attempts: u32,
    /// Per-channel retransmit-shelf capacity in frames.
    pub shelf_cap: usize,
}

impl WireFaultConfig {
    /// Default profile: every fault kind armed at a low per-frame rate —
    /// enough to see several injections (and retransmits) in any
    /// collective of a few hundred frames, rare enough that back-to-back
    /// faults on one frame stay inside the default retry budget with
    /// overwhelming probability.
    pub fn new(seed: u64) -> Self {
        Self {
            seed,
            header_flip_prob: 0.02,
            payload_flip_prob: 0.03,
            checksum_prob: 0.02,
            truncate_prob: 0.02,
            duplicate_prob: 0.03,
            reset_prob: 0.01,
            recover: true,
            max_attempts: 6,
            shelf_cap: 1024,
        }
    }

    /// Fault-storm profile for soak runs: an order of magnitude hotter.
    pub fn storm(seed: u64) -> Self {
        Self {
            header_flip_prob: 0.08,
            payload_flip_prob: 0.10,
            checksum_prob: 0.08,
            truncate_prob: 0.08,
            duplicate_prob: 0.10,
            reset_prob: 0.04,
            ..Self::new(seed)
        }
    }

    pub fn with_header_flip_prob(mut self, p: f64) -> Self {
        self.header_flip_prob = p;
        self
    }

    pub fn with_payload_flip_prob(mut self, p: f64) -> Self {
        self.payload_flip_prob = p;
        self
    }

    pub fn with_checksum_prob(mut self, p: f64) -> Self {
        self.checksum_prob = p;
        self
    }

    pub fn with_truncate_prob(mut self, p: f64) -> Self {
        self.truncate_prob = p;
        self
    }

    pub fn with_duplicate_prob(mut self, p: f64) -> Self {
        self.duplicate_prob = p;
        self
    }

    pub fn with_reset_prob(mut self, p: f64) -> Self {
        self.reset_prob = p;
        self
    }

    /// Disable the recovery layer: the first injected fault must surface
    /// as a typed `TransportFault` → `RankFailed`, never a panic.
    pub fn without_recovery(mut self) -> Self {
        self.recover = false;
        self
    }

    pub fn with_max_attempts(mut self, n: u32) -> Self {
        self.max_attempts = n.max(1);
        self
    }

    pub fn with_shelf_cap(mut self, cap: usize) -> Self {
        self.shelf_cap = cap.max(1);
        self
    }
}

/// A receiver-side corruption verdict for one transmission attempt.
/// `raw` is a per-decision hash the applier folds down to a concrete bit
/// index / cut point (the plan cannot know frame lengths; the applier
/// takes `raw % len`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WireMutation {
    pub kind: WireFaultKind,
    pub raw: u64,
}

/// One recorded injection.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WireFaultEvent {
    pub kind: WireFaultKind,
    pub src: usize,
    pub dst: usize,
    pub seq: u64,
    /// Transmission attempt the fault landed on (0 = first delivery).
    pub attempt: u32,
}

/// Snapshot of everything a fault plan injected: per-kind counters, the
/// order-insensitive XOR digest (the replay fingerprint), and the capped
/// event log sorted by (src, dst, seq, attempt).
#[derive(Debug, Clone)]
pub struct WireFaultReport {
    pub seed: u64,
    pub header_flips: u64,
    pub payload_flips: u64,
    pub checksum_smashes: u64,
    pub truncations: u64,
    pub duplicates: u64,
    pub resets: u64,
    pub digest: u64,
    pub events: Vec<WireFaultEvent>,
}

impl WireFaultReport {
    /// Total injections across every kind.
    pub fn injected(&self) -> u64 {
        self.header_flips
            + self.payload_flips
            + self.checksum_smashes
            + self.truncations
            + self.duplicates
            + self.resets
    }
}

/// The seeded fault plan: pure decision functions plus the accounting
/// state (counters, digest, log) that the recovery layer feeds as it
/// applies the decisions.
pub struct WireFaultPlan {
    cfg: WireFaultConfig,
    header_flips: AtomicU64,
    payload_flips: AtomicU64,
    checksum_smashes: AtomicU64,
    truncations: AtomicU64,
    duplicates: AtomicU64,
    resets: AtomicU64,
    digest: AtomicU64,
    /// Occurrence counts per decision point, so a repeated injection at
    /// the same (src, dst, seq, attempt) — e.g. the mutation re-applied
    /// to a duplicated frame — salts the digest differently instead of
    /// XOR-cancelling (same trick as `chaos.rs`).
    seen: Mutex<HashMap<(usize, usize, u64, u32), u64>>,
    log: Mutex<Vec<WireFaultEvent>>,
}

impl WireFaultPlan {
    pub fn new(cfg: WireFaultConfig) -> Self {
        Self {
            cfg,
            header_flips: AtomicU64::new(0),
            payload_flips: AtomicU64::new(0),
            checksum_smashes: AtomicU64::new(0),
            truncations: AtomicU64::new(0),
            duplicates: AtomicU64::new(0),
            resets: AtomicU64::new(0),
            digest: AtomicU64::new(0),
            seen: Mutex::new(HashMap::new()),
            log: Mutex::new(Vec::new()),
        }
    }

    pub fn config(&self) -> &WireFaultConfig {
        &self.cfg
    }

    fn key(&self, salt: u64, src: usize, dst: usize, seq: u64, attempt: u32) -> u64 {
        mix(self
            .cfg
            .seed
            .wrapping_add(salt.wrapping_mul(0xC2B2_AE3D_27D4_EB4F))
            ^ (src as u64).wrapping_mul(0x1656_67B1_9E37_79F9)
            ^ (dst as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
            ^ seq.wrapping_mul(0x2545_F491_4F6C_DD1D)
            ^ ((attempt as u64) << 32 | attempt as u64))
    }

    /// Receiver-side corruption verdict for transmission attempt
    /// `attempt` of frame `seq` on channel src → dst. At most one
    /// corruption kind per attempt, sampled in fixed order.
    pub fn mutation(
        &self,
        src: usize,
        dst: usize,
        seq: u64,
        attempt: u32,
    ) -> Option<WireMutation> {
        let raw = self.key(SALT_RAW, src, dst, seq, attempt);
        let pick = |salt: u64, prob: f64| -> bool {
            prob > 0.0 && frac(self.key(salt, src, dst, seq, attempt)) < prob
        };
        let kind = if pick(SALT_HEADER, self.cfg.header_flip_prob) {
            WireFaultKind::HeaderFlip
        } else if pick(SALT_PAYLOAD, self.cfg.payload_flip_prob) {
            WireFaultKind::PayloadFlip
        } else if pick(SALT_CHECKSUM, self.cfg.checksum_prob) {
            WireFaultKind::ChecksumSmash
        } else if pick(SALT_TRUNCATE, self.cfg.truncate_prob) {
            WireFaultKind::Truncate
        } else {
            return None;
        };
        Some(WireMutation { kind, raw })
    }

    /// Sender-side verdict: write this frame to the wire twice?
    pub fn duplicate(&self, src: usize, dst: usize, seq: u64) -> bool {
        self.cfg.duplicate_prob > 0.0
            && frac(self.key(SALT_DUPLICATE, src, dst, seq, 0)) < self.cfg.duplicate_prob
    }

    /// Sender-side verdict: reset the stream before writing this frame?
    /// (Socket backends only; shm callers never consult it.)
    pub fn reset(&self, src: usize, dst: usize, seq: u64) -> bool {
        self.cfg.reset_prob > 0.0
            && frac(self.key(SALT_RESET, src, dst, seq, 0)) < self.cfg.reset_prob
    }

    /// Record one applied injection: count, fold into the digest, log.
    pub fn note(&self, kind: WireFaultKind, src: usize, dst: usize, seq: u64, attempt: u32) {
        let ctr = match kind {
            WireFaultKind::HeaderFlip => &self.header_flips,
            WireFaultKind::PayloadFlip => &self.payload_flips,
            WireFaultKind::ChecksumSmash => &self.checksum_smashes,
            WireFaultKind::Truncate => &self.truncations,
            WireFaultKind::Duplicate => &self.duplicates,
            WireFaultKind::Reset => &self.resets,
        };
        ctr.fetch_add(1, Ordering::Relaxed);
        let occurrence = {
            let mut seen = self.seen.lock().unwrap_or_else(|e| e.into_inner());
            let slot = seen.entry((src, dst, seq, attempt)).or_insert(0);
            let occ = *slot;
            *slot += 1;
            occ
        };
        let event = mix(self.key(SALT_DIGEST, src, dst, seq, attempt)
            ^ kind.tag().wrapping_mul(0xBF58_476D_1CE4_E5B9)
            ^ occurrence.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        self.digest.fetch_xor(event, Ordering::Relaxed);
        let mut log = self.log.lock().unwrap_or_else(|e| e.into_inner());
        if log.len() < WIRE_FAULT_LOG_CAP {
            log.push(WireFaultEvent { kind, src, dst, seq, attempt });
        }
    }

    /// Snapshot counters, digest and the (sorted) event log.
    pub fn report(&self) -> WireFaultReport {
        let mut events = self.log.lock().unwrap_or_else(|e| e.into_inner()).clone();
        events.sort_by_key(|e| (e.src, e.dst, e.seq, e.attempt, e.kind.tag()));
        WireFaultReport {
            seed: self.cfg.seed,
            header_flips: self.header_flips.load(Ordering::Relaxed),
            payload_flips: self.payload_flips.load(Ordering::Relaxed),
            checksum_smashes: self.checksum_smashes.load(Ordering::Relaxed),
            truncations: self.truncations.load(Ordering::Relaxed),
            resets: self.resets.load(Ordering::Relaxed),
            duplicates: self.duplicates.load(Ordering::Relaxed),
            digest: self.digest.load(Ordering::Relaxed),
            events,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decisions_are_pure_in_seed_and_key() {
        let a = WireFaultPlan::new(WireFaultConfig::storm(42));
        let b = WireFaultPlan::new(WireFaultConfig::storm(42));
        for src in 0..4 {
            for dst in 0..4 {
                for seq in 0..64u64 {
                    for attempt in 0..3u32 {
                        assert_eq!(
                            a.mutation(src, dst, seq, attempt),
                            b.mutation(src, dst, seq, attempt)
                        );
                    }
                    assert_eq!(a.duplicate(src, dst, seq), b.duplicate(src, dst, seq));
                    assert_eq!(a.reset(src, dst, seq), b.reset(src, dst, seq));
                }
            }
        }
    }

    #[test]
    fn different_seeds_differ() {
        let a = WireFaultPlan::new(WireFaultConfig::storm(1));
        let b = WireFaultPlan::new(WireFaultConfig::storm(2));
        let differs = (0..512u64).any(|seq| {
            a.mutation(0, 1, seq, 0) != b.mutation(0, 1, seq, 0)
                || a.duplicate(0, 1, seq) != b.duplicate(0, 1, seq)
        });
        assert!(differs, "seeds 1 and 2 produced identical fault streams");
    }

    #[test]
    fn storm_profile_injects_every_kind() {
        let plan = WireFaultPlan::new(WireFaultConfig::storm(7));
        let mut kinds = std::collections::HashSet::new();
        for src in 0..4 {
            for dst in 0..4 {
                for seq in 0..256u64 {
                    if let Some(m) = plan.mutation(src, dst, seq, 0) {
                        kinds.insert(m.kind);
                    }
                    if plan.duplicate(src, dst, seq) {
                        kinds.insert(WireFaultKind::Duplicate);
                    }
                    if plan.reset(src, dst, seq) {
                        kinds.insert(WireFaultKind::Reset);
                    }
                }
            }
        }
        for kind in [
            WireFaultKind::HeaderFlip,
            WireFaultKind::PayloadFlip,
            WireFaultKind::ChecksumSmash,
            WireFaultKind::Truncate,
            WireFaultKind::Duplicate,
            WireFaultKind::Reset,
        ] {
            assert!(kinds.contains(&kind), "storm profile never sampled {kind}");
        }
    }

    #[test]
    fn report_counts_and_digest_replay() {
        let drive = |seed: u64| {
            let plan = WireFaultPlan::new(WireFaultConfig::storm(seed));
            for seq in 0..200u64 {
                if let Some(m) = plan.mutation(1, 2, seq, 0) {
                    plan.note(m.kind, 1, 2, seq, 0);
                }
                if plan.duplicate(1, 2, seq) {
                    plan.note(WireFaultKind::Duplicate, 1, 2, seq, 0);
                }
            }
            plan.report()
        };
        let a = drive(9);
        let b = drive(9);
        assert!(a.injected() > 0, "storm at seed 9 must inject something");
        assert_eq!(a.digest, b.digest, "same seed, same drive ⇒ same digest");
        assert_eq!(a.events, b.events);
        let c = drive(10);
        assert_ne!(a.digest, c.digest, "different seed ⇒ different digest");
    }

    #[test]
    fn digest_does_not_cancel_on_repeats() {
        let plan = WireFaultPlan::new(WireFaultConfig::storm(3));
        plan.note(WireFaultKind::PayloadFlip, 0, 1, 5, 0);
        let once = plan.report().digest;
        plan.note(WireFaultKind::PayloadFlip, 0, 1, 5, 0);
        let twice = plan.report().digest;
        assert_ne!(once, 0);
        assert_ne!(twice, 0, "even repetition must not XOR-cancel to zero");
        assert_ne!(once, twice);
    }

    #[test]
    fn disabled_probabilities_never_fire() {
        let cfg = WireFaultConfig {
            header_flip_prob: 0.0,
            payload_flip_prob: 0.0,
            checksum_prob: 0.0,
            truncate_prob: 0.0,
            duplicate_prob: 0.0,
            reset_prob: 0.0,
            ..WireFaultConfig::new(5)
        };
        let plan = WireFaultPlan::new(cfg);
        for seq in 0..512u64 {
            assert_eq!(plan.mutation(0, 1, seq, 0), None);
            assert!(!plan.duplicate(0, 1, seq));
            assert!(!plan.reset(0, 1, seq));
        }
    }
}
