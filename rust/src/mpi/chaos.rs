//! Deterministic chaos injection for the rendezvous transport.
//!
//! The paper's Theorem-1 claims are only reproducible if the message
//! matching under every algorithm is *order-insensitive*: a message-passing
//! schedule may legally deliver any interleaving that respects per-edge
//! FIFO, and the slot/overflow/pending machinery of
//! [`Inbox`](super::inbox::Inbox) must produce bit-identical results under
//! all of them (the adversarial-schedule methodology of arXiv 2604.25667
//! and arXiv 2410.14234). This module makes those interleavings a
//! first-class, *seeded and replayable* test axis:
//!
//! * **Message embargo** — a deposited message may be held inside the
//!   receiver's inbox for a deterministic duration before it becomes
//!   matchable, reordering delivery across (src, round) keys. Embargoes
//!   always expire, so no chaos schedule can deadlock a correct program.
//! * **Slot diversion** — a message may be routed straight to the inbox's
//!   unordered overflow queue, exercising the overflow + pending paths
//!   that a collision-free schedule would never touch.
//! * **Scheduler perturbation** — deterministic `yield_now` injections at
//!   rank boundaries (send, blocking receive, barrier) shake thread
//!   interleavings without changing any message content.
//! * **Pool pressure** — the per-rank [`BufferPool`](super::pool) can be
//!   made to drop every Nth recycled buffer (forced misses) so algorithms
//!   are validated against cold-pool allocation paths too.
//! * **Targeted drops** — an exact (src, dst, round) message can be
//!   discarded to prove that lost messages surface as clean, attributed
//!   `recv_timeout` errors instead of hangs.
//! * **Rank death** — a `(rank, tick)` fault escalates a drop into the
//!   deterministic demise of a whole rank: past the trigger tick, every
//!   send/receive on that rank fails, its world poisons survivors'
//!   blocking receives, and the failure is *attributed* ("rank N died")
//!   rather than surfacing as an anonymous timeout.
//!
//! Every decision is a pure function of `(seed, src, dst, round)` or
//! `(seed, rank, tick)` — no global RNG state, no time dependence — so a
//! failing schedule reproduces from its seed alone (`exscan fuzz --seed`).
//! The [`ChaosReport`] additionally carries an order-insensitive digest of
//! all injected decisions, letting tests assert that two runs at the same
//! seed injected the *identical* schedule.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Tuning knobs for one world's chaos injection. Plain data; lives on
/// [`WorldConfig`](super::WorldConfig) and is cloned into the world's
/// shared [`Chaos`] state at construction.
#[derive(Debug, Clone)]
pub struct ChaosConfig {
    /// Root of every decision. Same seed ⇒ same injected schedule.
    pub seed: u64,
    /// Fraction of messages (per (src, dst, round) key) held under
    /// embargo before they become matchable. In [0, 1].
    pub delay_prob: f64,
    /// Upper bound of one embargo; the actual duration is a deterministic
    /// fraction of this. Keep well below the world's `recv_timeout`.
    pub max_delay: Duration,
    /// Fraction of messages diverted past their slot into the unordered
    /// overflow queue. In [0, 1].
    pub divert_prob: f64,
    /// Probability of an injected `yield_now` at each rank boundary
    /// (send, blocking receive, barrier). In [0, 1].
    pub yield_prob: f64,
    /// When nonzero, every Nth buffer returned to a rank's pool is
    /// dropped instead of retained — forced steady-state pool misses.
    pub pool_discard_period: u64,
    /// Messages to silently discard, keyed (src, dst, round) — the
    /// lost-message fault used by the `recv_timeout` tests.
    pub drop: Vec<(usize, usize, u64)>,
    /// Rank-death faults, keyed (rank, tick): once the rank's private
    /// chaos-point counter reaches `tick`, every subsequent send/receive
    /// on that rank fails deterministically — the in-job equivalent of
    /// the thread dying. The `>=` trigger (rather than `==`) is load-
    /// bearing: ticks also advance at barriers, where death is *not*
    /// checked (a dead rank inside `VBarrier::wait` would hang the
    /// world), so an exact match could be skipped over.
    pub rank_death: Vec<(usize, u64)>,
}

impl ChaosConfig {
    /// Default adversarial-but-safe profile: delays and diversions on,
    /// pool pressure and drops off.
    pub fn new(seed: u64) -> Self {
        ChaosConfig {
            seed,
            delay_prob: 0.35,
            max_delay: Duration::from_micros(200),
            divert_prob: 0.25,
            yield_prob: 0.2,
            pool_discard_period: 0,
            drop: Vec::new(),
            rank_death: Vec::new(),
        }
    }

    pub fn with_max_delay(mut self, d: Duration) -> Self {
        self.max_delay = d;
        self
    }

    pub fn with_delay_prob(mut self, p: f64) -> Self {
        self.delay_prob = p.clamp(0.0, 1.0);
        self
    }

    pub fn with_divert_prob(mut self, p: f64) -> Self {
        self.divert_prob = p.clamp(0.0, 1.0);
        self
    }

    pub fn with_yield_prob(mut self, p: f64) -> Self {
        self.yield_prob = p.clamp(0.0, 1.0);
        self
    }

    /// Drop every Nth recycled pool buffer (0 disables).
    pub fn with_pool_discard_period(mut self, period: u64) -> Self {
        self.pool_discard_period = period;
        self
    }

    /// Silently discard the message (src → dst, round).
    pub fn with_drop(mut self, src: usize, dst: usize, round: u64) -> Self {
        self.drop.push((src, dst, round));
        self
    }

    /// Kill `rank` once its chaos-point counter reaches `tick`: all of
    /// its later sends/receives fail deterministically and survivors see
    /// an attributed rank-failure instead of a bare timeout. Multiple
    /// entries for distinct ranks (or the same rank at increasing ticks
    /// after an engine rebuild) model periodic death for soak runs.
    pub fn with_rank_death(mut self, rank: usize, tick: u64) -> Self {
        self.rank_death.push((rank, tick));
        self
    }
}

/// What the chaos layer decided to do with one message.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChaosAction {
    /// Deliver normally (not logged).
    Deliver,
    /// Hold under embargo for this many microseconds before matchable.
    Delay { micros: u64 },
    /// Route past the slot into the overflow queue.
    Divert,
    /// Discard (fault injection).
    Drop,
}

/// One logged injection decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChaosEvent {
    pub src: usize,
    pub dst: usize,
    pub round: u64,
    pub action: ChaosAction,
}

/// Aggregate view of everything a world's chaos layer injected.
#[derive(Debug, Clone, Default)]
pub struct ChaosReport {
    pub seed: u64,
    pub delayed: u64,
    pub diverted: u64,
    pub dropped: u64,
    pub yields: u64,
    /// Distinct ranks this chaos instance killed.
    pub rank_deaths: u64,
    /// Order-insensitive digest over all message decisions: equal digests
    /// ⇒ the identical schedule was injected (replay check).
    pub schedule_digest: u64,
    /// The first [`SCHEDULE_LOG_CAP`] non-trivial decisions, for failure
    /// reports. (The digest covers the complete schedule.)
    pub events: Vec<ChaosEvent>,
}

/// Cap on the retained event log (the digest is uncapped).
pub const SCHEDULE_LOG_CAP: usize = 4096;

/// SplitMix64 finalizer: the one-way mixer behind every decision.
fn mix(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

/// Uniform fraction in [0, 1) from a hash.
fn frac(h: u64) -> f64 {
    (h >> 11) as f64 / (1u64 << 53) as f64
}

const SALT_DELAY: u64 = 0xD31A;
const SALT_DELAY_LEN: u64 = 0xD31B;
const SALT_DIVERT: u64 = 0xD1FE;
const SALT_YIELD: u64 = 0x71E1;

/// Shared per-world chaos state: immutable decisions + counters.
pub struct Chaos {
    cfg: ChaosConfig,
    delayed: AtomicU64,
    diverted: AtomicU64,
    dropped: AtomicU64,
    yields: AtomicU64,
    rank_deaths: AtomicU64,
    /// XOR-accumulated digest of message decisions — XOR commutes, so the
    /// digest is independent of the thread interleaving that records it.
    digest: AtomicU64,
    /// Per-key occurrence counts: the same (src, dst, round) key is
    /// re-planned across successive jobs on a persistent world, and its
    /// decision is pure in the key — without an occurrence salt, even
    /// repetition counts would XOR-cancel out of the digest. Re-plans of
    /// one key are serialized by the executor's job order, so the
    /// occurrence numbering is itself replay-deterministic.
    seen: Mutex<HashMap<(usize, usize, u64), u64>>,
    log: Mutex<Vec<ChaosEvent>>,
}

impl Chaos {
    pub(crate) fn new(cfg: ChaosConfig) -> Self {
        Chaos {
            cfg,
            delayed: AtomicU64::new(0),
            diverted: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
            yields: AtomicU64::new(0),
            rank_deaths: AtomicU64::new(0),
            digest: AtomicU64::new(0),
            seen: Mutex::new(HashMap::new()),
            log: Mutex::new(Vec::new()),
        }
    }

    /// Hash of one (salted) message key under this seed.
    fn key(&self, salt: u64, src: usize, dst: usize, round: u64) -> u64 {
        let k = (src as u64)
            .wrapping_mul(0xC2B2_AE3D_27D4_EB4F)
            .wrapping_add((dst as u64).wrapping_mul(0x1656_67B1_9E37_79F9))
            .wrapping_add(round.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        mix(self.cfg.seed ^ mix(salt ^ k))
    }

    /// Decide the fate of the message (src → dst, round). Pure in
    /// (seed, src, dst, round); counters and log record what was chosen.
    pub(crate) fn plan_message(&self, src: usize, dst: usize, round: u64) -> ChaosAction {
        let action = if self.cfg.drop.iter().any(|&(s, d, r)| (s, d, r) == (src, dst, round)) {
            ChaosAction::Drop
        } else if frac(self.key(SALT_DELAY, src, dst, round)) < self.cfg.delay_prob {
            let span = self.cfg.max_delay.as_micros() as u64;
            let micros = if span == 0 {
                0
            } else {
                // Never zero: a chosen delay must actually embargo.
                1 + self.key(SALT_DELAY_LEN, src, dst, round) % span
            };
            ChaosAction::Delay { micros }
        } else if frac(self.key(SALT_DIVERT, src, dst, round)) < self.cfg.divert_prob {
            ChaosAction::Divert
        } else {
            ChaosAction::Deliver
        };

        match action {
            ChaosAction::Deliver => {}
            other => {
                match other {
                    ChaosAction::Delay { .. } => self.delayed.fetch_add(1, Ordering::Relaxed),
                    ChaosAction::Divert => self.diverted.fetch_add(1, Ordering::Relaxed),
                    ChaosAction::Drop => self.dropped.fetch_add(1, Ordering::Relaxed),
                    ChaosAction::Deliver => unreachable!(),
                };
                let tag = match other {
                    ChaosAction::Delay { micros } => 0x100 | micros,
                    ChaosAction::Divert => 0x200,
                    ChaosAction::Drop => 0x300,
                    ChaosAction::Deliver => 0,
                };
                let occurrence = {
                    let mut seen = self.seen.lock().unwrap();
                    let n = seen.entry((src, dst, round)).or_insert(0);
                    *n += 1;
                    *n
                };
                let enc = mix(
                    self.key(0xE0E0, src, dst, round)
                        ^ tag
                        ^ occurrence.wrapping_mul(0x9E37_79B9_7F4A_7C15),
                );
                self.digest.fetch_xor(enc, Ordering::Relaxed);
                let mut log = self.log.lock().unwrap();
                if log.len() < SCHEDULE_LOG_CAP {
                    log.push(ChaosEvent { src, dst, round, action: other });
                }
            }
        }
        action
    }

    /// Whether `rank` is scheduled to die at or before chaos-point
    /// `tick`. Pure in `(cfg, rank, tick)` — the caller (RankCtx) owns
    /// the one-time transition and the side effects (poisoning inboxes,
    /// registering with the world's dead-rank set).
    pub(crate) fn should_die(&self, rank: usize, tick: u64) -> bool {
        self.cfg.rank_death.iter().any(|&(r, t)| r == rank && tick >= t)
    }

    /// Record one rank's (first) death for the report.
    pub(crate) fn note_death(&self) {
        self.rank_deaths.fetch_add(1, Ordering::Relaxed);
    }

    /// Deterministically yield the current thread at a rank boundary.
    /// `tick` is the rank's private, monotonically increasing chaos-point
    /// counter, so the decision sequence per rank is schedule-independent.
    pub(crate) fn maybe_yield(&self, rank: usize, tick: u64) {
        if self.cfg.yield_prob <= 0.0 {
            return;
        }
        let h = self.key(SALT_YIELD, rank, 0, tick);
        if frac(h) < self.cfg.yield_prob {
            self.yields.fetch_add(1, Ordering::Relaxed);
            std::thread::yield_now();
        }
    }

    pub fn report(&self) -> ChaosReport {
        let mut events = self.log.lock().unwrap().clone();
        // Canonical order: the log is appended from many rank threads, so
        // sort it to make reports comparable across replays. Entries with
        // equal keys are identical (the action is a pure function of the
        // key), so the sort is fully deterministic.
        events.sort_by_key(|e| (e.src, e.dst, e.round));
        ChaosReport {
            seed: self.cfg.seed,
            delayed: self.delayed.load(Ordering::Relaxed),
            diverted: self.diverted.load(Ordering::Relaxed),
            dropped: self.dropped.load(Ordering::Relaxed),
            yields: self.yields.load(Ordering::Relaxed),
            rank_deaths: self.rank_deaths.load(Ordering::Relaxed),
            schedule_digest: self.digest.load(Ordering::Relaxed),
            events,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decisions_are_pure_in_seed_and_key() {
        let a = Chaos::new(ChaosConfig::new(42));
        let b = Chaos::new(ChaosConfig::new(42));
        for src in 0..8 {
            for dst in 0..8 {
                for round in 0..32u64 {
                    assert_eq!(
                        a.plan_message(src, dst, round),
                        b.plan_message(src, dst, round),
                        "src={src} dst={dst} round={round}"
                    );
                }
            }
        }
        let (ra, rb) = (a.report(), b.report());
        assert_eq!(ra.schedule_digest, rb.schedule_digest);
        assert_eq!(ra.delayed, rb.delayed);
        assert_eq!(ra.diverted, rb.diverted);
        assert_eq!(ra.events, rb.events);
    }

    #[test]
    fn different_seeds_give_different_schedules() {
        let a = Chaos::new(ChaosConfig::new(1));
        let b = Chaos::new(ChaosConfig::new(2));
        let mut differs = false;
        for src in 0..4 {
            for round in 0..64u64 {
                if a.plan_message(src, src + 1, round) != b.plan_message(src, src + 1, round) {
                    differs = true;
                }
            }
        }
        assert!(differs, "two seeds must not inject the same schedule");
        assert_ne!(a.report().schedule_digest, b.report().schedule_digest);
    }

    #[test]
    fn default_profile_injects_all_kinds() {
        let c = Chaos::new(ChaosConfig::new(7));
        for src in 0..16 {
            for dst in 0..16 {
                for round in 0..16u64 {
                    c.plan_message(src, dst, round);
                }
            }
        }
        let r = c.report();
        assert!(r.delayed > 0, "{r:?}");
        assert!(r.diverted > 0, "{r:?}");
        assert_eq!(r.dropped, 0, "no drops unless configured: {r:?}");
        // Frequencies in the right ballpark of the configured probabilities.
        let total = 16u64 * 16 * 16;
        assert!(r.delayed > total / 5 && r.delayed < total / 2, "{r:?}");
    }

    #[test]
    fn targeted_drop_matches_exactly() {
        let c = Chaos::new(
            ChaosConfig::new(3).with_delay_prob(0.0).with_divert_prob(0.0).with_drop(1, 2, 9),
        );
        assert_eq!(c.plan_message(1, 2, 9), ChaosAction::Drop);
        assert_eq!(c.plan_message(1, 2, 8), ChaosAction::Deliver);
        assert_eq!(c.plan_message(2, 1, 9), ChaosAction::Deliver);
        assert_eq!(c.report().dropped, 1);
    }

    #[test]
    fn digest_does_not_cancel_on_even_repetition() {
        // The same key re-planned (successive jobs on a persistent world)
        // must keep perturbing the digest: occurrence-salted encodings
        // cannot XOR-cancel pairwise.
        let c = Chaos::new(ChaosConfig::new(11).with_delay_prob(1.0));
        c.plan_message(0, 1, 3);
        let once = c.report().schedule_digest;
        assert_ne!(once, 0);
        c.plan_message(0, 1, 3);
        let twice = c.report().schedule_digest;
        assert_ne!(twice, 0, "even repetition counts must stay visible");
        assert_ne!(twice, once);
    }

    #[test]
    fn rank_death_triggers_at_and_after_tick() {
        let c = Chaos::new(ChaosConfig::new(5).with_rank_death(2, 10));
        assert!(!c.should_die(2, 0));
        assert!(!c.should_die(2, 9));
        assert!(c.should_die(2, 10), "trigger tick is inclusive");
        assert!(c.should_die(2, 11), ">= trigger keeps firing (barrier ticks may skip exact)");
        assert!(!c.should_die(1, 10_000), "only the configured rank dies");
        assert_eq!(c.report().rank_deaths, 0, "should_die is pure; note_death counts");
        c.note_death();
        assert_eq!(c.report().rank_deaths, 1);
    }

    #[test]
    fn delays_are_bounded_and_nonzero() {
        let c = Chaos::new(ChaosConfig::new(11).with_delay_prob(1.0));
        for round in 0..200u64 {
            match c.plan_message(0, 1, round) {
                ChaosAction::Delay { micros } => {
                    assert!(micros >= 1 && micros <= 200, "micros={micros}");
                }
                other => panic!("delay_prob=1.0 must always delay, got {other:?}"),
            }
        }
    }
}
