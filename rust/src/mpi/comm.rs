//! Communicators with context ids, and the packed message tag they stamp.
//!
//! The transport matches messages on `(src, tag)`. Up to PR 3 the tag was a
//! bare round index, which is only unambiguous while **one** collective is
//! in flight per world — the round counter restarts at 0 for every
//! collective, so two concurrent scans would cross-match each other's
//! round-k messages. The scan service (see [`crate::svc`]) keeps many
//! collectives in flight on one persistent [`World`](super::World), so the
//! tag is widened into a packed [`TagKey`]:
//!
//! ```text
//! bit 63        48 47        32 31                    0
//!     ┌───────────┬────────────┬───────────────────────┐
//!     │ ctx (u16) │ chunk (u16)│      round (u32)      │
//!     └───────────┴────────────┴───────────────────────┘
//! ```
//!
//! * **ctx** — the communicator's context id. Collectives on different
//!   communicators are match-isolated even when their (src, round) pairs
//!   coincide. Context 0 ([`WORLD_CTX`]) is the implicit world scope of a
//!   bare [`RankCtx`](super::RankCtx), so a world-scope tag packs to
//!   exactly the old bare round value (bit-compatible with pre-comm
//!   traces and chaos drop keys).
//! * **chunk** — a sub-round lane id. The chunked pipeline
//!   ([`ExscanChunked`](crate::coll::ExscanChunked)) tags each chunk's
//!   lane here (its *trace* round index stays the distinct per-(round,
//!   chunk) value, which is what the one-ported invariants and the honest
//!   `q·C` round count key on — see that module's docs).
//! * **round** — the algorithm-defined communication-round index, exactly
//!   as before.
//!
//! A [`Comm`] is a *group* (world ranks, in communicator-rank order) plus a
//! context id. Creation follows MPI: [`Comm::world`] is the implicit full
//! communicator; `dup` clones the group under a fresh context;
//! `split` partitions by color. Context ids come from the owning world's
//! [`CtxAlloc`]; long-lived services that create communicators per batch
//! should recycle a fixed ring of dup'd communicators instead of
//! allocating forever (65 535 ids; the allocator panics on exhaustion
//! rather than silently aliasing live contexts).

use std::sync::atomic::{AtomicU16, Ordering};
use std::sync::Arc;

/// Context id of the implicit world scope (a bare `RankCtx` outside any
/// [`Comm`] scope). World-scope tags pack to the bare round value.
pub const WORLD_CTX: u16 = 0;

/// The packed message-matching key: (context, lane, round). See the module
/// docs for the bit layout and field semantics.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct TagKey {
    pub ctx: u16,
    pub chunk: u16,
    pub round: u32,
}

impl TagKey {
    pub fn new(ctx: u16, chunk: u16, round: u32) -> Self {
        TagKey { ctx, chunk, round }
    }

    /// Pack into the wire tag. Injective by construction: the three fields
    /// occupy disjoint bit ranges.
    pub fn pack(self) -> u64 {
        ((self.ctx as u64) << 48) | ((self.chunk as u64) << 32) | self.round as u64
    }

    /// Inverse of [`pack`](Self::pack).
    pub fn unpack(tag: u64) -> Self {
        TagKey {
            ctx: (tag >> 48) as u16,
            chunk: (tag >> 32) as u16,
            round: tag as u32,
        }
    }
}

/// Context-id allocator, owned by a [`World`](super::World). Ids start at 1
/// (0 is [`WORLD_CTX`]) and are never reused; exhaustion panics instead of
/// aliasing a live context (recycle communicators to avoid it — see the
/// module docs).
#[derive(Debug)]
pub struct CtxAlloc {
    next: AtomicU16,
}

impl Default for CtxAlloc {
    fn default() -> Self {
        Self::new()
    }
}

impl CtxAlloc {
    pub fn new() -> Self {
        CtxAlloc { next: AtomicU16::new(1) }
    }

    /// Allocate a fresh context id (≥ 1). Exhaustion panics *without*
    /// advancing the counter (compare-exchange, no wrap), so even a
    /// caught panic can never be followed by an alloc that aliases a
    /// live context.
    pub fn alloc(&self) -> u16 {
        let mut cur = self.next.load(Ordering::SeqCst);
        loop {
            assert!(
                cur != 0,
                "context ids exhausted (65535 allocated); recycle communicators"
            );
            match self.next.compare_exchange(
                cur,
                cur.wrapping_add(1),
                Ordering::SeqCst,
                Ordering::SeqCst,
            ) {
                Ok(_) => return cur,
                Err(now) => cur = now,
            }
        }
    }
}

/// A communicator: a context id plus the member world ranks in
/// communicator-rank order. Cheap to clone (the group is shared).
///
/// All addressing inside a [`with_comm`](super::RankCtx::with_comm) scope
/// is communicator-relative: `rank()`/`size()` report the member's position
/// and the group size, and peer arguments to the transport primitives are
/// communicator ranks. Messages are stamped with the context id, so
/// collectives on distinct communicators over one world can be in flight
/// simultaneously without cross-matching.
#[derive(Debug, Clone)]
pub struct Comm {
    ctx: u16,
    ranks: Arc<Vec<usize>>,
}

impl Comm {
    /// Construct from an explicit context id and member list (world ranks
    /// in communicator-rank order; must be non-empty and duplicate-free).
    ///
    /// The caller owns the context-id discipline: two communicators with
    /// the same `ctx` must never have collectives in flight on the same
    /// world at the same time (the scan service's ring recycling relies on
    /// exactly this, serialized by the executor's job latch).
    pub fn new(ctx: u16, ranks: Vec<usize>) -> Self {
        assert!(!ranks.is_empty(), "a communicator needs at least one member");
        let mut seen = ranks.clone();
        seen.sort_unstable();
        seen.dedup();
        assert_eq!(seen.len(), ranks.len(), "duplicate world rank in communicator");
        Comm { ctx, ranks: Arc::new(ranks) }
    }

    /// The implicit world communicator: context 0, all `p` ranks.
    pub fn world(p: usize) -> Self {
        Comm { ctx: WORLD_CTX, ranks: Arc::new((0..p).collect()) }
    }

    /// `MPI_Comm_dup`: same members, fresh context id — collectives on the
    /// duplicate are match-isolated from the parent's.
    pub fn dup(&self, alloc: &CtxAlloc) -> Comm {
        Comm { ctx: alloc.alloc(), ranks: Arc::clone(&self.ranks) }
    }

    /// `MPI_Comm_split`: partition the members by `colors` (one entry per
    /// member, in communicator-rank order). Returns one communicator per
    /// distinct color, ordered by color value; members keep their relative
    /// order (key = parent rank).
    pub fn split(&self, alloc: &CtxAlloc, colors: &[usize]) -> Vec<Comm> {
        assert_eq!(colors.len(), self.size(), "one color per member");
        let mut distinct: Vec<usize> = colors.to_vec();
        distinct.sort_unstable();
        distinct.dedup();
        distinct
            .into_iter()
            .map(|color| {
                let members: Vec<usize> = self
                    .ranks
                    .iter()
                    .zip(colors)
                    .filter(|(_, &c)| c == color)
                    .map(|(&w, _)| w)
                    .collect();
                Comm { ctx: alloc.alloc(), ranks: Arc::new(members) }
            })
            .collect()
    }

    /// This communicator's context id.
    pub fn ctx(&self) -> u16 {
        self.ctx
    }

    /// Number of members.
    pub fn size(&self) -> usize {
        self.ranks.len()
    }

    /// Member world ranks in communicator-rank order.
    pub fn ranks(&self) -> &[usize] {
        &self.ranks
    }

    /// World rank of communicator rank `r` (panics if out of range; the
    /// transport validates before calling).
    pub fn world_rank(&self, r: usize) -> usize {
        self.ranks[r]
    }

    /// Communicator rank of `world_rank`, or `None` for non-members.
    pub fn rank_of(&self, world_rank: usize) -> Option<usize> {
        // Groups are small (≤ p); a linear probe beats a map here.
        self.ranks.iter().position(|&w| w == world_rank)
    }

    /// Whether the members form a contiguous ascending world-rank range
    /// (the shape the scan service's segmented coalescing packs by).
    pub fn is_contiguous(&self) -> bool {
        self.ranks.windows(2).all(|w| w[1] == w[0] + 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tagkey_roundtrips_exhaustively_on_field_boundaries() {
        // Full cartesian boundary grid — every field at 0, 1, mid, max —
        // plus a dense sweep of the low rounds (the values real schedules
        // use).
        let ctxs = [0u16, 1, 2, 0x7FFF, 0xFFFE, 0xFFFF];
        let chunks = [0u16, 1, 7, 0x8000, 0xFFFF];
        let rounds = [0u32, 1, 2, 63, 0x1_0000, 0x7FFF_FFFF, u32::MAX];
        for &ctx in &ctxs {
            for &chunk in &chunks {
                for &round in &rounds {
                    let k = TagKey::new(ctx, chunk, round);
                    assert_eq!(TagKey::unpack(k.pack()), k, "{k:?}");
                }
            }
        }
        for round in 0..4096u32 {
            let k = TagKey::new(3, 5, round);
            assert_eq!(TagKey::unpack(k.pack()), k);
        }
    }

    #[test]
    fn tagkey_packing_is_collision_free() {
        // Distinct (ctx, round, chunk) triples must pack to distinct tags.
        let mut seen = std::collections::HashSet::new();
        for ctx in [0u16, 1, 9, 0xFFFF] {
            for chunk in [0u16, 1, 8, 0xFFFF] {
                for round in [0u32, 1, 17, 0xFFFF_FFFF] {
                    assert!(
                        seen.insert(TagKey::new(ctx, chunk, round).pack()),
                        "collision at ctx={ctx} chunk={chunk} round={round}"
                    );
                }
            }
        }
        assert_eq!(seen.len(), 4 * 4 * 4);
    }

    #[test]
    fn world_scope_tags_are_bare_rounds() {
        // ctx 0 / chunk 0 packs to exactly the old bare round tag, keeping
        // pre-comm chaos drop keys and traces bit-compatible.
        for round in [0u32, 1, 2, 1000, u32::MAX] {
            assert_eq!(TagKey::new(WORLD_CTX, 0, round).pack(), round as u64);
        }
    }

    #[test]
    fn ctx_alloc_is_sequential_and_never_zero() {
        let a = CtxAlloc::new();
        assert_eq!(a.alloc(), 1);
        assert_eq!(a.alloc(), 2);
        assert_eq!(a.alloc(), 3);
    }

    #[test]
    fn world_comm_shape() {
        let w = Comm::world(5);
        assert_eq!(w.ctx(), WORLD_CTX);
        assert_eq!(w.size(), 5);
        assert_eq!(w.ranks(), &[0, 1, 2, 3, 4]);
        assert!(w.is_contiguous());
        assert_eq!(w.rank_of(3), Some(3));
        assert_eq!(w.rank_of(5), None);
    }

    #[test]
    fn dup_keeps_members_changes_ctx() {
        let alloc = CtxAlloc::new();
        let w = Comm::world(4);
        let a = w.dup(&alloc);
        let b = w.dup(&alloc);
        assert_eq!(a.ranks(), w.ranks());
        assert_eq!(b.ranks(), w.ranks());
        assert_ne!(a.ctx(), WORLD_CTX);
        assert_ne!(a.ctx(), b.ctx(), "dups must be match-isolated");
    }

    #[test]
    fn split_partitions_by_color_preserving_order() {
        let alloc = CtxAlloc::new();
        let w = Comm::world(6);
        // colors: even ranks → 0, odd ranks → 1
        let parts = w.split(&alloc, &[0, 1, 0, 1, 0, 1]);
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[0].ranks(), &[0, 2, 4]);
        assert_eq!(parts[1].ranks(), &[1, 3, 5]);
        assert_ne!(parts[0].ctx(), parts[1].ctx());
        assert!(!parts[0].is_contiguous());
        assert_eq!(parts[0].rank_of(4), Some(2));
        assert_eq!(parts[0].rank_of(1), None);
        assert_eq!(parts[1].world_rank(2), 5);
    }

    #[test]
    fn split_contiguous_halves() {
        let alloc = CtxAlloc::new();
        let w = Comm::world(8);
        let parts = w.split(&alloc, &[0, 0, 0, 0, 1, 1, 1, 1]);
        assert!(parts[0].is_contiguous() && parts[1].is_contiguous());
        assert_eq!(parts[1].ranks(), &[4, 5, 6, 7]);
        assert_eq!(parts[1].rank_of(6), Some(2));
    }

    #[test]
    fn split_of_split_nests() {
        let alloc = CtxAlloc::new();
        let w = Comm::world(8);
        let halves = w.split(&alloc, &[0, 0, 0, 0, 1, 1, 1, 1]);
        let quarters = halves[1].split(&alloc, &[0, 0, 1, 1]);
        assert_eq!(quarters[0].ranks(), &[4, 5]);
        assert_eq!(quarters[1].ranks(), &[6, 7]);
        let all: Vec<u16> =
            [&halves[0], &halves[1], &quarters[0], &quarters[1]].iter().map(|c| c.ctx()).collect();
        let mut dedup = all.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), all.len(), "every communicator gets its own context");
    }

    #[test]
    #[should_panic(expected = "duplicate world rank")]
    fn duplicate_members_rejected() {
        Comm::new(1, vec![0, 1, 1]);
    }
}
