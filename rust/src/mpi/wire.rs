//! Frame codec shared by the cross-process transports (shm rings and
//! socket streams): length-prefixed, versioned, checksummed message
//! frames carrying one [`Msg`](super::msg::Msg) each.
//!
//! A frame is a fixed 56-byte little-endian header followed by the
//! payload (`elem_count × T::wire_bytes()` bytes, elements encoded via
//! [`Elem::write_wire`](super::elem::Elem::write_wire)):
//!
//! | offset | size | field          | notes                                   |
//! |-------:|-----:|----------------|-----------------------------------------|
//! |      0 |    4 | magic          | `0x5853_434E` ("XSCN")                  |
//! |      4 |    2 | version        | [`WIRE_VERSION`]                        |
//! |      6 |    1 | kind           | 0 deliver · 1 delayed · 2 overflow      |
//! |      7 |    1 | reserved       | must be 0                               |
//! |      8 |    4 | src            | sender's **world** rank                 |
//! |     12 |    4 | dst            | receiver's world rank                   |
//! |     16 |    8 | tag            | packed `TagKey` (ctx, chunk, round)     |
//! |     24 |    8 | delay_micros   | embargo hold (kind = delayed only)      |
//! |     32 |    8 | vtime          | sender's virtual clock, f64 bits        |
//! |     40 |    4 | elem_count     | payload elements                        |
//! |     44 |    4 | payload_len    | payload bytes (= count × wire_bytes)    |
//! |     48 |    8 | checksum       | FNV-1a 64 over header[0..48] ∥ payload  |
//!
//! The `kind` byte ships the chaos plan over the wire: the sender's
//! [`plan_message`](super::chaos::Chaos::plan_message) decision (deliver /
//! embargo / divert-to-overflow) is made once at the send site and encoded
//! here, so the receiving side deposits into its local inbox through
//! exactly the same three entry points the thread backend uses — chaos
//! schedules, XOR digests and trace invariants are backend-independent by
//! construction. Checksum or header corruption is surfaced as an
//! attributed decode error, never a silent drop.

use anyhow::{bail, Result};

use super::elem::Elem;

/// "XSCN" — rejects cross-talk from anything that is not an exscan peer.
pub const WIRE_MAGIC: u32 = 0x5853_434E;
/// Bumped on any incompatible frame-layout change.
pub const WIRE_VERSION: u16 = 1;
/// Fixed header size in bytes.
pub const HEADER_BYTES: usize = 56;

/// How the receiving side must deposit the decoded message into its
/// local inbox — the sender's chaos decision, shipped in the frame.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrameKind {
    /// Normal delivery: `Inbox::deposit`.
    Deliver,
    /// Chaos embargo: `Inbox::deposit_delayed(now + delay_micros)`.
    Delayed,
    /// Chaos slot diversion: `Inbox::deposit_overflow`.
    Overflow,
}

impl FrameKind {
    fn code(self) -> u8 {
        match self {
            FrameKind::Deliver => 0,
            FrameKind::Delayed => 1,
            FrameKind::Overflow => 2,
        }
    }

    fn from_code(code: u8) -> Result<Self> {
        match code {
            0 => Ok(FrameKind::Deliver),
            1 => Ok(FrameKind::Delayed),
            2 => Ok(FrameKind::Overflow),
            other => bail!("wire: unknown frame kind {other}"),
        }
    }
}

/// Decoded frame header.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FrameHeader {
    pub kind: FrameKind,
    pub src: usize,
    pub dst: usize,
    pub tag: u64,
    pub delay_micros: u64,
    pub vtime: f64,
    pub elem_count: usize,
    pub payload_len: usize,
}

/// FNV-1a 64-bit over a byte stream — cheap, dependency-free, and enough
/// to catch framing bugs and torn writes (this is an integrity check
/// against software defects, not an adversarial MAC).
pub fn fnv1a(chunks: &[&[u8]]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for chunk in chunks {
        for &b in *chunk {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

/// Encode one message into a self-delimiting frame.
pub fn encode_frame<T: Elem>(
    kind: FrameKind,
    src: usize,
    dst: usize,
    tag: u64,
    delay_micros: u64,
    vtime: f64,
    data: &[T],
) -> Vec<u8> {
    let payload_len = data.len() * T::wire_bytes();
    let mut out = Vec::with_capacity(HEADER_BYTES + payload_len);
    out.extend_from_slice(&WIRE_MAGIC.to_le_bytes());
    out.extend_from_slice(&WIRE_VERSION.to_le_bytes());
    out.push(kind.code());
    out.push(0); // reserved
    out.extend_from_slice(&(src as u32).to_le_bytes());
    out.extend_from_slice(&(dst as u32).to_le_bytes());
    out.extend_from_slice(&tag.to_le_bytes());
    out.extend_from_slice(&delay_micros.to_le_bytes());
    out.extend_from_slice(&vtime.to_bits().to_le_bytes());
    out.extend_from_slice(&(data.len() as u32).to_le_bytes());
    out.extend_from_slice(&(payload_len as u32).to_le_bytes());
    debug_assert_eq!(out.len(), 48);
    for v in data {
        v.write_wire(&mut out);
    }
    let checksum = fnv1a(&[&out[..48], &out[48..]]);
    // Splice the checksum in at offset 48 (it was computed over
    // header[0..48] ∥ payload, i.e. with itself absent).
    let mut framed = Vec::with_capacity(HEADER_BYTES + payload_len);
    framed.extend_from_slice(&out[..48]);
    framed.extend_from_slice(&checksum.to_le_bytes());
    framed.extend_from_slice(&out[48..]);
    framed
}

fn le_u32(bytes: &[u8], at: usize) -> u32 {
    u32::from_le_bytes([bytes[at], bytes[at + 1], bytes[at + 2], bytes[at + 3]])
}

fn le_u64(bytes: &[u8], at: usize) -> u64 {
    let mut raw = [0u8; 8];
    raw.copy_from_slice(&bytes[at..at + 8]);
    u64::from_le_bytes(raw)
}

/// Decode and validate a frame header (`header.len() == HEADER_BYTES`).
/// The payload checksum is verified separately by
/// [`verify_payload`] once the payload bytes are available.
pub fn decode_header(header: &[u8]) -> Result<FrameHeader> {
    assert_eq!(header.len(), HEADER_BYTES);
    let magic = le_u32(header, 0);
    if magic != WIRE_MAGIC {
        bail!("wire: bad magic {magic:#010x} (want {WIRE_MAGIC:#010x})");
    }
    let version = u16::from_le_bytes([header[4], header[5]]);
    if version != WIRE_VERSION {
        bail!("wire: version {version} (this build speaks {WIRE_VERSION})");
    }
    if header[7] != 0 {
        bail!("wire: nonzero reserved byte {}", header[7]);
    }
    Ok(FrameHeader {
        kind: FrameKind::from_code(header[6])?,
        src: le_u32(header, 8) as usize,
        dst: le_u32(header, 12) as usize,
        tag: le_u64(header, 16),
        delay_micros: le_u64(header, 24),
        vtime: f64::from_bits(le_u64(header, 32)),
        elem_count: le_u32(header, 40) as usize,
        payload_len: le_u32(header, 44) as usize,
    })
}

/// Verify the frame checksum (header bytes with the checksum field as
/// transmitted at offset 48, payload bytes as received).
pub fn verify_payload(header: &[u8], payload: &[u8]) -> Result<()> {
    assert_eq!(header.len(), HEADER_BYTES);
    let want = le_u64(header, 48);
    let got = fnv1a(&[&header[..48], payload]);
    if got != want {
        bail!("wire: checksum mismatch (got {got:#018x}, frame says {want:#018x})");
    }
    Ok(())
}

/// Decode a verified payload into elements. Rejects length mismatches
/// (truncation, count/len disagreement) before touching element bytes.
pub fn decode_payload<T: Elem>(h: &FrameHeader, payload: &[u8]) -> Result<Vec<T>> {
    let stride = T::wire_bytes();
    if h.payload_len != h.elem_count * stride || payload.len() != h.payload_len {
        bail!(
            "wire: payload length {} != {} elements × {} bytes (header says {})",
            payload.len(),
            h.elem_count,
            stride,
            h.payload_len
        );
    }
    let mut out = Vec::with_capacity(h.elem_count);
    for i in 0..h.elem_count {
        out.push(T::read_wire(&payload[i * stride..(i + 1) * stride]));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpi::elem::Rec2;

    fn roundtrip<T: Elem>(kind: FrameKind, data: &[T]) {
        let frame = encode_frame(kind, 3, 5, 0xABCD_EF01, 150, 2.5, data);
        assert_eq!(frame.len(), HEADER_BYTES + data.len() * T::wire_bytes());
        let h = decode_header(&frame[..HEADER_BYTES]).unwrap();
        verify_payload(&frame[..HEADER_BYTES], &frame[HEADER_BYTES..]).unwrap();
        assert_eq!(h.kind, kind);
        assert_eq!((h.src, h.dst, h.tag), (3, 5, 0xABCD_EF01));
        assert_eq!(h.delay_micros, 150);
        assert_eq!(h.vtime, 2.5);
        let decoded: Vec<T> = decode_payload(&h, &frame[HEADER_BYTES..]).unwrap();
        assert_eq!(decoded, data);
    }

    #[test]
    fn frame_roundtrip_all_kinds_and_types() {
        roundtrip(FrameKind::Deliver, &[1i64, -2, i64::MAX]);
        roundtrip(FrameKind::Delayed, &[0.5f64; 17]);
        roundtrip(FrameKind::Overflow, &[] as &[i64]); // m = 0 frames exist
        roundtrip(
            FrameKind::Deliver,
            &[Rec2::new([1.0, 2.0, 3.0, 4.0], [5.0, 6.0]), Rec2::identity()],
        );
    }

    #[test]
    fn corruption_is_caught() {
        let mut frame = encode_frame(FrameKind::Deliver, 0, 1, 7, 0, 0.0, &[42i64]);
        // Flip one payload bit: checksum must catch it.
        frame[HEADER_BYTES] ^= 0x10;
        assert!(verify_payload(&frame[..HEADER_BYTES], &frame[HEADER_BYTES..]).is_err());
        // Bad magic / version / kind are rejected at header decode.
        let good = encode_frame(FrameKind::Deliver, 0, 1, 7, 0, 0.0, &[42i64]);
        let mut bad = good.clone();
        bad[0] ^= 0xFF;
        assert!(decode_header(&bad[..HEADER_BYTES]).is_err());
        let mut bad = good.clone();
        bad[4] = 99;
        assert!(decode_header(&bad[..HEADER_BYTES]).is_err());
        let mut bad = good.clone();
        bad[6] = 9;
        assert!(decode_header(&bad[..HEADER_BYTES]).is_err());
        // Truncated payload is rejected by the length check.
        let h = decode_header(&good[..HEADER_BYTES]).unwrap();
        assert!(decode_payload::<i64>(&h, &good[HEADER_BYTES..HEADER_BYTES + 4]).is_err());
    }
}
