//! Frame codec shared by the cross-process transports (shm rings and
//! socket streams): length-prefixed, versioned, checksummed message
//! frames carrying one [`Msg`](super::msg::Msg) each.
//!
//! A frame is a fixed 64-byte little-endian header followed by the
//! payload (`elem_count × T::wire_bytes()` bytes, elements encoded via
//! [`Elem::write_wire`](super::elem::Elem::write_wire)):
//!
//! | offset | size | field          | notes                                   |
//! |-------:|-----:|----------------|-----------------------------------------|
//! |      0 |    4 | magic          | `0x5853_434E` ("XSCN")                  |
//! |      4 |    2 | version        | [`WIRE_VERSION`]                        |
//! |      6 |    1 | kind           | 0 deliver · 1 delayed · 2 overflow      |
//! |      7 |    1 | reserved       | must be 0                               |
//! |      8 |    4 | src            | sender's **world** rank                 |
//! |     12 |    4 | dst            | receiver's world rank                   |
//! |     16 |    8 | tag            | packed `TagKey` (ctx, chunk, round)     |
//! |     24 |    8 | delay_micros   | embargo hold (kind = delayed only)      |
//! |     32 |    8 | vtime          | sender's virtual clock, f64 bits        |
//! |     40 |    4 | elem_count     | payload elements                        |
//! |     44 |    4 | payload_len    | payload bytes (= count × wire_bytes)    |
//! |     48 |    8 | seq            | per-(src → dst) channel sequence number |
//! |     56 |    8 | checksum       | FNV-1a 64 over header[0..56] ∥ payload  |
//!
//! Version 2 (PR 10) grew the header from 56 to 64 bytes: the `seq`
//! field numbers every frame on its ordered (src → dst) channel starting
//! at 0, which is what makes duplicate suppression and NACK/retransmit
//! recovery (`mpi/recover.rs`) addressable — a corrupt frame is retried
//! *by sequence number*, and a replayed duplicate is recognized and
//! dropped instead of double-delivered.
//!
//! The `kind` byte ships the chaos plan over the wire: the sender's
//! [`plan_message`](super::chaos::Chaos::plan_message) decision (deliver /
//! embargo / divert-to-overflow) is made once at the send site and encoded
//! here, so the receiving side deposits into its local inbox through
//! exactly the same three entry points the thread backend uses — chaos
//! schedules, XOR digests and trace invariants are backend-independent by
//! construction. Checksum or header corruption is surfaced as an
//! attributed decode error, never a silent drop.

use anyhow::{bail, Result};

use super::elem::Elem;

/// "XSCN" — rejects cross-talk from anything that is not an exscan peer.
pub const WIRE_MAGIC: u32 = 0x5853_434E;
/// Bumped on any incompatible frame-layout change (2: seq field, PR 10).
pub const WIRE_VERSION: u16 = 2;
/// Fixed header size in bytes.
pub const HEADER_BYTES: usize = 64;
/// Byte offset of the checksum field (FNV over everything before it plus
/// the payload; the checksum is absent from its own input).
pub const CHECKSUM_OFFSET: usize = 56;
/// Byte offset of the channel sequence number.
pub const SEQ_OFFSET: usize = 48;

/// How the receiving side must deposit the decoded message into its
/// local inbox — the sender's chaos decision, shipped in the frame.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrameKind {
    /// Normal delivery: `Inbox::deposit`.
    Deliver,
    /// Chaos embargo: `Inbox::deposit_delayed(now + delay_micros)`.
    Delayed,
    /// Chaos slot diversion: `Inbox::deposit_overflow`.
    Overflow,
}

impl FrameKind {
    fn code(self) -> u8 {
        match self {
            FrameKind::Deliver => 0,
            FrameKind::Delayed => 1,
            FrameKind::Overflow => 2,
        }
    }

    fn from_code(code: u8) -> Result<Self> {
        match code {
            0 => Ok(FrameKind::Deliver),
            1 => Ok(FrameKind::Delayed),
            2 => Ok(FrameKind::Overflow),
            other => bail!("wire: unknown frame kind {other}"),
        }
    }
}

/// Decoded frame header.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FrameHeader {
    pub kind: FrameKind,
    pub src: usize,
    pub dst: usize,
    pub tag: u64,
    pub delay_micros: u64,
    pub vtime: f64,
    pub elem_count: usize,
    pub payload_len: usize,
    /// Position of this frame in its ordered (src → dst) channel, from 0.
    pub seq: u64,
}

/// FNV-1a 64-bit over a byte stream — cheap, dependency-free, and enough
/// to catch framing bugs and torn writes (this is an integrity check
/// against software defects, not an adversarial MAC).
pub fn fnv1a(chunks: &[&[u8]]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for chunk in chunks {
        for &b in *chunk {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

/// Encode one message into a self-delimiting frame.
#[allow(clippy::too_many_arguments)]
pub fn encode_frame<T: Elem>(
    kind: FrameKind,
    src: usize,
    dst: usize,
    tag: u64,
    delay_micros: u64,
    vtime: f64,
    seq: u64,
    data: &[T],
) -> Vec<u8> {
    let payload_len = data.len() * T::wire_bytes();
    let mut out = Vec::with_capacity(HEADER_BYTES + payload_len);
    out.extend_from_slice(&WIRE_MAGIC.to_le_bytes());
    out.extend_from_slice(&WIRE_VERSION.to_le_bytes());
    out.push(kind.code());
    out.push(0); // reserved
    out.extend_from_slice(&(src as u32).to_le_bytes());
    out.extend_from_slice(&(dst as u32).to_le_bytes());
    out.extend_from_slice(&tag.to_le_bytes());
    out.extend_from_slice(&delay_micros.to_le_bytes());
    out.extend_from_slice(&vtime.to_bits().to_le_bytes());
    out.extend_from_slice(&(data.len() as u32).to_le_bytes());
    out.extend_from_slice(&(payload_len as u32).to_le_bytes());
    out.extend_from_slice(&seq.to_le_bytes());
    debug_assert_eq!(out.len(), CHECKSUM_OFFSET);
    for v in data {
        v.write_wire(&mut out);
    }
    let checksum = fnv1a(&[&out[..CHECKSUM_OFFSET], &out[CHECKSUM_OFFSET..]]);
    // Splice the checksum in at its offset (it was computed over
    // header[0..56] ∥ payload, i.e. with itself absent).
    let mut framed = Vec::with_capacity(HEADER_BYTES + payload_len);
    framed.extend_from_slice(&out[..CHECKSUM_OFFSET]);
    framed.extend_from_slice(&checksum.to_le_bytes());
    framed.extend_from_slice(&out[CHECKSUM_OFFSET..]);
    framed
}

fn le_u32(bytes: &[u8], at: usize) -> u32 {
    u32::from_le_bytes([bytes[at], bytes[at + 1], bytes[at + 2], bytes[at + 3]])
}

fn le_u64(bytes: &[u8], at: usize) -> u64 {
    let mut raw = [0u8; 8];
    raw.copy_from_slice(&bytes[at..at + 8]);
    u64::from_le_bytes(raw)
}

/// Decode and validate a frame header (`header.len() == HEADER_BYTES`).
/// The payload checksum is verified separately by
/// [`verify_payload`] once the payload bytes are available.
pub fn decode_header(header: &[u8]) -> Result<FrameHeader> {
    if header.len() != HEADER_BYTES {
        bail!("wire: short header ({} bytes, want {HEADER_BYTES})", header.len());
    }
    let magic = le_u32(header, 0);
    if magic != WIRE_MAGIC {
        bail!("wire: bad magic {magic:#010x} (want {WIRE_MAGIC:#010x})");
    }
    let version = u16::from_le_bytes([header[4], header[5]]);
    if version != WIRE_VERSION {
        bail!("wire: version {version} (this build speaks {WIRE_VERSION})");
    }
    if header[7] != 0 {
        bail!("wire: nonzero reserved byte {}", header[7]);
    }
    Ok(FrameHeader {
        kind: FrameKind::from_code(header[6])?,
        src: le_u32(header, 8) as usize,
        dst: le_u32(header, 12) as usize,
        tag: le_u64(header, 16),
        delay_micros: le_u64(header, 24),
        vtime: f64::from_bits(le_u64(header, 32)),
        elem_count: le_u32(header, 40) as usize,
        payload_len: le_u32(header, 44) as usize,
        seq: le_u64(header, SEQ_OFFSET),
    })
}

/// Verify the frame checksum (header bytes with the checksum field as
/// transmitted, payload bytes as received).
pub fn verify_payload(header: &[u8], payload: &[u8]) -> Result<()> {
    if header.len() != HEADER_BYTES {
        bail!("wire: short header ({} bytes, want {HEADER_BYTES})", header.len());
    }
    let want = le_u64(header, CHECKSUM_OFFSET);
    let got = fnv1a(&[&header[..CHECKSUM_OFFSET], payload]);
    if got != want {
        bail!("wire: checksum mismatch (got {got:#018x}, frame says {want:#018x})");
    }
    Ok(())
}

/// Read the channel sequence number straight out of an encoded frame
/// without a full header decode — the send-side fault plan and the
/// retransmit shelf are keyed by seq, and the frame may already be
/// serialized when they need it.
pub fn peek_seq(frame: &[u8]) -> Option<u64> {
    if frame.len() < HEADER_BYTES {
        return None;
    }
    Some(le_u64(frame, SEQ_OFFSET))
}

/// Decode a verified payload into elements. Rejects length mismatches
/// (truncation, count/len disagreement) before touching element bytes.
pub fn decode_payload<T: Elem>(h: &FrameHeader, payload: &[u8]) -> Result<Vec<T>> {
    let stride = T::wire_bytes();
    if h.payload_len != h.elem_count.saturating_mul(stride) || payload.len() != h.payload_len {
        bail!(
            "wire: payload length {} != {} elements × {} bytes (header says {})",
            payload.len(),
            h.elem_count,
            stride,
            h.payload_len
        );
    }
    let mut out = Vec::with_capacity(h.elem_count);
    for i in 0..h.elem_count {
        out.push(T::read_wire(&payload[i * stride..(i + 1) * stride]));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpi::elem::Rec2;

    fn roundtrip<T: Elem>(kind: FrameKind, data: &[T]) {
        let frame = encode_frame(kind, 3, 5, 0xABCD_EF01, 150, 2.5, 9, data);
        assert_eq!(frame.len(), HEADER_BYTES + data.len() * T::wire_bytes());
        let h = decode_header(&frame[..HEADER_BYTES]).unwrap();
        verify_payload(&frame[..HEADER_BYTES], &frame[HEADER_BYTES..]).unwrap();
        assert_eq!(h.kind, kind);
        assert_eq!((h.src, h.dst, h.tag), (3, 5, 0xABCD_EF01));
        assert_eq!(h.delay_micros, 150);
        assert_eq!(h.vtime, 2.5);
        assert_eq!(h.seq, 9);
        assert_eq!(peek_seq(&frame), Some(9));
        let decoded: Vec<T> = decode_payload(&h, &frame[HEADER_BYTES..]).unwrap();
        assert_eq!(decoded, data);
    }

    #[test]
    fn frame_roundtrip_all_kinds_and_types() {
        roundtrip(FrameKind::Deliver, &[1i64, -2, i64::MAX]);
        roundtrip(FrameKind::Delayed, &[0.5f64; 17]);
        roundtrip(FrameKind::Overflow, &[] as &[i64]); // m = 0 frames exist
        roundtrip(
            FrameKind::Deliver,
            &[Rec2::new([1.0, 2.0, 3.0, 4.0], [5.0, 6.0]), Rec2::identity()],
        );
    }

    #[test]
    fn corruption_is_caught() {
        let mut frame = encode_frame(FrameKind::Deliver, 0, 1, 7, 0, 0.0, 0, &[42i64]);
        // Flip one payload bit: checksum must catch it.
        frame[HEADER_BYTES] ^= 0x10;
        assert!(verify_payload(&frame[..HEADER_BYTES], &frame[HEADER_BYTES..]).is_err());
        // Bad magic / version / kind are rejected at header decode.
        let good = encode_frame(FrameKind::Deliver, 0, 1, 7, 0, 0.0, 0, &[42i64]);
        let mut bad = good.clone();
        bad[0] ^= 0xFF;
        assert!(decode_header(&bad[..HEADER_BYTES]).is_err());
        let mut bad = good.clone();
        bad[4] = 99;
        assert!(decode_header(&bad[..HEADER_BYTES]).is_err());
        let mut bad = good.clone();
        bad[6] = 9;
        assert!(decode_header(&bad[..HEADER_BYTES]).is_err());
        // A flipped seq bit lands in the checksummed region too.
        let mut bad = good.clone();
        bad[SEQ_OFFSET] ^= 0x01;
        assert!(verify_payload(&bad[..HEADER_BYTES], &bad[HEADER_BYTES..]).is_err());
        // Truncated payload is rejected by the length check.
        let h = decode_header(&good[..HEADER_BYTES]).unwrap();
        assert!(decode_payload::<i64>(&h, &good[HEADER_BYTES..HEADER_BYTES + 4]).is_err());
        // Short header slices are an error, not a panic.
        assert!(decode_header(&good[..10]).is_err());
        assert!(verify_payload(&good[..10], &good[HEADER_BYTES..]).is_err());
    }

    /// SplitMix64 — the same tiny deterministic generator the chaos and
    /// wire-fault layers use, so the fuzz corpus replays from its seed.
    fn splitmix(state: &mut u64) -> u64 {
        *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = *state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// The receiver pipeline as one fallible step: header decode,
    /// checksum verification, payload decode. Exactly what the wire
    /// backends run per frame — so "never panics" here is "never panics"
    /// there.
    fn full_decode(frame: &[u8]) -> Result<(FrameHeader, Vec<i64>)> {
        let split = frame.len().min(HEADER_BYTES);
        let h = decode_header(&frame[..split])?;
        verify_payload(&frame[..split], &frame[split..])?;
        let data = decode_payload::<i64>(&h, &frame[split..])?;
        Ok((h, data))
    }

    /// Property fuzz: any single byte-level mutation of a valid frame —
    /// bit flip, byte smash, truncation, junk extension — must come back
    /// as either a clean decode of the *original* content or an
    /// attributed error. Never a panic, never silently different data.
    #[test]
    fn codec_survives_arbitrary_mutations() {
        let mut rng = 0x51C4_F00Du64;
        for iter in 0..4096u64 {
            let n = (splitmix(&mut rng) % 24) as usize;
            let data: Vec<i64> =
                (0..n).map(|_| splitmix(&mut rng) as i64).collect();
            let kind = match splitmix(&mut rng) % 3 {
                0 => FrameKind::Deliver,
                1 => FrameKind::Delayed,
                _ => FrameKind::Overflow,
            };
            let frame = encode_frame(
                kind,
                (splitmix(&mut rng) % 64) as usize,
                (splitmix(&mut rng) % 64) as usize,
                splitmix(&mut rng),
                splitmix(&mut rng) % 1000,
                f64::from_bits(0x3FF0_0000_0000_0000), // 1.0: always finite
                splitmix(&mut rng),
                &data,
            );
            let (h0, d0) = full_decode(&frame).expect("pristine frame must decode");
            assert_eq!(d0, data);

            let mut mutated = frame.clone();
            match splitmix(&mut rng) % 4 {
                0 => {
                    // Single bit flip anywhere in the frame.
                    let bit = (splitmix(&mut rng) % (mutated.len() as u64 * 8)) as usize;
                    mutated[bit / 8] ^= 1 << (bit % 8);
                }
                1 => {
                    // Whole-byte smash.
                    let at = (splitmix(&mut rng) % mutated.len() as u64) as usize;
                    mutated[at] = splitmix(&mut rng) as u8;
                }
                2 => {
                    // Truncate at an arbitrary boundary (possibly mid-header).
                    let keep = (splitmix(&mut rng) % mutated.len() as u64) as usize;
                    mutated.truncate(keep);
                }
                _ => {
                    // Append junk bytes.
                    let extra = 1 + (splitmix(&mut rng) % 16) as usize;
                    for _ in 0..extra {
                        mutated.push(splitmix(&mut rng) as u8);
                    }
                }
            }
            match full_decode(&mutated) {
                // A mutation the pipeline accepts must not have changed
                // what it decodes to (e.g. a flip that the checksum field
                // itself absorbed cannot exist — FNV covers every byte).
                Ok((h, d)) => {
                    assert_eq!(
                        (h, d),
                        (h0, d0.clone()),
                        "iter {iter}: accepted a mutation that changed the content"
                    );
                }
                Err(e) => {
                    assert!(
                        !format!("{e:#}").is_empty(),
                        "iter {iter}: error must be attributed"
                    );
                }
            }
        }
    }

    /// Pure-garbage robustness: random byte strings of arbitrary length
    /// through the full receive pipeline — every outcome is a typed
    /// error (no random string passes an FNV + magic + version gauntlet),
    /// and nothing panics.
    #[test]
    fn codec_rejects_random_garbage() {
        let mut rng = 0xBAD_C0DEu64;
        for _ in 0..2048u64 {
            let len = (splitmix(&mut rng) % 160) as usize;
            let garbage: Vec<u8> = (0..len).map(|_| splitmix(&mut rng) as u8).collect();
            assert!(full_decode(&garbage).is_err());
        }
    }
}
