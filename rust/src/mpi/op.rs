//! Binary associative operators (`MPI_Op` equivalents) and `reduce_local`.
//!
//! The central contract mirrors `MPI_Reduce_local(inbuf, inoutbuf)`:
//! `inout[i] = in[i] ⊕ inout[i]`, where `in` holds the *earlier-ranked*
//! partial result. Order matters for non-commutative operators, and all
//! algorithms in [`crate::coll`] are written to respect it.
//!
//! Operators come in three flavours:
//! * native Rust closures over typed slices (the fast path),
//! * the [`Rec2`](crate::mpi::Rec2) affine-composition operator, and
//! * PJRT-backed operators ([`crate::runtime::PjrtOp`]) that execute the
//!   AOT-compiled Pallas `reduce_local` kernel — the Layer-1 hot spot.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use super::elem::{Elem, Rec2};

/// Number of counter shards per operator. Power of two; ranks index their
/// shard as `rank & (COUNTER_SHARDS - 1)`, so worlds up to 64 ranks get a
/// truly private shard and larger worlds still spread 64 ways. 64 shards ×
/// 128 B = 8 KiB per operator — negligible next to one m-element buffer.
const COUNTER_SHARDS: usize = 64;

/// One application counter, padded to its own cache line (128 B covers the
/// 2-line prefetcher granularity on x86 and the 128 B lines on Apple ARM),
/// so two ranks bumping adjacent shards never share a line.
#[repr(align(128))]
#[derive(Default)]
struct CounterShard(AtomicU64);

/// A binary, associative element-wise operator over vectors of `T`.
pub trait CombineOp<T: Elem>: Send + Sync {
    /// Operator name (used in benchmark tables and artifact lookup).
    fn name(&self) -> &str;

    /// `inout[i] = input[i] ⊕ inout[i]` — `input` is the earlier operand.
    fn combine(&self, input: &[T], inout: &mut [T]);

    /// Whether the operator commutes (MPI predefined ops do; user-defined
    /// ops may not). Algorithms never exploit commutativity here, but the
    /// mpich-baseline bookkeeping branches on it, as the real library does.
    fn commutative(&self) -> bool {
        true
    }
}

/// Shared handle to an operator plus the application counters used by the
/// round/op-count experiments (Theorem 1 verification).
///
/// The counters are sharded per rank and padded to cache lines: every rank
/// thread bumps its own shard with a relaxed add, so steady-state scan
/// rounds touch no shared cache line (the old single `AtomicU64` was a
/// point of true sharing for all p ranks on every ⊕). Aggregation happens
/// lazily, only when the trace/table layer asks via [`applications`].
///
/// [`applications`]: OpRef::applications
pub struct OpRef<T: Elem> {
    op: Arc<dyn CombineOp<T>>,
    shards: Box<[CounterShard]>,
}

impl<T: Elem> OpRef<T> {
    pub fn new(op: Arc<dyn CombineOp<T>>) -> Self {
        let shards: Vec<CounterShard> =
            (0..COUNTER_SHARDS).map(|_| CounterShard::default()).collect();
        OpRef { op, shards: shards.into_boxed_slice() }
    }

    /// Operator name. Borrowed — this is read inside sweep loops and table
    /// renderers, which must not allocate per call.
    pub fn name(&self) -> &str {
        self.op.name()
    }

    /// The underlying shared combine operator. Used by the scan service to
    /// build per-batch `OpRef`s (fresh counters, same semantics).
    pub fn shared_op(&self) -> Arc<dyn CombineOp<T>> {
        Arc::clone(&self.op)
    }

    pub fn commutative(&self) -> bool {
        self.op.commutative()
    }

    /// Apply `inout = input ⊕ inout`, counting on shard 0. Single-threaded
    /// callers (oracles, unit tests); rank threads use
    /// [`reduce_local_sharded`](Self::reduce_local_sharded) via `RankCtx`.
    pub fn reduce_local(&self, input: &[T], inout: &mut [T]) {
        self.reduce_local_sharded(0, input, inout);
    }

    /// Apply `inout = input ⊕ inout`, counting on the caller's shard
    /// (`shard` is the rank id; wrapped into the shard array). The hot
    /// path: one relaxed add on a rank-private cache line.
    pub fn reduce_local_sharded(&self, shard: usize, input: &[T], inout: &mut [T]) {
        debug_assert_eq!(input.len(), inout.len());
        self.shards[shard & (COUNTER_SHARDS - 1)].0.fetch_add(1, Ordering::Relaxed);
        self.op.combine(input, inout);
    }

    /// Total ⊕ applications across all ranks since construction/reset
    /// (lazy aggregation over the shards).
    pub fn applications(&self) -> u64 {
        self.shards.iter().map(|s| s.0.load(Ordering::Relaxed)).sum()
    }

    pub fn reset_applications(&self) {
        for s in self.shards.iter() {
            s.0.store(0, Ordering::Relaxed);
        }
    }
}

/// A native operator defined by a per-element closure.
pub struct FnOp<T: Elem, F: Fn(T, T) -> T + Send + Sync> {
    name: &'static str,
    commutative: bool,
    f: F,
    _t: std::marker::PhantomData<T>,
}

impl<T: Elem, F: Fn(T, T) -> T + Send + Sync> CombineOp<T> for FnOp<T, F> {
    fn name(&self) -> &str {
        self.name
    }

    fn combine(&self, input: &[T], inout: &mut [T]) {
        for (o, &i) in inout.iter_mut().zip(input) {
            *o = (self.f)(i, *o);
        }
    }

    fn commutative(&self) -> bool {
        self.commutative
    }
}

/// Constructors for the predefined operators.
pub mod ops {
    use super::*;

    fn mk<T: Elem, F: Fn(T, T) -> T + Send + Sync + 'static>(
        name: &'static str,
        commutative: bool,
        f: F,
    ) -> OpRef<T> {
        OpRef::new(Arc::new(FnOp { name, commutative, f, _t: std::marker::PhantomData }))
    }

    /// `MPI_BXOR` over i64 — the operator the paper benchmarks.
    pub fn bxor() -> OpRef<i64> {
        mk("bxor_i64", true, |a: i64, b: i64| a ^ b)
    }

    /// `MPI_BOR` over i64.
    pub fn bor() -> OpRef<i64> {
        mk("bor_i64", true, |a: i64, b: i64| a | b)
    }

    /// `MPI_SUM` over i64 (wrapping, as C longs would overflow silently).
    pub fn sum_i64() -> OpRef<i64> {
        mk("sum_i64", true, |a: i64, b: i64| a.wrapping_add(b))
    }

    /// `MPI_SUM` over u64 (wrapping — exactly associative & commutative,
    /// ideal for property tests).
    pub fn sum_u64() -> OpRef<u64> {
        mk("sum_u64", true, |a: u64, b: u64| a.wrapping_add(b))
    }

    /// `MPI_SUM` over f64. NOTE: float addition is not exactly associative;
    /// tests using it must compare with tolerance.
    pub fn sum_f64() -> OpRef<f64> {
        mk("sum_f64", true, |a: f64, b: f64| a + b)
    }

    /// `MPI_MAX` over i64.
    pub fn max_i64() -> OpRef<i64> {
        mk("max_i64", true, |a: i64, b: i64| a.max(b))
    }

    /// `MPI_MIN` over i64.
    pub fn min_i64() -> OpRef<i64> {
        mk("min_i64", true, |a: i64, b: i64| a.min(b))
    }

    /// Affine-map composition over [`Rec2`]: the input (earlier) map is
    /// applied first. Non-commutative.
    pub fn rec2_compose() -> OpRef<Rec2> {
        mk("matrec_f32", false, |earlier: Rec2, later: Rec2| earlier.then(&later))
    }

    /// A deliberately slow operator for the op-cost ablation: BXOR plus a
    /// tunable amount of busy work per element, emulating an expensive
    /// user-defined MPI operator.
    pub fn expensive_bxor(work_iters: u32) -> OpRef<i64> {
        OpRef::new(Arc::new(ExpensiveBxor { work_iters }))
    }

    struct ExpensiveBxor {
        work_iters: u32,
    }

    impl CombineOp<i64> for ExpensiveBxor {
        fn name(&self) -> &str {
            "expensive_bxor_i64"
        }

        fn combine(&self, input: &[i64], inout: &mut [i64]) {
            for (o, &i) in inout.iter_mut().zip(input) {
                let exact = i ^ *o;
                // Data-dependent busy loop the optimizer cannot remove.
                let mut x = exact;
                for k in 0..self.work_iters {
                    x = x.wrapping_mul(0x9E3779B97F4A7C15u64 as i64).rotate_left((k % 63) + 1);
                }
                // Fold the busy result in as a provable no-op so the loop
                // stays live but the semantics remain exactly BXOR.
                *o = exact ^ (std::hint::black_box(x) & 0);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::ops;
    use super::*;

    #[test]
    fn reduce_local_order() {
        // combine(in, inout): inout = in ⊕ inout, with `in` earlier.
        let op = ops::rec2_compose();
        let earlier = Rec2::new([2.0, 0.0, 0.0, 2.0], [1.0, 1.0]);
        let later = Rec2::new([1.0, 1.0, 0.0, 1.0], [0.0, 3.0]);
        let mut inout = [later];
        op.reduce_local(&[earlier], &mut inout);
        assert_eq!(inout[0], earlier.then(&later));
    }

    #[test]
    fn application_counter() {
        let op = ops::bxor();
        let mut buf = vec![0i64; 4];
        op.reduce_local(&[1, 2, 3, 4], &mut buf);
        op.reduce_local(&[1, 2, 3, 4], &mut buf);
        assert_eq!(op.applications(), 2);
        assert_eq!(buf, vec![0, 0, 0, 0]);
        op.reset_applications();
        assert_eq!(op.applications(), 0);
    }

    #[test]
    fn sharded_counters_aggregate_across_ranks() {
        // Counts land on per-rank shards (incl. the wrap beyond the shard
        // count) and aggregate exactly; reset clears every shard.
        let op = ops::sum_u64();
        let mut buf = vec![0u64; 2];
        for rank in [0usize, 1, 7, 63, 64, 1151] {
            op.reduce_local_sharded(rank, &[1, 2], &mut buf);
        }
        assert_eq!(op.applications(), 6);
        op.reset_applications();
        assert_eq!(op.applications(), 0);
        op.reduce_local(&[1, 2], &mut buf); // shard-0 convenience path
        assert_eq!(op.applications(), 1);
    }

    #[test]
    fn name_is_borrowed() {
        let op = ops::bxor();
        let name: &str = op.name(); // no allocation, just a borrow
        assert_eq!(name, "bxor_i64");
    }

    #[test]
    fn bxor_semantics() {
        let op = ops::bxor();
        let mut b = vec![0b1010i64, -1];
        op.reduce_local(&[0b0110, 0], &mut b);
        assert_eq!(b, vec![0b1100, -1]);
    }

    #[test]
    fn expensive_bxor_exact() {
        let slow = ops::expensive_bxor(64);
        let fast = ops::bxor();
        let input: Vec<i64> = (0..33).map(|i| i * 7 - 11).collect();
        let mut a: Vec<i64> = (0..33).map(|i| i ^ 0x5a).collect();
        let mut b = a.clone();
        slow.reduce_local(&input, &mut a);
        fast.reduce_local(&input, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn sum_wrapping() {
        let op = ops::sum_i64();
        let mut b = vec![i64::MAX];
        op.reduce_local(&[1], &mut b);
        assert_eq!(b, vec![i64::MIN]);
    }

    #[test]
    fn minmax() {
        let mx = ops::max_i64();
        let mn = ops::min_i64();
        let mut b = vec![3i64, -5];
        mx.reduce_local(&[1, 7], &mut b);
        assert_eq!(b, vec![3, 7]);
        let mut b = vec![3i64, -5];
        mn.reduce_local(&[1, 7], &mut b);
        assert_eq!(b, vec![1, -5]);
    }
}
