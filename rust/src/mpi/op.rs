//! Binary associative operators (`MPI_Op` equivalents), `reduce_local`,
//! and the **slice-kernel dispatch engine**.
//!
//! The central contract mirrors `MPI_Reduce_local(inbuf, inoutbuf)`:
//! `inout[i] = in[i] ⊕ inout[i]`, where `in` holds the *earlier-ranked*
//! partial result. Order matters for non-commutative operators, and all
//! algorithms in [`crate::coll`] are written to respect it.
//!
//! Operators come in three flavours:
//! * native Rust operators over typed slices (the fast path),
//! * the [`Rec2`](crate::mpi::Rec2) affine-composition operator, and
//! * PJRT-backed operators ([`crate::runtime::PjrtOp`]) that execute the
//!   AOT-compiled Pallas `reduce_local` kernel — the Layer-1 hot spot.
//!
//! ## Kernel dispatch (EXPERIMENTS.md §Perf)
//!
//! A ⊕ application used to be one virtual `combine` call through
//! `Arc<dyn CombineOp<T>>` per application, every round, on every rank —
//! a per-round constant multiplied by q = ⌈log₂(p−1) + log₂(4/3)⌉.
//! Dispatch is now resolved **once per collective**, not once per
//! application: [`OpRef::kernel`] resolves the operator to an
//! [`OpKernel`] handle holding either a *statically dispatched*
//! monomorphized slice kernel (the built-in ops: bxor/bor/sum/min/max
//! over the integer types, f64 sum, Rec2 compose — see [`kernels`]) or
//! the dyn [`CombineOp::combine_slice`] fallback. All hot-path reduces
//! (`RankCtx::fold` and everything funnelling through it, plus
//! [`OpRef::reduce_local_sharded`]) apply through the handle, so the
//! Arc deref + vtable lookup leaves the per-application path entirely
//! for built-in operators, and costs exactly one resolved `fn` call per
//! *slice* otherwise. The per-element reference dispatch survives as
//! [`OpRef::kernel_per_element`] (selected world-wide by
//! `WorldConfig::with_per_element_ops(true)`) and is asserted
//! bit-identical to the slice path in `tests/kernel_equivalence.rs`.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use super::elem::{Elem, Rec2};

/// Number of counter shards per operator. Power of two; ranks index their
/// shard as `rank & (COUNTER_SHARDS - 1)`, so worlds up to 64 ranks get a
/// truly private shard and larger worlds still spread 64 ways. 64 shards ×
/// 128 B = 8 KiB per operator — negligible next to one m-element buffer.
const COUNTER_SHARDS: usize = 64;

/// One application counter, padded to its own cache line (128 B covers the
/// 2-line prefetcher granularity on x86 and the 128 B lines on Apple ARM),
/// so two ranks bumping adjacent shards never share a line.
#[repr(align(128))]
#[derive(Default)]
struct CounterShard(AtomicU64);

/// A monomorphized whole-slice combine: `inout[i] = input[i] ⊕ inout[i]`
/// with `input` the earlier operand, over the full slice in one call.
/// Plain `fn` pointers so an [`OpKernel`] dispatches with a direct call —
/// no fat pointer, no vtable.
pub type SliceKernelFn<T> = fn(&[T], &mut [T]);

/// A monomorphized in-place **inclusive prefix scan across rows**: the
/// buffer is row-major `n × width` and after the call row `j` holds
/// `row_0 ⊕ … ⊕ row_j` (earlier rows are the earlier ⊕ operands). This is
/// the local phase of the large-m block algorithms ([`crate::coll`]):
/// one direct call scans all rows in a single tight loop nest the
/// compiler can autovectorize, instead of `n−1` dispatched `combine`
/// calls. `width == 0` is a no-op.
pub type ScanKernelFn<T> = fn(&mut [T], usize);

/// A binary, associative element-wise operator over vectors of `T`.
pub trait CombineOp<T: Elem>: Send + Sync {
    /// Operator name (used in benchmark tables and artifact lookup).
    fn name(&self) -> &str;

    /// `inout[i] = input[i] ⊕ inout[i]` — `input` is the earlier operand.
    /// This is the semantic ground truth and the per-element *reference*
    /// path of the A/B comparison (`WorldConfig::with_per_element_ops`);
    /// implementations apply the scalar ⊕ element by element.
    fn combine(&self, input: &[T], inout: &mut [T]);

    /// Slice-wide combine. The default forwards to [`combine`]: inside a
    /// concrete impl the call is statically dispatched and the combine
    /// loop monomorphizes into an autovectorizable tight loop — the dyn
    /// indirection is paid once per *slice*, never per element.
    /// Specialized impls (e.g. a blocked or kernel-launched combine)
    /// override it.
    ///
    /// Contract: bit-identical to [`combine`] on every input (asserted
    /// for all registered operators in `tests/kernel_equivalence.rs`).
    ///
    /// [`combine`]: Self::combine
    fn combine_slice(&self, input: &[T], inout: &mut [T]) {
        self.combine(input, inout)
    }

    /// A statically dispatched slice kernel for this operator, if one
    /// exists. Resolved once per [`OpRef`] construction and once per
    /// collective into an [`OpKernel`]; `None` falls back to the dyn
    /// [`combine_slice`](Self::combine_slice) call per application.
    fn slice_kernel(&self) -> Option<SliceKernelFn<T>> {
        None
    }

    /// In-place inclusive prefix scan over row-major `n × width` rows:
    /// row `j` becomes `row_0 ⊕ … ⊕ row_j`. The default folds each row
    /// into the next via [`combine_slice`](Self::combine_slice) — one dyn
    /// call per *row*, never per element — and is the semantic reference
    /// the tight-loop [`scan_kernel`](Self::scan_kernel)s must match
    /// bit-identically (asserted in `tests/kernel_equivalence.rs`).
    fn scan_slice(&self, rows: &mut [T], width: usize) {
        if width == 0 {
            return;
        }
        let n = rows.len() / width;
        for j in 1..n {
            let (earlier, rest) = rows.split_at_mut(j * width);
            self.combine_slice(&earlier[(j - 1) * width..], &mut rest[..width]);
        }
    }

    /// A statically dispatched prefix-scan kernel, if one exists (the
    /// built-in operators register the [`kernels::scan_*`] tight loops).
    /// `None` falls back to the dyn [`scan_slice`](Self::scan_slice).
    fn scan_kernel(&self) -> Option<ScanKernelFn<T>> {
        None
    }

    /// Whether the operator commutes (MPI predefined ops do; user-defined
    /// ops may not). Algorithms never exploit commutativity here, but the
    /// mpich-baseline bookkeeping branches on it, as the real library does.
    fn commutative(&self) -> bool {
        true
    }
}

/// The monomorphized tight-loop slice kernels for the built-in operators.
/// Each is a plain `fn` over asserted-equal-length slices whose loop body
/// the compiler autovectorizes; [`OpKernel`] calls them directly, with no
/// dyn dispatch anywhere on the path. Exposed for the hotpath bench's
/// kernel sweep.
pub mod kernels {
    use super::super::elem::Rec2;

    #[inline]
    pub fn bxor_i64(input: &[i64], inout: &mut [i64]) {
        for (o, &i) in inout.iter_mut().zip(input) {
            *o = i ^ *o;
        }
    }

    #[inline]
    pub fn bor_i64(input: &[i64], inout: &mut [i64]) {
        for (o, &i) in inout.iter_mut().zip(input) {
            *o = i | *o;
        }
    }

    #[inline]
    pub fn sum_i64(input: &[i64], inout: &mut [i64]) {
        for (o, &i) in inout.iter_mut().zip(input) {
            *o = i.wrapping_add(*o);
        }
    }

    #[inline]
    pub fn sum_u64(input: &[u64], inout: &mut [u64]) {
        for (o, &i) in inout.iter_mut().zip(input) {
            *o = i.wrapping_add(*o);
        }
    }

    #[inline]
    pub fn sum_f64(input: &[f64], inout: &mut [f64]) {
        for (o, &i) in inout.iter_mut().zip(input) {
            *o = i + *o;
        }
    }

    #[inline]
    pub fn max_i64(input: &[i64], inout: &mut [i64]) {
        for (o, &i) in inout.iter_mut().zip(input) {
            *o = i.max(*o);
        }
    }

    #[inline]
    pub fn min_i64(input: &[i64], inout: &mut [i64]) {
        for (o, &i) in inout.iter_mut().zip(input) {
            *o = i.min(*o);
        }
    }

    /// Affine-map composition (`earlier.then(&later)`), 22 flops per
    /// element, fully inlined — the "expensive ⊕" regime where removing
    /// the per-application dispatch matters least in relative terms but
    /// the inlined `then` still beats an opaque closure call.
    #[inline]
    pub fn rec2_compose(input: &[Rec2], inout: &mut [Rec2]) {
        for (o, &i) in inout.iter_mut().zip(input) {
            *o = i.then(&*o);
        }
    }

    // ── Prefix-scan tight loops (the local phase of the large-m block
    // algorithms). Row-major n × width; row j ← row_{j-1} ⊕ row_j with
    // the earlier row as the earlier operand. Both loop bounds are plain
    // slice arithmetic, so the inner column loop autovectorizes exactly
    // like the combine kernels above. ──

    macro_rules! scan_kernel {
        ($name:ident, $ty:ty, $o:ident, $i:ident, $body:expr) => {
            #[inline]
            pub fn $name(rows: &mut [$ty], width: usize) {
                if width == 0 {
                    return;
                }
                let n = rows.len() / width;
                for j in 1..n {
                    let (earlier, rest) = rows.split_at_mut(j * width);
                    let prev = &earlier[(j - 1) * width..];
                    for ($o, &$i) in rest[..width].iter_mut().zip(prev) {
                        *$o = $body;
                    }
                }
            }
        };
    }

    scan_kernel!(scan_bxor_i64, i64, o, i, i ^ *o);
    scan_kernel!(scan_bor_i64, i64, o, i, i | *o);
    scan_kernel!(scan_sum_i64, i64, o, i, i.wrapping_add(*o));
    scan_kernel!(scan_sum_u64, u64, o, i, i.wrapping_add(*o));
    scan_kernel!(scan_sum_f64, f64, o, i, i + *o);
    scan_kernel!(scan_max_i64, i64, o, i, i.max(*o));
    scan_kernel!(scan_min_i64, i64, o, i, i.min(*o));
    scan_kernel!(scan_rec2_compose, Rec2, o, i, i.then(&*o));
}

/// Resolved dispatch of one [`OpKernel`].
#[derive(Clone, Copy)]
enum Kern<T: Elem> {
    /// Monomorphized tight loop, called directly (built-in operators).
    Static(SliceKernelFn<T>),
    /// One virtual `combine_slice` call per application (user-defined
    /// operators without a registered kernel, lifted/segmented operators,
    /// PJRT-backed kernels).
    DynSlice,
    /// The per-element reference path (`combine`), kept behind
    /// `WorldConfig::with_per_element_ops(true)` as the A/B baseline.
    PerElement,
}

/// An operator resolved to its slice kernel, **once per collective**.
///
/// Obtained from [`OpRef::kernel`] (or `RankCtx::kernel`, which honours
/// the world's A/B flag) at the top of an algorithm's `run` and passed to
/// the fused `RankCtx` primitives: every subsequent ⊕ application is a
/// counter bump plus a direct (or single-dyn) slice call. `Copy`, two
/// words — cheap to pass around by reference or value.
#[derive(Clone, Copy)]
pub struct OpKernel<'op, T: Elem> {
    op: &'op OpRef<T>,
    kern: Kern<T>,
}

impl<'op, T: Elem> OpKernel<'op, T> {
    /// Apply `inout = input ⊕ inout`, counting on the caller's shard
    /// (`shard` is the rank id; wrapped into the shard array). The hot
    /// path: one relaxed add on a rank-private cache line, then the
    /// resolved slice call.
    #[inline]
    pub fn apply_sharded(&self, shard: usize, input: &[T], inout: &mut [T]) {
        debug_assert_eq!(input.len(), inout.len());
        self.op.bump(shard);
        match self.kern {
            Kern::Static(f) => f(input, inout),
            Kern::DynSlice => self.op.op.combine_slice(input, inout),
            Kern::PerElement => self.op.op.combine(input, inout),
        }
    }

    /// In-place inclusive prefix scan over the first `n` rows of the
    /// row-major `n × width` buffer (`rows.len() >= n * width`), counting
    /// the `n − 1` ⊕ applications on the caller's shard in one bump.
    /// Dispatch follows the handle's resolution: static handles use the
    /// registered [`ScanKernelFn`] tight loop (falling back to the dyn
    /// [`CombineOp::scan_slice`] when the operator registered a combine
    /// kernel but no scan kernel), dyn-slice handles use `scan_slice`,
    /// and the per-element reference path folds row into row via
    /// `combine`. All three are bit-identical by contract.
    ///
    /// `width == 0` rows still count their `n − 1` applications — the
    /// algorithms' closed-form ⊕ counts stay m-independent, matching
    /// `RankCtx::fold`'s unconditional accounting.
    pub fn scan_sharded(&self, shard: usize, rows: &mut [T], width: usize, n: usize) {
        debug_assert!(rows.len() >= n * width);
        if n <= 1 {
            return;
        }
        self.op.bump_n(shard, (n - 1) as u64);
        if width == 0 {
            return;
        }
        let rows = &mut rows[..n * width];
        match self.kern {
            Kern::Static(_) => match self.op.scan {
                Some(s) => s(rows, width),
                None => self.op.op.scan_slice(rows, width),
            },
            Kern::DynSlice => self.op.op.scan_slice(rows, width),
            Kern::PerElement => {
                for j in 1..n {
                    let (earlier, rest) = rows.split_at_mut(j * width);
                    self.op.op.combine(&earlier[(j - 1) * width..], &mut rest[..width]);
                }
            }
        }
    }

    /// The operator handle this kernel was resolved from.
    pub fn op(&self) -> &'op OpRef<T> {
        self.op
    }

    /// Operator name (borrowed; see [`OpRef::name`]).
    pub fn name(&self) -> &str {
        self.op.name()
    }

    pub fn commutative(&self) -> bool {
        self.op.commutative()
    }

    /// How this kernel dispatches: `"static"` (monomorphized fn pointer),
    /// `"dyn-slice"` (virtual `combine_slice`) or `"per-element"` (the
    /// reference path). Bench/table reporting only.
    pub fn dispatch(&self) -> &'static str {
        match self.kern {
            Kern::Static(_) => "static",
            Kern::DynSlice => "dyn-slice",
            Kern::PerElement => "per-element",
        }
    }
}

/// Shared handle to an operator plus the application counters used by the
/// round/op-count experiments (Theorem 1 verification).
///
/// The counters are sharded per rank and padded to cache lines: every rank
/// thread bumps its own shard with a relaxed add, so steady-state scan
/// rounds touch no shared cache line (the old single `AtomicU64` was a
/// point of true sharing for all p ranks on every ⊕). Aggregation happens
/// lazily, only when the trace/table layer asks via [`applications`].
///
/// The operator's slice kernel is resolved once, at construction; see the
/// module docs and [`kernel`](Self::kernel).
///
/// [`applications`]: OpRef::applications
pub struct OpRef<T: Elem> {
    op: Arc<dyn CombineOp<T>>,
    /// Slice kernel resolved at construction (one dyn `slice_kernel`
    /// call, ever), so per-collective [`kernel`](Self::kernel) resolution
    /// is a field read.
    kern: Option<SliceKernelFn<T>>,
    /// Prefix-scan kernel resolved at construction, same discipline.
    scan: Option<ScanKernelFn<T>>,
    shards: Box<[CounterShard]>,
}

impl<T: Elem> OpRef<T> {
    pub fn new(op: Arc<dyn CombineOp<T>>) -> Self {
        let kern = op.slice_kernel();
        let scan = op.scan_kernel();
        let shards: Vec<CounterShard> =
            (0..COUNTER_SHARDS).map(|_| CounterShard::default()).collect();
        OpRef { op, kern, scan, shards: shards.into_boxed_slice() }
    }

    /// Operator name. Borrowed — this is read inside sweep loops and table
    /// renderers, which must not allocate per call.
    pub fn name(&self) -> &str {
        self.op.name()
    }

    /// The underlying shared combine operator. Used by the scan service to
    /// build per-batch `OpRef`s (fresh counters, same semantics).
    pub fn shared_op(&self) -> Arc<dyn CombineOp<T>> {
        Arc::clone(&self.op)
    }

    pub fn commutative(&self) -> bool {
        self.op.commutative()
    }

    /// Resolve the slice-dispatch kernel for this operator: the collective
    /// entry point (call once per `run`, not per application). Static for
    /// the built-in operators, dyn `combine_slice` otherwise.
    #[inline]
    pub fn kernel(&self) -> OpKernel<'_, T> {
        OpKernel {
            op: self,
            kern: match self.kern {
                Some(f) => Kern::Static(f),
                None => Kern::DynSlice,
            },
        }
    }

    /// The per-element reference dispatch (`combine`), kept for the A/B
    /// comparison (`WorldConfig::with_per_element_ops(true)` routes every
    /// collective through it). Bit-identical to [`kernel`](Self::kernel)
    /// by the [`CombineOp`] contract.
    #[inline]
    pub fn kernel_per_element(&self) -> OpKernel<'_, T> {
        OpKernel { op: self, kern: Kern::PerElement }
    }

    /// One application on the given shard (relaxed, rank-private line).
    #[inline]
    fn bump(&self, shard: usize) {
        self.shards[shard & (COUNTER_SHARDS - 1)].0.fetch_add(1, Ordering::Relaxed);
    }

    /// `n` applications on the given shard in one relaxed add — the scan
    /// kernels apply `n − 1` ⊕ per launch and count them all at once.
    #[inline]
    fn bump_n(&self, shard: usize, n: u64) {
        self.shards[shard & (COUNTER_SHARDS - 1)].0.fetch_add(n, Ordering::Relaxed);
    }

    /// Apply `inout = input ⊕ inout`, counting on shard 0.
    #[deprecated(
        since = "0.2.0",
        note = "pass an explicit caller shard: `reduce_local_sharded(shard, …)` \
                (shard 0 silently aliased every unsharded caller onto one counter line)"
    )]
    pub fn reduce_local(&self, input: &[T], inout: &mut [T]) {
        self.reduce_local_sharded(0, input, inout);
    }

    /// Apply `inout = input ⊕ inout`, counting on the caller's shard
    /// (`shard` is the rank id — single-threaded callers such as oracles
    /// and unit tests pass 0 explicitly; rank threads funnel through
    /// `RankCtx`). Dispatches through the resolved slice kernel.
    pub fn reduce_local_sharded(&self, shard: usize, input: &[T], inout: &mut [T]) {
        self.kernel().apply_sharded(shard, input, inout);
    }

    /// Total ⊕ applications across all ranks since construction/reset
    /// (lazy aggregation over the shards).
    pub fn applications(&self) -> u64 {
        self.shards.iter().map(|s| s.0.load(Ordering::Relaxed)).sum()
    }

    pub fn reset_applications(&self) {
        for s in self.shards.iter() {
            s.0.store(0, Ordering::Relaxed);
        }
    }
}

/// A native operator defined by a per-element closure, optionally paired
/// with a monomorphized slice kernel (the built-in constructors in
/// [`ops`] all register one).
pub struct FnOp<T: Elem, F: Fn(T, T) -> T + Send + Sync> {
    name: &'static str,
    commutative: bool,
    f: F,
    /// Statically dispatched slice kernel; must be bit-identical to the
    /// per-element loop over `f`.
    kernel: Option<SliceKernelFn<T>>,
    /// Statically dispatched prefix-scan kernel; must be bit-identical
    /// to folding each row into the next with `f`.
    scan: Option<ScanKernelFn<T>>,
    _t: std::marker::PhantomData<T>,
}

impl<T: Elem, F: Fn(T, T) -> T + Send + Sync> CombineOp<T> for FnOp<T, F> {
    fn name(&self) -> &str {
        self.name
    }

    fn combine(&self, input: &[T], inout: &mut [T]) {
        for (o, &i) in inout.iter_mut().zip(input) {
            *o = (self.f)(i, *o);
        }
    }

    fn combine_slice(&self, input: &[T], inout: &mut [T]) {
        match self.kernel {
            Some(k) => k(input, inout),
            None => self.combine(input, inout),
        }
    }

    fn slice_kernel(&self) -> Option<SliceKernelFn<T>> {
        self.kernel
    }

    fn scan_kernel(&self) -> Option<ScanKernelFn<T>> {
        self.scan
    }

    fn commutative(&self) -> bool {
        self.commutative
    }
}

/// Constructors for the predefined operators.
pub mod ops {
    use super::*;

    fn mk<T: Elem, F: Fn(T, T) -> T + Send + Sync + 'static>(
        name: &'static str,
        commutative: bool,
        f: F,
        kernel: Option<SliceKernelFn<T>>,
        scan: Option<ScanKernelFn<T>>,
    ) -> OpRef<T> {
        OpRef::new(Arc::new(FnOp {
            name,
            commutative,
            f,
            kernel,
            scan,
            _t: std::marker::PhantomData,
        }))
    }

    /// `MPI_BXOR` over i64 — the operator the paper benchmarks.
    pub fn bxor() -> OpRef<i64> {
        mk(
            "bxor_i64",
            true,
            |a: i64, b: i64| a ^ b,
            Some(kernels::bxor_i64),
            Some(kernels::scan_bxor_i64),
        )
    }

    /// `MPI_BOR` over i64.
    pub fn bor() -> OpRef<i64> {
        mk(
            "bor_i64",
            true,
            |a: i64, b: i64| a | b,
            Some(kernels::bor_i64),
            Some(kernels::scan_bor_i64),
        )
    }

    /// `MPI_SUM` over i64 (wrapping, as C longs would overflow silently).
    pub fn sum_i64() -> OpRef<i64> {
        mk(
            "sum_i64",
            true,
            |a: i64, b: i64| a.wrapping_add(b),
            Some(kernels::sum_i64),
            Some(kernels::scan_sum_i64),
        )
    }

    /// `MPI_SUM` over u64 (wrapping — exactly associative & commutative,
    /// ideal for property tests).
    pub fn sum_u64() -> OpRef<u64> {
        mk(
            "sum_u64",
            true,
            |a: u64, b: u64| a.wrapping_add(b),
            Some(kernels::sum_u64),
            Some(kernels::scan_sum_u64),
        )
    }

    /// `MPI_SUM` over f64. NOTE: float addition is not exactly associative;
    /// tests using it must compare with tolerance.
    pub fn sum_f64() -> OpRef<f64> {
        mk(
            "sum_f64",
            true,
            |a: f64, b: f64| a + b,
            Some(kernels::sum_f64),
            Some(kernels::scan_sum_f64),
        )
    }

    /// `MPI_MAX` over i64.
    pub fn max_i64() -> OpRef<i64> {
        mk(
            "max_i64",
            true,
            |a: i64, b: i64| a.max(b),
            Some(kernels::max_i64),
            Some(kernels::scan_max_i64),
        )
    }

    /// `MPI_MIN` over i64.
    pub fn min_i64() -> OpRef<i64> {
        mk(
            "min_i64",
            true,
            |a: i64, b: i64| a.min(b),
            Some(kernels::min_i64),
            Some(kernels::scan_min_i64),
        )
    }

    /// Affine-map composition over [`Rec2`]: the input (earlier) map is
    /// applied first. Non-commutative.
    pub fn rec2_compose() -> OpRef<Rec2> {
        mk(
            "matrec_f32",
            false,
            |earlier: Rec2, later: Rec2| earlier.then(&later),
            Some(kernels::rec2_compose),
            Some(kernels::scan_rec2_compose),
        )
    }

    /// A deliberately slow operator for the op-cost ablation: BXOR plus a
    /// tunable amount of busy work per element, emulating an expensive
    /// user-defined MPI operator. Registers no slice kernel, so it also
    /// exercises the dyn `combine_slice` fallback dispatch.
    pub fn expensive_bxor(work_iters: u32) -> OpRef<i64> {
        OpRef::new(Arc::new(ExpensiveBxor { work_iters }))
    }

    struct ExpensiveBxor {
        work_iters: u32,
    }

    impl CombineOp<i64> for ExpensiveBxor {
        fn name(&self) -> &str {
            "expensive_bxor_i64"
        }

        fn combine(&self, input: &[i64], inout: &mut [i64]) {
            for (o, &i) in inout.iter_mut().zip(input) {
                let exact = i ^ *o;
                // Data-dependent busy loop the optimizer cannot remove.
                let mut x = exact;
                for k in 0..self.work_iters {
                    x = x.wrapping_mul(0x9E3779B97F4A7C15u64 as i64).rotate_left((k % 63) + 1);
                }
                // Fold the busy result in as a provable no-op so the loop
                // stays live but the semantics remain exactly BXOR.
                *o = exact ^ (std::hint::black_box(x) & 0);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::ops;
    use super::*;

    #[test]
    fn reduce_local_order() {
        // combine(in, inout): inout = in ⊕ inout, with `in` earlier.
        let op = ops::rec2_compose();
        let earlier = Rec2::new([2.0, 0.0, 0.0, 2.0], [1.0, 1.0]);
        let later = Rec2::new([1.0, 1.0, 0.0, 1.0], [0.0, 3.0]);
        let mut inout = [later];
        op.reduce_local_sharded(0, &[earlier], &mut inout);
        assert_eq!(inout[0], earlier.then(&later));
    }

    #[test]
    fn application_counter() {
        let op = ops::bxor();
        let mut buf = vec![0i64; 4];
        op.reduce_local_sharded(0, &[1, 2, 3, 4], &mut buf);
        op.reduce_local_sharded(0, &[1, 2, 3, 4], &mut buf);
        assert_eq!(op.applications(), 2);
        assert_eq!(buf, vec![0, 0, 0, 0]);
        op.reset_applications();
        assert_eq!(op.applications(), 0);
    }

    #[test]
    fn sharded_counters_aggregate_across_ranks() {
        // Counts land on per-rank shards (incl. the wrap beyond the shard
        // count) and aggregate exactly; reset clears every shard.
        let op = ops::sum_u64();
        let mut buf = vec![0u64; 2];
        for rank in [0usize, 1, 7, 63, 64, 1151] {
            op.reduce_local_sharded(rank, &[1, 2], &mut buf);
        }
        assert_eq!(op.applications(), 6);
        op.reset_applications();
        assert_eq!(op.applications(), 0);
    }

    #[test]
    #[allow(deprecated)]
    fn deprecated_unsharded_entry_still_forwards_to_shard_0() {
        let op = ops::sum_u64();
        let mut buf = vec![0u64; 2];
        op.reduce_local(&[1, 2], &mut buf);
        assert_eq!(op.applications(), 1);
        assert_eq!(buf, vec![1, 2]);
    }

    #[test]
    fn name_is_borrowed() {
        let op = ops::bxor();
        let name: &str = op.name(); // no allocation, just a borrow
        assert_eq!(name, "bxor_i64");
    }

    #[test]
    fn bxor_semantics() {
        let op = ops::bxor();
        let mut b = vec![0b1010i64, -1];
        op.reduce_local_sharded(0, &[0b0110, 0], &mut b);
        assert_eq!(b, vec![0b1100, -1]);
    }

    #[test]
    fn expensive_bxor_exact() {
        let slow = ops::expensive_bxor(64);
        let fast = ops::bxor();
        let input: Vec<i64> = (0..33).map(|i| i * 7 - 11).collect();
        let mut a: Vec<i64> = (0..33).map(|i| i ^ 0x5a).collect();
        let mut b = a.clone();
        slow.reduce_local_sharded(0, &input, &mut a);
        fast.reduce_local_sharded(0, &input, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn sum_wrapping() {
        let op = ops::sum_i64();
        let mut b = vec![i64::MAX];
        op.reduce_local_sharded(0, &[1], &mut b);
        assert_eq!(b, vec![i64::MIN]);
    }

    #[test]
    fn minmax() {
        let mx = ops::max_i64();
        let mn = ops::min_i64();
        let mut b = vec![3i64, -5];
        mx.reduce_local_sharded(0, &[1, 7], &mut b);
        assert_eq!(b, vec![3, 7]);
        let mut b = vec![3i64, -5];
        mn.reduce_local_sharded(0, &[1, 7], &mut b);
        assert_eq!(b, vec![1, -5]);
    }

    #[test]
    fn builtin_ops_resolve_static_kernels() {
        assert_eq!(ops::bxor().kernel().dispatch(), "static");
        assert_eq!(ops::sum_u64().kernel().dispatch(), "static");
        assert_eq!(ops::rec2_compose().kernel().dispatch(), "static");
        // No registered kernel → dyn combine_slice fallback.
        assert_eq!(ops::expensive_bxor(4).kernel().dispatch(), "dyn-slice");
        // The reference dispatch is always available.
        assert_eq!(ops::bxor().kernel_per_element().dispatch(), "per-element");
    }

    #[test]
    fn kernel_paths_are_bit_identical_and_count_once() {
        let op = ops::sum_i64();
        let input: Vec<i64> = (0..257).map(|i| i * 31 - 9).collect();
        let base: Vec<i64> = (0..257).map(|i| !(i * 7)).collect();
        let mut a = base.clone();
        let mut b = base.clone();
        let mut c = base.clone();
        op.kernel().apply_sharded(1, &input, &mut a);
        op.kernel_per_element().apply_sharded(2, &input, &mut b);
        op.reduce_local_sharded(3, &input, &mut c);
        assert_eq!(a, b, "slice kernel must match the per-element reference");
        assert_eq!(a, c, "reduce_local_sharded must route through the kernel");
        assert_eq!(op.applications(), 3, "each application counts exactly once");
    }

    #[test]
    fn kernel_resolution_is_per_collective_not_per_apply() {
        // The handle is Copy and borrows the OpRef: resolve once, apply
        // many times; counters aggregate on the one underlying operator.
        let op = ops::bxor();
        let k = op.kernel();
        let k2 = k; // Copy
        let mut buf = vec![0i64; 8];
        for shard in 0..10 {
            k.apply_sharded(shard, &[1; 8], &mut buf);
            k2.apply_sharded(shard, &[1; 8], &mut buf);
        }
        assert_eq!(op.applications(), 20);
        assert_eq!(buf, vec![0i64; 8]);
    }

    #[test]
    fn scan_kernel_matches_repeated_combine_bitwise() {
        // The tight-loop prefix scan must be bit-identical to folding each
        // row into the next with the combine kernel — including f64, where
        // "equal" means equal bits, not approximate.
        fn check<T: Elem>(op: &OpRef<T>, base: &[T], width: usize) {
            let n = base.len() / width.max(1);
            let mut scanned = base.to_vec();
            op.kernel().scan_sharded(0, &mut scanned, width, n);
            let mut reference = base.to_vec();
            for j in 1..n {
                let (earlier, rest) = reference.split_at_mut(j * width);
                op.kernel().apply_sharded(0, &earlier[(j - 1) * width..], &mut rest[..width]);
            }
            assert_eq!(scanned, reference, "op={}", op.name());
        }
        let rows_i64: Vec<i64> = (0..7 * 5).map(|i| (i * 37 - 91) ^ (i << 3)).collect();
        for op in [ops::bxor(), ops::bor(), ops::sum_i64(), ops::max_i64(), ops::min_i64()] {
            check(&op, &rows_i64, 5);
        }
        let rows_u64: Vec<u64> = (0..6 * 4).map(|i| (i as u64).wrapping_mul(0x9E37_79B9)).collect();
        check(&ops::sum_u64(), &rows_u64, 4);
        let rows_f64: Vec<f64> = (0..5 * 3).map(|i| (i as f64) * 0.7 - 3.1).collect();
        let f = ops::sum_f64();
        let mut scanned = rows_f64.clone();
        f.kernel().scan_sharded(0, &mut scanned, 3, 5);
        let mut reference = rows_f64;
        for j in 1..5 {
            let (earlier, rest) = reference.split_at_mut(j * 3);
            f.kernel().apply_sharded(0, &earlier[(j - 1) * 3..], &mut rest[..3]);
        }
        for (a, b) in scanned.iter().zip(&reference) {
            assert_eq!(a.to_bits(), b.to_bits(), "f64 prefix scan must match by bits");
        }
        let rows_rec2: Vec<Rec2> = (0..4 * 2)
            .map(|i| {
                Rec2::new(
                    [1.0, 0.03 * i as f32, -0.02 * i as f32, 1.0],
                    [i as f32 * 0.5, 1.0 - i as f32 * 0.25],
                )
            })
            .collect();
        check(&ops::rec2_compose(), &rows_rec2, 2);
    }

    #[test]
    fn scan_counts_n_minus_one_applications() {
        let op = ops::sum_i64();
        let mut rows = vec![1i64; 6 * 8];
        op.kernel().scan_sharded(3, &mut rows, 8, 6);
        assert_eq!(op.applications(), 5, "n rows scan in n−1 applications");
        // Zero-width rows: the accounting is m-independent — the n−1
        // applications still count, matching RankCtx::fold on empty slices.
        let mut empty: Vec<i64> = vec![];
        op.kernel().scan_sharded(3, &mut empty, 0, 6);
        assert_eq!(op.applications(), 10);
        // n <= 1 scans nothing and counts nothing.
        op.kernel().scan_sharded(3, &mut rows, 8, 1);
        op.kernel().scan_sharded(3, &mut empty, 0, 0);
        assert_eq!(op.applications(), 10);
    }

    #[test]
    fn scan_dispatch_paths_agree() {
        // Static tight loop ≡ dyn scan_slice fallback ≡ per-element
        // reference, and a no-scan-kernel operator (expensive_bxor) takes
        // the dyn fallback without misbehaving.
        let rows: Vec<i64> = (0..9 * 4).map(|i| (i * 13 + 5) ^ 0x2A).collect();
        let op = ops::bxor();
        let mut a = rows.clone();
        op.kernel().scan_sharded(0, &mut a, 4, 9);
        let mut b = rows.clone();
        op.kernel_per_element().scan_sharded(0, &mut b, 4, 9);
        assert_eq!(a, b, "per-element scan path must match static");
        let slow = ops::expensive_bxor(8);
        let mut c = rows;
        slow.kernel().scan_sharded(0, &mut c, 4, 9);
        assert_eq!(a, c, "dyn scan_slice fallback must match");
        assert_eq!(slow.applications(), 8);
    }

    #[test]
    fn dyn_slice_fallback_matches_reference() {
        // expensive_bxor has no static kernel: dyn combine_slice must
        // still be bit-identical to the per-element reference.
        let op = ops::expensive_bxor(16);
        let input: Vec<i64> = (0..100).map(|i| i * 13 + 5).collect();
        let base: Vec<i64> = (0..100).map(|i| i ^ 0x77).collect();
        let mut a = base.clone();
        let mut b = base;
        op.kernel().apply_sharded(0, &input, &mut a);
        op.kernel_per_element().apply_sharded(0, &input, &mut b);
        assert_eq!(a, b);
    }
}
