//! Shared-memory transport backend: per-(src, dst) SPSC byte rings laid
//! out in one `MAP_SHARED | MAP_ANONYMOUS` mmap'd segment, carrying
//! [`wire`](super::wire)-encoded frames.
//!
//! ## Layout
//!
//! ```text
//! segment := seg_header (128 B: poison flag + reserved)
//!          ∥ p² × channel,  channel (src, dst) at index src·p + dst
//! channel := head (AtomicU64, own 128-B line)   — consumer cursor
//!          ∥ tail (AtomicU64, own 128-B line)   — producer cursor
//!          ∥ ring data (RING_CAP bytes, power of two)
//! ```
//!
//! `head`/`tail` are monotonically increasing byte counters (index =
//! counter & (RING_CAP − 1), wrap by split copy). The producer copies the
//! whole frame **before** its single `Release` store of `tail`, so the
//! consumer can never observe a partial frame; the consumer advances
//! `head` with a `Release` store only after copying the frame out. One
//! producer per channel (the sending rank's thread), one consumer (the
//! receiving rank's thread — the executor pins one thread per rank).
//!
//! ## Matching
//!
//! The segment only moves bytes. Each rank keeps a process-local slot
//! [`Inbox`] as its matcher: [`ShmTransport::take`] alternates draining
//! the rank's p incoming rings (seq-check/verify/repair through the
//! shared [`WireRecovery`] layer, then decode and deposit through the
//! same `deposit`/`deposit_delayed`/`deposit_overflow` entry points
//! the thread backend uses — the frame's `kind` byte carries the sender's
//! chaos decision) with short-sliced `recv_match` waits, so the
//! (src, ctx, chunk, round) slot keying, overflow and embargo semantics
//! are byte-for-byte the inbox's own. A ring write does not wake a parked
//! receiver, so waits are sliced at [`DRAIN_SLICE`]; that bounds the
//! added rendezvous latency, and the cross-backend differential suite
//! verifies outputs/digests/traces are unaffected.
//!
//! The anonymous shared mapping is inherited across `fork`, and all
//! transport state that crosses the rendezvous boundary (cursors, poison
//! flag, frames) lives inside the segment — the matcher inboxes are
//! per-process caches of in-flight frames, so a forked multi-process
//! world needs no additional shared state. In-process worlds (this
//! crate's executors) run one thread per rank over the same segment.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use super::elem::Elem;
use super::inbox::{Inbox, InboxStats};
use super::msg::Msg;
use super::pool::PoolBuf;
use super::recover::{
    FrameVerdict, TransportFault, TransportFaultKind, TransportStats, WireRecovery,
};
use super::transport::{Transport, TransportBackend, TransportTuning};
use super::wire::{decode_header, decode_payload, encode_frame, FrameKind, HEADER_BYTES};
use super::wirefault::WireFaultReport;

/// Ring capacity per directed channel, bytes (power of two). Bounds the
/// largest frame a channel can carry: `HEADER_BYTES + payload` must fit.
/// 1 MiB covers every registered workload up to m = 65536 × i64 with
/// room; larger messages belong on the thread backend (the error names
/// this constant).
const RING_CAP: usize = 1 << 20;
/// Mask for cursor → ring index (RING_CAP is a power of two).
const RING_MASK: u64 = (RING_CAP as u64) - 1;
/// Segment header: one cache line holding the poison flag.
const SEG_HEADER: usize = 128;
/// Channel header: head and tail on their own 128-byte lines.
const CH_HEADER: usize = 256;
/// Byte stride of one channel inside the segment.
const CH_STRIDE: usize = CH_HEADER + RING_CAP;
/// Receive waits are sliced at this period so the consumer keeps
/// draining its rings while blocked (ring writes cannot wake a parked
/// inbox receiver).
const DRAIN_SLICE: Duration = Duration::from_micros(100);
/// On entry to a blocking take, poll spin-only (no park) for this long
/// before falling back to parked slices — keeps the in-window rendezvous
/// latency near the thread backend's.
const HOT_POLL: Duration = Duration::from_micros(300);

#[cfg(any(target_os = "linux", target_os = "android", target_os = "macos"))]
mod sys {
    use core::ffi::c_void;

    pub const PROT_READ: i32 = 1;
    pub const PROT_WRITE: i32 = 2;
    pub const MAP_SHARED: i32 = 0x01;
    #[cfg(any(target_os = "linux", target_os = "android"))]
    pub const MAP_ANONYMOUS: i32 = 0x20;
    #[cfg(target_os = "macos")]
    pub const MAP_ANONYMOUS: i32 = 0x1000;

    // Self-declared bindings (the workspace deliberately has no libc
    // dependency); signatures match POSIX.
    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> i32;
    }
}

/// Probe whether this host can construct the shm backend (maps and
/// unmaps one page). Attributed error otherwise.
pub fn probe() -> Result<()> {
    #[cfg(any(target_os = "linux", target_os = "android", target_os = "macos"))]
    {
        let len = 4096usize;
        let ptr = unsafe {
            sys::mmap(
                std::ptr::null_mut(),
                len,
                sys::PROT_READ | sys::PROT_WRITE,
                sys::MAP_SHARED | sys::MAP_ANONYMOUS,
                -1,
                0,
            )
        };
        if ptr as isize == -1 {
            bail!(
                "transport backend 'shm' unavailable: mmap(MAP_SHARED|MAP_ANONYMOUS) failed \
                 (errno via OS): {}",
                std::io::Error::last_os_error()
            );
        }
        unsafe { sys::munmap(ptr, len) };
        Ok(())
    }
    #[cfg(not(any(target_os = "linux", target_os = "android", target_os = "macos")))]
    {
        bail!("transport backend 'shm' unavailable: no mmap bindings for this OS")
    }
}

/// Owns the mapped segment; unmapped on drop.
struct Segment {
    base: *mut u8,
    len: usize,
}

// The raw pointer is into a MAP_SHARED mapping private to this transport;
// all concurrent access goes through the atomics and the SPSC publish
// protocol documented above.
unsafe impl Send for Segment {}
unsafe impl Sync for Segment {}

impl Segment {
    fn map(len: usize) -> Result<Segment> {
        #[cfg(any(target_os = "linux", target_os = "android", target_os = "macos"))]
        {
            let ptr = unsafe {
                sys::mmap(
                    std::ptr::null_mut(),
                    len,
                    sys::PROT_READ | sys::PROT_WRITE,
                    sys::MAP_SHARED | sys::MAP_ANONYMOUS,
                    -1,
                    0,
                )
            };
            if ptr as isize == -1 {
                bail!(
                    "transport backend 'shm' unavailable: mmap of {len} bytes failed: {}",
                    std::io::Error::last_os_error()
                );
            }
            // Anonymous mappings are zero-filled: cursors and the poison
            // flag start at 0 with no extra initialization.
            Ok(Segment { base: ptr as *mut u8, len })
        }
        #[cfg(not(any(target_os = "linux", target_os = "android", target_os = "macos")))]
        {
            let _ = len;
            bail!("transport backend 'shm' unavailable: no mmap bindings for this OS")
        }
    }
}

impl Drop for Segment {
    fn drop(&mut self) {
        #[cfg(any(target_os = "linux", target_os = "android", target_os = "macos"))]
        unsafe {
            sys::munmap(self.base as *mut core::ffi::c_void, self.len);
        }
    }
}

/// The shared-memory backend. See the module docs for the protocol.
pub(crate) struct ShmTransport<T> {
    seg: Segment,
    p: usize,
    /// Per-rank process-local matchers (identical machinery to the
    /// thread backend; frames land here once drained from the rings).
    inboxes: Vec<Inbox<T>>,
    /// Seq accounting, duplicate suppression, retransmit shelf and the
    /// typed-fault slot — shared machinery with the socket backend
    /// (`mpi/recover.rs`).
    recovery: WireRecovery,
}

impl<T: Elem> ShmTransport<T> {
    pub fn new(p: usize, tuning: &TransportTuning) -> Result<Self> {
        let len = SEG_HEADER + p * p * CH_STRIDE;
        let seg = Segment::map(len)?;
        Ok(ShmTransport {
            seg,
            p,
            inboxes: (0..p).map(|_| Inbox::new_with(tuning.fixed_spin)).collect(),
            recovery: WireRecovery::new(TransportBackend::Shm, p, tuning.wirefault.as_ref()),
        })
    }

    /// The segment-resident poison flag (cache line 0).
    fn poison_flag(&self) -> &AtomicU64 {
        unsafe { &*(self.seg.base as *const AtomicU64) }
    }

    fn channel_base(&self, src: usize, dst: usize) -> *mut u8 {
        debug_assert!(src < self.p && dst < self.p);
        unsafe { self.seg.base.add(SEG_HEADER + (src * self.p + dst) * CH_STRIDE) }
    }

    fn cursors(&self, src: usize, dst: usize) -> (&AtomicU64, &AtomicU64) {
        let base = self.channel_base(src, dst);
        unsafe { (&*(base as *const AtomicU64), &*(base.add(128) as *const AtomicU64)) }
    }

    fn ring_ptr(&self, src: usize, dst: usize) -> *mut u8 {
        unsafe { self.channel_base(src, dst).add(CH_HEADER) }
    }

    /// Copy `bytes` into the ring at absolute cursor `at` (split on wrap).
    fn ring_copy_in(&self, src: usize, dst: usize, at: u64, bytes: &[u8]) {
        let ring = self.ring_ptr(src, dst);
        let idx = (at & RING_MASK) as usize;
        let first = bytes.len().min(RING_CAP - idx);
        unsafe {
            std::ptr::copy_nonoverlapping(bytes.as_ptr(), ring.add(idx), first);
            if first < bytes.len() {
                std::ptr::copy_nonoverlapping(
                    bytes.as_ptr().add(first),
                    ring,
                    bytes.len() - first,
                );
            }
        }
    }

    /// Copy `out.len()` bytes out of the ring at absolute cursor `at`.
    fn ring_copy_out(&self, src: usize, dst: usize, at: u64, out: &mut [u8]) {
        let ring = self.ring_ptr(src, dst);
        let idx = (at & RING_MASK) as usize;
        let first = out.len().min(RING_CAP - idx);
        unsafe {
            std::ptr::copy_nonoverlapping(ring.add(idx), out.as_mut_ptr(), first);
            if first < out.len() {
                std::ptr::copy_nonoverlapping(
                    ring,
                    out.as_mut_ptr().add(first),
                    out.len() - first,
                );
            }
        }
    }

    /// Producer side: block (spin + yield) until the channel has room,
    /// then publish the frame with one Release store of `tail`. Frames
    /// are dropped silently once the transport is poisoned (the world is
    /// tearing down; receivers are already waking attributed).
    fn ring_write(&self, src: usize, dst: usize, frame: &[u8]) {
        assert!(
            frame.len() <= RING_CAP,
            "shm transport: {}-byte frame exceeds the {}-byte ring capacity \
             (src={src} dst={dst}); use the thread backend for messages this large \
             or raise shm::RING_CAP",
            frame.len(),
            RING_CAP
        );
        let (head, tail) = self.cursors(src, dst);
        let t = tail.load(Ordering::Relaxed); // sole producer: own cursor
        loop {
            let h = head.load(Ordering::Acquire);
            let free = RING_CAP as u64 - (t - h);
            if free >= frame.len() as u64 {
                break;
            }
            if self.poison_flag().load(Ordering::Acquire) != 0 {
                return; // dropped on the floor: world death in progress
            }
            std::hint::spin_loop();
            std::thread::yield_now();
        }
        self.ring_copy_in(src, dst, t, frame);
        tail.store(t + frame.len() as u64, Ordering::Release);
    }

    /// Consumer side: drain every complete frame addressed to rank `me`
    /// into its local inbox. Sole consumer of channels (*, me).
    ///
    /// Every frame is copied out contiguously and routed through
    /// [`WireRecovery::process_frame`] — that is where injected wire
    /// faults mutate the local copy, where checksum failures trigger the
    /// retransmit shelf, and where duplicates are suppressed by seq. A
    /// corrupt frame is **never** a panic: when the retry budget
    /// exhausts, the typed fault is recorded first-wins and the whole
    /// transport is poisoned so blocked receivers wake attributed.
    fn drain(&self, me: usize) {
        let mut header = [0u8; HEADER_BYTES];
        for src in 0..self.p {
            let (head, tail) = self.cursors(src, me);
            loop {
                let h = head.load(Ordering::Relaxed); // sole consumer
                let t = tail.load(Ordering::Acquire);
                let avail = t - h;
                if avail < HEADER_BYTES as u64 {
                    break; // producer publishes whole frames: nothing here
                }
                self.ring_copy_out(src, me, h, &mut header);
                // The transmitted length comes straight off the ring: the
                // producer publishes whole frames with one Release store,
                // and injected mutations happen on the copied-out frame
                // inside process_frame, so these bytes are as written.
                let payload_len =
                    u32::from_le_bytes(header[44..48].try_into().unwrap()) as usize;
                let total = (HEADER_BYTES + payload_len) as u64;
                debug_assert!(avail >= total, "partial frame published");
                let mut frame = vec![0u8; HEADER_BYTES + payload_len];
                self.ring_copy_out(src, me, h, &mut frame);
                head.store(h + total, Ordering::Release);
                let bytes = match self.recovery.process_frame(src, me, frame) {
                    Ok(FrameVerdict::Dup) => continue,
                    Ok(FrameVerdict::Deliver(bytes)) => bytes,
                    Err(_fault) => {
                        // Typed fault already recorded first-wins in the
                        // recovery slot; wake everyone attributed.
                        self.poison_all();
                        return;
                    }
                };
                let fh = match decode_header(&bytes) {
                    Ok(fh) => fh,
                    Err(_) => {
                        self.recovery.raise_external(
                            src,
                            me,
                            TransportFaultKind::CorruptHeader,
                        );
                        self.poison_all();
                        return;
                    }
                };
                let data: Vec<T> = match decode_payload(&fh, &bytes[HEADER_BYTES..]) {
                    Ok(data) => data,
                    Err(_) => {
                        self.recovery.raise_external(
                            src,
                            me,
                            TransportFaultKind::UndecodablePayload,
                        );
                        self.poison_all();
                        return;
                    }
                };
                let msg = Msg {
                    src: fh.src,
                    tag: fh.tag,
                    data: PoolBuf::detached(data),
                    vtime: fh.vtime,
                };
                match fh.kind {
                    FrameKind::Deliver => self.inboxes[me].deposit(msg),
                    FrameKind::Delayed => self.inboxes[me].deposit_delayed(
                        msg,
                        Instant::now() + Duration::from_micros(fh.delay_micros),
                    ),
                    FrameKind::Overflow => self.inboxes[me].deposit_overflow(msg),
                }
            }
        }
    }

    fn send_frame(&self, to: usize, kind: FrameKind, delay_micros: u64, msg: Msg<T>) {
        let src = msg.src;
        let seq = self.recovery.next_seq(src, to);
        let frame =
            encode_frame(kind, src, to, msg.tag, delay_micros, msg.vtime, seq, &msg.data);
        drop(msg); // lease ends here: the pooled send buffer recycles now
        let plan = self.recovery.on_send(src, to, seq, &frame);
        self.ring_write(src, to, &frame);
        if plan.duplicate {
            // Injected duplicate: the receiver must suppress it by seq.
            self.ring_write(src, to, &frame);
        }
    }
}

impl<T: Elem> Transport<T> for ShmTransport<T> {
    fn post(&self, to: usize, msg: Msg<T>) {
        self.send_frame(to, FrameKind::Deliver, 0, msg);
    }

    fn post_delayed(&self, to: usize, msg: Msg<T>, release_at: Instant) {
        // The embargo crosses the boundary as a relative hold: Instants
        // are process-local. Computed back on the receiving side at
        // deposit time; the hold is what chaos planned, minus transit.
        let micros = release_at.saturating_duration_since(Instant::now()).as_micros() as u64;
        self.send_frame(to, FrameKind::Delayed, micros, msg);
    }

    fn post_overflow(&self, to: usize, msg: Msg<T>) {
        self.send_frame(to, FrameKind::Overflow, 0, msg);
    }

    fn take(
        &self,
        me: usize,
        src: usize,
        tag: u64,
        pending: &mut Vec<Msg<T>>,
        deadline: Instant,
    ) -> Option<Msg<T>> {
        let hot_until = Instant::now() + HOT_POLL;
        loop {
            self.drain(me);
            let now = Instant::now();
            // Hot window: spin-probe only (a deadline already in the past
            // still probes the slot + overflow once per recv_match).
            // After it: park in DRAIN_SLICE slices so arriving frames are
            // picked up promptly even though ring writes can't wake us.
            let slice = if now < hot_until {
                now
            } else {
                deadline.min(now + DRAIN_SLICE)
            };
            if let Some(m) = self.inboxes[me].recv_match(src, tag, pending, slice) {
                return Some(m);
            }
            if self.poison_flag().load(Ordering::Acquire) != 0 {
                return None;
            }
            if Instant::now() >= deadline {
                return None;
            }
        }
    }

    fn poison_all(&self) {
        self.poison_flag().store(1, Ordering::Release);
        for inbox in &self.inboxes {
            inbox.poison();
        }
    }

    fn stats(&self, me: usize) -> InboxStats {
        self.inboxes[me].stats()
    }

    fn wire_stats(&self) -> TransportStats {
        self.recovery.stats()
    }

    fn fault(&self) -> Option<TransportFault> {
        self.recovery.fault()
    }

    fn wire_report(&self) -> Option<WireFaultReport> {
        self.recovery.report()
    }

    fn name(&self) -> &'static str {
        "shm"
    }
}

#[cfg(all(test, any(target_os = "linux", target_os = "android", target_os = "macos")))]
mod tests {
    use super::*;
    use crate::mpi::pool::PoolBuf;

    fn mk_msg(src: usize, tag: u64, data: Vec<i64>) -> Msg<i64> {
        Msg { src, tag, data: PoolBuf::detached(data), vtime: 0.0 }
    }

    #[test]
    fn shm_roundtrip_and_matching() {
        let t: ShmTransport<i64> = ShmTransport::new(2, &TransportTuning::default()).unwrap();
        t.post(1, mk_msg(0, 7, vec![1, 2, 3]));
        t.post(1, mk_msg(0, 8, vec![9]));
        let mut pending = Vec::new();
        // Out-of-order take: tag 8 before tag 7 — both land intact.
        let deadline = Instant::now() + Duration::from_secs(5);
        let m = t.take(1, 0, 8, &mut pending, deadline).unwrap();
        assert_eq!(&m.data[..], &[9]);
        let m = t.take(1, 0, 7, &mut pending, deadline).unwrap();
        assert_eq!(&m.data[..], &[1, 2, 3]);
    }

    #[test]
    fn shm_ring_wraparound_preserves_frames() {
        let t: ShmTransport<i64> = ShmTransport::new(2, &TransportTuning::default()).unwrap();
        // Push enough traffic through one channel to wrap the ring
        // several times; every frame must come back intact and in order.
        let m = 4096; // 32 KiB payloads: ~32 KiB/frame, > 3 wraps total
        let rounds = 3 * (RING_CAP / (m * 8)) as u32 + 5;
        let mut pending = Vec::new();
        for k in 0..rounds {
            let payload: Vec<i64> = (0..m as i64).map(|i| i ^ k as i64).collect();
            t.post(1, mk_msg(0, k as u64, payload.clone()));
            let got = t
                .take(1, 0, k as u64, &mut pending, Instant::now() + Duration::from_secs(5))
                .unwrap();
            assert_eq!(&got.data[..], &payload[..], "round {k}");
        }
        assert!(pending.is_empty());
    }

    #[test]
    fn shm_poison_wakes_blocked_take() {
        let t =
            std::sync::Arc::new(ShmTransport::<i64>::new(2, &TransportTuning::default()).unwrap());
        let t2 = std::sync::Arc::clone(&t);
        let waiter = std::thread::spawn(move || {
            let mut pending = Vec::new();
            let deadline = Instant::now() + Duration::from_secs(30);
            t2.take(1, 0, 99, &mut pending, deadline)
        });
        std::thread::sleep(Duration::from_millis(30));
        t.poison_all();
        let got = waiter.join().unwrap();
        assert!(got.is_none(), "poison must wake the blocked take promptly");
    }

    #[test]
    fn corrupt_ring_frame_is_a_typed_fault_not_a_panic() {
        // Corrupt a published frame in place on the ring (no fault plan
        // armed, so no shelf to repair from): take must return None with
        // a typed first-wins fault recorded — never a receiver panic.
        let t: ShmTransport<i64> = ShmTransport::new(2, &TransportTuning::default()).unwrap();
        t.post(1, mk_msg(0, 7, vec![1, 2, 3]));
        // Flip a payload byte of the frame sitting at cursor 0 of the
        // (0 → 1) channel. The header stays intact (so framing holds)
        // but the checksum no longer verifies.
        unsafe {
            *t.ring_ptr(0, 1).add(HEADER_BYTES) ^= 0xFF;
        }
        let mut pending = Vec::new();
        let got = t.take(1, 0, 7, &mut pending, Instant::now() + Duration::from_secs(5));
        assert!(got.is_none(), "corrupt frame must not deliver");
        let fault = t.fault().expect("typed fault recorded");
        assert_eq!(fault.kind, TransportFaultKind::ChecksumMismatch);
        assert_eq!((fault.src, fault.dst, fault.seq), (0, 1, 0));
        assert_eq!(t.wire_stats().faults, 1);
        // The transport is poisoned: later takes wake attributed too.
        let got = t.take(1, 0, 8, &mut pending, Instant::now() + Duration::from_secs(5));
        assert!(got.is_none());
    }

    #[test]
    fn injected_duplicates_are_suppressed_end_to_end() {
        use crate::mpi::wirefault::WireFaultConfig;
        // Certain duplication on every frame, everything else off: each
        // frame is written to the ring twice and must deliver exactly
        // once, with the dup counter accounting for the copies.
        let cfg = WireFaultConfig::new(3)
            .with_header_flip_prob(0.0)
            .with_payload_flip_prob(0.0)
            .with_checksum_prob(0.0)
            .with_truncate_prob(0.0)
            .with_duplicate_prob(1.0)
            .with_reset_prob(0.0);
        let tuning = TransportTuning { wirefault: Some(cfg), ..TransportTuning::default() };
        let t: ShmTransport<i64> = ShmTransport::new(2, &tuning).unwrap();
        let deadline = Instant::now() + Duration::from_secs(5);
        let mut pending = Vec::new();
        for k in 0..4u64 {
            t.post(1, mk_msg(0, k, vec![k as i64]));
            let m = t.take(1, 0, k, &mut pending, deadline).unwrap();
            assert_eq!(&m.data[..], &[k as i64]);
        }
        assert_eq!(t.wire_stats().dropped_dups, 4);
        assert_eq!(t.wire_stats().faults, 0);
        let report = t.wire_report().expect("plan armed");
        assert_eq!(report.duplicates, 4);
        assert!(pending.is_empty());
    }

    #[test]
    fn probe_reports_available_here() {
        probe().unwrap();
    }
}
