//! The message-passing substrate: an MPI-flavoured, typed, thread-backed
//! communication layer with two interchangeable clock modes.
//!
//! * [`elem`] — element types (`MPI_Datatype` analogue), incl. [`Rec2`].
//! * [`op`] — associative operators (`MPI_Op` + `MPI_Reduce_local`) with
//!   per-rank sharded application counters and the [`OpKernel`] slice
//!   dispatch engine (resolved once per collective).
//! * [`comm`] — communicators with context ids ([`Comm`], `dup`/`split`)
//!   and the packed [`TagKey`] that match-isolates concurrent collectives.
//! * [`ctx`] — the per-rank API: `send`/`recv`/`sendrecv`/`reduce_local`
//!   plus the fused `recv_reduce`/`sendrecv_reduce` compute hot path and
//!   communicator scoping (`with_comm`/`with_chunk`).
//! * [`pool`] — recycling per-rank buffer pools (zero-allocation sends).
//! * [`inbox`] — slot-keyed rendezvous matching (no MPMC lock, no scan).
//! * [`world`] — topology, the one-shot [`run_world`]/[`run_scan`] entry
//!   points and the persistent [`World`] executor.
//! * [`chaos`] — seeded deterministic fault injection (message embargo,
//!   slot diversion, scheduler yields, pool pressure, targeted drops, and
//!   scheduled **rank death** with poison-wake attribution via
//!   [`World::dead_ranks`]) for the differential self-verification
//!   harness (EXPERIMENTS.md §Chaos, §Robustness).
//!
//! Real MPI is deliberately *not* a dependency: the paper's claims are
//! about round structure and ⊕ counts, which this substrate reproduces
//! with exact one-ported semantics, while the virtual clock scales the
//! evaluation to the paper's 36×32 cluster on a laptop.

pub mod chaos;
pub mod comm;
pub mod ctx;
pub mod elem;
pub(crate) mod inbox;
pub mod msg;
pub mod op;
pub mod pool;
pub mod vbarrier;
pub mod world;

pub use chaos::{ChaosAction, ChaosConfig, ChaosEvent, ChaosReport};
pub use comm::{Comm, CtxAlloc, TagKey, WORLD_CTX};
pub use ctx::{ClockMode, RankCtx};
pub use elem::{Dtype, Elem, Rec2};
pub use inbox::InboxStats;
pub use op::{kernels, ops, CombineOp, FnOp, OpKernel, OpRef, ScanKernelFn, SliceKernelFn};
pub use pool::{PoolBuf, PoolStats};
pub use world::{
    rank_threads_spawned, run_scan, run_world, RunResult, Topology, World, WorldConfig,
};
