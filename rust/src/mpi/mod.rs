//! The message-passing substrate: an MPI-flavoured, typed communication
//! layer with two interchangeable clock modes and a **pluggable
//! rendezvous transport** — worlds run over in-process thread inboxes,
//! shared-memory rings, or socket meshes, selected per world.
//!
//! * [`elem`] — element types (`MPI_Datatype` analogue), incl. [`Rec2`];
//!   every element also defines a padding-free little-endian **wire
//!   encoding** used by the cross-process transports.
//! * [`op`] — associative operators (`MPI_Op` + `MPI_Reduce_local`) with
//!   per-rank sharded application counters and the [`OpKernel`] slice
//!   dispatch engine (resolved once per collective).
//! * [`comm`] — communicators with context ids ([`Comm`], `dup`/`split`)
//!   and the packed [`TagKey`] that match-isolates concurrent collectives.
//! * [`ctx`] — the per-rank API: `send`/`recv`/`sendrecv`/`reduce_local`
//!   plus the fused `recv_reduce`/`sendrecv_reduce` compute hot path and
//!   communicator scoping (`with_comm`/`with_chunk`). Chaos decisions are
//!   made here, **above** the transport boundary, so injected schedules
//!   and digests are backend-independent by construction.
//! * [`pool`] — recycling per-rank buffer pools (zero-allocation sends).
//! * [`transport`] — the [`Transport`](transport::Transport) boundary
//!   (post / matched take / poison-wake, pooled-buffer lease semantics)
//!   and the [`TransportBackend`] selector. Three backends:
//!   * **thread** ([`inbox`]) — the slot-keyed rendezvous matcher (no
//!     MPMC lock, no scan; adaptive per-slot EMA spin budget). The
//!     default, and the oracle every other backend is differentially
//!     verified against.
//!   * **shm** ([`shm`]) — per-(src, dst) SPSC byte rings in one
//!     `MAP_SHARED` mmap'd segment; checksummed frames ([`wire`]),
//!     drained into the same inbox matcher with the same
//!     (src, ctx, chunk, round) keying.
//!   * **tcp / uds** ([`socket`]) — loopback TCP or Unix-domain stream
//!     mesh with per-peer send/recv threads feeding the inbox matcher;
//!     length-prefixed, versioned, checksummed frames.
//!
//!   Both wire backends share the [`recover`] layer: sequence-numbered
//!   frames (wire v2), seq-based duplicate suppression, NACK/retransmit
//!   repair of corrupt frames under a bounded exponential-backoff
//!   budget, and the typed [`TransportFault`] taxonomy that replaced
//!   every receiver-thread panic — faults funnel through poison-wake
//!   into the engine's `RankFailed` attribution. [`wirefault`] is the
//!   seeded, replayable fault *injector* driving that machinery from
//!   **below** the chaos boundary (frame bit flips, checksum smashes,
//!   truncation, duplication, stream resets), armed per world via
//!   [`WorldConfig::with_wire_faults`].
//! * [`world`] — topology, the one-shot [`run_world`]/[`run_scan`] entry
//!   points and the persistent [`World`] executor;
//!   [`WorldConfig::with_transport`] selects the backend.
//! * [`chaos`] — seeded deterministic fault injection (message embargo,
//!   slot diversion, scheduler yields, pool pressure, targeted drops, and
//!   scheduled **rank death** with poison-wake attribution via
//!   [`World::dead_ranks`]) for the differential self-verification
//!   harness. The chaos layer wraps **any** backend verbatim — same
//!   seeds, same XOR digests, same trace invariants (EXPERIMENTS.md
//!   §Chaos, §Robustness, §Transport).
//!
//! Real MPI is deliberately *not* a dependency: the paper's claims are
//! about round structure and ⊕ counts, which this substrate reproduces
//! with exact one-ported semantics, while the virtual clock scales the
//! evaluation to the paper's 36×32 cluster on a laptop.

pub mod chaos;
pub mod comm;
pub mod ctx;
pub mod elem;
pub(crate) mod inbox;
pub mod msg;
pub mod op;
pub mod pool;
pub(crate) mod recover;
pub(crate) mod shm;
pub(crate) mod socket;
pub(crate) mod transport;
pub mod vbarrier;
pub(crate) mod wire;
pub mod wirefault;
pub mod world;

pub use chaos::{ChaosAction, ChaosConfig, ChaosEvent, ChaosReport};
pub use comm::{Comm, CtxAlloc, TagKey, WORLD_CTX};
pub use ctx::{ClockMode, RankCtx};
pub use elem::{Dtype, Elem, Rec2};
pub use inbox::InboxStats;
pub use op::{kernels, ops, CombineOp, FnOp, OpKernel, OpRef, ScanKernelFn, SliceKernelFn};
pub use pool::{PoolBuf, PoolStats};
pub use recover::{TransportFault, TransportFaultKind, TransportStats};
pub use transport::{TransportBackend, DEFAULT_WRITE_TIMEOUT};
pub use wirefault::{WireFaultConfig, WireFaultEvent, WireFaultKind, WireFaultReport};
pub use world::{
    rank_threads_spawned, run_scan, run_world, RunResult, Topology, World, WorldConfig,
};
