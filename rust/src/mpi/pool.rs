//! Per-rank recycling buffer pools: the zero-allocation half of the
//! transport (EXPERIMENTS.md §Perf).
//!
//! Every message used to pay one heap allocation (`data.to_vec()`) on the
//! send side and one deallocation when the receiver dropped it. Scan
//! algorithms send the same-length vector every round, so in steady state
//! the allocator traffic is pure waste — and at m = 1 it *dominates* the
//! per-round software cost the paper's round-count argument depends on.
//!
//! The pool closes the loop: [`RankCtx::send`](super::RankCtx) acquires a
//! buffer from the sending rank's pool, the buffer travels inside the
//! [`Msg`](super::msg::Msg) envelope, and the receiver's [`PoolBuf`] handle
//! recycles it back to the *owning* (sender's) pool on drop. Because every
//! rank in a scan sends about as often as it receives, each pool converges
//! after one warm-up scan and the hit-rate counters read ~100% — asserted
//! by `tests/transport.rs::pool_steady_state_allocates_nothing`.

use std::ops::{Deref, DerefMut};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Counters exported for tests and the hotpath benchmark. `misses` is the
/// number of `acquire` calls that had to touch the global allocator; in
/// steady state it must stop moving.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// Acquires served entirely from the free list.
    pub hits: u64,
    /// Acquires that allocated (empty free list or undersized buffer).
    pub misses: u64,
    /// Buffers returned to the free list on `PoolBuf` drop.
    pub recycled: u64,
    /// Buffers deliberately dropped on release by chaos pool pressure
    /// (see [`super::chaos::ChaosConfig::pool_discard_period`]). Always 0
    /// outside chaos worlds.
    pub chaos_discarded: u64,
}

impl PoolStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            return 0.0;
        }
        self.hits as f64 / total as f64
    }

    pub fn merge(&mut self, other: &PoolStats) {
        self.hits += other.hits;
        self.misses += other.misses;
        self.recycled += other.recycled;
        self.chaos_discarded += other.chaos_discarded;
    }
}

struct FreeList<T> {
    bufs: Vec<Vec<T>>,
    /// Total `capacity * size_of::<T>()` retained, to bound memory when a
    /// sweep shrinks m after a large-vector point.
    bytes: usize,
}

/// A recycling free list of `Vec<T>` buffers, one per rank.
///
/// Lock discipline: one short `Mutex` section per acquire/release. The
/// only cross-thread traffic is the receiver returning a buffer to the
/// sender's pool — one uncontended lock in the common rendezvous schedule.
pub struct BufferPool<T> {
    free: Mutex<FreeList<T>>,
    /// Retention budget in bytes; buffers beyond it are dropped on release.
    budget_bytes: usize,
    /// Chaos pool pressure: when nonzero, every Nth release drops the
    /// buffer instead of retaining it (deterministic forced misses).
    discard_period: u64,
    releases: AtomicU64,
    hits: AtomicU64,
    misses: AtomicU64,
    recycled: AtomicU64,
    chaos_discarded: AtomicU64,
}

/// Default retention budget per rank. Scans keep at most a few same-sized
/// buffers in flight per rank, so this is generous for any m the
/// benchmarks use while bounding worst-case retention at p = 1152 to
/// ~2.3 GB (vs the ~1 GB the old per-message allocation path had in
/// flight at m = 100 000 anyway).
pub const DEFAULT_BUDGET_BYTES: usize = 2 << 20;

impl<T> BufferPool<T> {
    pub fn new(budget_bytes: usize) -> Self {
        Self::with_discard_period(budget_bytes, 0)
    }

    /// Pool with chaos pressure: every `discard_period`-th release drops
    /// the buffer (0 disables — identical to [`new`](Self::new)).
    pub fn with_discard_period(budget_bytes: usize, discard_period: u64) -> Self {
        BufferPool {
            free: Mutex::new(FreeList { bufs: Vec::new(), bytes: 0 }),
            budget_bytes,
            discard_period,
            releases: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            recycled: AtomicU64::new(0),
            chaos_discarded: AtomicU64::new(0),
        }
    }

    pub fn stats(&self) -> PoolStats {
        PoolStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            recycled: self.recycled.load(Ordering::Relaxed),
            chaos_discarded: self.chaos_discarded.load(Ordering::Relaxed),
        }
    }

    /// Number of buffers currently retained (test hook).
    pub fn retained(&self) -> usize {
        self.free.lock().unwrap().bufs.len()
    }

    /// Pop a retained buffer (or allocate an empty one), classifying the
    /// acquire as hit/miss against the capacity the caller needs.
    fn pop_counted(&self, want: usize) -> Vec<T> {
        let popped = {
            let mut free = self.free.lock().unwrap();
            let b = free.bufs.pop();
            if let Some(ref b) = b {
                free.bytes = free.bytes.saturating_sub(b.capacity() * std::mem::size_of::<T>());
            }
            b
        };
        match popped {
            Some(b) if b.capacity() >= want => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                b
            }
            Some(b) => {
                // Undersized: refilling it will reallocate anyway.
                self.misses.fetch_add(1, Ordering::Relaxed);
                b
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                Vec::with_capacity(want)
            }
        }
    }

    fn release(&self, buf: Vec<T>) {
        if self.discard_period > 0 {
            let n = self.releases.fetch_add(1, Ordering::Relaxed) + 1;
            if n % self.discard_period == 0 {
                // Chaos pool pressure: let the allocator have it back so
                // the next acquire of this size is a forced miss.
                self.chaos_discarded.fetch_add(1, Ordering::Relaxed);
                return;
            }
        }
        let bytes = buf.capacity() * std::mem::size_of::<T>();
        let mut free = self.free.lock().unwrap();
        if free.bytes + bytes <= self.budget_bytes || free.bufs.is_empty() {
            free.bytes += bytes;
            free.bufs.push(buf);
            self.recycled.fetch_add(1, Ordering::Relaxed);
        }
        // else: over budget — let the allocator have it back.
    }
}

impl<T: Copy> BufferPool<T> {
    /// Acquire a buffer holding a copy of `src`. Steady state: pop a
    /// retained buffer and `memcpy` into it — no allocator call.
    /// (Associated fn, not a method: the handle must capture the `Arc`.)
    pub fn acquire_copy(pool: &Arc<Self>, src: &[T]) -> PoolBuf<T> {
        let mut buf = pool.pop_counted(src.len());
        buf.clear();
        buf.extend_from_slice(src);
        PoolBuf { buf, pool: Some(Arc::clone(pool)) }
    }

    /// Acquire a buffer of `len` copies of `fill` — the pooled counterpart
    /// of `vec![fill; len]`, used for algorithm scratch space
    /// ([`RankCtx::scratch_filled`](super::RankCtx::scratch_filled)).
    /// Steady state: pop + fill, no allocator call.
    pub fn acquire_filled(pool: &Arc<Self>, len: usize, fill: T) -> PoolBuf<T> {
        let mut buf = pool.pop_counted(len);
        buf.clear();
        buf.resize(len, fill);
        PoolBuf { buf, pool: Some(Arc::clone(pool)) }
    }
}

/// An owned transport buffer that recycles itself to its pool on drop.
///
/// This is what [`RankCtx::recv_owned`](super::RankCtx::recv_owned) hands
/// to the algorithms: they only ever read it (as the `input` operand of
/// `reduce_local`) or combine in place, which `Deref`/`DerefMut` to `[T]`
/// cover — no call-site changes versus the old `Box<[T]>`.
pub struct PoolBuf<T> {
    buf: Vec<T>,
    pool: Option<Arc<BufferPool<T>>>,
}

impl<T> PoolBuf<T> {
    /// A pool-less buffer (dropped normally). Used by tests and any path
    /// that genuinely needs a one-off allocation.
    pub fn detached(buf: Vec<T>) -> Self {
        PoolBuf { buf, pool: None }
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }
}

impl<T: Copy> PoolBuf<T> {
    /// Resize in place (amortized allocation-free once the buffer has seen
    /// its peak length) — lets block-structured algorithms reuse one
    /// scratch buffer across variable-length blocks.
    pub fn resize(&mut self, len: usize, fill: T) {
        self.buf.resize(len, fill);
    }

    /// Replace the contents with a copy of `src` (clear + extend; no
    /// allocation when capacity suffices).
    pub fn copy_from(&mut self, src: &[T]) {
        self.buf.clear();
        self.buf.extend_from_slice(src);
    }
}

impl<T> Deref for PoolBuf<T> {
    type Target = [T];
    fn deref(&self) -> &[T] {
        &self.buf
    }
}

impl<T> DerefMut for PoolBuf<T> {
    fn deref_mut(&mut self) -> &mut [T] {
        &mut self.buf
    }
}

impl<T: std::fmt::Debug> std::fmt::Debug for PoolBuf<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        self.buf.fmt(f)
    }
}

impl<T> Drop for PoolBuf<T> {
    fn drop(&mut self) {
        if let Some(pool) = self.pool.take() {
            pool.release(std::mem::take(&mut self.buf));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recycles_in_steady_state() {
        let pool: Arc<BufferPool<i64>> = Arc::new(BufferPool::new(1 << 20));
        let data = [1i64, 2, 3, 4];
        {
            let b = BufferPool::acquire_copy(&pool, &data);
            assert_eq!(&*b, &data[..]);
        } // drop → recycle
        let s0 = pool.stats();
        assert_eq!(s0.misses, 1);
        assert_eq!(s0.recycled, 1);
        for _ in 0..100 {
            let b = BufferPool::acquire_copy(&pool, &data);
            assert_eq!(b.len(), 4);
        }
        let s = pool.stats();
        assert_eq!(s.misses, 1, "steady state must not allocate");
        assert_eq!(s.hits, 100);
        assert!(s.hit_rate() > 0.98);
    }

    #[test]
    fn budget_bounds_retention() {
        // Budget fits exactly one 8-element i64 buffer (64 bytes).
        let pool: Arc<BufferPool<i64>> = Arc::new(BufferPool::new(64));
        let a = BufferPool::acquire_copy(&pool, &[0i64; 8]);
        let b = BufferPool::acquire_copy(&pool, &[0i64; 8]);
        drop(a);
        drop(b); // second release exceeds the budget → dropped
        assert_eq!(pool.retained(), 1);
    }

    #[test]
    fn undersized_buffer_counts_as_miss() {
        let pool: Arc<BufferPool<i64>> = Arc::new(BufferPool::new(1 << 20));
        drop(BufferPool::acquire_copy(&pool, &[1i64])); // retained with capacity 1
        let big: Vec<i64> = (0..100).collect();
        let b = BufferPool::acquire_copy(&pool, &big);
        assert_eq!(&*b, &big[..]);
        assert_eq!(pool.stats().misses, 2);
    }

    #[test]
    fn detached_never_touches_pool() {
        let b: PoolBuf<i64> = PoolBuf::detached(vec![9, 9]);
        assert_eq!(b.len(), 2);
        drop(b); // no panic, no pool
    }

    #[test]
    fn acquire_filled_recycles_like_acquire_copy() {
        let pool: Arc<BufferPool<i64>> = Arc::new(BufferPool::new(1 << 20));
        drop(BufferPool::acquire_filled(&pool, 8, 0i64)); // miss, retained
        for _ in 0..50 {
            let b = BufferPool::acquire_filled(&pool, 8, 7i64);
            assert_eq!(&*b, &[7i64; 8][..]);
        }
        let s = pool.stats();
        assert_eq!(s.misses, 1, "steady state must not allocate");
        assert_eq!(s.hits, 50);
    }

    #[test]
    fn resize_and_copy_from() {
        let pool: Arc<BufferPool<i64>> = Arc::new(BufferPool::new(1 << 20));
        let mut b = BufferPool::acquire_filled(&pool, 4, 1i64);
        b.resize(2, 0);
        assert_eq!(&*b, &[1i64, 1][..]);
        b.resize(5, 9);
        assert_eq!(&*b, &[1i64, 1, 9, 9, 9][..]);
        b.copy_from(&[3, 4]);
        assert_eq!(&*b, &[3i64, 4][..]);
    }

    #[test]
    fn discard_period_forces_deterministic_misses() {
        // Every 3rd release is dropped: with one buffer circulating, the
        // acquire right after a discarded release must miss.
        let pool: Arc<BufferPool<i64>> = Arc::new(BufferPool::with_discard_period(1 << 20, 3));
        for _ in 0..30 {
            drop(BufferPool::acquire_copy(&pool, &[1i64, 2]));
        }
        let s = pool.stats();
        assert_eq!(s.chaos_discarded, 10, "{s:?}");
        // First acquire misses (cold), then each discarded release causes
        // one more miss on the following acquire — except the final
        // release (no acquire follows it): 1 + 9.
        assert_eq!(s.misses, 1 + 9, "{s:?}");
        assert_eq!(s.hits + s.misses, 30, "{s:?}");
    }

    #[test]
    fn mutation_through_deref_mut() {
        let pool: Arc<BufferPool<i64>> = Arc::new(BufferPool::new(1 << 20));
        let mut b = BufferPool::acquire_copy(&pool, &[1i64, 2]);
        b[0] = 41;
        b[1] += 40;
        assert_eq!(&*b, &[41i64, 42][..]);
    }
}
