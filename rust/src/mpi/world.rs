//! World construction and the persistent rank executor.
//!
//! Two ways to run a per-rank program against [`RankCtx`]:
//!
//! * [`run_world`] — one-shot: spawn p scoped threads, run the closure,
//!   join. Right for single collectives and tests.
//! * [`World`] — persistent: spawn p rank threads **once** and submit any
//!   number of jobs to them. The benchmark harness sweeps hundreds of
//!   (algorithm, m) points per configuration; respawning p = 1152 OS
//!   threads per point used to dominate sweep wall-time and perturb the
//!   measured times (EXPERIMENTS.md §Perf). Rank state (the transport —
//!   thread inboxes, shm rings or a socket mesh, per
//!   [`WorldConfig::with_transport`] —
//!   buffer pools, barrier, virtual clocks) persists across jobs, so
//!   steady-state measurement points run with warm pools and no allocator
//!   or scheduler noise.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use anyhow::{anyhow, Result};

use super::chaos::{Chaos, ChaosConfig, ChaosReport};
use super::comm::{Comm, CtxAlloc};
use super::ctx::{recv_timeout, ClockMode, RankCtx};
use super::elem::Elem;
use super::pool::{BufferPool, PoolStats, DEFAULT_BUDGET_BYTES};
use super::recover::{TransportFault, TransportStats};
use super::transport::{
    build_transport, Transport, TransportBackend, TransportTuning, DEFAULT_WRITE_TIMEOUT,
};
use super::vbarrier::VBarrier;
use super::wirefault::{WireFaultConfig, WireFaultReport};
use crate::coll::ScanAlgorithm;
use crate::cost::{CostModel, CostParams};
use crate::mpi::op::OpRef;
use crate::trace::{RankTrace, TraceReport};
use crate::util::Channel;

/// Physical layout of the simulated (or emulated) machine: `nodes` compute
/// nodes with `ranks_per_node` ranks each, block placement (`node = rank /
/// ranks_per_node`) — the MPI default the paper's cluster uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Topology {
    pub nodes: usize,
    pub ranks_per_node: usize,
}

impl Topology {
    /// `nodes × ranks_per_node` cluster (e.g. `cluster(36, 32)` is the
    /// paper's large configuration).
    pub fn cluster(nodes: usize, ranks_per_node: usize) -> Self {
        assert!(nodes >= 1 && ranks_per_node >= 1);
        Topology { nodes, ranks_per_node }
    }

    /// Single-node world with `p` ranks (for host-local benchmarking).
    pub fn flat(p: usize) -> Self {
        Topology { nodes: 1, ranks_per_node: p }
    }

    pub fn size(&self) -> usize {
        self.nodes * self.ranks_per_node
    }
}

/// Configuration for one world: topology, clock mode, tracing, transport
/// tuning.
#[derive(Clone)]
pub struct WorldConfig {
    pub topology: Topology,
    pub mode: ClockMode,
    pub tracing: bool,
    /// Stack size per rank thread. The algorithms heap-allocate their
    /// buffers, so a small stack suffices even at p = 1152.
    pub stack_size: usize,
    /// Per-receive deadlock deadline for this world. `None` falls back to
    /// the process-wide `EXSCAN_RECV_TIMEOUT_MS` / 60 s default. Setting
    /// it here avoids the read-once env-var race in failure-injection
    /// tests and lets one world fail fast without shortening every other.
    pub recv_timeout: Option<Duration>,
    /// Retention budget of each rank's send-buffer pool, in bytes.
    pub pool_budget_bytes: usize,
    /// Route the fused receive-reduce primitives through the pre-fusion
    /// two-pass flow (owned copy, then reduce). Results and traces are
    /// identical by construction; only the per-round memory traffic
    /// differs. A/B reference for `tests/fused_equivalence.rs` and the
    /// hotpath m-sweep — leave `false` for real measurements.
    pub unfused_compat: bool,
    /// Route every ⊕ application through the per-element reference
    /// dispatch (`CombineOp::combine`) instead of the resolved slice
    /// kernel. Bit-identical results and traces by the `CombineOp`
    /// contract (asserted in `tests/kernel_equivalence.rs`); A/B
    /// reference for the hotpath kernel sweep — leave `false` for real
    /// measurements.
    pub per_element_ops: bool,
    /// Give every inbox the fixed (pre-adaptive) 100-probe spin budget
    /// instead of the per-slot EMA-driven adaptive budget. A/B reference
    /// for the hotpath latency sweep — leave `false` for real
    /// measurements.
    pub fixed_spin: bool,
    /// Seeded deterministic fault injection (message embargo/diversion,
    /// scheduler yields, pool pressure, targeted drops). `None` for real
    /// measurements; see [`ChaosConfig`] and EXPERIMENTS.md §Chaos.
    pub chaos: Option<ChaosConfig>,
    /// Which rendezvous backend this world's ranks communicate through
    /// (EXPERIMENTS.md §Transport). `Thread` — the in-process slot inbox
    /// — is the default and the differential oracle; `Shm`/`Tcp`/`Uds`
    /// move every message through a shared-memory ring or a socket mesh
    /// and are host-capability gated (probe with
    /// [`TransportBackend::probe`]).
    pub backend: TransportBackend,
    /// Watchdog on socket-stream writes: a peer that stops reading for
    /// this long is a typed `write-timeout` fault rather than a wedged
    /// send thread. Ignored by the thread and shm backends.
    pub write_timeout: Duration,
    /// Seeded wire-level fault injection *below* the chaos boundary
    /// (frame bit flips, checksum smashes, truncation, duplication,
    /// stream resets) for the wire backends. `None` — the default — for
    /// real measurements; see `mpi/wirefault.rs` and EXPERIMENTS.md
    /// §Robustness. Ignored by the thread backend (no frames).
    pub wirefault: Option<WireFaultConfig>,
}

impl WorldConfig {
    /// Real-clock world over the given topology.
    pub fn new(topology: Topology) -> Self {
        WorldConfig {
            topology,
            mode: ClockMode::Real,
            tracing: false,
            stack_size: 512 * 1024,
            recv_timeout: None,
            pool_budget_bytes: DEFAULT_BUDGET_BYTES,
            unfused_compat: false,
            per_element_ops: false,
            fixed_spin: false,
            chaos: None,
            backend: TransportBackend::Thread,
            write_timeout: DEFAULT_WRITE_TIMEOUT,
            wirefault: None,
        }
    }

    /// Switch to the simulated-cluster virtual clock with these parameters.
    pub fn virtual_clock(mut self, params: CostParams) -> Self {
        let model = CostModel::new(params, self.topology.ranks_per_node);
        self.mode = ClockMode::Virtual(Arc::new(model));
        self
    }

    /// Switch to the virtual clock priced off a [`crate::topo::Topo`]
    /// per-link matrix. The topology must cover exactly this world's
    /// ranks (accounting uses world ranks, so the matrix also applies
    /// inside sub-communicators).
    pub fn virtual_clock_topo(mut self, topo: Arc<crate::topo::Topo>) -> Self {
        assert_eq!(
            topo.size(),
            self.topology.size(),
            "topology matrix must cover the world"
        );
        self.mode = ClockMode::Virtual(Arc::new(CostModel::with_topo(topo)));
        self
    }

    /// Enable per-rank event tracing.
    pub fn with_trace(mut self, tracing: bool) -> Self {
        self.tracing = tracing;
        self
    }

    /// Set the per-receive deadlock deadline for this world only.
    pub fn with_recv_timeout(mut self, timeout: Duration) -> Self {
        self.recv_timeout = Some(timeout);
        self
    }

    /// Run this world's receive-reduce primitives through the pre-fusion
    /// two-pass flow (A/B reference; see the field docs).
    pub fn with_unfused_compat(mut self, unfused: bool) -> Self {
        self.unfused_compat = unfused;
        self
    }

    /// Route this world's ⊕ applications through the per-element
    /// reference dispatch (A/B reference; see the field docs).
    pub fn with_per_element_ops(mut self, per_element: bool) -> Self {
        self.per_element_ops = per_element;
        self
    }

    /// Use the fixed (pre-adaptive) spin budget in this world's inboxes
    /// (A/B reference; see the field docs).
    pub fn with_fixed_spin(mut self, fixed: bool) -> Self {
        self.fixed_spin = fixed;
        self
    }

    /// Enable deterministic chaos injection for this world.
    pub fn with_chaos(mut self, chaos: ChaosConfig) -> Self {
        self.chaos = Some(chaos);
        self
    }

    /// Select the rendezvous transport backend for this world (see the
    /// field docs; default [`TransportBackend::Thread`]).
    pub fn with_transport(mut self, backend: TransportBackend) -> Self {
        self.backend = backend;
        self
    }

    /// Set the socket-stream write watchdog for this world (see the
    /// field docs; default [`DEFAULT_WRITE_TIMEOUT`]).
    pub fn with_write_timeout(mut self, timeout: Duration) -> Self {
        self.write_timeout = timeout;
        self
    }

    /// Arm seeded wire-level fault injection on this world's wire
    /// backend (see the field docs; no-op on the thread backend).
    pub fn with_wire_faults(mut self, cfg: WireFaultConfig) -> Self {
        self.wirefault = Some(cfg);
        self
    }

    /// Construct this world's transport, or fail attributed (backend
    /// name + host-side reason) when the backend is unavailable here.
    fn build_transport<T: Elem>(&self, p: usize) -> Result<Arc<dyn Transport<T>>> {
        let tuning = TransportTuning {
            fixed_spin: self.fixed_spin,
            write_timeout: self.write_timeout,
            wirefault: self.wirefault.clone(),
        };
        build_transport::<T>(self.backend, p, &tuning)
    }

    fn build_chaos(&self) -> Option<Arc<Chaos>> {
        self.chaos.as_ref().map(|c| Arc::new(Chaos::new(c.clone())))
    }

    fn build_pool<T>(&self) -> Arc<BufferPool<T>> {
        let discard = self.chaos.as_ref().map(|c| c.pool_discard_period).unwrap_or(0);
        Arc::new(BufferPool::with_discard_period(self.pool_budget_bytes, discard))
    }

    pub fn size(&self) -> usize {
        self.topology.size()
    }

    fn recv_deadline(&self) -> Duration {
        self.recv_timeout.unwrap_or_else(recv_timeout)
    }
}

/// Output of [`run_scan`]: per-rank result vectors, per-rank times and
/// (if tracing) the merged trace.
#[derive(Debug)]
pub struct RunResult<T> {
    pub outputs: Vec<Vec<T>>,
    /// Per-rank completion time in µs: virtual clock (virtual mode) or
    /// wall time between the pre-barrier and local completion (real mode).
    pub times_us: Vec<f64>,
    pub trace: Option<TraceReport>,
}

impl<T> RunResult<T> {
    /// The paper's per-run statistic: time of the slowest rank.
    pub fn completion_us(&self) -> f64 {
        self.times_us.iter().copied().fold(0.0, f64::max)
    }
}

/// Cumulative count of rank threads ever spawned by this process (both
/// [`run_world`] and [`World::new`]). Lets tests assert that a sweep
/// spawns its threads exactly once.
static RANK_THREADS_SPAWNED: AtomicUsize = AtomicUsize::new(0);

/// Total rank threads spawned by this process so far (test hook).
pub fn rank_threads_spawned() -> usize {
    RANK_THREADS_SPAWNED.load(Ordering::SeqCst)
}

/// Shared registry of ranks killed by chaos rank-death in one world.
///
/// The dying rank registers itself here (from inside its job — the worker
/// thread itself must stay alive to keep counting the completion latch
/// down), and survivors consult it from their blocking-receive loops to
/// convert a poison wake-up into an *attributed* failure ("rank N died")
/// instead of an anonymous deadline expiry. `any()` is the hot-path
/// check: a single relaxed load that stays zero for chaos-free worlds.
#[derive(Debug, Default)]
pub(crate) struct DeadRanks {
    count: AtomicUsize,
    set: Mutex<Vec<usize>>,
}

impl DeadRanks {
    /// Register `rank` as dead; returns true the first time only (the
    /// caller bumps the chaos report exactly once per rank).
    pub(crate) fn mark_dead(&self, rank: usize) -> bool {
        let mut set = lock_recover(&self.set);
        if set.contains(&rank) {
            return false;
        }
        set.push(rank);
        set.sort_unstable();
        self.count.fetch_add(1, Ordering::Release);
        true
    }

    /// Fast check: has any rank died in this world?
    pub(crate) fn any(&self) -> bool {
        self.count.load(Ordering::Acquire) > 0
    }

    /// Sorted list of dead ranks.
    pub(crate) fn list(&self) -> Vec<usize> {
        lock_recover(&self.set).clone()
    }
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    payload
        .downcast_ref::<&str>()
        .map(|s| s.to_string())
        .or_else(|| payload.downcast_ref::<String>().cloned())
        .unwrap_or_else(|| "rank thread panicked".into())
}

/// Spawn `p` rank threads and run `f` on each; returns the per-rank results
/// in rank order. The closure gets a fully wired [`RankCtx`]. One-shot:
/// threads are joined before returning. Benchmark sweeps should use the
/// persistent [`World`] executor instead.
pub fn run_world<T, R, F>(cfg: &WorldConfig, f: F) -> Result<Vec<R>>
where
    T: Elem,
    R: Send,
    F: Fn(&mut RankCtx<T>) -> Result<R> + Send + Sync,
{
    let p = cfg.size();
    assert!(p >= 1);
    let transport: Arc<dyn Transport<T>> = cfg.build_transport(p)?;
    let pools: Vec<Arc<BufferPool<T>>> = (0..p).map(|_| cfg.build_pool()).collect();
    let barrier = Arc::new(VBarrier::new(p));
    let recv_deadline = cfg.recv_deadline();
    let chaos = cfg.build_chaos();
    let dead = Arc::new(DeadRanks::default());

    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(p);
        let fref = &f;
        for rank in 0..p {
            let transport = Arc::clone(&transport);
            let pool = Arc::clone(&pools[rank]);
            let barrier = Arc::clone(&barrier);
            let mode = cfg.mode.clone();
            let tracing = cfg.tracing;
            let unfused = cfg.unfused_compat;
            let per_element = cfg.per_element_ops;
            let chaos = chaos.clone();
            let dead = Arc::clone(&dead);
            let builder = std::thread::Builder::new()
                .name(format!("rank-{rank}"))
                .stack_size(cfg.stack_size);
            let handle = builder
                .spawn_scoped(scope, move || {
                    RANK_THREADS_SPAWNED.fetch_add(1, Ordering::SeqCst);
                    let mut ctx = RankCtx::new(
                        rank,
                        p,
                        transport,
                        pool,
                        barrier,
                        mode,
                        tracing,
                        unfused,
                        per_element,
                        recv_deadline,
                        chaos,
                        dead,
                    );
                    fref(&mut ctx)
                })
                .expect("failed to spawn rank thread");
            handles.push(handle);
        }
        let mut out = Vec::with_capacity(p);
        let mut first_err = None;
        for h in handles {
            match h.join() {
                Ok(Ok(r)) => out.push(Some(r)),
                Ok(Err(e)) => {
                    first_err.get_or_insert(e);
                    out.push(None);
                }
                Err(panic) => {
                    let msg = panic_message(&*panic);
                    first_err.get_or_insert(anyhow::anyhow!("rank panicked: {msg}"));
                    out.push(None);
                }
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(out.into_iter().map(|r| r.unwrap()).collect()),
        }
    })
}

/// Poison-tolerant lock: the executor's bookkeeping mutexes hold plain
/// data that stays consistent even if a holder unwound mid-assignment, and
/// propagating poison would either hang `Latch::wait` (a worker dying
/// before `count_down`) or kill workers for good — so recover instead.
fn lock_recover<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

/// A simple countdown latch: [`World::run`] blocks on it until every rank
/// worker has finished (and fully dropped) its submitted job.
struct Latch {
    remaining: Mutex<usize>,
    cv: Condvar,
}

impl Latch {
    fn new(n: usize) -> Self {
        Latch { remaining: Mutex::new(n), cv: Condvar::new() }
    }

    fn count_down(&self) {
        let mut r = lock_recover(&self.remaining);
        *r -= 1;
        if *r == 0 {
            self.cv.notify_all();
        }
    }

    fn wait(&self) {
        let mut r = lock_recover(&self.remaining);
        while *r > 0 {
            r = self.cv.wait(r).unwrap_or_else(|p| p.into_inner());
        }
    }
}

/// A job submitted to one rank worker: the erased closure plus the latch
/// the worker pings *after the closure (and everything it captured) has
/// been dropped* — that ordering is what makes the lifetime erasure in
/// [`World::run`] sound.
type Job<T> = (Box<dyn FnOnce(&mut RankCtx<T>) + Send + 'static>, Arc<Latch>);

/// The persistent world executor: p rank threads spawned once, accepting
/// submitted per-rank jobs until dropped.
///
/// Transport state (inboxes, pools), the barrier and each rank's virtual
/// clock persist across jobs; callers that measure reset clocks per
/// repetition exactly as before. Jobs run in submission order on every
/// rank. After a job fails on some rank (e.g. a receive deadline), stale
/// unmatched messages may remain buffered; treat the world as tainted and
/// build a fresh one — exactly the discipline the old spawn-per-call API
/// enforced by construction.
pub struct World<T: Elem> {
    cfg: WorldConfig,
    jobs: Vec<Arc<Channel<Job<T>>>>,
    pools: Vec<Arc<BufferPool<T>>>,
    chaos: Option<Arc<Chaos>>,
    dead: Arc<DeadRanks>,
    /// Kept for the wire-level observability accessors
    /// ([`wire_stats`](Self::wire_stats) and friends); rank contexts hold
    /// their own clones.
    transport: Arc<dyn Transport<T>>,
    handles: Vec<std::thread::JoinHandle<()>>,
    /// Serializes whole `run` calls: jobs from two overlapping runs would
    /// interleave differently per rank and desynchronize the barrier.
    run_lock: Mutex<()>,
    /// Context-id allocator for communicators created over this world
    /// ([`dup_comm`](Self::dup_comm)/[`split_comm`](Self::split_comm)).
    ctxs: CtxAlloc,
}

impl<T: Elem> World<T> {
    /// Spawn the rank threads for this configuration (exactly once).
    /// Panics (attributed) when the configured transport backend is
    /// unavailable on this host — probe with
    /// [`TransportBackend::probe`] or use [`try_new`](Self::try_new)
    /// where construction failure must be recoverable.
    pub fn new(cfg: WorldConfig) -> Self {
        let backend = cfg.backend;
        Self::try_new(cfg).unwrap_or_else(|e| {
            panic!("world construction failed on transport '{backend}': {e:#}")
        })
    }

    /// Fallible construction: `Err` (instead of a panic) when the
    /// configured transport backend cannot be built on this host.
    pub fn try_new(cfg: WorldConfig) -> Result<Self> {
        let p = cfg.size();
        assert!(p >= 1);
        let transport: Arc<dyn Transport<T>> = cfg.build_transport(p)?;
        let pools: Vec<Arc<BufferPool<T>>> = (0..p).map(|_| cfg.build_pool()).collect();
        let barrier = Arc::new(VBarrier::new(p));
        let recv_deadline = cfg.recv_deadline();
        let chaos = cfg.build_chaos();
        let dead = Arc::new(DeadRanks::default());

        let mut jobs: Vec<Arc<Channel<Job<T>>>> = Vec::with_capacity(p);
        let mut handles = Vec::with_capacity(p);
        for rank in 0..p {
            let ch: Arc<Channel<Job<T>>> = Arc::new(Channel::new());
            let rx = Arc::clone(&ch);
            let transport = Arc::clone(&transport);
            let pool = Arc::clone(&pools[rank]);
            let barrier = Arc::clone(&barrier);
            let mode = cfg.mode.clone();
            let tracing = cfg.tracing;
            let unfused = cfg.unfused_compat;
            let per_element = cfg.per_element_ops;
            let rank_chaos = chaos.clone();
            let rank_dead = Arc::clone(&dead);
            let stack = cfg.stack_size;
            let handle = std::thread::Builder::new()
                .name(format!("rank-{rank}"))
                .stack_size(stack)
                .spawn(move || {
                    RANK_THREADS_SPAWNED.fetch_add(1, Ordering::SeqCst);
                    let mut ctx = RankCtx::new(
                        rank,
                        p,
                        transport,
                        pool,
                        barrier,
                        mode,
                        tracing,
                        unfused,
                        per_element,
                        recv_deadline,
                        rank_chaos,
                        rank_dead,
                    );
                    while let Some((job, done)) = rx.pop_wait() {
                        job(&mut ctx);
                        // `job` (the box and every capture) is dropped by
                        // the end of the statement above — only then may
                        // the latch release `World::run`.
                        ctx.rearm_trace();
                        done.count_down();
                    }
                })
                .expect("failed to spawn rank thread");
            jobs.push(ch);
            handles.push(handle);
        }
        Ok(World {
            cfg,
            jobs,
            pools,
            chaos,
            dead,
            transport,
            handles,
            run_lock: Mutex::new(()),
            ctxs: CtxAlloc::new(),
        })
    }

    /// The implicit world communicator (context 0, all ranks). Collectives
    /// run *outside* any [`RankCtx::with_comm`] scope already use it.
    pub fn comm_world(&self) -> Comm {
        Comm::world(self.size())
    }

    /// `MPI_Comm_dup`: same members as `parent`, fresh context id —
    /// collectives on the two are match-isolated and may be in flight on
    /// this world simultaneously.
    pub fn dup_comm(&self, parent: &Comm) -> Comm {
        parent.dup(&self.ctxs)
    }

    /// `MPI_Comm_split`: partition `parent` by color (one entry per
    /// member, in communicator-rank order); returns one communicator per
    /// distinct color, each with a fresh context id.
    pub fn split_comm(&self, parent: &Comm, colors: &[usize]) -> Vec<Comm> {
        parent.split(&self.ctxs, colors)
    }

    pub fn config(&self) -> &WorldConfig {
        &self.cfg
    }

    pub fn size(&self) -> usize {
        self.cfg.size()
    }

    /// Aggregated send-pool counters over all ranks (the transport's
    /// zero-allocation evidence; see `tests/transport.rs`).
    pub fn pool_stats(&self) -> PoolStats {
        let mut total = PoolStats::default();
        for p in &self.pools {
            total.merge(&p.stats());
        }
        total
    }

    /// What the chaos layer has injected so far (None for non-chaos
    /// worlds). The report's `schedule_digest` is the replay check: two
    /// worlds at the same seed running the same jobs report equal digests.
    pub fn chaos_report(&self) -> Option<ChaosReport> {
        self.chaos.as_ref().map(|c| c.report())
    }

    /// Sorted list of ranks killed by chaos rank-death in this world
    /// (empty for healthy worlds). This is the engine's *structural*
    /// failure-attribution source — no error-string parsing. A non-empty
    /// list means the world is permanently degraded: rebuild it.
    pub fn dead_ranks(&self) -> Vec<usize> {
        self.dead.list()
    }

    /// Wire-level recovery/fault counters (retransmits, reconnects,
    /// suppressed duplicates, fatal faults). All-zero on the thread
    /// backend and on clean wire runs.
    pub fn wire_stats(&self) -> TransportStats {
        self.transport.wire_stats()
    }

    /// First typed transport fault recorded on this world's wire
    /// backend, if any (`None` on the thread backend and healthy runs).
    pub fn transport_fault(&self) -> Option<TransportFault> {
        self.transport.fault()
    }

    /// Injection report of the armed wire-fault plan (`None` unless
    /// [`WorldConfig::with_wire_faults`] armed one). The report's
    /// `digest` is the replay check: two worlds at the same seed running
    /// the same jobs report equal digests.
    pub fn wire_report(&self) -> Option<WireFaultReport> {
        self.transport.wire_report()
    }

    /// Run `f` once on every rank and collect results in rank order.
    ///
    /// `f` and `R` may borrow from the caller's stack (inputs, algorithm
    /// references): this call does not return until every rank worker has
    /// finished *and dropped* its job, so no borrow escapes — the same
    /// guarantee `std::thread::scope` gives, provided here by the
    /// completion latch.
    pub fn run<R, F>(&self, f: F) -> Result<Vec<R>>
    where
        R: Send,
        F: Fn(&mut RankCtx<T>) -> Result<R> + Send + Sync,
    {
        let p = self.size();
        let _serial = lock_recover(&self.run_lock);
        let f = Arc::new(f);
        let results: Arc<Mutex<Vec<Option<Result<R>>>>> =
            Arc::new(Mutex::new((0..p).map(|_| None).collect()));
        let latch = Arc::new(Latch::new(p));

        // Phase 1 — build every job. This phase may allocate (and thus in
        // principle unwind) freely: nothing has been submitted yet, so an
        // unwind here leaks no borrow to a worker.
        let mut built: Vec<Job<T>> = Vec::with_capacity(p);
        for _rank in 0..p {
            let f = Arc::clone(&f);
            let results = Arc::clone(&results);
            let job: Box<dyn FnOnce(&mut RankCtx<T>) + Send + '_> = Box::new(move |ctx| {
                let out = match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    (*f)(ctx)
                })) {
                    Ok(r) => r,
                    Err(payload) => {
                        Err(anyhow!("rank panicked: {}", panic_message(&*payload)))
                    }
                };
                // Poison-recovering: this write must never unwind, or the
                // worker would die before counting the latch down.
                lock_recover(&results)[ctx.rank()] = Some(out);
            });
            // SAFETY: lifetime erasure only. The job runs on a worker that
            // outlives `self`; the borrows inside `f`/`R` stay valid
            // because this function blocks on `latch` until every worker
            // has executed *and dropped* its job (the worker counts the
            // latch down strictly after the job box and its captured Arcs
            // are gone), and `run` holds its own `f`/`results` Arcs until
            // after that wait — so the last drop of any capture happens
            // on this stack frame, before the borrowed data can die.
            // Phase 2 below is unwind-free between the first push and the
            // wait: every operation in it recovers mutex poison instead of
            // panicking, so `latch.wait()` is always reached once any job
            // has been submitted.
            let job: Box<dyn FnOnce(&mut RankCtx<T>) + Send + 'static> =
                unsafe { std::mem::transmute(job) };
            built.push((job, Arc::clone(&latch)));
        }

        // Phase 2 — submit and wait (panic-free, see SAFETY above).
        for (rank, job) in built.into_iter().enumerate() {
            if self.jobs[rank].push(job).is_err() {
                // Worker already shut down (world is being dropped?).
                lock_recover(&results)[rank] =
                    Some(Err(anyhow!("rank {rank} executor has shut down")));
                latch.count_down();
            }
        }
        latch.wait();

        let mut first_err = None;
        let mut out = Vec::with_capacity(p);
        for (rank, slot) in lock_recover(&results).drain(..).enumerate() {
            match slot {
                Some(Ok(v)) => out.push(Some(v)),
                Some(Err(e)) => {
                    first_err.get_or_insert(e);
                    out.push(None);
                }
                None => {
                    first_err.get_or_insert(anyhow!("rank {rank} produced no result"));
                    out.push(None);
                }
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(out.into_iter().map(|r| r.unwrap()).collect()),
        }
    }
}

impl<T: Elem> Drop for World<T> {
    fn drop(&mut self) {
        for ch in &self.jobs {
            ch.close();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Run one scan collective over per-rank `inputs` and collect outputs,
/// per-rank times and the optional trace. This is the one-shot convenience
/// wrapper; the benchmark harness drives repetitions through a persistent
/// [`World`] so threads are spawned only once per sweep.
pub fn run_scan<T: Elem>(
    cfg: &WorldConfig,
    algo: &dyn ScanAlgorithm<T>,
    op: &OpRef<T>,
    inputs: &[Vec<T>],
) -> Result<RunResult<T>> {
    let p = cfg.size();
    assert_eq!(inputs.len(), p, "need one input vector per rank");
    let m = inputs.first().map(|v| v.len()).unwrap_or(0);
    assert!(inputs.iter().all(|v| v.len() == m), "all ranks must contribute m elements");

    let overhead = match &cfg.mode {
        ClockMode::Virtual(model) => model.params.overhead,
        ClockMode::Real => 0.0,
    };

    let per_rank = run_world::<T, (Vec<T>, f64, Option<RankTrace>), _>(cfg, |ctx| {
        // Borrow, don't clone: inputs outlive the scoped rank threads.
        let input = &inputs[ctx.rank()];
        let mut output = vec![T::filler(); m];
        ctx.barrier();
        let start = std::time::Instant::now();
        algo.run(ctx, input, &mut output, op)?;
        let elapsed_us = start.elapsed().as_secs_f64() * 1e6;
        let time = if ctx.is_virtual() { ctx.vclock() + overhead } else { elapsed_us };
        Ok((output, time, ctx.take_trace()))
    })?;

    let mut outputs = Vec::with_capacity(p);
    let mut times = Vec::with_capacity(p);
    let mut traces = Vec::with_capacity(p);
    for (o, t, tr) in per_rank {
        outputs.push(o);
        times.push(t);
        if let Some(tr) = tr {
            traces.push(tr);
        }
    }
    let trace = (!traces.is_empty()).then(|| TraceReport::new(traces));
    Ok(RunResult { outputs, times_us: times, trace })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpi::ops;

    #[test]
    fn topology_sizes() {
        assert_eq!(Topology::cluster(36, 32).size(), 1152);
        assert_eq!(Topology::flat(7).size(), 7);
    }

    #[test]
    fn run_world_collects_in_rank_order() {
        let cfg = WorldConfig::new(Topology::flat(9));
        let out = run_world::<i64, usize, _>(&cfg, |ctx| Ok(ctx.rank() * 10)).unwrap();
        assert_eq!(out, (0..9).map(|r| r * 10).collect::<Vec<_>>());
    }

    #[test]
    fn ring_exchange_all_ranks() {
        // Each rank sends its rank to the right neighbour, receives from
        // the left: classic ring, exercises sendrecv + matching.
        let cfg = WorldConfig::new(Topology::flat(16));
        let out = run_world::<i64, i64, _>(&cfg, |ctx| {
            let p = ctx.size();
            let r = ctx.rank();
            let sbuf = [r as i64];
            let mut rbuf = [0i64];
            ctx.sendrecv(0, (r + 1) % p, &sbuf, (r + p - 1) % p, &mut rbuf)?;
            Ok(rbuf[0])
        })
        .unwrap();
        assert_eq!(out, (0..16).map(|r| ((r + 16 - 1) % 16) as i64).collect::<Vec<_>>());
    }

    #[test]
    fn error_propagates() {
        let cfg = WorldConfig::new(Topology::flat(4));
        let res = run_world::<i64, (), _>(&cfg, |ctx| {
            if ctx.rank() == 2 {
                anyhow::bail!("boom");
            }
            Ok(())
        });
        assert!(res.is_err());
    }

    #[test]
    fn out_of_range_send_errors() {
        let cfg = WorldConfig::new(Topology::flat(2));
        let res = run_world::<i64, (), _>(&cfg, |ctx| {
            if ctx.rank() == 0 {
                ctx.send(0, 5, &[1i64])?;
            }
            Ok(())
        });
        assert!(res.is_err());
    }

    #[test]
    fn virtual_clock_ring() {
        // p=4, one round of ring sendrecv, flat inter-node α=2, β=0:
        // every rank's clock ends at exactly 2.
        let params = CostParams {
            alpha_intra: 1.0,
            alpha_inter: 2.0,
            beta_intra: 0.0,
            beta_inter: 0.0,
            gamma: 0.0,
            overhead: 0.0,
        };
        let cfg = WorldConfig::new(Topology::cluster(4, 1)).virtual_clock(params);
        let clocks = run_world::<i64, f64, _>(&cfg, |ctx| {
            let p = ctx.size();
            let r = ctx.rank();
            let sbuf = [0i64];
            let mut rbuf = [0i64];
            ctx.sendrecv(0, (r + 1) % p, &sbuf, (r + p - 1) % p, &mut rbuf)?;
            Ok(ctx.vclock())
        })
        .unwrap();
        assert_eq!(clocks, vec![2.0; 4]);
    }

    #[test]
    fn run_scan_shape_checks() {
        use crate::coll::Exscan123;
        let cfg = WorldConfig::new(Topology::flat(4));
        // Inputs r+1 so no exclusive prefix collides with the filler value
        // (0): rank 0's output must remain exactly the untouched filler,
        // per MPI_Exscan semantics (output on rank 0 is undefined and the
        // implementation must not write it).
        let inputs: Vec<Vec<i64>> = (0..4).map(|r| vec![r as i64 + 1; 3]).collect();
        let res = run_scan(&cfg, &Exscan123, &ops::bxor(), &inputs).unwrap();
        assert_eq!(res.outputs.len(), 4);
        assert_eq!(res.outputs[0], vec![0, 0, 0], "rank 0 output must stay filler");
        assert_eq!(res.outputs[1], vec![1, 1, 1]); // V_1 = [1,1,1]
        assert_eq!(res.outputs[2], vec![3, 3, 3]); // 1 ^ 2
        assert_eq!(res.outputs[3], vec![0, 0, 0]); // 1 ^ 2 ^ 3
        assert_eq!(res.times_us.len(), 4);
    }

    #[test]
    fn executor_reuses_the_same_threads_across_jobs() {
        // Thread-identity check (parallel-test safe, unlike the global
        // spawn counter — that one is asserted in the isolated
        // tests/executor_spawn.rs binary): every job must observe the
        // exact same OS thread per rank.
        let world: World<i64> = World::new(WorldConfig::new(Topology::flat(6)));
        let ids_of = |round: u32| {
            world
                .run(move |ctx| {
                    let _ = round;
                    Ok((ctx.rank(), std::thread::current().id()))
                })
                .unwrap()
        };
        let first = ids_of(0);
        for round in 1..5u32 {
            assert_eq!(ids_of(round), first, "job {round} ran on different threads");
        }
    }

    #[test]
    fn executor_jobs_may_borrow_caller_state() {
        // The lifetime-erased path: the job closure borrows a stack local.
        let world: World<i64> = World::new(WorldConfig::new(Topology::flat(8)));
        let weights: Vec<i64> = (0..8).map(|r| (r as i64) * 100).collect();
        let out = world
            .run(|ctx| {
                let p = ctx.size();
                let r = ctx.rank();
                let sbuf = [weights[r]];
                let mut rbuf = [0i64];
                ctx.sendrecv(0, (r + 1) % p, &sbuf, (r + p - 1) % p, &mut rbuf)?;
                Ok(rbuf[0])
            })
            .unwrap();
        assert_eq!(out, (0..8).map(|r| ((r + 7) % 8) as i64 * 100).collect::<Vec<_>>());
    }

    #[test]
    fn executor_propagates_panics_as_errors() {
        let world: World<i64> = World::new(WorldConfig::new(Topology::flat(3)));
        let res = world.run(|ctx| {
            if ctx.rank() == 1 {
                panic!("injected executor failure");
            }
            Ok(())
        });
        let err = format!("{:#}", res.unwrap_err());
        assert!(err.contains("injected executor failure"), "{err}");
        // The world survives a panicked job: workers caught the unwind.
        let ok = world.run(|ctx| Ok(ctx.rank())).unwrap();
        assert_eq!(ok, vec![0, 1, 2]);
    }

    #[test]
    fn rank_death_attributes_and_registers() {
        // Kill rank 2 at its very first chaos point (tick 1, the ring
        // send). Its own job must fail with the rank-death message; any
        // survivor blocked on it must be poisoned awake and attribute the
        // death instead of waiting out the receive deadline.
        let chaos = ChaosConfig::new(9)
            .with_delay_prob(0.0)
            .with_divert_prob(0.0)
            .with_yield_prob(0.0)
            .with_rank_death(2, 1);
        let cfg = WorldConfig::new(Topology::flat(4))
            .with_chaos(chaos)
            .with_recv_timeout(Duration::from_secs(10));
        let world: World<i64> = World::new(cfg);
        let t0 = std::time::Instant::now();
        let res = world.run(|ctx| {
            let p = ctx.size();
            let r = ctx.rank();
            let sbuf = [r as i64];
            let mut rbuf = [0i64];
            ctx.sendrecv(0, (r + 1) % p, &sbuf, (r + p - 1) % p, &mut rbuf)?;
            Ok(rbuf[0])
        });
        let err = format!("{:#}", res.unwrap_err());
        assert!(err.contains("rank-death"), "{err}");
        assert!(
            t0.elapsed() < Duration::from_secs(8),
            "survivors must not wait out the full receive deadline"
        );
        assert_eq!(world.dead_ranks(), vec![2]);
        assert_eq!(world.chaos_report().unwrap().rank_deaths, 1);
    }

    #[test]
    fn executor_scan_with_warm_pools() {
        use crate::coll::Exscan123;
        let world: World<i64> = World::new(WorldConfig::new(Topology::flat(8)));
        let inputs: Vec<Vec<i64>> = (0..8).map(|r| vec![r as i64; 4]).collect();
        let op = ops::bxor();
        for _ in 0..3 {
            let outputs = world
                .run(|ctx| {
                    let mut output = vec![0i64; 4];
                    ctx.barrier();
                    Exscan123.run(ctx, &inputs[ctx.rank()], &mut output, &op)?;
                    Ok(output)
                })
                .unwrap();
            assert_eq!(outputs[3], vec![0 ^ 1 ^ 2; 4]);
        }
        let stats = world.pool_stats();
        assert!(stats.hits > 0, "pools must recycle across jobs: {stats:?}");
    }
}
