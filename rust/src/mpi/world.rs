//! World construction: spawn p rank threads over a topology and run a
//! per-rank program against [`RankCtx`].

use std::sync::Arc;

use anyhow::Result;

use super::ctx::{ClockMode, RankCtx};
use super::elem::Elem;
use super::msg::Msg;
use super::op::OpRef;
use super::vbarrier::VBarrier;
use crate::coll::ScanAlgorithm;
use crate::cost::{CostModel, CostParams};
use crate::trace::{RankTrace, TraceReport};
use crate::util::Channel;

/// Physical layout of the simulated (or emulated) machine: `nodes` compute
/// nodes with `ranks_per_node` ranks each, block placement (`node = rank /
/// ranks_per_node`) — the MPI default the paper's cluster uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Topology {
    pub nodes: usize,
    pub ranks_per_node: usize,
}

impl Topology {
    /// `nodes × ranks_per_node` cluster (e.g. `cluster(36, 32)` is the
    /// paper's large configuration).
    pub fn cluster(nodes: usize, ranks_per_node: usize) -> Self {
        assert!(nodes >= 1 && ranks_per_node >= 1);
        Topology { nodes, ranks_per_node }
    }

    /// Single-node world with `p` ranks (for host-local benchmarking).
    pub fn flat(p: usize) -> Self {
        Topology { nodes: 1, ranks_per_node: p }
    }

    pub fn size(&self) -> usize {
        self.nodes * self.ranks_per_node
    }
}

/// Configuration for one world: topology, clock mode, tracing.
#[derive(Clone)]
pub struct WorldConfig {
    pub topology: Topology,
    pub mode: ClockMode,
    pub tracing: bool,
    /// Stack size per rank thread. The algorithms heap-allocate their
    /// buffers, so a small stack suffices even at p = 1152.
    pub stack_size: usize,
}

impl WorldConfig {
    /// Real-clock world over the given topology.
    pub fn new(topology: Topology) -> Self {
        WorldConfig { topology, mode: ClockMode::Real, tracing: false, stack_size: 512 * 1024 }
    }

    /// Switch to the simulated-cluster virtual clock with these parameters.
    pub fn virtual_clock(mut self, params: CostParams) -> Self {
        let model = CostModel::new(params, self.topology.ranks_per_node);
        self.mode = ClockMode::Virtual(Arc::new(model));
        self
    }

    /// Enable per-rank event tracing.
    pub fn with_trace(mut self, tracing: bool) -> Self {
        self.tracing = tracing;
        self
    }

    pub fn size(&self) -> usize {
        self.topology.size()
    }
}

/// Output of [`run_scan`]: per-rank result vectors, per-rank times and
/// (if tracing) the merged trace.
#[derive(Debug)]
pub struct RunResult<T> {
    pub outputs: Vec<Vec<T>>,
    /// Per-rank completion time in µs: virtual clock (virtual mode) or
    /// wall time between the pre-barrier and local completion (real mode).
    pub times_us: Vec<f64>,
    pub trace: Option<TraceReport>,
}

impl<T> RunResult<T> {
    /// The paper's per-run statistic: time of the slowest rank.
    pub fn completion_us(&self) -> f64 {
        self.times_us.iter().copied().fold(0.0, f64::max)
    }
}

/// Spawn `p` rank threads and run `f` on each; returns the per-rank results
/// in rank order. The closure gets a fully wired [`RankCtx`].
pub fn run_world<T, R, F>(cfg: &WorldConfig, f: F) -> Result<Vec<R>>
where
    T: Elem,
    R: Send + 'static,
    F: Fn(&mut RankCtx<T>) -> Result<R> + Send + Sync,
{
    let p = cfg.size();
    assert!(p >= 1);
    let mailboxes: Arc<Vec<Channel<Msg<T>>>> =
        Arc::new((0..p).map(|_| Channel::new()).collect());
    let barrier = Arc::new(VBarrier::new(p));

    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(p);
        let fref = &f;
        for rank in 0..p {
            let mailboxes = Arc::clone(&mailboxes);
            let barrier = Arc::clone(&barrier);
            let mode = cfg.mode.clone();
            let tracing = cfg.tracing;
            let builder = std::thread::Builder::new()
                .name(format!("rank-{rank}"))
                .stack_size(cfg.stack_size);
            let handle = builder
                .spawn_scoped(scope, move || {
                    let mut ctx = RankCtx::new(rank, p, mailboxes, barrier, mode, tracing);
                    fref(&mut ctx)
                })
                .expect("failed to spawn rank thread");
            handles.push(handle);
        }
        let mut out = Vec::with_capacity(p);
        let mut first_err = None;
        for h in handles {
            match h.join() {
                Ok(Ok(r)) => out.push(Some(r)),
                Ok(Err(e)) => {
                    first_err.get_or_insert(e);
                    out.push(None);
                }
                Err(panic) => {
                    let msg = panic
                        .downcast_ref::<&str>()
                        .map(|s| s.to_string())
                        .or_else(|| panic.downcast_ref::<String>().cloned())
                        .unwrap_or_else(|| "rank thread panicked".into());
                    first_err.get_or_insert(anyhow::anyhow!("rank panicked: {msg}"));
                    out.push(None);
                }
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(out.into_iter().map(|r| r.unwrap()).collect()),
        }
    })
}

/// Run one scan collective over per-rank `inputs` and collect outputs,
/// per-rank times and the optional trace. This is the one-shot convenience
/// wrapper; the benchmark harness drives repetitions through [`run_world`]
/// directly so threads are spawned only once.
pub fn run_scan<T: Elem>(
    cfg: &WorldConfig,
    algo: &dyn ScanAlgorithm<T>,
    op: &OpRef<T>,
    inputs: &[Vec<T>],
) -> Result<RunResult<T>> {
    let p = cfg.size();
    assert_eq!(inputs.len(), p, "need one input vector per rank");
    let m = inputs.first().map(|v| v.len()).unwrap_or(0);
    assert!(inputs.iter().all(|v| v.len() == m), "all ranks must contribute m elements");

    let overhead = match &cfg.mode {
        ClockMode::Virtual(model) => model.params.overhead,
        ClockMode::Real => 0.0,
    };

    let per_rank = run_world::<T, (Vec<T>, f64, Option<RankTrace>), _>(cfg, |ctx| {
        // Borrow, don't clone: inputs outlive the scoped rank threads.
        let input = &inputs[ctx.rank()];
        let mut output = vec![T::filler(); m];
        ctx.barrier();
        let start = std::time::Instant::now();
        algo.run(ctx, input, &mut output, op)?;
        let elapsed_us = start.elapsed().as_secs_f64() * 1e6;
        let time = if ctx.is_virtual() { ctx.vclock() + overhead } else { elapsed_us };
        Ok((output, time, ctx.take_trace()))
    })?;

    let mut outputs = Vec::with_capacity(p);
    let mut times = Vec::with_capacity(p);
    let mut traces = Vec::with_capacity(p);
    for (o, t, tr) in per_rank {
        outputs.push(o);
        times.push(t);
        if let Some(tr) = tr {
            traces.push(tr);
        }
    }
    let trace = (!traces.is_empty()).then(|| TraceReport::new(traces));
    Ok(RunResult { outputs, times_us: times, trace })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpi::ops;

    #[test]
    fn topology_sizes() {
        assert_eq!(Topology::cluster(36, 32).size(), 1152);
        assert_eq!(Topology::flat(7).size(), 7);
    }

    #[test]
    fn run_world_collects_in_rank_order() {
        let cfg = WorldConfig::new(Topology::flat(9));
        let out = run_world::<i64, usize, _>(&cfg, |ctx| Ok(ctx.rank() * 10)).unwrap();
        assert_eq!(out, (0..9).map(|r| r * 10).collect::<Vec<_>>());
    }

    #[test]
    fn ring_exchange_all_ranks() {
        // Each rank sends its rank to the right neighbour, receives from
        // the left: classic ring, exercises sendrecv + matching.
        let cfg = WorldConfig::new(Topology::flat(16));
        let out = run_world::<i64, i64, _>(&cfg, |ctx| {
            let p = ctx.size();
            let r = ctx.rank();
            let sbuf = [r as i64];
            let mut rbuf = [0i64];
            ctx.sendrecv(0, (r + 1) % p, &sbuf, (r + p - 1) % p, &mut rbuf)?;
            Ok(rbuf[0])
        })
        .unwrap();
        assert_eq!(out, (0..16).map(|r| ((r + 16 - 1) % 16) as i64).collect::<Vec<_>>());
    }

    #[test]
    fn error_propagates() {
        let cfg = WorldConfig::new(Topology::flat(4));
        let res = run_world::<i64, (), _>(&cfg, |ctx| {
            if ctx.rank() == 2 {
                anyhow::bail!("boom");
            }
            Ok(())
        });
        assert!(res.is_err());
    }

    #[test]
    fn out_of_range_send_errors() {
        let cfg = WorldConfig::new(Topology::flat(2));
        let res = run_world::<i64, (), _>(&cfg, |ctx| {
            if ctx.rank() == 0 {
                ctx.send(0, 5, &[1i64])?;
            }
            Ok(())
        });
        assert!(res.is_err());
    }

    #[test]
    fn virtual_clock_ring() {
        // p=4, one round of ring sendrecv, flat inter-node α=2, β=0:
        // every rank's clock ends at exactly 2.
        let params = CostParams {
            alpha_intra: 1.0,
            alpha_inter: 2.0,
            beta_intra: 0.0,
            beta_inter: 0.0,
            gamma: 0.0,
            overhead: 0.0,
        };
        let cfg = WorldConfig::new(Topology::cluster(4, 1)).virtual_clock(params);
        let clocks = run_world::<i64, f64, _>(&cfg, |ctx| {
            let p = ctx.size();
            let r = ctx.rank();
            let sbuf = [0i64];
            let mut rbuf = [0i64];
            ctx.sendrecv(0, (r + 1) % p, &sbuf, (r + p - 1) % p, &mut rbuf)?;
            Ok(ctx.vclock())
        })
        .unwrap();
        assert_eq!(clocks, vec![2.0; 4]);
    }

    #[test]
    fn run_scan_shape_checks() {
        use crate::coll::Exscan123;
        let cfg = WorldConfig::new(Topology::flat(4));
        let inputs: Vec<Vec<i64>> = (0..4).map(|r| vec![r as i64; 3]).collect();
        let res = run_scan(&cfg, &Exscan123, &ops::bxor(), &inputs).unwrap();
        assert_eq!(res.outputs.len(), 4);
        assert_eq!(res.outputs[1], vec![0, 0, 0]); // V_0 = zeros ^ ... well r=1: V_0 = [0,0,0]
    }
}
