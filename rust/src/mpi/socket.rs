//! Socket transport backend: TCP loopback or Unix-domain stream pairs
//! carrying [`wire`](super::wire)-encoded frames, with per-peer send and
//! receive threads feeding the same slot-inbox matching logic the thread
//! backend uses.
//!
//! One stream per **ordered** rank pair (src, dst): the sending rank's
//! `post` enqueues an encoded frame on the pair's send queue; a dedicated
//! send thread drains the queue and writes frames to the stream; a
//! dedicated receive thread on the destination side reads frames
//! (`read_exact` header, then payload), verifies version + checksum, and
//! deposits the decoded message into the destination rank's local
//! [`Inbox`] through the entry point named by the frame's `kind` byte
//! (deliver / delayed-embargo / overflow-diversion — the sender's chaos
//! decision shipped over the wire). Receives therefore block in plain
//! `recv_match` and are woken by the deposit like any thread-backend
//! receive; rendezvous latency past the wire hop is the inbox's own.
//!
//! ## Failure attribution
//!
//! Every stream-level failure is a **typed** [`TransportFault`] — never
//! a receiver-thread panic. Frames carry per-channel sequence numbers
//! (wire v2); the shared [`WireRecovery`] layer suppresses duplicates,
//! repairs corrupt frames from the sender's retransmit shelf inside a
//! bounded exponential-backoff budget, and on budget exhaustion (or a
//! reset/write timeout with recovery disabled) records the fault
//! first-wins and poisons every inbox. Blocked `take`s then return
//! `None`; the rank context polls [`Transport::fault`], marks the
//! faulted source dead, and bails attributed — funneling into the
//! engine's `RankFailed` classification. A message chaos-dropped at the
//! send site never reaches the transport at all, so the matching receive
//! times out with the standard attributed `recv_timeout` error naming
//! backend, rank, round and src.
//!
//! ## Teardown
//!
//! Dropping the transport raises the `closing` flag, then closes every
//! send queue; send threads drain, exit and drop their write halves;
//! receive threads see EOF **with the flag up** and exit silently (EOF
//! with the flag down is a mid-run connection reset: typed fault).
//! Writes carry a configurable watchdog timeout
//! ([`TransportTuning::write_timeout`]) so a wedged peer cannot hang the
//! drop. Worlds are torn down before their transport, so no rank thread
//! is still posting at that point.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
#[cfg(unix)]
use std::os::unix::net::UnixStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::elem::Elem;
use super::inbox::{Inbox, InboxStats};
use super::msg::Msg;
use super::pool::PoolBuf;
use super::recover::{
    FrameVerdict, TransportFault, TransportFaultKind, TransportStats, WireRecovery,
};
use super::transport::{Transport, TransportBackend, TransportTuning};
use super::wire::{
    decode_header, decode_payload, encode_frame, peek_seq, FrameKind, HEADER_BYTES, WIRE_MAGIC,
};
use super::wirefault::WireFaultReport;
use crate::util::Channel;

/// Either stream flavor behind one interface.
enum Stream {
    Tcp(TcpStream),
    #[cfg(unix)]
    Unix(UnixStream),
}

impl Stream {
    fn set_write_timeout(&self, timeout: Duration) -> std::io::Result<()> {
        match self {
            Stream::Tcp(s) => s.set_write_timeout(Some(timeout)),
            #[cfg(unix)]
            Stream::Unix(s) => s.set_write_timeout(Some(timeout)),
        }
    }

    /// Tear the stream down both ways — the injected connection-reset
    /// path (recovery disabled): the peer's read fails mid-run.
    fn shutdown(&self) {
        let _ = match self {
            Stream::Tcp(s) => s.shutdown(std::net::Shutdown::Both),
            #[cfg(unix)]
            Stream::Unix(s) => s.shutdown(std::net::Shutdown::Both),
        };
    }
}

impl Read for Stream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.read(buf),
            #[cfg(unix)]
            Stream::Unix(s) => s.read(buf),
        }
    }
}

impl Write for Stream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.write(buf),
            #[cfg(unix)]
            Stream::Unix(s) => s.write(buf),
        }
    }
    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            Stream::Tcp(s) => s.flush(),
            #[cfg(unix)]
            Stream::Unix(s) => s.flush(),
        }
    }
}

pub(crate) struct SocketTransport<T> {
    p: usize,
    flavor: TransportBackend,
    /// Per-rank local matchers; receive threads deposit into them.
    inboxes: Arc<Vec<Inbox<T>>>,
    /// Send queue per ordered pair, index src·p + dst.
    queues: Vec<Arc<Channel<Vec<u8>>>>,
    send_threads: Vec<JoinHandle<()>>,
    recv_threads: Vec<JoinHandle<()>>,
    /// Seq accounting, duplicate suppression, retransmit shelf and the
    /// first-wins typed-fault slot — shared machinery with the shm
    /// backend (`mpi/recover.rs`).
    recovery: Arc<WireRecovery>,
    /// Raised before the orderly teardown closes the send queues, so
    /// receive threads can tell clean EOF from a mid-run reset.
    closing: Arc<AtomicBool>,
}

/// Pairing hello written on each fresh TCP connection so the accepting
/// side can route the stream to its (src, dst) pair regardless of accept
/// order: magic, src, dst, zero.
fn write_hello(s: &mut TcpStream, src: usize, dst: usize) -> std::io::Result<()> {
    let mut hello = [0u8; 16];
    hello[0..4].copy_from_slice(&WIRE_MAGIC.to_le_bytes());
    hello[4..8].copy_from_slice(&(src as u32).to_le_bytes());
    hello[8..12].copy_from_slice(&(dst as u32).to_le_bytes());
    s.write_all(&hello)
}

fn read_hello(s: &mut TcpStream) -> Result<(usize, usize)> {
    let mut hello = [0u8; 16];
    s.read_exact(&mut hello).context("reading pairing hello")?;
    let magic = u32::from_le_bytes([hello[0], hello[1], hello[2], hello[3]]);
    if magic != WIRE_MAGIC {
        bail!("bad pairing hello magic {magic:#010x}");
    }
    let src = u32::from_le_bytes([hello[4], hello[5], hello[6], hello[7]]) as usize;
    let dst = u32::from_le_bytes([hello[8], hello[9], hello[10], hello[11]]) as usize;
    Ok((src, dst))
}

/// Build the p² stream mesh for the requested flavor. Entry (src, dst)
/// is a (write half, read half) pair: the write half goes to the pair's
/// send thread, the read half to its receive thread.
fn build_mesh(flavor: TransportBackend, p: usize) -> Result<Vec<(Stream, Stream)>> {
    let mut mesh = Vec::with_capacity(p * p);
    match flavor {
        #[cfg(unix)]
        TransportBackend::Uds => {
            for _ in 0..p * p {
                let (w, r) = UnixStream::pair()
                    .context("transport backend 'uds': socketpair failed")?;
                mesh.push((Stream::Unix(w), Stream::Unix(r)));
            }
        }
        #[cfg(not(unix))]
        TransportBackend::Uds => {
            bail!("transport backend 'uds' unavailable: unix-domain sockets need a unix host")
        }
        TransportBackend::Tcp => {
            let listener = TcpListener::bind("127.0.0.1:0")
                .context("transport backend 'tcp': cannot bind a loopback listener")?;
            let addr = listener.local_addr()?;
            // Connect + accept one pair at a time: loopback connects
            // complete against the listen backlog, and the hello routes
            // the accepted stream even if the kernel reordered anything.
            let mut read_halves: Vec<Option<Stream>> = (0..p * p).map(|_| None).collect();
            let mut write_halves: Vec<Option<Stream>> = (0..p * p).map(|_| None).collect();
            for src in 0..p {
                for dst in 0..p {
                    let mut w = TcpStream::connect(addr)
                        .context("transport backend 'tcp': loopback connect failed")?;
                    w.set_nodelay(true)?;
                    write_hello(&mut w, src, dst)
                        .context("transport backend 'tcp': pairing hello failed")?;
                    write_halves[src * p + dst] = Some(Stream::Tcp(w));
                    let (mut r, _) = listener
                        .accept()
                        .context("transport backend 'tcp': accept failed")?;
                    r.set_nodelay(true)?;
                    let (hsrc, hdst) = read_hello(&mut r)?;
                    if hsrc >= p || hdst >= p || read_halves[hsrc * p + hdst].is_some() {
                        bail!(
                            "transport backend 'tcp': pairing hello claims duplicate or \
                             out-of-range channel {hsrc}→{hdst}"
                        );
                    }
                    read_halves[hsrc * p + hdst] = Some(Stream::Tcp(r));
                }
            }
            for i in 0..p * p {
                let (Some(w), Some(r)) = (write_halves[i].take(), read_halves[i].take()) else {
                    bail!("transport backend 'tcp': mesh pairing left channel {i} unpaired");
                };
                mesh.push((w, r));
            }
        }
        TransportBackend::Thread | TransportBackend::Shm => {
            unreachable!("not a socket flavor")
        }
    }
    Ok(mesh)
}

/// Poison every inbox so blocked receivers wake (and return `None`; the
/// rank context then polls the typed fault and attributes it).
fn poison_inboxes<T: Elem>(inboxes: &[Inbox<T>]) {
    for inbox in inboxes {
        inbox.poison();
    }
}

impl<T: Elem> SocketTransport<T> {
    pub fn new(flavor: TransportBackend, p: usize, tuning: &TransportTuning) -> Result<Self> {
        debug_assert!(matches!(flavor, TransportBackend::Tcp | TransportBackend::Uds));
        let mesh = build_mesh(flavor, p)?;
        let inboxes: Arc<Vec<Inbox<T>>> =
            Arc::new((0..p).map(|_| Inbox::new_with(tuning.fixed_spin)).collect());
        let recovery = Arc::new(WireRecovery::new(flavor, p, tuning.wirefault.as_ref()));
        let closing = Arc::new(AtomicBool::new(false));
        let mut queues = Vec::with_capacity(p * p);
        let mut send_threads = Vec::with_capacity(p * p);
        let mut recv_threads = Vec::with_capacity(p * p);

        for (i, (write_half, read_half)) in mesh.into_iter().enumerate() {
            let (src, dst) = (i / p, i % p);
            let name = flavor.name();

            let queue: Arc<Channel<Vec<u8>>> = Arc::new(Channel::new());
            let q = Arc::clone(&queue);
            let rec = Arc::clone(&recovery);
            let ib = Arc::clone(&inboxes);
            let mut w = write_half;
            if let Err(e) = w.set_write_timeout(tuning.write_timeout) {
                bail!("transport backend '{name}': cannot arm write watchdog: {e}");
            }
            send_threads.push(
                std::thread::Builder::new()
                    .name(format!("{name}-send-{src}-{dst}"))
                    .spawn(move || {
                        while let Some(frame) = q.pop_wait() {
                            let seq = peek_seq(&frame).unwrap_or(0);
                            // Injected connection reset: the plan is pure
                            // in (seed, src, dst, seq), so this thread
                            // re-derives the decision the sampler made.
                            if rec.reset_planned(src, dst, seq) {
                                if rec.recovery_enabled() {
                                    // Reconnect-with-backoff: on the
                                    // in-process mesh the "fresh stream"
                                    // is the same socketpair, so recovery
                                    // is a counted backoff before the
                                    // frame goes out untouched.
                                    rec.note_reset_reconnect(src, dst, seq);
                                    std::thread::sleep(WireRecovery::backoff(1));
                                } else {
                                    rec.note_reset_fatal(src, dst, seq);
                                    rec.raise(TransportFault {
                                        backend: rec.backend(),
                                        src,
                                        dst,
                                        seq,
                                        kind: TransportFaultKind::ConnectionReset,
                                        attempts: 1,
                                    });
                                    w.shutdown();
                                    poison_inboxes(&ib);
                                    return;
                                }
                            }
                            if let Err(e) = w.write_all(&frame).and_then(|()| w.flush()) {
                                let kind = if matches!(
                                    e.kind(),
                                    std::io::ErrorKind::WouldBlock
                                        | std::io::ErrorKind::TimedOut
                                ) {
                                    TransportFaultKind::WriteTimeout
                                } else {
                                    TransportFaultKind::ConnectionReset
                                };
                                rec.raise(TransportFault {
                                    backend: rec.backend(),
                                    src,
                                    dst,
                                    seq,
                                    kind,
                                    attempts: 1,
                                });
                                poison_inboxes(&ib);
                                return;
                            }
                        }
                        // Queue closed: drop the write half → peer reads EOF.
                    })
                    .expect("failed to spawn transport send thread"),
            );
            queues.push(queue);

            let rec = Arc::clone(&recovery);
            let ib = Arc::clone(&inboxes);
            let cl = Arc::clone(&closing);
            let mut r = read_half;
            recv_threads.push(
                std::thread::Builder::new()
                    .name(format!("{name}-recv-{src}-{dst}"))
                    .spawn(move || {
                        let mut header = [0u8; HEADER_BYTES];
                        loop {
                            if let Err(e) = r.read_exact(&mut header) {
                                // EOF between frames with the closing
                                // flag up (or a fault already recorded —
                                // the peer's send thread bailed) is the
                                // orderly exit; anything else is a
                                // mid-run reset: typed fault, poison,
                                // exit — never a panic.
                                let orderly = e.kind() == std::io::ErrorKind::UnexpectedEof
                                    && (cl.load(Ordering::Acquire) || rec.fault().is_some());
                                if !orderly {
                                    rec.raise_external(
                                        src,
                                        dst,
                                        TransportFaultKind::ConnectionReset,
                                    );
                                    poison_inboxes(&ib);
                                }
                                return;
                            }
                            // Injected mutations happen inside
                            // process_frame on the local copy, so the
                            // header bytes on the stream are as written;
                            // a header that fails structural decode here
                            // is genuine corruption — unframeable, fatal.
                            let payload_len = match decode_header(&header) {
                                Ok(fh) => fh.payload_len,
                                Err(_) => {
                                    rec.raise_external(
                                        src,
                                        dst,
                                        TransportFaultKind::CorruptHeader,
                                    );
                                    poison_inboxes(&ib);
                                    return;
                                }
                            };
                            let mut frame = vec![0u8; HEADER_BYTES + payload_len];
                            frame[..HEADER_BYTES].copy_from_slice(&header);
                            if r.read_exact(&mut frame[HEADER_BYTES..]).is_err() {
                                rec.raise_external(src, dst, TransportFaultKind::Truncated);
                                poison_inboxes(&ib);
                                return;
                            }
                            let bytes = match rec.process_frame(src, dst, frame) {
                                Ok(FrameVerdict::Dup) => continue,
                                Ok(FrameVerdict::Deliver(bytes)) => bytes,
                                Err(_fault) => {
                                    // Typed fault recorded first-wins by
                                    // the recovery layer.
                                    poison_inboxes(&ib);
                                    return;
                                }
                            };
                            let Ok(fh) = decode_header(&bytes) else {
                                rec.raise_external(
                                    src,
                                    dst,
                                    TransportFaultKind::CorruptHeader,
                                );
                                poison_inboxes(&ib);
                                return;
                            };
                            let Ok(data) = decode_payload::<T>(&fh, &bytes[HEADER_BYTES..])
                            else {
                                rec.raise_external(
                                    src,
                                    dst,
                                    TransportFaultKind::UndecodablePayload,
                                );
                                poison_inboxes(&ib);
                                return;
                            };
                            let msg = Msg {
                                src: fh.src,
                                tag: fh.tag,
                                data: PoolBuf::detached(data),
                                vtime: fh.vtime,
                            };
                            match fh.kind {
                                FrameKind::Deliver => ib[dst].deposit(msg),
                                FrameKind::Delayed => ib[dst].deposit_delayed(
                                    msg,
                                    Instant::now() + Duration::from_micros(fh.delay_micros),
                                ),
                                FrameKind::Overflow => ib[dst].deposit_overflow(msg),
                            }
                        }
                    })
                    .expect("failed to spawn transport recv thread"),
            );
        }

        Ok(SocketTransport {
            p,
            flavor,
            inboxes,
            queues,
            send_threads,
            recv_threads,
            recovery,
            closing,
        })
    }

    fn enqueue(&self, to: usize, kind: FrameKind, delay_micros: u64, msg: Msg<T>) {
        let src = msg.src;
        let seq = self.recovery.next_seq(src, to);
        let frame =
            encode_frame(kind, src, to, msg.tag, delay_micros, msg.vtime, seq, &msg.data);
        drop(msg); // lease ends: the pooled send buffer recycles now
        let plan = self.recovery.on_send(src, to, seq, &frame);
        // A closed queue means teardown is in progress; the frame is
        // dropped like any post into a dying world.
        let q = &self.queues[src * self.p + to];
        if plan.duplicate {
            // Injected duplicate: the receiver must suppress it by seq.
            let _ = q.push(frame.clone());
        }
        let _ = q.push(frame);
    }
}

impl<T: Elem> Transport<T> for SocketTransport<T> {
    fn post(&self, to: usize, msg: Msg<T>) {
        if self.recovery.fault().is_some() {
            return; // world death in progress: drop like a dying post
        }
        self.enqueue(to, FrameKind::Deliver, 0, msg);
    }

    fn post_delayed(&self, to: usize, msg: Msg<T>, release_at: Instant) {
        if self.recovery.fault().is_some() {
            return;
        }
        let micros = release_at.saturating_duration_since(Instant::now()).as_micros() as u64;
        self.enqueue(to, FrameKind::Delayed, micros, msg);
    }

    fn post_overflow(&self, to: usize, msg: Msg<T>) {
        if self.recovery.fault().is_some() {
            return;
        }
        self.enqueue(to, FrameKind::Overflow, 0, msg);
    }

    fn take(
        &self,
        me: usize,
        src: usize,
        tag: u64,
        pending: &mut Vec<Msg<T>>,
        deadline: Instant,
    ) -> Option<Msg<T>> {
        // A fault recorded before this call would not re-trigger the
        // edge-triggered poison inside recv_match — bail up front (the
        // rank context polls `fault()` and attributes the typed fault).
        if self.recovery.fault().is_some() {
            return None;
        }
        // Deposits come from the receive threads and wake parked
        // receivers through the inbox itself, so a single full-deadline
        // recv_match suffices — no drain slicing needed on this backend.
        self.inboxes[me].recv_match(src, tag, pending, deadline)
    }

    fn poison_all(&self) {
        poison_inboxes(&self.inboxes);
    }

    fn stats(&self, me: usize) -> InboxStats {
        self.inboxes[me].stats()
    }

    fn wire_stats(&self) -> TransportStats {
        self.recovery.stats()
    }

    fn fault(&self) -> Option<TransportFault> {
        self.recovery.fault()
    }

    fn wire_report(&self) -> Option<WireFaultReport> {
        self.recovery.report()
    }

    fn name(&self) -> &'static str {
        self.flavor.name()
    }
}

impl<T> Drop for SocketTransport<T> {
    fn drop(&mut self) {
        // Raise the closing flag first so receive threads classify the
        // coming EOFs as orderly, then close every send queue: send
        // threads drain what's left, exit, and drop their write halves;
        // receive threads then read EOF and exit. The write watchdog
        // bounds a wedged peer.
        self.closing.store(true, Ordering::Release);
        for q in &self.queues {
            q.close();
        }
        for h in self.send_threads.drain(..) {
            let _ = h.join();
        }
        for h in self.recv_threads.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk_msg(src: usize, tag: u64, data: Vec<i64>) -> Msg<i64> {
        Msg { src, tag, data: PoolBuf::detached(data), vtime: 0.0 }
    }

    fn roundtrip_on(flavor: TransportBackend) {
        let t: SocketTransport<i64> =
            SocketTransport::new(flavor, 3, &TransportTuning::default()).unwrap();
        let mut pending = Vec::new();
        let deadline = Instant::now() + Duration::from_secs(10);
        t.post(2, mk_msg(0, 5, vec![10, 20]));
        t.post(2, mk_msg(1, 5, vec![30]));
        let a = t.take(2, 0, 5, &mut pending, deadline).unwrap();
        let b = t.take(2, 1, 5, &mut pending, deadline).unwrap();
        assert_eq!(&a.data[..], &[10, 20]);
        assert_eq!(&b.data[..], &[30]);
        assert_eq!(t.name(), flavor.name());
    }

    #[test]
    fn tcp_loopback_roundtrip() {
        if TransportBackend::Tcp.is_available() {
            roundtrip_on(TransportBackend::Tcp);
        }
    }

    #[cfg(unix)]
    #[test]
    fn uds_roundtrip() {
        roundtrip_on(TransportBackend::Uds);
    }

    #[cfg(unix)]
    #[test]
    fn poison_wakes_blocked_socket_take() {
        let t = Arc::new(
            SocketTransport::<i64>::new(TransportBackend::Uds, 2, &TransportTuning::default())
                .unwrap(),
        );
        let t2 = Arc::clone(&t);
        let waiter = std::thread::spawn(move || {
            let mut pending = Vec::new();
            t2.take(1, 0, 42, &mut pending, Instant::now() + Duration::from_secs(30))
        });
        std::thread::sleep(Duration::from_millis(30));
        t.poison_all();
        assert!(waiter.join().unwrap().is_none());
    }

    /// Everything off except the one probability the test drives to 1.
    #[cfg(unix)]
    fn only(cfg: crate::mpi::wirefault::WireFaultConfig) -> TransportTuning {
        TransportTuning { wirefault: Some(cfg), ..TransportTuning::default() }
    }

    #[cfg(unix)]
    fn quiet(seed: u64) -> crate::mpi::wirefault::WireFaultConfig {
        crate::mpi::wirefault::WireFaultConfig::new(seed)
            .with_header_flip_prob(0.0)
            .with_payload_flip_prob(0.0)
            .with_checksum_prob(0.0)
            .with_truncate_prob(0.0)
            .with_duplicate_prob(0.0)
            .with_reset_prob(0.0)
    }

    #[cfg(unix)]
    #[test]
    fn uds_injected_duplicates_are_suppressed() {
        let tuning = only(quiet(3).with_duplicate_prob(1.0));
        let t: SocketTransport<i64> =
            SocketTransport::new(TransportBackend::Uds, 2, &tuning).unwrap();
        let mut pending = Vec::new();
        let deadline = Instant::now() + Duration::from_secs(10);
        for k in 0..4u64 {
            t.post(1, mk_msg(0, k, vec![k as i64]));
            let m = t.take(1, 0, k, &mut pending, deadline).unwrap();
            assert_eq!(&m.data[..], &[k as i64]);
        }
        // The second copies ride the same FIFO stream; once a later
        // original delivered, every earlier duplicate has been counted.
        // Poll briefly for the trailing duplicate of the last frame.
        let waited = Instant::now() + Duration::from_secs(5);
        while t.wire_stats().dropped_dups < 4 && Instant::now() < waited {
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(t.wire_stats().dropped_dups, 4);
        assert_eq!(t.wire_stats().faults, 0);
        assert_eq!(t.wire_report().expect("plan armed").duplicates, 4);
        assert!(pending.is_empty());
    }

    #[cfg(unix)]
    #[test]
    fn uds_injected_reset_with_recovery_reconnects_and_delivers() {
        let tuning = only(quiet(5).with_reset_prob(1.0));
        let t: SocketTransport<i64> =
            SocketTransport::new(TransportBackend::Uds, 2, &tuning).unwrap();
        let mut pending = Vec::new();
        t.post(1, mk_msg(0, 7, vec![42]));
        let m = t.take(1, 0, 7, &mut pending, Instant::now() + Duration::from_secs(10)).unwrap();
        assert_eq!(&m.data[..], &[42]);
        assert!(t.wire_stats().reconnects >= 1, "reset must be recovered via reconnect");
        assert_eq!(t.wire_stats().faults, 0);
    }

    #[cfg(unix)]
    #[test]
    fn uds_injected_reset_without_recovery_is_typed_fault() {
        let tuning = only(quiet(5).with_reset_prob(1.0).without_recovery());
        let t: SocketTransport<i64> =
            SocketTransport::new(TransportBackend::Uds, 2, &tuning).unwrap();
        let mut pending = Vec::new();
        t.post(1, mk_msg(0, 7, vec![42]));
        let got = t.take(1, 0, 7, &mut pending, Instant::now() + Duration::from_secs(10));
        assert!(got.is_none(), "reset frame must not deliver");
        let fault = t.fault().expect("typed fault recorded");
        assert_eq!(fault.kind, TransportFaultKind::ConnectionReset);
        assert_eq!((fault.src, fault.dst), (0, 1));
        assert!(t.wire_stats().faults >= 1);
        // Posts after the fault are dropped, not panics.
        t.post(1, mk_msg(0, 8, vec![1]));
    }
}
