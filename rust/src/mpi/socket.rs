//! Socket transport backend: TCP loopback or Unix-domain stream pairs
//! carrying [`wire`](super::wire)-encoded frames, with per-peer send and
//! receive threads feeding the same slot-inbox matching logic the thread
//! backend uses.
//!
//! One stream per **ordered** rank pair (src, dst): the sending rank's
//! `post` enqueues an encoded frame on the pair's send queue; a dedicated
//! send thread drains the queue and writes frames to the stream; a
//! dedicated receive thread on the destination side reads frames
//! (`read_exact` header, then payload), verifies version + checksum, and
//! deposits the decoded message into the destination rank's local
//! [`Inbox`] through the entry point named by the frame's `kind` byte
//! (deliver / delayed-embargo / overflow-diversion — the sender's chaos
//! decision shipped over the wire). Receives therefore block in plain
//! `recv_match` and are woken by the deposit like any thread-backend
//! receive; rendezvous latency past the wire hop is the inbox's own.
//!
//! ## Failure attribution
//!
//! A stream fault or corrupt frame (bad magic/version/checksum, length
//! mismatch) records an attributed fault naming the channel and poisons
//! every inbox; the next `take` on any rank panics with that fault, which
//! the world's panic containment surfaces as the run's error. A message
//! chaos-dropped at the send site never reaches the transport at all, so
//! the matching receive times out with the standard attributed
//! `recv_timeout` error naming backend, rank, round and src.
//!
//! ## Teardown
//!
//! Dropping the transport closes every send queue; send threads drain,
//! exit and drop their write halves; receive threads see EOF and exit.
//! Writes carry a watchdog timeout so a wedged peer cannot hang the
//! drop. Worlds are torn down before their transport, so no rank thread
//! is still posting at that point.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
#[cfg(unix)]
use std::os::unix::net::UnixStream;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::elem::Elem;
use super::inbox::{Inbox, InboxStats};
use super::msg::Msg;
use super::pool::PoolBuf;
use super::transport::{Transport, TransportBackend};
use super::wire::{
    decode_header, decode_payload, encode_frame, verify_payload, FrameKind, HEADER_BYTES,
    WIRE_MAGIC,
};
use crate::util::Channel;

/// Watchdog on stream writes: a peer that stops reading for this long is
/// treated as faulted rather than wedging the send thread (and any later
/// teardown) forever.
const WRITE_TIMEOUT: Duration = Duration::from_secs(10);

/// Either stream flavor behind one interface.
enum Stream {
    Tcp(TcpStream),
    #[cfg(unix)]
    Unix(UnixStream),
}

impl Stream {
    fn set_write_timeout(&self) -> std::io::Result<()> {
        match self {
            Stream::Tcp(s) => s.set_write_timeout(Some(WRITE_TIMEOUT)),
            #[cfg(unix)]
            Stream::Unix(s) => s.set_write_timeout(Some(WRITE_TIMEOUT)),
        }
    }
}

impl Read for Stream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.read(buf),
            #[cfg(unix)]
            Stream::Unix(s) => s.read(buf),
        }
    }
}

impl Write for Stream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.write(buf),
            #[cfg(unix)]
            Stream::Unix(s) => s.write(buf),
        }
    }
    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            Stream::Tcp(s) => s.flush(),
            #[cfg(unix)]
            Stream::Unix(s) => s.flush(),
        }
    }
}

/// Shared fault slot: first attributed transport fault wins; every
/// subsequent `take` re-raises it on the rank threads.
#[derive(Default)]
struct Fault {
    slot: Mutex<Option<String>>,
}

impl Fault {
    fn set(&self, msg: String) {
        let mut slot = self.slot.lock().unwrap();
        slot.get_or_insert(msg);
    }

    fn get(&self) -> Option<String> {
        self.slot.lock().unwrap().clone()
    }
}

pub(crate) struct SocketTransport<T> {
    p: usize,
    flavor: TransportBackend,
    /// Per-rank local matchers; receive threads deposit into them.
    inboxes: Arc<Vec<Inbox<T>>>,
    /// Send queue per ordered pair, index src·p + dst.
    queues: Vec<Arc<Channel<Vec<u8>>>>,
    send_threads: Vec<JoinHandle<()>>,
    recv_threads: Vec<JoinHandle<()>>,
    fault: Arc<Fault>,
}

/// Pairing hello written on each fresh TCP connection so the accepting
/// side can route the stream to its (src, dst) pair regardless of accept
/// order: magic, src, dst, zero.
fn write_hello(s: &mut TcpStream, src: usize, dst: usize) -> std::io::Result<()> {
    let mut hello = [0u8; 16];
    hello[0..4].copy_from_slice(&WIRE_MAGIC.to_le_bytes());
    hello[4..8].copy_from_slice(&(src as u32).to_le_bytes());
    hello[8..12].copy_from_slice(&(dst as u32).to_le_bytes());
    s.write_all(&hello)
}

fn read_hello(s: &mut TcpStream) -> Result<(usize, usize)> {
    let mut hello = [0u8; 16];
    s.read_exact(&mut hello).context("reading pairing hello")?;
    let magic = u32::from_le_bytes([hello[0], hello[1], hello[2], hello[3]]);
    if magic != WIRE_MAGIC {
        bail!("bad pairing hello magic {magic:#010x}");
    }
    let src = u32::from_le_bytes([hello[4], hello[5], hello[6], hello[7]]) as usize;
    let dst = u32::from_le_bytes([hello[8], hello[9], hello[10], hello[11]]) as usize;
    Ok((src, dst))
}

/// Build the p² stream mesh for the requested flavor. Entry (src, dst)
/// is a (write half, read half) pair: the write half goes to the pair's
/// send thread, the read half to its receive thread.
fn build_mesh(flavor: TransportBackend, p: usize) -> Result<Vec<(Stream, Stream)>> {
    let mut mesh = Vec::with_capacity(p * p);
    match flavor {
        #[cfg(unix)]
        TransportBackend::Uds => {
            for _ in 0..p * p {
                let (w, r) = UnixStream::pair()
                    .context("transport backend 'uds': socketpair failed")?;
                mesh.push((Stream::Unix(w), Stream::Unix(r)));
            }
        }
        #[cfg(not(unix))]
        TransportBackend::Uds => {
            bail!("transport backend 'uds' unavailable: unix-domain sockets need a unix host")
        }
        TransportBackend::Tcp => {
            let listener = TcpListener::bind("127.0.0.1:0")
                .context("transport backend 'tcp': cannot bind a loopback listener")?;
            let addr = listener.local_addr()?;
            // Connect + accept one pair at a time: loopback connects
            // complete against the listen backlog, and the hello routes
            // the accepted stream even if the kernel reordered anything.
            let mut read_halves: Vec<Option<Stream>> = (0..p * p).map(|_| None).collect();
            let mut write_halves: Vec<Option<Stream>> = (0..p * p).map(|_| None).collect();
            for src in 0..p {
                for dst in 0..p {
                    let mut w = TcpStream::connect(addr)
                        .context("transport backend 'tcp': loopback connect failed")?;
                    w.set_nodelay(true)?;
                    write_hello(&mut w, src, dst)
                        .context("transport backend 'tcp': pairing hello failed")?;
                    write_halves[src * p + dst] = Some(Stream::Tcp(w));
                    let (mut r, _) = listener
                        .accept()
                        .context("transport backend 'tcp': accept failed")?;
                    r.set_nodelay(true)?;
                    let (hsrc, hdst) = read_hello(&mut r)?;
                    if hsrc >= p || hdst >= p || read_halves[hsrc * p + hdst].is_some() {
                        bail!(
                            "transport backend 'tcp': pairing hello claims duplicate or \
                             out-of-range channel {hsrc}→{hdst}"
                        );
                    }
                    read_halves[hsrc * p + hdst] = Some(Stream::Tcp(r));
                }
            }
            for i in 0..p * p {
                let (Some(w), Some(r)) = (write_halves[i].take(), read_halves[i].take()) else {
                    bail!("transport backend 'tcp': mesh pairing left channel {i} unpaired");
                };
                mesh.push((w, r));
            }
        }
        TransportBackend::Thread | TransportBackend::Shm => {
            unreachable!("not a socket flavor")
        }
    }
    Ok(mesh)
}

impl<T: Elem> SocketTransport<T> {
    pub fn new(flavor: TransportBackend, p: usize, fixed_spin: bool) -> Result<Self> {
        debug_assert!(matches!(flavor, TransportBackend::Tcp | TransportBackend::Uds));
        let mesh = build_mesh(flavor, p)?;
        let inboxes: Arc<Vec<Inbox<T>>> =
            Arc::new((0..p).map(|_| Inbox::new_with(fixed_spin)).collect());
        let fault = Arc::new(Fault::default());
        let mut queues = Vec::with_capacity(p * p);
        let mut send_threads = Vec::with_capacity(p * p);
        let mut recv_threads = Vec::with_capacity(p * p);

        for (i, (write_half, read_half)) in mesh.into_iter().enumerate() {
            let (src, dst) = (i / p, i % p);
            let name = flavor.name();

            let queue: Arc<Channel<Vec<u8>>> = Arc::new(Channel::new());
            let q = Arc::clone(&queue);
            let f = Arc::clone(&fault);
            let ib = Arc::clone(&inboxes);
            let mut w = write_half;
            if let Err(e) = w.set_write_timeout() {
                bail!("transport backend '{name}': cannot arm write watchdog: {e}");
            }
            send_threads.push(
                std::thread::Builder::new()
                    .name(format!("{name}-send-{src}-{dst}"))
                    .spawn(move || {
                        while let Some(frame) = q.pop_wait() {
                            if let Err(e) = w.write_all(&frame).and_then(|()| w.flush()) {
                                f.set(format!(
                                    "{name} transport: write on channel {src}→{dst} failed: {e}"
                                ));
                                for inbox in ib.iter() {
                                    inbox.poison();
                                }
                                return;
                            }
                        }
                        // Queue closed: drop the write half → peer reads EOF.
                    })
                    .expect("failed to spawn transport send thread"),
            );
            queues.push(queue);

            let f = Arc::clone(&fault);
            let ib = Arc::clone(&inboxes);
            let mut r = read_half;
            recv_threads.push(
                std::thread::Builder::new()
                    .name(format!("{name}-recv-{src}-{dst}"))
                    .spawn(move || {
                        let mut header = [0u8; HEADER_BYTES];
                        loop {
                            match r.read_exact(&mut header) {
                                Ok(()) => {}
                                // EOF between frames is the orderly
                                // teardown path; anything else (including
                                // EOF mid-header) is a fault.
                                Err(e) => {
                                    if e.kind() != std::io::ErrorKind::UnexpectedEof {
                                        f.set(format!(
                                            "{name} transport: read on channel {src}→{dst} failed: {e}"
                                        ));
                                        for inbox in ib.iter() {
                                            inbox.poison();
                                        }
                                    }
                                    return;
                                }
                            }
                            let step = || -> Result<()> {
                                let fh = decode_header(&header)?;
                                let mut payload = vec![0u8; fh.payload_len];
                                r.read_exact(&mut payload)
                                    .context("reading frame payload")?;
                                verify_payload(&header, &payload)?;
                                let data: Vec<T> = decode_payload(&fh, &payload)?;
                                let msg = Msg {
                                    src: fh.src,
                                    tag: fh.tag,
                                    data: PoolBuf::detached(data),
                                    vtime: fh.vtime,
                                };
                                match fh.kind {
                                    FrameKind::Deliver => ib[dst].deposit(msg),
                                    FrameKind::Delayed => ib[dst].deposit_delayed(
                                        msg,
                                        Instant::now()
                                            + Duration::from_micros(fh.delay_micros),
                                    ),
                                    FrameKind::Overflow => ib[dst].deposit_overflow(msg),
                                }
                                Ok(())
                            };
                            if let Err(e) = step() {
                                f.set(format!(
                                    "{name} transport: corrupt frame on channel {src}→{dst}: {e:#}"
                                ));
                                for inbox in ib.iter() {
                                    inbox.poison();
                                }
                                return;
                            }
                        }
                    })
                    .expect("failed to spawn transport recv thread"),
            );
        }

        Ok(SocketTransport { p, flavor, inboxes, queues, send_threads, recv_threads, fault })
    }

    fn enqueue(&self, to: usize, kind: FrameKind, delay_micros: u64, msg: Msg<T>) {
        let frame = encode_frame(kind, msg.src, to, msg.tag, delay_micros, msg.vtime, &msg.data);
        let src = msg.src;
        drop(msg); // lease ends: the pooled send buffer recycles now
        // A closed queue means teardown is in progress; the frame is
        // dropped like any post into a dying world.
        let _ = self.queues[src * self.p + to].push(frame);
    }

    /// Re-raise a recorded transport fault on the calling rank thread —
    /// the world's panic containment turns it into the run's error.
    fn check_fault(&self) {
        if let Some(e) = self.fault.get() {
            panic!("{e}");
        }
    }
}

impl<T: Elem> Transport<T> for SocketTransport<T> {
    fn post(&self, to: usize, msg: Msg<T>) {
        self.check_fault();
        self.enqueue(to, FrameKind::Deliver, 0, msg);
    }

    fn post_delayed(&self, to: usize, msg: Msg<T>, release_at: Instant) {
        self.check_fault();
        let micros = release_at.saturating_duration_since(Instant::now()).as_micros() as u64;
        self.enqueue(to, FrameKind::Delayed, micros, msg);
    }

    fn post_overflow(&self, to: usize, msg: Msg<T>) {
        self.check_fault();
        self.enqueue(to, FrameKind::Overflow, 0, msg);
    }

    fn take(
        &self,
        me: usize,
        src: usize,
        tag: u64,
        pending: &mut Vec<Msg<T>>,
        deadline: Instant,
    ) -> Option<Msg<T>> {
        // A fault recorded before this call would not re-trigger the
        // edge-triggered poison inside recv_match — raise it up front.
        self.check_fault();
        // Deposits come from the receive threads and wake parked
        // receivers through the inbox itself, so a single full-deadline
        // recv_match suffices — no drain slicing needed on this backend.
        let got = self.inboxes[me].recv_match(src, tag, pending, deadline);
        if got.is_none() {
            self.check_fault();
        }
        got
    }

    fn poison_all(&self) {
        for inbox in self.inboxes.iter() {
            inbox.poison();
        }
    }

    fn stats(&self, me: usize) -> InboxStats {
        self.inboxes[me].stats()
    }

    fn name(&self) -> &'static str {
        self.flavor.name()
    }
}

impl<T> Drop for SocketTransport<T> {
    fn drop(&mut self) {
        // Close every send queue: send threads drain what's left, exit,
        // and drop their write halves; receive threads then read EOF and
        // exit. The write watchdog bounds a wedged peer.
        for q in &self.queues {
            q.close();
        }
        for h in self.send_threads.drain(..) {
            let _ = h.join();
        }
        for h in self.recv_threads.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk_msg(src: usize, tag: u64, data: Vec<i64>) -> Msg<i64> {
        Msg { src, tag, data: PoolBuf::detached(data), vtime: 0.0 }
    }

    fn roundtrip_on(flavor: TransportBackend) {
        let t: SocketTransport<i64> = SocketTransport::new(flavor, 3, false).unwrap();
        let mut pending = Vec::new();
        let deadline = Instant::now() + Duration::from_secs(10);
        t.post(2, mk_msg(0, 5, vec![10, 20]));
        t.post(2, mk_msg(1, 5, vec![30]));
        let a = t.take(2, 0, 5, &mut pending, deadline).unwrap();
        let b = t.take(2, 1, 5, &mut pending, deadline).unwrap();
        assert_eq!(&a.data[..], &[10, 20]);
        assert_eq!(&b.data[..], &[30]);
        assert_eq!(t.name(), flavor.name());
    }

    #[test]
    fn tcp_loopback_roundtrip() {
        if TransportBackend::Tcp.is_available() {
            roundtrip_on(TransportBackend::Tcp);
        }
    }

    #[cfg(unix)]
    #[test]
    fn uds_roundtrip() {
        roundtrip_on(TransportBackend::Uds);
    }

    #[cfg(unix)]
    #[test]
    fn poison_wakes_blocked_socket_take() {
        let t = Arc::new(SocketTransport::<i64>::new(TransportBackend::Uds, 2, false).unwrap());
        let t2 = Arc::clone(&t);
        let waiter = std::thread::spawn(move || {
            let mut pending = Vec::new();
            t2.take(1, 0, 42, &mut pending, Instant::now() + Duration::from_secs(30))
        });
        std::thread::sleep(Duration::from_millis(30));
        t.poison_all();
        assert!(waiter.join().unwrap().is_none());
    }
}
