//! The rendezvous transport boundary: every world moves messages through
//! a [`Transport`] — post on the sender side, matched take on the
//! receiver side, poison to wake every blocked receiver on rank death.
//!
//! Three backends implement the contract (selected per world via
//! [`WorldConfig::with_transport`](super::world::WorldConfig::with_transport)):
//!
//! * [`ThreadTransport`] — the in-process slot inbox
//!   ([`inbox`](super::inbox)), extracted verbatim: one [`Inbox`] per
//!   rank, pooled buffers handed sender → receiver by move, the adaptive
//!   per-slot EMA spin budget untouched. The oracle backend.
//! * [`ShmTransport`](super::shm::ShmTransport) — per-(src, dst) SPSC
//!   byte rings in one `MAP_SHARED` mmap'd segment; frames are encoded
//!   with the [`wire`](super::wire) codec and drained by the receiving
//!   rank into its local inbox, so matching (and the (src, ctx, chunk,
//!   round) slot keying) is byte-for-byte the same machinery.
//! * [`SocketTransport`](super::socket::SocketTransport) — TCP loopback
//!   or Unix-domain stream pairs with per-peer send and receive threads;
//!   receive threads decode frames and deposit into the destination
//!   rank's local inbox.
//!
//! ## The contract
//!
//! * **Ordering** — frames between one (src, dst) pair arrive in post
//!   order; matching is by exact (src, tag), so cross-key reordering
//!   (which the chaos embargo deliberately produces) is always legal.
//! * **Chaos stays above the boundary** — injection decisions are made
//!   once, at the send site in `RankCtx::post`, before the transport is
//!   involved; the wire backends ship the decision in the frame's `kind`
//!   byte. Seeds, XOR schedule digests and trace invariants are therefore
//!   backend-independent by construction — the property the cross-backend
//!   differential tests (`tests/backend_matrix.rs`) hold every backend to.
//! * **Poison wakes everyone** — [`Transport::poison_all`] must make
//!   every in-flight and future [`Transport::take`] return `None`
//!   promptly (the caller disambiguates death from deadline via the
//!   dead-rank registry).
//! * **Buffer lease** — the posted [`Msg`] owns a pooled buffer leased
//!   from the *sender's* pool. The thread backend moves the lease to the
//!   receiver (dropping the received message recycles the buffer into the
//!   sender's pool — the zero-allocation steady state). Wire backends end
//!   the lease at serialization time (the sender's buffer recycles
//!   immediately) and surface received payloads as detached buffers.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use super::elem::Elem;
use super::inbox::{Inbox, InboxStats};
use super::msg::Msg;
use super::recover::{TransportFault, TransportStats};
use super::wirefault::{WireFaultConfig, WireFaultReport};

/// Default send-side write watchdog for the socket backends — was a
/// hardcoded constant in `socket.rs`; now configurable per world via
/// [`WorldConfig::with_write_timeout`](super::world::WorldConfig::with_write_timeout).
pub const DEFAULT_WRITE_TIMEOUT: Duration = Duration::from_secs(10);

/// Backend-independent knobs threaded from `WorldConfig` into
/// [`build_transport`] — bundled so adding a knob does not ripple a new
/// parameter through every backend constructor.
#[derive(Debug, Clone)]
pub(crate) struct TransportTuning {
    /// Pin the inbox spin budget (disable the adaptive EMA).
    pub fixed_spin: bool,
    /// Send-side write watchdog for socket streams.
    pub write_timeout: Duration,
    /// Seeded wire-fault injection plan (None = clean wire).
    pub wirefault: Option<WireFaultConfig>,
}

impl Default for TransportTuning {
    fn default() -> Self {
        TransportTuning {
            fixed_spin: false,
            write_timeout: DEFAULT_WRITE_TIMEOUT,
            wirefault: None,
        }
    }
}

/// Which rendezvous backend a world's ranks communicate through.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TransportBackend {
    /// In-process slot inboxes (the default, and the oracle the other
    /// backends are differentially verified against).
    #[default]
    Thread,
    /// Shared-memory rings over a `MAP_SHARED` mmap'd segment (unix).
    Shm,
    /// TCP loopback streams with framed messages.
    Tcp,
    /// Unix-domain stream pairs with framed messages (unix).
    Uds,
}

impl TransportBackend {
    /// Every selectable backend, in CLI presentation order.
    pub fn all() -> [TransportBackend; 4] {
        [
            TransportBackend::Thread,
            TransportBackend::Shm,
            TransportBackend::Tcp,
            TransportBackend::Uds,
        ]
    }

    pub fn name(&self) -> &'static str {
        match self {
            TransportBackend::Thread => "thread",
            TransportBackend::Shm => "shm",
            TransportBackend::Tcp => "tcp",
            TransportBackend::Uds => "uds",
        }
    }

    /// Cheap host-capability check with an attributed error: names the
    /// backend and the reason it cannot run here. `Ok(())` means a world
    /// over this backend can be constructed on this host right now.
    pub fn probe(&self) -> Result<()> {
        match self {
            TransportBackend::Thread => Ok(()),
            TransportBackend::Shm => super::shm::probe(),
            TransportBackend::Tcp => match std::net::TcpListener::bind("127.0.0.1:0") {
                Ok(_) => Ok(()),
                Err(e) => bail!(
                    "transport backend 'tcp' unavailable: cannot bind a loopback listener: {e}"
                ),
            },
            #[cfg(unix)]
            TransportBackend::Uds => match std::os::unix::net::UnixStream::pair() {
                Ok(_) => Ok(()),
                Err(e) => {
                    bail!("transport backend 'uds' unavailable: cannot create a socket pair: {e}")
                }
            },
            #[cfg(not(unix))]
            TransportBackend::Uds => {
                bail!("transport backend 'uds' unavailable: unix-domain sockets need a unix host")
            }
        }
    }

    pub fn is_available(&self) -> bool {
        self.probe().is_ok()
    }

    /// The backends that probe as usable on this host, thread first.
    pub fn available() -> Vec<TransportBackend> {
        Self::all().into_iter().filter(|b| b.is_available()).collect()
    }
}

impl std::fmt::Display for TransportBackend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for TransportBackend {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "thread" => Ok(TransportBackend::Thread),
            "shm" => Ok(TransportBackend::Shm),
            "tcp" => Ok(TransportBackend::Tcp),
            "uds" => Ok(TransportBackend::Uds),
            other => bail!("unknown transport backend {other:?} (expected thread|shm|tcp|uds)"),
        }
    }
}

/// The rendezvous operations a world needs from its message substrate.
/// All ranks share one transport instance; `me` is always the calling
/// rank (receive-side operations are single-consumer per rank — the
/// executor pins one thread per rank, which the shm ring relies on).
pub(crate) trait Transport<T: Elem>: Send + Sync {
    /// Deliver `msg` toward rank `to`'s matcher (normal path).
    fn post(&self, to: usize, msg: Msg<T>);

    /// Chaos embargo: hold `msg` until `release_at`, then make it
    /// matchable at rank `to` (delivery order across keys may invert).
    fn post_delayed(&self, to: usize, msg: Msg<T>, release_at: Instant);

    /// Chaos slot diversion: deliver via rank `to`'s unordered overflow
    /// path, bypassing the keyed slot.
    fn post_overflow(&self, to: usize, msg: Msg<T>);

    /// Blocking matched receive on rank `me` for (src, tag). Non-matching
    /// arrivals go to `pending` (the caller's rank-private out-of-order
    /// buffer, which the caller scans before calling). Returns `None` on
    /// deadline expiry or poison wake-up — the caller disambiguates and
    /// may re-enter with the remaining deadline.
    fn take(
        &self,
        me: usize,
        src: usize,
        tag: u64,
        pending: &mut Vec<Msg<T>>,
        deadline: Instant,
    ) -> Option<Msg<T>>;

    /// Rank-death wake: force every blocked and future [`take`](Self::take)
    /// on every rank to return `None` promptly.
    fn poison_all(&self);

    /// Receive-side spin/park counters for rank `me`.
    fn stats(&self, me: usize) -> InboxStats;

    /// Whole-transport recovery/fault counters (retransmits, reconnects,
    /// suppressed duplicates, fatal faults). The thread backend has no
    /// wire and reports zeros.
    fn wire_stats(&self) -> TransportStats {
        TransportStats::default()
    }

    /// First fatal typed transport fault, if one was raised. The rank
    /// context polls this after a poisoned `take` to attribute the
    /// failure (`RankFailed`) instead of a bare deadline error.
    fn fault(&self) -> Option<TransportFault> {
        None
    }

    /// Wire-fault injection report, when this transport runs with a
    /// seeded fault plan armed.
    fn wire_report(&self) -> Option<WireFaultReport> {
        None
    }

    /// Backend name for attributed errors ("thread" | "shm" | "tcp" | "uds").
    fn name(&self) -> &'static str;
}

/// The extracted in-process backend: one slot [`Inbox`] per rank, all
/// operations delegated verbatim. Zero behavior change from the
/// pre-trait transport — the adaptive-spin/EMA machinery, overflow and
/// embargo queues, poison epochs and Dekker park handshake live in
/// [`inbox`](super::inbox) untouched.
pub(crate) struct ThreadTransport<T> {
    inboxes: Vec<Inbox<T>>,
}

impl<T> ThreadTransport<T> {
    pub fn new(p: usize, fixed_spin: bool) -> Self {
        ThreadTransport { inboxes: (0..p).map(|_| Inbox::new_with(fixed_spin)).collect() }
    }
}

impl<T: Elem> Transport<T> for ThreadTransport<T> {
    fn post(&self, to: usize, msg: Msg<T>) {
        self.inboxes[to].deposit(msg);
    }

    fn post_delayed(&self, to: usize, msg: Msg<T>, release_at: Instant) {
        self.inboxes[to].deposit_delayed(msg, release_at);
    }

    fn post_overflow(&self, to: usize, msg: Msg<T>) {
        self.inboxes[to].deposit_overflow(msg);
    }

    fn take(
        &self,
        me: usize,
        src: usize,
        tag: u64,
        pending: &mut Vec<Msg<T>>,
        deadline: Instant,
    ) -> Option<Msg<T>> {
        self.inboxes[me].recv_match(src, tag, pending, deadline)
    }

    fn poison_all(&self) {
        for inbox in &self.inboxes {
            inbox.poison();
        }
    }

    fn stats(&self, me: usize) -> InboxStats {
        self.inboxes[me].stats()
    }

    fn name(&self) -> &'static str {
        "thread"
    }
}

/// Construct the selected backend for a `p`-rank world, or fail with an
/// attributed error naming the backend and the host-side reason.
pub(crate) fn build_transport<T: Elem>(
    backend: TransportBackend,
    p: usize,
    tuning: &TransportTuning,
) -> Result<Arc<dyn Transport<T>>> {
    match backend {
        TransportBackend::Thread => Ok(Arc::new(ThreadTransport::new(p, tuning.fixed_spin))),
        TransportBackend::Shm => {
            Ok(Arc::new(super::shm::ShmTransport::new(p, tuning)?))
        }
        TransportBackend::Tcp | TransportBackend::Uds => Ok(Arc::new(
            super::socket::SocketTransport::new(backend, p, tuning)?,
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_parse_and_names() {
        for b in TransportBackend::all() {
            assert_eq!(b.name().parse::<TransportBackend>().unwrap(), b);
            assert_eq!(format!("{b}"), b.name());
        }
        let err = "rdma".parse::<TransportBackend>().unwrap_err();
        assert!(format!("{err:#}").contains("thread|shm|tcp|uds"));
    }

    #[test]
    fn thread_backend_always_probes_available() {
        assert!(TransportBackend::Thread.is_available());
        assert!(TransportBackend::available().contains(&TransportBackend::Thread));
    }
}
