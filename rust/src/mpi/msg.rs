//! In-flight message envelope used by both transports.

use super::pool::PoolBuf;

/// A typed point-to-point message. `tag` is the communication-round index
/// of the sending algorithm — matching on it enforces the round structure
/// (a message sent in round k can only satisfy a round-k receive).
///
/// `data` is a pool-owned buffer acquired from the *sender's* rank pool;
/// dropping the message (or the `PoolBuf` handed out by `recv_owned`)
/// recycles it, so steady-state rounds never touch the allocator.
#[derive(Debug)]
pub(crate) struct Msg<T> {
    pub src: usize,
    pub tag: u64,
    pub data: PoolBuf<T>,
    /// Sender's virtual clock at the instant of sending (virtual mode;
    /// 0.0 in real mode).
    pub vtime: f64,
}
