//! In-flight message envelope used by every transport backend: thread
//! inboxes carry it directly; shm and socket backends serialize it into a
//! [`wire`](super::wire) frame and rebuild it on the receiving side.

use super::pool::PoolBuf;

/// A typed point-to-point message. `tag` is a packed
/// [`TagKey`](super::comm::TagKey) — `(ctx, chunk, round)` — not a bare
/// round index: `round` is the sending algorithm's communication-round
/// index (matching on it enforces the round structure — a message sent in
/// round k can only satisfy a round-k receive), `ctx` is the context id of
/// the communicator the collective runs on (0 for world-scope traffic), and
/// `chunk` is a wire-level sub-round lane id (the chunked pipeline tags
/// each chunk's lane; see [`ExscanChunked`](crate::coll::ExscanChunked)).
/// World-scope, lane-0 tags pack to exactly the bare round value, so
/// single-collective traffic is bit-compatible with the pre-communicator
/// transport.
///
/// `src` is always a **world** rank, even for communicator-scoped traffic
/// (the receiver resolves its communicator peer to a world rank before
/// matching).
///
/// `data` is a pool-owned buffer acquired from the *sender's* rank pool;
/// dropping the message (or the `PoolBuf` handed out by `recv_owned`)
/// recycles it, so steady-state rounds never touch the allocator.
#[derive(Debug)]
pub(crate) struct Msg<T> {
    pub src: usize,
    pub tag: u64,
    pub data: PoolBuf<T>,
    /// Sender's virtual clock at the instant of sending (virtual mode;
    /// 0.0 in real mode).
    pub vtime: f64,
}
