//! The PJRT executor: one dedicated thread owns the `PjRtClient` and the
//! compiled executables; a request channel serializes kernel launches.
//!
//! Why a thread: the `xla` crate's handles wrap raw PJRT pointers that are
//! not `Sync`, while our scan ranks run on many threads. A single executor
//! matches the deployment model anyway — one accelerator queue shared by
//! the node's ranks.

#[cfg(feature = "pjrt")]
use std::collections::HashMap;
use std::path::PathBuf;

use std::sync::Arc;

#[cfg(feature = "pjrt")]
use anyhow::{bail, Context};
use anyhow::{anyhow, Result};

use super::artifact::Manifest;
use crate::util::{Channel, OneShot};

/// How long a caller waits for the executor before declaring it dead.
const REPLY_TIMEOUT: std::time::Duration = std::time::Duration::from_secs(120);

/// Cumulative executor statistics.
#[derive(Debug, Clone, Copy, Default)]
pub struct RuntimeStats {
    pub launches: u64,
    pub elements: u64,
    pub compiles: u64,
}

enum Request {
    ReduceI64 {
        op: String,
        a: Vec<i64>,
        b: Vec<i64>,
        reply: Arc<OneShot<Result<Vec<i64>>>>,
    },
    ReduceF32 {
        op: String,
        /// Row width (1 for scalar ops, 6 for `matrec_f32`).
        width: usize,
        a: Vec<f32>,
        b: Vec<f32>,
        reply: Arc<OneShot<Result<Vec<f32>>>>,
    },
    BlockExscanI64 {
        op: String,
        k: usize,
        data: Vec<i64>,
        reply: Arc<OneShot<Result<Vec<i64>>>>,
    },
    Stats {
        reply: Arc<OneShot<RuntimeStats>>,
    },
}

/// Cloneable, thread-safe handle to the executor.
#[derive(Clone)]
pub struct PjrtHandle {
    tx: Arc<Channel<Request>>,
}

impl PjrtHandle {
    /// `inout = input ⊕ inout` through the compiled `reduce` kernel.
    /// (`input` is the earlier operand, matching `MPI_Reduce_local`.)
    pub fn reduce_i64(&self, op: &str, input: &[i64], inout: &mut [i64]) -> Result<()> {
        let reply = Arc::new(OneShot::new());
        self.tx
            .push(Request::ReduceI64 {
                op: op.to_string(),
                a: input.to_vec(),
                b: inout.to_vec(),
                reply: Arc::clone(&reply),
            })
            .map_err(|_| anyhow!("PJRT executor thread exited"))?;
        let out = reply
            .take_timeout(REPLY_TIMEOUT)
            .ok_or_else(|| anyhow!("PJRT executor reply timeout"))??;
        inout.copy_from_slice(&out[..inout.len()]);
        Ok(())
    }

    /// f32 variant; `width` is the per-element row width (6 for Rec2).
    pub fn reduce_f32(&self, op: &str, width: usize, input: &[f32], inout: &mut [f32]) -> Result<()> {
        let reply = Arc::new(OneShot::new());
        self.tx
            .push(Request::ReduceF32 {
                op: op.to_string(),
                width,
                a: input.to_vec(),
                b: inout.to_vec(),
                reply: Arc::clone(&reply),
            })
            .map_err(|_| anyhow!("PJRT executor thread exited"))?;
        let out = reply
            .take_timeout(REPLY_TIMEOUT)
            .ok_or_else(|| anyhow!("PJRT executor reply timeout"))??;
        inout.copy_from_slice(&out[..inout.len()]);
        Ok(())
    }

    /// Exclusive scan across the k rows of a (k, m) block — the fused
    /// Pallas kernel used by the hierarchical/node-leader path. `data` is
    /// row-major k×m; returns k×m where row j = ⊕ of rows 0..j (row 0 is
    /// returned as the operator's "empty" convention: all rows shifted,
    /// see the kernel docs).
    pub fn block_exscan_i64(&self, op: &str, k: usize, data: &[i64]) -> Result<Vec<i64>> {
        let reply = Arc::new(OneShot::new());
        self.tx
            .push(Request::BlockExscanI64 {
                op: op.to_string(),
                k,
                data: data.to_vec(),
                reply: Arc::clone(&reply),
            })
            .map_err(|_| anyhow!("PJRT executor thread exited"))?;
        reply
            .take_timeout(REPLY_TIMEOUT)
            .ok_or_else(|| anyhow!("PJRT executor reply timeout"))?
    }

    pub fn stats(&self) -> Result<RuntimeStats> {
        let reply = Arc::new(OneShot::new());
        self.tx
            .push(Request::Stats { reply: Arc::clone(&reply) })
            .map_err(|_| anyhow!("PJRT executor thread exited"))?;
        reply
            .take_timeout(REPLY_TIMEOUT)
            .ok_or_else(|| anyhow!("PJRT executor reply timeout"))
    }
}

/// The executor factory. Owns nothing after start: the worker thread keeps
/// the client alive as long as any [`PjrtHandle`] exists.
pub struct PjrtRuntime;

impl PjrtRuntime {
    /// Start an executor over the given artifacts directory.
    ///
    /// Without the `pjrt` cargo feature (the offline default — the `xla`
    /// crate cannot be fetched without a registry) this returns a clear
    /// error instead of an executor; [`try_default`](Self::try_default)
    /// returns `None` so tests and examples skip gracefully.
    #[cfg(not(feature = "pjrt"))]
    pub fn start(dir: impl Into<PathBuf>) -> Result<PjrtHandle> {
        let _ = Manifest::load(dir.into())?;
        anyhow::bail!(
            "exscan was built without the `pjrt` feature; rebuild with \
             `--features pjrt` (requires the xla crate) to run compiled kernels"
        )
    }

    /// Start an executor over the given artifacts directory.
    #[cfg(feature = "pjrt")]
    pub fn start(dir: impl Into<PathBuf>) -> Result<PjrtHandle> {
        let manifest = Manifest::load(dir.into())?;
        let tx: Arc<Channel<Request>> = Arc::new(Channel::new());
        let rx = Arc::clone(&tx);
        let init: Arc<OneShot<Result<()>>> = Arc::new(OneShot::new());
        let init_w = Arc::clone(&init);
        std::thread::Builder::new()
            .name("pjrt-executor".into())
            .spawn(move || {
                let mut worker = match Worker::new(manifest) {
                    Ok(w) => {
                        init_w.put(Ok(()));
                        w
                    }
                    Err(e) => {
                        init_w.put(Err(e));
                        return;
                    }
                };
                // The executor lives for the process: requests arrive from
                // any rank at any time; an idle wait just re-polls.
                loop {
                    if let Some(req) = rx.pop_timeout(std::time::Duration::from_secs(3600)) {
                        worker.handle(req);
                    }
                }
            })
            .expect("spawn pjrt-executor");
        init.take_timeout(REPLY_TIMEOUT)
            .ok_or_else(|| anyhow!("PJRT executor died during init"))??;
        Ok(PjrtHandle { tx })
    }

    /// Start from the default artifacts directory; `None` if the artifacts
    /// have not been built (lets tests skip gracefully).
    pub fn try_default() -> Option<PjrtHandle> {
        let dir = Manifest::default_dir();
        if !dir.join("manifest.tsv").exists() {
            return None;
        }
        PjrtRuntime::start(dir).ok()
    }
}

#[cfg(feature = "pjrt")]
struct Worker {
    manifest: Manifest,
    client: xla::PjRtClient,
    /// artifact name -> compiled executable.
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
    stats: RuntimeStats,
}

#[cfg(feature = "pjrt")]
impl Worker {
    fn new(manifest: Manifest) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Worker { manifest, client, cache: HashMap::new(), stats: RuntimeStats::default() })
    }

    fn executable(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(name) {
            let entry = self
                .manifest
                .artifacts
                .iter()
                .find(|e| e.name == name)
                .ok_or_else(|| anyhow!("artifact {name} not in manifest"))?
                .clone();
            let path = self.manifest.path_of(&entry);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 artifact path"))?,
            )
            .map_err(|e| anyhow!("loading {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
            self.stats.compiles += 1;
            self.cache.insert(name.to_string(), exe);
        }
        Ok(self.cache.get(name).unwrap())
    }

    fn handle(&mut self, req: Request) {
        match req {
            Request::ReduceI64 { op, a, b, reply } => {
                reply.put(self.reduce_i64(&op, a, b));
            }
            Request::ReduceF32 { op, width, a, b, reply } => {
                reply.put(self.reduce_f32(&op, width, a, b));
            }
            Request::BlockExscanI64 { op, k, data, reply } => {
                reply.put(self.block_exscan_i64(&op, k, data));
            }
            Request::Stats { reply } => {
                reply.put(self.stats);
            }
        }
    }

    fn reduce_i64(&mut self, op: &str, mut a: Vec<i64>, mut b: Vec<i64>) -> Result<Vec<i64>> {
        let n = a.len();
        if b.len() != n {
            bail!("reduce_i64: length mismatch {n} vs {}", b.len());
        }
        let entry = self
            .manifest
            .find_reduce(op, n)
            .ok_or_else(|| anyhow!("no reduce artifact for op={op} m>={n}"))?
            .clone();
        // Element-wise kernels are row-independent: zero padding is safe.
        a.resize(entry.m, 0);
        b.resize(entry.m, 0);
        let la = xla::Literal::vec1(&a);
        let lb = xla::Literal::vec1(&b);
        let exe = self.executable(&entry.name)?;
        let out = exe
            .execute::<xla::Literal>(&[la, lb])
            .map_err(|e| anyhow!("executing {}: {e:?}", entry.name))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result: {e:?}"))?;
        let tuple = out.to_tuple1().map_err(|e| anyhow!("untupling: {e:?}"))?;
        let mut v = tuple.to_vec::<i64>().map_err(|e| anyhow!("to_vec: {e:?}"))?;
        v.truncate(n);
        self.stats.launches += 1;
        self.stats.elements += n as u64;
        Ok(v)
    }

    fn reduce_f32(&mut self, op: &str, width: usize, mut a: Vec<f32>, mut b: Vec<f32>) -> Result<Vec<f32>> {
        let n = a.len();
        if b.len() != n || width == 0 || n % width != 0 {
            bail!("reduce_f32: bad shapes (n={n}, width={width})");
        }
        let rows = n / width;
        let entry = self
            .manifest
            .find_reduce(op, rows)
            .ok_or_else(|| anyhow!("no reduce artifact for op={op} rows>={rows}"))?
            .clone();
        a.resize(entry.m * width, 0.0);
        b.resize(entry.m * width, 0.0);
        let (la, lb) = if width == 1 {
            (xla::Literal::vec1(&a), xla::Literal::vec1(&b))
        } else {
            (
                xla::Literal::vec1(&a)
                    .reshape(&[entry.m as i64, width as i64])
                    .map_err(|e| anyhow!("reshape: {e:?}"))?,
                xla::Literal::vec1(&b)
                    .reshape(&[entry.m as i64, width as i64])
                    .map_err(|e| anyhow!("reshape: {e:?}"))?,
            )
        };
        let exe = self.executable(&entry.name)?;
        let out = exe
            .execute::<xla::Literal>(&[la, lb])
            .map_err(|e| anyhow!("executing {}: {e:?}", entry.name))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result: {e:?}"))?;
        let tuple = out.to_tuple1().map_err(|e| anyhow!("untupling: {e:?}"))?;
        let mut v = tuple.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))?;
        v.truncate(n);
        self.stats.launches += 1;
        self.stats.elements += rows as u64;
        Ok(v)
    }

    fn block_exscan_i64(&mut self, op: &str, k: usize, data: Vec<i64>) -> Result<Vec<i64>> {
        if k == 0 || data.len() % k != 0 {
            bail!("block_exscan: data not divisible into k={k} rows");
        }
        let m = data.len() / k;
        let entry = self
            .manifest
            .find_block_exscan(op, k, m)
            .ok_or_else(|| anyhow!("no block_exscan artifact for op={op} k={k} m>={m}"))?
            .clone();
        // Pad each row to entry.m columns.
        let mut padded = vec![0i64; k * entry.m];
        for row in 0..k {
            padded[row * entry.m..row * entry.m + m]
                .copy_from_slice(&data[row * m..(row + 1) * m]);
        }
        let lit = xla::Literal::vec1(&padded)
            .reshape(&[k as i64, entry.m as i64])
            .map_err(|e| anyhow!("reshape: {e:?}"))?;
        let exe = self.executable(&entry.name)?;
        let out = exe
            .execute::<xla::Literal>(&[lit])
            .map_err(|e| anyhow!("executing {}: {e:?}", entry.name))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result: {e:?}"))?;
        let tuple = out.to_tuple1().map_err(|e| anyhow!("untupling: {e:?}"))?;
        let v = tuple.to_vec::<i64>().map_err(|e| anyhow!("to_vec: {e:?}"))?;
        let mut result = vec![0i64; k * m];
        for row in 0..k {
            result[row * m..(row + 1) * m]
                .copy_from_slice(&v[row * entry.m..row * entry.m + m]);
        }
        self.stats.launches += 1;
        self.stats.elements += (k * m) as u64;
        Ok(result)
    }
}
