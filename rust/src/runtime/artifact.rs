//! Artifact manifest: what `make artifacts` produced and how to use it.
//!
//! The manifest is a simple line-based TSV file (`manifest.tsv`) written by
//! `python/compile/aot.py` — this offline build carries no JSON dependency,
//! and a fixed-column format keeps both producers honest:
//!
//! ```text
//! exscan-artifacts v1 jax=<version>
//! <name>\t<kind>\t<op>\t<dtype>\t<m>\t<k>\t<file>
//! ```

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// One AOT-compiled kernel artifact (an HLO-text file).
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    /// Unique name, e.g. `reduce_bxor_i64_m4096`.
    pub name: String,
    /// Kernel kind: `reduce` (element-wise ⊕) or `block_exscan`.
    pub kind: String,
    /// Operator name matching [`crate::mpi::CombineOp::name`]
    /// (`bxor_i64`, `sum_f32`, `matrec_f32`, …).
    pub op: String,
    /// Element dtype as named by `Dtype::name`.
    pub dtype: String,
    /// Padded element count the kernel was compiled for.
    pub m: usize,
    /// Extra leading dimension for `block_exscan` kernels (ranks per
    /// block); 0 for plain reduce kernels.
    pub k: usize,
    /// File name within the artifacts directory.
    pub file: String,
}

/// The manifest written by `python/compile/aot.py`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub jax_version: String,
    pub artifacts: Vec<ArtifactEntry>,
    pub dir: PathBuf,
}

impl Manifest {
    /// Parse the manifest text format.
    pub fn parse(text: &str, dir: PathBuf) -> Result<Self> {
        let mut lines = text.lines();
        let header = lines.next().unwrap_or("");
        let mut parts = header.split_whitespace();
        if parts.next() != Some("exscan-artifacts") || parts.next() != Some("v1") {
            bail!("bad manifest header: {header:?}");
        }
        let jax_version = parts
            .next()
            .and_then(|s| s.strip_prefix("jax="))
            .unwrap_or("")
            .to_string();
        let mut artifacts = Vec::new();
        for (i, line) in lines.enumerate() {
            if line.trim().is_empty() || line.starts_with('#') {
                continue;
            }
            let cols: Vec<&str> = line.split('\t').collect();
            if cols.len() != 7 {
                bail!("manifest line {} has {} columns, want 7: {line:?}", i + 2, cols.len());
            }
            artifacts.push(ArtifactEntry {
                name: cols[0].to_string(),
                kind: cols[1].to_string(),
                op: cols[2].to_string(),
                dtype: cols[3].to_string(),
                m: cols[4].parse().with_context(|| format!("bad m on line {}", i + 2))?,
                k: cols[5].parse().with_context(|| format!("bad k on line {}", i + 2))?,
                file: cols[6].to_string(),
            });
        }
        Ok(Manifest { jax_version, artifacts, dir })
    }

    /// Load `<dir>/manifest.tsv`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref();
        let path = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts` first)", path.display()))?;
        Self::parse(&text, dir.to_path_buf())
    }

    /// Default artifacts directory: `$EXSCAN_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("EXSCAN_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    /// True when a manifest exists in the default directory.
    pub fn default_available() -> bool {
        Self::default_dir().join("manifest.tsv").exists()
    }

    /// Absolute path of an entry's HLO file.
    pub fn path_of(&self, e: &ArtifactEntry) -> PathBuf {
        self.dir.join(&e.file)
    }

    /// Smallest `reduce` artifact for `op` that fits `m` elements.
    pub fn find_reduce(&self, op: &str, m: usize) -> Option<&ArtifactEntry> {
        self.artifacts
            .iter()
            .filter(|e| e.kind == "reduce" && e.op == op && e.m >= m)
            .min_by_key(|e| e.m)
    }

    /// The block-exscan artifact for `op` with `k` rows fitting `m`.
    pub fn find_block_exscan(&self, op: &str, k: usize, m: usize) -> Option<&ArtifactEntry> {
        self.artifacts
            .iter()
            .filter(|e| e.kind == "block_exscan" && e.op == op && e.k == k && e.m >= m)
            .min_by_key(|e| e.m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "exscan-artifacts v1 jax=0.8.2\n\
        reduce_bxor_i64_m256\treduce\tbxor_i64\ti64\t256\t0\treduce_bxor_i64_m256.hlo.txt\n\
        reduce_bxor_i64_m4096\treduce\tbxor_i64\ti64\t4096\t0\treduce_bxor_i64_m4096.hlo.txt\n\
        reduce_sum_f32_m256\treduce\tsum_f32\tf32\t256\t0\treduce_sum_f32_m256.hlo.txt\n\
        block_exscan_bxor_i64_k32_m256\tblock_exscan\tbxor_i64\ti64\t256\t32\tblock.hlo.txt\n";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, "/tmp".into()).unwrap();
        assert_eq!(m.jax_version, "0.8.2");
        assert_eq!(m.artifacts.len(), 4);
        assert_eq!(m.artifacts[1].m, 4096);
    }

    #[test]
    fn find_reduce_smallest_fit() {
        let m = Manifest::parse(SAMPLE, "/tmp".into()).unwrap();
        assert_eq!(m.find_reduce("bxor_i64", 100).unwrap().m, 256);
        assert_eq!(m.find_reduce("bxor_i64", 257).unwrap().m, 4096);
        assert!(m.find_reduce("bxor_i64", 5000).is_none());
        assert!(m.find_reduce("nope", 1).is_none());
    }

    #[test]
    fn find_block_exscan_needs_matching_k() {
        let m = Manifest::parse(SAMPLE, "/tmp".into()).unwrap();
        assert!(m.find_block_exscan("bxor_i64", 32, 100).is_some());
        assert!(m.find_block_exscan("bxor_i64", 16, 100).is_none());
    }

    #[test]
    fn bad_header_rejected() {
        assert!(Manifest::parse("nope v2\n", "/tmp".into()).is_err());
    }

    #[test]
    fn bad_column_count_rejected() {
        let text = "exscan-artifacts v1 jax=x\nonly\tthree\tcols\n";
        assert!(Manifest::parse(text, "/tmp".into()).is_err());
    }

    #[test]
    fn missing_dir_is_error() {
        assert!(Manifest::load("/definitely/not/here").is_err());
    }
}
