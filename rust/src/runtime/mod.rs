//! The PJRT runtime bridge: loads AOT-compiled HLO artifacts produced by
//! `python/compile/aot.py` (Layer 1/2 Pallas + JAX kernels, lowered once at
//! build time) and executes them from the Rust hot path. Python is never
//! on the request path — the artifacts directory is the only interface.
//!
//! * [`artifact`] — the manifest format (`artifacts/manifest.json`) and
//!   artifact discovery.
//! * [`client`] — a dedicated executor thread owning the `PjRtClient` and
//!   the compiled executables (the `xla` crate's handles are not `Sync`;
//!   a single-consumer request channel serializes kernel launches, which
//!   also models the single accelerator queue).
//! * [`pjrt_op`] — [`CombineOp`](crate::mpi::CombineOp) adapters so a
//!   compiled kernel can serve as the ⊕ operator of any scan algorithm.

pub mod artifact;
pub mod client;
pub mod pjrt_op;

pub use artifact::{ArtifactEntry, Manifest};
pub use client::{PjrtHandle, PjrtRuntime};
pub use pjrt_op::{pjrt_bxor_i64, pjrt_rec2_compose, pjrt_sum_f32, PjrtOp};
