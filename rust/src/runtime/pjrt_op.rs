//! [`CombineOp`] adapters over the PJRT executor: the AOT-compiled Pallas
//! `reduce_local` kernels as first-class ⊕ operators for any scan
//! algorithm. This is the "expensive, user-defined MPI operator" path the
//! paper's ⊕-count analysis is about — every application is a real kernel
//! launch, so an algorithm that does `2⌈log₂p⌉−1` of them instead of `q−1`
//! pays measurably.

use crate::mpi::{CombineOp, Rec2};

use super::client::PjrtHandle;

/// A compiled-kernel operator. `T`-specific constructors below.
pub struct PjrtOp {
    handle: PjrtHandle,
    op: &'static str,
    commutative: bool,
}

/// BXOR over i64 through the compiled kernel (the paper's benchmark op).
pub fn pjrt_bxor_i64(handle: PjrtHandle) -> crate::mpi::OpRef<i64> {
    crate::mpi::OpRef::new(std::sync::Arc::new(PjrtOp {
        handle,
        op: "bxor_i64",
        commutative: true,
    }))
}

/// Float sum through the compiled kernel.
pub fn pjrt_sum_f32(handle: PjrtHandle) -> crate::mpi::OpRef<f32> {
    crate::mpi::OpRef::new(std::sync::Arc::new(PjrtOp {
        handle,
        op: "sum_f32",
        commutative: true,
    }))
}

/// Affine 2×2 recurrence composition through the compiled kernel
/// (non-commutative; the expensive-⊕ ablation operator).
pub fn pjrt_rec2_compose(handle: PjrtHandle) -> crate::mpi::OpRef<Rec2> {
    crate::mpi::OpRef::new(std::sync::Arc::new(PjrtOp {
        handle,
        op: "matrec_f32",
        commutative: false,
    }))
}

impl CombineOp<i64> for PjrtOp {
    fn name(&self) -> &str {
        self.op
    }

    fn combine(&self, input: &[i64], inout: &mut [i64]) {
        self.handle
            .reduce_i64(self.op, input, inout)
            .expect("PJRT reduce_local kernel failed");
    }

    fn commutative(&self) -> bool {
        self.commutative
    }
}

impl CombineOp<f32> for PjrtOp {
    fn name(&self) -> &str {
        self.op
    }

    fn combine(&self, input: &[f32], inout: &mut [f32]) {
        self.handle
            .reduce_f32(self.op, 1, input, inout)
            .expect("PJRT reduce_local kernel failed");
    }

    fn commutative(&self) -> bool {
        self.commutative
    }
}

impl CombineOp<Rec2> for PjrtOp {
    fn name(&self) -> &str {
        self.op
    }

    fn combine(&self, input: &[Rec2], inout: &mut [Rec2]) {
        // Flatten (A, b) to 6 f32 per element, row-major.
        let flat = |xs: &[Rec2]| -> Vec<f32> {
            let mut v = Vec::with_capacity(xs.len() * 6);
            for e in xs {
                v.extend_from_slice(&e.a);
                v.extend_from_slice(&e.b);
            }
            v
        };
        let fin = flat(input);
        let mut fio = flat(inout);
        self.handle
            .reduce_f32(self.op, 6, &fin, &mut fio)
            .expect("PJRT matrec kernel failed");
        for (e, chunk) in inout.iter_mut().zip(fio.chunks_exact(6)) {
            e.a.copy_from_slice(&chunk[..4]);
            e.b.copy_from_slice(&chunk[4..]);
        }
    }

    fn commutative(&self) -> bool {
        self.commutative
    }
}
