//! Topology model: node grouping plus a **seeded synthetic per-link α-β
//! matrix** the virtual clock consults for per-hop costs.
//!
//! The flat α-β-γ model ([`crate::cost`]) prices every hop by its *link
//! class* (intra- vs inter-node) only. Real clusters are messier: links
//! jitter around the class mean, and hierarchy is the whole reason a
//! two-level scheme can win. [`Topo`] makes placement a first-class,
//! deterministic input:
//!
//! * **grouping** — `nodes × ranks_per_node` block placement (`node =
//!   rank / ranks_per_node`, the MPI default), same convention as
//!   [`crate::mpi::Topology`] (which stays the *executor's* shape; `Topo`
//!   is the *cost* shape layered on top of it).
//! * **per-link matrix** — a full `p × p` α (latency, µs) and β (inverse
//!   bandwidth, µs/byte) matrix, generated from class base parameters
//!   plus a seeded ±jitter per link. Same seed → bit-identical matrix,
//!   by construction (one fixed-order [`Rng`] stream), so topology wins
//!   measured on the virtual clock are replayable.
//! * **presets** — [`Topo::flat`] (uniform: every distinct-rank link at
//!   the inter-class base, the null hypothesis where hierarchy-aware
//!   schemes must *not* win), [`Topo::two_level`] (strongly hierarchical:
//!   cheap intra-node links, expensive inter-node links), and
//!   [`Topo::paper_36x1`] (36 single-rank nodes with the α-β parameters
//!   fitted from the paper's Table 1).
//!
//! The virtual clock integration is one hook: [`crate::cost::CostModel`]
//! holds an optional `Arc<Topo>` and, when present, prices each
//! `round_cost(from, to, bytes)` off this matrix instead of the class
//! parameters. `WorldConfig::virtual_clock_topo` installs it; nothing in
//! `mpi/ctx.rs` changes (accounting already passes world ranks).

use anyhow::{bail, Result};

use crate::cost::{CostParams, LinkClass};
use crate::util::Rng;

/// Fractional ±jitter applied per link around the class base parameters.
/// Small enough that class means still predict selection reliably, large
/// enough that the matrix is a genuine per-link surface (and the
/// determinism tests have real bits to compare).
pub const LINK_JITTER: f64 = 0.05;

/// A concrete cluster: block node grouping plus the seeded per-link α-β
/// matrix. Construct via the presets or [`Topo::parse`]; the matrix is
/// fully determined by `(preset shape, seed)`.
#[derive(Debug, Clone)]
pub struct Topo {
    /// Human-readable preset spec (`"flat:36"`, `"2level:4x9"`, …).
    name: String,
    nodes: usize,
    ranks_per_node: usize,
    seed: u64,
    /// Class base parameters the per-link values jitter around (also the
    /// γ / overhead source — those are machine-wide, not per-link).
    base: CostParams,
    /// Row-major `p × p` per-link latency (µs); diagonal is 0.
    alpha: Vec<f64>,
    /// Row-major `p × p` per-link inverse bandwidth (µs/byte); diagonal 0.
    beta: Vec<f64>,
}

impl Topo {
    /// Build a topology from class base parameters: every off-diagonal
    /// link gets its class base (intra or inter by block placement)
    /// scaled by a seeded jitter in `[1 - LINK_JITTER, 1 + LINK_JITTER)`.
    /// Links are generated in fixed row-major order from one
    /// `Rng::seed_from_u64(seed)` stream, so the matrix is bit-identical
    /// across runs and hosts for the same `(shape, base, seed)`.
    pub fn from_params(
        name: impl Into<String>,
        nodes: usize,
        ranks_per_node: usize,
        base: CostParams,
        seed: u64,
    ) -> Self {
        assert!(nodes >= 1 && ranks_per_node >= 1);
        let p = nodes * ranks_per_node;
        let mut rng = Rng::seed_from_u64(seed);
        let mut alpha = vec![0.0; p * p];
        let mut beta = vec![0.0; p * p];
        for from in 0..p {
            for to in 0..p {
                if from == to {
                    continue; // self-loop: free, as in the flat model
                }
                // Two draws per link, always in (alpha, beta) order, so
                // the stream layout is part of the determinism contract.
                let ja = 1.0 + LINK_JITTER * (2.0 * rng.gen_f64() - 1.0);
                let jb = 1.0 + LINK_JITTER * (2.0 * rng.gen_f64() - 1.0);
                let class = link_class(from, to, ranks_per_node);
                alpha[from * p + to] = base.alpha(class) * ja;
                beta[from * p + to] = base.beta(class) * jb;
            }
        }
        Topo { name: name.into(), nodes, ranks_per_node, seed, base, alpha, beta }
    }

    /// Uniform (non-hierarchical) cluster of `p` ranks: every
    /// distinct-rank link at the *inter*-node base. The null-hypothesis
    /// preset: on this matrix the two-level scheme must never win.
    pub fn flat(p: usize, seed: u64) -> Self {
        assert!(p >= 1);
        let base = CostParams {
            alpha_intra: UNIFORM_ALPHA,
            alpha_inter: UNIFORM_ALPHA,
            beta_intra: UNIFORM_BETA,
            beta_inter: UNIFORM_BETA,
            gamma: SYNTH_GAMMA,
            overhead: SYNTH_OVERHEAD,
        };
        // nodes = 1: every link classifies intra, but intra == inter here
        // so the classes are indistinguishable — genuinely uniform.
        Topo::from_params(format!("flat:{p}"), 1, p, base, seed)
    }

    /// Strongly hierarchical `nodes × ppn` cluster: cheap intra-node
    /// links, ~20× more expensive inter-node links (the regime
    /// EXPERIMENTS.md §Topology targets, past the hierarchical-exscan
    /// crossover).
    pub fn two_level(nodes: usize, ppn: usize, seed: u64) -> Self {
        let base = CostParams {
            alpha_intra: HIER_ALPHA_INTRA,
            alpha_inter: HIER_ALPHA_INTER,
            beta_intra: HIER_BETA_INTRA,
            beta_inter: HIER_BETA_INTER,
            gamma: SYNTH_GAMMA,
            overhead: SYNTH_OVERHEAD,
        };
        Topo::from_params(format!("2level:{nodes}x{ppn}"), nodes, ppn, base, seed)
    }

    /// The paper's 36×1 cluster with α-β-γ fitted from Table 1 (every
    /// distinct-rank link is inter-node — one MPI process per node).
    pub fn paper_36x1(seed: u64) -> Self {
        Topo::from_params("paper36x1", 36, 1, CostParams::paper_36x1(), seed)
    }

    /// Parse a CLI topology spec: `flat:P`, `2level:NxK`, or `paper36x1`.
    pub fn parse(spec: &str, seed: u64) -> Result<Self> {
        if spec == "paper36x1" {
            return Ok(Topo::paper_36x1(seed));
        }
        if let Some(p) = spec.strip_prefix("flat:") {
            let p: usize = p.parse().map_err(|_| bad_spec(spec))?;
            if p < 1 {
                return Err(bad_spec(spec));
            }
            return Ok(Topo::flat(p, seed));
        }
        if let Some(shape) = spec.strip_prefix("2level:") {
            let (n, k) = shape.split_once('x').ok_or_else(|| bad_spec(spec))?;
            let n: usize = n.parse().map_err(|_| bad_spec(spec))?;
            let k: usize = k.parse().map_err(|_| bad_spec(spec))?;
            if n < 1 || k < 1 {
                return Err(bad_spec(spec));
            }
            return Ok(Topo::two_level(n, k, seed));
        }
        Err(bad_spec(spec))
    }

    /// The hierarchical preset list the `topo_sweep` bench section gates
    /// on (the flat null-hypothesis preset is added by the caller).
    pub fn hierarchical_presets(seed: u64) -> Vec<Topo> {
        vec![
            Topo::two_level(4, 9, seed),
            Topo::two_level(4, 8, seed),
            Topo::two_level(6, 6, seed),
        ]
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn nodes(&self) -> usize {
        self.nodes
    }

    pub fn ranks_per_node(&self) -> usize {
        self.ranks_per_node
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Total rank count `p`.
    pub fn size(&self) -> usize {
        self.nodes * self.ranks_per_node
    }

    /// Whether the matrix actually distinguishes link classes (false for
    /// [`Topo::flat`], where intra and inter bases coincide).
    pub fn is_hierarchical(&self) -> bool {
        self.nodes > 1
            && self.ranks_per_node > 1
            && (self.base.alpha_intra != self.base.alpha_inter
                || self.base.beta_intra != self.base.beta_inter)
    }

    /// Node of a rank (block placement).
    pub fn node_of(&self, rank: usize) -> usize {
        rank / self.ranks_per_node
    }

    /// Class of the `from → to` link under this topology's grouping.
    pub fn link(&self, from: usize, to: usize) -> LinkClass {
        link_class(from, to, self.ranks_per_node)
    }

    /// Per-link latency (µs); 0 on the diagonal.
    pub fn alpha(&self, from: usize, to: usize) -> f64 {
        self.alpha[from * self.size() + to]
    }

    /// Per-link inverse bandwidth (µs/byte); 0 on the diagonal.
    pub fn beta(&self, from: usize, to: usize) -> f64 {
        self.beta[from * self.size() + to]
    }

    /// Machine-wide ⊕ cost (µs/byte).
    pub fn gamma(&self) -> f64 {
        self.base.gamma
    }

    /// Machine-wide per-collective overhead (µs).
    pub fn overhead(&self) -> f64 {
        self.base.overhead
    }

    /// The class base parameters the links jitter around (class-mean view
    /// of this matrix — what the flat predictor and the calibration
    /// satellite compare against).
    pub fn class_params(&self) -> CostParams {
        self.base
    }

    /// One `from → to` hop priced off the matrix.
    pub fn hop_cost(&self, from: usize, to: usize, bytes: usize) -> f64 {
        self.alpha(from, to) + bytes as f64 * self.beta(from, to)
    }

    /// FNV-1a digest over the exact bit patterns of both matrices — the
    /// determinism fingerprint (same seed ⇒ same digest, different seed ⇒
    /// different digest with overwhelming probability).
    pub fn matrix_digest(&self) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        let mut mix = |v: f64| {
            for b in v.to_bits().to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x100_0000_01b3);
            }
        };
        for &v in &self.alpha {
            mix(v);
        }
        for &v in &self.beta {
            mix(v);
        }
        h
    }
}

/// Block-placement link classification shared with the flat cost model.
fn link_class(from: usize, to: usize, ranks_per_node: usize) -> LinkClass {
    if from == to {
        LinkClass::SelfLoop
    } else if from / ranks_per_node == to / ranks_per_node {
        LinkClass::IntraNode
    } else {
        LinkClass::InterNode
    }
}

fn bad_spec(spec: &str) -> anyhow::Error {
    anyhow::anyhow!("bad topology spec '{spec}' (want flat:P, 2level:NxK, or paper36x1)")
}

// Synthetic base parameters (µs, µs/byte). The hierarchical set puts the
// inter/intra latency ratio at 25× — well past the ≈20× crossover where
// EXPERIMENTS.md §Perf shows hierarchy-aware schemes start winning — so
// the topo_sweep gates hold with margin even under ±5% link jitter.
const UNIFORM_ALPHA: f64 = 8.0;
const UNIFORM_BETA: f64 = 0.004;
const HIER_ALPHA_INTRA: f64 = 0.4;
const HIER_ALPHA_INTER: f64 = 10.0;
const HIER_BETA_INTRA: f64 = 0.001;
const HIER_BETA_INTER: f64 = 0.005;
const SYNTH_GAMMA: f64 = 0.0005;
const SYNTH_OVERHEAD: f64 = 1.0;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_seed_bit_identical_matrix() {
        let a = Topo::two_level(4, 9, 42);
        let b = Topo::two_level(4, 9, 42);
        assert_eq!(a.alpha, b.alpha);
        assert_eq!(a.beta, b.beta);
        assert_eq!(a.matrix_digest(), b.matrix_digest());
        let c = Topo::two_level(4, 9, 43);
        assert_ne!(a.matrix_digest(), c.matrix_digest());
    }

    #[test]
    fn link_classes_follow_block_placement() {
        let t = Topo::two_level(3, 4, 7);
        assert_eq!(t.link(0, 0), LinkClass::SelfLoop);
        assert_eq!(t.link(0, 3), LinkClass::IntraNode);
        assert_eq!(t.link(3, 4), LinkClass::InterNode);
        assert_eq!(t.node_of(11), 2);
        assert_eq!(t.size(), 12);
        assert!(t.is_hierarchical());
    }

    #[test]
    fn jitter_stays_within_band_and_classes_separate() {
        let t = Topo::two_level(4, 9, 5);
        let p = t.size();
        for from in 0..p {
            for to in 0..p {
                match t.link(from, to) {
                    LinkClass::SelfLoop => {
                        assert_eq!(t.alpha(from, to), 0.0);
                        assert_eq!(t.beta(from, to), 0.0);
                    }
                    LinkClass::IntraNode => {
                        let a = t.alpha(from, to);
                        assert!(a >= HIER_ALPHA_INTRA * (1.0 - LINK_JITTER) - 1e-12);
                        assert!(a <= HIER_ALPHA_INTRA * (1.0 + LINK_JITTER) + 1e-12);
                    }
                    LinkClass::InterNode => {
                        let a = t.alpha(from, to);
                        assert!(a >= HIER_ALPHA_INTER * (1.0 - LINK_JITTER) - 1e-12);
                        assert!(a <= HIER_ALPHA_INTER * (1.0 + LINK_JITTER) + 1e-12);
                    }
                }
            }
        }
        // Even with jitter the classes never overlap (25× ratio ≫ ±5%).
        let worst_intra = HIER_ALPHA_INTRA * (1.0 + LINK_JITTER);
        let best_inter = HIER_ALPHA_INTER * (1.0 - LINK_JITTER);
        assert!(worst_intra < best_inter);
    }

    #[test]
    fn flat_preset_is_uniform() {
        let t = Topo::flat(9, 11);
        assert!(!t.is_hierarchical());
        let base = t.class_params();
        assert_eq!(base.alpha_intra, base.alpha_inter);
        for from in 0..9 {
            for to in 0..9 {
                if from != to {
                    let a = t.alpha(from, to);
                    assert!(a >= UNIFORM_ALPHA * (1.0 - LINK_JITTER) - 1e-12);
                    assert!(a <= UNIFORM_ALPHA * (1.0 + LINK_JITTER) + 1e-12);
                }
            }
        }
    }

    #[test]
    fn parse_round_trips() {
        assert_eq!(Topo::parse("flat:36", 1).unwrap().size(), 36);
        let t = Topo::parse("2level:4x9", 1).unwrap();
        assert_eq!((t.nodes(), t.ranks_per_node()), (4, 9));
        assert_eq!(t.name(), "2level:4x9");
        assert_eq!(Topo::parse("paper36x1", 1).unwrap().size(), 36);
        assert!(Topo::parse("ring:8", 1).is_err());
        assert!(Topo::parse("2level:4", 1).is_err());
        assert!(Topo::parse("flat:0", 1).is_err());
    }

    #[test]
    fn paper_preset_all_inter() {
        let t = Topo::paper_36x1(3);
        assert_eq!(t.size(), 36);
        assert_eq!(t.link(0, 1), LinkClass::InterNode);
        assert!(t.hop_cost(0, 1, 8) > 0.0);
    }
}
