//! Critical-path extraction: which chain of rounds determines the
//! completion time, and what each link on it costs.
//!
//! Replays the trace like [`super::replay`], but remembers, for every
//! rank, which event its clock last waited on. Walking backwards from the
//! slowest rank yields the dependency chain the α-β-γ model charges —
//! making "123-doubling saves one round of α_inter" directly visible per
//! configuration (`exscan trace --critical`).

use std::collections::HashMap;

use super::{EventKind, TraceReport};
use crate::cost::{CostModel, LinkClass};

/// One hop on the critical path.
#[derive(Debug, Clone, PartialEq)]
pub struct Hop {
    pub round: u32,
    /// The rank whose clock this hop advanced.
    pub rank: usize,
    /// Sender, for communication hops; `None` for ⊕ applications.
    pub from: Option<usize>,
    pub link: Option<LinkClass>,
    /// Time spent in this hop (µs): round cost or reduce cost, measured
    /// end-to-end along the chain (so the hops telescope to the
    /// completion time).
    pub cost_us: f64,
    /// Portion of the rank's elapsed time spent blocked before this hop's
    /// transfer began (µs): the gap between the rank becoming idle and
    /// the matching send being posted. Zero for local ⊕ hops and for
    /// receives whose message was already in flight.
    pub wait_us: f64,
    /// Clock after the hop (µs).
    pub at_us: f64,
    /// True when the rank had to wait on the sender (the hop is a genuine
    /// dependency, not just local sequencing).
    pub waited: bool,
}

/// The replayed critical path, slowest rank backwards to time zero.
#[derive(Debug, Clone)]
pub struct CriticalPath {
    pub completion_us: f64,
    pub final_rank: usize,
    /// Hops in forward (chronological) order.
    pub hops: Vec<Hop>,
}

impl CriticalPath {
    /// Communication rounds on the path.
    pub fn comm_rounds(&self) -> usize {
        self.hops.iter().filter(|h| h.from.is_some()).count()
    }

    /// ⊕ applications charged on the path.
    pub fn reduce_hops(&self) -> usize {
        self.hops.iter().filter(|h| h.from.is_none()).count()
    }

    /// Inter-node rounds on the path (the expensive ones).
    pub fn inter_rounds(&self) -> usize {
        self.hops.iter().filter(|h| h.link == Some(LinkClass::InterNode)).count()
    }
}

/// Extract the critical path of a traced collective under `model`, with
/// all messages resized to `bytes` (see [`super::replay`] for semantics).
pub fn critical_path(report: &TraceReport, model: &CostModel, bytes: usize) -> CriticalPath {
    let p = report.p;
    // Forward replay, remembering per-event timing and dependencies.
    #[derive(Clone)]
    struct Ev {
        rank: usize,
        idx: usize,
        start: f64,
        /// When the hop's transfer/compute actually began: `start` for
        /// local work and already-arrived messages, the sender's posting
        /// stamp for waited receives.
        ready: f64,
        end: f64,
        dep: Option<(usize, usize)>, // (rank, idx) of the sender event we waited on
        waited: bool,
    }
    let mut clock = vec![0.0f64; p];
    let mut idxp = vec![0usize; p];
    // (from, to, round) -> (stamp, sender event key)
    let mut send_time: HashMap<(usize, usize, u32), (f64, (usize, usize))> = HashMap::new();
    let mut evs: HashMap<(usize, usize), Ev> = HashMap::new();
    let mut last_ev: Vec<Option<(usize, usize)>> = vec![None; p];

    loop {
        let mut progressed = false;
        let mut all_done = true;
        for r in 0..p {
            let events = &report.traces[r].events;
            while idxp[r] < events.len() {
                let i = idxp[r];
                let e = events[i];
                let key = (r, i);
                match e.kind {
                    EventKind::Reduce { .. } => {
                        let start = clock[r];
                        clock[r] += model.reduce_cost(bytes);
                        evs.insert(key, Ev { rank: r, idx: i, start, ready: start, end: clock[r], dep: last_ev[r], waited: false });
                        last_ev[r] = Some(key);
                        idxp[r] += 1;
                        progressed = true;
                    }
                    EventKind::Send { to, .. } => {
                        send_time.entry((r, to, e.round)).or_insert((clock[r], last_ev[r].unwrap_or(key)));
                        let paired_from = events.get(i + 1).and_then(|n| match n.kind {
                            EventKind::Recv { from, .. } if n.round == e.round => Some(from),
                            _ => None,
                        });
                        match paired_from {
                            Some(from) => {
                                let Some(&(st, skey)) = send_time.get(&(from, r, e.round)) else {
                                    break;
                                };
                                let c_out = model.round_cost(r, to, bytes);
                                let c_in = model.round_cost(from, r, bytes);
                                let start = clock[r];
                                let waited = st > clock[r];
                                let ready = clock[r].max(st);
                                clock[r] = ready + c_out.max(c_in);
                                let dep = if waited { Some(skey) } else { last_ev[r] };
                                let rkey = (r, i + 1);
                                evs.insert(rkey, Ev { rank: r, idx: i + 1, start, ready, end: clock[r], dep, waited });
                                last_ev[r] = Some(rkey);
                                idxp[r] += 2;
                                progressed = true;
                            }
                            None => {
                                let start = clock[r];
                                clock[r] += model.round_cost(r, to, bytes);
                                evs.insert(key, Ev { rank: r, idx: i, start, ready: start, end: clock[r], dep: last_ev[r], waited: false });
                                last_ev[r] = Some(key);
                                idxp[r] += 1;
                                progressed = true;
                            }
                        }
                    }
                    EventKind::Recv { from, .. } => {
                        let Some(&(st, skey)) = send_time.get(&(from, r, e.round)) else {
                            break;
                        };
                        let start = clock[r];
                        let waited = st > clock[r];
                        let ready = clock[r].max(st);
                        clock[r] = ready + model.round_cost(from, r, bytes);
                        let dep = if waited { Some(skey) } else { last_ev[r] };
                        evs.insert(key, Ev { rank: r, idx: i, start, ready, end: clock[r], dep, waited });
                        last_ev[r] = Some(key);
                        idxp[r] += 1;
                        progressed = true;
                    }
                }
            }
            if idxp[r] < events.len() {
                all_done = false;
            }
        }
        if all_done {
            break;
        }
        assert!(progressed, "critical-path replay stuck: unmatched receive");
    }

    // Slowest rank, then walk deps backwards.
    let final_rank = (0..p).max_by(|&a, &b| clock[a].partial_cmp(&clock[b]).unwrap()).unwrap_or(0);
    let mut hops = Vec::new();
    let mut cur = last_ev[final_rank];
    while let Some(key) = cur {
        let ev = &evs[&key];
        let e = report.traces[ev.rank].events[ev.idx];
        let (from, link) = match e.kind {
            EventKind::Recv { from, .. } => {
                (Some(from), Some(model.link(from, ev.rank)))
            }
            EventKind::Send { to, .. } => (Some(to), Some(model.link(ev.rank, to))),
            EventKind::Reduce { .. } => (None, None),
        };
        hops.push(Hop {
            round: e.round,
            rank: ev.rank,
            from,
            link,
            // Pure transfer/compute cost: waits are reported separately,
            // not silently folded in (a waited hop's elapsed time is
            // wait_us + the transfer itself).
            cost_us: ev.end - ev.ready,
            wait_us: ev.ready - ev.start,
            at_us: ev.end,
            waited: ev.waited,
        });
        cur = ev.dep;
    }
    hops.reverse();
    // Re-base hop costs end-to-end along the chain so they telescope to
    // the completion time; when a dependency chain leaves slack between
    // consecutive hops (the sender posted before its own chain-end), the
    // slack is accounted as additional wait, never as transfer cost.
    let mut prev_end = 0.0;
    for h in &mut hops {
        let total = h.at_us - prev_end;
        h.wait_us += (total - h.cost_us - h.wait_us).max(0.0);
        h.cost_us = total;
        prev_end = h.at_us;
    }
    CriticalPath { completion_us: clock[final_rank], final_rank, hops }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::inputs_i64;
    use crate::cost::{CostModel, CostParams};
    use crate::mpi::{ops, run_scan, Topology, WorldConfig};
    use crate::prelude::*;

    fn trace_of(algo: &dyn ScanAlgorithm<i64>, nodes: usize, rpn: usize) -> TraceReport {
        let topo = Topology::cluster(nodes, rpn);
        let cfg = WorldConfig::new(topo)
            .virtual_clock(CostParams::generic())
            .with_trace(true);
        let inputs = inputs_i64(topo.size(), 4, 1);
        run_scan(&cfg, algo, &ops::bxor(), &inputs).unwrap().trace.unwrap()
    }

    #[test]
    fn path_completion_matches_replay() {
        let model = CostModel::new(CostParams::generic(), 1);
        for algo in crate::coll::paper_exscan_algorithms::<i64>() {
            let tr = trace_of(algo.as_ref(), 20, 1);
            let cp = critical_path(&tr, &model, 32);
            let replayed = crate::trace::replay::replay_completion(&tr, &model, 32);
            assert!(
                (cp.completion_us - replayed).abs() < 1e-9,
                "{}: {} vs {}",
                algo.name(),
                cp.completion_us,
                replayed
            );
            // The chain must account for the entire completion time.
            let total: f64 = cp.hops.iter().map(|h| h.cost_us).sum();
            assert!((total - cp.completion_us).abs() < 1e-9, "{}", algo.name());
        }
    }

    #[test]
    fn comm_rounds_on_path_match_round_counts() {
        let model = CostModel::new(CostParams::generic(), 1);
        let tr = trace_of(&Exscan123, 36, 1);
        let cp = critical_path(&tr, &model, 32);
        // The 123 path from rank p-1 passes through q rounds. The ⊕ hops
        // are Theorem 1's q-1 result-path folds, plus the round-1 sender's
        // W ⊕ V preparation when the wait binds through it (the paper's
        // ternary-reduce-local footnote made visible).
        assert_eq!(cp.comm_rounds() as u32, 6);
        assert!(cp.reduce_hops() >= 5 && cp.reduce_hops() <= 6, "{}", cp.reduce_hops());
    }

    #[test]
    fn waited_hop_charges_wait_separately_from_transfer() {
        use crate::trace::{EventKind, RankTrace, TraceReport};
        // Rank 0 computes three ⊕ (32 µs each at γ = 1, 32 B) and then
        // sends; rank 1 only receives, so it blocks 96 µs before the
        // 1 µs (α) transfer. The pre-fix code folded the wait into
        // cost_us (charging 97); the wait must be reported separately.
        let params = CostParams {
            alpha_intra: 1.0,
            alpha_inter: 1.0,
            beta_intra: 0.0,
            beta_inter: 0.0,
            gamma: 1.0,
            overhead: 0.0,
        };
        let model = CostModel::new(params, 1);
        let mut t0 = RankTrace::new(0);
        for _ in 0..3 {
            t0.push(0, EventKind::Reduce { bytes: 32 });
        }
        t0.push(0, EventKind::Send { to: 1, bytes: 32 });
        let mut t1 = RankTrace::new(1);
        t1.push(0, EventKind::Recv { from: 0, bytes: 32 });
        let cp = critical_path(&TraceReport::new(vec![t0, t1]), &model, 32);
        assert!((cp.completion_us - 97.0).abs() < 1e-9, "{}", cp.completion_us);
        let recv = cp.hops.last().unwrap();
        assert_eq!((recv.rank, recv.from), (1, Some(0)));
        assert!(recv.waited);
        assert!((recv.cost_us - 1.0).abs() < 1e-9, "transfer cost {}", recv.cost_us);
        assert!((recv.wait_us - 96.0).abs() < 1e-9, "wait {}", recv.wait_us);
        // Local ⊕ hops never wait, and the chain still telescopes.
        for h in &cp.hops[..cp.hops.len() - 1] {
            assert_eq!(h.wait_us, 0.0, "round {} rank {}", h.round, h.rank);
        }
        // The chain still telescopes: the 96 µs rank 1 waited is the
        // sender's ⊕ hops on the chain, so it is NOT added again.
        let total: f64 = cp.hops.iter().map(|h| h.cost_us).sum();
        assert!((total - cp.completion_us).abs() < 1e-9);
    }

    #[test]
    fn hierarchical_path_classifies_links() {
        let model = CostModel::new(CostParams::generic(), 8);
        let tr = trace_of(&Exscan123, 8, 8);
        let cp = critical_path(&tr, &model, 32);
        assert!(cp.inter_rounds() >= 1);
        assert!(cp.inter_rounds() < cp.comm_rounds());
    }
}
