//! Clock replay: re-run a recorded trace under the α-β-γ model with an
//! arbitrary vector size.
//!
//! The communication *pattern* of every algorithm here is independent of
//! the vector length m (all messages are full m-element vectors). A single
//! traced run therefore determines the virtual completion time for every m:
//! we replay the per-rank event logs with per-rank logical clocks, scaling
//! each message and each ⊕ application to `bytes`. This is how the figure
//! sweeps predict 1152-rank timings without re-running 1152 threads per
//! data point.

use std::collections::HashMap;

use super::{EventKind, TraceReport};
use crate::cost::CostModel;

/// Replay the trace with all messages and reductions resized to `bytes`.
/// Returns the final virtual clock per rank (µs, excluding the per-call
/// `overhead` parameter, which the caller adds once).
///
/// Semantics mirror the live virtual transport exactly:
/// * `Reduce`: `clock += γ·bytes`
/// * lone `Send`: stamp `clock`, then `clock += α+β·bytes`
/// * lone `Recv`: `clock = max(clock, stamp) + α+β·bytes`
/// * `Send` immediately followed by a same-round `Recv` (a simultaneous
///   send-receive): stamp, then `clock = max(clock, stamp_in) +
///   max(c_out, c_in)`.
pub fn replay_clocks(report: &TraceReport, model: &CostModel, bytes: usize) -> Vec<f64> {
    let p = report.p;
    let mut clock = vec![0.0f64; p];
    let mut idx = vec![0usize; p];
    let mut send_time: HashMap<(usize, usize, u32), f64> = HashMap::new();

    loop {
        let mut progressed = false;
        let mut all_done = true;
        for r in 0..p {
            let events = &report.traces[r].events;
            while idx[r] < events.len() {
                let e = events[idx[r]];
                match e.kind {
                    EventKind::Reduce { .. } => {
                        clock[r] += model.reduce_cost(bytes);
                        idx[r] += 1;
                        progressed = true;
                    }
                    EventKind::Send { to, .. } => {
                        // Expose the stamp immediately so the peer can make
                        // progress even if we end up waiting on a paired recv.
                        send_time.entry((r, to, e.round)).or_insert(clock[r]);
                        let paired_from = events.get(idx[r] + 1).and_then(|n| match n.kind {
                            EventKind::Recv { from, .. } if n.round == e.round => Some(from),
                            _ => None,
                        });
                        match paired_from {
                            Some(from) => {
                                let Some(&st) = send_time.get(&(from, r, e.round)) else {
                                    break; // peer has not sent yet
                                };
                                let c_out = model.round_cost(r, to, bytes);
                                let c_in = model.round_cost(from, r, bytes);
                                clock[r] = clock[r].max(st) + c_out.max(c_in);
                                idx[r] += 2;
                                progressed = true;
                            }
                            None => {
                                clock[r] += model.round_cost(r, to, bytes);
                                idx[r] += 1;
                                progressed = true;
                            }
                        }
                    }
                    EventKind::Recv { from, .. } => {
                        let Some(&st) = send_time.get(&(from, r, e.round)) else {
                            break;
                        };
                        clock[r] = clock[r].max(st) + model.round_cost(from, r, bytes);
                        idx[r] += 1;
                        progressed = true;
                    }
                }
            }
            if idx[r] < events.len() {
                all_done = false;
            }
        }
        if all_done {
            return clock;
        }
        assert!(progressed, "trace replay stuck: unmatched receive in trace");
    }
}

/// Completion time of the collective: max over ranks of the replayed clock.
pub fn replay_completion(report: &TraceReport, model: &CostModel, bytes: usize) -> f64 {
    replay_clocks(report, model, bytes).into_iter().fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::{CostModel, CostParams};
    use crate::trace::RankTrace;

    fn model() -> CostModel {
        CostModel::new(
            CostParams {
                alpha_intra: 1.0,
                alpha_inter: 10.0,
                beta_intra: 0.0,
                beta_inter: 0.0,
                gamma: 0.5,
                overhead: 0.0,
            },
            64, // everything intra-node
        )
    }

    #[test]
    fn pingpong_two_rounds() {
        // Round 0: 0 -> 1; round 1: 1 -> 0. All intra (α=1).
        let mut t0 = RankTrace::new(0);
        t0.push(0, EventKind::Send { to: 1, bytes: 8 });
        t0.push(1, EventKind::Recv { from: 1, bytes: 8 });
        let mut t1 = RankTrace::new(1);
        t1.push(0, EventKind::Recv { from: 0, bytes: 8 });
        t1.push(1, EventKind::Send { to: 0, bytes: 8 });
        let clocks = replay_clocks(&TraceReport::new(vec![t0, t1]), &model(), 8);
        // rank1: recv at max(0,0)+1 = 1; send stamps 1, +1 => 2.
        // rank0: send 0->1 (clock 1), recv: max(1, 1)+1 = 2.
        assert_eq!(clocks, vec![2.0, 2.0]);
    }

    #[test]
    fn paired_sendrecv_costs_one_round() {
        // Ring exchange 0 <-> 1 via simultaneous sendrecv in round 0.
        let mut t0 = RankTrace::new(0);
        t0.push(0, EventKind::Send { to: 1, bytes: 8 });
        t0.push(0, EventKind::Recv { from: 1, bytes: 8 });
        let mut t1 = RankTrace::new(1);
        t1.push(0, EventKind::Send { to: 0, bytes: 8 });
        t1.push(0, EventKind::Recv { from: 0, bytes: 8 });
        let clocks = replay_clocks(&TraceReport::new(vec![t0, t1]), &model(), 8);
        assert_eq!(clocks, vec![1.0, 1.0]);
    }

    #[test]
    fn reduce_adds_gamma() {
        let mut t0 = RankTrace::new(0);
        t0.push(0, EventKind::Send { to: 1, bytes: 4 });
        let mut t1 = RankTrace::new(1);
        t1.push(0, EventKind::Recv { from: 0, bytes: 4 });
        t1.push(0, EventKind::Reduce { bytes: 4 });
        let clocks = replay_clocks(&TraceReport::new(vec![t0, t1]), &model(), 4);
        // recv: 0+1 = 1; reduce: +0.5*4 = 3.0
        assert_eq!(clocks[1], 3.0);
    }

    #[test]
    fn bytes_rescaling() {
        // Trace recorded at 8 bytes, replayed at 800: cost scales with β.
        let m = CostModel::new(
            CostParams {
                alpha_intra: 1.0,
                alpha_inter: 1.0,
                beta_intra: 0.01,
                beta_inter: 0.01,
                gamma: 0.0,
                overhead: 0.0,
            },
            1,
        );
        let mut t0 = RankTrace::new(0);
        t0.push(0, EventKind::Send { to: 1, bytes: 8 });
        let mut t1 = RankTrace::new(1);
        t1.push(0, EventKind::Recv { from: 0, bytes: 8 });
        let rep = TraceReport::new(vec![t0, t1]);
        assert!((replay_completion(&rep, &m, 8) - 1.08).abs() < 1e-9);
        assert!((replay_completion(&rep, &m, 800) - 9.0).abs() < 1e-9);
    }

    #[test]
    #[should_panic(expected = "stuck")]
    fn unmatched_recv_panics() {
        let mut t0 = RankTrace::new(0);
        t0.push(0, EventKind::Recv { from: 1, bytes: 8 });
        replay_clocks(&TraceReport::new(vec![t0, RankTrace::new(1)]), &model(), 8);
    }
}
