//! Communication-round tracing and invariant checking.
//!
//! Every send, receive and ⊕ application can be recorded per rank. From the
//! merged trace we (a) count communication rounds and ⊕ applications — the
//! paper's two cost metrics, checked against the closed forms of Theorem 1
//! in the test suite — and (b) verify the *one-ported* model assumption:
//! no rank sends more than one message or receives more than one message
//! in the same round.

pub mod critical;
pub mod invariants;
pub mod replay;

pub use critical::{critical_path, CriticalPath, Hop};
pub use invariants::{check_all, InvariantViolation};
pub use replay::replay_clocks;


/// What happened at one point of a rank's execution.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    Send { to: usize, bytes: usize },
    Recv { from: usize, bytes: usize },
    /// One `reduce_local` (⊕) application over `bytes` bytes. `round` is
    /// the communication round it is attributed to.
    Reduce { bytes: usize },
}

/// A traced event, attributed to an algorithm-defined round index and the
/// context id of the communicator it ran on (0 = world scope; see
/// [`crate::mpi::TagKey`]). Send/recv peers are recorded as **world**
/// ranks; use [`TraceReport::for_ctx`] to view one communicator's
/// sub-trace in communicator coordinates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceEvent {
    pub ctx: u16,
    pub round: u32,
    pub kind: EventKind,
}

/// The ordered event log of a single rank.
#[derive(Debug, Clone, Default)]
pub struct RankTrace {
    pub rank: usize,
    pub events: Vec<TraceEvent>,
}

impl RankTrace {
    pub fn new(rank: usize) -> Self {
        RankTrace { rank, events: Vec::new() }
    }

    /// Record a world-scope (context 0) event.
    pub fn push(&mut self, round: u32, kind: EventKind) {
        self.push_ctx(0, round, kind);
    }

    /// Record an event attributed to communicator context `ctx`.
    pub fn push_ctx(&mut self, ctx: u16, round: u32, kind: EventKind) {
        self.events.push(TraceEvent { ctx, round, kind });
    }

    /// Number of ⊕ applications this rank performed.
    pub fn ops(&self) -> u32 {
        self.events.iter().filter(|e| matches!(e.kind, EventKind::Reduce { .. })).count() as u32
    }

    /// Rounds in which this rank communicated (sent or received), counted
    /// per (ctx, round) so concurrent collectives don't alias.
    pub fn comm_rounds(&self) -> u32 {
        let mut rounds: Vec<(u16, u32)> = self
            .events
            .iter()
            .filter(|e| !matches!(e.kind, EventKind::Reduce { .. }))
            .map(|e| (e.ctx, e.round))
            .collect();
        rounds.sort_unstable();
        rounds.dedup();
        rounds.len() as u32
    }
}

/// Merged view over all ranks of one collective call.
#[derive(Debug, Clone)]
pub struct TraceReport {
    pub p: usize,
    pub traces: Vec<RankTrace>,
}

impl TraceReport {
    pub fn new(traces: Vec<RankTrace>) -> Self {
        TraceReport { p: traces.len(), traces }
    }

    /// Global number of communication rounds: the number of distinct
    /// (ctx, round) indices in which *any* rank communicated. (For a
    /// single collective round indices are dense, so this equals
    /// `max round + 1`; for a mixed multi-communicator trace it sums the
    /// collectives' rounds — extract one with [`for_ctx`](Self::for_ctx)
    /// for a per-collective count.)
    pub fn total_rounds(&self) -> u32 {
        let mut rounds: Vec<(u16, u32)> = self
            .traces
            .iter()
            .flat_map(|t| t.events.iter())
            .filter(|e| !matches!(e.kind, EventKind::Reduce { .. }))
            .map(|e| (e.ctx, e.round))
            .collect();
        rounds.sort_unstable();
        rounds.dedup();
        rounds.len() as u32
    }

    /// Extract the sub-trace of one communicator in **communicator
    /// coordinates**: `members` is the communicator's world-rank list in
    /// communicator-rank order (see [`Comm::ranks`]); the result has one
    /// trace per member, ranks and send/recv peers remapped to
    /// communicator ranks, and events normalized to context 0 — so it
    /// compares bit-for-bit against the trace of the same collective run
    /// standalone on a world of the communicator's size.
    ///
    /// [`Comm::ranks`]: crate::mpi::Comm::ranks
    pub fn for_ctx(&self, ctx: u16, members: &[usize]) -> TraceReport {
        let comm_rank = |world: usize| {
            members
                .iter()
                .position(|&w| w == world)
                .expect("event peer must be a communicator member")
        };
        let traces = members
            .iter()
            .enumerate()
            .map(|(cr, &wr)| {
                let mut t = RankTrace::new(cr);
                if let Some(src) = self.traces.iter().find(|t| t.rank == wr) {
                    for e in &src.events {
                        if e.ctx != ctx {
                            continue;
                        }
                        let kind = match e.kind {
                            EventKind::Send { to, bytes } => {
                                EventKind::Send { to: comm_rank(to), bytes }
                            }
                            EventKind::Recv { from, bytes } => {
                                EventKind::Recv { from: comm_rank(from), bytes }
                            }
                            EventKind::Reduce { .. } => e.kind,
                        };
                        t.push(e.round, kind);
                    }
                }
                t
            })
            .collect();
        TraceReport::new(traces)
    }

    /// ⊕ applications per rank.
    pub fn ops_per_rank(&self) -> Vec<u32> {
        self.traces.iter().map(|t| t.ops()).collect()
    }

    /// Maximum ⊕ applications over ranks (the per-processor computation
    /// cost the paper compares).
    pub fn max_ops(&self) -> u32 {
        self.ops_per_rank().into_iter().max().unwrap_or(0)
    }

    /// Total ⊕ applications over all ranks. Cross-checked against the
    /// lazily aggregated sharded counters of [`OpRef`] (which count the
    /// same applications from the operator side) by the hotpath bench and
    /// the CI m-sweep gate.
    ///
    /// [`OpRef`]: crate::mpi::OpRef
    pub fn total_ops(&self) -> u64 {
        self.traces.iter().map(|t| t.ops() as u64).sum()
    }

    /// ⊕ applications on the completion-critical last rank `p-1` — the
    /// count Theorem 1 states (`q-1` for the 123-doubling algorithm).
    pub fn last_rank_ops(&self) -> u32 {
        self.traces.last().map(|t| t.ops()).unwrap_or(0)
    }

    /// Total messages sent.
    pub fn total_messages(&self) -> usize {
        self.traces
            .iter()
            .flat_map(|t| t.events.iter())
            .filter(|e| matches!(e.kind, EventKind::Send { .. }))
            .count()
    }

    /// Total bytes moved over all links.
    pub fn total_bytes(&self) -> usize {
        self.traces
            .iter()
            .flat_map(|t| t.events.iter())
            .filter_map(|e| match e.kind {
                EventKind::Send { bytes, .. } => Some(bytes),
                _ => None,
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mini_trace() -> TraceReport {
        // Two ranks, one round: 0 -> 1, rank 1 reduces once.
        let mut t0 = RankTrace::new(0);
        t0.push(0, EventKind::Send { to: 1, bytes: 8 });
        let mut t1 = RankTrace::new(1);
        t1.push(0, EventKind::Recv { from: 0, bytes: 8 });
        t1.push(0, EventKind::Reduce { bytes: 8 });
        TraceReport::new(vec![t0, t1])
    }

    #[test]
    fn counts() {
        let r = mini_trace();
        assert_eq!(r.total_rounds(), 1);
        assert_eq!(r.ops_per_rank(), vec![0, 1]);
        assert_eq!(r.max_ops(), 1);
        assert_eq!(r.last_rank_ops(), 1);
        assert_eq!(r.total_ops(), 1);
        assert_eq!(r.total_messages(), 1);
        assert_eq!(r.total_bytes(), 8);
    }

    #[test]
    fn comm_rounds_ignores_reduce() {
        let mut t = RankTrace::new(0);
        t.push(0, EventKind::Send { to: 1, bytes: 8 });
        t.push(3, EventKind::Reduce { bytes: 8 });
        assert_eq!(t.comm_rounds(), 1);
        assert_eq!(t.ops(), 1);
    }

    #[test]
    fn rounds_key_on_ctx_and_round() {
        // Two concurrent collectives, both using round 0: the totals must
        // not alias their rounds together.
        let mut t = RankTrace::new(0);
        t.push_ctx(1, 0, EventKind::Send { to: 1, bytes: 8 });
        t.push_ctx(2, 0, EventKind::Send { to: 1, bytes: 8 });
        assert_eq!(t.comm_rounds(), 2);
        let mut t1 = RankTrace::new(1);
        t1.push_ctx(1, 0, EventKind::Recv { from: 0, bytes: 8 });
        t1.push_ctx(2, 0, EventKind::Recv { from: 0, bytes: 8 });
        let r = TraceReport::new(vec![t, t1]);
        assert_eq!(r.total_rounds(), 2);
    }

    #[test]
    fn for_ctx_extracts_in_comm_coordinates() {
        // World of 4; a collective on ctx 7 over world ranks {1, 3}
        // (comm ranks 0, 1), interleaved with world-scope traffic.
        let mut t1 = RankTrace::new(1);
        t1.push(0, EventKind::Send { to: 2, bytes: 8 }); // world-scope noise
        t1.push_ctx(7, 0, EventKind::Send { to: 3, bytes: 16 });
        t1.push_ctx(7, 0, EventKind::Recv { from: 3, bytes: 16 });
        t1.push_ctx(7, 0, EventKind::Reduce { bytes: 16 });
        let mut t3 = RankTrace::new(3);
        t3.push_ctx(7, 0, EventKind::Send { to: 1, bytes: 16 });
        t3.push_ctx(7, 0, EventKind::Recv { from: 1, bytes: 16 });
        let report =
            TraceReport::new(vec![RankTrace::new(0), t1, RankTrace::new(2), t3]);
        let sub = report.for_ctx(7, &[1, 3]);
        assert_eq!(sub.p, 2);
        assert_eq!(sub.traces[0].rank, 0);
        assert_eq!(sub.traces[1].rank, 1);
        // Peers remapped to comm ranks, ctx normalized to 0 — equal to
        // what a standalone p=2 run would record.
        let mut want0 = RankTrace::new(0);
        want0.push(0, EventKind::Send { to: 1, bytes: 16 });
        want0.push(0, EventKind::Recv { from: 1, bytes: 16 });
        want0.push(0, EventKind::Reduce { bytes: 16 });
        assert_eq!(sub.traces[0].events, want0.events);
        assert_eq!(sub.total_rounds(), 1);
        assert_eq!(sub.total_ops(), 1);
    }
}
