//! Invariant checks over merged traces.
//!
//! The paper's lower-bound argument assumes *one-ported* communication:
//! per round, each processor takes part in at most one send and one receive
//! (a simultaneous send-receive). These checks make that assumption
//! machine-verified for every algorithm in the library, and additionally
//! verify that the trace is self-consistent (every send has exactly one
//! matching receive in the same round, no self-messages).

use super::{EventKind, TraceReport};
use std::collections::HashMap;

/// A violated structural invariant, with enough context to debug it.
/// `ctx` is the communicator context the offending round ran on (0 =
/// world scope): the one-ported and matching disciplines hold per
/// (ctx, round), since concurrent collectives legitimately reuse round
/// indices on distinct communicators.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum InvariantViolation {
    /// Rank sent more than one message in one round.
    MultipleSends { rank: usize, ctx: u16, round: u32, count: usize },
    /// Rank received more than one message in one round.
    MultipleRecvs { rank: usize, ctx: u16, round: u32, count: usize },
    /// A send with no matching receive (or vice versa).
    Unmatched { from: usize, to: usize, ctx: u16, round: u32, sends: usize, recvs: usize },
    /// A rank messaged itself.
    SelfMessage { rank: usize, ctx: u16, round: u32 },
    /// Send and matching receive disagree on the payload size.
    SizeMismatch { from: usize, to: usize, ctx: u16, round: u32, sent: usize, received: usize },
}

impl std::fmt::Display for InvariantViolation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{self:?}")
    }
}

/// Run every invariant check; returns all violations (empty = clean).
pub fn check_all(report: &TraceReport) -> Vec<InvariantViolation> {
    let mut out = Vec::new();
    check_one_ported(report, &mut out);
    check_matching(report, &mut out);
    out
}

/// One-ported model: per (rank, ctx, round), at most one send and one
/// receive.
fn check_one_ported(report: &TraceReport, out: &mut Vec<InvariantViolation>) {
    for t in &report.traces {
        let mut sends: HashMap<(u16, u32), usize> = HashMap::new();
        let mut recvs: HashMap<(u16, u32), usize> = HashMap::new();
        for e in &t.events {
            match e.kind {
                EventKind::Send { to, .. } => {
                    *sends.entry((e.ctx, e.round)).or_default() += 1;
                    if to == t.rank {
                        out.push(InvariantViolation::SelfMessage {
                            rank: t.rank,
                            ctx: e.ctx,
                            round: e.round,
                        });
                    }
                }
                EventKind::Recv { .. } => *recvs.entry((e.ctx, e.round)).or_default() += 1,
                EventKind::Reduce { .. } => {}
            }
        }
        for (&(ctx, round), &count) in &sends {
            if count > 1 {
                out.push(InvariantViolation::MultipleSends { rank: t.rank, ctx, round, count });
            }
        }
        for (&(ctx, round), &count) in &recvs {
            if count > 1 {
                out.push(InvariantViolation::MultipleRecvs { rank: t.rank, ctx, round, count });
            }
        }
    }
}

/// Every (from, to, ctx, round) send is matched by exactly one receive
/// with the same byte count.
fn check_matching(report: &TraceReport, out: &mut Vec<InvariantViolation>) {
    // (from, to, ctx, round) -> (send bytes, send count, recv bytes, recv count)
    type Key = (usize, usize, u16, u32);
    let mut table: HashMap<Key, (usize, usize, usize, usize)> = HashMap::new();
    for t in &report.traces {
        for e in &t.events {
            match e.kind {
                EventKind::Send { to, bytes } => {
                    let ent = table.entry((t.rank, to, e.ctx, e.round)).or_default();
                    ent.0 = bytes;
                    ent.1 += 1;
                }
                EventKind::Recv { from, bytes } => {
                    let ent = table.entry((from, t.rank, e.ctx, e.round)).or_default();
                    ent.2 = bytes;
                    ent.3 += 1;
                }
                EventKind::Reduce { .. } => {}
            }
        }
    }
    for (&(from, to, ctx, round), &(sb, sc, rb, rc)) in &table {
        if sc != rc {
            out.push(InvariantViolation::Unmatched { from, to, ctx, round, sends: sc, recvs: rc });
        } else if sb != rb {
            out.push(InvariantViolation::SizeMismatch {
                from,
                to,
                ctx,
                round,
                sent: sb,
                received: rb,
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::RankTrace;

    #[test]
    fn clean_trace_passes() {
        let mut t0 = RankTrace::new(0);
        t0.push(0, EventKind::Send { to: 1, bytes: 16 });
        let mut t1 = RankTrace::new(1);
        t1.push(0, EventKind::Recv { from: 0, bytes: 16 });
        assert!(check_all(&TraceReport::new(vec![t0, t1])).is_empty());
    }

    #[test]
    fn detects_double_send() {
        let mut t0 = RankTrace::new(0);
        t0.push(0, EventKind::Send { to: 1, bytes: 8 });
        t0.push(0, EventKind::Send { to: 2, bytes: 8 });
        let mut t1 = RankTrace::new(1);
        t1.push(0, EventKind::Recv { from: 0, bytes: 8 });
        let mut t2 = RankTrace::new(2);
        t2.push(0, EventKind::Recv { from: 0, bytes: 8 });
        let v = check_all(&TraceReport::new(vec![t0, t1, t2]));
        assert!(v.iter().any(|x| matches!(x, InvariantViolation::MultipleSends { rank: 0, .. })));
    }

    #[test]
    fn concurrent_ctxs_may_reuse_round_indices() {
        // One send per (ctx, round) is one-ported even when two contexts
        // both use round 0 — and matching is per context, so a ctx-1 send
        // cannot satisfy a ctx-2 receive.
        let mut t0 = RankTrace::new(0);
        t0.push_ctx(1, 0, EventKind::Send { to: 1, bytes: 8 });
        t0.push_ctx(2, 0, EventKind::Send { to: 1, bytes: 8 });
        let mut t1 = RankTrace::new(1);
        t1.push_ctx(1, 0, EventKind::Recv { from: 0, bytes: 8 });
        t1.push_ctx(2, 0, EventKind::Recv { from: 0, bytes: 8 });
        assert!(check_all(&TraceReport::new(vec![t0.clone(), t1])).is_empty());
        // Drop the ctx-2 receive: must surface as unmatched on ctx 2.
        let mut t1b = RankTrace::new(1);
        t1b.push_ctx(1, 0, EventKind::Recv { from: 0, bytes: 8 });
        let v = check_all(&TraceReport::new(vec![t0, t1b]));
        assert!(
            v.iter().any(|x| matches!(x, InvariantViolation::Unmatched { ctx: 2, .. })),
            "{v:?}"
        );
    }

    #[test]
    fn detects_unmatched() {
        let mut t0 = RankTrace::new(0);
        t0.push(0, EventKind::Send { to: 1, bytes: 8 });
        let t1 = RankTrace::new(1);
        let v = check_all(&TraceReport::new(vec![t0, t1]));
        assert!(v.iter().any(|x| matches!(x, InvariantViolation::Unmatched { .. })));
    }

    #[test]
    fn detects_size_mismatch() {
        let mut t0 = RankTrace::new(0);
        t0.push(0, EventKind::Send { to: 1, bytes: 8 });
        let mut t1 = RankTrace::new(1);
        t1.push(0, EventKind::Recv { from: 0, bytes: 4 });
        let v = check_all(&TraceReport::new(vec![t0, t1]));
        assert!(v.iter().any(|x| matches!(x, InvariantViolation::SizeMismatch { .. })));
    }

    #[test]
    fn detects_self_message() {
        let mut t0 = RankTrace::new(0);
        t0.push(0, EventKind::Send { to: 0, bytes: 8 });
        t0.push(0, EventKind::Recv { from: 0, bytes: 8 });
        let v = check_all(&TraceReport::new(vec![t0]));
        assert!(v.iter().any(|x| matches!(x, InvariantViolation::SelfMessage { .. })));
    }
}
