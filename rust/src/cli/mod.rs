//! `exscan` CLI: the launcher over the library. Subcommands map 1:1 to the
//! DESIGN.md experiments — `table1`/`sweep` regenerate the paper's
//! artifacts, `calibrate`/`predict`/`trace`/`tune` expose the cost model
//! and invariant machinery, `run` executes a single collective, and
//! `kernel-smoke` proves the PJRT artifact path end to end.

pub mod args;

use anyhow::{anyhow, bail, Result};

use crate::bench::{
    figure1_sweep, format_table, table1_rows, to_csv, BenchConfig, PaperConfig, SweepSpec,
};
use crate::coll::{
    all_exscan_algorithms, exscan_by_name, select_exscan, ScanAlgorithm, TuningTable,
};
use crate::cost::{fit_flat, predict_flat, CostParams, PAPER_TABLE1_36X1, PAPER_TABLE1_36X32};
use crate::mpi::{ops, run_scan, Topology, TransportBackend, WorldConfig};
use args::Args;

pub const USAGE: &str = "exscan — exclusive prefix sums (Träff 2025 reproduction)

USAGE: exscan <COMMAND> [FLAGS]

COMMANDS:
  table1    regenerate Table 1 on the simulated cluster
              --config 36x1|36x32   (default: both)
  sweep     dense m-sweep for Figure 1, writes CSV
              --config 36x1|36x32   (default: both)
              --out PATH            (default: figure1.csv)
              --quick               small grid
  calibrate fit the α-β-γ model to the embedded paper data
  predict   closed-form predictions for all algorithms
              --p N  --m N  --ranks-per-node N
              --topo SPEC  per-link predictions + topology-aware selection
                           (SPEC: flat:P | 2level:NxK | paper36x1;
                            --topo-seed N, default 1)
  run       run one algorithm on a real transport backend
              --algo NAME  --p N  --m N  --reps N
              --transport thread|shm|tcp|uds  (default: thread)
              --write-timeout-ms MS  per-write deadline for socket sends
                                     (default: 10000)
              --topo SPEC  run on the virtual clock priced by the per-link
                           matrix instead (p comes from the spec; the
                           two-level algo takes its node shape from it)
  trace     rounds, ⊕ counts and invariant check for one algorithm
              --algo NAME  --p N  --ranks-per-node N  --m N  --critical
  tune      print the cost-model-driven selection table
              --p LIST  --ranks-per-node N
  fuzz      differential chaos sweep: every exscan algorithm under a
            seeded adversarial message schedule, checked against the
            serial oracle and Theorem-1 counts (EXPERIMENTS.md §Chaos)
              --seed N    (default: 1)  --p-max P  (default: 64)
              --p LIST    pin exact world sizes (overrides --p-max grid)
              --m LIST    pin exact vector lengths
              --quick     small-p, small-m budget (the CI profile)
              --transport thread|shm|tcp|uds  (default: thread)
              --wire-fault-seed S  (wire backends only) also run the
                          wire-fault differential: seeded frame faults
                          injected below the chaos boundary; with
                          recovery the run must be bit-identical to the
                          thread oracle (nonzero retransmissions), and
                          with recovery off the same storm must fail as
                          a typed, attributed transport fault
            also runs the pinned pool steady-state and rank-death
            differential checks at the same seed
  serve     multi-tenant scan service demo: N independent small-m exscan
            requests through the batching engine, every result verified
            against its serial oracle, amortized rounds/request reported
            (EXPERIMENTS.md §Service)
              --requests N      (default: 256; 24 with --smoke)
              --batch-window US batching window in µs (default: 500)
              --p N  --m N  --algo NAME  --max-batch K
              --chaos-seed S    run the engine under seeded chaos and
                                differentially verify the service path
                                (plus the concurrent-communicator check)
              --soak N          repeat the workload for N waves through
                                one engine (sustained-load soak mode)
              --soak-requests N total request budget for the whole soak,
                                split evenly across the waves (overrides
                                --requests; env: EXSCAN_SOAK_REQUESTS)
              --write-timeout-ms MS  per-write deadline for the engine
                                worlds' socket sends (default: 10000)
              --wire-fault-seed S  arm seeded wire-frame faults (with
                                recovery) on the engine's worlds: every
                                result must still verify against its
                                oracle, and the wire recovery counters
                                are reported
              --kill-rank R     inject rank death: kill rank R once it
                                reaches chaos tick T (--kill-tick,
                                default 16); failed requests must come
                                back typed RankFailed, the engine must
                                rebuild its worlds live, and the
                                zero-lost-requests invariant must hold
              --smoke           small deterministic CI budget
              --transport thread|shm|tcp|uds  (default: thread)
  transports  list transport backends and probe availability on this host
              (exit 0; machine-readable `name available|unavailable` lines
              — CI uses this to gate its backend matrix)
  kernel-smoke  exercise the AOT PJRT kernel path
              --artifacts DIR       (default: artifacts)
  verify-claims run the full evaluation and check every §3 claim
  help      this text
";

/// Entry point used by `main`.
pub fn run_argv(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv)?;
    match args.subcommand.as_deref() {
        Some("table1") => cmd_table1(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("calibrate") => cmd_calibrate(),
        Some("predict") => cmd_predict(&args),
        Some("run") => cmd_run(&args),
        Some("trace") => cmd_trace(&args),
        Some("tune") => cmd_tune(&args),
        Some("fuzz") => cmd_fuzz(&args),
        Some("serve") => cmd_serve(&args),
        Some("transports") => cmd_transports(),
        Some("kernel-smoke") => cmd_kernel_smoke(&args),
        Some("verify-claims") => cmd_verify_claims(),
        Some("help") | None => {
            print!("{USAGE}");
            Ok(())
        }
        Some(other) => bail!("unknown command {other:?}\n{USAGE}"),
    }
}

/// Parse `--transport` (default `thread`) and probe the backend, so an
/// unavailable backend fails *here* with an attributed error — before any
/// world construction — rather than deep inside an engine rebuild.
fn transport_arg(args: &Args) -> Result<TransportBackend> {
    let backend: TransportBackend = match args.flag("transport") {
        None => TransportBackend::Thread,
        Some(s) => s.parse()?,
    };
    backend.probe()?;
    Ok(backend)
}

/// `exscan transports`: one `name available|unavailable [reason]` line per
/// backend. CI's backend matrix greps this to decide which backends the
/// runner can exercise (shm needs mmap; uds needs unix sockets; tcp needs
/// a bindable loopback). Available wire backends additionally run a tiny
/// recovered fault smoke and report its recovery counters — the
/// parenthetical rides after the `available` token CI matches on.
fn cmd_transports() -> Result<()> {
    for b in TransportBackend::all() {
        match b.probe() {
            Ok(()) => match wire_fault_smoke(b) {
                Some(detail) => println!("{} available ({detail})", b.name()),
                None => println!("{} available", b.name()),
            },
            Err(e) => println!("{} unavailable ({e:#})", b.name()),
        }
    }
    Ok(())
}

/// A 4-rank recovered fault smoke on one wire backend: storm-level
/// seeded injection with recovery on, output checked against the serial
/// oracle, recovery counters returned for the listing. `None` for the
/// thread backend, which has no wire layer to fault.
fn wire_fault_smoke(backend: TransportBackend) -> Option<String> {
    use crate::mpi::{WireFaultConfig, World};
    if backend == TransportBackend::Thread {
        return None;
    }
    const P: usize = 4;
    const M: usize = 16;
    const SEED: u64 = 7;
    let inputs = crate::bench::inputs_i64(P, M, SEED);
    let world: World<i64> = World::new(
        WorldConfig::new(Topology::flat(P))
            .with_transport(backend)
            .with_wire_faults(WireFaultConfig::storm(SEED)),
    );
    let op = ops::bxor();
    let run = world.run(|ctx| {
        let mut out = vec![0i64; M];
        crate::coll::Exscan123.run(ctx, &inputs[ctx.rank()], &mut out, &op)?;
        Ok(out)
    });
    let s = world.wire_stats();
    Some(match run {
        Ok(outs) => {
            let oracle = crate::coll::validate::oracle_exscan(&inputs, &op);
            let ok = (1..P).all(|r| Some(&outs[r]) == oracle[r].as_ref());
            format!(
                "fault-smoke {}: {} retransmits, {} reconnects, {} dups suppressed",
                if ok { "ok" } else { "MISMATCH" },
                s.retransmits,
                s.reconnects,
                s.dropped_dups
            )
        }
        Err(e) => format!("fault-smoke FAILED: {e:#}"),
    })
}

fn configs(args: &Args) -> Result<Vec<PaperConfig>> {
    match args.flag("config") {
        None => Ok(vec![PaperConfig::C36x1, PaperConfig::C36x32]),
        Some(s) => s
            .split(',')
            .map(|part| {
                PaperConfig::parse(part)
                    .ok_or_else(|| anyhow!("unknown config {part} (want 36x1 or 36x32)"))
            })
            .collect(),
    }
}

fn cmd_table1(args: &Args) -> Result<()> {
    for cfg in configs(args)? {
        let rows = table1_rows(cfg, &[1, 10, 100, 1000, 10_000, 100_000])?;
        println!("== Table 1, p = {} (simulated vs paper) ==", cfg.label());
        println!(
            "{:>8} | {:>10} {:>10} {:>10} {:>10} | {:>10} {:>10} {:>10} {:>10}",
            "m",
            "native",
            "two-op",
            "1-dbl",
            "123",
            "paper-nat",
            "paper-2op",
            "paper-1dbl",
            "paper-123"
        );
        for (row, paper) in rows.iter().zip(cfg.paper_rows()) {
            println!(
                "{:>8} | {:>10.2} {:>10.2} {:>10.2} {:>10.2} | {:>10.2} {:>10.2} {:>10.2} {:>10.2}",
                row.m,
                row.native,
                row.two_op,
                row.one_doubling,
                row.otd123,
                paper.1,
                paper.2,
                paper.3,
                paper.4
            );
        }
        println!();
    }
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let out: String = args.get("out", "figure1.csv".to_string())?;
    let spec = if args.switch("quick") { SweepSpec::quick() } else { SweepSpec::figure1() };
    let mut csv = String::new();
    for cfg in configs(args)? {
        let ms = figure1_sweep(cfg, &spec)?;
        println!("{}", format_table(&format!("Figure 1 sweep, {}", cfg.label()), &ms));
        let part = to_csv(cfg.label(), &ms);
        if csv.is_empty() {
            csv = part;
        } else {
            csv.push_str(part.split_once('\n').map(|x| x.1).unwrap_or(""));
        }
    }
    std::fs::write(&out, &csv)?;
    println!("wrote {out}");
    Ok(())
}

fn cmd_calibrate() -> Result<()> {
    for data in [&PAPER_TABLE1_36X1, &PAPER_TABLE1_36X32] {
        let rep = fit_flat(data, 8);
        println!("== calibration {} ==", rep.label);
        println!("portable: {:#?}", rep.params);
        println!("native:   {:#?}", rep.native_params);
        println!(
            "rel RMSE: portable {:.1}%, native {:.1}%",
            rep.rel_rmse * 100.0,
            rep.native_rel_rmse * 100.0
        );
        println!();
    }
    Ok(())
}

fn cmd_predict(args: &Args) -> Result<()> {
    let m: usize = args.get("m", 1000)?;
    if let Some(spec) = args.flag("topo") {
        let spec = spec.to_string();
        return cmd_predict_topo(args, &spec, m);
    }
    let p: usize = args.get("p", 36)?;
    let rpn: usize = args.get("ranks-per-node", 1)?;
    let params = CostParams::paper_36x1();
    println!("closed-form α-β-γ predictions (p={p}, m={m}, {rpn} ranks/node):");
    println!("{:>18} {:>8} {:>6} {:>12}", "algorithm", "rounds", "ops", "time (µs)");
    for algo in all_exscan_algorithms::<i64>() {
        // critical_schedule is m-aware: m-dependent algorithms (chunked,
        // pipelined chain) report their real round count and per-message
        // payload instead of the per-chunk closed forms.
        let (skips, ops, msg_elems) = algo.critical_schedule(p, m);
        let pred = predict_flat(&skips, ops, p, rpn, msg_elems * 8, &params);
        println!(
            "{:>18} {:>8} {:>6} {:>12.2}",
            algo.name(),
            pred.rounds,
            pred.ops,
            pred.time_us
        );
    }
    let best = select_exscan::<i64>(p, m, &params, rpn);
    println!("selected: {}", best.name());
    Ok(())
}

/// `exscan predict --topo SPEC`: per-link closed forms for every flat
/// candidate plus the phase-composed two-level prediction, and the
/// topology-aware selection winner.
fn cmd_predict_topo(args: &Args, spec: &str, m: usize) -> Result<()> {
    use crate::cost::{predict_flat_topo, predict_two_level};
    let seed: u64 = args.get("topo-seed", 1u64)?;
    let topo = crate::topo::Topo::parse(spec, seed)?;
    let p = topo.size();
    println!(
        "per-link α-β-γ predictions on {} (p={p}, m={m}, seed {seed}, \
         digest {:#018x}):",
        topo.name(),
        topo.matrix_digest()
    );
    println!(
        "{:>18} {:>8} {:>6} {:>6} {:>12}",
        "algorithm", "rounds", "ops", "inter", "time (µs)"
    );
    for algo in all_exscan_algorithms::<i64>() {
        if algo.name() == "two-level" {
            continue; // priced below with the topology's own node shape
        }
        let (skips, ops, msg_elems) = algo.critical_schedule(p, m);
        let pred = predict_flat_topo(&skips, ops, msg_elems * 8, &topo);
        println!(
            "{:>18} {:>8} {:>6} {:>6} {:>12.2}",
            algo.name(),
            pred.rounds,
            pred.ops,
            pred.inter_rounds,
            pred.time_us
        );
    }
    if topo.is_hierarchical() {
        let pred = predict_two_level(&topo, m * 8);
        println!(
            "{:>18} {:>8} {:>6} {:>6} {:>12.2}",
            "two-level", pred.rounds, pred.ops, pred.inter_rounds, pred.time_us
        );
    }
    let best = crate::coll::select_exscan_topo::<i64>(p, m, &topo);
    println!("selected: {}", best.name());
    Ok(())
}

fn cmd_run(args: &Args) -> Result<()> {
    let name: String = args.get("algo", "123-doubling".to_string())?;
    if let Some(spec) = args.flag("topo") {
        let spec = spec.to_string();
        return cmd_run_topo(args, &name, &spec);
    }
    let p: usize = args.get("p", 36)?;
    let m: usize = args.get("m", 1000)?;
    let reps: usize = args.get("reps", 20)?;
    let algo: Box<dyn ScanAlgorithm<i64>> =
        exscan_by_name(&name).ok_or_else(|| anyhow!("unknown algorithm {name}"))?;
    let backend = transport_arg(args)?;
    let write_timeout_ms: u64 = args.get("write-timeout-ms", 10_000u64)?;
    let world = WorldConfig::new(Topology::flat(p))
        .with_transport(backend)
        .with_write_timeout(std::time::Duration::from_millis(write_timeout_ms));
    let bench = BenchConfig { warmups: 3, reps, validate: true };
    let inputs = crate::bench::inputs_i64(p, m, 1);
    let meas =
        crate::bench::measure_exscan(&world, &bench, algo.as_ref(), &ops::bxor(), &inputs)?;
    println!(
        "{} p={p} m={m} transport={backend}: min {:.2} µs, mean {:.2} µs (±{:.2}), \
         {} reps — output verified",
        meas.algo, meas.min_us, meas.mean_us, meas.stddev_us, meas.reps
    );
    Ok(())
}

/// `exscan run --topo SPEC`: one collective on a virtual-clock world
/// priced by the per-link matrix, oracle-verified, with the modeled
/// completion time and traced round count. The world size comes from the
/// spec; `--algo two-level` takes its node shape from the matrix.
fn cmd_run_topo(args: &Args, name: &str, spec: &str) -> Result<()> {
    use std::sync::Arc;
    let seed: u64 = args.get("topo-seed", 1u64)?;
    let m: usize = args.get("m", 1000)?;
    let topo = Arc::new(crate::topo::Topo::parse(spec, seed)?);
    let p = topo.size();
    let algo: Box<dyn ScanAlgorithm<i64>> = if name == "two-level" {
        Box::new(crate::coll::ExscanTwoLevel::new(topo.ranks_per_node()))
    } else {
        exscan_by_name(name).ok_or_else(|| anyhow!("unknown algorithm {name}"))?
    };
    let cfg = WorldConfig::new(Topology::flat(p))
        .virtual_clock_topo(topo.clone())
        .with_trace(true);
    let inputs = crate::bench::inputs_i64(p, m, 1);
    let res = run_scan(&cfg, algo.as_ref(), &ops::bxor(), &inputs)?;
    crate::coll::validate::assert_exscan_matches(&inputs, &ops::bxor(), &res.outputs);
    let trace = res.trace.expect("tracing enabled");
    let violations = crate::trace::check_all(&trace);
    anyhow::ensure!(violations.is_empty(), "{} invariant violations", violations.len());
    println!(
        "{} on {} (seed {seed}, digest {:#018x}) p={p} m={m}: \
         {:.2} µs virtual completion, {} rounds — output verified",
        algo.name(),
        topo.name(),
        topo.matrix_digest(),
        res.completion_us(),
        trace.total_rounds()
    );
    Ok(())
}

fn cmd_trace(args: &Args) -> Result<()> {
    let name: String = args.get("algo", "123-doubling".to_string())?;
    let p: usize = args.get("p", 36)?;
    let rpn: usize = args.get("ranks-per-node", 1)?;
    let m: usize = args.get("m", 4)?;
    let algo: Box<dyn ScanAlgorithm<i64>> =
        exscan_by_name(&name).ok_or_else(|| anyhow!("unknown algorithm {name}"))?;
    anyhow::ensure!(p % rpn == 0, "p must be divisible by ranks-per-node");
    let topo = Topology::cluster(p / rpn, rpn);
    let world = WorldConfig::new(topo).with_trace(true);
    let inputs = crate::bench::inputs_i64(p, m, 1);
    let res = run_scan(&world, algo.as_ref(), &ops::bxor(), &inputs)?;
    let trace = res.trace.expect("tracing enabled");
    let violations = crate::trace::check_all(&trace);
    println!("algorithm: {}", algo.name());
    println!("p = {p}");
    println!(
        "communication rounds: {} (predicted {})",
        trace.total_rounds(),
        algo.predicted_rounds_m(p, m)
    );
    println!(
        "⊕ applications: last rank {} (predicted {}), max over ranks {}",
        trace.last_rank_ops(),
        algo.predicted_ops(p),
        trace.max_ops()
    );
    println!("messages: {}, bytes: {}", trace.total_messages(), trace.total_bytes());
    if violations.is_empty() {
        println!("one-ported + matching invariants: OK");
    } else {
        for v in &violations {
            println!("VIOLATION: {v}");
        }
        bail!("{} invariant violations", violations.len());
    }
    if args.switch("critical") {
        use crate::cost::CostModel;
        let params = CostParams::paper_36x1();
        let model = CostModel::new(params, rpn);
        let cp = crate::trace::critical_path(&trace, &model, m * 8);
        println!(
            "\ncritical path (α-β-γ, {} bytes): completes at {:.2} µs on rank {}",
            m * 8,
            cp.completion_us + params.overhead,
            cp.final_rank
        );
        println!(
            "{} comm rounds ({} inter-node) + {} ⊕ on the chain:",
            cp.comm_rounds(),
            cp.inter_rounds(),
            cp.reduce_hops()
        );
        for h in &cp.hops {
            let what = match (h.from, h.link) {
                (Some(f), Some(l)) => format!("round {:>2}: rank {:>4} ← {:>4} ({l:?})", h.round, h.rank, f),
                _ => format!("round {:>2}: rank {:>4} ⊕", h.round, h.rank),
            };
            let waited = if h.wait_us > 0.0 {
                format!("  (waited {:.3} µs)", h.wait_us)
            } else {
                String::new()
            };
            println!("  {what:<44} +{:>7.3} µs  @ {:>8.3} µs{waited}", h.cost_us, h.at_us);
        }
    }
    Ok(())
}

fn cmd_tune(args: &Args) -> Result<()> {
    let ps = args.get_list("p", &[4, 16, 36, 64, 256, 1024, 1152])?;
    let rpn: usize = args.get("ranks-per-node", 1)?;
    let table = TuningTable::build(ps, CostParams::paper_36x1(), rpn);
    print!("{:>8}", "p\\bytes");
    for &b in &table.size_buckets {
        print!(" {b:>10}");
    }
    println!();
    for (pi, &p) in table.p_buckets.iter().enumerate() {
        print!("{p:>8}");
        for c in &table.choice[pi] {
            let short = match *c {
                "123-doubling" => "123",
                "two-op-doubling" => "2op",
                "1-doubling" => "1dbl",
                "pipelined-chain" => "pipe",
                "block-exscan" => "blk",
                other => other,
            };
            print!(" {short:>10}");
        }
        println!();
    }
    Ok(())
}

/// Differential chaos fuzzing (EXPERIMENTS.md §Chaos): every registered
/// exscan algorithm × {bxor, sum_i64, rec2_compose, and the lifted
/// segmented seg_bxor/seg_sum over `Seg<i64>`} × m grid × p grid
/// under a seeded adversarial message schedule, on persistent executors.
/// Any failure prints with its seed; the same seed replays the identical
/// injected schedule.
fn cmd_fuzz(args: &Args) -> Result<()> {
    let seed: u64 = args.get("seed", 1u64)?;
    let p_max: usize = args.get("p-max", 64)?;
    let quick = args.switch("quick");

    let mut default_ps: Vec<usize> = (2..=9).filter(|&p| p <= p_max).collect();
    if !quick {
        let mut p = 16;
        while p <= p_max {
            default_ps.push(p);
            p *= 2;
        }
        if !default_ps.contains(&p_max) && p_max >= 2 {
            default_ps.push(p_max);
        }
    }
    // --p / --m pin the exact grid — the replay path printed by failure
    // labels (`exscan fuzz --seed N --p P --m M`) re-runs precisely the
    // failing case's world and vector length, whatever harness produced
    // it.
    let p_values = args.get_list("p", &default_ps)?;
    anyhow::ensure!(
        !p_values.is_empty() && p_values.iter().all(|&p| p >= 2),
        "need world sizes >= 2 (got {p_values:?})"
    );
    let default_ms: Vec<usize> =
        if quick { vec![0, 1, 17, 1024] } else { vec![0, 1, 17, 4096] };
    let m_values = args.get_list("m", &default_ms)?;
    let backend = transport_arg(args)?;

    println!(
        "chaos fuzz: seed={seed}, p ∈ {p_values:?}, m ∈ {m_values:?}, \
         transport={backend} (all exscan algorithms × {{bxor_i64, sum_i64, \
         rec2_compose, seg_bxor_i64, seg_sum_i64}})"
    );
    let out = crate::coll::validate::chaos_fuzz_on(backend, seed, &p_values, &m_values);
    println!(
        "{} cases; injected: {} delayed, {} diverted, {} yields, {} dropped \
         (schedule digest {:#018x})",
        out.cases, out.delayed, out.diverted, out.yields, out.dropped, out.schedule_digest
    );

    let pool = crate::coll::validate::chaos_pool_steady_state(seed);
    match &pool {
        Ok(()) => println!("pool steady state under chaos: zero-allocation OK"),
        Err(e) => println!("pool steady state under chaos: FAIL ({e})"),
    }

    // Pinned at p = 6 regardless of the grid: the check is about failure
    // *attribution* (typed rank-death, poison wake, registry contents),
    // not about scaling, and a fixed size keeps the repro seed-only.
    let rd = crate::coll::validate::rank_death_differential(seed, 6);
    match &rd {
        Ok(()) => println!("rank-death differential (p=6): attributed + oracle-clean"),
        Err(e) => println!("rank-death differential (p=6): FAIL ({e})"),
    }

    // ── Wire-fault differential (EXPERIMENTS.md §Robustness): frame
    // faults injected *below* the chaos boundary; recovery-enabled runs
    // must be bit-identical to the thread oracle, recovery-disabled runs
    // must fail typed and attributed — never panic. ──
    let mut wf_failures = 0usize;
    if let Some(s) = args.flag("wire-fault-seed") {
        let wf_seed: u64 =
            s.parse().map_err(|_| anyhow!("--wire-fault-seed: cannot parse {s:?}"))?;
        anyhow::ensure!(
            backend != TransportBackend::Thread,
            "--wire-fault-seed needs a wire backend (--transport shm|tcp|uds); \
             the thread backend has no wire layer to fault"
        );
        // Wire worlds are OS-thread meshes per rank; keep the sweep to
        // small sizes (the machinery, not the scaling, is under test).
        let wf_ps: Vec<usize> = p_values.iter().copied().filter(|&p| p <= 8).collect();
        anyhow::ensure!(!wf_ps.is_empty(), "wire-fault differential needs a p <= 8");
        let wf_ms: Vec<usize> =
            m_values.iter().copied().filter(|&m| m <= 1024).collect();
        let wf = crate::coll::validate::wire_fault_differential(
            backend, wf_seed, &wf_ps, &wf_ms,
        );
        println!(
            "wire-fault differential: {} cases, {} injected; {} retransmits, \
             {} reconnects, {} dups suppressed (fault digest {:#018x})",
            wf.cases, wf.injected, wf.retransmits, wf.reconnects, wf.dropped_dups,
            wf.fault_digest
        );
        for f in &wf.failures {
            println!("FAIL {f}");
        }
        wf_failures += wf.failures.len();
        match crate::coll::validate::wire_fault_no_recovery(backend, wf_seed, 4) {
            Ok(()) => println!(
                "wire-fault no-recovery (p=4): typed transport fault, attributed, \
                 no panic"
            ),
            Err(e) => {
                println!("wire-fault no-recovery (p=4): FAIL ({e})");
                wf_failures += 1;
            }
        }
    }

    if out.failures.is_empty() && pool.is_ok() && rd.is_ok() && wf_failures == 0 {
        println!("all cases bit-identical to oracle with Theorem-1 counts");
        Ok(())
    } else {
        for f in &out.failures {
            println!("FAIL {f}");
        }
        bail!(
            "{} chaos-fuzz failure(s); reproduce with `exscan fuzz --seed {seed}{}{}`",
            out.failures.len()
                + usize::from(pool.is_err())
                + usize::from(rd.is_err())
                + wf_failures,
            if quick { " --quick" } else { "" },
            if backend == TransportBackend::Thread {
                String::new()
            } else {
                format!(" --transport {backend}")
            }
        )
    }
}

/// The multi-tenant scan service demo and verification driver: submit N
/// independent small-m exscan requests (a deterministic mix of full-world
/// batches across two operators and sub-range requests that exercise the
/// segmented-lane coalescer), wait on every nonblocking handle, verify
/// each result bit-exactly against its serial oracle, and report the
/// amortized rounds/request the batcher achieved. With `--chaos-seed`,
/// the engine's worlds run under seeded fault injection, making the same
/// oracle check the *service chaos differential* (integer operators are
/// exactly associative, so the serial-clean-world reference and the
/// oracle coincide bit for bit); the concurrent-communicator differential
/// (`validate::chaos_concurrent_comms`) runs on top.
///
/// `--soak N` repeats the workload for N waves through one engine
/// (sustained load through the batching/backpressure path), and
/// `--kill-rank R` arms rank-death injection: once rank R reaches chaos
/// tick `--kill-tick` (a per-rank count of chaos decision points — low
/// values fire within the first batch), it dies mid-collective. Requests
/// caught in the dying wave must come back typed
/// [`SvcError::RankFailed`](crate::svc::SvcError) naming the victim, the
/// engine must rebuild its worlds live, later waves must verify against
/// the oracle again, and `submitted == completed + failed` must hold at
/// quiesce (EXPERIMENTS.md §Robustness).
fn cmd_serve(args: &Args) -> Result<()> {
    use std::time::{Duration, Instant};

    use crate::coll::validate::chaos_concurrent_comms;
    use crate::coll::validate::oracle_exscan;
    use crate::mpi::{ChaosConfig, WireFaultConfig};
    use crate::svc::{BatchPolicy, EngineConfig, ReqOp, ScanEngine, ScanRequest, SvcError};

    let smoke = args.switch("smoke");
    let p: usize = args.get("p", 8)?;
    let mut requests: usize = {
        let n = args.get("requests", if smoke { 24 } else { 256 })?;
        if smoke {
            n.min(24)
        } else {
            n
        }
    };
    let m: usize = args.get("m", 16)?;
    let window_us: u64 = args.get("batch-window", 500)?;
    let max_batch: usize = args.get("max-batch", 64)?;
    let algo: String = args.get("algo", "123-doubling".to_string())?;
    let chaos_seed: Option<u64> = match args.flag("chaos-seed") {
        None => None,
        Some(s) => {
            Some(s.parse().map_err(|_| anyhow!("--chaos-seed: cannot parse {s:?}"))?)
        }
    };
    let waves: usize = args.get("soak", 1)?;
    let kill_rank: Option<usize> = match args.flag("kill-rank") {
        None => None,
        Some(s) => {
            Some(s.parse().map_err(|_| anyhow!("--kill-rank: cannot parse {s:?}"))?)
        }
    };
    let kill_tick: u64 = args.get("kill-tick", 16u64)?;
    anyhow::ensure!(p >= 4, "serve needs p >= 4 (got {p})");
    anyhow::ensure!(waves >= 1, "--soak needs at least one wave");
    // Explicit soak request budget: total requests over the whole soak,
    // split evenly across the waves. The flag wins over the
    // EXSCAN_SOAK_REQUESTS env; either overrides --requests (the road to
    // the million-request soak without a command-line forest).
    let soak_budget: Option<usize> = match args.flag("soak-requests") {
        Some(s) => {
            Some(s.parse().map_err(|_| anyhow!("--soak-requests: cannot parse {s:?}"))?)
        }
        None => match std::env::var("EXSCAN_SOAK_REQUESTS") {
            Ok(v) => Some(
                v.parse()
                    .map_err(|_| anyhow!("EXSCAN_SOAK_REQUESTS: cannot parse {v:?}"))?,
            ),
            Err(_) => None,
        },
    };
    if let Some(budget) = soak_budget {
        anyhow::ensure!(budget >= 1, "the soak request budget must be at least 1");
        requests = (budget / waves).max(1);
        if smoke {
            requests = requests.min(24);
        }
    }
    if let Some(r) = kill_rank {
        anyhow::ensure!(r < p, "--kill-rank {r} out of range for p={p}");
    }

    let backend = transport_arg(args)?;
    let write_timeout_ms: u64 = args.get("write-timeout-ms", 10_000u64)?;
    let wf_seed: Option<u64> = match args.flag("wire-fault-seed") {
        None => None,
        Some(s) => Some(
            s.parse().map_err(|_| anyhow!("--wire-fault-seed: cannot parse {s:?}"))?,
        ),
    };
    if wf_seed.is_some() {
        anyhow::ensure!(
            backend != TransportBackend::Thread,
            "--wire-fault-seed needs a wire backend (--transport shm|tcp|uds); \
             the thread backend has no wire layer to fault"
        );
    }
    let mut cfg = EngineConfig::new(p)
        .with_algo(&algo)
        .with_transport(backend)
        .with_write_timeout(Duration::from_millis(write_timeout_ms))
        .with_policy(BatchPolicy {
            window: Duration::from_micros(window_us),
            max_batch,
            ..Default::default()
        });
    if let Some(s) = wf_seed {
        cfg = cfg.with_wire_faults(WireFaultConfig::new(s));
    }
    let mut chaos = chaos_seed.map(ChaosConfig::new);
    if let Some(r) = kill_rank {
        // Without --chaos-seed the death is the *only* injected fault
        // (delay/divert/yield off), so every failure must be attributed
        // RankFailed — a generic timeout here is a bug, not bad luck.
        let base = chaos.take().unwrap_or_else(|| {
            ChaosConfig::new(0xDEAD)
                .with_delay_prob(0.0)
                .with_divert_prob(0.0)
                .with_yield_prob(0.0)
        });
        chaos = Some(base.with_rank_death(r, kill_tick));
    }
    if let Some(c) = chaos {
        cfg = cfg.with_chaos(c);
    }
    let engine = ScanEngine::<i64>::new(cfg).map_err(|e| anyhow!("{e}"))?;
    println!(
        "scan service: {requests} requests × {waves} wave(s), p={p}, m={m}, algo={algo}, \
         transport={backend}, window={window_us}µs, max-batch={max_batch}{}{}",
        match chaos_seed {
            Some(s) => format!(", chaos seed {s}"),
            None => String::new(),
        },
        match kill_rank {
            Some(r) => format!(", kill rank {r} at tick {kill_tick}"),
            None => String::new(),
        }
    );
    if let Some(s) = wf_seed {
        println!(
            "wire faults armed (seed {s}, recovery on): every result must still \
             verify bit-exactly against its oracle"
        );
    }

    // Deterministic mixed workload; expected results precomputed from the
    // serial oracle (bit-exact for these integer operators). Each wave
    // submits, flushes, and drains before the next — closed-loop, so a
    // rank death fails at most the in-flight wave and the post-rebuild
    // waves prove the engine recovered.
    let seed_base = chaos_seed.unwrap_or(0xCAFE);
    let total = waves * requests;
    let mut verified = 0usize;
    let mut death_failed = 0usize;
    for wave in 0..waves {
        let mut handles = Vec::with_capacity(requests);
        let mut expected = Vec::with_capacity(requests);
        for i in 0..requests {
            let g = wave * requests + i;
            let rseed = seed_base ^ (g as u64 + 1).wrapping_mul(0x9E37_79B9);
            let (req, oracle) = if i % 3 == 2 {
                // Sub-range request: exercises segmented lanes / solo plans.
                let start = i % (p / 2);
                let span = 2 + i % (p - start - 1).max(1).min(3);
                let inputs = crate::bench::inputs_i64(span, m, rseed);
                let oracle = oracle_exscan(&inputs, &ops::sum_i64());
                (ScanRequest::over(ReqOp::sum_i64(), start, inputs), oracle)
            } else if i % 2 == 0 {
                let inputs = crate::bench::inputs_i64(p, m, rseed);
                let oracle = oracle_exscan(&inputs, &ops::bxor());
                (ScanRequest::full(ReqOp::bxor_i64(), inputs), oracle)
            } else {
                let inputs = crate::bench::inputs_i64(p, m, rseed);
                let oracle = oracle_exscan(&inputs, &ops::sum_i64());
                (ScanRequest::full(ReqOp::sum_i64(), inputs), oracle)
            };
            handles.push(engine.submit(req).map_err(|e| anyhow!("submit {g}: {e}"))?);
            expected.push(oracle);
        }
        engine.flush();

        for (i, (h, oracle)) in handles.into_iter().zip(expected).enumerate() {
            match h.wait_timeout(Duration::from_secs(120)) {
                Ok(out) => {
                    for (r, want) in oracle.iter().enumerate() {
                        if let Some(want) = want {
                            anyhow::ensure!(
                                &out.outputs[r] == want,
                                "wave {wave} request {i}: member {r} diverged \
                                 from serial oracle"
                            );
                        }
                    }
                    verified += 1;
                }
                Err(SvcError::RankFailed { rank, .. }) if kill_rank.is_some() => {
                    anyhow::ensure!(
                        Some(rank) == kill_rank,
                        "wave {wave} request {i}: death attributed to rank {rank}, \
                         expected {kill_rank:?}"
                    );
                    death_failed += 1;
                }
                Err(e) => bail!("wave {wave} request {i} failed: {e}"),
            }
        }
    }

    // `completed` is bumped after the handles are fulfilled, so give the
    // dispatcher a beat to finish its accounting before gating on it.
    let ms = {
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            let s = engine.metrics();
            if (s.submitted == s.completed + s.failed && s.inflight_bytes == 0)
                || Instant::now() >= deadline
            {
                break s;
            }
            std::thread::sleep(Duration::from_micros(200));
        }
    };
    println!(
        "verified {verified}/{total} against the serial oracle{}",
        if chaos_seed.is_some() { " (under chaos)" } else { "" }
    );
    if kill_rank.is_some() {
        println!(
            "rank-death: {death_failed} request(s) failed typed RankFailed, \
             {} world rebuild(s), engine kept serving",
            ms.worlds_rebuilt
        );
    }
    println!(
        "batches: {} ({} concat, {} segmented, {} solo); coalesced elems/rank total: {}",
        ms.batches, ms.concat_batches, ms.segmented_batches, ms.solo_batches, ms.coalesced_elems
    );
    println!(
        "rounds paid: {} vs {} solo-equivalent → amortization {:.2}x, \
         {:.3} rounds/request",
        ms.rounds_paid,
        ms.rounds_solo_equiv,
        ms.round_amortization,
        ms.amortized_rounds_per_request
    );
    println!(
        "latency: p50 {:.1} µs, p99 {:.1} µs, p999 {:.1} µs over {} completions",
        ms.latency_p50_us, ms.latency_p99_us, ms.latency_p999_us, ms.latency_count
    );
    let wire_active = ms.wire_retransmits
        + ms.wire_reconnects
        + ms.wire_dropped_dups
        + ms.transport_faults;
    if wf_seed.is_some() || wire_active > 0 {
        println!(
            "wire recovery: {} retransmits, {} reconnects, {} dups suppressed, \
             {} typed faults",
            ms.wire_retransmits, ms.wire_reconnects, ms.wire_dropped_dups,
            ms.transport_faults
        );
    }
    if wf_seed.is_some() {
        anyhow::ensure!(
            ms.wire_retransmits + ms.wire_reconnects + ms.wire_dropped_dups >= 1,
            "wire faults were armed but the recovery layer never acted — \
             the self-healing run proved nothing"
        );
    }
    anyhow::ensure!(
        ms.submitted == ms.completed + ms.failed,
        "lost requests: submitted {} != completed {} + failed {}",
        ms.submitted,
        ms.completed,
        ms.failed
    );
    anyhow::ensure!(
        ms.inflight_bytes == 0,
        "inflight-bytes gauge must drain to 0 at quiesce (got {})",
        ms.inflight_bytes
    );
    if kill_rank.is_some() {
        anyhow::ensure!(
            ms.rank_failures >= 1,
            "--kill-rank produced no attributed failure; raise --soak or \
             lower --kill-tick so the victim reaches its death tick"
        );
        anyhow::ensure!(
            ms.worlds_rebuilt >= 1,
            "rank death must trigger a live world rebuild"
        );
        anyhow::ensure!(
            ms.rank_failures == ms.failed,
            "every failure under rank-death injection must be typed RankFailed \
             ({} of {} were)",
            ms.rank_failures,
            ms.failed
        );
    } else {
        anyhow::ensure!(ms.failed == 0, "{} requests failed", ms.failed);
        anyhow::ensure!(
            ms.round_amortization >= 1.0 - 1e-9,
            "coalescing must never pay more rounds than solo execution"
        );
    }

    if let Some(seed) = chaos_seed {
        chaos_concurrent_comms(seed, 8)
            .map_err(|e| anyhow!("concurrent-communicator differential: {e}"))?;
        println!(
            "concurrent-communicator differential (8 in-flight collectives, seed {seed}): OK"
        );
    }
    Ok(())
}

/// Experiment E5: run both Table-1 grids and machine-check every claim
/// the paper's §3 makes, printing a PASS/FAIL report.
fn cmd_verify_claims() -> Result<()> {
    let grid = [1usize, 10, 100, 1000, 10_000, 100_000];
    let mut failures = 0usize;
    let mut check = |name: &str, ok: bool, detail: String| {
        println!("{} {name}: {detail}", if ok { "PASS" } else { "FAIL" });
        if !ok {
            failures += 1;
        }
    };

    let rows36 = table1_rows(PaperConfig::C36x1, &grid)?;
    let rows1152 = table1_rows(PaperConfig::C36x32, &grid)?;

    // 1. "1-doubling … sometimes on par with 123, but never better."
    let never_better = rows36
        .iter()
        .chain(&rows1152)
        .all(|r| r.otd123 <= r.one_doubling + 1e-9);
    check("123 never loses to 1-doubling (both configs, all m)", never_better, String::new());

    // 2. ~25% improvement over native at m = 10^4, 36x1.
    let mid = rows36.iter().find(|r| r.m == 10_000).unwrap();
    let imp = (mid.native - mid.otd123) / mid.native * 100.0;
    check(
        "native→123 improvement at m=10⁴ (paper: 25%)",
        imp > 20.0,
        format!("{imp:.1}%"),
    );

    // 3. two-⊕'s extra applications hurt at large m (both configs).
    let big36 = rows36.iter().find(|r| r.m == 100_000).unwrap();
    let big1152 = rows1152.iter().find(|r| r.m == 100_000).unwrap();
    check(
        "two-⊕ penalty at m=10⁵",
        big36.two_op > big36.otd123 && big1152.two_op > big1152.otd123,
        format!(
            "36x1: {:.0} vs {:.0}; 36x32: {:.0} vs {:.0}",
            big36.two_op, big36.otd123, big1152.two_op, big1152.otd123
        ),
    );

    // 4. "For very small m, [two-⊕] is sometimes the best."
    let small1152 = rows1152.iter().find(|r| r.m == 1).unwrap();
    let two_op_best = small1152.two_op <= small1152.otd123
        && small1152.two_op <= small1152.one_doubling
        && small1152.two_op <= small1152.native;
    check(
        "two-⊕ best at m=1 on 36x32 (as in the paper)",
        two_op_best,
        format!("{:.2} µs", small1152.two_op),
    );

    // 5. "MPI_Exscan … can be significantly improved" — 123 beats native
    //    at every m >= 1000 in both configurations.
    let improved = rows36
        .iter()
        .chain(&rows1152)
        .filter(|r| r.m >= 1000)
        .all(|r| r.otd123 < r.native);
    check("123 beats native at every m ≥ 1000 (both configs)", improved, String::new());

    // 6. Theorem 1 round counts at the paper's sizes.
    use crate::coll::Exscan123;
    let a: &dyn ScanAlgorithm<i64> = &Exscan123;
    check(
        "Theorem 1 round counts (p=36: 6, p=1152: 11)",
        a.predicted_rounds(36) == 6 && a.predicted_rounds(1152) == 11,
        format!("{} / {}", a.predicted_rounds(36), a.predicted_rounds(1152)),
    );

    println!();
    if failures == 0 {
        println!("all §3 claims reproduced");
        Ok(())
    } else {
        bail!("{failures} claim(s) failed")
    }
}

fn cmd_kernel_smoke(args: &Args) -> Result<()> {
    use crate::runtime::{pjrt_bxor_i64, PjrtRuntime};
    let artifacts: String = args.get("artifacts", "artifacts".to_string())?;
    let handle = PjrtRuntime::start(artifacts)?;
    // Direct kernel check.
    let mut inout = vec![0b1100i64, 7, -1, 0];
    handle.reduce_i64("bxor_i64", &[0b1010, 1, 2, 3], &mut inout)?;
    anyhow::ensure!(inout == vec![0b0110, 6, -3, 3], "kernel numerics: {inout:?}");
    println!("reduce_local kernel: OK ({inout:?})");
    // Full collective with the compiled kernel as ⊕.
    let p = 12;
    let m = 100;
    let op = pjrt_bxor_i64(handle.clone());
    let world = WorldConfig::new(Topology::flat(p));
    let inputs = crate::bench::inputs_i64(p, m, 2);
    let res = run_scan(&world, &crate::coll::Exscan123, &op, &inputs)?;
    crate::coll::validate::assert_exscan_matches(&inputs, &ops::bxor(), &res.outputs);
    let stats = handle.stats()?;
    println!(
        "123-doubling with PJRT ⊕ over p={p}, m={m}: verified; {} kernel launches, {} compiles",
        stats.launches, stats.compiles
    );
    Ok(())
}
