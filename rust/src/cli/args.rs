//! A minimal `--flag value` / `--switch` argument parser (offline-build
//! replacement for `clap`).

use std::collections::HashMap;

use anyhow::{anyhow, bail, Result};

/// Parsed arguments: one subcommand, named flags, boolean switches.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    flags: HashMap<String, String>,
    switches: Vec<String>,
}

impl Args {
    /// Parse `argv[1..]`: first bare word is the subcommand; `--name value`
    /// pairs become flags; a `--name` followed by another `--…` (or end of
    /// input) is a boolean switch.
    pub fn parse(argv: &[String]) -> Result<Self> {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                // `--name=value` form
                if let Some((n, v)) = name.split_once('=') {
                    out.flags.insert(n.to_string(), v.to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    out.flags.insert(name.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    out.switches.push(name.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(a.clone());
            } else {
                bail!("unexpected positional argument {a:?}");
            }
            i += 1;
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn switch(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }

    /// Typed flag with default.
    pub fn get<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T> {
        match self.flags.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("flag --{name}: cannot parse {v:?}")),
        }
    }

    /// Comma-separated list flag.
    pub fn get_list(&self, name: &str, default: &[usize]) -> Result<Vec<usize>> {
        match self.flags.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|x| x.trim().parse().map_err(|_| anyhow!("flag --{name}: bad entry {x:?}")))
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(&s.iter().map(|x| x.to_string()).collect::<Vec<_>>()).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse(&["table1", "--config", "36x1", "--quick"]);
        assert_eq!(a.subcommand.as_deref(), Some("table1"));
        assert_eq!(a.flag("config"), Some("36x1"));
        assert!(a.switch("quick"));
        assert!(!a.switch("nope"));
    }

    #[test]
    fn equals_form() {
        let a = parse(&["run", "--p=36", "--m=100"]);
        assert_eq!(a.get("p", 0usize).unwrap(), 36);
        assert_eq!(a.get("m", 0usize).unwrap(), 100);
    }

    #[test]
    fn typed_defaults() {
        let a = parse(&["run"]);
        assert_eq!(a.get("p", 42usize).unwrap(), 42);
        assert_eq!(a.get_list("ps", &[1, 2]).unwrap(), vec![1, 2]);
    }

    #[test]
    fn list_flag() {
        let a = parse(&["tune", "--p", "4,16,64"]);
        assert_eq!(a.get_list("p", &[]).unwrap(), vec![4, 16, 64]);
    }

    #[test]
    fn bad_parse_errors() {
        let a = parse(&["run", "--p", "abc"]);
        assert!(a.get("p", 0usize).is_err());
    }

    #[test]
    fn double_positional_rejected() {
        let argv: Vec<String> = vec!["a".into(), "b".into()];
        assert!(Args::parse(&argv).is_err());
    }
}
