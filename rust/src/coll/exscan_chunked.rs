//! Chunked-pipeline exclusive scan for **large vectors** on the doubling
//! skeleton — the workload the paper defers to "pipelined, fixed-degree
//! tree" algorithms, opened here on top of the 1-doubling round structure
//! (LightScan-style chunking, arXiv 1604.04815).
//!
//! `⊕` is element-wise, so an m-element exscan is C independent exscans
//! over fixed-size chunks. Within every doubling round the rank posts
//! chunk `i`'s send (a non-blocking pooled deposit), then blocks in the
//! fused [`sendrecv_reduce`](crate::mpi::RankCtx::sendrecv_reduce) for
//! chunk `i`'s receive — so while this rank reduces chunk `i`, its
//! successor already holds chunk `i`'s message, and the send of chunk
//! `i+1` overlaps the successor's reduce of chunk `i`. The flat algorithms
//! serialize the whole m-element reduce behind the whole m-element
//! receive; here both streams at chunk granularity, keeping the working
//! set L1-resident ([`DEFAULT_CHUNK_ELEMS`]) and the pipeline full.
//!
//! Each (round, chunk) pair gets its own one-ported round tag, so the
//! trace invariants hold unchanged and the honest round count is
//! `q(p) · C` ([`rounds_for`](ExscanChunked::rounds_for)) — chunking buys
//! bandwidth/compute overlap, not fewer rounds, which is why it only wins
//! once m is large enough that β/γ dominate α (see the hotpath m-sweep).
//! On the wire, each chunk's traffic additionally carries its lane id in
//! the [`TagKey::chunk`](crate::mpi::TagKey) field (`c mod 2¹⁶`; the
//! round index alone already guarantees uniqueness, the lane makes the
//! chunk structure visible at the transport level).

use anyhow::Result;

use super::{ExscanOneDoubling, ScanAlgorithm, ScanKind};
use crate::mpi::{Elem, OpRef, RankCtx};

/// Default chunk length in elements: 4096 (32 KiB of i64) keeps a chunk
/// comfortably L1-resident on every current core while amortizing the
/// per-chunk tag/slot overhead to noise. Vectors at or below one chunk
/// degenerate to the flat 1-doubling schedule.
pub const DEFAULT_CHUNK_ELEMS: usize = 4096;

/// Chunked 1-doubling exclusive scan with a chunk-length policy.
pub struct ExscanChunked {
    /// Fixed chunk length in elements, or `None` for
    /// [`DEFAULT_CHUNK_ELEMS`].
    pub chunk_elems: Option<usize>,
}

impl ExscanChunked {
    /// Default chunk length.
    pub fn auto() -> Self {
        ExscanChunked { chunk_elems: None }
    }

    /// Fixed chunk length (≥ 1 element).
    pub fn with_chunk_elems(n: usize) -> Self {
        assert!(n >= 1);
        ExscanChunked { chunk_elems: Some(n) }
    }

    fn chunk_len(&self) -> usize {
        self.chunk_elems.unwrap_or(DEFAULT_CHUNK_ELEMS)
    }

    /// Number of chunks an m-element vector is cut into (≥ 1; a zero-length
    /// vector still runs one empty chunk so the shift round closes).
    pub fn chunk_count(&self, m: usize) -> usize {
        m.div_ceil(self.chunk_len()).max(1)
    }

    /// Exact round count for (p, m): every flat 1-doubling round carries
    /// one tagged message per chunk, so `(1 + ⌈log₂(p−1)⌉) · C`.
    pub fn rounds_for(&self, p: usize, m: usize) -> u32 {
        flat_rounds(p) * self.chunk_count(m) as u32
    }

    /// ⊕ applications on the completion-critical rank `p−1`: one fold per
    /// chunk per doubling round, `⌈log₂(p−1)⌉ · C`.
    pub fn ops_for(&self, p: usize, m: usize) -> u32 {
        flat_ops(p) * self.chunk_count(m) as u32
    }
}

/// 1-doubling round count — delegated to the flat skeleton so the closed
/// forms can never drift from the schedule this algorithm runs per chunk.
fn flat_rounds(p: usize) -> u32 {
    <ExscanOneDoubling as ScanAlgorithm<i64>>::predicted_rounds(&ExscanOneDoubling, p)
}

/// 1-doubling critical-rank ⊕ count (delegated, see [`flat_rounds`]).
fn flat_ops(p: usize) -> u32 {
    <ExscanOneDoubling as ScanAlgorithm<i64>>::predicted_ops(&ExscanOneDoubling, p)
}

impl<T: Elem> ScanAlgorithm<T> for ExscanChunked {
    fn name(&self) -> &'static str {
        "chunked-doubling"
    }

    fn kind(&self) -> ScanKind {
        ScanKind::Exclusive
    }

    fn run(
        &self,
        ctx: &mut RankCtx<T>,
        input: &[T],
        output: &mut [T],
        op: &OpRef<T>,
    ) -> Result<()> {
        let (r, p, m) = (ctx.rank(), ctx.size(), input.len());
        if p <= 1 {
            return Ok(());
        }
        // Resolve ⊕ to its slice kernel once for the whole collective
        // (the per-application dispatch is then a direct call — mpi::op).
        let op = &ctx.kernel(op);
        let ce = self.chunk_len();
        let nc = self.chunk_count(m);
        let nc32 = nc as u32;
        // Chunk c covers the fixed-size range [c·ce, (c+1)·ce) ∩ [0, m).
        let range = |c: usize| (c * ce).min(m)..((c + 1) * ce).min(m);

        // ── Round 0 (shift V right, chunk-wise; tags 0..C): establishes
        // W_r = V_{r-1}. Rank 0 streams its chunks and is done. ──
        {
            let (to, from) = (r + 1, r.checked_sub(1));
            for c in 0..nc {
                let rg = range(c);
                let tag = c as u32;
                ctx.with_chunk(c as u16, |ctx| match (to < p, from) {
                    (true, Some(f)) => {
                        ctx.sendrecv(tag, to, &input[rg.clone()], f, &mut output[rg.clone()])
                    }
                    (true, None) => ctx.send(tag, to, &input[rg.clone()]),
                    (false, Some(f)) => ctx.recv(tag, f, &mut output[rg.clone()]),
                    (false, None) => unreachable!("p > 1"),
                })?;
            }
        }
        if r == 0 {
            return Ok(());
        }

        // ── Doubling rounds k ≥ 1 (skips s_k = 2^{k-1}) over ranks 1..p,
        // chunk-pipelined: tags k·C..k·C+C. Posting chunk c's send before
        // blocking on chunk c's receive lets the send of chunk c+1 overlap
        // the peer's reduce of chunk c; the fused sendrecv_reduce folds
        // each arriving chunk straight from the pooled receive buffer. ──
        let mut s = 1usize;
        let mut k = 1u32;
        while s < p - 1 {
            let to = r + s;
            let from = if r > s { Some(r - s) } else { None }; // from >= 1
            for c in 0..nc {
                let rg = range(c);
                let tag = k * nc32 + c as u32;
                ctx.with_chunk(c as u16, |ctx| match (to < p, from) {
                    (true, Some(f)) => {
                        ctx.sendrecv_reduce(tag, to, f, op, &mut output[rg.clone()])
                    }
                    (true, None) => ctx.send(tag, to, &output[rg.clone()]),
                    (false, Some(f)) => ctx.recv_reduce(tag, f, op, &mut output[rg.clone()]),
                    (false, None) => Ok(()),
                })?;
            }
            s *= 2;
            k += 1;
        }
        Ok(())
    }

    /// The p-dependent flat round count (per chunk); exact counts for a
    /// concrete m come from [`rounds_for`](ExscanChunked::rounds_for),
    /// like [`PipelinedChain`](super::PipelinedChain).
    fn predicted_rounds(&self, p: usize) -> u32 {
        flat_rounds(p)
    }

    /// m-aware round count: `q(p) · C` — what the trace measures.
    fn predicted_rounds_m(&self, p: usize, m: usize) -> u32 {
        self.rounds_for(p, m)
    }

    fn predicted_ops(&self, p: usize) -> u32 {
        flat_ops(p)
    }

    fn critical_skips(&self, p: usize) -> Vec<usize> {
        // Same per-chunk partner distances as the flat skeleton (each
        // repeated C times for a concrete m — see `critical_schedule`).
        <ExscanOneDoubling as ScanAlgorithm<T>>::critical_skips(&ExscanOneDoubling, p)
    }

    /// m-dependent prediction inputs: every flat round repeats C times at
    /// chunk-sized payload; the total ⊕ work (`ops · chunk bytes`) equals
    /// the flat algorithm's.
    fn critical_schedule(&self, p: usize, m: usize) -> (Vec<usize>, u32, usize) {
        let c = self.chunk_count(m);
        let skips: Vec<usize> = <Self as ScanAlgorithm<T>>::critical_skips(self, p)
            .into_iter()
            .flat_map(|s| std::iter::repeat(s).take(c))
            .collect();
        (skips, self.ops_for(p, m), m.div_ceil(c))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coll::validate::assert_exscan_matches;
    use crate::coll::ExscanOneDoubling;
    use crate::mpi::{ops, run_scan, Topology, WorldConfig};

    #[test]
    fn matches_oracle_across_chunk_boundaries() {
        // Chunk length below, at and above m, and m not a multiple of it.
        for p in [2usize, 3, 5, 9, 16] {
            for (ce, m) in [(1usize, 7usize), (4, 17), (8, 8), (64, 17), (5, 0)] {
                let algo = ExscanChunked::with_chunk_elems(ce);
                let cfg = WorldConfig::new(Topology::flat(p));
                let inputs: Vec<Vec<i64>> = (0..p)
                    .map(|r| (0..m).map(|i| (r * 131 + i * 7) as i64 ^ 0x1234).collect())
                    .collect();
                let res = run_scan(&cfg, &algo, &ops::sum_i64(), &inputs).unwrap();
                assert_exscan_matches(&inputs, &ops::sum_i64(), &res.outputs);
            }
        }
    }

    #[test]
    fn noncommutative_chunk_order() {
        use crate::coll::validate::oracle_exscan;
        use crate::mpi::Rec2;
        for p in [3usize, 6, 11] {
            let m = 5;
            let algo = ExscanChunked::with_chunk_elems(2); // 3 chunks, last short
            let cfg = WorldConfig::new(Topology::flat(p));
            let inputs: Vec<Vec<Rec2>> = (0..p)
                .map(|r| {
                    (0..m)
                        .map(|i| {
                            Rec2::new(
                                [1.0, 0.02 * (r + i) as f32, -0.01 * r as f32, 1.0],
                                [r as f32 * 0.5, i as f32 * 0.25],
                            )
                        })
                        .collect()
                })
                .collect();
            let res = run_scan(&cfg, &algo, &ops::rec2_compose(), &inputs).unwrap();
            let oracle = oracle_exscan(&inputs, &ops::rec2_compose());
            for r in 1..p {
                let e = oracle[r].as_ref().unwrap();
                for i in 0..m {
                    for j in 0..4 {
                        assert!(
                            (res.outputs[r][i].a[j] - e[i].a[j]).abs() < 1e-3,
                            "p={p} r={r} i={i}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn bit_identical_to_flat_one_doubling() {
        // Same skeleton, same per-element fold order: the chunked schedule
        // must reproduce the flat 1-doubling outputs exactly.
        let p = 13;
        let m = 50;
        let cfg = WorldConfig::new(Topology::flat(p));
        let inputs: Vec<Vec<i64>> =
            (0..p).map(|r| (0..m).map(|i| ((r * m + i) as i64) << 3 | 5).collect()).collect();
        let flat = run_scan(&cfg, &ExscanOneDoubling, &ops::sum_i64(), &inputs).unwrap();
        let chunked = run_scan(
            &cfg,
            &ExscanChunked::with_chunk_elems(7),
            &ops::sum_i64(),
            &inputs,
        )
        .unwrap();
        assert_eq!(flat.outputs[1..], chunked.outputs[1..]);
    }

    #[test]
    fn rounds_and_ops_scale_with_chunk_count() {
        for (p, m, ce) in [(9usize, 12usize, 4usize), (5, 10, 3), (2, 8, 2), (17, 5, 64)] {
            let algo = ExscanChunked::with_chunk_elems(ce);
            let cfg = WorldConfig::new(Topology::flat(p)).with_trace(true);
            let inputs: Vec<Vec<i64>> =
                (0..p).map(|r| (0..m).map(|i| (r + i) as i64).collect()).collect();
            let res = run_scan(&cfg, &algo, &ops::bxor(), &inputs).unwrap();
            let trace = res.trace.unwrap();
            assert_eq!(trace.total_rounds(), algo.rounds_for(p, m), "rounds p={p} m={m}");
            assert_eq!(trace.last_rank_ops(), algo.ops_for(p, m), "ops p={p} m={m}");
            assert!(crate::trace::check_all(&trace).is_empty(), "invariants p={p} m={m}");
        }
    }

    #[test]
    fn critical_schedule_expands_per_chunk() {
        // The m-aware prediction inputs must match the real schedule: one
        // skip per (round, chunk), the chunked ⊕ count, chunk-sized
        // messages — while m-independent algorithms keep their defaults.
        let a = ExscanChunked::with_chunk_elems(16);
        let algo: &dyn ScanAlgorithm<i64> = &a;
        let (skips, ops, msg_elems) = algo.critical_schedule(9, 48); // 3 chunks
        assert_eq!(skips.len() as u32, a.rounds_for(9, 48));
        assert_eq!(ops, a.ops_for(9, 48));
        assert_eq!(msg_elems, 16);
        let flat: &dyn ScanAlgorithm<i64> = &ExscanOneDoubling;
        let (s, o, me) = flat.critical_schedule(9, 48);
        assert_eq!(s, flat.critical_skips(9));
        assert_eq!(o, flat.predicted_ops(9));
        assert_eq!(me, 48);
    }

    #[test]
    fn auto_policy_counts() {
        let a = ExscanChunked::auto();
        assert_eq!(a.chunk_count(0), 1);
        assert_eq!(a.chunk_count(1), 1);
        assert_eq!(a.chunk_count(DEFAULT_CHUNK_ELEMS), 1);
        assert_eq!(a.chunk_count(DEFAULT_CHUNK_ELEMS + 1), 2);
        assert_eq!(a.chunk_count(10 * DEFAULT_CHUNK_ELEMS), 10);
        // Flat p-part matches the 1-doubling closed forms.
        let algo: &dyn ScanAlgorithm<i64> = &a;
        assert_eq!(algo.predicted_rounds(36), 7);
        assert_eq!(algo.predicted_ops(36), 6);
    }
}
