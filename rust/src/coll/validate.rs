//! Sequential oracles the whole test suite validates against.

use crate::mpi::{Elem, OpRef};

/// Sequential inclusive scan: `out[r] = V_0 ⊕ … ⊕ V_r`, element-wise.
pub fn oracle_scan<T: Elem>(inputs: &[Vec<T>], op: &OpRef<T>) -> Vec<Vec<T>> {
    assert!(!inputs.is_empty());
    let mut acc = inputs[0].clone();
    let mut out = vec![acc.clone()];
    for v in &inputs[1..] {
        // acc = acc ⊕ v, with acc the earlier operand: inout starts as v.
        let mut next = v.clone();
        op.reduce_local(&acc, &mut next);
        acc = next;
        out.push(acc.clone());
    }
    out
}

/// Sequential exclusive scan: `out[r] = V_0 ⊕ … ⊕ V_{r-1}` for `r > 0`;
/// `out[0]` is `None` (undefined, as MPI_Exscan leaves it).
pub fn oracle_exscan<T: Elem>(inputs: &[Vec<T>], op: &OpRef<T>) -> Vec<Option<Vec<T>>> {
    let inc = oracle_scan(inputs, op);
    let mut out = vec![None];
    for w in inc.into_iter().take(inputs.len() - 1) {
        out.push(Some(w));
    }
    out
}

/// Convenience for tests: compare a parallel exclusive-scan result against
/// the oracle, ignoring rank 0.
pub fn assert_exscan_matches<T: Elem>(inputs: &[Vec<T>], op: &OpRef<T>, outputs: &[Vec<T>]) {
    let oracle = oracle_exscan(inputs, op);
    assert_eq!(oracle.len(), outputs.len());
    for (r, expect) in oracle.iter().enumerate() {
        if let Some(expect) = expect {
            assert_eq!(
                &outputs[r], expect,
                "rank {r} exclusive prefix mismatch (p={}, m={})",
                inputs.len(),
                inputs[0].len()
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpi::ops;

    #[test]
    fn oracle_scan_sum() {
        let inputs: Vec<Vec<i64>> = (1..=4).map(|r| vec![r as i64, 10 * r as i64]).collect();
        let out = oracle_scan(&inputs, &ops::sum_i64());
        assert_eq!(out[0], vec![1, 10]);
        assert_eq!(out[1], vec![3, 30]);
        assert_eq!(out[3], vec![10, 100]);
    }

    #[test]
    fn oracle_exscan_sum() {
        let inputs: Vec<Vec<i64>> = (1..=4).map(|r| vec![r as i64]).collect();
        let out = oracle_exscan(&inputs, &ops::sum_i64());
        assert!(out[0].is_none());
        assert_eq!(out[1].as_ref().unwrap(), &vec![1]);
        assert_eq!(out[3].as_ref().unwrap(), &vec![6]);
    }

    #[test]
    fn oracle_respects_order_noncommutative() {
        use crate::mpi::Rec2;
        let a = Rec2::new([1.0, 1.0, 0.0, 1.0], [1.0, 2.0]);
        let b = Rec2::new([2.0, 0.0, 1.0, 1.0], [0.0, 1.0]);
        let c = Rec2::new([0.0, 1.0, 1.0, 0.0], [3.0, 0.0]);
        let inputs = vec![vec![a], vec![b], vec![c]];
        let out = oracle_scan(&inputs, &ops::rec2_compose());
        // out[2] must be a∘then b∘then c in rank order: a.then(b).then(c)
        assert_eq!(out[2][0], a.then(&b).then(&c));
    }
}
