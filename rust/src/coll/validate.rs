//! Sequential oracles the whole test suite validates against, and the
//! **differential self-verification harness**: every registered exscan
//! algorithm, run under seeded chaos injection (message embargo/diversion,
//! scheduler yields — see [`crate::mpi::chaos`]) on a persistent
//! [`World`], checked three ways per case:
//!
//! 1. **chaos ≡ clean** — outputs and traces of the chaos run must be
//!    bit-identical to a no-chaos run of the same algorithm (schedule
//!    perturbation must be unobservable, even for non-associative float
//!    rounding: same operand association, same results);
//! 2. **clean ≡ oracle** — exact for integer operators, tolerance-checked
//!    for the non-commutative `rec2_compose` (tree associations round
//!    differently than the oracle's left fold);
//! 3. **Theorem-1 counts** — traced rounds and ⊕ applications match the
//!    closed forms (exact where the paper states exact counts, bounded
//!    elsewhere), the one-ported invariants hold, and the sharded
//!    [`OpRef`] counters agree with the trace.
//!
//! Any failure reproduces from its seed alone: `exscan fuzz --seed N`.

use anyhow::Result;

use super::segmented::{seg_bxor_i64, seg_sum_i64, Seg};
use super::{
    two_level_max_ops, two_level_ops, two_level_rounds, Exscan123, Exscan1247, ExscanBlelloch,
    ExscanBlock, ExscanChunked, ExscanHierarchical, ExscanLinear, ExscanMpich, ExscanOneDoubling,
    ExscanPow2, ExscanRsag, ExscanShiftScan, ExscanTwoLevel, ExscanTwoOp, PipelinedChain,
    ScanAlgorithm,
};
use crate::mpi::{
    ops, ChaosConfig, Comm, Elem, OpRef, Rec2, Topology, TransportBackend, WireFaultConfig,
    World, WorldConfig,
};
use crate::trace::{check_all, RankTrace, TraceReport};
use crate::util::bits::{rounds_123, rounds_1247, rounds_one_doubling, rounds_pow2};
use crate::util::ceil_log2;

/// Sequential inclusive scan: `out[r] = V_0 ⊕ … ⊕ V_r`, element-wise.
pub fn oracle_scan<T: Elem>(inputs: &[Vec<T>], op: &OpRef<T>) -> Vec<Vec<T>> {
    assert!(!inputs.is_empty());
    let mut acc = inputs[0].clone();
    let mut out = vec![acc.clone()];
    for v in &inputs[1..] {
        // acc = acc ⊕ v, with acc the earlier operand: inout starts as v.
        // Single-threaded oracle: counts explicitly on shard 0.
        let mut next = v.clone();
        op.reduce_local_sharded(0, &acc, &mut next);
        acc = next;
        out.push(acc.clone());
    }
    out
}

/// Sequential exclusive scan: `out[r] = V_0 ⊕ … ⊕ V_{r-1}` for `r > 0`;
/// `out[0]` is `None` (undefined, as MPI_Exscan leaves it).
pub fn oracle_exscan<T: Elem>(inputs: &[Vec<T>], op: &OpRef<T>) -> Vec<Option<Vec<T>>> {
    let inc = oracle_scan(inputs, op);
    let mut out = vec![None];
    for w in inc.into_iter().take(inputs.len() - 1) {
        out.push(Some(w));
    }
    out
}

/// Convenience for tests: compare a parallel exclusive-scan result against
/// the oracle, ignoring rank 0.
pub fn assert_exscan_matches<T: Elem>(inputs: &[Vec<T>], op: &OpRef<T>, outputs: &[Vec<T>]) {
    let oracle = oracle_exscan(inputs, op);
    assert_eq!(oracle.len(), outputs.len());
    for (r, expect) in oracle.iter().enumerate() {
        if let Some(expect) = expect {
            assert_eq!(
                &outputs[r], expect,
                "rank {r} exclusive prefix mismatch (p={}, m={})",
                inputs.len(),
                inputs[0].len()
            );
        }
    }
}

// ───────────────── differential self-verification harness ─────────────────

/// Expected trace counts for one (algorithm, p, m) case. `None` fields are
/// not checked; exact fields use the paper's closed forms.
#[derive(Debug, Clone, Copy, Default)]
pub struct CountCheck {
    pub rounds: Option<u32>,
    pub rounds_le: Option<u32>,
    pub last_ops: Option<u32>,
    pub max_ops_le: Option<u32>,
}

/// Aggregate result of one fuzz sweep.
#[derive(Debug, Default)]
pub struct FuzzOutcome {
    pub cases: usize,
    /// Chaos injection totals over every world in the sweep.
    pub delayed: u64,
    pub diverted: u64,
    pub yields: u64,
    pub dropped: u64,
    /// XOR of the per-world schedule digests — the replay fingerprint:
    /// re-running the same sweep at the same seed yields the same value.
    pub schedule_digest: u64,
    /// Human-readable failure descriptions (empty = all cases passed).
    pub failures: Vec<String>,
}

type CheckFn = Box<dyn Fn(usize, usize) -> CountCheck>;

/// Chunk length of the fuzz sweep's fixed-chunk `ExscanChunked` variant —
/// single source for both the algorithm instance and its closed-form
/// check (8 chunks at the m = 4096 grid point).
const FUZZ_CHUNK_ELEMS: usize = 512;

/// Closed-form counts for a concrete chunk policy (shared by the auto and
/// fixed-chunk candidates so the instance and its check cannot diverge).
fn chunked_check(a: &ExscanChunked, p: usize, m: usize) -> CountCheck {
    CountCheck {
        rounds: Some(a.rounds_for(p, m)),
        last_ops: Some(a.ops_for(p, m)),
        ..Default::default()
    }
}

/// Every registered exclusive-scan algorithm plus variants forcing the
/// multi-chunk and hierarchical paths, each paired with its closed-form
/// count check.
fn fuzz_candidates<T: Elem>() -> Vec<(Box<dyn ScanAlgorithm<T>>, CheckFn)> {
    let mut v: Vec<(Box<dyn ScanAlgorithm<T>>, CheckFn)> = vec![
        (
            Box::new(ExscanMpich),
            Box::new(|p, _| CountCheck {
                rounds: Some(ceil_log2(p)),
                max_ops_le: Some(2 * ceil_log2(p) - 1),
                ..Default::default()
            }),
        ),
        (
            Box::new(ExscanTwoOp),
            Box::new(|p, _| CountCheck {
                rounds: Some(ceil_log2(p)),
                max_ops_le: Some(2 * ceil_log2(p) - 1),
                ..Default::default()
            }),
        ),
        (
            Box::new(ExscanOneDoubling),
            Box::new(|p, _| {
                let ops = if p <= 2 { 0 } else { ceil_log2(p - 1) };
                CountCheck {
                    rounds: Some(rounds_one_doubling(p)),
                    last_ops: Some(ops),
                    max_ops_le: Some(ops),
                    ..Default::default()
                }
            }),
        ),
        (
            // Theorem 1: q rounds, q−1 ⊕ on the completion-critical rank.
            Box::new(Exscan123),
            Box::new(|p, _| {
                let q = rounds_123(p);
                CountCheck {
                    rounds: Some(q),
                    last_ops: Some(q.saturating_sub(1)),
                    max_ops_le: Some(q),
                    ..Default::default()
                }
            }),
        ),
        (
            Box::new(ExscanBlelloch),
            Box::new(|p, _| CountCheck {
                rounds_le: Some(2 * ceil_log2(p)),
                max_ops_le: Some(2 * ceil_log2(p)),
                ..Default::default()
            }),
        ),
        (
            Box::new(ExscanShiftScan),
            Box::new(|p, _| CountCheck {
                rounds: Some(ceil_log2(p) + 1),
                max_ops_le: Some(ceil_log2(p)),
                ..Default::default()
            }),
        ),
        (
            Box::new(ExscanLinear),
            Box::new(|p, _| CountCheck {
                rounds: Some((p - 1) as u32),
                max_ops_le: Some(1),
                ..Default::default()
            }),
        ),
        (
            Box::new(PipelinedChain::auto()),
            Box::new(|p, m| {
                let a = PipelinedChain::auto();
                CountCheck {
                    rounds: Some(a.rounds_for(p, m)),
                    max_ops_le: Some(a.ops_for(p, m)),
                    ..Default::default()
                }
            }),
        ),
        (
            Box::new(ExscanChunked::auto()),
            Box::new(|p, m| chunked_check(&ExscanChunked::auto(), p, m)),
        ),
        (
            // Small chunks so the m = 4096 grid point runs a genuinely
            // multi-chunk (8-chunk) pipelined schedule.
            Box::new(ExscanChunked::with_chunk_elems(FUZZ_CHUNK_ELEMS)),
            Box::new(|p, m| {
                chunked_check(&ExscanChunked::with_chunk_elems(FUZZ_CHUNK_ELEMS), p, m)
            }),
        ),
        (
            // Counts depend on node shape; only invariants + differential
            // checks apply.
            Box::new(ExscanHierarchical::new(3)),
            Box::new(|_, _| CountCheck::default()),
        ),
        (
            // Reduce-scatter + allgather composition: exact closed forms
            // 2(p−1) rounds, p−2 ⊕ on every rank.
            Box::new(ExscanRsag),
            Box::new(|p, _| {
                let (rounds, ops) = ExscanRsag::closed_form(p);
                CountCheck {
                    rounds: Some(rounds),
                    last_ops: Some(ops),
                    max_ops_le: Some(ops),
                    ..Default::default()
                }
            }),
        ),
        (
            // Block decomposition with the cost-model auto group (g = 1 at
            // the small fuzz m values → exercises the degenerate path).
            Box::new(ExscanBlock::auto()),
            Box::new(|p, m| {
                let a = ExscanBlock::auto();
                let eb = T::size_bytes();
                CountCheck {
                    rounds: Some(a.rounds_for(p, m, eb)),
                    last_ops: Some(a.ops_for(p, m, eb)),
                    max_ops_le: Some(a.max_ops_for(p, m, eb)),
                    ..Default::default()
                }
            }),
        ),
        (
            // Forced two-wide groups: a genuinely decomposed schedule at
            // every even fuzz p (odd p snaps to g = 1).
            Box::new(ExscanBlock::with_group(2)),
            Box::new(|p, m| {
                let a = ExscanBlock::with_group(2);
                let eb = T::size_bytes();
                CountCheck {
                    rounds: Some(a.rounds_for(p, m, eb)),
                    last_ops: Some(a.ops_for(p, m, eb)),
                    max_ops_le: Some(a.max_ops_for(p, m, eb)),
                    ..Default::default()
                }
            }),
        ),
        (
            // 2026 follow-up: ⌈log₂p⌉ rounds (round-optimal), K−1 ⊕ on
            // the last rank; senders pay up to 2(K−1) preparing W⊕V.
            Box::new(ExscanPow2),
            Box::new(|p, _| {
                let k = rounds_pow2(p);
                CountCheck {
                    rounds: Some(k),
                    last_ops: Some(k.saturating_sub(1)),
                    max_ops_le: Some(2 * k.saturating_sub(1)),
                    ..Default::default()
                }
            }),
        ),
        (
            // 2026 follow-up: ⌈log₂(p−1)+log₂(8/7)⌉ rounds, q−1 ⊕ on the
            // last rank, q+1 ⊕ max (two fortified sender folds).
            Box::new(Exscan1247),
            Box::new(|p, _| {
                let q = rounds_1247(p);
                CountCheck {
                    rounds: Some(q),
                    last_ops: Some(q.saturating_sub(1)),
                    max_ops_le: Some(q + 1),
                    ..Default::default()
                }
            }),
        ),
        (
            // Two-level leader scheme at a fixed node shape: closed forms
            // from the union round plan (node phases + leader exscan).
            Box::new(ExscanTwoLevel::new(4)),
            Box::new(|p, _| CountCheck {
                rounds: Some(two_level_rounds(4, p)),
                last_ops: Some(two_level_ops(4, p)),
                max_ops_le: Some(two_level_max_ops(4, p)),
                ..Default::default()
            }),
        ),
    ];
    v.shrink_to_fit();
    v
}

/// Run one traced scan on a persistent world; outputs + merged trace in
/// rank order.
fn run_world_scan<T: Elem>(
    world: &World<T>,
    algo: &dyn ScanAlgorithm<T>,
    op: &OpRef<T>,
    inputs: &[Vec<T>],
) -> Result<(Vec<Vec<T>>, TraceReport)> {
    let m = inputs.first().map(|v| v.len()).unwrap_or(0);
    let per = world.run(|ctx| {
        let input = &inputs[ctx.rank()];
        let mut output = vec![T::filler(); m];
        ctx.barrier();
        algo.run(ctx, input, &mut output, op)?;
        Ok((output, ctx.take_trace()))
    })?;
    let mut outputs = Vec::with_capacity(per.len());
    let mut traces = Vec::with_capacity(per.len());
    for (rank, (o, t)) in per.into_iter().enumerate() {
        outputs.push(o);
        traces.push(t.unwrap_or_else(|| RankTrace::new(rank)));
    }
    Ok((outputs, TraceReport::new(traces)))
}

/// Oracle comparison for exactly associative (integer) operators:
/// bit-identical per rank (rank 0 ignored).
fn oracle_check_exact<T: Elem>(
    inputs: &[Vec<T>],
    op: &OpRef<T>,
    outputs: &[Vec<T>],
) -> Option<String> {
    let oracle = oracle_exscan(inputs, op);
    for (r, expect) in oracle.iter().enumerate() {
        if let Some(expect) = expect {
            if &outputs[r] != expect {
                return Some(format!("rank {r} differs from oracle_exscan"));
            }
        }
    }
    None
}

/// Oracle comparison for the non-commutative float composition: the tree
/// associations round differently than the oracle's left fold, so this is
/// a tolerance check (the bit-identity requirement is chaos ≡ clean).
fn oracle_check_rec2(
    inputs: &[Vec<Rec2>],
    op: &OpRef<Rec2>,
    outputs: &[Vec<Rec2>],
) -> Option<String> {
    let oracle = oracle_exscan(inputs, op);
    let p = inputs.len();
    let tol = 1e-3f32 * (p as f32).max(4.0);
    for r in 1..p {
        let expect = oracle[r].as_ref().unwrap();
        for (i, (got, want)) in outputs[r].iter().zip(expect).enumerate() {
            for j in 0..4 {
                if (got.a[j] - want.a[j]).abs() > tol {
                    return Some(format!(
                        "rank {r} elem {i} a[{j}]: {} vs oracle {}",
                        got.a[j], want.a[j]
                    ));
                }
            }
            for j in 0..2 {
                if (got.b[j] - want.b[j]).abs() > tol * 4.0 {
                    return Some(format!(
                        "rank {r} elem {i} b[{j}]: {} vs oracle {}",
                        got.b[j], want.b[j]
                    ));
                }
            }
        }
    }
    None
}

/// One element type's sweep at world size `p`: every candidate × operator
/// × m, chaos run differentially checked against the clean run, the
/// oracle and the closed-form counts.
fn fuzz_world<T: Elem>(
    backend: TransportBackend,
    seed: u64,
    p: usize,
    m_values: &[usize],
    mk_ops: &[fn() -> OpRef<T>],
    mk_inputs: fn(usize, usize, u64) -> Vec<Vec<T>>,
    oracle_check: fn(&[Vec<T>], &OpRef<T>, &[Vec<T>]) -> Option<String>,
    out: &mut FuzzOutcome,
) {
    assert!(p >= 2, "chaos fuzz needs p >= 2");
    let chaos_seed = seed ^ (p as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    let mk_chaos = || -> World<T> {
        World::new(
            WorldConfig::new(Topology::flat(p))
                .with_trace(true)
                .with_transport(backend)
                .with_chaos(ChaosConfig::new(chaos_seed)),
        )
    };
    let mk_clean = || -> World<T> {
        World::new(
            WorldConfig::new(Topology::flat(p))
                .with_trace(true)
                .with_transport(backend),
        )
    };
    // Fold a (possibly about-to-be-replaced) chaos world's injection
    // totals into the outcome.
    fn absorb<T: Elem>(world: &World<T>, out: &mut FuzzOutcome) {
        if let Some(report) = world.chaos_report() {
            out.delayed += report.delayed;
            out.diverted += report.diverted;
            out.yields += report.yields;
            out.dropped += report.dropped;
            out.schedule_digest ^= report.schedule_digest;
        }
    }
    let mut chaos_world = mk_chaos();
    let mut clean_world = mk_clean();
    let candidates = fuzz_candidates::<T>();

    for &m in m_values {
        for mk_op in mk_ops {
            let inputs = mk_inputs(p, m, seed ^ (m as u64).wrapping_mul(0xC2B2_AE35));
            for (algo, expected) in &candidates {
                out.cases += 1;
                let op = mk_op();
                let chaos_run = run_world_scan(&chaos_world, algo.as_ref(), &op, &inputs);
                let chaos_ops = op.applications();
                op.reset_applications();
                let clean_run = run_world_scan(&clean_world, algo.as_ref(), &op, &inputs);
                let label = format!(
                    "algo={} op={} p={p} m={m} seed={seed} \
                     (reproduce: exscan fuzz --seed {seed} --p {p} --m {m})",
                    algo.name(),
                    op.name()
                );
                let ((c_out, c_tr), (n_out, n_tr)) = match (chaos_run, clean_run) {
                    (Ok(c), Ok(n)) => (c, n),
                    // A failed run leaves stale (src, round)-tagged
                    // messages buffered; tags restart at 0 every case, so
                    // a tainted world would cascade misattributed
                    // failures into later cases. Rebuild both worlds
                    // (absorbing the chaos totals first).
                    (Err(e), _) => {
                        out.failures.push(format!("{label}: chaos run failed: {e:#}"));
                        absorb(&chaos_world, out);
                        chaos_world = mk_chaos();
                        clean_world = mk_clean();
                        continue;
                    }
                    (_, Err(e)) => {
                        out.failures.push(format!("{label}: clean run failed: {e:#}"));
                        absorb(&chaos_world, out);
                        chaos_world = mk_chaos();
                        clean_world = mk_clean();
                        continue;
                    }
                };
                if c_out != n_out {
                    out.failures
                        .push(format!("{label}: chaos and clean outputs diverged"));
                    continue;
                }
                if let Some(msg) = oracle_check(&inputs, &op, &c_out) {
                    out.failures.push(format!("{label}: oracle mismatch: {msg}"));
                    continue;
                }
                // Full per-rank event logs (kind, round, bytes, order) —
                // not just the aggregate counts: schedule perturbation
                // must be invisible in the trace, bit for bit.
                if c_tr.traces.len() != n_tr.traces.len()
                    || c_tr
                        .traces
                        .iter()
                        .zip(&n_tr.traces)
                        .any(|(a, b)| a.events != b.events)
                {
                    out.failures
                        .push(format!("{label}: chaos and clean traces diverged"));
                    continue;
                }
                let violations = check_all(&c_tr);
                if !violations.is_empty() {
                    out.failures.push(format!(
                        "{label}: {} one-ported/matching violations, first: {}",
                        violations.len(),
                        violations[0]
                    ));
                    continue;
                }
                if chaos_ops != c_tr.total_ops() {
                    out.failures.push(format!(
                        "{label}: sharded ⊕ counters ({chaos_ops}) disagree with trace ({})",
                        c_tr.total_ops()
                    ));
                    continue;
                }
                let check = expected(p, m);
                if let Some(r) = check.rounds {
                    if c_tr.total_rounds() != r {
                        out.failures.push(format!(
                            "{label}: rounds {} != closed form {r}",
                            c_tr.total_rounds()
                        ));
                        continue;
                    }
                }
                if let Some(r) = check.rounds_le {
                    if c_tr.total_rounds() > r {
                        out.failures.push(format!(
                            "{label}: rounds {} exceed bound {r}",
                            c_tr.total_rounds()
                        ));
                        continue;
                    }
                }
                if let Some(o) = check.last_ops {
                    if c_tr.last_rank_ops() != o {
                        out.failures.push(format!(
                            "{label}: last-rank ⊕ {} != closed form {o}",
                            c_tr.last_rank_ops()
                        ));
                        continue;
                    }
                }
                if let Some(o) = check.max_ops_le {
                    if c_tr.max_ops() > o {
                        out.failures.push(format!(
                            "{label}: max ⊕ {} exceeds bound {o}",
                            c_tr.max_ops()
                        ));
                        continue;
                    }
                }
            }
        }
    }

    absorb(&chaos_world, out);
}

/// The full differential sweep: every registered exscan algorithm ×
/// {bxor_i64, sum_i64, rec2_compose (non-commutative), and the **lifted
/// segmented** seg_bxor_i64/seg_sum_i64 over `Seg<i64>`} × `m_values` ×
/// `p_values`, under seeded chaos on persistent executors. The segmented
/// case pins [`segmented`](super::segmented) correctness under reordered
/// delivery — the lifted operator's flag rule is non-commutative and
/// direction-sensitive, exactly what an adversarial schedule would break
/// if any algorithm mis-ordered a fold. Failures are collected (not
/// panicked) so the CLI can print them with the repro seed.
pub fn chaos_fuzz(seed: u64, p_values: &[usize], m_values: &[usize]) -> FuzzOutcome {
    chaos_fuzz_on(TransportBackend::Thread, seed, p_values, m_values)
}

/// [`chaos_fuzz`] on an explicit transport backend. The chaos layer sits
/// above the transport boundary (decisions are made in `RankCtx::post` and
/// shipped inside the frame), so for a given seed the injected schedule —
/// and therefore `schedule_digest` and every injection counter — must be
/// **bit-identical across backends**. The backend-oracle test
/// (`tests/backend_matrix.rs`) asserts exactly that against the thread
/// world.
pub fn chaos_fuzz_on(
    backend: TransportBackend,
    seed: u64,
    p_values: &[usize],
    m_values: &[usize],
) -> FuzzOutcome {
    let mut out = FuzzOutcome::default();
    for &p in p_values {
        fuzz_world::<i64>(
            backend,
            seed,
            p,
            m_values,
            &[ops::bxor as fn() -> OpRef<i64>, ops::sum_i64 as fn() -> OpRef<i64>],
            crate::bench::inputs_i64,
            oracle_check_exact::<i64>,
            &mut out,
        );
        fuzz_world::<Rec2>(
            backend,
            seed,
            p,
            m_values,
            &[ops::rec2_compose as fn() -> OpRef<Rec2>],
            crate::bench::inputs_rec2,
            oracle_check_rec2,
            &mut out,
        );
        fuzz_world::<Seg<i64>>(
            backend,
            seed,
            p,
            m_values,
            &[
                seg_bxor_i64 as fn() -> OpRef<Seg<i64>>,
                seg_sum_i64 as fn() -> OpRef<Seg<i64>>,
            ],
            crate::bench::inputs_seg_i64,
            oracle_check_exact::<Seg<i64>>,
            &mut out,
        );
    }
    out
}

// ───────────────── concurrent-communicator differential ─────────────────

/// N concurrent in-flight exscans on **distinct communicators** over one
/// persistent chaos world, differentially verified: each collective's
/// outputs AND per-context trace must be bit-identical to the same
/// request executed serially on a clean world of the communicator's size.
///
/// The communicators alternate full-world `dup`s and contiguous
/// `split`-ranges; algorithms and operators vary per communicator. All N
/// collectives run inside a single executor job — each rank walks the
/// communicators it belongs to in order, so ranks genuinely interleave
/// progress across collectives (a rank done with collective i starts
/// i + 1 while its peers are still inside i), and the chaos layer
/// additionally embargoes/diverts/yields on top. Only the packed
/// `TagKey` context isolation makes this correct; reverting the tag to a
/// bare round index makes this function fail immediately.
pub fn chaos_concurrent_comms(seed: u64, n_comms: usize) -> std::result::Result<(), String> {
    const P: usize = 8;
    assert!(n_comms >= 1);
    let world: World<i64> = World::new(
        WorldConfig::new(Topology::flat(P))
            .with_trace(true)
            .with_chaos(ChaosConfig::new(seed)),
    );
    let world_comm = world.comm_world();

    let algos: Vec<Box<dyn ScanAlgorithm<i64>>> = vec![
        Box::new(Exscan123),
        Box::new(ExscanOneDoubling),
        Box::new(ExscanTwoOp),
        Box::new(ExscanMpich),
    ];
    let m_grid = [1usize, 4, 17, 0, 5, 33];

    let mut comms: Vec<Comm> = Vec::new();
    let mut ops_v: Vec<OpRef<i64>> = Vec::new();
    let mut inputs: Vec<Vec<Vec<i64>>> = Vec::new();
    for i in 0..n_comms {
        let comm = if i % 2 == 0 {
            world.dup_comm(&world_comm)
        } else {
            // A contiguous sub-range [start, end), varied per i.
            let start = i % 3;
            let end = (start + 3 + i % (P - 2)).min(P);
            let colors: Vec<usize> =
                (0..P).map(|r| usize::from(r >= start && r < end)).collect();
            world.split_comm(&world_comm, &colors).pop().expect("at least one color")
        };
        ops_v.push(if i % 2 == 0 { ops::bxor() } else { ops::sum_i64() });
        inputs.push(crate::bench::inputs_i64(
            comm.size(),
            m_grid[i % m_grid.len()],
            seed ^ (i as u64 + 1).wrapping_mul(0xA5A5_5A5A),
        ));
        comms.push(comm);
    }

    // ── The concurrent run: all N collectives inside one job. ──
    let per = world
        .run(|ctx| {
            let w = ctx.rank();
            let mut outs: Vec<Option<Vec<i64>>> = vec![None; comms.len()];
            for (i, comm) in comms.iter().enumerate() {
                let Some(cr) = comm.rank_of(w) else { continue };
                let input = &inputs[i][cr];
                let mut output = vec![0i64; input.len()];
                let algo = &algos[i % algos.len()];
                ctx.with_comm(comm, |sub| algo.run(sub, input, &mut output, &ops_v[i]))?;
                outs[i] = Some(output);
            }
            Ok((outs, ctx.take_trace()))
        })
        .map_err(|e| format!("concurrent job failed (seed {seed}): {e:#}"))?;

    let mut outs: Vec<Vec<Option<Vec<i64>>>> = Vec::with_capacity(P);
    let mut traces: Vec<RankTrace> = Vec::with_capacity(P);
    for (rank, (o, t)) in per.into_iter().enumerate() {
        outs.push(o);
        traces.push(t.unwrap_or_else(|| RankTrace::new(rank)));
    }
    let report = TraceReport::new(traces);

    // ── Serial references: each collective alone on a clean world. ──
    for (i, comm) in comms.iter().enumerate() {
        let label = format!("seed {seed}, collective {i} (ctx {})", comm.ctx());
        let clean: World<i64> =
            World::new(WorldConfig::new(Topology::flat(comm.size())).with_trace(true));
        let algo = &algos[i % algos.len()];
        let op = if i % 2 == 0 { ops::bxor() } else { ops::sum_i64() };
        let (serial_out, serial_tr) =
            run_world_scan(&clean, algo.as_ref(), &op, &inputs[i])
                .map_err(|e| format!("{label}: serial reference failed: {e:#}"))?;
        for (cr, &wr) in comm.ranks().iter().enumerate() {
            let got = outs[wr][i]
                .as_ref()
                .ok_or_else(|| format!("{label}: member rank {wr} produced no output"))?;
            if got != &serial_out[cr] {
                return Err(format!(
                    "{label}: output of comm rank {cr} (world {wr}) diverged from serial"
                ));
            }
        }
        let sub = report.for_ctx(comm.ctx(), comm.ranks());
        for cr in 0..comm.size() {
            if sub.traces[cr].events != serial_tr.traces[cr].events {
                return Err(format!(
                    "{label}: per-context trace of comm rank {cr} diverged from serial"
                ));
            }
        }
        let violations = check_all(&sub);
        if !violations.is_empty() {
            return Err(format!("{label}: {} invariant violations", violations.len()));
        }
    }
    // The whole mixed trace must also be invariant-clean per (ctx, round).
    let violations = check_all(&report);
    if !violations.is_empty() {
        return Err(format!(
            "seed {seed}: mixed trace has {} violations, first: {}",
            violations.len(),
            violations[0]
        ));
    }
    Ok(())
}

/// The zero-allocation claim under chaos: with embargo/diversion/yields
/// active (but no pool pressure), steady-state scan rounds must still be
/// served entirely from the recycling pools. Chaos decisions are pure in
/// (seed, src, dst, round), so the peak buffer demand is identical every
/// sweep and the miss counter must converge exactly as without chaos.
pub fn chaos_pool_steady_state(seed: u64) -> std::result::Result<(), String> {
    const P: usize = 8;
    const M: usize = 64;
    let world: World<i64> = World::new(
        WorldConfig::new(Topology::flat(P)).with_chaos(ChaosConfig::new(seed)),
    );
    let inputs = crate::bench::inputs_i64(P, M, seed);
    let op = ops::bxor();
    let algos: Vec<Box<dyn ScanAlgorithm<i64>>> = vec![
        Box::new(Exscan123),
        Box::new(ExscanChunked::with_chunk_elems(16)),
    ];
    let oracle = oracle_exscan(&inputs, &op);
    let sweep = |world: &World<i64>| -> std::result::Result<(), String> {
        for algo in &algos {
            let outputs = world
                .run(|ctx| {
                    let mut output = vec![0i64; M];
                    ctx.barrier();
                    algo.run(ctx, &inputs[ctx.rank()], &mut output, &op)?;
                    Ok(output)
                })
                .map_err(|e| format!("{} under chaos: {e:#}", algo.name()))?;
            for r in 1..P {
                if Some(&outputs[r]) != oracle[r].as_ref() {
                    return Err(format!("{} rank {r} wrong under chaos", algo.name()));
                }
            }
        }
        Ok(())
    };

    // Chaos *decisions* are deterministic, but embargoed buffers are held
    // for wall-clock durations, so the peak simultaneous-outstanding
    // buffer count can shift with OS scheduling. Run up to two full
    // warm→steady cycles: a transient scheduling spike re-warms and
    // passes on the retry; a genuine per-round allocation regression
    // accrues misses in every cycle and still fails.
    let mut last_err = String::new();
    for _attempt in 0..2 {
        // Warm until the pools meet their peak demand: the miss counter
        // must stop moving for two consecutive sweeps within 60.
        let mut prev = world.pool_stats();
        let mut stable_streak = 0;
        for _ in 0..60 {
            sweep(&world)?;
            let now = world.pool_stats();
            if now.misses == prev.misses {
                stable_streak += 1;
                prev = now;
                if stable_streak >= 2 {
                    break;
                }
            } else {
                stable_streak = 0;
                prev = now;
            }
        }
        if stable_streak < 2 {
            last_err = format!("pool demand did not stabilize under chaos: {prev:?}");
            continue;
        }
        for _ in 0..20 {
            sweep(&world)?;
        }
        let steady = world.pool_stats();
        if steady.misses != prev.misses {
            last_err = format!(
                "steady-state chaos sweeps allocated: warm {prev:?} vs steady {steady:?}"
            );
            continue;
        }
        if steady.hits <= prev.hits {
            last_err = format!("pool hits must keep accruing: {steady:?}");
            continue;
        }
        return Ok(());
    }
    Err(last_err)
}

/// Rank-death differential: the same (seed, p, inputs) run twice —
/// once on a **doomed** world where rank `p/2` is killed at its first
/// chaos point, once on a clean world.
///
/// The doomed run must fail *attributed* — the error chain names
/// `rank-death` (no survivor waits out its receive deadline; the
/// poison wake in [`crate::mpi::Inbox`] guarantees that) — and the
/// world's [`World::dead_ranks`] registry must contain exactly the
/// victim. The clean run must match [`oracle_exscan`] bit-for-bit.
/// Together these pin the structural attribution path the scan
/// service's live-rebuild logic depends on: a death is never reported
/// as a generic timeout, and death injection never corrupts results
/// computed without it.
pub fn rank_death_differential(seed: u64, p: usize) -> std::result::Result<(), String> {
    assert!(p >= 2, "rank-death differential needs p >= 2");
    const M: usize = 64;
    let victim = p / 2;
    let op = ops::bxor();
    let inputs = crate::bench::inputs_i64(p, M, seed);
    let algo = Exscan123;
    let job = |world: &World<i64>| {
        world.run(|ctx| {
            let input = &inputs[ctx.rank()];
            let mut output = vec![0i64; M];
            // No barrier before the scan: the victim dies at tick 1, so
            // the first chaos point it reaches kills it; a barrier would
            // only move where the survivors observe the death.
            algo.run(ctx, input, &mut output, &op)?;
            Ok(output)
        })
    };

    // ── Doomed run: delay/divert/yield off so the only injected fault
    // is the death itself, and the attribution cannot hide behind an
    // embargo-induced timeout. ──
    let chaos = ChaosConfig::new(seed)
        .with_delay_prob(0.0)
        .with_divert_prob(0.0)
        .with_yield_prob(0.0)
        .with_rank_death(victim, 1);
    let doomed: World<i64> = World::new(
        WorldConfig::new(Topology::flat(p))
            .with_chaos(chaos)
            .with_recv_timeout(std::time::Duration::from_secs(2)),
    );
    let t0 = std::time::Instant::now();
    match job(&doomed) {
        Ok(_) => {
            return Err(format!(
                "seed {seed} p={p}: doomed world succeeded despite rank-death injection"
            ))
        }
        Err(e) => {
            let err = format!("{e:#}");
            if !err.contains("rank-death") {
                return Err(format!(
                    "seed {seed} p={p}: failure not attributed to rank-death: {err}"
                ));
            }
        }
    }
    if t0.elapsed() >= std::time::Duration::from_secs(2) {
        return Err(format!(
            "seed {seed} p={p}: survivors waited out the receive deadline \
             instead of being poisoned awake"
        ));
    }
    let dead = doomed.dead_ranks();
    if dead != vec![victim] {
        return Err(format!(
            "seed {seed} p={p}: dead-rank registry {dead:?} != [{victim}]"
        ));
    }
    match doomed.chaos_report() {
        Some(r) if r.rank_deaths == 1 => {}
        Some(r) => {
            return Err(format!(
                "seed {seed} p={p}: chaos report counted {} deaths, expected 1",
                r.rank_deaths
            ))
        }
        None => return Err(format!("seed {seed} p={p}: doomed world has no chaos report")),
    }

    // ── Clean differential: same seed-derived inputs, no chaos. ──
    let clean: World<i64> = World::new(WorldConfig::new(Topology::flat(p)));
    let outputs = job(&clean)
        .map_err(|e| format!("seed {seed} p={p}: clean run failed: {e:#}"))?;
    if let Some(msg) = oracle_check_exact(&inputs, &op, &outputs) {
        return Err(format!("seed {seed} p={p}: clean run oracle mismatch: {msg}"));
    }
    Ok(())
}

// ───────────────────── wire-fault differential ─────────────────────

/// Aggregate result of one wire-fault differential sweep.
#[derive(Debug, Default)]
pub struct WireFaultOutcome {
    pub cases: usize,
    /// Recovery counters summed over every faulted world in the sweep.
    pub retransmits: u64,
    pub reconnects: u64,
    pub dropped_dups: u64,
    /// Total injected wire faults, by the injectors' own accounting.
    pub injected: u64,
    /// XOR of the per-world [`crate::mpi::WireFaultReport`] digests —
    /// the replay fingerprint: the same sweep at the same seed yields
    /// the same value.
    pub fault_digest: u64,
    /// Human-readable failure descriptions (empty = all cases passed).
    pub failures: Vec<String>,
}

/// Fold a (possibly about-to-be-replaced) faulted world's recovery
/// counters and injection report into the outcome.
fn absorb_wire<T: Elem>(world: &World<T>, out: &mut WireFaultOutcome) {
    let s = world.wire_stats();
    out.retransmits += s.retransmits;
    out.reconnects += s.reconnects;
    out.dropped_dups += s.dropped_dups;
    if let Some(r) = world.wire_report() {
        out.injected += r.injected();
        out.fault_digest ^= r.digest;
    }
}

/// The self-healing gate (EXPERIMENTS.md §Robustness): a representative
/// algorithm set run on a wire backend with seeded frame faults injected
/// **below** the chaos boundary and recovery enabled must be
/// bit-identical — outputs, traces and chaos schedule digest — to the
/// clean thread-world oracle at the same seeds, while actually
/// exercising the repair machinery (the sweep must retransmit at least
/// once, or it proved nothing and fails). Chaos injection runs on *both*
/// worlds at the same derived seed, so the digest comparison pins the
/// layering claim: wire corruption and repair below the boundary is
/// invisible to everything above it.
pub fn wire_fault_differential(
    backend: TransportBackend,
    seed: u64,
    p_values: &[usize],
    m_values: &[usize],
) -> WireFaultOutcome {
    let mut out = WireFaultOutcome::default();
    for &p in p_values {
        assert!(p >= 2, "wire-fault differential needs p >= 2");
        let chaos_seed = seed ^ (p as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mk_faulted = || -> World<i64> {
            World::new(
                WorldConfig::new(Topology::flat(p))
                    .with_trace(true)
                    .with_transport(backend)
                    .with_chaos(ChaosConfig::new(chaos_seed))
                    .with_wire_faults(WireFaultConfig::new(seed)),
            )
        };
        let mk_oracle = || -> World<i64> {
            World::new(
                WorldConfig::new(Topology::flat(p))
                    .with_trace(true)
                    .with_chaos(ChaosConfig::new(chaos_seed)),
            )
        };
        let mut faulted = mk_faulted();
        let mut oracle_w = mk_oracle();
        let fails_before = out.failures.len();
        let algos: Vec<Box<dyn ScanAlgorithm<i64>>> = vec![
            Box::new(Exscan123),
            Box::new(ExscanOneDoubling),
            Box::new(ExscanMpich),
            Box::new(Exscan1247),
        ];
        let mk_ops =
            [ops::bxor as fn() -> OpRef<i64>, ops::sum_i64 as fn() -> OpRef<i64>];
        for &m in m_values {
            for mk_op in &mk_ops {
                let inputs = crate::bench::inputs_i64(
                    p,
                    m,
                    seed ^ (m as u64).wrapping_mul(0xC2B2_AE35),
                );
                for algo in &algos {
                    out.cases += 1;
                    let op = mk_op();
                    let label = format!(
                        "wire-fault algo={} op={} backend={backend} p={p} m={m} \
                         seed={seed} (reproduce: exscan fuzz --transport {backend} \
                         --wire-fault-seed {seed} --p {p} --m {m})",
                        algo.name(),
                        op.name()
                    );
                    let f_run = run_world_scan(&faulted, algo.as_ref(), &op, &inputs);
                    let o_run = run_world_scan(&oracle_w, algo.as_ref(), &op, &inputs);
                    let ((f_out, f_tr), (o_out, o_tr)) = match (f_run, o_run) {
                        (Ok(f), Ok(o)) => (f, o),
                        // A failed run leaves the faulted transport
                        // poisoned (and the oracle world possibly holding
                        // stale tags): rebuild both, absorbing the
                        // faulted world's counters first.
                        (Err(e), _) => {
                            out.failures
                                .push(format!("{label}: faulted run failed: {e:#}"));
                            absorb_wire(&faulted, &mut out);
                            faulted = mk_faulted();
                            oracle_w = mk_oracle();
                            continue;
                        }
                        (_, Err(e)) => {
                            out.failures
                                .push(format!("{label}: oracle run failed: {e:#}"));
                            absorb_wire(&faulted, &mut out);
                            faulted = mk_faulted();
                            oracle_w = mk_oracle();
                            continue;
                        }
                    };
                    if f_out != o_out {
                        out.failures.push(format!(
                            "{label}: outputs diverged from the thread oracle"
                        ));
                        continue;
                    }
                    if let Some(msg) = oracle_check_exact(&inputs, &op, &f_out) {
                        out.failures.push(format!("{label}: oracle mismatch: {msg}"));
                        continue;
                    }
                    if f_tr.traces.len() != o_tr.traces.len()
                        || f_tr
                            .traces
                            .iter()
                            .zip(&o_tr.traces)
                            .any(|(a, b)| a.events != b.events)
                    {
                        out.failures.push(format!(
                            "{label}: traces diverged from the thread oracle"
                        ));
                        continue;
                    }
                }
            }
        }
        // Chaos decisions live above the transport boundary: for a clean
        // sweep the schedule digests must agree bit for bit even though
        // the wire below was being corrupted and repaired the whole time.
        if out.failures.len() == fails_before {
            let fd = faulted.chaos_report().map(|r| r.schedule_digest);
            let od = oracle_w.chaos_report().map(|r| r.schedule_digest);
            if fd != od {
                out.failures.push(format!(
                    "wire-fault backend={backend} p={p} seed={seed}: chaos schedule \
                     digest {fd:?} != thread-oracle digest {od:?}"
                ));
            }
        }
        absorb_wire(&faulted, &mut out);
    }
    if out.failures.is_empty() && out.retransmits == 0 {
        out.failures.push(format!(
            "wire-fault sweep (backend={backend}, seed={seed}) exercised no \
             retransmission — the self-healing gate proved nothing"
        ));
    }
    out
}

/// Recovery disabled: the same class of injected wire faults must
/// surface as a **typed, attributed** failure — an error chain naming
/// the transport fault, a populated [`World::transport_fault`], the
/// faulting channel's source rank in [`World::dead_ranks`] — and must
/// surface promptly via poison-wake, never as a receiver-thread panic
/// and never by waiting out the receive deadline. Storm-level
/// probabilities (boosted further here) make the first faults land
/// within a handful of frames at any seed.
pub fn wire_fault_no_recovery(
    backend: TransportBackend,
    seed: u64,
    p: usize,
) -> std::result::Result<(), String> {
    assert!(p >= 2, "wire-fault differential needs p >= 2");
    const M: usize = 64;
    let deadline = std::time::Duration::from_secs(2);
    let op = ops::bxor();
    let inputs = crate::bench::inputs_i64(p, M, seed);
    let cfg = WireFaultConfig::storm(seed)
        .with_checksum_prob(0.5)
        .with_truncate_prob(0.25)
        .without_recovery();
    let world: World<i64> = World::new(
        WorldConfig::new(Topology::flat(p))
            .with_transport(backend)
            .with_wire_faults(cfg)
            .with_recv_timeout(deadline),
    );
    // Per-frame corruption odds are ~2/3, so a fault lands almost surely
    // in the first scan; the retries only guard pathological seeds
    // (decisions are pure in seq, so later runs sample fresh ones).
    let mut failure: Option<String> = None;
    for _ in 0..4 {
        let t0 = std::time::Instant::now();
        let run = world.run(|ctx| {
            let input = &inputs[ctx.rank()];
            let mut output = vec![0i64; M];
            Exscan123.run(ctx, input, &mut output, &op)?;
            Ok(output)
        });
        match run {
            Ok(_) => continue,
            Err(e) => {
                if t0.elapsed() >= deadline {
                    return Err(format!(
                        "backend={backend} seed={seed} p={p}: survivors waited out \
                         the receive deadline instead of being poisoned awake"
                    ));
                }
                failure = Some(format!("{e:#}"));
                break;
            }
        }
    }
    let Some(err) = failure else {
        return Err(format!(
            "backend={backend} seed={seed} p={p}: storm-faulted world kept \
             succeeding with recovery disabled"
        ));
    };
    if !err.contains("transport fault") {
        return Err(format!(
            "backend={backend} seed={seed} p={p}: failure not attributed to a \
             transport fault: {err}"
        ));
    }
    let Some(fault) = world.transport_fault() else {
        return Err(format!(
            "backend={backend} seed={seed} p={p}: no typed fault recorded on the \
             transport"
        ));
    };
    if fault.attempts < 1 {
        return Err(format!(
            "backend={backend} seed={seed} p={p}: typed fault carries zero attempts"
        ));
    }
    if !world.dead_ranks().contains(&fault.src) {
        return Err(format!(
            "backend={backend} seed={seed} p={p}: fault channel source {} absent \
             from the dead-rank registry {:?}",
            fault.src,
            world.dead_ranks()
        ));
    }
    if world.wire_stats().faults == 0 {
        return Err(format!(
            "backend={backend} seed={seed} p={p}: fault counter still zero after \
             an attributed failure"
        ));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpi::ops;

    #[test]
    fn oracle_scan_sum() {
        let inputs: Vec<Vec<i64>> = (1..=4).map(|r| vec![r as i64, 10 * r as i64]).collect();
        let out = oracle_scan(&inputs, &ops::sum_i64());
        assert_eq!(out[0], vec![1, 10]);
        assert_eq!(out[1], vec![3, 30]);
        assert_eq!(out[3], vec![10, 100]);
    }

    #[test]
    fn oracle_exscan_sum() {
        let inputs: Vec<Vec<i64>> = (1..=4).map(|r| vec![r as i64]).collect();
        let out = oracle_exscan(&inputs, &ops::sum_i64());
        assert!(out[0].is_none());
        assert_eq!(out[1].as_ref().unwrap(), &vec![1]);
        assert_eq!(out[3].as_ref().unwrap(), &vec![6]);
    }

    #[test]
    fn rank_death_differential_attributes_and_matches_oracle() {
        rank_death_differential(0xD1FF, 4).unwrap();
    }

    #[test]
    fn oracle_respects_order_noncommutative() {
        use crate::mpi::Rec2;
        let a = Rec2::new([1.0, 1.0, 0.0, 1.0], [1.0, 2.0]);
        let b = Rec2::new([2.0, 0.0, 1.0, 1.0], [0.0, 1.0]);
        let c = Rec2::new([0.0, 1.0, 1.0, 0.0], [3.0, 0.0]);
        let inputs = vec![vec![a], vec![b], vec![c]];
        let out = oracle_scan(&inputs, &ops::rec2_compose());
        // out[2] must be a∘then b∘then c in rank order: a.then(b).then(c)
        assert_eq!(out[2][0], a.then(&b).then(&c));
    }
}
