//! Supporting collectives (binomial broadcast/reduce, recursive-doubling
//! allreduce, chain gather/scatter to a root) — the substrate an MPI-like
//! library needs around the scan family, used by the hierarchical exscan
//! and available standalone. All are round-tagged and one-ported like the
//! scan algorithms, so the same trace machinery verifies them.
//!
//! Round-tag discipline: every collective takes a `base` round offset and
//! returns the first free round index, so collectives can be sequenced in
//! one algorithm without tag collisions.

use anyhow::Result;

use crate::mpi::{Elem, OpRef, RankCtx};
use crate::util::ceil_log2;

/// Binomial-tree broadcast from `root`. Returns the next free round.
pub fn bcast<T: Elem>(
    ctx: &mut RankCtx<T>,
    base: u32,
    root: usize,
    buf: &mut [T],
) -> Result<u32> {
    let p = ctx.size();
    if p <= 1 {
        return Ok(base);
    }
    let rounds = ceil_log2(p);
    // Work in root-relative rank space: vr = (rank - root) mod p.
    let vr = (ctx.rank() + p - root) % p;
    // Round k: every rank vr < 2^k that already holds the data sends to
    // vr + 2^k (doubling the informed set each round).
    for k in 0..rounds {
        let span = 1usize << k;
        if vr < span {
            let dst = vr + span;
            if dst < p {
                ctx.send(base + k, (dst + root) % p, buf)?;
            }
        } else if vr < span * 2 {
            let src = vr - span;
            ctx.recv(base + k, (src + root) % p, buf)?;
        }
    }
    Ok(base + rounds)
}

/// Binomial-tree reduction to `root`: `result = V_0 ⊕ V_1 ⊕ … ⊕ V_{p-1}`
/// in rank order (safe for non-commutative ⊕). Root-relative only for
/// `root == 0` reductions of ordered data; general roots reduce in
/// *rank* order and then move the result, costing one extra round.
pub fn reduce<T: Elem>(
    ctx: &mut RankCtx<T>,
    base: u32,
    root: usize,
    op: &OpRef<T>,
    input: &[T],
    output: &mut [T],
) -> Result<u32> {
    let p = ctx.size();
    let r = ctx.rank();
    // Resolve ⊕ to its slice kernel once for the whole collective
    // (the per-application dispatch is then a direct call — mpi::op).
    let op = &ctx.kernel(op);
    let mut acc = ctx.scratch_from(input);
    let rounds = ceil_log2(p.max(2));
    if p > 1 {
        // Binomial combine toward rank 0, preserving rank order: at level
        // k, rank r (r % 2^{k+1} == 0) folds in r + 2^k (later block):
        // acc = acc ⊕ recv, fused in the pooled receive buffer.
        for k in 0..rounds {
            let span = 1usize << k;
            if r % (span * 2) == 0 {
                let src = r + span;
                if src < p {
                    ctx.recv_reduce_right(base + k, src, op, &mut acc)?;
                }
            } else if r % (span * 2) == span {
                ctx.send(base + k, r - span, &acc)?;
                break; // this rank is done after sending
            }
        }
    }
    let mut next = base + if p > 1 { rounds } else { 0 };
    if root == 0 {
        if r == 0 {
            output.copy_from_slice(&acc);
        }
    } else {
        // Move the result from rank 0 to the requested root.
        if r == 0 {
            ctx.send(next, root, &acc)?;
        } else if r == root {
            ctx.recv(next, 0, output)?;
        }
        next += 1;
    }
    Ok(next)
}

/// Recursive-doubling allreduce (rank order preserved for non-commutative
/// ⊕ via the mpich swap trick). Requires no identity element.
pub fn allreduce<T: Elem>(
    ctx: &mut RankCtx<T>,
    base: u32,
    op: &OpRef<T>,
    input: &[T],
    output: &mut [T],
) -> Result<u32> {
    let p = ctx.size();
    let r = ctx.rank();
    // Resolve ⊕ to its slice kernel once for the whole collective
    // (the per-application dispatch is then a direct call — mpi::op).
    let op = &ctx.kernel(op);
    output.copy_from_slice(input);
    if p <= 1 {
        return Ok(base);
    }
    // Non-power-of-two handling, mpich-style and rank-order safe: pair up
    // the first 2·tail ranks (odd sends into even), so the surviving
    // "body" ranks hold *contiguous* rank blocks and recursive doubling
    // remains correct for non-commutative ⊕.
    let body = 1usize << crate::util::floor_log2(p);
    let tail = p - body;
    let mut k = base;
    // Body index nr for participating ranks; None while waiting.
    let nr: Option<usize> = if tail == 0 {
        Some(r)
    } else if r < 2 * tail {
        if r % 2 == 1 {
            ctx.send(k, r - 1, output)?;
            None
        } else {
            // Own block (r) is earlier than r+1's: output = output ⊕ recv.
            ctx.recv_reduce_right(k, r + 1, op, output)?;
            Some(r / 2)
        }
    } else {
        Some(r - tail)
    };
    if tail > 0 {
        k += 1;
    }
    // Recursive doubling over the body; blocks stay contiguous in nr
    // order (nr < partner ⇔ our block is earlier). Both operand orders
    // run fused, straight out of the pooled receive buffer.
    let rd_rounds = crate::util::ceil_log2(body.max(2));
    if let Some(nr) = nr {
        let orig = |x: usize| if x < tail { 2 * x } else { x + tail };
        let mut mask = 1usize;
        let mut kk = k;
        while mask < body {
            let dst_nr = nr ^ mask;
            let dst = orig(dst_nr);
            if nr > dst_nr {
                // Partner block earlier: output = recv ⊕ output.
                ctx.sendrecv_reduce(kk, dst, dst, op, output)?;
            } else {
                // Own block earlier: output = output ⊕ recv.
                ctx.sendrecv_reduce_right(kk, dst, dst, op, output)?;
            }
            mask <<= 1;
            kk += 1;
        }
    }
    k += if body >= 2 { rd_rounds } else { 0 };
    // Paired-out ranks get the final value back.
    if tail > 0 {
        if r < 2 * tail {
            if r % 2 == 0 {
                ctx.send(k, r + 1, output)?;
            } else {
                ctx.recv(k, r - 1, output)?;
            }
        }
        k += 1;
    }
    Ok(k)
}

/// Gather m-element vectors from `group` members to `group[0]` over a
/// chain (one receive per round at the root — one-ported). `rows` must
/// hold `group.len() * m` at the root; others may pass an empty slice.
pub fn gather_chain<T: Elem>(
    ctx: &mut RankCtx<T>,
    base: u32,
    group: &[usize],
    input: &[T],
    rows: &mut [T],
) -> Result<u32> {
    let r = ctx.rank();
    let m = input.len();
    let root = group[0];
    if r == root {
        rows[..m].copy_from_slice(input);
        for (j, &src) in group.iter().enumerate().skip(1) {
            ctx.recv(base + j as u32 - 1, src, &mut rows[j * m..(j + 1) * m])?;
        }
    } else if let Some(j) = group.iter().position(|&g| g == r) {
        ctx.send(base + j as u32 - 1, root, input)?;
    }
    Ok(base + group.len() as u32 - 1)
}

/// Scatter per-member m-element rows from `group[0]` over a chain.
pub fn scatter_chain<T: Elem>(
    ctx: &mut RankCtx<T>,
    base: u32,
    group: &[usize],
    rows: &[T],
    output: &mut [T],
) -> Result<u32> {
    let r = ctx.rank();
    let m = output.len();
    let root = group[0];
    if r == root {
        output.copy_from_slice(&rows[..m]);
        for (j, &dst) in group.iter().enumerate().skip(1) {
            ctx.send(base + j as u32 - 1, dst, &rows[j * m..(j + 1) * m])?;
        }
    } else if let Some(j) = group.iter().position(|&g| g == r) {
        ctx.recv(base + j as u32 - 1, root, output)?;
    }
    Ok(base + group.len() as u32 - 1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpi::{ops, run_world, Topology, WorldConfig};

    #[test]
    fn bcast_all_roots() {
        for p in [2usize, 3, 5, 8, 13] {
            for root in [0, p / 2, p - 1] {
                let cfg = WorldConfig::new(Topology::flat(p));
                let out = run_world::<i64, Vec<i64>, _>(&cfg, |ctx| {
                    let mut buf = if ctx.rank() == root { vec![42, -7] } else { vec![0, 0] };
                    bcast(ctx, 0, root, &mut buf)?;
                    Ok(buf)
                })
                .unwrap();
                for (r, v) in out.iter().enumerate() {
                    assert_eq!(v, &vec![42, -7], "p={p} root={root} r={r}");
                }
            }
        }
    }

    #[test]
    fn reduce_rank_order() {
        use crate::mpi::Rec2;
        for p in [2usize, 3, 6, 9] {
            let cfg = WorldConfig::new(Topology::flat(p));
            let inputs: Vec<Rec2> = (0..p)
                .map(|r| Rec2::new([1.0, 0.1 * r as f32, 0.0, 1.0], [r as f32, 1.0]))
                .collect();
            let expect = inputs[1..].iter().fold(inputs[0], |a, e| a.then(e));
            let ins = inputs.clone();
            let out = run_world::<Rec2, Vec<Rec2>, _>(&cfg, move |ctx| {
                let mut out = vec![Rec2::identity()];
                reduce(ctx, 0, 0, &ops::rec2_compose(), &[ins[ctx.rank()]], &mut out)?;
                Ok(out)
            })
            .unwrap();
            for i in 0..4 {
                assert!((out[0][0].a[i] - expect.a[i]).abs() < 1e-4, "p={p}");
            }
        }
    }

    #[test]
    fn reduce_to_nonzero_root() {
        let p = 7;
        let cfg = WorldConfig::new(Topology::flat(p));
        let out = run_world::<i64, i64, _>(&cfg, |ctx| {
            let mut out = vec![0i64];
            reduce(ctx, 0, 3, &ops::sum_i64(), &[ctx.rank() as i64], &mut out)?;
            Ok(out[0])
        })
        .unwrap();
        assert_eq!(out[3], 21);
    }

    #[test]
    fn allreduce_matches_total() {
        for p in [2usize, 3, 4, 5, 7, 8, 12, 16] {
            let cfg = WorldConfig::new(Topology::flat(p));
            let out = run_world::<i64, Vec<i64>, _>(&cfg, |ctx| {
                let input = vec![ctx.rank() as i64 + 1, 1 << ctx.rank()];
                let mut output = vec![0i64; 2];
                allreduce(ctx, 0, &ops::sum_i64(), &input, &mut output)?;
                Ok(output)
            })
            .unwrap();
            let total: i64 = (0..p as i64).map(|r| r + 1).sum();
            let mask: i64 = (0..p).map(|r| 1i64 << r).sum();
            for (r, v) in out.iter().enumerate() {
                assert_eq!(v, &vec![total, mask], "p={p} r={r}");
            }
        }
    }

    #[test]
    fn allreduce_noncommutative() {
        use crate::mpi::Rec2;
        for p in [3usize, 5, 8] {
            let cfg = WorldConfig::new(Topology::flat(p));
            let inputs: Vec<Rec2> = (0..p)
                .map(|r| Rec2::new([1.0, 0.05 * r as f32, 0.02, 1.0], [1.0, -(r as f32)]))
                .collect();
            let expect = inputs[1..].iter().fold(inputs[0], |a, e| a.then(e));
            let ins = inputs.clone();
            let out = run_world::<Rec2, Rec2, _>(&cfg, move |ctx| {
                let mut output = vec![Rec2::identity()];
                allreduce(ctx, 0, &ops::rec2_compose(), &[ins[ctx.rank()]], &mut output)?;
                Ok(output[0])
            })
            .unwrap();
            for v in &out {
                for i in 0..4 {
                    assert!((v.a[i] - expect.a[i]).abs() < 1e-3, "p={p}");
                }
            }
        }
    }

    #[test]
    fn gather_scatter_roundtrip() {
        let p = 6;
        let group: Vec<usize> = vec![2, 0, 4, 5]; // root = 2
        let cfg = WorldConfig::new(Topology::flat(p));
        let g2 = group.clone();
        let out = run_world::<i64, Vec<i64>, _>(&cfg, move |ctx| {
            let r = ctx.rank();
            let input = vec![r as i64 * 10, r as i64 * 10 + 1];
            let in_group = g2.contains(&r);
            let mut rows = if r == g2[0] { vec![0i64; g2.len() * 2] } else { vec![] };
            if in_group {
                gather_chain(ctx, 0, &g2, &input, &mut rows)?;
            }
            // Root doubles everything, scatters back.
            let mut output = vec![0i64; 2];
            if in_group {
                if r == g2[0] {
                    for v in rows.iter_mut() {
                        *v *= 2;
                    }
                }
                scatter_chain(ctx, 100, &g2, &rows, &mut output)?;
            }
            Ok(output)
        })
        .unwrap();
        for &g in &group {
            assert_eq!(out[g], vec![g as i64 * 20, g as i64 * 20 + 2], "rank {g}");
        }
    }
}
