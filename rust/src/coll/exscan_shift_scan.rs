//! Exclusive scan by "inclusive scan, then shift": the other conventional
//! reduction the paper's introduction sketches. Runs the full
//! `⌈log₂p⌉`-round doubling inclusive scan on all p ranks, then one extra
//! round shifting `W_r` to `r+1`. One more round than 1-doubling whenever
//! `⌈log₂p⌉ = ⌈log₂(p−1)⌉`, and it scans one rank more than necessary —
//! included to make the paper's "shift before vs shift after" comparison
//! concrete.

use anyhow::Result;

use super::scan_doubling::ScanDoubling;
use super::{ScanAlgorithm, ScanKind};
use crate::mpi::{Elem, OpRef, RankCtx};
use crate::util::ceil_log2;

/// Inclusive doubling scan followed by a right shift.
pub struct ExscanShiftScan;

impl<T: Elem> ScanAlgorithm<T> for ExscanShiftScan {
    fn name(&self) -> &'static str {
        "scan-then-shift"
    }

    fn kind(&self) -> ScanKind {
        ScanKind::Exclusive
    }

    fn run(
        &self,
        ctx: &mut RankCtx<T>,
        input: &[T],
        output: &mut [T],
        op: &OpRef<T>,
    ) -> Result<()> {
        let (r, p, m) = (ctx.rank(), ctx.size(), input.len());
        if p <= 1 {
            return Ok(());
        }
        // Inclusive scan into a pooled temporary (rounds 0..⌈log₂p⌉).
        let mut inc = ctx.scratch_filled(m);
        ScanAlgorithm::<T>::run(&ScanDoubling, ctx, input, &mut inc, op)?;
        // Shift round: W_r -> r+1.
        let shift_round = ceil_log2(p);
        let (to, from) = (r + 1, r.checked_sub(1));
        match (to < p, from) {
            (true, Some(f)) => ctx.sendrecv(shift_round, to, &inc, f, output)?,
            (true, None) => ctx.send(shift_round, to, &inc)?,
            (false, Some(f)) => ctx.recv(shift_round, f, output)?,
            (false, None) => unreachable!("p > 1"),
        }
        Ok(())
    }

    fn predicted_rounds(&self, p: usize) -> u32 {
        if p <= 1 {
            0
        } else {
            ceil_log2(p) + 1
        }
    }

    fn predicted_ops(&self, p: usize) -> u32 {
        // The inclusive scan's per-rank folds; the shift adds none.
        if p <= 1 {
            0
        } else {
            ceil_log2(p)
        }
    }

    fn critical_skips(&self, p: usize) -> Vec<usize> {
        let mut s = <ScanDoubling as ScanAlgorithm<i64>>::critical_skips(&ScanDoubling, p);
        s.push(1);
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coll::validate::assert_exscan_matches;
    use crate::mpi::{ops, run_scan, Topology, WorldConfig};

    #[test]
    fn matches_oracle() {
        for p in [2usize, 3, 5, 9, 17, 36] {
            let cfg = WorldConfig::new(Topology::flat(p));
            let inputs: Vec<Vec<i64>> = (0..p).map(|r| vec![(r as i64) << 1 | 1]).collect();
            let res = run_scan(&cfg, &ExscanShiftScan, &ops::bxor(), &inputs).unwrap();
            assert_exscan_matches(&inputs, &ops::bxor(), &res.outputs);
        }
    }

    #[test]
    fn one_extra_round() {
        let p = 36;
        let cfg = WorldConfig::new(Topology::flat(p)).with_trace(true);
        let inputs: Vec<Vec<i64>> = (0..p).map(|r| vec![r as i64]).collect();
        let res = run_scan(&cfg, &ExscanShiftScan, &ops::bxor(), &inputs).unwrap();
        let trace = res.trace.unwrap();
        assert_eq!(trace.total_rounds(), 7); // ceil(log2 36) + 1
        assert!(crate::trace::check_all(&trace).is_empty());
    }
}
