//! **1247-doubling exclusive scan** — the doubly-fortified algorithm from
//! Träff's 2026 follow-up *"Two Efficient Message-passing Exclusive Scan
//! Algorithms"*: skips `1, 2, 4, 7, 14, 28, …` with **two** fortified
//! rounds where [`Exscan123`](super::Exscan123) has one.
//!
//! * Round 0 shifts `V_{r-1}` into `W_r` (no ⊕), as in 123-doubling.
//! * Rounds 1 (skip 2) and 2 (skip 4) are *fortified*: rank `r` sends
//!   the inclusive partial `W ⊕ V`, so the receiver's trailing coverage
//!   jumps `1 → 3 → 7` — one doubling-plus-one step further than 123's
//!   single fortified round.
//! * Rounds `k ≥ 3` are plain exclusive doubling with skips
//!   `s_k = 7·2^{k-3} = c_{k-1}`: fold the incoming `W_{r-s}`, sent
//!   as-is. Rank 0 (whose W is empty) exits after its round-2 send.
//!
//! Coverage after round `k` is `c_0 = 1, c_1 = 3, c_2 = 7, c_k =
//! 2·c_{k-1}`, so the total is `q = ⌈log₂(p−1) + log₂(8/7)⌉` rounds —
//! between [`ExscanPow2`](super::ExscanPow2)'s `⌈log₂ p⌉` lower bound and
//! 123's `⌈log₂(p−1) + log₂(4/3)⌉` (strictly fewer than 123 at e.g.
//! p = 29, equal at p = 36). The completion-critical rank still applies
//! only `q − 1` ⊕ (round 0 is a copy); middle ranks pay up to one extra
//! ⊕ in each of the two fortified rounds, so no rank exceeds `q + 1`.
//! This is the middle step of the fortification ladder: more fortified
//! rounds trade per-rank ⊕ for round count.

use anyhow::Result;

use super::{ScanAlgorithm, ScanKind};
use crate::mpi::{Elem, OpRef, RankCtx};
use crate::util::bits::rounds_1247;

/// 1247-doubling exclusive scan (2026 follow-up paper).
pub struct Exscan1247;

impl<T: Elem> ScanAlgorithm<T> for Exscan1247 {
    fn name(&self) -> &'static str {
        "1247-doubling"
    }

    fn kind(&self) -> ScanKind {
        ScanKind::Exclusive
    }

    fn run(
        &self,
        ctx: &mut RankCtx<T>,
        input: &[T],
        output: &mut [T],
        op: &OpRef<T>,
    ) -> Result<()> {
        let (r, p) = (ctx.rank(), ctx.size());
        if p <= 1 {
            return Ok(());
        }
        let op = &ctx.kernel(op);
        // ── Round 0, s_0 = 1: shift V right; establishes W_r = V_{r-1}. ──
        {
            let (t, f) = (r + 1, r.checked_sub(1));
            match (t < p, f) {
                (true, Some(f)) => ctx.sendrecv(0, t, input, f, output)?,
                (true, None) => ctx.send(0, t, input)?, // rank 0
                (false, Some(f)) => ctx.recv(0, f, output)?, // rank p-1
                (false, None) => unreachable!("p > 1"),
            }
        }
        if p == 2 {
            return Ok(()); // rank 1 already holds V_0
        }

        // ── Fortified rounds 1 (skip 2) and 2 (skip 4): send W ⊕ V so the
        // receiver's coverage jumps 1 → 3 → 7. Rank 0 sends its bare input
        // (its inclusive partial is V_0) and pays no ⊕; the incoming
        // partial always folds as the earlier operand. ──
        for (k, s) in [(1u32, 2usize), (2, 4)] {
            let send = r + s < p;
            let recv = r >= s;
            match (send, recv) {
                (true, true) => {
                    let mut w_prime = ctx.scratch_from(input);
                    ctx.reduce_local(k, op, output, &mut w_prime);
                    ctx.sendrecv_reduce_into(k, r + s, &w_prime, r - s, op, output)?;
                }
                (true, false) if r == 0 => ctx.send(k, r + s, input)?,
                (true, false) => {
                    let mut w_prime = ctx.scratch_from(input);
                    ctx.reduce_local(k, op, output, &mut w_prime);
                    ctx.send(k, r + s, &w_prime)?;
                }
                (false, true) => ctx.recv_reduce(k, r - s, op, output)?,
                (false, false) => {}
            }
        }

        // ── Rounds k >= 3, s_k = 7·2^(k-3) = c_{k-1}: plain exclusive
        // doubling — the value sent is the value kept. Receives come from
        // ranks f >= 1 only (r > s ⇒ f = r − s >= 1; rank 0 has left),
        // and a rank whose coverage already reaches r (r <= c_{k-1}) only
        // keeps sending. Both conditions are monotone in k, so a rank is
        // done once neither holds. ──
        let mut k = 3u32;
        let mut s = 7usize;
        loop {
            let send = r >= 1 && r + s < p;
            let recv = r > s; // r > c_{k-1}: still missing trailing inputs
            match (send, recv) {
                (true, true) => ctx.sendrecv_reduce(k, r + s, r - s, op, output)?,
                (true, false) => ctx.send(k, r + s, output)?,
                (false, true) => ctx.recv_reduce(k, r - s, op, output)?,
                (false, false) => break,
            }
            k += 1;
            s *= 2;
        }
        Ok(())
    }

    fn predicted_rounds(&self, p: usize) -> u32 {
        rounds_1247(p)
    }

    /// `q − 1` ⊕ on the completion-critical rank `p−1` (round 0 is a
    /// copy) — same count as 123-doubling at fewer-or-equal rounds.
    fn predicted_ops(&self, p: usize) -> u32 {
        rounds_1247(p).saturating_sub(1)
    }

    fn critical_skips(&self, p: usize) -> Vec<usize> {
        // Receive distances of rank p-1: 1, 2, 4, 7, 14, … until coverage.
        let q = rounds_1247(p);
        (0..q)
            .map(|k| match k {
                0 => 1,
                1 => 2,
                2 => 4,
                _ => 7 * (1usize << (k - 3)),
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coll::validate::assert_exscan_matches;
    use crate::mpi::{ops, run_scan, Topology, WorldConfig};
    use crate::util::bits::{rounds_123, rounds_pow2};

    #[test]
    fn matches_oracle_exhaustive_small_p() {
        for p in 2usize..=40 {
            let cfg = WorldConfig::new(Topology::flat(p));
            let inputs: Vec<Vec<i64>> = (0..p)
                .map(|r| vec![(r as i64).wrapping_mul(0x2545_F491) ^ 0x3C3C, 1 << (r % 60)])
                .collect();
            let res = run_scan(&cfg, &Exscan1247, &ops::bxor(), &inputs).unwrap();
            assert_exscan_matches(&inputs, &ops::bxor(), &res.outputs);
        }
    }

    #[test]
    fn closed_form_rounds_and_ops() {
        for p in 2usize..=70 {
            let cfg = WorldConfig::new(Topology::flat(p)).with_trace(true);
            let inputs: Vec<Vec<i64>> = (0..p).map(|r| vec![r as i64]).collect();
            let res = run_scan(&cfg, &Exscan1247, &ops::bxor(), &inputs).unwrap();
            let trace = res.trace.unwrap();
            let algo: &dyn ScanAlgorithm<i64> = &Exscan1247;
            let q = algo.predicted_rounds(p);
            assert_eq!(trace.total_rounds(), q, "rounds p={p}");
            assert_eq!(trace.last_rank_ops(), algo.predicted_ops(p), "last-rank ops p={p}");
            // Middle ranks pay one extra ⊕ in each of the two fortified rounds.
            assert!(trace.max_ops() <= q + 1, "max ops bound p={p}");
            assert!(crate::trace::check_all(&trace).is_empty(), "invariants p={p}");
        }
    }

    #[test]
    fn sits_between_pow2_and_123() {
        let algo: &dyn ScanAlgorithm<i64> = &Exscan1247;
        for p in 2usize..=4096 {
            assert!(rounds_pow2(p) <= algo.predicted_rounds(p), "p={p}");
            assert!(algo.predicted_rounds(p) <= rounds_123(p), "p={p}");
        }
        // The second fortified round buys a real round at e.g. p = 29…
        assert_eq!(algo.predicted_rounds(29), 5);
        assert_eq!(rounds_123(29), 6);
        // …and matches 123 at the paper's p = 36.
        assert_eq!(algo.predicted_rounds(36), 6);
    }

    #[test]
    fn small_p_edge_arms_exhaustive_under_chaos() {
        use crate::mpi::ChaosConfig;
        use crate::trace::EventKind;
        for p in 2usize..=9 {
            for seed in [21u64, 22, 23] {
                let cfg = WorldConfig::new(Topology::flat(p))
                    .with_trace(true)
                    .with_chaos(ChaosConfig::new(seed ^ ((p as u64) << 8)));
                let inputs: Vec<Vec<i64>> =
                    (0..p).map(|r| vec![(r as i64 + 3) * 11, !(r as i64)]).collect();
                let res = run_scan(&cfg, &Exscan1247, &ops::bxor(), &inputs).unwrap();
                assert_exscan_matches(&inputs, &ops::bxor(), &res.outputs);
                let trace = res.trace.unwrap();
                let algo: &dyn ScanAlgorithm<i64> = &Exscan1247;
                let q = algo.predicted_rounds(p);
                assert_eq!(trace.total_rounds(), q, "rounds p={p} seed={seed}");
                assert_eq!(
                    trace.last_rank_ops(),
                    algo.predicted_ops(p),
                    "last-rank ops p={p} seed={seed}"
                );
                assert!(
                    crate::trace::check_all(&trace).is_empty(),
                    "invariants p={p} seed={seed}"
                );
                // Rank 0 only sends (rounds 0-2, as far as targets exist),
                // never receives or reduces, even under chaos ordering.
                let r0 = &trace.traces[0];
                assert!(
                    r0.events.iter().all(|e| !matches!(e.kind, EventKind::Recv { .. })),
                    "rank 0 must not receive, p={p} seed={seed}"
                );
                assert_eq!(r0.ops(), 0, "rank 0 must not reduce, p={p} seed={seed}");
                assert_eq!(
                    r0.comm_rounds(),
                    q.min(3),
                    "rank 0 exits after its round-2 send, p={p} seed={seed}"
                );
            }
        }
    }

    #[test]
    fn noncommutative_order() {
        use crate::coll::validate::oracle_exscan;
        use crate::mpi::Rec2;
        for p in [3usize, 5, 9, 14, 29] {
            let cfg = WorldConfig::new(Topology::flat(p));
            let inputs: Vec<Vec<Rec2>> = (0..p)
                .map(|r| {
                    vec![Rec2::new(
                        [1.0, 0.02 * r as f32, -0.03 * r as f32, 1.0],
                        [r as f32 * 0.4, 1.0 - r as f32 * 0.2],
                    )]
                })
                .collect();
            let res = run_scan(&cfg, &Exscan1247, &ops::rec2_compose(), &inputs).unwrap();
            let oracle = oracle_exscan(&inputs, &ops::rec2_compose());
            for r in 1..p {
                let e = oracle[r].as_ref().unwrap();
                for i in 0..4 {
                    assert!(
                        (res.outputs[r][0].a[i] - e[0].a[i]).abs() < 1e-3,
                        "p={p} r={r} a[{i}]"
                    );
                }
            }
        }
    }

    #[test]
    fn multi_element_vectors() {
        let p = 23;
        for m in [0usize, 1, 2, 17, 256] {
            let cfg = WorldConfig::new(Topology::flat(p));
            let inputs: Vec<Vec<i64>> = (0..p)
                .map(|r| (0..m).map(|i| (r * 37 + i * 13) as i64).collect())
                .collect();
            let res = run_scan(&cfg, &Exscan1247, &ops::sum_i64(), &inputs).unwrap();
            assert_exscan_matches(&inputs, &ops::sum_i64(), &res.outputs);
        }
    }
}
