//! Pipelined chain exclusive scan for **large vectors** — the algorithm
//! family the paper points to ([7, 8]: pipelined, fixed-degree trees) for
//! the regime where bandwidth, not rounds, dominates. This is the
//! fixed-degree-1 member: the m-element vector is cut into B blocks that
//! ripple down the processor chain, so the per-hop payload is m/B and the
//! total time is ≈ `(p + B − 2)·(α + (m/B)·β + (m/B)·γ)` — asymptotically
//! `m·β` instead of the doubling algorithms' `⌈log₂p⌉·m·β`.
//!
//! Round structure (tag `t`): rank r receives block `t−(r−1)` from `r−1`
//! and simultaneously sends block `t−r` of the combined prefix to `r+1` —
//! one send and one receive per round, so the one-ported invariant holds
//! and the trace validator accepts it like any other algorithm here.

use anyhow::Result;

use super::{ScanAlgorithm, ScanKind};
use crate::mpi::{Elem, OpRef, RankCtx};

/// Pipelined chain exclusive scan with a block-count policy.
pub struct PipelinedChain {
    /// Fixed number of blocks, or `None` to auto-tune as ⌈√m⌉ clamped to
    /// [1, 64] (balances the `B·α` fill cost against the `m/B` payload).
    pub blocks: Option<usize>,
}

impl PipelinedChain {
    /// Auto-tuned block count.
    pub fn auto() -> Self {
        PipelinedChain { blocks: None }
    }

    /// Fixed block count (≥ 1).
    pub fn with_blocks(b: usize) -> Self {
        assert!(b >= 1);
        PipelinedChain { blocks: Some(b) }
    }

    /// The block count used for an m-element vector.
    pub fn block_count(&self, m: usize) -> usize {
        match self.blocks {
            Some(b) => b.min(m.max(1)),
            None => ((m as f64).sqrt().ceil() as usize).clamp(1, 64).min(m.max(1)),
        }
    }
}

/// Split `0..m` into `b` nearly equal contiguous block ranges.
fn block_ranges(m: usize, b: usize) -> Vec<std::ops::Range<usize>> {
    let b = b.min(m.max(1));
    let base = m / b;
    let extra = m % b;
    let mut out = Vec::with_capacity(b);
    let mut lo = 0;
    for j in 0..b {
        let len = base + usize::from(j < extra);
        out.push(lo..lo + len);
        lo += len;
    }
    out
}

impl<T: Elem> ScanAlgorithm<T> for PipelinedChain {
    fn name(&self) -> &'static str {
        "pipelined-chain"
    }

    fn kind(&self) -> ScanKind {
        ScanKind::Exclusive
    }

    fn run(
        &self,
        ctx: &mut RankCtx<T>,
        input: &[T],
        output: &mut [T],
        op: &OpRef<T>,
    ) -> Result<()> {
        let (r, p, m) = (ctx.rank(), ctx.size(), input.len());
        if p <= 1 {
            return Ok(());
        }
        // Resolve ⊕ to its slice kernel once for the whole collective
        // (the per-application dispatch is then a direct call — mpi::op).
        let op = &ctx.kernel(op);
        let nb = self.block_count(m);
        let ranges = block_ranges(m, nb);
        // Degenerate m = 0: fall back to a single empty "block" so the
        // chain still closes (every rank must hear from its predecessor).
        let ranges = if ranges.is_empty() { vec![0..0] } else { ranges };
        let nb = ranges.len();

        if r == 0 {
            // Head of the chain: stream own input blocks, one per round.
            for (j, range) in ranges.iter().enumerate() {
                ctx.send(j as u32, 1, &input[range.clone()])?;
            }
            return Ok(());
        }

        // Interior/tail rank: block j arrives at round (r-1)+j and — once
        // combined with the local input — departs at round r+j. Incoming
        // block j+1 and outgoing block j therefore share round r+j: a true
        // simultaneous send-receive (steady pipeline state).
        let sends = r + 1 < p;
        let first_t = r - 1;
        let last_t = if sends { r + nb - 1 } else { r + nb - 2 };
        // Pooled scratch buffers sized to the largest block up front, so
        // the acquire is classified against the real capacity need and
        // later per-block resizes stay within capacity (allocation-free).
        let max_block = ranges.iter().map(|r| r.len()).max().unwrap_or(0);
        let mut blk = ctx.scratch_filled(max_block);
        let mut fwd = ctx.scratch_filled(max_block); // combined block awaiting departure
        for t in first_t..=last_t {
            let j_in = t - (r - 1);
            let has_in = j_in < nb;
            let has_out = sends && t >= r; // j_out = t - r, always < nb here
            if has_in {
                blk.resize(ranges[j_in].len(), T::filler());
            }
            match (has_in, has_out) {
                (true, true) => ctx.sendrecv(t as u32, r + 1, &fwd, r - 1, &mut blk)?,
                (true, false) => ctx.recv(t as u32, r - 1, &mut blk)?,
                (false, true) => ctx.send(t as u32, r + 1, &fwd)?,
                (false, false) => unreachable!("loop bounds exclude idle rounds"),
            }
            if has_in {
                let range = ranges[j_in].clone();
                output[range.clone()].copy_from_slice(&blk);
                if sends {
                    // Prepare block j_in of W_{r+1} = W_r ⊕ V_r for round t+1.
                    fwd.copy_from(&input[range]);
                    ctx.reduce_local(t as u32, op, &blk, &mut fwd);
                }
            }
        }
        Ok(())
    }

    fn predicted_rounds(&self, p: usize) -> u32 {
        // Depends on m via B; report the p-dependent part for B = 1
        // (callers needing the exact count use `rounds_for(p, m)`).
        p.saturating_sub(1) as u32
    }

    /// m-aware round count: `p + B − 2` — what the trace measures.
    fn predicted_rounds_m(&self, p: usize, m: usize) -> u32 {
        self.rounds_for(p, m)
    }

    fn predicted_ops(&self, _p: usize) -> u32 {
        1 // per block; see `ops_for`
    }

    fn critical_skips(&self, p: usize) -> Vec<usize> {
        vec![1; p.saturating_sub(1)]
    }

    /// m-dependent prediction inputs: `p + B − 2` unit-distance rounds at
    /// block-sized payload, one ⊕ per block on an interior rank.
    fn critical_schedule(&self, p: usize, m: usize) -> (Vec<usize>, u32, usize) {
        let b = self.block_count(m);
        let rounds = (p + b).saturating_sub(2);
        (vec![1; rounds], self.ops_for(p, m), m.div_ceil(b.max(1)))
    }
}

impl PipelinedChain {
    /// Exact round count for (p, m): `p + B − 2`.
    pub fn rounds_for(&self, p: usize, m: usize) -> u32 {
        if p <= 1 {
            0
        } else {
            (p + self.block_count(m) - 2) as u32
        }
    }

    /// ⊕ applications on an interior rank: one per block.
    pub fn ops_for(&self, p: usize, m: usize) -> u32 {
        if p <= 2 {
            // rank p-1 never forwards; with p = 2 no rank combines.
            0
        } else {
            self.block_count(m) as u32
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coll::validate::assert_exscan_matches;
    use crate::mpi::{ops, run_scan, Topology, WorldConfig};

    #[test]
    fn block_ranges_cover() {
        for (m, b) in [(10, 3), (7, 7), (64, 8), (5, 64), (1, 1)] {
            let rs = block_ranges(m, b);
            assert_eq!(rs.iter().map(|r| r.len()).sum::<usize>(), m);
            let mut next = 0;
            for r in &rs {
                assert_eq!(r.start, next);
                next = r.end;
            }
            assert_eq!(next, m);
        }
    }

    #[test]
    fn matches_oracle_various_blocks() {
        for p in [2usize, 3, 5, 9] {
            for b in [1usize, 2, 4, 16] {
                let cfg = WorldConfig::new(Topology::flat(p));
                let algo = PipelinedChain::with_blocks(b);
                let inputs: Vec<Vec<i64>> =
                    (0..p).map(|r| (0..33).map(|i| (r * 100 + i) as i64).collect()).collect();
                let res = run_scan(&cfg, &algo, &ops::sum_i64(), &inputs).unwrap();
                assert_exscan_matches(&inputs, &ops::sum_i64(), &res.outputs);
            }
        }
    }

    #[test]
    fn auto_blocks_reasonable() {
        let a = PipelinedChain::auto();
        assert_eq!(a.block_count(1), 1);
        assert_eq!(a.block_count(100), 10);
        assert_eq!(a.block_count(1_000_000), 64);
    }

    #[test]
    fn round_count_and_invariants() {
        let p = 6;
        let b = 4;
        let algo = PipelinedChain::with_blocks(b);
        let cfg = WorldConfig::new(Topology::flat(p)).with_trace(true);
        let inputs: Vec<Vec<i64>> =
            (0..p).map(|r| (0..16).map(|i| (r + i) as i64).collect()).collect();
        let res = run_scan(&cfg, &algo, &ops::bxor(), &inputs).unwrap();
        let trace = res.trace.unwrap();
        assert_eq!(trace.total_rounds(), algo.rounds_for(p, 16));
        assert_eq!(trace.max_ops(), algo.ops_for(p, 16));
        assert!(crate::trace::check_all(&trace).is_empty());
    }

    #[test]
    fn zero_length_vectors() {
        let p = 4;
        let cfg = WorldConfig::new(Topology::flat(p));
        let inputs: Vec<Vec<i64>> = (0..p).map(|_| vec![]).collect();
        let res = run_scan(&cfg, &PipelinedChain::auto(), &ops::bxor(), &inputs).unwrap();
        assert!(res.outputs.iter().all(|o| o.is_empty()));
    }
}
