//! The library-native baseline: a faithful port of mpich's
//! `MPIR_Exscan_intra_recursive_doubling` — the algorithm mpich-4.1.2
//! dispatches to for `MPI_Exscan` at the message sizes the paper measures.
//!
//! Recursive doubling on the hypercube `rank ^ mask`: every round each rank
//! exchanges its running *partial_scan* (the reduction of the block of
//! ranks it has subsumed) with its cube partner, folds the partner's
//! partial into `partial_scan`, and — when the partner block lies *below*
//! its own rank — also folds it into the result buffer. Non-power-of-two
//! sizes simply skip rounds whose partner does not exist. Up to two ⊕ per
//! round, `⌈log₂p⌉` rounds, plus the extra internal buffer copies the real
//! library pays (modelled by the calibrated "native" cost parameters).

use anyhow::Result;

use super::{ScanAlgorithm, ScanKind};
use crate::mpi::{Elem, OpRef, RankCtx};
use crate::util::ceil_log2;

/// mpich-style recursive-doubling exclusive scan (the "native MPI_Exscan").
pub struct ExscanMpich;

impl<T: Elem> ScanAlgorithm<T> for ExscanMpich {
    fn name(&self) -> &'static str {
        "native-mpich"
    }

    fn kind(&self) -> ScanKind {
        ScanKind::Exclusive
    }

    fn run(
        &self,
        ctx: &mut RankCtx<T>,
        input: &[T],
        output: &mut [T],
        op: &OpRef<T>,
    ) -> Result<()> {
        let (rank, p, m) = (ctx.rank(), ctx.size(), input.len());
        if p <= 1 {
            return Ok(());
        }
        // Resolve ⊕ to its slice kernel once for the whole collective
        // (the per-application dispatch is then a direct call — mpi::op).
        let op = &ctx.kernel(op);
        // partial_scan: reduction over the contiguous rank block this rank
        // has subsumed so far; starts as the local input (mpich copies
        // sendbuf into a temporary — here a pooled ctx scratch buffer).
        let mut partial_scan = ctx.scratch_from(input);
        let mut flag = false; // has `output` received its first contribution?

        let mut mask = 1usize;
        let mut k = 0u32;
        while mask < p {
            let dst = rank ^ mask;
            if dst < p {
                if rank > dst {
                    // Partner block is strictly below ours: it extends both
                    // the partial and the exclusive result. The received
                    // partial has two consumers, so this is the one branch
                    // that keeps the owned receive (fusing would force an
                    // extra copy of the incoming vector).
                    let tmp = ctx.sendrecv_owned(k, dst, &partial_scan, dst, m)?;
                    ctx.reduce_local(k, op, &tmp, &mut partial_scan); // partial = tmp ⊕ partial
                    if !flag {
                        output.copy_from_slice(&tmp);
                        flag = true;
                    } else {
                        ctx.reduce_local(k, op, &tmp, output); // recv = tmp ⊕ recv
                    }
                } else if op.commutative() {
                    // Partner block is above: only the partial grows —
                    // fused fold straight from the receive buffer.
                    ctx.sendrecv_reduce(k, dst, dst, op, &mut partial_scan)?;
                } else {
                    // Our block is the *earlier* operand; mpich reduces
                    // (partial_scan, tmp) then swaps — the fused
                    // right-operand variant does exactly that in place.
                    ctx.sendrecv_reduce_right(k, dst, dst, op, &mut partial_scan)?;
                }
            }
            mask <<= 1;
            k += 1;
        }
        Ok(())
    }

    fn predicted_rounds(&self, p: usize) -> u32 {
        if p <= 1 {
            0
        } else {
            ceil_log2(p)
        }
    }

    /// Worst-rank bound: two ⊕ in every round it pairs in, minus the first
    /// result copy: `2⌈log₂p⌉ − 1` (attained at p a power of two).
    fn predicted_ops(&self, p: usize) -> u32 {
        if p <= 1 {
            0
        } else {
            2 * ceil_log2(p) - 1
        }
    }

    fn critical_skips(&self, p: usize) -> Vec<usize> {
        // Hypercube partner distance is exactly `mask` for the rounds the
        // last rank participates in.
        let r = p - 1;
        let mut out = Vec::new();
        let mut mask = 1usize;
        while mask < p {
            if r ^ mask < p {
                out.push(mask);
            }
            mask <<= 1;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coll::validate::assert_exscan_matches;
    use crate::mpi::{ops, run_scan, Topology, WorldConfig};

    #[test]
    fn matches_oracle_many_p() {
        for p in 2usize..=40 {
            let cfg = WorldConfig::new(Topology::flat(p));
            let inputs: Vec<Vec<i64>> =
                (0..p).map(|r| vec![(r as i64) * 17 - 4, !(r as i64) << 2]).collect();
            let res = run_scan(&cfg, &ExscanMpich, &ops::bxor(), &inputs).unwrap();
            assert_exscan_matches(&inputs, &ops::bxor(), &res.outputs);
        }
    }

    #[test]
    fn noncommutative_swap_path() {
        use crate::coll::validate::oracle_exscan;
        use crate::mpi::Rec2;
        for p in [2usize, 3, 6, 8, 13] {
            let cfg = WorldConfig::new(Topology::flat(p));
            let inputs: Vec<Vec<Rec2>> = (0..p)
                .map(|r| vec![Rec2::new([1.0, 0.1 * r as f32, 0.05, 1.0], [1.0, r as f32])])
                .collect();
            let res = run_scan(&cfg, &ExscanMpich, &ops::rec2_compose(), &inputs).unwrap();
            let oracle = oracle_exscan(&inputs, &ops::rec2_compose());
            for r in 1..p {
                let e = oracle[r].as_ref().unwrap();
                for i in 0..2 {
                    assert!((res.outputs[r][0].b[i] - e[0].b[i]).abs() < 1e-3, "p={p} r={r}");
                }
            }
        }
    }

    #[test]
    fn rounds_match() {
        for p in [2usize, 3, 4, 7, 8, 9, 36] {
            let cfg = WorldConfig::new(Topology::flat(p)).with_trace(true);
            let inputs: Vec<Vec<i64>> = (0..p).map(|r| vec![r as i64]).collect();
            let res = run_scan(&cfg, &ExscanMpich, &ops::bxor(), &inputs).unwrap();
            let trace = res.trace.unwrap();
            let algo: &dyn ScanAlgorithm<i64> = &ExscanMpich;
            assert_eq!(trace.total_rounds(), algo.predicted_rounds(p), "p={p}");
            assert!(trace.max_ops() <= algo.predicted_ops(p), "p={p}");
            assert!(crate::trace::check_all(&trace).is_empty(), "p={p}");
        }
    }
}
