//! **Algorithm 1: the 123-doubling exclusive scan** — the paper's new
//! contribution (Theorem 1).
//!
//! Skips `s_0 = 1, s_1 = 2, s_k = 3·2^{k-2}` for `k ≥ 2`.
//!
//! * Round 0 shifts `V_{r-1}` into `W_r` (no ⊕), exactly as 1-doubling.
//! * Round 1 is the trick that wins back the extra round: rank `r`
//!   *receives from distance 2* the value `W_{r-2} ⊕ V_{r-2}`
//!   (= `V_{r-3} ⊕ V_{r-2}`), so after folding it covers **three**
//!   trailing inputs — the exclusive invariant directly jumps to skip
//!   `s_2 = 3` instead of 2.
//! * Rounds `k ≥ 2` double the 3-skip: fold `W_{r-s_k}`, sent as-is.
//!
//! Total: `q = ⌈log₂(p−1) + log₂(4/3)⌉` simultaneous send-receive rounds
//! with `q−1` ⊕ applications on the completion-critical rank `p−1`
//! (middle ranks pay one extra ⊕ in round 1 to prepare the outgoing
//! `W ⊕ V`, the place where a ternary `MPI_Reduce_local` would help [10]).

use anyhow::Result;

use super::{ScanAlgorithm, ScanKind};
use crate::mpi::{Elem, OpKernel, OpRef, RankCtx};
use crate::util::bits::rounds_123;

/// The 123-doubling exscan run over an arbitrary **participant list**
/// (`ranks`, scope-relative, ascending participant order), with rounds
/// based at `base`: participant `i` contributes `total` and receives
/// `total_0 ⊕ … ⊕ total_{i−1}` into `prefix`. Returns whether `prefix`
/// was written (`false` for participant 0 — the "empty prefix" is
/// tracked out of band, no identity element required). Non-participants
/// must not call this. The caller owns round-base bookkeeping: rounds
/// `base .. base + rounds_123(ranks.len())` are consumed.
///
/// This is the inner engine shared by [`ExscanHierarchical`]
/// (participants = node leaders) and [`ExscanBlock`] (participants =
/// same-index members across groups): one source for the translated
/// round-0/round-1/doubling arms instead of three hand-inlined copies.
///
/// [`ExscanHierarchical`]: super::ExscanHierarchical
/// [`ExscanBlock`]: super::ExscanBlock
pub(crate) fn exscan_123_group<T: Elem>(
    ctx: &mut RankCtx<T>,
    base: u32,
    ranks: &[usize],
    op: &OpKernel<T>,
    total: &[T],
    prefix: &mut [T],
) -> Result<bool> {
    let nodes = ranks.len();
    let nr = ranks
        .iter()
        .position(|&x| x == ctx.rank())
        .expect("exscan_123_group caller must be a participant");
    if nodes <= 1 {
        return Ok(false);
    }
    let mut have = false;
    // Round 0 (skip 1): shift totals right.
    {
        let (t, f) = (nr + 1, nr.checked_sub(1));
        match (t < nodes, f) {
            (true, Some(f)) => {
                ctx.sendrecv(base, ranks[t], total, ranks[f], prefix)?;
                have = true;
            }
            (true, None) => ctx.send(base, ranks[t], total)?,
            (false, Some(f)) => {
                ctx.recv(base, ranks[f], prefix)?;
                have = true;
            }
            (false, None) => {}
        }
    }
    if nodes > 2 {
        // Round 1 (skip 2): send W ⊕ total so the receiver's coverage
        // jumps to three trailing participants (the 123 trick).
        let (t, f) = (nr + 2, nr.checked_sub(2));
        match (t < nodes, f, nr) {
            (true, Some(f), _) => {
                let mut w_prime = ctx.scratch_from(total);
                ctx.reduce_local(base + 1, op, prefix, &mut w_prime);
                ctx.sendrecv_reduce_into(base + 1, ranks[t], &w_prime, ranks[f], op, prefix)?;
            }
            (true, None, 0) => ctx.send(base + 1, ranks[t], total)?,
            (true, None, _) => {
                let mut w_prime = ctx.scratch_from(total);
                ctx.reduce_local(base + 1, op, prefix, &mut w_prime);
                ctx.send(base + 1, ranks[t], &w_prime)?;
            }
            (false, Some(f), _) => {
                ctx.recv_reduce(base + 1, ranks[f], op, prefix)?;
            }
            _ => {}
        }
        // Rounds >= 2 with skips 3·2^(j-2); participant 0 is done.
        let mut j = 2u32;
        let mut s = 3usize;
        while nr != 0 {
            let t = nr + s;
            let f = if nr > s { Some(nr - s) } else { None };
            match (t < nodes, f) {
                (true, Some(f)) => {
                    ctx.sendrecv_reduce(base + j, ranks[t], ranks[f], op, prefix)?
                }
                (true, None) => ctx.send(base + j, ranks[t], prefix)?,
                (false, Some(f)) => ctx.recv_reduce(base + j, ranks[f], op, prefix)?,
                (false, None) => break,
            }
            j += 1;
            s *= 2;
        }
    }
    Ok(have)
}

/// 123-doubling exclusive scan (Algorithm 1 of the paper).
pub struct Exscan123;

impl<T: Elem> ScanAlgorithm<T> for Exscan123 {
    fn name(&self) -> &'static str {
        "123-doubling"
    }

    fn kind(&self) -> ScanKind {
        ScanKind::Exclusive
    }

    fn run(
        &self,
        ctx: &mut RankCtx<T>,
        input: &[T],
        output: &mut [T],
        op: &OpRef<T>,
    ) -> Result<()> {
        let (r, p) = (ctx.rank(), ctx.size());
        if p <= 1 {
            return Ok(());
        }
        // Resolve ⊕ to its slice kernel once for the whole collective
        // (the per-application dispatch is then a direct call — mpi::op).
        let op = &ctx.kernel(op);
        // ── Round 0, s_0 = 1: shift V right; establishes W_r = V_{r-1}. ──
        {
            let (t, f) = (r + 1, r.checked_sub(1));
            match (t < p, f) {
                (true, Some(f)) => ctx.sendrecv(0, t, input, f, output)?,
                (true, None) => ctx.send(0, t, input)?, // rank 0
                (false, Some(f)) => ctx.recv(0, f, output)?, // rank p-1
                (false, None) => unreachable!("p > 1"),
            }
        }
        if p == 2 {
            return Ok(()); // rank 1 already holds V_0
        }

        // ── Round 1, s_1 = 2: send the *inclusive* partial W ⊕ V from
        // distance 2 so the receiver's coverage jumps from 1 to 3 trailing
        // inputs (the invariant lands directly on s_2 = 3). Rank 0 sends
        // its bare input V_0 (it has no W) and is then done. ──
        {
            let (t, f) = (r + 2, r.checked_sub(2));
            match (t < p, f, r) {
                (true, Some(f), _) => {
                    // W' = W ⊕ V: W (covering V_{r-1}) is the earlier
                    // operand. W' lives in a pooled ctx scratch buffer
                    // (zero steady-state allocations) and the incoming
                    // partial folds via the fused sendrecv_reduce_into,
                    // straight from the pooled receive buffer.
                    let mut w_prime = ctx.scratch_from(input);
                    ctx.reduce_local(1, op, output, &mut w_prime);
                    ctx.sendrecv_reduce_into(1, t, &w_prime, f, op, output)?; // W = T ⊕ W
                }
                (true, None, 0) => {
                    ctx.send(1, t, input)?;
                    return Ok(()); // processor r = 0 done
                }
                (true, None, _) => {
                    // Rank 1: sends W' = W ⊕ V = V_0 ⊕ V_1, keeps W = V_0.
                    let mut w_prime = ctx.scratch_from(input);
                    ctx.reduce_local(1, op, output, &mut w_prime);
                    ctx.send(1, t, &w_prime)?;
                }
                (false, Some(f), _) => {
                    ctx.recv_reduce(1, f, op, output)?;
                }
                // Unreachable by the guards above (t = r+2 >= p with r < 2
                // implies p <= 3, and p == 2 returned after round 0; at
                // p == 3 rank 0 has t = 2 < p). Kept as a safe early-out
                // rather than an unreachable!() so a future round-0 refactor
                // cannot turn it into a panic.
                (false, None, 0) => return Ok(()),
                (false, None, _) => {} // p == 3, rank 1: complete after round 0
            }
        }

        // ── Rounds k >= 2, s_k = 3·2^{k-2}: plain exclusive doubling. The
        // value sent is the value kept, so one fused sendrecv_reduce per
        // round. Receives come from ranks f >= 1 only (rank 0 has left). ──
        let mut k = 2u32;
        let mut s = 3usize;
        loop {
            let t = r + s;
            let f = if r > s { Some(r - s) } else { None }; // strictly 0 < f
            match (t < p, f) {
                (true, Some(f)) => ctx.sendrecv_reduce(k, t, f, op, output)?,
                (true, None) => ctx.send(k, t, output)?,
                (false, Some(f)) => ctx.recv_reduce(k, f, op, output)?,
                (false, None) => break, // neither port active: done
            }
            k += 1;
            s *= 2;
        }
        Ok(())
    }

    fn predicted_rounds(&self, p: usize) -> u32 {
        rounds_123(p)
    }

    /// Theorem 1: `q − 1` ⊕ applications on the completion-critical rank.
    fn predicted_ops(&self, p: usize) -> u32 {
        rounds_123(p).saturating_sub(1)
    }

    fn critical_skips(&self, p: usize) -> Vec<usize> {
        // Receive distances of rank p-1: 1, 2, 3, 6, 12, … until coverage.
        let q = rounds_123(p);
        (0..q)
            .map(|k| match k {
                0 => 1,
                1 => 2,
                _ => 3 * (1usize << (k - 2)),
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coll::validate::assert_exscan_matches;
    use crate::mpi::{ops, run_scan, Topology, WorldConfig};

    #[test]
    fn matches_oracle_exhaustive_small_p() {
        for p in 2usize..=40 {
            let cfg = WorldConfig::new(Topology::flat(p));
            let inputs: Vec<Vec<i64>> = (0..p)
                .map(|r| vec![(r as i64).wrapping_mul(0x517C_C1B7) ^ 0xF0F0, 1 << (r % 60)])
                .collect();
            let res = run_scan(&cfg, &Exscan123, &ops::bxor(), &inputs).unwrap();
            assert_exscan_matches(&inputs, &ops::bxor(), &res.outputs);
        }
    }

    #[test]
    fn theorem1_rounds_and_ops() {
        for p in 2usize..=70 {
            let cfg = WorldConfig::new(Topology::flat(p)).with_trace(true);
            let inputs: Vec<Vec<i64>> = (0..p).map(|r| vec![r as i64]).collect();
            let res = run_scan(&cfg, &Exscan123, &ops::bxor(), &inputs).unwrap();
            let trace = res.trace.unwrap();
            let algo: &dyn ScanAlgorithm<i64> = &Exscan123;
            let q = algo.predicted_rounds(p);
            assert_eq!(trace.total_rounds(), q, "rounds p={p}");
            assert_eq!(trace.last_rank_ops(), algo.predicted_ops(p), "last-rank ops p={p}");
            // Middle ranks may pay one extra ⊕ (round-1 send preparation).
            assert!(trace.max_ops() <= q, "max ops bound p={p}");
            assert!(crate::trace::check_all(&trace).is_empty(), "invariants p={p}");
        }
    }

    #[test]
    fn small_p_edge_arms_exhaustive_under_chaos() {
        // The p ∈ {2, 3, 4, 5} worlds hit every round-0/round-1 arm
        // (rank 0 early return, the p = 3 "no partner" arms, rank 1's
        // send-only round 1). Under seeded chaos ordering the outputs,
        // the Theorem-1 counts and the trace's round bookkeeping for the
        // early-exiting rank 0 must all be unchanged.
        use crate::mpi::ChaosConfig;
        use crate::trace::EventKind;
        for p in 2usize..=5 {
            for seed in [1u64, 2, 3, 4, 5] {
                let cfg = WorldConfig::new(Topology::flat(p))
                    .with_trace(true)
                    .with_chaos(ChaosConfig::new(seed ^ ((p as u64) << 8)));
                let inputs: Vec<Vec<i64>> =
                    (0..p).map(|r| vec![(r as i64 + 1) * 3, !(r as i64)]).collect();
                let res = run_scan(&cfg, &Exscan123, &ops::bxor(), &inputs).unwrap();
                assert_exscan_matches(&inputs, &ops::bxor(), &res.outputs);
                let trace = res.trace.unwrap();
                let algo: &dyn ScanAlgorithm<i64> = &Exscan123;
                let q = algo.predicted_rounds(p);
                assert_eq!(trace.total_rounds(), q, "rounds p={p} seed={seed}");
                assert_eq!(
                    trace.last_rank_ops(),
                    algo.predicted_ops(p),
                    "last-rank ops p={p} seed={seed}"
                );
                assert!(
                    crate::trace::check_all(&trace).is_empty(),
                    "invariants p={p} seed={seed}"
                );
                // Round-count consistency for the early-exiting rank 0:
                // it only ever sends (rounds 0 and, for p >= 3, 1), never
                // receives, never reduces — even under chaos ordering.
                let r0 = &trace.traces[0];
                assert!(
                    r0.events.iter().all(|e| !matches!(e.kind, EventKind::Recv { .. })),
                    "rank 0 must not receive, p={p} seed={seed}"
                );
                assert_eq!(r0.ops(), 0, "rank 0 must not reduce, p={p} seed={seed}");
                assert_eq!(
                    r0.comm_rounds(),
                    q.min(2),
                    "rank 0 exits after its round-1 send, p={p} seed={seed}"
                );
            }
        }
    }

    #[test]
    fn paper_counts() {
        let algo: &dyn ScanAlgorithm<i64> = &Exscan123;
        // p=36: q = ceil(log2 35 + log2 4/3) = 6 rounds, 5 ⊕.
        assert_eq!(algo.predicted_rounds(36), 6);
        assert_eq!(algo.predicted_ops(36), 5);
        // p=1152: q = 11 rounds — one fewer than 1-doubling's 12.
        assert_eq!(algo.predicted_rounds(1152), 11);
    }

    #[test]
    fn noncommutative_order() {
        use crate::coll::validate::oracle_exscan;
        use crate::mpi::Rec2;
        for p in [3usize, 5, 9, 14, 27] {
            let cfg = WorldConfig::new(Topology::flat(p));
            let inputs: Vec<Vec<Rec2>> = (0..p)
                .map(|r| {
                    vec![Rec2::new(
                        [1.0, 0.02 * r as f32, -0.01 * r as f32, 1.0],
                        [r as f32 * 0.5, 1.0 - r as f32 * 0.25],
                    )]
                })
                .collect();
            let res = run_scan(&cfg, &Exscan123, &ops::rec2_compose(), &inputs).unwrap();
            let oracle = oracle_exscan(&inputs, &ops::rec2_compose());
            for r in 1..p {
                let e = oracle[r].as_ref().unwrap();
                for i in 0..4 {
                    assert!(
                        (res.outputs[r][0].a[i] - e[0].a[i]).abs() < 1e-3,
                        "p={p} r={r} a[{i}]"
                    );
                }
            }
        }
    }

    #[test]
    fn multi_element_vectors() {
        let p = 19;
        for m in [0usize, 1, 2, 17, 256] {
            let cfg = WorldConfig::new(Topology::flat(p));
            let inputs: Vec<Vec<i64>> = (0..p)
                .map(|r| (0..m).map(|i| (r * 31 + i * 7) as i64).collect())
                .collect();
            let res = run_scan(&cfg, &Exscan123, &ops::sum_i64(), &inputs).unwrap();
            assert_exscan_matches(&inputs, &ops::sum_i64(), &res.outputs);
        }
    }
}
