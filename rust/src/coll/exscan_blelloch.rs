//! Binomial-tree up/down-sweep exclusive scan (Blelloch-style), as an
//! ablation point: `2⌈log₂p⌉` rounds but only one active transfer
//! direction per phase and no identity element required.
//!
//! * **Up-sweep** (reduce toward rank 0): at level k, rank `r` with
//!   `r % 2^{k+1} == 0` folds in the segment sum of `r + 2^k`
//!   (`acc_r = acc_r ⊕ acc_{r+2^k}`, own block earlier). Segments clip at
//!   `p`, so any world size works.
//! * **Down-sweep**: rank `r` holds the exclusive prefix of its segment
//!   start and sends to each child `r + 2^k` that child's prefix
//!   `prefix_r ⊕ saved_k` where `saved_k` is the pre-fold left-half sum
//!   remembered on the way up. Rank 0's prefix is the empty product, so it
//!   forwards `saved_k` bare — no operator identity is ever needed.

use anyhow::Result;

use super::{ScanAlgorithm, ScanKind};
use crate::mpi::{Elem, OpRef, RankCtx};
use crate::util::ceil_log2;

/// Binomial up/down-sweep exclusive scan.
pub struct ExscanBlelloch;

impl<T: Elem> ScanAlgorithm<T> for ExscanBlelloch {
    fn name(&self) -> &'static str {
        "blelloch"
    }

    fn kind(&self) -> ScanKind {
        ScanKind::Exclusive
    }

    fn run(
        &self,
        ctx: &mut RankCtx<T>,
        input: &[T],
        output: &mut [T],
        op: &OpRef<T>,
    ) -> Result<()> {
        let (r, p, m) = (ctx.rank(), ctx.size(), input.len());
        if p <= 1 {
            return Ok(());
        }
        // Resolve ⊕ to its slice kernel once for the whole collective
        // (the per-application dispatch is then a direct call — mpi::op).
        let op = &ctx.kernel(op);
        let levels = ceil_log2(p); // K
        let mut acc = ctx.scratch_from(input);
        // saved[k] = acc before folding the level-k right child (i.e. the
        // sum of the left half of the level-(k+1) segment); pooled scratch
        // snapshots, so the up-sweep allocates nothing in steady state.
        let mut saved: Vec<Option<crate::mpi::PoolBuf<T>>> =
            (0..levels).map(|_| None).collect();

        // ── Up-sweep: rounds 0..levels. ──
        for k in 0..levels {
            let span = 1usize << k;
            if r % (span * 2) == 0 {
                let child = r + span;
                if child < p {
                    saved[k as usize] = Some(ctx.scratch_from(&acc));
                    // Own (left) block is earlier: acc = acc ⊕ recv, fused
                    // in the pooled receive buffer (no local temporary).
                    ctx.recv_reduce_right(k, child, op, &mut acc)?;
                }
            } else if r % (span * 2) == span {
                let parent = r - span;
                ctx.send(k, parent, &acc)?;
                // This rank is passive until the down-sweep.
            }
        }

        // ── Down-sweep: rounds levels..2*levels. `have_prefix` is false
        // only on the rank-0 spine (empty exclusive prefix). ──
        let mut prefix = ctx.scratch_filled(m);
        let mut have_prefix = false;
        if r != 0 {
            // Wait for the parent's prefix: the parent is the rank that
            // received from us on the up-sweep, at the highest level where
            // we were a right child.
            let k = (0..levels).find(|&k| {
                let span = 1usize << k;
                r % (span * 2) == span
            });
            // Every nonzero rank is a right child at exactly the level of
            // its lowest set bit.
            let k = k.expect("nonzero rank has a lowest set bit");
            let parent = r - (1usize << k);
            // Down-sweep round for level k is (2*levels - 1 - k).
            let round = 2 * levels - 1 - k;
            ctx.recv(round, parent, &mut prefix)?;
            have_prefix = true;
        }
        // Forward prefixes to children, highest level first.
        for k in (0..levels).rev() {
            let span = 1usize << k;
            if r % (span * 2) == 0 {
                let child = r + span;
                if child < p {
                    let left_sum = saved[k as usize]
                        .take()
                        .expect("saved left-half sum for every folded child");
                    let round = 2 * levels - 1 - k;
                    if have_prefix {
                        // child prefix = prefix ⊕ left_sum (prefix earlier).
                        let mut child_prefix = left_sum;
                        ctx.reduce_local(round, op, &prefix, &mut child_prefix);
                        ctx.send(round, child, &child_prefix)?;
                    } else {
                        // Rank-0 spine: empty prefix ⊕ left_sum = left_sum.
                        ctx.send(round, child, &left_sum)?;
                    }
                }
            }
        }
        if have_prefix {
            output.copy_from_slice(&prefix);
        }
        Ok(())
    }

    fn predicted_rounds(&self, p: usize) -> u32 {
        if p <= 1 {
            0
        } else {
            2 * ceil_log2(p)
        }
    }

    /// Critical-rank ⊕ count: the deepest leaf folds nothing on the
    /// up-sweep and receives a ready prefix, but interior spine ranks pay
    /// up to `⌈log₂p⌉` up-sweep folds and `⌈log₂p⌉ − 1` down-sweep
    /// combines; we report the worst-rank bound.
    fn predicted_ops(&self, p: usize) -> u32 {
        if p <= 1 {
            0
        } else {
            2 * ceil_log2(p) - 1
        }
    }

    fn critical_skips(&self, p: usize) -> Vec<usize> {
        // Rank p-1's transfers: up-sweep send at its lowest-set-bit level,
        // down-sweep receive from the same parent.
        if p <= 1 {
            return vec![];
        }
        let r = p - 1;
        let k = r.trailing_zeros();
        vec![1usize << k, 1usize << k]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coll::validate::assert_exscan_matches;
    use crate::mpi::{ops, run_scan, Topology, WorldConfig};

    #[test]
    fn matches_oracle_many_p() {
        for p in 2usize..=40 {
            let cfg = WorldConfig::new(Topology::flat(p));
            let inputs: Vec<Vec<i64>> =
                (0..p).map(|r| vec![(r as i64) * 13 + 5, -(r as i64)]).collect();
            let res = run_scan(&cfg, &ExscanBlelloch, &ops::sum_i64(), &inputs).unwrap();
            assert_exscan_matches(&inputs, &ops::sum_i64(), &res.outputs);
        }
    }

    #[test]
    fn noncommutative() {
        use crate::coll::validate::oracle_exscan;
        use crate::mpi::Rec2;
        for p in [2usize, 5, 8, 11, 16, 21] {
            let cfg = WorldConfig::new(Topology::flat(p));
            let inputs: Vec<Vec<Rec2>> = (0..p)
                .map(|r| vec![Rec2::new([1.0, 0.03 * r as f32, 0.01, 1.0], [0.5, r as f32])])
                .collect();
            let res = run_scan(&cfg, &ExscanBlelloch, &ops::rec2_compose(), &inputs).unwrap();
            let oracle = oracle_exscan(&inputs, &ops::rec2_compose());
            for r in 1..p {
                let e = oracle[r].as_ref().unwrap();
                for i in 0..2 {
                    assert!((res.outputs[r][0].b[i] - e[0].b[i]).abs() < 1e-3, "p={p} r={r}");
                }
            }
        }
    }

    #[test]
    fn round_bound() {
        for p in [2usize, 3, 8, 9, 36] {
            let cfg = WorldConfig::new(Topology::flat(p)).with_trace(true);
            let inputs: Vec<Vec<i64>> = (0..p).map(|r| vec![r as i64]).collect();
            let res = run_scan(&cfg, &ExscanBlelloch, &ops::bxor(), &inputs).unwrap();
            let trace = res.trace.unwrap();
            let algo: &dyn ScanAlgorithm<i64> = &ExscanBlelloch;
            assert!(trace.total_rounds() <= algo.predicted_rounds(p), "p={p}");
            assert!(crate::trace::check_all(&trace).is_empty(), "p={p}");
        }
    }
}
