//! Cost-model-driven algorithm selection — the "tuning table" mechanism
//! real MPI libraries (including mpich) use to dispatch a collective to a
//! concrete algorithm based on communicator size and message size.
//!
//! Instead of hard-coded thresholds we evaluate the closed-form α-β-γ
//! prediction for every candidate and pick the argmin; a pre-computed
//! [`TuningTable`] caches the decision boundaries so the hot path is a
//! lookup, exactly like `MPIR_CVAR`-style tuning files.

use crate::cost::{predict_flat, CostParams};
use crate::mpi::Elem;

use super::{
    exscan_by_name, paper_exscan_algorithms, PipelinedChain, ScanAlgorithm,
};

/// Choose the predicted-fastest exclusive-scan algorithm for (p, bytes).
/// Candidates: the paper's three portable algorithms plus the pipelined
/// chain (which takes over for very large vectors). Every candidate is
/// ranked through its own `critical_schedule(p, m)`, so m-dependent
/// schedules (the chain's blocks) price their real round count and
/// per-message payload.
pub fn select_exscan<T: Elem>(
    p: usize,
    m: usize,
    params: &CostParams,
    ranks_per_node: usize,
) -> Box<dyn ScanAlgorithm<T>> {
    let mut candidates: Vec<Box<dyn ScanAlgorithm<T>>> = paper_exscan_algorithms::<T>()
        .into_iter()
        .filter(|a| a.name() != "native-mpich") // the baseline, not a candidate
        .collect();
    candidates.push(Box::new(PipelinedChain::auto()));

    let mut best: Option<(f64, Box<dyn ScanAlgorithm<T>>)> = None;
    for algo in candidates {
        let (skips, ops, msg_elems) = algo.critical_schedule(p, m);
        let pred =
            predict_flat(&skips, ops, p, ranks_per_node, msg_elems * T::size_bytes(), params);
        if best.as_ref().map(|(t, _)| pred.time_us < *t).unwrap_or(true) {
            best = Some((pred.time_us, algo));
        }
    }
    best.expect("at least one candidate").1
}

/// A precomputed decision table over (p, message-size) buckets.
#[derive(Debug, Clone)]
pub struct TuningTable {
    pub params: CostParams,
    pub ranks_per_node: usize,
    /// Power-of-two message-size bucket boundaries (bytes).
    pub size_buckets: Vec<usize>,
    /// `choice[pi][bi]` = algorithm name for p-bucket pi, size-bucket bi.
    pub p_buckets: Vec<usize>,
    pub choice: Vec<Vec<&'static str>>,
}

impl TuningTable {
    /// Build a table for the given p values, size buckets 8 B … 8 MiB.
    pub fn build(p_buckets: Vec<usize>, params: CostParams, ranks_per_node: usize) -> Self {
        let size_buckets: Vec<usize> = (3..=23).map(|k| 1usize << k).collect();
        let mut choice = Vec::with_capacity(p_buckets.len());
        for &p in &p_buckets {
            let mut row = Vec::with_capacity(size_buckets.len());
            for &bytes in &size_buckets {
                let algo = select_exscan::<i64>(p, bytes / 8, &params, ranks_per_node);
                row.push(leak_name(algo.name()));
            }
            choice.push(row);
        }
        TuningTable { params, ranks_per_node, size_buckets, p_buckets, choice }
    }

    /// Look up the algorithm for (p, bytes), snapping to enclosing buckets.
    pub fn lookup<T: Elem>(&self, p: usize, bytes: usize) -> Box<dyn ScanAlgorithm<T>> {
        let pi = self
            .p_buckets
            .iter()
            .position(|&b| p <= b)
            .unwrap_or(self.p_buckets.len() - 1);
        let bi = self
            .size_buckets
            .iter()
            .position(|&b| bytes <= b)
            .unwrap_or(self.size_buckets.len() - 1);
        exscan_by_name::<T>(self.choice[pi][bi]).expect("table names are valid")
    }
}

/// The algorithm names are `&'static str` already; this keeps the table
/// type simple without cloning.
fn leak_name(n: &str) -> &'static str {
    match n {
        "123-doubling" => "123-doubling",
        "1-doubling" => "1-doubling",
        "two-op-doubling" => "two-op-doubling",
        "pipelined-chain" => "pipelined-chain",
        "native-mpich" => "native-mpich",
        other => Box::leak(other.to_string().into_boxed_str()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::CostParams;

    #[test]
    fn small_messages_prefer_fewest_rounds() {
        // Tiny vectors: round count dominates → 123-doubling (or two-op
        // when ⌈log₂p⌉ < q, impossible; two-op ties at best).
        let a = select_exscan::<i64>(36, 1, &CostParams::paper_36x1(), 1);
        assert!(
            a.name() == "123-doubling" || a.name() == "two-op-doubling",
            "picked {}",
            a.name()
        );
    }

    #[test]
    fn huge_messages_prefer_pipeline() {
        // 8 MB vectors on 8 ranks: bandwidth dominates → pipelined chain.
        let a = select_exscan::<i64>(8, 1_000_000, &CostParams::paper_36x1(), 1);
        assert_eq!(a.name(), "pipelined-chain");
    }

    #[test]
    fn table_lookup_consistent_with_direct_selection() {
        let params = CostParams::paper_36x1();
        let table = TuningTable::build(vec![4, 16, 64, 256, 1024], params, 1);
        for (p, bytes) in [(4usize, 8usize), (16, 1 << 10), (64, 1 << 20), (1024, 64)] {
            let via_table = table.lookup::<i64>(p, bytes);
            let direct = select_exscan::<i64>(p, bytes / 8, &params, 1);
            assert_eq!(via_table.name(), direct.name(), "p={p} bytes={bytes}");
        }
    }

    #[test]
    fn never_selects_native() {
        for m in [1usize, 100, 10_000, 1_000_000] {
            let a = select_exscan::<i64>(36, m, &CostParams::paper_36x1(), 1);
            assert_ne!(a.name(), "native-mpich");
        }
    }
}
