//! Cost-model-driven algorithm selection — the "tuning table" mechanism
//! real MPI libraries (including mpich) use to dispatch a collective to a
//! concrete algorithm based on communicator size and message size.
//!
//! Instead of hard-coded thresholds we evaluate the closed-form α-β-γ
//! prediction for every candidate and pick the argmin; a pre-computed
//! [`TuningTable`] caches the decision boundaries so the hot path is a
//! lookup, exactly like `MPIR_CVAR`-style tuning files.

use crate::cost::{predict_flat, predict_flat_topo, predict_two_level, CostParams};
use crate::mpi::Elem;
use crate::topo::Topo;

use super::{
    exscan_by_name, paper_exscan_algorithms, Exscan1247, ExscanBlock, ExscanPow2, ExscanRsag,
    ExscanTwoLevel, PipelinedChain, ScanAlgorithm,
};

/// The selection candidate pool: the paper's three portable round-optimal
/// algorithms plus the three bandwidth-regime engines (pipelined chain,
/// block decomposition, reduce-scatter + allgather). Public so the bench
/// crossover gate can recompute the argmin over the *same* pool.
pub fn select_candidates<T: Elem>() -> Vec<Box<dyn ScanAlgorithm<T>>> {
    let mut candidates: Vec<Box<dyn ScanAlgorithm<T>>> = paper_exscan_algorithms::<T>()
        .into_iter()
        .filter(|a| a.name() != "native-mpich") // the baseline, not a candidate
        .collect();
    candidates.push(Box::new(PipelinedChain::auto()));
    candidates.push(Box::new(ExscanBlock::auto()));
    candidates.push(Box::new(ExscanRsag));
    candidates
}

/// Choose the predicted-fastest exclusive-scan algorithm for (p, bytes).
/// Every candidate is ranked through its own `critical_schedule(p, m)`,
/// so m-dependent schedules (the chain's blocks, the block decomposition's
/// group width, rsag's m/p messages) price their real round count and
/// per-message payload — this is where the selection crosses over from the
/// round-optimal regime (small m: full-vector messages, fewest rounds
/// wins) to the bandwidth regime (large m: more rounds of m/g- or
/// m/p-element messages win).
pub fn select_exscan<T: Elem>(
    p: usize,
    m: usize,
    params: &CostParams,
    ranks_per_node: usize,
) -> Box<dyn ScanAlgorithm<T>> {
    let mut best: Option<(f64, Box<dyn ScanAlgorithm<T>>)> = None;
    for algo in select_candidates::<T>() {
        let (skips, ops, msg_elems) = algo.critical_schedule(p, m);
        let pred =
            predict_flat(&skips, ops, p, ranks_per_node, msg_elems * T::size_bytes(), params);
        if best.as_ref().map(|(t, _)| pred.time_us < *t).unwrap_or(true) {
            best = Some((pred.time_us, algo));
        }
    }
    best.expect("at least one candidate").1
}

/// Topology-aware selection: rank the flat pool *plus* the follow-up
/// algorithms and the two-level scheme against a concrete [`Topo`] link
/// matrix. The flat candidates come first and the argmin is strict, so
/// on a uniform matrix (where per-link pricing degenerates to the class
/// means) the winner is exactly [`select_exscan`]'s — hierarchy can only
/// change the decision where the matrix actually is hierarchical. The
/// two-level scheme is considered only on hierarchical topologies
/// (`nodes > 1 && ppn > 1`), priced by its phase-composed
/// [`predict_two_level`] closed form; the follow-up algorithms price
/// their critical schedules per-link like everyone else.
pub fn select_exscan_topo<T: Elem>(p: usize, m: usize, topo: &Topo) -> Box<dyn ScanAlgorithm<T>> {
    assert_eq!(p, topo.size(), "selection is sized to the topology matrix");
    let elem = T::size_bytes();
    let mut candidates: Vec<Box<dyn ScanAlgorithm<T>>> = select_candidates::<T>();
    candidates.push(Box::new(ExscanPow2));
    candidates.push(Box::new(Exscan1247));
    let mut best: Option<(f64, Box<dyn ScanAlgorithm<T>>)> = None;
    for algo in candidates {
        let (skips, ops, msg_elems) = algo.critical_schedule(p, m);
        let pred = predict_flat_topo(&skips, ops, msg_elems * elem, topo);
        if best.as_ref().map(|(t, _)| pred.time_us < *t).unwrap_or(true) {
            best = Some((pred.time_us, algo));
        }
    }
    if topo.is_hierarchical() {
        let pred = predict_two_level(topo, m * elem);
        if best.as_ref().map(|(t, _)| pred.time_us < *t).unwrap_or(true) {
            best = Some((
                pred.time_us,
                Box::new(ExscanTwoLevel::new(topo.ranks_per_node())),
            ));
        }
    }
    best.expect("at least one candidate").1
}

/// A precomputed decision table over (p, message-size) buckets.
#[derive(Debug, Clone)]
pub struct TuningTable {
    pub params: CostParams,
    pub ranks_per_node: usize,
    /// Power-of-two message-size bucket boundaries (bytes).
    pub size_buckets: Vec<usize>,
    /// `choice[pi][bi]` = algorithm name for p-bucket pi, size-bucket bi.
    pub p_buckets: Vec<usize>,
    pub choice: Vec<Vec<&'static str>>,
}

impl TuningTable {
    /// Build a table for the given p values, size buckets 8 B … 8 MiB.
    pub fn build(p_buckets: Vec<usize>, params: CostParams, ranks_per_node: usize) -> Self {
        let size_buckets: Vec<usize> = (3..=23).map(|k| 1usize << k).collect();
        let mut choice = Vec::with_capacity(p_buckets.len());
        for &p in &p_buckets {
            let mut row = Vec::with_capacity(size_buckets.len());
            for &bytes in &size_buckets {
                let algo = select_exscan::<i64>(p, bytes / 8, &params, ranks_per_node);
                row.push(leak_name(algo.name()));
            }
            choice.push(row);
        }
        TuningTable { params, ranks_per_node, size_buckets, p_buckets, choice }
    }

    /// Look up the algorithm for (p, bytes), snapping to enclosing buckets.
    pub fn lookup<T: Elem>(&self, p: usize, bytes: usize) -> Box<dyn ScanAlgorithm<T>> {
        let pi = self
            .p_buckets
            .iter()
            .position(|&b| p <= b)
            .unwrap_or(self.p_buckets.len() - 1);
        let bi = self
            .size_buckets
            .iter()
            .position(|&b| bytes <= b)
            .unwrap_or(self.size_buckets.len() - 1);
        exscan_by_name::<T>(self.choice[pi][bi]).expect("table names are valid")
    }
}

/// The algorithm names are `&'static str` already; this keeps the table
/// type simple without cloning.
fn leak_name(n: &str) -> &'static str {
    match n {
        "123-doubling" => "123-doubling",
        "1-doubling" => "1-doubling",
        "two-op-doubling" => "two-op-doubling",
        "pipelined-chain" => "pipelined-chain",
        "block-exscan" => "block-exscan",
        "rsag" => "rsag",
        "native-mpich" => "native-mpich",
        "pow2-doubling" => "pow2-doubling",
        "1247-doubling" => "1247-doubling",
        "two-level" => "two-level",
        other => Box::leak(other.to_string().into_boxed_str()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::CostParams;

    #[test]
    fn small_messages_prefer_fewest_rounds() {
        // Tiny vectors: round count dominates → 123-doubling (or two-op
        // when ⌈log₂p⌉ < q, impossible; two-op ties at best).
        let a = select_exscan::<i64>(36, 1, &CostParams::paper_36x1(), 1);
        assert!(
            a.name() == "123-doubling" || a.name() == "two-op-doubling",
            "picked {}",
            a.name()
        );
    }

    #[test]
    fn huge_messages_prefer_pipeline() {
        // 8 MB vectors on 8 ranks: bandwidth dominates, and at small p the
        // chain's β factor 1+(p−2)/B (B = 64) ≈ 1.1 undercuts the block
        // and rsag factors (≈ 2) → pipelined chain.
        let a = select_exscan::<i64>(8, 1_000_000, &CostParams::paper_36x1(), 1);
        assert_eq!(a.name(), "pipelined-chain");
    }

    #[test]
    fn large_p_large_m_crosses_over_to_block_or_rsag() {
        let params = CostParams::paper_36x1();
        // Small m at p = 256: the α term dominates, fewest rounds wins
        // (two-op's ⌈log₂p⌉ = 8 or 123's q = 9; both round-regime).
        let a = select_exscan::<i64>(256, 1, &params, 1);
        assert!(
            a.name() == "123-doubling" || a.name() == "two-op-doubling",
            "small m picked {}",
            a.name()
        );
        // Large m at p = 256: the chain's block cap (B ≤ 64) leaves it a β
        // factor of 1+(p−2)/64 ≈ 5, while block/rsag move ≈ 2m elements
        // over the critical path regardless of p → bandwidth regime.
        let b = select_exscan::<i64>(256, 1 << 20, &params, 1);
        assert!(
            b.name() == "block-exscan" || b.name() == "rsag",
            "large m picked {}",
            b.name()
        );
    }

    #[test]
    fn selection_is_argmin_over_candidate_pool() {
        use crate::cost::predict_flat;
        let params = CostParams::paper_36x1();
        for m in [1usize, 64, 4096, 262_144, 1 << 20] {
            for p in [8usize, 36, 256] {
                let picked = select_exscan::<i64>(p, m, &params, 1);
                let mut best: Option<(f64, &'static str)> = None;
                for algo in select_candidates::<i64>() {
                    let (skips, ops, msg_elems) = algo.critical_schedule(p, m);
                    let pred = predict_flat(&skips, ops, p, 1, msg_elems * 8, &params);
                    if best.map(|(t, _)| pred.time_us < t).unwrap_or(true) {
                        best = Some((pred.time_us, leak_name(algo.name())));
                    }
                }
                assert_eq!(picked.name(), best.unwrap().1, "p={p} m={m}");
            }
        }
    }

    #[test]
    fn table_lookup_consistent_with_direct_selection() {
        let params = CostParams::paper_36x1();
        let table = TuningTable::build(vec![4, 16, 64, 256, 1024], params, 1);
        for (p, bytes) in [(4usize, 8usize), (16, 1 << 10), (64, 1 << 20), (1024, 64)] {
            let via_table = table.lookup::<i64>(p, bytes);
            let direct = select_exscan::<i64>(p, bytes / 8, &params, 1);
            assert_eq!(via_table.name(), direct.name(), "p={p} bytes={bytes}");
        }
    }

    #[test]
    fn never_selects_native() {
        for m in [1usize, 100, 10_000, 1_000_000] {
            let a = select_exscan::<i64>(36, m, &CostParams::paper_36x1(), 1);
            assert_ne!(a.name(), "native-mpich");
        }
    }
}
