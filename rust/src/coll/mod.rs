//! The scan collective library: every algorithm the paper describes,
//! the library-native baseline it benchmarks against, and several
//! extensions, all programmed against [`RankCtx`].
//!
//! | Algorithm | Kind | Rounds | ⊕ (critical rank) |
//! |---|---|---|---|
//! | [`ScanDoubling`] (Hillis-Steele) | inclusive | ⌈log₂p⌉ | ⌈log₂p⌉ |
//! | [`ExscanTwoOp`] (two-⊕ doubling) | exclusive | ⌈log₂p⌉ | 2⌈log₂p⌉−1 (max over ranks) |
//! | [`ExscanOneDoubling`] (1-doubling) | exclusive | 1+⌈log₂(p−1)⌉ | ⌈log₂(p−1)⌉ |
//! | [`Exscan123`] (**Algorithm 1**) | exclusive | ⌈log₂(p−1)+log₂(4/3)⌉ | q−1 |
//! | [`ExscanMpich`] (native baseline) | exclusive | ⌈log₂p⌉ | ≤2⌈log₂p⌉−1 |
//! | [`ExscanBlelloch`] (up/down sweep) | exclusive | 2⌈log₂p⌉ | ≤2⌈log₂p⌉ |
//! | [`ExscanShiftScan`] (scan + shift) | exclusive | ⌈log₂p⌉+1 | ⌈log₂p⌉ |
//! | [`ExscanLinear`] | exclusive | p−1 | 1 |
//! | [`PipelinedChain`] | exclusive | p+B−2 | B (blocks) |
//! | [`ExscanChunked`] | exclusive | (1+⌈log₂(p−1)⌉)·C | ⌈log₂(p−1)⌉·C (C chunks) |
//! | [`ExscanBlock`] | exclusive | 2(g−1)+q(p/g) | 2(g−1)+q(p/g)−1, m/g-elem msgs |
//! | [`ExscanRsag`] | exclusive | 2(p−1) | p−2, m/p-element messages |
//! | [`ExscanPow2`] (2026 follow-up) | exclusive | ⌈log₂p⌉ | ⌈log₂p⌉−1 (≤2(⌈log₂p⌉−1) max) |
//! | [`Exscan1247`] (2026 follow-up) | exclusive | ⌈log₂(p−1)+log₂(8/7)⌉ | q−1 (≤q+1 max) |
//! | [`ExscanTwoLevel`] (topology-aware) | exclusive | [`exscan_two_level::two_level_rounds`] | r₁₂₃(k)+1 |
//!
//! The first block of rows is the paper's **small-m** regime: full-vector
//! messages every round, so fewer rounds wins. The last two rows are the
//! **large-m** (bandwidth) regime the paper defers to other algorithms:
//! [`ExscanBlock`] decomposes the vector over groups of `g` ranks and
//! reuses the round-optimal 123 engine over `m/g`-element group totals,
//! and [`ExscanRsag`] composes a reduce-scatter with an allgather so every
//! message carries only `m/p` elements. [`select_exscan`] crosses over
//! between the regimes at the α-β-γ-predicted m.

pub mod basic;
pub mod exscan_123;
pub mod exscan_1247;
pub mod exscan_blelloch;
pub mod exscan_block;
pub mod exscan_chunked;
pub mod exscan_hierarchical;
pub mod exscan_linear;
pub mod exscan_mpich;
pub mod exscan_one_doubling;
pub mod exscan_pow2;
pub mod exscan_rsag;
pub mod exscan_shift_scan;
pub mod exscan_two_level;
pub mod exscan_two_op;
pub mod scan_doubling;
pub mod scan_pipelined;
pub mod segmented;
pub mod select;
pub mod validate;

pub use basic::{allreduce, bcast, gather_chain, reduce, scatter_chain};
pub use exscan_123::Exscan123;
pub use exscan_1247::Exscan1247;
pub use exscan_chunked::ExscanChunked;
pub use exscan_hierarchical::ExscanHierarchical;
pub use segmented::{seg_bxor_i64, seg_max_i64, seg_sum_i64, Seg};
pub use exscan_blelloch::ExscanBlelloch;
pub use exscan_block::ExscanBlock;
pub use exscan_linear::ExscanLinear;
pub use exscan_mpich::ExscanMpich;
pub use exscan_one_doubling::ExscanOneDoubling;
pub use exscan_pow2::ExscanPow2;
pub use exscan_rsag::ExscanRsag;
pub use exscan_shift_scan::ExscanShiftScan;
pub use exscan_two_level::{two_level_max_ops, two_level_ops, two_level_rounds, ExscanTwoLevel};
pub use exscan_two_op::ExscanTwoOp;
pub use scan_doubling::ScanDoubling;
pub use scan_pipelined::PipelinedChain;
pub use select::{select_candidates, select_exscan, select_exscan_topo, TuningTable};
pub use validate::{oracle_exscan, oracle_scan};

use anyhow::Result;

use crate::mpi::{Elem, OpRef, RankCtx};

/// Inclusive (`MPI_Scan`) or exclusive (`MPI_Exscan`) semantics.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScanKind {
    Inclusive,
    /// Output on rank 0 is undefined, as in MPI_Exscan.
    Exclusive,
}

/// A scan algorithm runnable on any world. Implementations must be pure
/// coordination: all communication through `ctx`, all combining through
/// `ctx.reduce_local` (so rounds and ⊕ applications are traced and the
/// virtual clock advances).
pub trait ScanAlgorithm<T: Elem>: Send + Sync {
    /// Short name used in tables ("123-doubling", …).
    fn name(&self) -> &'static str;

    fn kind(&self) -> ScanKind;

    /// Execute on this rank. `input` is this rank's V (length m); the
    /// result W is written to `output` (same length). For exclusive scans
    /// rank 0's output is left untouched (undefined, per MPI).
    fn run(
        &self,
        ctx: &mut RankCtx<T>,
        input: &[T],
        output: &mut [T],
        op: &OpRef<T>,
    ) -> Result<()>;

    /// Closed-form number of communication rounds for world size `p`
    /// (the paper's primary metric; verified against traces in tests).
    fn predicted_rounds(&self, p: usize) -> u32;

    /// Closed-form rounds at a concrete vector length. The default covers
    /// m-independent schedules; algorithms whose round structure depends
    /// on m (the chunked pipeline, the block-pipelined chain) override it
    /// so the scan service's round accounting and coalescing benefit gate
    /// ([`crate::svc`]) match what the trace will actually measure.
    fn predicted_rounds_m(&self, p: usize, m: usize) -> u32 {
        let _ = m;
        self.predicted_rounds(p)
    }

    /// Closed-form ⊕ applications, counted as the paper counts them
    /// (see each implementation's docs; verified against traces).
    fn predicted_ops(&self, p: usize) -> u32;

    /// Partner distances (skips) of the completion-critical rank's
    /// receives, one per round it receives in — feeds the hierarchical
    /// cost-model calibration (intra- vs inter-node round classification).
    fn critical_skips(&self, p: usize) -> Vec<usize>;

    /// Inputs for the closed-form α-β-γ prediction at a concrete vector
    /// length: `(critical skips, critical-path ⊕ count, elements per
    /// message)`. The default covers m-independent schedules (full-vector
    /// messages every round); algorithms whose round structure depends on
    /// m (the chunked pipeline, the block-pipelined chain) override it so
    /// `exscan predict` and the selection table rank them honestly.
    fn critical_schedule(&self, p: usize, m: usize) -> (Vec<usize>, u32, usize) {
        (self.critical_skips(p), self.predicted_ops(p), m)
    }
}

/// All exclusive-scan algorithms participating in the paper's comparison,
/// in the paper's table order: native baseline, two-⊕, 1-doubling,
/// 123-doubling.
pub fn paper_exscan_algorithms<T: Elem>() -> Vec<Box<dyn ScanAlgorithm<T>>> {
    vec![
        Box::new(ExscanMpich),
        Box::new(ExscanTwoOp),
        Box::new(ExscanOneDoubling),
        Box::new(Exscan123),
    ]
}

/// Every exclusive-scan algorithm in the library (paper set + extensions).
pub fn all_exscan_algorithms<T: Elem>() -> Vec<Box<dyn ScanAlgorithm<T>>> {
    vec![
        Box::new(ExscanMpich),
        Box::new(ExscanTwoOp),
        Box::new(ExscanOneDoubling),
        Box::new(Exscan123),
        Box::new(ExscanBlelloch),
        Box::new(ExscanShiftScan),
        Box::new(ExscanLinear),
        Box::new(PipelinedChain::auto()),
        Box::new(ExscanChunked::auto()),
        Box::new(ExscanBlock::auto()),
        Box::new(ExscanRsag),
        Box::new(ExscanPow2),
        Box::new(Exscan1247),
        Box::new(ExscanTwoLevel::new(4)),
    ]
}

/// Look an algorithm up by its table name.
pub fn exscan_by_name<T: Elem>(name: &str) -> Option<Box<dyn ScanAlgorithm<T>>> {
    all_exscan_algorithms::<T>()
        .into_iter()
        .find(|a| a.name() == name)
}
