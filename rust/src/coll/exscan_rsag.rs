//! Reduce-scatter + allgather–composed exclusive scan — the
//! bandwidth-optimal large-m regime, after Träff's "Optimal,
//! Non-pipelined Reduce-scatter and Allreduce Algorithms" (2024).
//!
//! The full-vector doubling algorithms move the *whole* m-vector every
//! round: `q·mβ` bandwidth on the critical path. Here the vector is cut
//! into `p` blocks and rank `b` becomes the **owner** of block `b`:
//!
//! 1. **Transpose (reduce-scatter shape)**: `p−1` cyclic exchange steps;
//!    at step `k` rank `r` sends its block-`(r+k) mod p` slice to rank
//!    `(r+k) mod p` and receives rank `(r−k) mod p`'s contribution to its
//!    own block. Rank `p−1`'s vector appears in no exclusive prefix, so
//!    it never sends. Each step moves `m/p` elements per port.
//! 2. **Local prefix scan**: the owner scans its `p−1` collected rows in
//!    one [`scan_rows`](crate::mpi::RankCtx::scan_rows) launch (the
//!    tight-loop kernels of [`crate::mpi::kernels`]); row `j` becomes
//!    `V_0 ⊕ … ⊕ V_j` restricted to the owned block — i.e. the owner now
//!    holds `W_t`'s block for **every** target `t ≥ 1`.
//! 3. **Return (allgather shape)**: `p−1` more cyclic steps deliver
//!    `W_t[block r]` from each owner `r` to each target `t` (rank 0's
//!    output is undefined and receives nothing).
//!
//! Every exchange step runs on its own [`TagKey`](crate::mpi::TagKey)
//! chunk lane, so the blocks of different steps stream through the
//! transport without cross-matching; trace rounds stay distinct per step
//! (the trace does not record lanes, and the one-ported invariant is per
//! round). Cost: `2(p−1)` rounds of `m/p`-element messages and `p−2`
//! block-width ⊕ — `≈ 2mβ` critical-path bandwidth and `≈ mγ` compute,
//! independent of `p`, versus the doubling family's `q·mβ` and
//! `(q−1)·mγ`. The α-β crossover against the round-optimal family is
//! what [`select_exscan`](super::select_exscan) predicts (see
//! EXPERIMENTS.md §Perf).

use anyhow::Result;

use super::{ScanAlgorithm, ScanKind};
use crate::mpi::{Elem, OpRef, RankCtx};

/// Element range of block `b` when `m` elements split into `p` even
/// blocks: the first `m mod p` blocks take `⌈m/p⌉` elements, the rest
/// `⌊m/p⌋` (empty blocks are fine when `m < p`).
pub(crate) fn block_range(m: usize, p: usize, b: usize) -> std::ops::Range<usize> {
    let q = m / p;
    let rem = m % p;
    let start = b * q + b.min(rem);
    start..start + q + usize::from(b < rem)
}

/// Reduce-scatter/allgather-composed exclusive scan (block owners).
pub struct ExscanRsag;

impl ExscanRsag {
    /// Shared closed forms (also used by the differential harness so the
    /// instance and its check cannot diverge): `(rounds, ops-per-rank)`.
    pub fn closed_form(p: usize) -> (u32, u32) {
        if p <= 1 {
            return (0, 0);
        }
        (2 * (p as u32 - 1), p as u32 - 2)
    }
}

impl<T: Elem> ScanAlgorithm<T> for ExscanRsag {
    fn name(&self) -> &'static str {
        "rsag"
    }

    fn kind(&self) -> ScanKind {
        ScanKind::Exclusive
    }

    fn run(
        &self,
        ctx: &mut RankCtx<T>,
        input: &[T],
        output: &mut [T],
        op: &OpRef<T>,
    ) -> Result<()> {
        let (r, p, m) = (ctx.rank(), ctx.size(), input.len());
        if p <= 1 {
            return Ok(());
        }
        let op = &ctx.kernel(op);
        let my = block_range(m, p, r);
        let w = my.len();

        // Rows of this rank's owned block, rank-major j = 0..p−2 (rank
        // p−1's vector is in no exclusive prefix, so p−1 rows suffice).
        let mut rows = vec![T::filler(); (p - 1) * w];
        if r + 1 < p {
            rows[r * w..(r + 1) * w].copy_from_slice(&input[my.clone()]);
        }

        // ── Phase 1: cyclic transpose (reduce-scatter shape). Step k on
        // its own chunk lane; rank p−1 only receives. ──
        for k in 1..p {
            let round = (k - 1) as u32;
            let to = (r + k) % p;
            let from = (r + p - k) % p;
            let send_active = r + 1 < p;
            let recv_active = from + 1 < p;
            ctx.with_chunk(k as u16, |c| {
                let rrow = &mut rows[from * w..]; // row `from` (recv arm only)
                match (send_active, recv_active) {
                    (true, true) => c.sendrecv(
                        round,
                        to,
                        &input[block_range(m, p, to)],
                        from,
                        &mut rrow[..w],
                    ),
                    (true, false) => c.send(round, to, &input[block_range(m, p, to)]),
                    (false, true) => c.recv(round, from, &mut rrow[..w]),
                    (false, false) => Ok(()),
                }
            })?;
        }

        // ── Phase 2: one prefix-scan launch over the p−1 rows; row j
        // becomes V_0 ⊕ … ⊕ V_j on this block (p−2 applications). ──
        ctx.scan_rows((p - 1) as u32, op, &mut rows, w, p - 1);

        // ── Phase 3: cyclic return (allgather shape). Owner r holds
        // W_t[block r] = rows[t−1]; target rank 0 receives nothing. ──
        for k in 1..p {
            let round = (p - 1 + k - 1) as u32;
            let to = (r + k) % p;
            let from = (r + p - k) % p;
            let send_active = to != 0;
            let recv_active = r != 0;
            ctx.with_chunk(k as u16, |c| {
                let dst = block_range(m, p, from);
                match (send_active, recv_active) {
                    (true, true) => {
                        c.sendrecv(round, to, &rows[(to - 1) * w..to * w], from, &mut output[dst])
                    }
                    (true, false) => c.send(round, to, &rows[(to - 1) * w..to * w]),
                    (false, true) => c.recv(round, from, &mut output[dst]),
                    (false, false) => Ok(()),
                }
            })?;
        }
        if r >= 1 {
            output[my].copy_from_slice(&rows[(r - 1) * w..r * w]);
        }
        Ok(())
    }

    fn predicted_rounds(&self, p: usize) -> u32 {
        Self::closed_form(p).0
    }

    /// `p − 2` block-width ⊕ on **every** rank (the scan phase), so the
    /// critical rank's count equals the per-rank count.
    fn predicted_ops(&self, p: usize) -> u32 {
        Self::closed_form(p).1
    }

    fn critical_skips(&self, p: usize) -> Vec<usize> {
        // Rank p−1 receives at cyclic distance k in both phases.
        if p <= 1 {
            return vec![];
        }
        (1..p).chain(1..p).collect()
    }

    /// `2(p−1)` rounds of `⌈m/p⌉`-element messages; `p−2` ⊕ at block
    /// width — the honest bandwidth-regime schedule for the α-β-γ model.
    fn critical_schedule(&self, p: usize, m: usize) -> (Vec<usize>, u32, usize) {
        if p <= 1 {
            return (vec![], 0, m);
        }
        (
            <Self as ScanAlgorithm<T>>::critical_skips(self, p),
            Self::closed_form(p).1,
            m.div_ceil(p),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coll::validate::assert_exscan_matches;
    use crate::mpi::{ops, run_scan, Topology, WorldConfig};

    #[test]
    fn block_ranges_partition_exactly() {
        for (m, p) in [(0usize, 4usize), (3, 4), (4, 4), (10, 4), (17, 5), (100, 7), (5, 9)] {
            let mut covered = 0;
            for b in 0..p {
                let range = block_range(m, p, b);
                assert_eq!(range.start, covered, "m={m} p={p} b={b}");
                covered = range.end;
            }
            assert_eq!(covered, m, "m={m} p={p}");
        }
    }

    #[test]
    fn matches_oracle_grid() {
        for p in 2usize..=16 {
            for m in [0usize, 1, 3, 17, 40] {
                let cfg = WorldConfig::new(Topology::flat(p));
                let inputs: Vec<Vec<i64>> = (0..p)
                    .map(|r| (0..m).map(|i| ((r * 131 + i * 17) as i64) ^ 0x5A5A).collect())
                    .collect();
                let res = run_scan(&cfg, &ExscanRsag, &ops::bxor(), &inputs).unwrap();
                assert_exscan_matches(&inputs, &ops::bxor(), &res.outputs);
            }
        }
    }

    #[test]
    fn uneven_blocks_and_sums() {
        // m not divisible by p (ragged block widths) and m < p (empty
        // trailing blocks) — the partition arithmetic must stay exact.
        for (p, m) in [(7usize, 5usize), (7, 64), (7, 100), (13, 6), (9, 1000)] {
            let cfg = WorldConfig::new(Topology::flat(p));
            let inputs: Vec<Vec<i64>> = (0..p)
                .map(|r| (0..m).map(|i| (r * 31 + i * 7) as i64).collect())
                .collect();
            let res = run_scan(&cfg, &ExscanRsag, &ops::sum_i64(), &inputs).unwrap();
            assert_exscan_matches(&inputs, &ops::sum_i64(), &res.outputs);
        }
    }

    #[test]
    fn noncommutative_order() {
        use crate::coll::validate::oracle_exscan;
        use crate::mpi::Rec2;
        for p in [3usize, 5, 9, 12] {
            let m = 6; // blocks of width 0 and 1 at p > m, ragged otherwise
            let cfg = WorldConfig::new(Topology::flat(p));
            let inputs: Vec<Vec<Rec2>> = (0..p)
                .map(|r| {
                    (0..m)
                        .map(|i| {
                            Rec2::new(
                                [1.0, 0.02 * r as f32, -0.01 * i as f32, 1.0],
                                [r as f32 * 0.5, 1.0 - i as f32 * 0.25],
                            )
                        })
                        .collect()
                })
                .collect();
            let res = run_scan(&cfg, &ExscanRsag, &ops::rec2_compose(), &inputs).unwrap();
            let oracle = oracle_exscan(&inputs, &ops::rec2_compose());
            for r in 1..p {
                let e = oracle[r].as_ref().unwrap();
                for (a, b) in res.outputs[r].iter().zip(e) {
                    for i in 0..4 {
                        assert!((a.a[i] - b.a[i]).abs() < 1e-3, "p={p} r={r}");
                    }
                }
            }
        }
    }

    #[test]
    fn closed_form_rounds_and_ops() {
        for p in 2usize..=24 {
            let cfg = WorldConfig::new(Topology::flat(p)).with_trace(true);
            let inputs: Vec<Vec<i64>> =
                (0..p).map(|r| (0..10).map(|i| (r * 7 + i) as i64).collect()).collect();
            let res = run_scan(&cfg, &ExscanRsag, &ops::bxor(), &inputs).unwrap();
            let trace = res.trace.unwrap();
            let algo: &dyn ScanAlgorithm<i64> = &ExscanRsag;
            assert_eq!(trace.total_rounds(), algo.predicted_rounds(p), "rounds p={p}");
            assert_eq!(trace.last_rank_ops(), algo.predicted_ops(p), "last-rank ops p={p}");
            // Every rank scans: the max equals the closed form too.
            assert_eq!(trace.max_ops(), algo.predicted_ops(p), "max ops p={p}");
            assert!(crate::trace::check_all(&trace).is_empty(), "invariants p={p}");
        }
    }

    #[test]
    fn ops_are_m_independent() {
        // Closed-form ⊕ counts hold even at m = 0 (empty blocks): the scan
        // launch records its n−1 applications regardless of width.
        for m in [0usize, 1, 2, 31] {
            let p = 6;
            let cfg = WorldConfig::new(Topology::flat(p)).with_trace(true);
            let inputs: Vec<Vec<i64>> = (0..p).map(|r| vec![r as i64; m]).collect();
            let res = run_scan(&cfg, &ExscanRsag, &ops::bxor(), &inputs).unwrap();
            let trace = res.trace.unwrap();
            let algo: &dyn ScanAlgorithm<i64> = &ExscanRsag;
            assert_eq!(trace.total_rounds(), algo.predicted_rounds(p), "m={m}");
            assert_eq!(trace.last_rank_ops(), algo.predicted_ops(p), "m={m}");
        }
    }

    #[test]
    fn chaos_reordering_is_bit_identical() {
        use crate::mpi::ChaosConfig;
        for p in [2usize, 3, 5, 8] {
            for seed in [1u64, 2, 3] {
                let cfg = WorldConfig::new(Topology::flat(p))
                    .with_trace(true)
                    .with_chaos(ChaosConfig::new(seed ^ ((p as u64) << 8)));
                let inputs: Vec<Vec<i64>> = (0..p)
                    .map(|r| (0..9).map(|i| ((r + 1) * (i + 3)) as i64).collect())
                    .collect();
                let res = run_scan(&cfg, &ExscanRsag, &ops::bxor(), &inputs).unwrap();
                assert_exscan_matches(&inputs, &ops::bxor(), &res.outputs);
                let trace = res.trace.unwrap();
                assert!(
                    crate::trace::check_all(&trace).is_empty(),
                    "invariants p={p} seed={seed}"
                );
            }
        }
    }
}
